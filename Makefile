PYTHONPATH := src:.
export PYTHONPATH

.PHONY: check test smoke bench docs-check

test:
	python -m pytest -x -q

smoke:
	python -m benchmarks.run --smoke

# execute every code block in docs/*.md and README.md (jax-free)
docs-check:
	python tools/check_docs.py

# tier-1 tests + the graph-core smoke benchmark (its internal O(P)
# comm-storage and sparse-counter assertions make perf regressions fail
# loudly) + executable documentation
check: test smoke docs-check

bench:
	python -m benchmarks.run
