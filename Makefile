PYTHONPATH := src:.
export PYTHONPATH

.PHONY: check test smoke bench

test:
	python -m pytest -x -q

smoke:
	python -m benchmarks.run --smoke

# tier-1 tests + the graph-core smoke benchmark (its internal O(P)
# comm-storage assertion makes perf regressions fail loudly)
check: test smoke

bench:
	python -m benchmarks.run
