PYTHONPATH := src:.
export PYTHONPATH

.PHONY: check test smoke bench bench-smoke docs-check chaos-smoke \
	scenario-smoke scenario-smoke-jax detect-fused-smoke run-store-smoke

test:
	python -m pytest -x -q

# jax-free graph-core benchmark at tiny scales: the replay/simulate fast
# path and its internal O(P) comm-storage + sparse-counter + wavefront==
# sequential assertions run on every `make check`
bench-smoke:
	python -m benchmarks.run --smoke

smoke: bench-smoke

# execute every code block in docs/*.md and README.md (jax-free)
docs-check:
	python tools/check_docs.py

# seeded fault-injection run of the always-on monitor (jax-free): the
# streamed result must match the one-shot pipeline bit-identically, with
# crash recovery and degraded-fleet coverage exercised; writes
# chaos-report.txt (uploaded as a CI artifact)
chaos-smoke:
	python tools/chaos_smoke.py

# two bank scenarios end-to-end from committed real-model traces
# (jax-free): detect + backtrack + root causes scored against declared
# accuracy floors at 512/2048 procs; writes scenario-accuracy.csv
# (uploaded as a CI artifact)
scenario-smoke:
	python tools/scenario_smoke.py

# the same bank scenarios scored through the jitted jax detectors (CI
# jax job only); writes scenario-accuracy-jax.csv (uploaded as its own
# artifact) — a jax-vs-numpy accuracy divergence fails there
scenario-smoke-jax:
	python tools/scenario_smoke.py --backend jax \
		--out scenario-accuracy-jax.csv

# the fused detection kernels in Pallas interpret mode (the same kernel
# code that compiles on TPU) checked against the pure-numpy oracle;
# exits 0 with a note when jax is absent (the no-jax CI job runs this
# too)
detect-fused-smoke:
	python tools/detect_fused_smoke.py

# the multi-run regression store end-to-end (jax-free): clean-vs-faulted
# scenario runs recorded + diffed with asserted flagging precision, and
# a 65536-proc clustered record/diff with the regressed cluster required
# to contain the true culprit procs; writes run-store-smoke.txt
# (uploaded as a CI artifact)
run-store-smoke:
	python tools/run_store_smoke.py

# tier-1 tests + the graph-core smoke benchmark (perf regressions fail
# loudly) + executable documentation + the monitor chaos smoke + the
# scenario-bank accuracy smoke + the fused-kernel interpret smoke + the
# run-store regression-service smoke
check: test bench-smoke docs-check chaos-smoke scenario-smoke \
	detect-fused-smoke run-store-smoke

bench:
	python -m benchmarks.run
