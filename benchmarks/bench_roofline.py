"""Roofline table: reads the dry-run artifacts (framework deliverable g).

Per (arch x shape x mesh): the three roofline terms in seconds, the
dominant bottleneck, MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) vs the
trip-count-exact HLO dot FLOPs, and one-line guidance.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.configs import SHAPES, get as get_config
from repro.launch.mesh import PEAK_FLOPS_BF16

ARTIFACT_DIR = "artifacts/dryrun"


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.param_count(active_only=(cfg.family == "moe"))
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / chips
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch / chips


def _table(artifact_dir: str, label: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or "__opt-" in os.path.basename(path) \
                and artifact_dir == ARTIFACT_DIR:
            continue
        arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
        r = rec["roofline"]
        hlo_flops = rec["cost"]["dot_flops_per_device"]
        mf = model_flops_per_device(arch, shape, rec["chips"])
        useful = mf / hlo_flops if hlo_flops else 0.0
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        mfu_bound = (mf / PEAK_FLOPS_BF16) / bound if bound else 0.0
        rows.append((arch, shape, mesh, bound, mfu_bound))
        emit(f"roofline[{label}]/{arch}/{shape}/{mesh}", bound * 1e6,
             f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
             f"collective_s={r['collective_s']:.4f};"
             f"bottleneck={r['bottleneck']};"
             f"useful_flops_ratio={useful:.2f};"
             f"roofline_MFU_bound={100 * mfu_bound:.1f}%")
    return rows


def run() -> None:
    base = {(a, s, m): b for a, s, m, b, _ in
            _table(ARTIFACT_DIR, "baseline")}
    final_dir = "artifacts/dryrun_final"
    if os.path.isdir(final_dir):
        final = _table(final_dir, "optimized")
        gains = [(a, s, m, base[(a, s, m)] / b)
                 for a, s, m, b, _ in final
                 if (a, s, m) in base and b > 0]
        if gains:
            mean_gain = sum(g for *_, g in gains) / len(gains)
            best = max(gains, key=lambda x: x[3])
            emit("roofline/optimized_vs_baseline", 0.0,
                 f"mean_speedup={mean_gain:.2f}x;"
                 f"best={best[0]}/{best[1]}/{best[2]}={best[3]:.2f}x;"
                 f"cells={len(gains)}")


if __name__ == "__main__":
    run()
