"""Paper §VI-D: root-cause case studies on the model zoo.

Three scenarios mirroring the paper's Zeus-MP / SST / Nekbone diagnoses,
each on a REAL train-step PSG with measured base times:

  1. zeus-mp analogue — a latent per-process delay in a compute LOOP
     propagates through dependence and surfaces at the step-end
     all-reduce; ScalAna must backtrack to the injected loop, not the
     all-reduce that exposed it.
  2. sst analogue — load imbalance (uneven per-process time in one
     vertex); abnormal detection + PMU-channel (flops/bytes counters)
     identify the vertex.
  3. nekbone analogue — a non-scalable dgemm-like vertex (serial
     fraction); log-log fitting flags it and backtracking reports the
     source line.

Plus the GROUND-TRUTH SCENARIO BANK accuracy table (``case_scenario_bank``):
every committed scenario in ``repro.scenarios.SCENARIOS`` — real-model
trace x declarative fault x machine-checkable truth — runs end-to-end at
512 and 2048 processes on BOTH detection backends, and its root-cause
precision / recall / path-hit-rate are asserted against the scenario's
declared floors.  One row per (scenario, scale, backend) cell; a floor
violation raises, failing the bench run loudly.
"""
from __future__ import annotations

import time

import jax

import numpy as np

from benchmarks.common import bench_setup, emit
from repro.core import (COMM, GraphProfiler, backtrack, detect_abnormal,
                        detect_non_scalable, root_causes)
from repro.core.inject import (schedule, seeded_base_times, simulate,
                               simulate_series, vectorized_base_times)


def _profiled_psg(arch: str):
    cfg, model, step, state, batch = bench_setup(arch, scale=1)
    prof = GraphProfiler(step, (state, batch), sample_every=2)
    s = state
    for _ in range(4):
        s, _ = prof.step(s, batch)
    psg, perf = prof.psg, prof.perf_vectors()
    comm = psg.new_vertex(COMM, "psum", parent=psg.root,
                          source="optim/adamw.py:60")
    comm.comm_kind, comm.comm_bytes = "all_reduce", 8e6
    tops = [v.vid for v in psg.vertices if v.parent == psg.root]
    psg.add_edge(tops[-2], comm.vid, "data")
    psg.add_edge(psg.root, comm.vid, "control")
    base = {vid: (perf[vid].time if vid in perf else 0.0)
            for vid in range(len(psg.vertices))}
    return psg, base, comm.vid


def case_straggler_loop(arch="tinyllama-1.1b", n_procs=128) -> None:
    psg, base, comm_vid = _profiled_psg(arch)
    loops = [v.vid for v in psg.vertices
             if v.kind == "Loop" and v.vid in schedule(psg)]
    target = loops[0] if loops else schedule(psg)[0]
    t0 = time.perf_counter()
    res = simulate(psg, n_procs,
                   seeded_base_times(base, n_vertices=len(psg.vertices)),
                   inject={(17, target): 0.5})
    ab = detect_abnormal(res.ppg)
    paths = backtrack(res.ppg, [], ab)
    rcs = root_causes(paths, psg, ppg=res.ppg)
    dt = time.perf_counter() - t0
    found = any(node == (17, target) for node, _, _ in rcs)
    src = psg.vertices[target].source
    emit(f"casestudy/zeusmp_straggler/{arch}", dt * 1e6,
         f"found={found};target={src};procs={n_procs}")


def case_load_imbalance(arch="moonshot-v1-16b-a3b", n_procs=64) -> None:
    psg, base, comm_vid = _profiled_psg(arch)
    sched = schedule(psg)
    target = max((v for v in sched if psg.vertices[v].kind in
                  ("Comp", "Loop")), key=lambda v: base.get(v, 0.0))

    @vectorized_base_times
    def times(procs, vid):
        t = base.get(vid, 0.0)
        if vid == target:
            return t * (1.0 + 0.8 * (procs % 7 == 3))   # imbalanced subset
        return np.full(procs.shape, t)

    t0 = time.perf_counter()
    res = simulate(psg, n_procs, times)
    ab = detect_abnormal(res.ppg, abnorm_thd=1.3)
    dt = time.perf_counter() - t0
    hit = any(a.vid == target for a in ab)
    pmu = psg.vertices[target].flops
    emit(f"casestudy/sst_imbalance/{arch}", dt * 1e6,
         f"found={hit};pmu_flops={pmu:.2e};"
         f"target={psg.vertices[target].source}")


def case_non_scalable_dgemm(arch="yi-6b") -> None:
    psg, base, comm_vid = _profiled_psg(arch)
    sched = schedule(psg)
    target = max((v for v in sched if psg.vertices[v].kind in
                  ("Comp", "Loop")), key=lambda v: base.get(v, 0.0))

    def time_at(p, vid, n):
        t = base.get(vid, 0.0)
        if vid == target:
            return t * (0.55 + 0.45 / n)       # serial fraction (Amdahl)
        return t / n

    t0 = time.perf_counter()
    series = simulate_series(psg, [16, 32, 64, 128], time_at)
    ns = detect_non_scalable(series)
    ab = detect_abnormal(series[128])
    paths = backtrack(series[128], ns, ab)
    rcs = root_causes(paths, psg, ppg=series[128])
    dt = time.perf_counter() - t0
    flagged = any(d.vid == target for d in ns)
    in_paths = any(n[1] == target for p in paths for n in p.nodes)
    emit(f"casestudy/nekbone_dgemm/{arch}", dt * 1e6,
         f"flagged={flagged};on_root_cause_path={in_paths};"
         f"target={psg.vertices[target].source}")


def case_scenario_bank(scales=(512, 2048),
                       backends=("numpy", "jax")) -> None:
    """The scenario-bank accuracy table: scenario x scale x backend."""
    from repro.scenarios import SCENARIOS, run_and_score

    for name, sc in SCENARIOS.items():
        for n_procs in scales:
            for backend in backends:
                t0 = time.perf_counter()
                res, score = run_and_score(sc, n_procs, backend=backend)
                dt = time.perf_counter() - t0
                assert score.passes(sc.truth), (
                    f"{name} @ {n_procs} procs ({backend}) under floors: "
                    f"{score.row()} vs precision>={sc.truth.min_precision} "
                    f"recall>={sc.truth.min_recall} "
                    f"path_hit>={sc.truth.min_path_hit}")
                emit(f"casestudy/scenario/{name}/{n_procs}procs/{backend}",
                     dt * 1e6,
                     f"precision={score.precision:.3f};"
                     f"recall={score.recall:.3f};"
                     f"path_hit={score.path_hit_rate:.3f};"
                     f"channel={res.channel};trace={sc.trace}")


def run() -> None:
    case_straggler_loop()
    case_load_imbalance()
    case_non_scalable_dgemm()
    case_scenario_bank()


if __name__ == "__main__":
    run()
