"""Paper Table I + Fig. 10/13: runtime overhead.

Three measurement regimes on the same train step:
  * baseline   — compiled step only;
  * scalana    — GraphProfiler with sample_every=K (graph-guided step-space
                 sampling; the paper's 1.73–3.5%-class channel);
  * tracing    — the instrumented interpreter EVERY step (per-event timing
                 of every top-level op = the Scalasca-analogue upper bound).

Reported: % overhead vs baseline.  The paper's claim reproduced here is
the *ordering* and magnitude gap: scalana << tracing.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import bench_setup, emit, timeit
from repro.core import GraphProfiler

ARCHS_BENCH = ["tinyllama-1.1b", "mamba2-130m", "moonshot-v1-16b-a3b"]
STEPS = 16
SAMPLE_EVERY = 16


def run() -> None:
    overheads = []
    for arch in ARCHS_BENCH:
        cfg, model, step, state, batch = bench_setup(arch)
        compiled = jax.jit(step)

        def run_compiled(n=STEPS):
            s = state
            for _ in range(n):
                s, m = compiled(s, batch)
            jax.block_until_ready(m["loss"])
            return s

        t_base = timeit(run_compiled, iters=2, warmup=1) / STEPS

        prof = GraphProfiler(step, (state, batch),
                             sample_every=SAMPLE_EVERY)

        def run_scalana(n=STEPS):
            s = state
            for _ in range(n):
                s, m = prof.step(s, batch)
            jax.block_until_ready(m["loss"])
            return s

        t_scal = timeit(run_scalana, iters=2, warmup=1) / STEPS

        tracer = GraphProfiler(step, (state, batch), sample_every=1)

        def run_traced(n=4):
            s = state
            for _ in range(n):
                s, m = tracer.step(s, batch)
            jax.block_until_ready(m["loss"])
            return s

        t_trace = timeit(run_traced, iters=1, warmup=1) / 4

        ov_scal = 100 * (t_scal - t_base) / t_base
        ov_trace = 100 * (t_trace - t_base) / t_base
        overheads.append(max(ov_scal, 0.0))
        emit(f"overhead/{arch}", t_base * 1e6,
             f"scalana={ov_scal:+.1f}%;tracing={ov_trace:+.1f}%;"
             f"K={SAMPLE_EVERY}")
    emit("overhead/mean_scalana", 0.0,
         f"{sum(overheads) / len(overheads):.1f}% "
         f"(paper: 1.73% @2048 procs, 3.52% avg)")


if __name__ == "__main__":
    run()
