"""Paper Table IV: post-mortem detection cost.

Time for problematic-vertex detection + backtracking root-cause analysis
on PPGs at increasing process counts (the paper: 0.29–11.81 s at 128
procs).  The PPG comes from the real tinyllama train-step PSG with
simulated per-process perf data + an injected straggler.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import bench_setup, emit
from repro.core import (COMM, backtrack, build_psg, contract,
                        detect_abnormal, detect_non_scalable, root_causes)
from repro.core.inject import schedule, simulate, simulate_series


def run() -> None:
    cfg, model, step, state, batch = bench_setup("tinyllama-1.1b", scale=1)
    psg = build_psg(step, state, batch)
    cpsg, _ = contract(psg, max_loop_depth=10)
    comm = cpsg.new_vertex(COMM, "psum", parent=cpsg.root,
                           source="optim/adamw.py:60")
    comm.comm_kind, comm.comm_bytes = "all_reduce", 8e6
    last_comp = [v.vid for v in cpsg.vertices if v.parent == cpsg.root][-2]
    cpsg.add_edge(last_comp, comm.vid, "data")
    cpsg.add_edge(cpsg.root, comm.vid, "control")
    sched = schedule(cpsg)
    target = next(v for v in sched if cpsg.vertices[v].kind == "Comp")

    for n_procs in (128, 512, 2048):
        series = simulate_series(
            cpsg, [n_procs // 4, n_procs // 2, n_procs],
            lambda p, vid, n: (0.128 / n)
            + (0.05 if (p == 4 and vid == target) else 0.0),
            jitter=0.02)
        top = series[n_procs]
        # jax is imported here, so "auto" resolves to the jitted detect
        # backend — warm its per-shape jit caches so the measurement is
        # steady-state detection cost, not trace+compile
        detect_non_scalable(series)
        detect_abnormal(top)
        t0 = time.perf_counter()
        ns = detect_non_scalable(series)
        ab = detect_abnormal(top)
        paths = backtrack(top, ns, ab)
        rcs = root_causes(paths, cpsg, ppg=top)
        dt = time.perf_counter() - t0
        found = any(node == (4, target) for node, _, _ in rcs)
        emit(f"detect/{n_procs}procs", dt * 1e6,
             f"cost_s={dt:.2f};paths={len(paths)};"
             f"root_cause_found={found} (paper: 0.29-11.81s @128)")


if __name__ == "__main__":
    run()
