"""Paper Table I + Fig. 11: storage cost.

ScalAna's retained bytes (contracted PSG + per-vertex perf vectors +
compressed comm records) vs. what a full tracer writes (one event per op
execution per step, 64 B each) and a flat profiler (per-op counters).
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_setup, emit
from repro.core import GraphProfiler

ARCHS_BENCH = ["tinyllama-1.1b", "yi-6b", "gemma-7b", "mamba2-130m",
               "dbrx-132b", "zamba2-2.7b"]
STEPS = 32


def run() -> None:
    for arch in ARCHS_BENCH:
        cfg, model, step, state, batch = bench_setup(arch, scale=1)
        prof = GraphProfiler(step, (state, batch), sample_every=8)
        s = state
        for _ in range(STEPS):
            s, _ = prof.step(s, batch)
        ours = prof.storage_bytes()
        trace = prof.full_trace_bytes()
        profile = len(prof.psg_full.vertices) * 8 * 4   # flat counters
        emit(f"storage/{arch}", 0.0,
             f"scalana={ours/1024:.1f}KiB;"
             f"tracing={trace/2**20:.1f}MiB;"
             f"profiling={profile/1024:.1f}KiB;"
             f"ratio_trace_over_scalana={trace/max(ours,1):.0f}x")


if __name__ == "__main__":
    run()
