"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  bench_psg        Table II   (PSG size, contraction ratio)
  bench_static     Table III  (static/compile-time overhead)
  bench_overhead   Table I + Fig. 10/13 (runtime overhead)
  bench_storage    Table I + Fig. 11    (storage cost)
  bench_detect     Table IV   (post-mortem detection cost)
  bench_casestudy  §VI-D      (root-cause case studies)
  bench_roofline   deliverable (g): roofline terms from the dry-run
  bench_graph_scale  graph-core scalability (512/2048/8192 procs)

``--smoke`` runs only the fast pure-numpy graph-core benchmark at tiny
scales — the perf-regression canary wired into ``make check`` (via
``make bench-smoke``).

The graph-scale rows are snapshotted to ``BENCH_graph_scale.json``
(override with ``--json PATH``, disable with ``--json ''``) so the perf
trajectory — ``simulate_s`` / ``simulate_series_s`` / ``detect_s`` per
scale — is machine-readable across PRs.  Smoke runs only write the
snapshot when ``--json`` is passed explicitly, so tiny-scale numbers
never clobber a full-run trajectory.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import traceback


def write_snapshot(path: str, rows, smoke: bool) -> None:
    if not path or not rows:
        return
    # stamped with the run-store schema (schema_version / commit /
    # wall_time / timestamp) so a BENCH snapshot ingests directly as
    # RunStore run metadata
    from repro.runs.store import run_metadata
    payload = run_metadata(bench="graph_scale", smoke=smoke, rows=rows)
    pathlib.Path(path).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {path} ({len(rows)} rows)", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset (psg,static,overhead,"
                         "storage,detect,casestudy,roofline,graph_scale)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast mode: graph-core benchmark at tiny scales, "
                         "no jax model workloads")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="graph-scale snapshot path (default "
                         "BENCH_graph_scale.json on full runs; '' disables)")
    args = ap.parse_args()
    json_path = args.json_path
    if json_path is None:
        json_path = "" if args.smoke else "BENCH_graph_scale.json"

    from benchmarks import bench_graph_scale
    if args.smoke:
        print("name,us_per_call,derived")
        rows = bench_graph_scale.run(smoke=True)
        write_snapshot(json_path, rows, smoke=True)
        return

    from benchmarks import (bench_casestudy, bench_detect, bench_overhead,
                            bench_psg, bench_roofline, bench_serving,
                            bench_static, bench_storage)
    suite = {
        "graph_scale": bench_graph_scale.run,
        "roofline": bench_roofline.run,
        "serving": bench_serving.run,
        "psg": bench_psg.run,
        "static": bench_static.run,
        "storage": bench_storage.run,
        "detect": bench_detect.run,
        "casestudy": bench_casestudy.run,
        "overhead": bench_overhead.run,
    }
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suite.items():
        if only and name not in only:
            continue
        try:
            result = fn()
            if name == "graph_scale":
                write_snapshot(json_path, result, smoke=False)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
