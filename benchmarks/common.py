"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.configs.base import RunConfig
from repro.models.api import build_model
from repro.optim import adamw_init
from repro.optim.schedule import constant
from repro.training.trainer import TrainState, make_train_step

# benchmark workload: bigger than smoke tests so per-op dispatch overhead
# in the instrumented interpreter is amortized (the paper's overhead
# numbers are on real workloads, not toys)
BENCH_BATCH = 8
BENCH_SEQ = 128


def bench_setup(arch: str, *, batch: int = BENCH_BATCH, seq: int = BENCH_SEQ,
                scale: int = 2):
    """(cfg, model, step_fn, state, batch) for a medium-size workload."""
    cfg = get_smoke(arch)
    cfg = cfg.replace(n_layers=cfg.n_layers * scale,
                      d_model=cfg.d_model * scale,
                      n_heads=max(cfg.n_heads * scale, 0) or cfg.n_heads,
                      d_ff=cfg.d_ff * scale, remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=adamw_init(params), residual=None,
                       step=jnp.zeros((), jnp.int32))
    run = RunConfig(arch=arch)
    step = make_train_step(model, run, constant(1e-3))
    b = {"tokens": jnp.ones((batch, seq + 1), jnp.int32)}
    if cfg.family == "encdec":
        b["frames"] = jnp.ones((batch, cfg.frontend_len, cfg.d_model),
                               cfg.cdtype())
    if cfg.family == "vlm":
        b["patches"] = jnp.ones((batch, cfg.frontend_len, cfg.d_model),
                                cfg.cdtype())
    return cfg, model, step, state, b


def timeit(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median seconds per call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
