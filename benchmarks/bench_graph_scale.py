"""Graph-core scalability: build + detect + backtrack at 512..8192 procs.

The indexed-graph acceptance benchmark: a synthetic-but-realistic training
step PSG (comp chain + halo-exchange p2p ring + grouped and global
collectives) is simulated with an injected straggler, then the full
post-mortem pipeline runs at 512/2048/8192 processes.  Reported per scale:

  * wall time for PPG build (simulate), detection (numpy AND — in the full
    run, when jax is importable — the jitted backend, post-warmup), and
    backtracking;
  * ``ppg.nbytes()`` and the comm-dependence share of it — collective
    dependence is stored as participant groups, so comm bytes grow O(P),
    not O(P²) (asserted: a materialized 8192-clique would need >1 GB);
  * counter storage: the column-sparse layout vs the dense (P, V)
    equivalent (asserted smaller — counters only materialize at the
    vertex subset that defines them).

The smoke mode (`run.py --smoke` / `make check`) imports only the lazy
analysis layer of `repro.core` and never touches jax — it is the jax-free
canary.  The full run additionally times `backend="jax"` detection.
"""
from __future__ import annotations

import time

from repro.core import (COMM, COMP, PSG, backtrack, detect_abnormal,
                        detect_non_scalable, root_causes)
from repro.core.inject import simulate, simulate_series

FULL_SCALES = (512, 2048, 8192)
SMOKE_SCALES = (8, 32)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    # local copy of benchmarks.common.emit: common.py imports jax + the
    # model zoo, which this pure-numpy benchmark must not depend on
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def build_step_psg(n_comp: int = 24, n_procs_hint: int = 8) -> PSG:
    """Synthetic train-step PSG: comp chain with a p2p halo ring, a grouped
    reduce-scatter and a global all-reduce (the GSPMD shapes that matter)."""
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    prev = None
    for i in range(n_comp):
        v = g.new_vertex(COMP, f"layer{i}", parent=root.vid,
                         source=f"model.py:{100 + i}")
        v.flops = 1e12
        if prev is not None:
            g.add_edge(prev, v.vid, "data")
        g.add_edge(root.vid, v.vid, "control")
        prev = v.vid
        if i == n_comp // 3:                      # halo exchange ring
            p2p = g.new_vertex(COMM, "ppermute", parent=root.vid,
                               source="model.py:halo")
            p2p.comm_kind, p2p.comm_bytes = "ppermute", 1e6
            p2p.p2p_pairs = [(p, (p + 1) % n_procs_hint)
                             for p in range(n_procs_hint)]
            g.add_edge(prev, p2p.vid, "data")
            g.add_edge(root.vid, p2p.vid, "control")
            prev = p2p.vid
        if i == 2 * n_comp // 3:                  # grouped reduce-scatter
            rs = g.new_vertex(COMM, "reduce_scatter", parent=root.vid,
                              source="model.py:rs")
            rs.comm_kind, rs.comm_bytes = "reduce_scatter", 4e6
            half = n_procs_hint // 2 or 1
            rs.meta["replica_groups"] = [list(range(half)),
                                         list(range(half, n_procs_hint))]
            g.add_edge(prev, rs.vid, "data")
            g.add_edge(root.vid, rs.vid, "control")
            prev = rs.vid
    ar = g.new_vertex(COMM, "psum", parent=root.vid, source="optim.py:60")
    ar.comm_kind, ar.comm_bytes = "all_reduce", 8e6
    g.add_edge(prev, ar.vid, "data")
    g.add_edge(root.vid, ar.vid, "control")
    return g


def run(smoke: bool = False) -> None:
    scales = SMOKE_SCALES if smoke else FULL_SCALES
    detect_backend = "numpy"
    if not smoke:
        try:
            import jax                                        # noqa: F401
            detect_backend = "jax"
        except ImportError:
            pass
    for n_procs in scales:
        psg = build_step_psg(n_procs_hint=n_procs)
        target = next(v.vid for v in psg.vertices if v.kind == COMP)

        t0 = time.perf_counter()
        series = simulate_series(
            psg, [max(n_procs // 4, 2), max(n_procs // 2, 2), n_procs],
            lambda p, vid, n: (0.128 / n)
            + (0.05 if (p == min(4, n_procs - 1) and vid == target) else 0.0))
        build_s = time.perf_counter() - t0
        top = series[n_procs]

        if detect_backend == "jax":
            # warm up the jit caches so detect_s reports steady-state
            # latency (the online-diagnostics number), not trace+compile
            detect_non_scalable(series, backend="jax")
            detect_abnormal(top, backend="jax")
        t0 = time.perf_counter()
        ns = detect_non_scalable(series, backend=detect_backend)
        ab = detect_abnormal(top, backend=detect_backend)
        detect_s = time.perf_counter() - t0

        detect_np_s = detect_s
        if detect_backend == "jax":
            # cross-backend check + numpy comparison timing (skipped when
            # the timed pass was numpy already)
            t0 = time.perf_counter()
            ns_np = detect_non_scalable(series, backend="numpy")
            ab_np = detect_abnormal(top, backend="numpy")
            detect_np_s = time.perf_counter() - t0
            assert [d.vid for d in ns] == [d.vid for d in ns_np] \
                and [(a.proc, a.vid) for a in ab] == [(a.proc, a.vid)
                                                     for a in ab_np], \
                "jitted and numpy detection disagree"

        t0 = time.perf_counter()
        paths = backtrack(top, ns, ab)
        rcs = root_causes(paths, psg, ppg=top)
        backtrack_s = time.perf_counter() - t0

        nbytes = top.nbytes()
        comm_nbytes = top.comm.nbytes()
        clique_nbytes = 16 * sum(
            sum(len(g_) * (len(g_) - 1) for g_ in top.comm.groups_of(v.vid))
            for v in psg.by_kind(COMM))
        # O(P) guarantee: implicit groups, never the materialized clique
        assert comm_nbytes < 64 * len(psg.vertices) * n_procs, \
            f"comm storage not O(P): {comm_nbytes} bytes at {n_procs} procs"
        # column-sparse counters must beat the dense (P, V) layout
        counter_nbytes = top.perf.counter_nbytes()
        counter_dense = top.perf.counter_dense_nbytes()
        assert counter_nbytes < counter_dense, \
            f"counter storage not sparse: {counter_nbytes} >= {counter_dense}"
        found = any(node[1] == target for node, _, _ in rcs)
        emit(f"graph_scale/{n_procs}procs",
             (build_s + detect_s + backtrack_s) * 1e6,
             f"build_s={build_s:.3f};detect_s={detect_s:.4f};"
             f"detect_backend={detect_backend};detect_numpy_s="
             f"{detect_np_s:.4f};backtrack_s={backtrack_s:.3f};"
             f"ppg_bytes={nbytes};comm_bytes={comm_nbytes};"
             f"clique_equiv_bytes={clique_nbytes};"
             f"counter_bytes={counter_nbytes};"
             f"counter_dense_equiv_bytes={counter_dense};"
             f"paths={len(paths)};root_cause_found={found}")


if __name__ == "__main__":
    run()
