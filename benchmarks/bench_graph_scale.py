"""Graph-core scalability: build + simulate + detect + backtrack at 512..8192.

The indexed-graph + replay-engine acceptance benchmark: a synthetic-but-
realistic training step PSG (comp chain + halo-exchange p2p ring + grouped
and global collectives) is simulated with an injected straggler, then the
full post-mortem pipeline runs at 512/2048/8192 processes.  Reported per
scale:

  * ``simulate_series_s`` — one stacked multi-scale replay pass (the PPG
    series the detectors consume; also reported as ``build_s``);
  * ``simulate_s`` vs ``simulate_seq_s`` — the wavefront replay engine
    against the retained PR-2-style baseline (per-pair Python p2p loop +
    scalar ``base_times`` callbacks) on a p2p-HEAVY schedule; the outputs
    are asserted bit-identical and the speedup is asserted >= 10x at the
    top scale (the vectorized-replay acceptance criterion);
  * wall time for detection and backtracking — ``detect_s`` is the
    DEFAULT configuration (``backend=None``/auto, which stays on numpy
    on CPU-only jax with host stores and is asserted within 2x of
    ``detect_numpy_s``); the explicit jitted timing is
    ``detect_jax_s`` (full run, post-warmup);
  * ``detect_unfused_s`` vs ``detect_fused_s`` vs
    ``detect_cached_steady_s`` (full run) — one device-fed detect cycle
    (non-scalable over the series + abnormal at the top scale) through
    the legacy multi-dispatch kernel chain (``fused=False``), the fused
    one-launch ops with cold merged-column caches, and the steady state
    (warm historical-scale cache, a 16-row dirty write on the live
    scale); the steady state is asserted to be exactly 2 fused launches
    (``detect_cached_launches``, via the launch-count seam) and >= 3x
    faster than the unfused chain at the top scale;
  * ``backtrack_s`` vs ``backtrack_batched_s`` — the scalar walk (the
    "auto" default; frontier-batching is opt-in since it stopped winning
    here, 0.62-1.12x) against the opt-in batched engine on a
    many-straggler scenario (>= 256 flagged (proc, vertex) pairs at the
    top scale); the paths are asserted identical, and the engines are
    asserted to stay within a small factor of EACH OTHER at the top
    scale — the scalar walk's per-step ``scanned | set(path)`` copy used
    to go quadratic there (1.3s vs 0.11s batched at 8192), fixed by the
    non-copying union view in ``backtrack_one``;
  * ``shard_merge_s`` — merging an 8-host sharded replay
    (``simulate(..., shards=8)``) into one store through
    ``PerfStore.from_shards`` (contiguous fresh ranges take the
    whole-block fast path), asserted equal to the unsharded replay;
  * ``detect_device_s`` vs ``detect_host_fed_s`` (full run only) — the
    jitted abnormal detector fed from device-resident shard buffers
    (``ppg.device_view()``) against the host-fed jitted path, with the
    incremental-upload guarantee asserted: after a 16-row write, the
    per-call transfer (``device_dirty_bytes``) must scale with the dirty
    rows, not O(P·V);
  * ``ppg.nbytes()`` and the comm-dependence share of it — collective
    dependence is stored as participant groups, so comm bytes grow O(P),
    not O(P²) (asserted);
  * counter storage: the column-sparse layout vs the dense (P, V)
    equivalent (asserted smaller);
  * ``socket_ingest/*`` rows — 512/2048/4096 loopback producers
    streaming versioned wire frames through shared real TCP connections
    into one ``SocketServer`` + resident ``Monitor``, asserted bit-
    identical to one-shot detection, with the wire-level delta
    compression ratio priced against a full-row baseline;
  * ``run_store_record_s`` / ``run_store_load_s`` / ``run_store_diff_s``
    — the persistent regression service priced per scale (record the
    faulted series + detect output through the checkpoint seam, reload,
    cross-run diff; the same-run diff asserted quiet), plus a
    ``run_store_fleet`` row: a 65536-proc clean/slowed pair clustered to
    <= 64 behavior representatives on record and diffed, with >= 100x
    row compression asserted on full runs and the regressed cluster
    required to contain every true culprit proc.

``run`` returns the rows as dicts; ``benchmarks/run.py`` snapshots them to
``BENCH_graph_scale.json`` so the perf trajectory is machine-readable
across PRs.

The smoke mode (`run.py --smoke` / `make check` via `make bench-smoke`)
imports only the lazy analysis layer of `repro.core` and never touches jax
— it is the jax-free canary.  The full run additionally times
`backend="jax"` detection.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (COMM, COMP, PSG, PerfStore, backtrack,
                        detect_abnormal, detect_non_scalable, root_causes)
from repro.core.inject import simulate, simulate_series, vectorized_base_times

FULL_SCALES = (512, 2048, 8192)
SMOKE_SCALES = (8, 32)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    # local copy of benchmarks.common.emit: common.py imports jax + the
    # model zoo, which this pure-numpy benchmark must not depend on
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def halo_ring_pairs(n_procs: int) -> List:
    """Ring-neighbor exchange posted in the standard even/odd interleave
    (all even-sender pairs, then all odd-sender pairs) — how concurrent
    halo exchanges are actually scheduled.  The interleave keeps the
    order-dependent p2p semantics two wavefront rounds deep instead of an
    artificial P-deep chain."""
    return ([(p, (p + 1) % n_procs) for p in range(0, n_procs, 2)]
            + [(p, (p + 1) % n_procs) for p in range(1, n_procs, 2)])


def build_step_psg(n_comp: int = 24, n_procs_hint: int = 8) -> PSG:
    """Synthetic train-step PSG: comp chain with a p2p halo ring, a grouped
    reduce-scatter and a global all-reduce (the GSPMD shapes that matter)."""
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    prev = None
    for i in range(n_comp):
        v = g.new_vertex(COMP, f"layer{i}", parent=root.vid,
                         source=f"model.py:{100 + i}")
        v.flops = 1e12
        if prev is not None:
            g.add_edge(prev, v.vid, "data")
        g.add_edge(root.vid, v.vid, "control")
        prev = v.vid
        if i == n_comp // 3:                      # halo exchange ring
            p2p = g.new_vertex(COMM, "ppermute", parent=root.vid,
                               source="model.py:halo")
            p2p.comm_kind, p2p.comm_bytes = "ppermute", 1e6
            p2p.p2p_pairs = halo_ring_pairs(n_procs_hint)
            g.add_edge(prev, p2p.vid, "data")
            g.add_edge(root.vid, p2p.vid, "control")
            prev = p2p.vid
        if i == 2 * n_comp // 3:                  # grouped reduce-scatter
            rs = g.new_vertex(COMM, "reduce_scatter", parent=root.vid,
                              source="model.py:rs")
            rs.comm_kind, rs.comm_bytes = "reduce_scatter", 4e6
            half = n_procs_hint // 2 or 1
            rs.meta["replica_groups"] = [list(range(half)),
                                         list(range(half, n_procs_hint))]
            g.add_edge(prev, rs.vid, "data")
            g.add_edge(root.vid, rs.vid, "control")
            prev = rs.vid
    ar = g.new_vertex(COMM, "psum", parent=root.vid, source="optim.py:60")
    ar.comm_kind, ar.comm_bytes = "all_reduce", 8e6
    g.add_edge(prev, ar.vid, "data")
    g.add_edge(root.vid, ar.vid, "control")
    return g


def build_p2p_heavy_psg(n_comp: int = 8, n_procs_hint: int = 8,
                        n_halo: int = 6) -> PSG:
    """p2p-heavy schedule for the replay-engine acceptance measurement:
    ``n_halo`` halo-exchange vertices (one full ring of pairs each)
    interleaved with a comp chain, closed by a global all-reduce."""
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    prev = None
    for i in range(max(n_comp, n_halo)):
        if i < n_comp:
            v = g.new_vertex(COMP, f"stage{i}", parent=root.vid,
                             source=f"model.py:{200 + i}")
            v.flops = 1e12
            if prev is not None:
                g.add_edge(prev, v.vid, "data")
            g.add_edge(root.vid, v.vid, "control")
            prev = v.vid
        if i < n_halo:
            p2p = g.new_vertex(COMM, f"ppermute{i}", parent=root.vid,
                               source=f"model.py:halo{i}")
            p2p.comm_kind, p2p.comm_bytes = "ppermute", 1e6
            p2p.p2p_pairs = halo_ring_pairs(n_procs_hint)
            if prev is not None:
                g.add_edge(prev, p2p.vid, "data")
            g.add_edge(root.vid, p2p.vid, "control")
            prev = p2p.vid
    ar = g.new_vertex(COMM, "psum", parent=root.vid, source="optim.py:60")
    ar.comm_kind, ar.comm_bytes = "all_reduce", 8e6
    g.add_edge(prev, ar.vid, "data")
    g.add_edge(root.vid, ar.vid, "control")
    return g


def build_fleet_ppg(psg, n_procs: int, slow: float = 1.0):
    """A fleet-scale PPG written straight into a PerfStore (replaying at
    65536 procs is not the point here): comp columns with deterministic
    per-proc jitter, the heaviest vertex slowed ``slow``x on the culprit
    procs (every 1024th-plus-7), one global collective group.  Shared
    with ``tools/run_store_smoke.py``.

    Returns (ppg, slowed_vid, culprit_proc_set)."""
    from repro.core.graph import PPG

    ppg = PPG(psg, n_procs)
    procs = np.arange(n_procs)
    culprits = procs[procs % 1024 == 7]
    comp = [v.vid for v in psg.vertices if v.kind == COMP]
    heavy = comp[len(comp) // 2]
    for i, vid in enumerate(comp):
        t = np.full(n_procs, 1e-3 * (1 + i % 3))
        t *= 1.0 + 1e-4 * ((procs * 2654435761 % 97) / 97.0)  # jitter
        if vid == heavy and slow != 1.0:
            t[culprits] *= slow
        ppg.perf.set_column(vid, t, counters={"flops": 1e9})
    for v in psg.vertices:
        if v.kind == COMM:
            ppg.perf.set_column(v.vid, np.full(n_procs, 1e-4))
            ppg.comm.add_group(v.vid, tuple(range(n_procs)))
    return ppg, heavy, set(culprits.tolist())


def bench_run_store(series, ns, ab):
    """Price the regression service per scale: record the faulted series
    (scaling curves + detect output) into a throwaway RunStore through
    the checkpoint seam, reload it, and diff two records of the same
    run.  Returns (record_s, load_s, diff_s); the same-run diff is
    asserted quiet and the detect output asserted to survive the disk
    round trip."""
    import tempfile

    from repro.runs import RunStore, diff_runs

    detect = {"non_scalable": ns, "abnormal": ab}
    with tempfile.TemporaryDirectory() as d:
        store = RunStore(d)
        t0 = time.perf_counter()
        rid = store.record(series=series, detect=detect)
        record_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        a = store.load(rid)
        load_s = time.perf_counter() - t0
        b = store.load(store.record(series=series, detect=detect))
        t0 = time.perf_counter()
        diff = diff_runs(a, b)
        diff_s = time.perf_counter() - t0
    assert not diff.regressions, \
        f"same-run diff flagged {len(diff.regressions)} regressions"
    assert a.detect is not None and \
        [d.vid for d in a.detect["non_scalable"]] == [d.vid for d in ns] \
        and [(x.proc, x.vid) for x in a.detect["abnormal"]] \
        == [(x.proc, x.vid) for x in ab], \
        "detect output did not survive the run-store round trip"
    return record_s, load_s, diff_s


def bench_run_store_fleet(n_procs: int, max_clusters: int = 64,
                          smoke: bool = False) -> Dict:
    """Clustered record + cross-run diff at fleet scale: a clean and a
    culprit-slowed PPG are each compressed to <= ``max_clusters``
    behavior representatives on record, then diffed; the regressed
    cluster must contain every true culprit proc, and on full runs the
    row compression is asserted >= 100x at 65536 procs."""
    import tempfile

    from repro.runs import RunStore, diff_runs, regressed_cluster

    psg = build_step_psg(n_comp=12, n_procs_hint=8)
    t0 = time.perf_counter()
    good, heavy, culprits = build_fleet_ppg(psg, n_procs, slow=1.0)
    bad, _, _ = build_fleet_ppg(psg, n_procs, slow=2.5)
    fleet_build_s = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as d:
        store = RunStore(d)
        t0 = time.perf_counter()
        a = store.load(store.record(ppg=good, cluster=max_clusters))
        b = store.load(store.record(ppg=bad, cluster=max_clusters))
        record_cluster_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        diff = diff_runs(a, b)
        diff_s = time.perf_counter() - t0

    reps = b.clustering.n_clusters
    compression = b.clustering.compression()
    k = regressed_cluster(b, diff)
    members = set(b.clustering.members(k).tolist()) if k is not None \
        else set()
    assert reps <= max_clusters, \
        f"{reps} representatives > cap {max_clusters}"
    assert heavy in diff.regressed_vids, \
        "clustered diff missed the slowed vertex"
    assert k is not None and culprits <= members, \
        f"regressed cluster {k} missing culprits: " \
        f"{len(culprits & members)}/{len(culprits)}"
    if not smoke:
        assert compression >= 100.0, \
            f"clustered store compression {compression:.0f}x < 100x " \
            f"at {n_procs} procs"
    return {
        "name": f"graph_scale/run_store_fleet/{n_procs}procs",
        "n_procs": n_procs,
        "run_store_fleet_build_s": fleet_build_s,
        "run_store_cluster_record_s": record_cluster_s,
        "run_store_fleet_diff_s": diff_s,
        "run_store_reps": reps,
        "run_store_compression": compression,
        "run_store_regressed_cluster": -1 if k is None else int(k),
        "run_store_culprits": len(culprits),
        "run_store_culprits_in_cluster": len(culprits & members),
    }


def bench_monitor(psg, target: int, straggler: int, n_procs: int,
                  backend: str):
    """Steady-state ingest->detect latency of the always-on monitor,
    clean fleet vs ~10% of hosts behind a seeded faulty transport.

    Returns (clean_s, faulty_s, n_hosts, n_faulty) — per-step wall time
    for flush(all hosts) + poll + detect, averaged post-warmup, with the
    final streamed detection asserted identical to the one-shot run on
    the fully-assembled truth store."""
    from repro.core.shard import ShardedStore, shard_ranges
    from repro.monitor import (FaultyTransport, Monitor, QueueTransport,
                               ShardProducer)

    # 512-host ceiling (was 128): 16 procs/host at the top scale, the
    # fleet shape the socket ingest bench (below) extends further
    n_hosts = max(2, min(512, n_procs // 16 or 2))
    n_faulty = max(1, n_hosts // 10)
    ranges = shard_ranges(n_procs, n_hosts)

    @vectorized_base_times
    def time_at(procs, vid):
        t = np.full(procs.shape, 0.128 / n_procs)
        if vid == target:
            t[procs == straggler] += 0.05
        return t

    truth = simulate(psg, n_procs, time_at, shards=ranges).ppg
    ab_ref = [(a.proc, a.vid) for a in detect_abnormal(truth,
                                                       backend=backend)]
    V = len(psg.vertices)
    results = {}
    for variant in ("clean", "faulty"):
        queue = QueueTransport()
        monitor = Monitor(psg, ranges, queue, comm=truth.comm,
                          detect_every=None, backend=backend)
        prod = ShardedStore(ranges, V)
        producers = []
        for h in range(n_hosts):
            tr = queue
            if variant == "faulty" and h < n_faulty:
                # delivery through the same queue, but lossy: drops are
                # retried (no-op sleeps), lost acks resend -> duplicates
                tr = FaultyTransport(queue, seed=h, p_drop=0.3,
                                     p_ack_loss=0.2)
            producers.append(ShardProducer(h, prod.shards[h], tr,
                                           sleep=lambda s: None))

        def step():
            for h, p in enumerate(producers):
                sh = prod.shards[h]
                sh.apply_rows(truth.perf.shards[h].extract_rows(
                    np.arange(sh.n_procs)))
                p.flush(heartbeat=False)
            monitor.poll()
            return monitor.force_detect()

        step()                                   # warmup (jit, first pin)
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            report = step()
        results[variant] = (time.perf_counter() - t0) / reps
        got = [(a.proc, a.vid) for a in report.abnormal]
        assert got == ab_ref, \
            f"monitor ({variant}) diverged from one-shot: {got} != {ab_ref}"
    return results["clean"], results["faulty"], n_hosts, n_faulty


def bench_socket_ingest(n_producers: int, *, rounds: int = 3,
                        backend: str = "numpy", n_comp: int = 12,
                        conn_cap: int = 128,
                        deadline_s: float = 300.0) -> Dict:
    """Multi-thousand-host fan-in over REAL loopback sockets.

    ``n_producers`` single-proc hosts stream ``rounds`` flushes — one
    full seed round, then steady-state single-column drifts — through at
    most ``conn_cap`` shared ``SocketTransport`` connections into one
    ``SocketServer`` + resident ``Monitor``.  The streamed store and
    detection are asserted bit-identical to the one-shot run on the
    producers' own store, and the wire-level delta compression is priced
    against a full-row baseline: a second ``DeltaEncoder(compress=False)``
    encodes the SAME deltas (resends included) purely to count bytes.
    Returns the metrics row dict; ``socket_ingest_s`` covers flush +
    drain for all rounds (including the baseline pricing overhead, so
    ``socket_deltas_per_s`` is a lower bound on ingest throughput)."""
    from repro.core.graph import PPG
    from repro.core.shard import ShardedStore, shard_ranges
    from repro.monitor import (Monitor, ProducerLink, ShardProducer,
                               SocketServer, SocketTransport, stores_equal)
    from repro.monitor.chaos import _ab_key, build_chaos_psg
    from repro.monitor.producer import ShardDelta
    from repro.monitor.transport import Transport
    from repro.monitor.wire import DeltaEncoder, encode_message

    class CountingTransport(Transport):
        """Forwards to a shared socket transport; prices the SAME deltas
        as full rows so the compression ratio is measured on identical
        traffic."""

        def __init__(self, inner):
            self.inner = inner
            self.baseline = DeltaEncoder(compress=False)
            self.full_bytes = 0

        def send(self, msg):
            self.inner.send(msg)        # raises on failure: not priced
            if isinstance(msg, ShardDelta):
                self.full_bytes += len(encode_message(msg, self.baseline))

        def recv(self, max_messages=None):
            return self.inner.recv(max_messages)

        def pending(self):
            return self.inner.pending()

    psg = build_chaos_psg(n_comp)
    V = len(psg.vertices)
    n_procs = n_producers                 # one proc per host: fleet fan-in
    ranges = shard_ranges(n_procs, n_producers)
    comps = [v.vid for v in psg.vertices if v.kind == COMP]
    target = comps[len(comps) // 2]
    straggler = n_procs // 3

    server = SocketServer().start()
    monitor = Monitor(psg, ranges, server, detect_every=None,
                      backend=backend)
    prod_store = ShardedStore(ranges, V)
    n_conns = min(conn_cap, n_producers)
    conns = [SocketTransport(server.address, seed=i) for i in range(n_conns)]
    counting = [CountingTransport(tr) for tr in conns]
    producers: List = []
    links: List = []
    try:
        for h in range(n_producers):
            p = ShardProducer(h, prod_store.shards[h],
                              counting[h % n_conns], max_retries=4,
                              base_backoff=0.001, max_backoff=0.01)
            producers.append(p)
            links.append(ProducerLink(p, conns[h % n_conns],
                                      resend_after=2.0))

        def drain(deadline):
            while True:
                if all(monitor.high[h] >= producers[h].seq
                       and not monitor.parked[h]
                       for h in range(n_producers)):
                    return
                if time.monotonic() > deadline:
                    raise RuntimeError(
                        f"socket ingest did not converge at "
                        f"{n_producers} producers: "
                        f"applied={sum(monitor.high.values())}/"
                        f"{sum(p.seq for p in producers)} "
                        f"server={server.stats()}")
                monitor.poll()
                server.send_acks({h: monitor.acked_seq(h)
                                  for h in range(n_producers)})
                for tr in conns:
                    tr.recv()             # pump acks -> prune unacked
                for link in links:
                    link.tick()
                time.sleep(0.001)

        deadline = time.monotonic() + deadline_s
        marks = []                        # (wire, fullrow) after each round
        t0 = time.perf_counter()
        for r in range(1, rounds + 1):
            if r == 1:                    # full seed: every column, once
                for vid in range(1, V):
                    t = np.full(n_procs, 0.01 + 0.001 * vid)
                    if vid == target:
                        t[straggler] += 0.05
                    prod_store.set_column(vid, t, samples=1,
                                          counters={"PAPI_TOT_CYC":
                                                    1e6 * vid})
            else:                         # steady state: one column drifts
                t = np.full(n_procs, 0.01 + 0.001 * target + 1e-4 * r)
                t[straggler] += 0.05
                prod_store.set_column(target, t, samples=1)
            for p in producers:
                p.flush(heartbeat=False)
            drain(deadline)
            marks.append((sum(tr.stats["delta_bytes"] for tr in conns),
                          sum(ct.full_bytes for ct in counting)))
        socket_ingest_s = time.perf_counter() - t0
        wire_bytes, fullrow_bytes = marks[-1]
        steady_wire = wire_bytes - marks[0][0]
        steady_full = fullrow_bytes - marks[0][1]

        # ack-prune tail (not timed): deliver the final acks
        tail = time.monotonic() + 30.0
        while any(p.unacked for p in producers) \
                and time.monotonic() < tail:
            server.send_acks({h: monitor.acked_seq(h)
                              for h in range(n_producers)})
            for tr in conns:
                tr.recv()
            time.sleep(0.002)
        assert not any(p.unacked for p in producers), \
            "acks did not prune the producers' unacked buffers"

        report = monitor.force_detect()
        ref_ppg = PPG(psg, n_procs, prod_store)
        ab_ref = detect_abnormal(ref_ppg, backend=backend)
        paths_ref = backtrack(ref_ppg, [], ab_ref)
        assert [_ab_key(a) for a in report.abnormal] \
            == [_ab_key(a) for a in ab_ref], \
            "streamed detection diverged from one-shot"
        assert [(p.start_reason, p.nodes) for p in report.paths] \
            == [(p.start_reason, p.nodes) for p in paths_ref], \
            "streamed backtrack diverged from one-shot"
        assert stores_equal(monitor.store, prod_store, V), \
            "streamed store not bit-identical to the producers' store"
    finally:
        for tr in conns:
            tr.close()
        server.stop()

    deltas = sum(p.seq for p in producers)
    wire_ratio = wire_bytes / max(fullrow_bytes, 1)
    steady_ratio = steady_wire / max(steady_full, 1)
    # the acceptance bar: compressed wire traffic measurably below the
    # full-row baseline over the whole run (the steady-state ratio is
    # far smaller still — one changed column per row)
    assert wire_ratio < 0.9, \
        f"wire compression not measurably below full rows: {wire_ratio:.2f}"
    return {
        "name": f"socket_ingest/{n_producers}hosts",
        "socket_producers": n_producers,
        "socket_conns": n_conns,
        "socket_rounds": rounds,
        "socket_deltas": deltas,
        "socket_ingest_s": socket_ingest_s,
        "socket_deltas_per_s": deltas / max(socket_ingest_s, 1e-9),
        "socket_wire_bytes": wire_bytes,
        "socket_fullrow_bytes": fullrow_bytes,
        "socket_wire_ratio": wire_ratio,
        "socket_steady_ratio": steady_ratio,
        "detect_backend": backend,
    }


def run(smoke: bool = False) -> List[Dict]:
    scales = SMOKE_SCALES if smoke else FULL_SCALES
    detect_backend = "numpy"
    if not smoke:
        try:
            import jax                                        # noqa: F401
            detect_backend = "jax"
        except ImportError:
            pass
    rows: List[Dict] = []
    for n_procs in scales:
        psg = build_step_psg(n_procs_hint=n_procs)
        target = next(v.vid for v in psg.vertices if v.kind == COMP)
        straggler = min(4, n_procs - 1)

        @vectorized_base_times
        def time_at(procs, vid, n):
            t = np.full(procs.shape, 0.128 / n)
            if vid == target:
                t[procs == straggler] += 0.05
            return t

        series_scales = [max(n_procs // 4, 2), max(n_procs // 2, 2), n_procs]
        t0 = time.perf_counter()
        series = simulate_series(psg, series_scales, time_at)
        build_s = simulate_series_s = time.perf_counter() - t0
        top = series[n_procs]

        # -- replay engine: wavefront vs the PR-2-style sequential loop --
        hpsg = build_p2p_heavy_psg(n_procs_hint=n_procs)

        @vectorized_base_times
        def base_vec(procs, vid):
            return np.full(procs.shape, 0.128 / n_procs)

        def base_scalar(p, vid):
            return 0.128 / n_procs

        base_scalar.scalana_vectorized = False   # PR-2 baseline: P·V calls
        simulate(hpsg, n_procs, base_vec, p2p="wavefront")      # warmup
        t0 = time.perf_counter()
        res_wave = simulate(hpsg, n_procs, base_vec, p2p="wavefront")
        simulate_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        res_seq = simulate(hpsg, n_procs, base_scalar, p2p="sequential")
        simulate_seq_s = time.perf_counter() - t0
        assert np.array_equal(res_wave.ppg.times_matrix(),
                              res_seq.ppg.times_matrix()) \
            and res_wave.clocks == res_seq.clocks, \
            "wavefront and sequential replay disagree"
        simulate_speedup = simulate_seq_s / max(simulate_s, 1e-12)
        if not smoke and n_procs == max(scales):
            assert simulate_speedup >= 10.0, \
                f"replay engine speedup {simulate_speedup:.1f}x < 10x " \
                f"at {n_procs} procs"

        # detect_s is the DEFAULT-configuration number: backend=None
        # (auto).  On CPU-only jax with host-side stores auto resolves
        # to numpy — the dispatch-bound jitted path is ~10x slower there
        # — so this must track detect_numpy_s; the explicit jitted
        # timing lives in detect_jax_s.
        t0 = time.perf_counter()
        ns = detect_non_scalable(series)
        ab = detect_abnormal(top)
        detect_s = time.perf_counter() - t0

        detect_np_s = detect_s
        detect_jax_s = 0.0
        if detect_backend == "jax":
            t0 = time.perf_counter()
            ns_np = detect_non_scalable(series, backend="numpy")
            ab_np = detect_abnormal(top, backend="numpy")
            detect_np_s = time.perf_counter() - t0
            # warm the jit caches so detect_jax_s reports steady-state
            # latency, not trace+compile
            detect_non_scalable(series, backend="jax")
            detect_abnormal(top, backend="jax")
            t0 = time.perf_counter()
            ns_jx = detect_non_scalable(series, backend="jax")
            ab_jx = detect_abnormal(top, backend="jax")
            detect_jax_s = time.perf_counter() - t0
            assert [d.vid for d in ns] == [d.vid for d in ns_np] \
                == [d.vid for d in ns_jx] \
                and [(a.proc, a.vid) for a in ab] == [(a.proc, a.vid)
                                                     for a in ab_np] \
                == [(a.proc, a.vid) for a in ab_jx], \
                "auto, numpy and jitted detection disagree"
            import jax as _jax
            if _jax.default_backend() == "cpu":
                # the auto-backend acceptance bar: with jax importable
                # but CPU-only, the default path must stay numpy-fast
                # (the old auto->jax pessimization was ~10x slower)
                assert detect_s <= 2.0 * detect_np_s + 0.05, \
                    f"backend=auto not tracking numpy on CPU-only jax: " \
                    f"{detect_s:.4f}s vs numpy {detect_np_s:.4f}s " \
                    f"at {n_procs} procs"

        t0 = time.perf_counter()
        paths = backtrack(top, ns, ab)
        rcs = root_causes(paths, psg, ppg=top)
        pipeline_backtrack_s = time.perf_counter() - t0

        # -- frontier-batched backtracking vs the scalar reference -------
        # many distinct stragglers at a mid-chain comp vertex: hundreds of
        # flagged (proc, vertex) pairs whose causal walks are long and
        # disjoint — the regime Algorithm 1 faces at scale, where the
        # scalar walk's per-step scanned-set copies go quadratic
        comps = [v.vid for v in psg.vertices if v.kind == COMP]
        mid = comps[len(comps) // 2]

        @vectorized_base_times
        def straggle(procs, vid):
            t = np.full(procs.shape, 0.128 / n_procs)
            if vid == mid:
                sel = procs % 16 == 5
                t[sel] += 0.05 * (1.0 + (procs[sel] % 7))
            return t

        res_bt = simulate(psg, n_procs, straggle)
        ab_bt = detect_abnormal(res_bt.ppg, top_k=4096, backend="numpy")
        t0 = time.perf_counter()
        paths_scalar = backtrack(res_bt.ppg, [], ab_bt, mode="scalar")
        backtrack_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        paths_batched = backtrack(res_bt.ppg, [], ab_bt, mode="batched")
        backtrack_batched_s = time.perf_counter() - t0
        assert [(p.nodes, p.start_reason) for p in paths_scalar] == \
            [(p.nodes, p.start_reason) for p in paths_batched], \
            "batched and scalar backtracking disagree"
        backtrack_speedup = backtrack_s / max(backtrack_batched_s, 1e-12)
        if not smoke and n_procs == max(scales):
            assert len(ab_bt) >= 256, \
                f"backtrack scenario flagged only {len(ab_bt)} pairs"
            # the scalar walk (the "auto" default since batched was
            # demoted — it wins or ties at 0.62-1.12x here) used to go
            # quadratic in its per-step `scanned | set(path)` copy (1.3s
            # vs 0.11s batched at 8192/512 flagged); the union-view fix
            # keeps the two engines within a small factor of each other,
            # and a regression in EITHER direction fails this
            assert backtrack_s <= 3.0 * backtrack_batched_s + 0.05, \
                f"scalar backtrack quadratic again? {backtrack_s:.3f}s vs " \
                f"batched {backtrack_batched_s:.3f}s at {n_procs} procs " \
                f"({len(ab_bt)} flagged)"
            assert backtrack_batched_s <= 3.0 * backtrack_s + 0.05, \
                f"batched backtrack regressed? {backtrack_batched_s:.3f}s " \
                f"vs scalar {backtrack_s:.3f}s at {n_procs} procs"

        # -- streamed shard merge ---------------------------------------
        res_sh = simulate(psg, n_procs, straggle, shards=8)
        t0 = time.perf_counter()
        merged = PerfStore.from_shards(res_sh.shards, n_procs=n_procs)
        shard_merge_s = time.perf_counter() - t0
        V = len(psg.vertices)
        assert np.array_equal(merged.time_matrix(V),
                              res_bt.ppg.perf.time_matrix(V)), \
            "shard-merged store differs from single-store replay"

        # -- device-resident detection (sharded store -> device buffers) -
        # the online regime: the jitted abnormal detector feeds from
        # per-host device blocks; after the first (full) pin, each call
        # re-uploads only the rows written since the last one — transfer
        # is O(dirty rows · V), asserted below against the full pin
        detect_device_s = detect_host_fed_s = 0.0
        device_full_bytes = device_dirty_bytes = device_dirty_rows = 0
        if detect_backend == "jax":
            sh_ppg = res_sh.ppg
            ab_dev = detect_abnormal(sh_ppg, backend="jax")  # pin + warm
            view = sh_ppg.device_view()
            device_full_bytes = view.last_upload_bytes
            ab_host = detect_abnormal(res_bt.ppg, backend="jax")  # warm
            assert [(a.proc, a.vid) for a in ab_dev] == \
                [(a.proc, a.vid) for a in ab_host], \
                "device-fed and host-fed abnormal detection disagree"
            t0 = time.perf_counter()
            detect_abnormal(sh_ppg, backend="jax")     # steady state
            detect_device_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            detect_abnormal(res_bt.ppg, backend="jax")
            detect_host_fed_s = time.perf_counter() - t0
            # an online step: a handful of rows change, then detect
            dirty = np.arange(0, n_procs, max(n_procs // 16, 1))[:16]
            sh_ppg.perf.set_entries(dirty, mid, 0.5)
            detect_abnormal(sh_ppg, backend="jax")
            device_dirty_rows = view.last_upload_rows
            device_dirty_bytes = view.last_upload_bytes
            assert device_dirty_rows == dirty.size, \
                f"expected {dirty.size} dirty rows, " \
                f"uploaded {device_dirty_rows}"
            # per-call transfer scales with dirty rows, not O(P·V):
            # dirty/full ratio must track rows/P (2x layout slack)
            assert device_dirty_bytes * n_procs <= \
                2 * device_full_bytes * device_dirty_rows, \
                f"incremental upload not O(dirty rows): " \
                f"{device_dirty_bytes}B for {device_dirty_rows} rows vs " \
                f"{device_full_bytes}B full pin at {n_procs} procs"

        # -- fused one-launch detection + historical-scale cache ---------
        # one full device-fed detect CYCLE (non-scalable over the series
        # + abnormal at the top scale), three ways: the legacy unfused
        # kernel chain (fused=False — what every call paid before), the
        # fused ops with cold merged-column caches (a first call), and
        # the steady state — warm caches, a 16-row dirty write on the
        # live scale, exactly 2 fused launches (asserted via the
        # launch-count seam, not inferred)
        detect_unfused_s = detect_fused_s = detect_cached_steady_s = 0.0
        detect_cached_launches = 0
        if detect_backend == "jax":
            from repro.core import detect_jax
            from repro.kernels.detect_fused import ops as fused_ops

            def _time_at_scale(n):
                # the series straggler base, pinned to one scale (plain
                # simulate() passes (procs, vid), not the series' 3-arg
                # form)
                @vectorized_base_times
                def f(procs, vid):
                    t = np.full(procs.shape, 0.128 / n)
                    if vid == target:
                        t[procs == straggler] += 0.05
                    return t
                return f

            series_sh = {n: simulate(psg, n, _time_at_scale(n),
                                     shards=min(8, n)).ppg
                         for n in series_scales}
            top_sh = series_sh[n_procs]
            sc = sorted(series_sh)
            top_children = psg.children(psg.root)
            present = np.ones((len(sc), V), bool)  # one psg, all scales
            views = [series_sh[n].device_view() for n in sc]

            def legacy_cycle():
                ns_v = detect_jax.non_scalable_views(
                    sc, views, V, present, top_children, -1.0, 0.35,
                    0.02, "mean", fused=False)
                ab_v = detect_jax.abnormal_topk_view(
                    top_sh.device_view(), V, top_children, 1.3, 0.01,
                    20, fused=False)
                return ns_v, ab_v

            def fused_cycle():
                return (detect_non_scalable(series_sh, backend="jax"),
                        detect_abnormal(top_sh, backend="jax"))

            ns_f, ab_f = fused_cycle()          # warm fused + fill caches
            ns_l, ab_l = legacy_cycle()         # warm the legacy chain
            assert [(a.proc, a.vid) for a in ab_f] == \
                [(int(p), int(v)) for v, p in zip(*ab_l[:2])], \
                "fused and legacy device detection disagree"

            t0 = time.perf_counter()
            legacy_cycle()
            detect_unfused_s = time.perf_counter() - t0

            for v in views[:-1]:                # cold caches: a 1st call
                v.cache_merged_column(None)
            t0 = time.perf_counter()
            fused_cycle()
            detect_fused_s = time.perf_counter() - t0

            # steady state: caches warm, 16 rows written on the live
            # scale since the last detect
            dirty = np.arange(0, n_procs, max(n_procs // 16, 1))[:16]
            top_sh.perf.set_entries(dirty, mid, 0.5)
            fused_ops.reset_launch_counts()
            t0 = time.perf_counter()
            fused_cycle()
            detect_cached_steady_s = time.perf_counter() - t0
            detect_cached_launches = sum(fused_ops.launch_counts.values())
            assert dict(fused_ops.launch_counts) == \
                {"non_scalable_live": 1, "abnormal": 1}, \
                f"steady-state detect not 2 fused launches: " \
                f"{dict(fused_ops.launch_counts)}"
            if not smoke and n_procs == max(scales):
                assert detect_cached_steady_s * 3.0 <= detect_unfused_s, \
                    f"cached fused detect not >=3x the unfused chain: " \
                    f"{detect_cached_steady_s:.4f}s vs " \
                    f"{detect_unfused_s:.4f}s at {n_procs} procs"

        # -- always-on monitor: steady-state ingest -> detect latency ----
        # per-host producers stream full-row deltas into a resident
        # Monitor; one "step" is flush + poll + detect.  The faulty
        # variant puts ~10% of the hosts behind a seeded lossy transport
        # (drops retried with no-op backoff sleeps, lost acks causing
        # duplicates), so the number reports the protocol overhead of a
        # misbehaving fleet, not time.sleep.  Both variants must end bit-
        # identical to the one-shot detection on the truth store.
        (monitor_ingest_detect_s, monitor_faulty_ingest_detect_s,
         monitor_hosts, monitor_faulty_hosts) = bench_monitor(
            psg, target, straggler, n_procs, detect_backend)

        # -- run store: record / reload / diff latency per scale ---------
        # the persistent regression service priced on this scale's
        # series: one record through the checkpoint seam (curves +
        # detect output + top-scale PPG), one reload, one cross-run diff
        (run_store_record_s, run_store_load_s,
         run_store_diff_s) = bench_run_store(series, ns, ab)

        nbytes = top.nbytes()
        comm_nbytes = top.comm.nbytes()
        clique_nbytes = 16 * sum(
            sum(len(g_) * (len(g_) - 1) for g_ in top.comm.groups_of(v.vid))
            for v in psg.by_kind(COMM))
        # O(P) guarantee: implicit groups, never the materialized clique
        assert comm_nbytes < 64 * len(psg.vertices) * n_procs, \
            f"comm storage not O(P): {comm_nbytes} bytes at {n_procs} procs"
        # column-sparse counters must beat the dense (P, V) layout
        counter_nbytes = top.perf.counter_nbytes()
        counter_dense = top.perf.counter_dense_nbytes()
        assert counter_nbytes < counter_dense, \
            f"counter storage not sparse: {counter_nbytes} >= {counter_dense}"
        found = any(node[1] == target for node, _, _ in rcs)
        row = {
            "name": f"graph_scale/{n_procs}procs",
            "n_procs": n_procs,
            "simulate_s": simulate_s,
            "simulate_seq_s": simulate_seq_s,
            "simulate_speedup": simulate_speedup,
            "simulate_series_s": simulate_series_s,
            "build_s": build_s,
            "detect_s": detect_s,
            "detect_backend": detect_backend,
            "detect_numpy_s": detect_np_s,
            "detect_jax_s": detect_jax_s,
            "pipeline_backtrack_s": pipeline_backtrack_s,
            "backtrack_s": backtrack_s,
            "backtrack_batched_s": backtrack_batched_s,
            "backtrack_speedup": backtrack_speedup,
            "backtrack_flagged": len(ab_bt),
            "shard_merge_s": shard_merge_s,
            "shard_hosts": len(res_sh.shards),
            "detect_device_s": detect_device_s,
            "detect_host_fed_s": detect_host_fed_s,
            "detect_unfused_s": detect_unfused_s,
            "detect_fused_s": detect_fused_s,
            "detect_cached_steady_s": detect_cached_steady_s,
            "detect_cached_launches": detect_cached_launches,
            "monitor_ingest_detect_s": monitor_ingest_detect_s,
            "monitor_faulty_ingest_detect_s": monitor_faulty_ingest_detect_s,
            "monitor_hosts": monitor_hosts,
            "monitor_faulty_hosts": monitor_faulty_hosts,
            "run_store_record_s": run_store_record_s,
            "run_store_load_s": run_store_load_s,
            "run_store_diff_s": run_store_diff_s,
            "device_full_bytes": device_full_bytes,
            "device_dirty_bytes": device_dirty_bytes,
            "device_dirty_rows": device_dirty_rows,
            "ppg_bytes": nbytes,
            "comm_bytes": comm_nbytes,
            "clique_equiv_bytes": clique_nbytes,
            "counter_bytes": counter_nbytes,
            "counter_dense_equiv_bytes": counter_dense,
            "paths": len(paths),
            "root_cause_found": found,
        }
        rows.append(row)
        emit(row["name"],
             (build_s + detect_s + pipeline_backtrack_s) * 1e6,
             f"simulate_s={simulate_s:.4f};simulate_seq_s="
             f"{simulate_seq_s:.4f};simulate_speedup="
             f"{simulate_speedup:.1f};simulate_series_s="
             f"{simulate_series_s:.3f};detect_s={detect_s:.4f};"
             f"detect_backend={detect_backend};detect_numpy_s="
             f"{detect_np_s:.4f};detect_jax_s={detect_jax_s:.4f};"
             f"backtrack_s={backtrack_s:.3f};"
             f"backtrack_batched_s={backtrack_batched_s:.4f};"
             f"backtrack_speedup={backtrack_speedup:.1f};"
             f"backtrack_flagged={len(ab_bt)};"
             f"shard_merge_s={shard_merge_s:.4f};"
             f"detect_device_s={detect_device_s:.4f};"
             f"detect_host_fed_s={detect_host_fed_s:.4f};"
             f"detect_unfused_s={detect_unfused_s:.4f};"
             f"detect_fused_s={detect_fused_s:.4f};"
             f"detect_cached_steady_s={detect_cached_steady_s:.4f};"
             f"detect_cached_launches={detect_cached_launches};"
             f"monitor_ingest_detect_s={monitor_ingest_detect_s:.4f};"
             f"monitor_faulty_ingest_detect_s="
             f"{monitor_faulty_ingest_detect_s:.4f};"
             f"monitor_hosts={monitor_hosts};"
             f"monitor_faulty_hosts={monitor_faulty_hosts};"
             f"run_store_record_s={run_store_record_s:.4f};"
             f"run_store_load_s={run_store_load_s:.4f};"
             f"run_store_diff_s={run_store_diff_s:.4f};"
             f"device_full_bytes={device_full_bytes};"
             f"device_dirty_bytes={device_dirty_bytes};"
             f"device_dirty_rows={device_dirty_rows};"
             f"ppg_bytes={nbytes};comm_bytes={comm_nbytes};"
             f"clique_equiv_bytes={clique_nbytes};"
             f"counter_bytes={counter_nbytes};"
             f"counter_dense_equiv_bytes={counter_dense};"
             f"paths={len(paths)};root_cause_found={found}")

    # -- real-socket ingest fan-in ------------------------------------
    # 512/2048/4096 loopback producers (8/32 in smoke) through <= 128
    # shared connections; one full seed round, then steady-state drift
    # rounds.  Streamed store + detection asserted bit-identical to the
    # one-shot run; the delta-compression ratio vs the full-row wire
    # baseline lands in BENCH_graph_scale.json.
    socket_scales = SMOKE_SCALES if smoke else (512, 2048, 4096)
    for n_hosts in socket_scales:
        srow = bench_socket_ingest(n_hosts, backend=detect_backend)
        rows.append(srow)
        emit(srow["name"], srow["socket_ingest_s"] * 1e6,
             f"producers={srow['socket_producers']};"
             f"conns={srow['socket_conns']};"
             f"deltas={srow['socket_deltas']};"
             f"deltas_per_s={srow['socket_deltas_per_s']:.0f};"
             f"wire_bytes={srow['socket_wire_bytes']};"
             f"fullrow_bytes={srow['socket_fullrow_bytes']};"
             f"wire_ratio={srow['socket_wire_ratio']:.3f};"
             f"steady_ratio={srow['socket_steady_ratio']:.3f}")

    # -- run store at fleet scale: clustered record + cross-run diff --
    # a 65536-proc clean/slowed pair (2048 in smoke) compressed to <= 64
    # behavior representatives on record, then diffed; compression is
    # asserted >= 100x on full runs and the regressed cluster must hold
    # every true culprit proc
    fleet_procs = 2048 if smoke else 65536
    frow = bench_run_store_fleet(fleet_procs, smoke=smoke)
    rows.append(frow)
    emit(frow["name"],
         (frow["run_store_cluster_record_s"]
          + frow["run_store_fleet_diff_s"]) * 1e6,
         f"build_s={frow['run_store_fleet_build_s']:.4f};"
         f"cluster_record_s={frow['run_store_cluster_record_s']:.4f};"
         f"diff_s={frow['run_store_fleet_diff_s']:.4f};"
         f"reps={frow['run_store_reps']};"
         f"compression={frow['run_store_compression']:.0f};"
         f"regressed_cluster={frow['run_store_regressed_cluster']};"
         f"culprits_in_cluster={frow['run_store_culprits_in_cluster']}"
         f"/{frow['run_store_culprits']}")
    return rows


if __name__ == "__main__":
    run()
