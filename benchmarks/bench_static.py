"""Paper Table III: static (compile-time) overhead.

ScalAna-static = jaxpr trace + PSG build + contraction, measured against
the program's own XLA compilation time (the paper reports 0.28–3.01% of
LLVM compile time).
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import bench_setup, emit
from repro.configs import ARCHS
from repro.core import build_psg, contract


def run() -> None:
    fracs = []
    for arch in ARCHS:
        cfg, model, step, state, batch = bench_setup(arch, scale=1)
        t0 = time.perf_counter()
        lowered = jax.jit(step).lower(state, batch)
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        t0 = time.perf_counter()
        psg = build_psg(step, state, batch)
        cpsg, _ = contract(psg, max_loop_depth=10)
        t_static = time.perf_counter() - t0

        frac = 100 * t_static / t_compile
        fracs.append(frac)
        emit(f"static/{arch}", t_static * 1e6,
             f"compile_s={t_compile:.2f};static_pct={frac:.2f}%")
    emit("static/mean", 0.0,
         f"{sum(fracs)/len(fracs):.2f}% of compile time (paper: 0.89%)")


if __name__ == "__main__":
    run()
