"""Framework table: serving engine throughput/latency (decode path).

Not a paper table (ScalAna has no serving section) — this benchmarks the
framework's serving substrate: continuous batching through the slot
engine at smoke scale, tok/s and per-request latency percentiles.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_smoke
from repro.models.api import build_model
from repro.serving import Request, ServingEngine

ARCHS_BENCH = ["tinyllama-1.1b", "mamba2-130m", "zamba2-2.7b"]


def run() -> None:
    for arch in ARCHS_BENCH:
        cfg = get_smoke(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = ServingEngine(model, params, batch_slots=4, max_seq=96)
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=rng.integers(1, cfg.vocab_size, size=6),
                        max_new_tokens=16)
                for i in range(8)]
        t0 = time.perf_counter()
        results = engine.run(reqs)
        wall = time.perf_counter() - t0
        toks = sum(len(r.tokens) for r in results)
        lat = sorted(r.latency_s for r in results)
        emit(f"serving/{arch}", wall / max(engine.decode_steps, 1) * 1e6,
             f"tok_per_s={toks / wall:.1f};decode_steps={engine.decode_steps};"
             f"p50_ms={lat[len(lat) // 2] * 1e3:.0f};"
             f"p99_ms={lat[-1] * 1e3:.0f}")


if __name__ == "__main__":
    run()
