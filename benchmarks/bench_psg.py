"""Paper Table II: PSG size and contraction per program.

Columns: #VBC (vertices before contraction), #VAC (after), #Loop, #Branch,
#Comp, #Comm, contraction ratio.  The paper reports a 68% average vertex
reduction; we report ours over the 10-architecture model zoo (the train
step of each) — the analogue of its 11-program suite.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import bench_setup, emit
from repro.configs import ARCHS
from repro.core import build_psg, contract


def run() -> None:
    ratios = []
    for arch in ARCHS:
        cfg, model, step, state, batch = bench_setup(arch, scale=1)
        t0 = time.perf_counter()
        psg = build_psg(step, state, batch)
        build_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        cpsg, _ = contract(psg, max_loop_depth=10)
        contract_s = time.perf_counter() - t0
        s0, s1 = psg.stats(), cpsg.stats()
        ratio = 1.0 - s1["total"] / max(s0["total"], 1)
        ratios.append(ratio)
        emit(f"psg/{arch}", (build_s + contract_s) * 1e6,
             f"VBC={s0['total']};VAC={s1['total']};"
             f"Loop={s1['Loop']};Branch={s1['Branch']};"
             f"Comp={s1['Comp']};Comm={s1['Comm']};"
             f"reduction={100 * ratio:.0f}%")
    emit("psg/mean_reduction", 0.0,
         f"{100 * sum(ratios) / len(ratios):.0f}% (paper: 68%)")


if __name__ == "__main__":
    run()
