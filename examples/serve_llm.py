"""Serve a small model with batched requests through the slot engine.

Mixed greedy/sampled traffic, continuous batching, per-request latency
accounting — the serving-side end-to-end driver.

    PYTHONPATH=src python examples/serve_llm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models.api import build_model
from repro.serving import Request, ServingEngine


def main() -> None:
    cfg = get_smoke("mamba2-130m")          # O(1)-state decode family
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, batch_slots=4, max_seq=96)

    rng = np.random.default_rng(0)
    requests = [
        Request(uid=i,
                prompt=rng.integers(1, cfg.vocab_size, size=6 + i % 5),
                max_new_tokens=12,
                temperature=0.0 if i % 2 == 0 else 0.8,
                seed=42)
        for i in range(10)
    ]

    t0 = time.time()
    results = engine.run(requests)
    wall = time.time() - t0

    tokens = sum(len(r.tokens) for r in results)
    print(f"served {len(results)} requests / {tokens} tokens "
          f"in {wall:.2f}s ({tokens / wall:.1f} tok/s, "
          f"{engine.decode_steps} decode steps)")
    for r in results:
        kind = "greedy" if r.uid % 2 == 0 else "t=0.8"
        print(f"  uid={r.uid:2d} [{kind}] prompt={r.prompt_len:2d} "
              f"latency={r.latency_s * 1e3:6.0f}ms tokens={r.tokens}")

    # determinism: re-serving the same greedy request yields the same text
    again = ServingEngine(model, params, batch_slots=1, max_seq=96).run(
        [requests[0]])
    assert again[0].tokens == results[0].tokens
    print("\ngreedy determinism under batching: OK")


if __name__ == "__main__":
    main()
