"""The paper's §II motivating experiment, end to end.

A delay is injected into ONE process of a 64-process SPMD training job.
The delay is latent: it propagates through communication dependence and
surfaces as waiting time at a collective far from the cause (in NPB-CG it
surfaced at an MPI_Allreduce 3 communication hops away).  ScalAna's
backtracking algorithm recovers the true (process, source-line) root cause
from the Program Performance Graph alone.

    PYTHONPATH=src python examples/diagnose_scaling_loss.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.configs.base import RunConfig
from repro.core import (COMM, GraphProfiler, backtrack, detect_abnormal,
                        detect_non_scalable, render_report, root_causes)
from repro.core.inject import schedule, simulate, simulate_series
from repro.optim import adamw_init
from repro.optim.schedule import constant
from repro.training.trainer import TrainState, make_train_step
from repro.models.api import build_model

N_PROCS = 64
STRAGGLER = 17


def main() -> None:
    # 1. ScalAna-static + ScalAna-prof: PSG + measured per-vertex times
    cfg = get_smoke("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=adamw_init(params),
                       residual=None, step=jnp.zeros((), jnp.int32))
    batch = {"tokens": jnp.ones((4, 65), jnp.int32)}
    step = make_train_step(model, RunConfig(), constant(1e-3))
    prof = GraphProfiler(step, (state, batch), sample_every=2)
    for _ in range(4):
        state, _ = prof.step(state, batch)
    psg, perf = prof.psg, prof.perf_vectors()

    # 2. the gradient all-reduce every DP step executes (on one CPU device
    #    GSPMD inserts none, so attach the comm vertex the 64-process run
    #    would have — see repro.core.commdep.annotate_from_hlo)
    tops = [v.vid for v in psg.vertices if v.parent == psg.root]
    ar = psg.new_vertex(COMM, "psum(grads)", parent=psg.root,
                        source="src/repro/optim/adamw.py:60")
    ar.comm_kind, ar.comm_bytes = "all_reduce", 8e6
    psg.add_edge(tops[-1], ar.vid, "data")
    psg.add_edge(psg.root, ar.vid, "control")

    # 3. inject a straggler into one process of the 64-process PPG
    target = next(v for v in schedule(psg)
                  if psg.vertices[v].kind == "Loop")
    print(f"injected: +500ms on process {STRAGGLER} at "
          f"{psg.vertices[target].source} (vertex {target})\n")
    # prof.base_times() seeds the replay engine's vectorized base_times
    # channel from the measured profile (unprofiled vertices replay at 0)
    res = simulate(psg, N_PROCS, prof.base_times(),
                   inject={(STRAGGLER, target): 0.5})

    # 4. ScalAna-detect: abnormal vertices + backtracking root cause
    ab = detect_abnormal(res.ppg, abnorm_thd=1.3)
    paths = backtrack(res.ppg, [], ab)
    print(render_report(res.ppg, [], ab, paths))

    rcs = root_causes(paths, psg, ppg=res.ppg)
    hit = any(node == (STRAGGLER, target) for node, _, _ in rcs)
    print(f"\nroot cause recovered: {hit}")
    assert hit, "backtracking must locate the injected straggler"


if __name__ == "__main__":
    main()
