"""Quickstart: train a small LM with ScalAna profiling on, then render the
scaling-loss report.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_smoke
from repro.configs.base import RunConfig, ShapeConfig
from repro.core import build_ppg, detect_abnormal, backtrack, render_report
from repro.training import Trainer


def main() -> None:
    run = RunConfig(
        arch="tinyllama-1.1b",
        total_steps=12,
        learning_rate=1e-3,
        warmup_steps=2,
        scalana=True,                 # graph-guided profiling ON
        scalana_sample_every=4,       # instrument every 4th step
    )
    cfg = get_smoke(run.arch)         # reduced same-family config (CPU)
    shape = ShapeConfig("quickstart", seq_len=64, global_batch=4,
                        kind="train")

    trainer = Trainer(run, arch_cfg=cfg, shape=shape)
    trainer.train(num_steps=run.total_steps)

    losses = [m["loss"] for m in trainer.metrics_log if "loss" in m]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {run.total_steps} steps")

    # ScalAna artifacts: contracted PSG + per-vertex perf vectors
    psg, perf, storage = trainer.scalana_artifacts()
    print(f"PSG: {psg.stats()}")
    print(f"profile storage: {storage / 1024:.1f} KiB "
          f"(a full trace would be "
          f"{trainer.profiler.full_trace_bytes() / 2**20:.1f} MiB)")

    ppg = build_ppg(psg, n_procs=1, perf=perf)
    report = render_report(ppg, [], detect_abnormal(ppg), [])
    print("\n" + report)


if __name__ == "__main__":
    main()
