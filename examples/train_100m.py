"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Exercises the full production path at CPU-feasible scale: deterministic
data pipeline, AdamW + cosine schedule, gradient accumulation, async
checkpointing with auto-resume (the run is intentionally split into two
halves to prove restart-exactness), and ScalAna profiling.

    PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import argparse
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.base import ArchConfig, RunConfig, ShapeConfig
from repro.training import Trainer

# ~100M params: 12 x (d=512, ff=2048) + 32k vocab tied-ish
CFG_100M = ArchConfig(
    name="lm-100m", family="dense",
    n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
    d_ff=2048, vocab_size=32000, mlp="swiglu", loss_chunk=64,
    remat=False,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=2)
    args = ap.parse_args()

    print(f"model: {CFG_100M.param_count() / 1e6:.0f}M params")
    ckpt = tempfile.mkdtemp(prefix="ckpt100m_")
    run = RunConfig(
        arch="lm-100m", total_steps=args.steps, learning_rate=3e-4,
        warmup_steps=max(args.steps // 20, 1),
        microbatch=args.microbatch,
        checkpoint_dir=ckpt, checkpoint_every=max(args.steps // 4, 1),
        scalana=True, scalana_sample_every=50,
    )
    shape = ShapeConfig("train100m", args.seq, args.batch, "train")

    half = args.steps // 2
    t0 = time.time()
    tr1 = Trainer(run, arch_cfg=CFG_100M, shape=shape)
    tr1.train(num_steps=half)                       # first half...
    print(f"[half 1] {half} steps, "
          f"loss {tr1.metrics_log[0]['loss']:.3f} -> "
          f"{tr1.metrics_log[-1]['loss']:.3f}")

    tr2 = Trainer(run, arch_cfg=CFG_100M, shape=shape)
    tr2.train(num_steps=args.steps - half)          # ...auto-resumes
    wall = time.time() - t0
    assert tr2.metrics_log[0]["step"] == half, "must resume, not restart"

    losses = ([m["loss"] for m in tr1.metrics_log]
              + [m["loss"] for m in tr2.metrics_log])
    toks = args.steps * args.batch * args.seq
    print(f"[half 2] resumed at step {half}")
    print(f"\n{args.steps} steps / {toks / 1e6:.1f}M tokens "
          f"in {wall:.0f}s ({toks / wall:.0f} tok/s on CPU)")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(min {min(losses):.3f})")
    assert losses[-1] < losses[0], "training must reduce loss"

    if tr2.profiler is not None:
        _, _, storage = tr2.scalana_artifacts()
        ov = tr2.profiler.overhead_estimate()
        print(f"scalana: storage={storage / 1024:.1f}KiB "
              f"overhead={100 * ov.get('overhead_frac', 0):.2f}%")
    shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
