"""Unit tests for the trip-count-exact HLO walker's byte model."""
import pytest

from repro.core.hlo_walk import (CompStats, _analyze_computation,
                                 _root_opcode, _split_computations,
                                 analyze_hlo)

MODULE = """HloModule test, entry_computation_layout={()->f32[]}

%fused_dus (param_0: f32[8,128], param_1: f32[128], param_2: s32[]) -> f32[8,128] {
  %param_0 = f32[8,128]{1,0} parameter(0)
  %param_1 = f32[128]{0} parameter(1)
  %bitcast.1 = f32[1,128]{1,0} bitcast(%param_1)
  %param_2 = s32[] parameter(2)
  %constant.0 = s32[] constant(0)
  ROOT %dynamic-update-slice.1 = f32[8,128]{1,0} dynamic-update-slice(%param_0, %bitcast.1, %param_2, %constant.0)
}

%body (arg: (s32[], f32[128,128], f32[8,128])) -> (s32[], f32[128,128], f32[8,128]) {
  %arg = (s32[], f32[128,128], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %w = f32[128,128]{1,0} get-tuple-element(%arg), index=1
  %acc = f32[8,128]{1,0} get-tuple-element(%arg), index=2
  %dot.1 = f32[128,128]{1,0} dot(%w, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %slice.1 = f32[128]{0} slice(%dot.1), slice={[0:1], [0:128]}
  %upd = f32[8,128]{1,0} fusion(%acc, %slice.1, %i), kind=kLoop, calls=%fused_dus
  %one = s32[] constant(1)
  %next = s32[] add(%i, %one)
  ROOT %out = (s32[], f32[128,128], f32[8,128]) tuple(%next, %w, %upd)
}

%cond (arg2: (s32[], f32[128,128], f32[8,128])) -> pred[] {
  %arg2 = (s32[], f32[128,128], f32[8,128]) parameter(0)
  %i2 = s32[] get-tuple-element(%arg2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main () -> f32[] {
  %w0 = f32[128,128]{1,0} constant({...})
  %acc0 = f32[8,128]{1,0} constant({...})
  %i0 = s32[] constant(0)
  %init = (s32[], f32[128,128], f32[8,128]) tuple(%i0, %w0, %acc0)
  %while.1 = (s32[], f32[128,128], f32[8,128]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %res = f32[8,128]{1,0} get-tuple-element(%while.1), index=2
  %r2 = f32[128,128]{1,0} get-tuple-element(%while.1), index=1
  ROOT %sum = f32[] constant(0)
}
"""


def test_split_and_roots():
    comps = _split_computations(MODULE)
    assert set(comps) == {"fused_dus", "body", "cond", "main"}
    assert comps["main"][1] is True          # ENTRY flag
    roots = {n: _root_opcode(l) for n, (l, _) in comps.items()}
    assert roots["fused_dus"] == "dynamic-update-slice"


def test_trip_count_multiplies_dot_flops():
    cost = analyze_hlo(MODULE)
    # one 128x128x128 dot per iteration, 5 iterations
    assert cost.dot_flops == pytest.approx(5 * 2 * 128 ** 3)


def test_in_place_dus_fusion_charged_slice_only():
    cost = analyze_hlo(MODULE)
    # per iteration, the DUS fusion moves 2x the 128-float update region
    # (read+write), NOT 2x the 8x128 destination; total mem must therefore
    # be far below what full-destination accounting would give
    full_dest_per_iter = 2 * 8 * 128 * 4
    assert cost.mem_bytes < 5 * (2 * 128 * 128 * 128)  # sanity ceiling
    # the dus contribution: 2*512B/iter, not 2*4096B/iter
    # (verified indirectly: removing dot+slice leaves < 3 KiB/iter)
    st = _analyze_computation(
        _split_computations(MODULE)["body"][0],
        {"fused_dus": "dynamic-update-slice"})
    dus_line_bytes = 2 * 128 * 4
    assert any(abs(st.mem_bytes - (x + dus_line_bytes)) < 1e4
               for x in (st.mem_bytes - dus_line_bytes,))  # structural
    # direct check: body's mem includes the 1KiB dus, not the 4KiB dest
    assert st.mem_bytes < 2 * (2 * 128 * 128 * 4) + 8192


def test_dynamic_slice_charged_output_only():
    lines = ["  %big = f32[1024,1024]{1,0} broadcast(%x)",
             "  %ds = f32[4]{0} dynamic-slice(%big, %i), "
             "dynamic_slice_sizes={4}"]
    st = _analyze_computation(lines)
    # broadcast charged fully; dynamic-slice only 2x its 16B output
    assert st.mem_bytes == pytest.approx(1024 * 1024 * 4 + 2 * 16)
