"""Checkpointing (fault tolerance) + Trainer integration tests."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke_batch, smoke_bundle
from repro.checkpoint import CheckpointManager, latest_step, save_checkpoint, \
    load_checkpoint
from repro.configs import get_smoke
from repro.configs.base import RunConfig, ShapeConfig
from repro.training import Trainer


@pytest.fixture()
def tmpdir(tmp_path):
    return str(tmp_path)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layer": {"w": jax.random.normal(k, (4, 4)),
                      "b": jnp.zeros((4,))},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_load_roundtrip(tmpdir):
    tree = _tree()
    save_checkpoint(tmpdir, 3, tree, extra_meta={"note": "x"})
    assert latest_step(tmpdir) == 3
    loaded, meta = load_checkpoint(tmpdir, 3, tree)
    assert meta == {"note": "x"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_tmp_visible(tmpdir):
    save_checkpoint(tmpdir, 1, _tree())
    entries = os.listdir(tmpdir)
    assert entries == ["step_1"]
    # a stale tmp dir from a crashed writer is ignored by latest_step
    os.makedirs(os.path.join(tmpdir, "step_9.tmp"))
    assert latest_step(tmpdir) == 1


def test_shape_mismatch_rejected(tmpdir):
    save_checkpoint(tmpdir, 1, _tree())
    bad = {"layer": {"w": jnp.zeros((2, 2)), "b": jnp.zeros((4,))},
           "step": jnp.asarray(0, jnp.int32)}
    with pytest.raises(ValueError):
        load_checkpoint(tmpdir, 1, bad)


def test_keep_n_gc(tmpdir):
    mgr = CheckpointManager(tmpdir, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(), blocking=True)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmpdir))
    assert steps == [3, 4]


def test_async_save_and_restore_latest(tmpdir):
    mgr = CheckpointManager(tmpdir, keep=3)
    mgr.save(5, _tree(5))
    mgr.wait()
    out = mgr.restore_latest(_tree(0))
    assert out is not None
    step, tree, _ = out
    assert step == 5
    np.testing.assert_array_equal(np.asarray(tree["layer"]["w"]),
                                  np.asarray(_tree(5)["layer"]["w"]))


def test_mesh_agnostic_reshard_hook(tmpdir):
    """shard_fn sees every leaf (elastic re-sharding entry point)."""
    save_checkpoint(tmpdir, 1, _tree())
    seen = []
    load_checkpoint(tmpdir, 1, _tree(),
                    shard_fn=lambda k, a: seen.append(k) or a)
    assert sorted(seen) == ["layer/b", "layer/w", "step"]


# ---------------------------------------------------------------------------
# Trainer integration
# ---------------------------------------------------------------------------

def _run(tmpdir, arch="tinyllama-1.1b", steps=6, **kw):
    run = RunConfig(arch=arch, total_steps=steps, learning_rate=1e-3,
                    warmup_steps=2, checkpoint_dir=tmpdir,
                    checkpoint_every=100, scalana=False, **kw)
    cfg = get_smoke(arch)
    shape = ShapeConfig("smoke", 32, 4, "train")
    return Trainer(run, arch_cfg=cfg, shape=shape)


def test_training_reduces_loss(tmpdir):
    tr = _run(tmpdir, steps=8)
    tr.train(num_steps=8)
    losses = [m["loss"] for m in tr.metrics_log if "loss" in m]
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)


def test_resume_continues_from_checkpoint(tmpdir):
    tr = _run(tmpdir, steps=4)
    tr.train(num_steps=4)
    tr2 = _run(tmpdir, steps=4)
    tr2.train(num_steps=4)
    assert tr2.metrics_log[0]["step"] == 4      # resumed, not restarted


def test_resume_bitwise_matches_uninterrupted(tmpdir):
    """Kill-and-restart equals an uninterrupted run (data determinism +
    full state in the checkpoint)."""
    other = tmpdir + "_b"
    tr_once = _run(other, steps=8)
    tr_once.train(num_steps=8)

    tr_a = _run(tmpdir, steps=8)
    tr_a.train(num_steps=4)                     # "crash" after 4
    tr_b = _run(tmpdir, steps=8)
    state = tr_b.train(num_steps=4)             # restart, 4 more

    uninterrupted = [m["loss"] for m in tr_once.metrics_log][4:]
    resumed = [m["loss"] for m in tr_b.metrics_log]
    np.testing.assert_allclose(resumed, uninterrupted, rtol=1e-5)
    shutil.rmtree(other, ignore_errors=True)


def test_grad_accumulation_matches_single_batch(tmpdir):
    """microbatch=2 gradient == full-batch gradient (same total step)."""
    arch = "tinyllama-1.1b"
    t1 = _run(tmpdir + "_1", steps=1)
    t2 = _run(tmpdir + "_2", steps=1, microbatch=2)
    s1 = t1.train(num_steps=2, resume=False)
    s2 = t2.train(num_steps=2, resume=False)
    l1 = [m["loss"] for m in t1.metrics_log]
    l2 = [m["loss"] for m in t2.metrics_log]
    np.testing.assert_allclose(l1, l2, rtol=2e-3)


def test_grad_compress_trains(tmpdir):
    tr = _run(tmpdir, steps=6, grad_compress=True)
    tr.train(num_steps=6)
    losses = [m["loss"] for m in tr.metrics_log if "loss" in m]
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)


def test_scalana_hooks_collect(tmpdir):
    run = RunConfig(arch="tinyllama-1.1b", total_steps=6, warmup_steps=2,
                    scalana=True, scalana_sample_every=3)
    cfg = get_smoke("tinyllama-1.1b")
    tr = Trainer(run, arch_cfg=cfg, shape=ShapeConfig("smoke", 32, 4, "train"))
    tr.train(num_steps=6)
    psg, perf, storage = tr.scalana_artifacts()
    assert psg.stats()["total"] > 5
    assert any(v.samples > 0 for v in perf.values())
    assert 0 < storage < 10 * 2**20      # KBs-to-MBs, not GBs
    assert len(tr.step_wall_times) == 6
