"""Assigned-architecture configs: exact published numbers + smoke reduction."""
import pytest

from repro.configs import ARCHS, SHAPES, get, get_smoke, shape_applicable

# (arch, layers, d_model, heads, kv_heads, d_ff, vocab) from the assignment
ASSIGNED = {
    "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
    "yi-6b": (32, 4096, 32, 4, 11008, 64000),
    "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
    "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
    "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
    "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
    "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
    "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
}


def test_all_archs_registered():
    assert set(ARCHS) == set(ASSIGNED)


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_exact_published_numbers(arch):
    cfg = get(arch)
    L, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.vocab_size == v
    if h:
        assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff


def test_family_extras():
    assert get("mamba2-130m").family == "ssm"
    assert get("mamba2-130m").ssm_state == 128
    assert get("zamba2-2.7b").family == "hybrid"
    assert get("zamba2-2.7b").ssm_state == 64
    assert get("moonshot-v1-16b-a3b").n_experts == 64
    assert get("moonshot-v1-16b-a3b").experts_per_token == 6
    assert get("dbrx-132b").n_experts == 16
    assert get("dbrx-132b").experts_per_token == 4
    assert get("gemma-7b").resolved_head_dim() == 256
    assert get("gemma-7b").mlp == "geglu"
    assert get("nemotron-4-15b").mlp == "relu2"
    assert get("seamless-m4t-medium").family == "encdec"
    assert get("seamless-m4t-medium").enc_layers > 0
    assert get("internvl2-2b").family == "vlm"


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_smoke_is_same_family_but_small(arch):
    full, smoke = get(arch), get_smoke(arch)
    assert smoke.family == full.family
    assert smoke.n_layers < full.n_layers
    assert smoke.d_model < full.d_model
    assert smoke.vocab_size < full.vocab_size
    if full.family == "moe":
        assert 0 < smoke.n_experts <= full.n_experts
        assert smoke.experts_per_token <= smoke.n_experts


def test_shapes_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_applicability():
    ok, _ = shape_applicable(get("mamba2-130m"), SHAPES["long_500k"])
    assert ok
    ok, _ = shape_applicable(get("zamba2-2.7b"), SHAPES["long_500k"])
    assert ok
    for arch in ("tinyllama-1.1b", "gemma-7b", "dbrx-132b"):
        ok, why = shape_applicable(get(arch), SHAPES["long_500k"])
        assert not ok and "sub-quadratic" in why


def test_param_counts_near_published():
    # sanity: 6N within a factor-of-2 band of the published sizes
    expect = {"tinyllama-1.1b": 1.1e9, "yi-6b": 6e9, "gemma-7b": 8.5e9,
              "nemotron-4-15b": 15e9, "mamba2-130m": 130e6,
              "dbrx-132b": 132e9, "zamba2-2.7b": 2.7e9}
    for arch, n in expect.items():
        got = get(arch).param_count()
        assert 0.5 * n < got < 2.2 * n, (arch, got, n)
