"""Problematic-vertex detection (§IV-A): unit + property tests."""
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (COMM, COMP, PSG, build_ppg, detect_abnormal,
                        detect_non_scalable, fit_loglog)
from repro.core.graph import PerfVector
from repro.core.inject import simulate_series


def _linear_psg(n_comp=6, with_comm=True):
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    prev = None
    for i in range(n_comp):
        v = g.new_vertex(COMP, f"comp{i}", parent=root.vid,
                         source=f"model.py:{10 + i}")
        v.flops = 100.0
        if prev is not None:
            g.add_edge(prev, v.vid, "data")
        g.add_edge(root.vid, v.vid, "control")
        prev = v.vid
    if with_comm:
        c = g.new_vertex(COMM, "psum", parent=root.vid, source="step.py:42")
        c.comm_kind, c.comm_bytes = "all_reduce", 1e6
        g.add_edge(prev, c.vid, "data")
        g.add_edge(root.vid, c.vid, "control")
    return g


def test_fit_loglog_recovers_slope():
    scales = [4, 8, 16, 32, 64]
    for b in (-1.0, -0.5, 0.0, 0.7):
        times = [2.0 * p ** b for p in scales]
        a, slope = fit_loglog(scales, times)
        assert slope == pytest.approx(b, abs=1e-6)
        assert a == pytest.approx(2.0, rel=1e-6)


def test_non_scalable_detects_amdahl_vertex():
    psg = _linear_psg()
    bad = 3       # vertex with a serial fraction

    def time_at(p, vid, n):
        v = psg.vertices[vid]
        if v.kind == COMM:
            return 0.0
        if vid == bad:
            return 1.0 * (0.6 + 0.4 / n)     # Amdahl
        return 1.0 / n

    series = simulate_series(psg, [4, 8, 16, 32], time_at)
    found = detect_non_scalable(series)
    assert found, "must detect the serial-fraction vertex"
    vids = [d.vid for d in found]
    assert bad in vids
    top = found[0]
    assert top.vid in (bad,) or top.kind == "Comm"
    assert top.source


def test_non_scalable_clean_program_no_flags():
    psg = _linear_psg(with_comm=False)

    def time_at(p, vid, n):
        return 1.0 / n                        # perfect strong scaling

    series = simulate_series(psg, [4, 8, 16, 32], time_at)
    found = detect_non_scalable(series)
    assert not found


@pytest.mark.parametrize("strategy", ["mean", "median", "max", "cluster"])
def test_merge_strategies_all_work(strategy):
    psg = _linear_psg()

    def time_at(p, vid, n):
        v = psg.vertices[vid]
        if v.kind == COMM:
            return 0.0
        base = 1.0 / n
        return base * (2.0 if (p == 0 and vid == 2) else 1.0)

    series = simulate_series(psg, [4, 8, 16], time_at)
    # just exercise every merge strategy end-to-end
    detect_non_scalable(series, strategy=strategy)


def test_abnormal_detects_straggler_process():
    psg = _linear_psg()
    perf = {p: {v.vid: PerfVector(time=0.1) for v in psg.vertices
                if v.kind == COMP} for p in range(8)}
    perf[5][2] = PerfVector(time=0.5)          # straggler: proc 5, vertex 2
    ppg = build_ppg(psg, 8, perf)
    found = detect_abnormal(ppg, abnorm_thd=1.3)
    assert found
    assert (found[0].proc, found[0].vid) == (5, 2)
    assert found[0].ratio == pytest.approx(5.0)


def test_abnormal_threshold_respected():
    psg = _linear_psg()
    perf = {p: {v.vid: PerfVector(time=0.1) for v in psg.vertices
                if v.kind == COMP} for p in range(8)}
    perf[5][2] = PerfVector(time=0.12)         # only 1.2x: below 1.3 thd
    ppg = build_ppg(psg, 8, perf)
    assert not detect_abnormal(ppg, abnorm_thd=1.3)
    assert detect_abnormal(ppg, abnorm_thd=1.1)


@settings(max_examples=25, deadline=None)
@given(
    straggler=st.integers(0, 7),
    vid=st.integers(1, 6),
    ratio=st.floats(1.5, 20.0),
)
def test_abnormal_property_injected_always_found(straggler, vid, ratio):
    psg = _linear_psg()
    perf = {p: {v.vid: PerfVector(time=0.1) for v in psg.vertices
                if v.kind == COMP} for p in range(8)}
    perf[straggler][vid] = PerfVector(time=0.1 * ratio)
    ppg = build_ppg(psg, 8, perf)
    found = detect_abnormal(ppg, abnorm_thd=1.3)
    assert any((a.proc, a.vid) == (straggler, vid) for a in found)


# ---------------------------------------------------------------------------
# backend validation + degraded-fleet row masks
# ---------------------------------------------------------------------------

def test_unknown_backend_raises_with_valid_values_listed():
    psg = _linear_psg()
    perf = {p: {v.vid: PerfVector(time=0.1) for v in psg.vertices
                if v.kind == COMP} for p in range(4)}
    ppg = build_ppg(psg, 4, perf)
    with pytest.raises(ValueError, match=r"'numpy', 'jax', 'auto'"):
        detect_abnormal(ppg, backend="torch")
    # case/whitespace are forgiven, not errors
    assert detect_abnormal(ppg, backend="  NumPy ") == \
        detect_abnormal(ppg, backend="numpy")


def test_env_backend_validated_and_attributed(monkeypatch):
    psg = _linear_psg()
    perf = {p: {v.vid: PerfVector(time=0.1) for v in psg.vertices
                if v.kind == COMP} for p in range(4)}
    ppg = build_ppg(psg, 4, perf)
    monkeypatch.setenv("SCALANA_DETECT_BACKEND", "cuda")
    with pytest.raises(ValueError,
                       match=r"\(from SCALANA_DETECT_BACKEND\): 'cuda'"):
        detect_abnormal(ppg)
    monkeypatch.setenv("SCALANA_DETECT_BACKEND", "numpy")
    detect_abnormal(ppg)                       # valid value passes through


def test_auto_backend_prefers_numpy_on_cpu_only_jax(monkeypatch):
    """Merely having jax importable must no longer flip auto onto the
    jitted path: on CPU-only jax with host-side stores the dispatch
    overhead makes it ~10x slower than numpy.  auto picks jax only when
    the data is device-resident (device_live) or a real accelerator is
    the default backend; explicit 'jax' (arg or env) still forces it."""
    jax = pytest.importorskip("jax")
    from repro.core.detect import _resolve_backend

    monkeypatch.delenv("SCALANA_DETECT_BACKEND", raising=False)
    if jax.default_backend() != "cpu":
        pytest.skip("accelerator present; auto legitimately routes to jax")
    assert "jax" in sys.modules
    assert _resolve_backend("auto") is None
    assert _resolve_backend(None) is None
    # a live DeviceShardView opts auto back into the jitted path
    assert _resolve_backend("auto", device_live=True) is not None
    # explicit request always wins over the CPU heuristic
    assert _resolve_backend("jax") is not None
    monkeypatch.setenv("SCALANA_DETECT_BACKEND", "jax")
    assert _resolve_backend(None) is not None


def test_proc_mask_excludes_rows_exactly():
    """Masked detection == one-shot on a store that never held the dead
    rows (exclusion, not zero-pollution: zeros would shift the median)."""
    psg = _linear_psg()
    perf = {p: {v.vid: PerfVector(time=0.1) for v in psg.vertices
                if v.kind == COMP} for p in range(8)}
    perf[5][2] = PerfVector(time=0.5)          # straggler on a DEAD proc
    perf[2][3] = PerfVector(time=0.4)          # straggler on a live proc
    ppg = build_ppg(psg, 8, perf)
    mask = np.ones(8, bool)
    mask[4:6] = False
    live = np.nonzero(mask)[0]
    sub = build_ppg(psg, 6, {i: perf[int(p)] for i, p in enumerate(live)})
    got = detect_abnormal(ppg, proc_mask=mask, backend="numpy")
    want = detect_abnormal(sub, backend="numpy")
    assert got, "live straggler must still be found"
    assert [(a.vid, a.proc, a.time, a.typical, a.ratio) for a in got] == \
        [(a.vid, int(live[a.proc]), a.time, a.typical, a.ratio)
         for a in want]
    assert all(a.proc != 5 for a in got)       # dead straggler is silent


def test_proc_mask_shape_mismatch_raises():
    psg = _linear_psg()
    perf = {p: {v.vid: PerfVector(time=0.1) for v in psg.vertices
                if v.kind == COMP} for p in range(4)}
    ppg = build_ppg(psg, 4, perf)
    with pytest.raises(ValueError, match="proc_mask"):
        detect_abnormal(ppg, proc_mask=np.ones(7, bool))


def test_non_scalable_proc_mask_subsets_reference_scale():
    series = simulate_series(_linear_psg(), [4, 8, 16],
                             lambda p, vid, n: 0.05 * n + 0.01 * vid)
    mask = np.ones(16, bool)                   # reference = largest scale
    mask[3:7] = False
    out = detect_non_scalable(series, proc_mask=mask)
    all_live = detect_non_scalable(series, proc_mask=np.ones_like(mask))
    ref = detect_non_scalable(series)
    assert [(d.vid, d.slope, d.share) for d in all_live] == \
        [(d.vid, d.slope, d.share) for d in ref]
    # empty live set: nothing to diagnose, never a crash
    assert detect_non_scalable(series, proc_mask=np.zeros_like(mask)) == []
    assert isinstance(out, list)
