"""Vectorized replay engine: wavefront p2p rounds vs the retained
sequential reference, the vectorized base_times channel, one-pass
multi-scale series, the PerfStore.set_entries scatter API, and the
SCALANA_DETECT_F32 kernel variant.

The sequential per-pair executor is the pre-vectorization semantics (plus
the sender-accumulation fix), so ``wavefront == sequential`` — asserted
BITWISE on clocks, times, and counters — pins the batched engine to the
order-dependent reference."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import COMM, COMP, PPG, PSG, build_ppg, detect_non_scalable
from repro.core.detect import _merge_matrix
from repro.core.graph import PerfStore, PerfVector
from repro.core.inject import (_p2p_rounds_greedy, default_comm_time,
                               p2p_rounds, schedule, seeded_base_times,
                               simulate, simulate_series,
                               vectorized_base_times)


# ---------------------------------------------------------------------------
# random replay scenarios
# ---------------------------------------------------------------------------

@st.composite
def replay_psg(draw):
    """Random schedule of comp / p2p / collective vertices.  p2p pair
    lists include chains (repeated processes), self-pairs and
    out-of-range processes."""
    n_procs = draw(st.integers(2, 10))
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    prev = None
    for i in range(draw(st.integers(2, 8))):
        kind = draw(st.sampled_from([COMP, COMP, "p2p", "coll"]))
        if kind == COMP:
            v = g.new_vertex(COMP, f"c{i}", parent=root.vid)
            v.flops = 1e9
        elif kind == "coll":
            v = g.new_vertex(COMM, f"psum{i}", parent=root.vid)
            v.comm_kind, v.comm_bytes = "all_reduce", 1e4
            if draw(st.booleans()) and n_procs >= 4:
                half = n_procs // 2
                v.meta["replica_groups"] = [list(range(half)),
                                            list(range(half, n_procs))]
        else:
            v = g.new_vertex(COMM, f"pp{i}", parent=root.vid)
            v.comm_kind, v.comm_bytes = "ppermute", 1e3
            v.p2p_pairs = [(draw(st.integers(0, n_procs)),
                            draw(st.integers(0, n_procs)))
                           for _ in range(draw(st.integers(1, 12)))]
        g.add_edge(root.vid, v.vid, "control")
        if prev is not None:
            g.add_edge(prev, v.vid, "data")
        prev = v.vid
    return g, n_procs


def _assert_same_sim(a, b, n_vertices):
    assert a.clocks == b.clocks                      # bitwise: list of f64
    assert np.array_equal(a.ppg.times_matrix(), b.ppg.times_matrix())
    assert np.array_equal(a.ppg.perf.samples[:, :n_vertices],
                          b.ppg.perf.samples[:, :n_vertices])
    for name in ("wait_s", "comm_bytes", "flops"):
        assert np.array_equal(a.ppg.perf.counter_matrix(name, n_vertices),
                              b.ppg.perf.counter_matrix(name, n_vertices))
    assert a.ppg.meta["makespan"] == b.ppg.meta["makespan"]


@settings(max_examples=25, deadline=None)
@given(data=replay_psg(), seed=st.integers(0, 10**6), jit=st.booleans())
def test_wavefront_matches_sequential_bitwise(data, seed, jit):
    """The tentpole property: wavefront-round replay produces IDENTICAL
    clocks, times, wait_s and PPG data to the retained sequential
    reference, for arbitrary pair orders (chains, self-pairs)."""
    g, n_procs = data
    V = len(g.vertices)

    def base(p, vid):                    # elementwise: works on both paths
        return 0.01 * ((p * 7 + vid) % 5 + 1)

    kw = dict(inject={(min(1, n_procs - 1), 1): 0.3},
              jitter=0.05 if jit else 0.0, seed=seed)
    wave = simulate(g, n_procs, base, p2p="wavefront", **kw)
    seq = simulate(g, n_procs, base, p2p="sequential", **kw)
    auto = simulate(g, n_procs, base, p2p="auto", **kw)
    _assert_same_sim(wave, seq, V)
    _assert_same_sim(auto, seq, V)


@settings(max_examples=30, deadline=None)
@given(n_procs=st.integers(1, 12), n_pairs=st.integers(0, 30),
       seed=st.integers(0, 10**6))
def test_p2p_rounds_match_greedy_reference(n_procs, n_pairs, seed):
    """Vectorized peel == scalar greedy coloring, and rounds are valid:
    within a round no process appears in two pairs, and each process's
    pairs keep their original relative order across rounds."""
    rng = np.random.default_rng(seed)
    pairs = [(int(a), int(b))
             for a, b in rng.integers(0, n_procs + 2, (n_pairs, 2))]
    got = p2p_rounds(pairs, n_procs)
    ref = _p2p_rounds_greedy(pairs, n_procs)
    assert len(got) == len(ref)
    for (gs, gd), (rs, rd) in zip(got, ref):
        assert np.array_equal(gs, rs) and np.array_equal(gd, rd)
    flat = []
    for gs, gd in got:
        used = list(gs) + [d for s, d in zip(gs, gd) if s != d]
        assert len(used) == len(set(used)), "process appears twice in round"
        flat.extend(zip(gs.tolist(), gd.tolist()))
    kept = [(s, d) for s, d in pairs if s < n_procs and d < n_procs]
    assert sorted(flat) == sorted(kept)


def test_p2p_rounds_bail_on_degenerate_chain():
    """A ring in natural order is a P-deep dependence chain: every round
    would hold one pair, so bail=True reports None (the dispatcher then
    uses the sequential executor) while the interleaved posting order
    colors in two rounds."""
    n = 128
    chain = [(p, (p + 1) % n) for p in range(n)]
    assert p2p_rounds(chain, n, bail=True) is None
    assert len(p2p_rounds(chain, n)) == n
    interleaved = ([(p, (p + 1) % n) for p in range(0, n, 2)]
                   + [(p, (p + 1) % n) for p in range(1, n, 2)])
    assert len(p2p_rounds(interleaved, n)) == 2


# ---------------------------------------------------------------------------
# sender-side accounting (the under-recording fix)
# ---------------------------------------------------------------------------

def _one_p2p_psg(pairs):
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    v = g.new_vertex(COMM, "ppermute", parent=root.vid)
    v.comm_kind, v.comm_bytes = "ppermute", 1e3
    v.p2p_pairs = pairs
    g.add_edge(root.vid, v.vid, "control")
    return g, v


@pytest.mark.parametrize("p2p", ["wavefront", "sequential"])
def test_p2p_sender_time_accumulates_across_pairs(p2p):
    """A process sending via several pairs records its TOTAL send time
    (one tc per pair), not a single tc — the PR-2 under-recording fix."""
    g, v = _one_p2p_psg([(0, 1), (0, 2)])
    res = simulate(g, 3, lambda p, vid: 0.0, p2p=p2p)
    tc = default_comm_time(v, 3, [0, 1])
    t = res.ppg.times_matrix()
    wait = res.ppg.perf.counter_matrix("wait_s", len(g.vertices))
    assert t[0, v.vid] == 2 * tc                  # two sends
    assert wait[0, v.vid] == 0.0
    assert t[1, v.vid] == tc                      # first receive: no wait
    # second receive: proc 0's clock already advanced one tc
    assert t[2, v.vid] == 2 * tc
    assert wait[2, v.vid] == tc


@pytest.mark.parametrize("p2p", ["wavefront", "sequential"])
def test_p2p_chain_within_vertex(p2p):
    """Self-chain 0→1→2 in ONE vertex: proc 1 receives then sends, so its
    time is (wait + tc) + tc and its clock advance matches its time."""
    g, v = _one_p2p_psg([(0, 1), (1, 2)])
    res = simulate(g, 3, lambda p, vid: 0.0, p2p=p2p)
    tc = default_comm_time(v, 3, [0, 1])
    t = res.ppg.times_matrix()
    assert t[1, v.vid] == 2 * tc                  # receive tc + send tc
    assert t[2, v.vid] == 2 * tc                  # waited tc, then tc
    assert res.clocks[1] == t[1, v.vid]
    assert res.ppg.perf.counter_matrix(
        "comm_bytes", len(g.vertices))[1, v.vid] == 2 * v.comm_bytes


def test_pairs_cache_sees_inplace_mutation():
    """Regression: in-place element edits of p2p_pairs (same list object,
    same length) must invalidate the cached pair array — wavefront and
    sequential replay must keep agreeing after the edit."""
    g, v = _one_p2p_psg([(0, 1), (2, 3)])
    simulate(g, 4, lambda p, vid: 0.0, p2p="wavefront")   # warm the cache
    v.p2p_pairs[1] = (1, 2)                               # now a chain
    wave = simulate(g, 4, lambda p, vid: 0.0, p2p="wavefront")
    seq = simulate(g, 4, lambda p, vid: 0.0, p2p="sequential")
    _assert_same_sim(wave, seq, len(g.vertices))
    tc = default_comm_time(v, 4, [0, 1])
    assert wave.ppg.times_matrix()[1, v.vid] == 2 * tc    # receive + send


def test_self_pair_is_handled():
    g, v = _one_p2p_psg([(1, 1), (0, 1)])
    for mode in ("wavefront", "sequential"):
        res = simulate(g, 2, lambda p, vid: 0.0, p2p=mode)
        tc = default_comm_time(v, 2, [0, 1])
        # self pair: receive tc + send tc; then a real receive adds more
        assert res.ppg.times_matrix()[1, v.vid] == pytest.approx(3 * tc)
        assert res.clocks[1] == pytest.approx(2 * tc)


# ---------------------------------------------------------------------------
# one-pass multi-scale series
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(data=replay_psg(), seed=st.integers(0, 10**6))
def test_series_matches_per_scale_simulate_bitwise(data, seed):
    g, n_procs = data
    scales = [2, 3, n_procs + 1]

    def time_at(p, vid, n):
        return 0.01 * ((p + vid) % 3 + 1) / n

    series = simulate_series(g, scales, time_at, jitter=0.02, seed=seed)
    for n in scales:
        ref = simulate(g, n, lambda p, vid: time_at(p, vid, n),
                       jitter=0.02, seed=seed + n)
        assert np.array_equal(series[n].times_matrix(),
                              ref.ppg.times_matrix())
        assert series[n].meta["makespan"] == ref.ppg.meta["makespan"]


def test_series_is_single_stacked_pass():
    """The acceptance probe: over {512..8192} the vertex schedule is
    walked ONCE — per scheduled vertex every scale advances before the
    next vertex, instead of S sequential passes over the schedule."""
    scales = (512, 1024, 2048, 4096, 8192)
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    prev = None
    for i in range(3):
        v = g.new_vertex(COMP, f"c{i}", parent=root.vid)
        g.add_edge(root.vid, v.vid, "control")
        if prev is not None:
            g.add_edge(prev, v.vid, "data")
        prev = v.vid
    ar = g.new_vertex(COMM, "psum", parent=root.vid)
    ar.comm_kind, ar.comm_bytes = "all_reduce", 1e6
    g.add_edge(prev, ar.vid, "data")
    g.add_edge(root.vid, ar.vid, "control")

    calls = []

    @vectorized_base_times
    def probe(procs, vid, n):
        calls.append((vid, n, procs.size))
        return 0.01

    series = simulate_series(g, scales, probe)
    assert sorted(series) == list(scales)
    comp_sched = [vid for vid in schedule(g)
                  if g.vertices[vid].kind != COMM]
    # exactly one vectorized call per (scheduled comp vertex, scale) ...
    assert len(calls) == len(scales) * len(comp_sched)
    assert all(size == n for _, n, size in calls)
    # ... grouped per vertex in schedule order: the stacked-pass signature
    assert [vid for vid, _, _ in calls] == \
        [vid for vid in comp_sched for _ in scales]
    assert [n for _, n, _ in calls] == list(scales) * len(comp_sched)


# ---------------------------------------------------------------------------
# base_times channel: shim + seeding
# ---------------------------------------------------------------------------

def _comp_chain(n_comp=3):
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    for i in range(n_comp):
        v = g.new_vertex(COMP, f"c{i}", parent=root.vid)
        g.add_edge(root.vid, v.vid, "control")
    return g


def test_scalar_branching_callable_falls_back_to_loop():
    g = _comp_chain()
    res = simulate(g, 4, lambda p, vid: 0.02 if p == 1 else 0.01)
    t = res.ppg.times_matrix()
    for vid in (1, 2, 3):
        assert t[1, vid] == 0.02
        assert t[0, vid] == t[2, vid] == t[3, vid] == 0.01


def test_vectorized_callable_gets_proc_array_once_per_vertex():
    g = _comp_chain()
    shapes = []

    @vectorized_base_times
    def base(procs, vid):
        shapes.append((vid, procs.shape))
        return np.full(procs.shape, 0.01)

    simulate(g, 4, base)
    assert shapes == [(1, (4,)), (2, (4,)), (3, (4,))]


def test_seeded_base_times_from_mapping_and_array():
    g = _comp_chain(3)
    table = {1: 0.1, 2: 0.2}                       # vid 3 unprofiled -> 0.0
    for seed in (seeded_base_times(table, n_vertices=len(g.vertices)),
                 seeded_base_times(np.array([0.0, 0.1, 0.2, 0.0]))):
        res = simulate(g, 4, seed)
        t = res.ppg.times_matrix()
        assert np.array_equal(t[:, 1], np.full(4, 0.1))
        assert np.array_equal(t[:, 2], np.full(4, 0.2))
        assert np.array_equal(t[:, 3], np.zeros(4))


# ---------------------------------------------------------------------------
# PerfStore.set_entries
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n_procs=st.integers(1, 10), seed=st.integers(0, 10**6),
       n_ops=st.integers(1, 20))
def test_set_entries_matches_scalar_set_entry(n_procs, seed, n_ops):
    """Random batched scatters (duplicates, growth, accumulate on/off)
    must be observationally identical to the per-entry loop."""
    rng = np.random.default_rng(seed)
    batched = PerfStore(n_procs, 3)
    scalar = PerfStore(n_procs, 3)
    names = ["wait_s", "flops"]
    for _ in range(n_ops):
        vid = int(rng.integers(8))                 # exercises column growth
        k = int(rng.integers(1, 2 * n_procs + 1))
        procs = rng.integers(0, n_procs, k)        # duplicates likely
        times = rng.uniform(0.1, 1.0, k)
        acc = bool(rng.integers(2))
        counters = {nm: rng.uniform(0.1, 5.0, k)
                    for nm in names if rng.uniform() < 0.7}
        batched.set_entries(procs, vid, times, counters=counters,
                            accumulate=acc)
        for i, p in enumerate(procs.tolist()):
            scalar.set_entry(p, vid, float(times[i]),
                             counters={nm: float(v[i])
                                       for nm, v in counters.items()},
                             accumulate=acc)
    assert len(batched) == len(scalar)
    assert np.array_equal(batched.time_matrix(8), scalar.time_matrix(8))
    assert np.array_equal(batched.samples[:, :8], scalar.samples[:, :8])
    for nm in names:
        assert np.array_equal(batched.counter_matrix(nm, 8),
                              scalar.counter_matrix(nm, 8))
    assert sorted(batched.keys()) == sorted(scalar.keys())


def test_set_entries_accumulate_from_unset_and_broadcast():
    s = PerfStore(4, 2)
    s.set_entries([0, 2], 1, 0.5, counters={"wait_s": 0.1})  # broadcast
    s.set_entries([2, 2], 1, [0.25, 0.25], accumulate=True,
                  counters={"wait_s": [0.1, 0.2]})
    assert s.time[0, 1] == 0.5
    assert s.time[2, 1] == 1.0                     # 0.5 + 0.25 + 0.25
    assert s.counter_at("wait_s", 2, 1) == pytest.approx(0.4)
    assert (1, 1) not in s and len(s) == 2
    s.set_entries(np.array([3]), 5, 2.0, accumulate=True)   # growth + unset
    assert s.time_matrix(6)[3, 5] == 2.0


def test_build_ppg_per_proc_dict_batched_path():
    """{proc: {vid: vec}} assembly goes through set_entries grouping;
    heterogeneous per-proc counter name sets must keep exact sparsity."""
    g = _comp_chain(2)
    perf = {0: {1: PerfVector(time=0.1, counters={"flops": 1.0})},
            1: {1: PerfVector(time=0.2, time_var=0.01,
                              counters={"flops": 2.0, "bytes": 3.0}),
                2: PerfVector(time=0.3)},
            3: {1: PerfVector(time=0.4)}}
    ppg = build_ppg(g, 4, perf)
    ref = PPG(g, 4)
    for p, d in perf.items():
        for vid, vec in d.items():
            ref.set_perf(p, vid, vec)
    assert np.array_equal(ppg.times_matrix(), ref.times_matrix())
    assert np.array_equal(ppg.var_matrix(), ref.var_matrix())
    for nm in ("flops", "bytes"):
        assert np.array_equal(ppg.perf.counter_matrix(nm, 3),
                              ref.perf.counter_matrix(nm, 3))
    assert ppg.perf[(0, 1)].counters == {"flops": 1.0}
    assert ppg.perf[(3, 1)].counters == {}
    assert sorted(ppg.perf.keys()) == sorted(ref.perf.keys())


# ---------------------------------------------------------------------------
# SCALANA_DETECT_F32: f32 kernel variant (loosened parity)
# ---------------------------------------------------------------------------

def _amdahl_series(seed=0):
    g = _comp_chain(6)
    rng = np.random.default_rng(seed)
    bad = set(rng.choice(6, 2, replace=False).tolist())

    def time_at(p, vid, n):
        if vid - 1 in bad:
            return 1.0 * (0.6 + 0.4 / n)
        return 1.0 / n

    return simulate_series(g, [4, 8, 16, 32], time_at, jitter=0.01,
                           seed=seed)


def test_detect_f32_merge_close_to_f64(monkeypatch):
    pytest.importorskip("jax")
    from repro.core import detect_jax
    rng = np.random.default_rng(7)
    t = rng.uniform(0.05, 1.0, (8, 6))
    t[rng.uniform(size=t.shape) < 0.2] = 0.0
    var = rng.uniform(0.001, 0.1, t.shape)
    ref64 = detect_jax.merge_matrix(t, "mean", var=var)
    assert ref64.dtype == np.float64
    monkeypatch.setenv("SCALANA_DETECT_F32", "1")
    got32 = detect_jax.merge_matrix(t, "mean", var=var)
    assert got32.dtype == np.float32
    assert np.allclose(got32, ref64, rtol=1e-4, atol=1e-6)
    assert np.allclose(got32, _merge_matrix(t, "mean"), rtol=1e-4,
                       atol=1e-6)


@pytest.mark.parametrize("strategy", ["mean", "max", "p0", "var"])
def test_detect_f32_end_to_end_close_to_numpy(monkeypatch, strategy):
    pytest.importorskip("jax")
    series = _amdahl_series()
    ref = detect_non_scalable(series, strategy=strategy, top_k=100,
                              backend="numpy")
    monkeypatch.setenv("SCALANA_DETECT_F32", "1")
    got = detect_non_scalable(series, strategy=strategy, top_k=100,
                              backend="jax")
    assert [d.vid for d in got] == [d.vid for d in ref]
    for x, y in zip(ref, got):
        assert y.slope == pytest.approx(x.slope, rel=1e-4)
        assert y.share == pytest.approx(x.share, rel=1e-4)
        for scale, t in x.times.items():
            assert y.times[scale] == pytest.approx(t, rel=1e-4)
