"""GraphProfiler (runtime channel) + dependence simulator tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import COMM, COMP, PSG, GraphProfiler
from repro.core.inject import (default_comm_time, schedule, simulate,
                               simulate_series)


def _fn(x, w):
    def body(c, _):
        return jnp.tanh(c @ w), None
    c, _ = jax.lax.scan(body, x, None, length=3)
    return jnp.sum(c)


def test_profiler_collects_per_vertex_times():
    x, w = jnp.ones((16, 32)), jnp.ones((32, 32))
    prof = GraphProfiler(_fn, (x, w), sample_every=2)
    for _ in range(6):
        prof.step(x, w)
    assert prof.sampled_steps == 3
    perf = prof.perf_vectors()
    timed = [v for v in perf.values() if v.samples > 0]
    assert timed, "sampled steps must attribute time to vertices"
    assert all(v.time >= 0 for v in timed)
    # counters carry the static channel
    assert any(v.counters.get("flops", 0) > 0 for v in perf.values())


def test_profiler_sampled_output_matches_compiled():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 16)),
                    jnp.float32)
    w = jnp.asarray(np.random.default_rng(1).standard_normal((16, 16)),
                    jnp.float32)
    prof = GraphProfiler(_fn, (x, w), sample_every=1)
    out_sampled = prof.step(x, w)          # instrumented path
    out_fast = jax.jit(_fn)(x, w)
    np.testing.assert_allclose(np.asarray(out_sampled),
                               np.asarray(out_fast), rtol=1e-5)


def test_profiler_seeds_vectorized_base_times():
    """prof.base_times() drives the replay engine's vectorized channel:
    every process replays the measured per-vertex mean, with no scalar
    fallback (the callable carries the vectorization marker)."""
    x, w = jnp.ones((16, 32)), jnp.ones((32, 32))
    prof = GraphProfiler(_fn, (x, w), sample_every=2)
    for _ in range(6):
        prof.step(x, w)
    base = prof.base_times()
    assert getattr(base, "scalana_vectorized", False)
    res = simulate(prof.psg, 4, base)
    t = res.ppg.times_matrix()
    for vid, vec in prof.perf_vectors().items():
        if vec.samples > 0:
            assert np.allclose(t[:, vid], vec.time)


def test_profiler_storage_far_below_full_trace():
    """Storage is O(graph) while tracing is O(steps x events): at realistic
    step counts the gap is orders of magnitude (paper Table I)."""
    x, w = jnp.ones((16, 32)), jnp.ones((32, 32))
    prof = GraphProfiler(_fn, (x, w), sample_every=2)
    stored_early = None
    for i in range(200):
        prof.step(x, w)
        if i == 9:
            stored_early = prof.storage_bytes()
    assert prof.storage_bytes() < prof.full_trace_bytes() / 10
    # retained bytes do not grow with steps (perf vectors, not events)
    assert prof.storage_bytes() <= stored_early * 1.5


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------

def _psg_with_collective():
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    a = g.new_vertex(COMP, "a", parent=root.vid)
    c = g.new_vertex(COMM, "psum", parent=root.vid)
    c.comm_kind, c.comm_bytes = "all_reduce", 8e6
    b = g.new_vertex(COMP, "b", parent=root.vid)
    g.add_edge(root.vid, a.vid, "control")
    g.add_edge(root.vid, c.vid, "control")
    g.add_edge(root.vid, b.vid, "control")
    g.add_edge(a.vid, c.vid, "data")
    g.add_edge(c.vid, b.vid, "data")
    return g, a.vid, c.vid, b.vid


def test_schedule_orders_top_level():
    g, a, c, b = _psg_with_collective()
    assert schedule(g) == [a, c, b]


def test_collective_syncs_clocks():
    g, a, c, b = _psg_with_collective()
    res = simulate(g, 4, lambda p, vid: 0.1 * (p + 1) if vid == a else 0.05)
    # after the collective everyone is synchronized; clocks equal
    assert len(set(np.round(res.clocks, 9))) == 1
    # the slowest pre-collective process (p=3) waits zero at the barrier
    assert res.ppg.perf[(3, c)].counters["wait_s"] == pytest.approx(0.0)
    assert res.ppg.perf[(0, c)].counters["wait_s"] == pytest.approx(0.3)


def test_makespan_lower_bound():
    g, a, c, b = _psg_with_collective()
    res = simulate(g, 4, lambda p, vid: 0.1)
    comm = default_comm_time(g.vertices[c], 4, list(range(4)))
    assert res.makespan >= 0.2 + comm - 1e-12


def test_injection_visible_at_other_processes():
    """Delay on p0 surfaces as waiting at p1..p3's collective — the latent
    propagation ScalAna exists to backtrack."""
    g, a, c, b = _psg_with_collective()
    res = simulate(g, 4, lambda p, vid: 0.01, inject={(0, a): 1.0})
    for p in (1, 2, 3):
        assert res.ppg.perf[(p, c)].counters["wait_s"] > 0.9


def test_series_scales_and_jitter_determinism():
    g, a, c, b = _psg_with_collective()
    s1 = simulate_series(g, [2, 4], lambda p, v, n: 0.1 / n,
                         jitter=0.05, seed=7)
    s2 = simulate_series(g, [2, 4], lambda p, v, n: 0.1 / n,
                         jitter=0.05, seed=7)
    for n in (2, 4):
        assert s1[n].meta["makespan"] == pytest.approx(s2[n].meta["makespan"])
