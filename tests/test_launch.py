"""Launch layer: cell assembly, lowering, dry-run record structure.

Uses a 1x1 ("data","model") mesh so the full sharding/lowering path runs
on the single CPU device (the 512-device production meshes are exercised
by python -m repro.launch.dryrun, which owns the XLA_FLAGS override)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from conftest import smoke_bundle
from repro.configs import SHAPES, get_smoke
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.shardings import (abstract_train_state, build_cell,
                                    rules_for_shape, train_state_shardings)
from repro.core.hlo_walk import analyze_hlo
from repro.distributed import axes as ax


def _tiny_shapes():
    return {
        "train": ShapeConfig("t", 32, 2, "train"),
        "prefill": ShapeConfig("p", 32, 2, "prefill"),
        "decode": ShapeConfig("d", 32, 2, "decode"),
    }


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m",
                                  "moonshot-v1-16b-a3b"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_cell_lowers_and_compiles(arch, kind, monkeypatch):
    """build_cell -> lower -> compile for every cell kind at smoke scale."""
    import repro.configs as configs
    cfg = get_smoke(arch)
    shape = _tiny_shapes()[kind]
    monkeypatch.setitem(SHAPES, shape.name, shape)
    mesh = make_host_mesh()
    cell = build_cell(arch, shape.name, mesh, cfg=cfg, donate=False)
    compiled = cell.lower().compile()
    from repro.launch.mesh import cost_analysis_dict
    assert cost_analysis_dict(compiled).get("flops", 0) > 0
    cost = analyze_hlo(compiled.as_text())
    assert cost.dot_flops > 0


def test_cell_options_seq_shard_lowers(monkeypatch):
    cfg = get_smoke("tinyllama-1.1b")
    shape = _tiny_shapes()["train"]
    monkeypatch.setitem(SHAPES, shape.name, shape)
    mesh = make_host_mesh()
    cell = build_cell("tinyllama-1.1b", shape.name, mesh, cfg=cfg,
                      donate=False, options={"seq_shard": True})
    assert cell.rules["res_seq"] == "model"
    cell.lower().compile()


def test_abstract_state_matches_real_state():
    cfg, model, params = smoke_bundle("tinyllama-1.1b")
    abs_state = abstract_train_state(model)
    flat_abs = jax.tree.leaves(abs_state.params)
    flat_real = jax.tree.leaves(params)
    assert len(flat_abs) == len(flat_real)
    for a, r in zip(flat_abs, flat_real):
        assert a.shape == r.shape


def test_state_shardings_tree_congruent():
    cfg, model, _ = smoke_bundle("tinyllama-1.1b")
    mesh = make_host_mesh()
    with ax.use_rules(mesh):
        sh = train_state_shardings(model, mesh)
        st = abstract_train_state(model)
    assert (len(jax.tree.leaves(sh.opt.mu))
            == len(jax.tree.leaves(st.opt.mu)))


def test_shape_rules_are_pure():
    """rules_for_shape never mutates DEFAULT_RULES."""
    before = dict(ax.DEFAULT_RULES)
    mesh = make_host_mesh()
    for s in SHAPES.values():
        rules_for_shape(s, get_smoke("tinyllama-1.1b"), mesh)
    assert ax.DEFAULT_RULES == before
