"""Always-on monitor: ingestion exactness, fault tolerance, degradation.

The acceptance contract (ISSUE 6): under seeded drop/duplicate/reorder/
delay schedules with eventual delivery, the monitor's final detect/
backtrack output is BIT-IDENTICAL to a one-shot run on the fully-
assembled store; with permanently dead hosts it equals a one-shot run
restricted to the live rows (and the report states fleet coverage); an
aggregator crash + snapshot restore converges to the same result.

Everything here is jax-free (the monitor package never imports jax);
device-path parity lives in test_device_detect.py.
"""
import threading

import numpy as np
import pytest

from repro.core import PerfStore, ShardedStore, build_ppg, detect_abnormal
from repro.core.graph import PPG
from repro.core.inject import simulate
from repro.core.shard import shard_ranges
from repro.monitor import (FaultyTransport, Monitor, QueueTransport,
                           ShardProducer, Transport, TransportError,
                           build_chaos_psg, chaos_run, live_subppg)


# ---------------------------------------------------------------------------
# the chaos property (acceptance criteria)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_chaos_clean_fleet_bit_identical(seed):
    r = chaos_run(seed=seed)
    assert r.abnormal_match and r.paths_match, r.transport_stats
    assert r.coverage_stated
    assert r.report.live_procs == r.report.total_procs
    # the schedule actually misbehaved and the windows actually absorbed
    assert r.transport_stats.get("dropped", 0) > 0
    assert r.duplicates_absorbed > 0


@pytest.mark.parametrize("seed,dead", [(1, (2, 5)), (3, (0,)),
                                       (6, (1, 4, 7))])
def test_chaos_dead_hosts_equal_one_shot_on_live_rows(seed, dead):
    r = chaos_run(seed=seed, dead_hosts=dead)
    assert r.converged
    assert r.report.degraded
    assert r.report.live_hosts == 8 - len(dead)
    assert "fleet coverage:" in r.report.text
    assert "DEGRADED" in r.report.coverage
    for h in dead:
        assert f"h{h}" in r.report.coverage


def test_chaos_crash_and_snapshot_restore_converges(tmp_path):
    r = chaos_run(seed=2, snapshot_dir=str(tmp_path), crash_after_round=2)
    assert r.converged
    assert r.deltas_applied > 0


def test_chaos_outage_window_recovers():
    r = chaos_run(seed=5, p_drop=0.0, outages=((4, 12),))
    assert r.converged
    assert r.transport_stats["outage"] > 0


def test_chaos_combined_crash_dead_hosts_heavy_faults(tmp_path):
    r = chaos_run(seed=4, snapshot_dir=str(tmp_path), crash_after_round=3,
                  dead_hosts=(0,), p_drop=0.3, p_dup=0.25, p_delay=0.4)
    assert r.converged
    assert r.report.degraded


# ---------------------------------------------------------------------------
# ingestion mechanics
# ---------------------------------------------------------------------------

def _fleet(n_procs=12, n_hosts=3, seed=0):
    """(psg, truth_ppg, ranges): a simulated workload on a sharded store."""
    psg = build_chaos_psg(6)
    ranges = shard_ranges(n_procs, n_hosts)
    sim = simulate(psg, n_procs,
                   lambda p, v: 0.0 if psg.vertices[v].kind == "Comm"
                   else 1.0 + 0.01 * v,
                   inject={(5, 2): 3.0}, comm_time=lambda *a: 0.05,
                   jitter=0.0, seed=seed, shards=ranges)
    return psg, sim.ppg, ranges


def test_sequence_windows_absorb_duplicates_and_reorder_exactly():
    psg, truth, ranges = _fleet()
    tr = QueueTransport()
    mon = Monitor(psg, ranges, tr, comm=truth.comm, detect_every=None)
    prod = ShardedStore(ranges, len(psg.vertices))
    producers = [ShardProducer(h, prod.shards[h], tr, sleep=lambda s: None)
                 for h in range(3)]
    # three rounds of deltas per host, captured instead of delivered
    deltas = []
    for r in range(3):
        for h, p in enumerate(producers):
            sh = prod.shards[h]
            blk = truth.perf.shards[h].extract_rows(
                np.arange(sh.n_procs))
            if r < 2:            # earlier rounds carry DIFFERENT row state
                blk.time[:, 2 * r + 2:] = 0.0
                blk.mask[:, 2 * r + 2:] = False
            sh.apply_rows(blk)
            deltas.append(p.flush(heartbeat=False))
    tr.recv()                     # start from an empty channel
    # deliver shuffled, with every delta duplicated
    rng = np.random.default_rng(0)
    order = rng.permutation(len(deltas))
    for i in order:
        tr.send(deltas[i])
        tr.send(deltas[i])        # duplicate
    mon.poll()
    assert mon.duplicates == len(deltas)
    assert all(mon.high[h] == 3 for h in range(3))
    assert all(not mon.parked[h] for h in range(3))
    # replica is bit-identical to the producers' final shard state
    np.testing.assert_array_equal(mon.store.time_matrix(len(psg.vertices)),
                                  prod.time_matrix(len(psg.vertices)))
    # stale duplicate arriving later is dropped on the floor
    tr.send(deltas[0])
    mon.poll()
    assert mon.duplicates == len(deltas) + 1


def test_out_of_order_delta_is_parked_until_gap_fills():
    psg, truth, ranges = _fleet()
    tr = QueueTransport()
    mon = Monitor(psg, ranges, tr, comm=truth.comm, detect_every=None)
    sh = ShardedStore(ranges, len(psg.vertices)).shards[0]
    p = ShardProducer(0, sh, tr, sleep=lambda s: None)
    blk = truth.perf.shards[0].extract_rows(np.arange(sh.n_procs))
    sh.apply_rows(blk)
    d1 = p.flush(heartbeat=False)
    sh.apply_rows(blk)
    d2 = p.flush(heartbeat=False)
    tr.recv()                     # drop the in-order originals
    tr.send(d2)                   # future seq first
    mon.poll()
    assert mon.high[0] == 0 and len(mon.parked[0]) == 1
    assert mon.applied == 0
    tr.send(d1)                   # the gap fills: both apply, in order
    mon.poll()
    assert mon.high[0] == 2 and not mon.parked[0]
    assert mon.applied == 2


class _FlakySends(Transport):
    """Raises on the first ``fail`` sends, then delivers."""

    def __init__(self, fail):
        self.fail = fail
        self.inner = QueueTransport()
        self.sends = 0

    def send(self, msg):
        self.sends += 1
        if self.sends <= self.fail:
            raise TransportError("flaky")
        self.inner.send(msg)

    def recv(self, max_messages=None):
        return self.inner.recv(max_messages)

    def pending(self):
        return self.inner.pending()


def test_producer_retries_with_exponential_backoff():
    psg, truth, ranges = _fleet()
    sh = ShardedStore(ranges, len(psg.vertices)).shards[0]
    sh.apply_rows(truth.perf.shards[0].extract_rows(np.arange(sh.n_procs)))
    tr = _FlakySends(fail=3)
    slept = []
    p = ShardProducer(0, sh, tr, base_backoff=0.01, max_backoff=0.04,
                      sleep=slept.append)
    d = p.flush(heartbeat=False)
    assert d is not None and tr.pending() == 1
    assert p.retries == 3
    assert slept == [0.01, 0.02, 0.04]       # doubling, capped


def test_producer_gives_up_then_drains_backlog_on_next_flush():
    psg, truth, ranges = _fleet()
    sh = ShardedStore(ranges, len(psg.vertices)).shards[0]
    sh.apply_rows(truth.perf.shards[0].extract_rows(np.arange(sh.n_procs)))
    tr = _FlakySends(fail=100)
    p = ShardProducer(0, sh, tr, max_retries=2, sleep=lambda s: None)
    p.flush(heartbeat=False)
    assert p.send_failures == 1 and tr.pending() == 0
    assert 1 in p.unacked
    tr.fail = 0                              # the link heals
    sh.apply_rows(truth.perf.shards[0].extract_rows(np.arange(2)))
    p.flush(heartbeat=False)                 # backlog first, then new delta
    got = tr.recv()
    assert [m.seq for m in got] == [1, 2]


def test_acks_prune_unacked_and_resend_replays_the_rest():
    psg, truth, ranges = _fleet()
    sh = ShardedStore(ranges, len(psg.vertices)).shards[0]
    tr = QueueTransport()
    p = ShardProducer(0, sh, tr, sleep=lambda s: None)
    for _ in range(3):
        sh.apply_rows(truth.perf.shards[0].extract_rows(
            np.arange(sh.n_procs)))
        p.flush(heartbeat=False)
    assert sorted(p.unacked) == [1, 2, 3]
    p.ack(2)
    assert sorted(p.unacked) == [3]
    tr.recv()
    assert p.resend_unacked() == 1
    assert [m.seq for m in tr.recv()] == [3]


def test_heartbeats_and_staleness_drive_the_live_set():
    psg, truth, ranges = _fleet()
    now = [0.0]
    tr = QueueTransport()
    mon = Monitor(psg, ranges, tr, comm=truth.comm, detect_every=None,
                  stale_after=1.5, clock=lambda: now[0])
    prod = ShardedStore(ranges, len(psg.vertices))
    producers = [ShardProducer(h, prod.shards[h], tr, clock=lambda: now[0],
                               sleep=lambda s: None) for h in range(3)]
    assert mon.live_hosts() == [0, 1, 2]     # startup grace
    now[0] = 2.0                             # silence -> everyone stale
    assert mon.live_hosts() == []
    for p in producers[:2]:
        p.send_heartbeat()
    mon.poll()
    assert mon.live_hosts() == [0, 1]
    mask = mon.proc_mask()
    assert mask[:8].all() and not mask[8:].any()
    st = mon.fleet_status()
    assert st.live_hosts == 2 and st.total_hosts == 3
    assert st.live_procs == 8 and st.total_procs == 12
    assert [h.live for h in st.hosts] == [True, True, False]


def test_snapshot_restore_recovers_store_and_windows(tmp_path):
    psg, truth, ranges = _fleet()
    tr = QueueTransport()
    mon = Monitor(psg, ranges, tr, comm=truth.comm, detect_every=None,
                  snapshot_dir=str(tmp_path), snapshot_every=2)
    prod = ShardedStore(ranges, len(psg.vertices))
    producers = [ShardProducer(h, prod.shards[h], tr, sleep=lambda s: None)
                 for h in range(3)]
    for h, p in enumerate(producers):
        prod.shards[h].apply_rows(truth.perf.shards[h].extract_rows(
            np.arange(prod.shards[h].n_procs)))
        p.flush(heartbeat=False)
    mon.poll()
    mon.snapshot()
    for h, p in enumerate(producers):
        p.ack(mon.acked_seq(h))
    assert all(not p.unacked for p in producers)
    high = dict(mon.high)
    V = len(psg.vertices)
    want = mon.store.time_matrix(V).copy()
    del mon
    mon2 = Monitor.restore(psg, QueueTransport(), str(tmp_path),
                           comm=truth.comm, detect_every=None)
    assert mon2.high == high
    np.testing.assert_array_equal(mon2.store.time_matrix(V), want)
    # counters survive too (backtrack needs wait_s)
    vids, vals, mask = mon2.store.counter_columns("wait_s")
    vids0, vals0, mask0 = truth.perf.counter_columns("wait_s")
    np.testing.assert_array_equal(np.sort(vids), np.sort(vids0))


def test_restore_without_snapshot_raises(tmp_path):
    psg, _, _ = _fleet()
    with pytest.raises(FileNotFoundError):
        Monitor.restore(psg, QueueTransport(), str(tmp_path))


# ---------------------------------------------------------------------------
# detection triggers + degraded equality
# ---------------------------------------------------------------------------

def _one_delta(truth, ranges, tr, psg, h=0):
    prod = ShardedStore(ranges, len(psg.vertices))
    p = ShardProducer(h, prod.shards[h], tr, sleep=lambda s: None)
    prod.shards[h].apply_rows(truth.perf.shards[h].extract_rows(
        np.arange(prod.shards[h].n_procs)))
    p.flush(heartbeat=False)
    return p


def test_detect_every_and_drift_and_interval_triggers():
    psg, truth, ranges = _fleet()
    now = [0.0]
    tr = QueueTransport()
    mon = Monitor(psg, ranges, tr, comm=truth.comm, detect_every=2,
                  clock=lambda: now[0])
    _one_delta(truth, ranges, tr, psg, h=0)
    assert mon.poll() is None                # 1 applied < detect_every
    _one_delta(truth, ranges, tr, psg, h=1)
    rep = mon.poll()
    assert rep is not None and rep.index == 0
    assert mon.poll() is None                # trigger state reset

    mon2 = Monitor(psg, ranges, tr, comm=truth.comm, detect_every=None,
                   drift_threshold=0.3, clock=lambda: now[0])
    _one_delta(truth, ranges, tr, psg, h=0)  # 4/12 procs = 1/3 touched
    assert mon2.poll() is not None

    mon3 = Monitor(psg, ranges, tr, comm=truth.comm, detect_every=None,
                   interval=10.0, clock=lambda: now[0])
    _one_delta(truth, ranges, tr, psg, h=0)
    assert mon3.poll() is None
    now[0] = 11.0
    assert mon3.poll() is not None


def test_degraded_detection_equals_live_subppg_one_shot():
    psg, truth, ranges = _fleet(n_procs=16, n_hosts=4)
    # _fleet injects the straggler at proc 5, which is on the dead host —
    # move it to a live proc so the degraded run still has work to find
    psg2 = build_chaos_psg(6)
    sim = simulate(psg2, 16,
                   lambda p, v: 0.0 if psg2.vertices[v].kind == "Comm"
                   else 1.0 + 0.01 * v,
                   inject={(2, 2): 3.0}, comm_time=lambda *a: 0.05,
                   jitter=0.0, seed=0, shards=ranges)
    truth = sim.ppg
    mask = np.ones(16, bool)
    mask[4:8] = False                        # host 1 dead
    live = np.nonzero(mask)[0]
    sub = live_subppg(truth, live)
    want = detect_abnormal(sub, backend="numpy")
    got = detect_abnormal(truth, proc_mask=mask, backend="numpy")
    assert want, "scenario produced no abnormal vertices"
    assert [(a.vid, int(live[a.proc]), a.time, a.typical) for a in want] \
        == [(a.vid, a.proc, a.time, a.typical) for a in got]


def test_live_subppg_filters_comm_groups_and_p2p():
    psg, truth, ranges = _fleet(n_procs=8, n_hosts=2)
    truth.add_p2p_edge(1, 2, 5, 2)
    truth.add_p2p_edge(1, 2, 2, 2)
    live = np.asarray([0, 1, 2, 3])          # host 1 (procs 4..7) dead
    sub = live_subppg(truth, live)
    assert sub.n_procs == 4
    # the all-reduce group shrinks to the live procs, remapped
    comm_vid = len(psg.vertices) - 1
    groups = sub.comm.groups_of(comm_vid)
    assert groups and sorted(groups[0]) == [0, 1, 2, 3]
    # live-to-live p2p survives (remapped), live-to-dead is gone
    assert ((1, 2), (2, 2)) in sub.comm.p2p_edges()
    assert all(max(e[0][0], e[1][0]) < 4 for e in sub.comm.p2p_edges())
    # perf rows are the live rows, exactly
    np.testing.assert_array_equal(
        sub.times_matrix(), truth.times_matrix()[live])


def test_threaded_monitor_streams_reports():
    psg, truth, ranges = _fleet()
    tr = QueueTransport()
    got = threading.Event()
    mon = Monitor(psg, ranges, tr, comm=truth.comm, detect_every=1,
                  on_report=lambda r: got.set())
    mon.start(poll_interval=0.005)
    try:
        _one_delta(truth, ranges, tr, psg, h=0)
        assert got.wait(timeout=5.0), "no report streamed"
    finally:
        mon.stop()
    assert mon.reports and mon.reports[-1].applied >= 1


def test_faulty_transport_is_deterministic_and_counts():
    def run(seed):
        tr = FaultyTransport(seed=seed, p_drop=0.3, p_dup=0.3, p_delay=0.3,
                             p_ack_loss=0.2)
        log = []
        for i in range(50):
            try:
                tr.send(i)
            except TransportError:
                log.append(("err", i))
        for _ in range(8):
            log.extend(("got", m) for m in tr.recv())
        tr.flush_held()
        log.extend(("got", m) for m in tr.recv())
        return log, dict(tr.stats)

    a, sa = run(7)
    b, sb = run(7)
    c, _ = run(8)
    assert a == b and sa == sb
    assert a != c
    assert sa["sends"] == 50
    assert {"dropped", "duplicated", "delayed", "ack_lost"} <= set(sa)
    # delivered exactly: every non-dropped send (+duplicates) arrives
    got = [m for tag, m in a if tag == "got"]
    assert len(got) == 50 - sa["dropped"] + sa["duplicated"]
