"""Wire protocol: framing, resync, and the delta-compression codec.

The codec contract under test (ISSUE 8): anything the frame layer
delivers decodes to the exact row state the producer transmitted —
diff rows reconstruct bit-identically against the lockstep caches, a
broken diff chain is REJECTED (never guessed at), and corruption costs
only the frames it overlapped.  Everything here is jax-free and
socket-free; the live-socket side lives in test_net.py.
"""
import struct
import zlib

import numpy as np
import pytest

from repro.core.shard import ShardedStore, shard_ranges
from repro.monitor import (Ack, DeltaDecoder, DeltaEncoder, FrameReader,
                           Heartbeat, ShardDelta, WireError, decode_message,
                           encode_frame, encode_message, stores_equal)
from repro.monitor.wire import (HEADER, MAGIC, MSG_ACK, MSG_DELTA,
                                MSG_HEARTBEAT, VERSION)

V = 8  # vertices in the little stores below


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def test_frame_roundtrip_across_arbitrary_chunking():
    frames = [encode_frame(MSG_HEARTBEAT, bytes([i]) * (10 + i))
              for i in range(5)]
    stream = b"".join(frames)
    reader = FrameReader()
    got = []
    # deliberately awkward chunk sizes: split mid-header and mid-payload
    for i in range(0, len(stream), 7):
        got += reader.feed(stream[i:i + 7])
    assert [(t, p) for t, p in got] \
        == [(MSG_HEARTBEAT, bytes([i]) * (10 + i)) for i in range(5)]
    assert reader.stats["frames"] == 5
    assert reader.stats.get("resyncs", 0) == 0
    assert reader.pending_bytes() == 0


def test_crc_corruption_drops_only_the_corrupted_frame():
    good = encode_frame(MSG_HEARTBEAT, b"aaaa")
    bad = bytearray(encode_frame(MSG_HEARTBEAT, b"bbbb"))
    bad[HEADER.size + 1] ^= 0xFF               # flip a payload bit
    reader = FrameReader()
    got = reader.feed(bytes(bad) + good)
    assert got == [(MSG_HEARTBEAT, b"aaaa")]
    assert reader.stats["crc_errors"] == 1
    assert reader.stats["resyncs"] >= 1


def test_resync_after_garbage_between_frames():
    a = encode_frame(MSG_HEARTBEAT, b"left")
    b = encode_frame(MSG_HEARTBEAT, b"right")
    garbage = b"\x00\xffnoise-that-is-not-a-frame\x13\x37"
    reader = FrameReader()
    got = reader.feed(a + garbage + b)
    assert got == [(MSG_HEARTBEAT, b"left"), (MSG_HEARTBEAT, b"right")]
    assert reader.stats["resyncs"] >= 1
    assert reader.stats["skipped_bytes"] >= len(garbage)


def test_garbage_containing_a_fake_magic_still_resyncs():
    # garbage that embeds the magic but not a valid frame: the reader
    # walks magic to magic until a real frame checks out
    good = encode_frame(MSG_HEARTBEAT, b"ok")
    fake = MAGIC + b"\x63\x01\xff\xff\xff\xff\x00\x00\x00\x00"
    reader = FrameReader()
    got = reader.feed(fake + good)
    assert got == [(MSG_HEARTBEAT, b"ok")]


def test_bad_version_and_oversize_are_skipped():
    wrong_version = bytearray(encode_frame(MSG_HEARTBEAT, b"x"))
    wrong_version[4] = VERSION + 9
    huge = HEADER.pack(MAGIC, VERSION, MSG_HEARTBEAT, 1 << 30,
                       zlib.crc32(b"") & 0xFFFFFFFF)
    good = encode_frame(MSG_HEARTBEAT, b"fine")
    reader = FrameReader(max_frame=1 << 20)
    got = reader.feed(bytes(wrong_version) + huge + good)
    assert got == [(MSG_HEARTBEAT, b"fine")]
    assert reader.stats["bad_version"] == 1
    assert reader.stats["oversize"] == 1


def test_resync_keeps_partial_magic_straddling_a_chunk_boundary():
    """Garbage followed by the first bytes of a healthy frame's magic:
    the resync must retain the partial magic, or the frame whose header
    straddles the chunk boundary is destroyed along with the garbage."""
    frame = encode_frame(MSG_HEARTBEAT, b"straddle")
    for cut in range(1, len(MAGIC)):
        reader = FrameReader()
        assert reader.feed(b"\x00\x01\x02garbage" + frame[:cut]) == []
        got = reader.feed(frame[cut:])
        assert got == [(MSG_HEARTBEAT, b"straddle")], f"cut={cut}"
        assert reader.stats["resyncs"] >= 1
        assert reader.pending_bytes() == 0


def test_encode_frame_enforces_max_frame_on_the_send_side():
    """An over-limit payload must fail loudly at encode time — the
    receiver would discard it as oversize forever."""
    with pytest.raises(WireError, match="max_frame"):
        encode_frame(MSG_HEARTBEAT, b"x" * 64, max_frame=32)
    with pytest.raises(WireError, match="max_frame"):
        encode_message(Heartbeat(host=0, seq=1, time=0.0), max_frame=4)
    # at the limit is fine
    assert encode_frame(MSG_HEARTBEAT, b"x" * 32, max_frame=32)


def test_torn_frame_counts_as_truncated_on_close():
    frame = encode_frame(MSG_HEARTBEAT, b"torn-in-half")
    reader = FrameReader()
    assert reader.feed(frame[:len(frame) // 2]) == []
    reader.close()
    assert reader.stats["truncated"] == 1
    assert reader.pending_bytes() == 0


# ---------------------------------------------------------------------------
# message serialization
# ---------------------------------------------------------------------------

def test_heartbeat_and_ack_roundtrip():
    reader = FrameReader()
    hb = Heartbeat(host=3, seq=17, time=12.5)
    ack = Ack(acks={0: 4, 7: 123456789012})
    frames = reader.feed(encode_message(hb) + encode_message(ack))
    assert len(frames) == 2
    got_hb = decode_message(*frames[0])
    got_ack = decode_message(*frames[1])
    assert got_hb == hb
    assert got_ack == ack


def test_unknown_type_and_malformed_payloads_raise_wire_error():
    with pytest.raises(WireError):
        decode_message(99, b"")
    with pytest.raises(WireError):
        decode_message(MSG_HEARTBEAT, b"short")
    with pytest.raises(WireError):
        decode_message(MSG_ACK, struct.pack("<I", 3) + b"x")
    with pytest.raises(TypeError):
        encode_message(object())


# ---------------------------------------------------------------------------
# the delta codec
# ---------------------------------------------------------------------------

def _fill(store, rng, procs, *, vids=range(1, V), counters=("PAPI_TOT_CYC",
                                                            "PAPI_L2_DCM")):
    """Randomly mutate some entries of ``store`` (marks rows dirty)."""
    for p in procs:
        for vid in vids:
            if rng.random() < 0.6:
                store.set_entry(int(p), int(vid), float(rng.random() * 10),
                                time_var=float(rng.random()),
                                samples=int(rng.integers(1, 5)),
                                counters={c: float(rng.integers(0, 50))
                                          for c in counters
                                          if rng.random() < 0.7})


def _flush(shard, host, seq):
    rows = shard.dirty_rows()
    block = shard.extract_rows(rows)
    shard.clear_dirty()
    return ShardDelta(host=host, seq=seq, proc_start=shard.proc_start,
                      block=block)


def _apply(delta, store):
    sh = store.shards[delta.host]
    sh.ensure_columns(delta.block.n_cols)
    sh.apply_rows(delta.block)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("compress", [False, True])
def test_delta_codec_replica_store_bit_identical(seed, compress):
    """Property: over a lossless wire, any flush schedule leaves the
    replica store bit-identical to the source — with and without
    compression."""
    rng = np.random.default_rng(seed)
    ranges = shard_ranges(12, 3)
    src = ShardedStore(ranges, V)
    dst = ShardedStore(ranges, V)
    enc = {h: DeltaEncoder(compress=compress) for h in range(3)}
    dec = {h: DeltaDecoder() for h in range(3)}
    seqs = {h: 0 for h in range(3)}
    for _ in range(6):
        for h in range(3):
            lo, hi = ranges[h]
            procs = [p for p in range(lo, hi) if rng.random() < 0.8]
            _fill(src, rng, procs)
            rows = src.shards[h].dirty_rows()
            if not rows.size:
                continue
            seqs[h] += 1
            delta = _flush(src.shards[h], h, seqs[h])
            payload = enc[h].encode(delta)
            out = dec[h].decode(payload)
            assert out is not None
            assert out.seq == delta.seq and out.host == delta.host
            _apply(out, dst)
    assert stores_equal(src, dst, V)


def test_steady_state_diffs_beat_full_rows():
    """After the first (full) flush, a small change re-encodes as a
    diff row and costs a fraction of the full encoding."""
    rng = np.random.default_rng(7)
    ranges = shard_ranges(4, 1)
    src = ShardedStore(ranges, V)
    _fill(src, rng, range(4), vids=range(1, V))
    enc = DeltaEncoder(compress=True)
    d1 = _flush(src.shards[0], 0, 1)
    full_bytes = len(enc.encode(d1))
    assert enc.stats["full_rows"] == 4         # nothing cached yet

    # touch ONE column of ONE row
    src.set_entry(2, 3, 42.0, counters={"PAPI_TOT_CYC": 9.0})
    d2 = _flush(src.shards[0], 0, 2)
    diff_payload = enc.encode(d2)
    assert enc.stats["diff_rows"] == 1
    assert len(diff_payload) < full_bytes / 4


def test_full_row_fallback_when_diff_is_denser():
    """When every column of a row changes, the diff encoding loses and
    the encoder falls back to the full row."""
    rng = np.random.default_rng(11)
    ranges = shard_ranges(2, 1)
    src = ShardedStore(ranges, V)
    _fill(src, rng, range(2))
    enc = DeltaEncoder(compress=True)
    enc.encode(_flush(src.shards[0], 0, 1))
    # rewrite EVERYTHING (all columns + counters change)
    for p in range(2):
        for vid in range(1, V):
            src.set_entry(p, vid, float(100 + p + vid),
                          time_var=1.0, samples=9,
                          counters={"PAPI_TOT_CYC": float(vid),
                                    "PAPI_L2_DCM": float(p + 1)})
    before = enc.stats.get("full_rows", 0)
    enc.encode(_flush(src.shards[0], 0, 2))
    assert enc.stats["full_rows"] > before     # diff lost, full row won


def test_broken_diff_chain_is_rejected_not_misapplied():
    """A diff whose base frame was lost (resync ate it) must make the
    delta undecodable — the decoder never guesses."""
    rng = np.random.default_rng(3)
    ranges = shard_ranges(4, 1)
    src = ShardedStore(ranges, V)
    _fill(src, rng, range(4))
    enc = DeltaEncoder(compress=True)
    dec = DeltaDecoder()
    p1 = enc.encode(_flush(src.shards[0], 0, 1))
    assert dec.decode(p1) is not None

    src.set_entry(1, 2, 5.0)
    d2 = _flush(src.shards[0], 0, 2)
    enc.encode(d2)                                 # diff against seq 1
    src.set_entry(1, 2, 6.0)
    d3 = _flush(src.shards[0], 0, 3)
    p3 = enc.encode(d3)                            # diff against seq 2

    # seq 2 lost on the wire: p3's chain is broken at the decoder
    assert dec.decode(p3) is None
    assert dec.stats["undecodable"] == 1
    # the producer resends 2 then 3 THROUGH THE SAME LIVE ENCODER (the
    # ProducerLink.tick path — no reconnect, no byte replay): the
    # encoder sees seq 2 has not advanced past the cache, emits a full
    # row, and the decoder accepts both in order (3 may legally diff
    # against the state the resent 2 just re-seeded)
    r2 = enc.encode(d2)
    r3 = enc.encode(d3)
    assert enc.stats["resend_full_rows"] >= 1
    assert dec.decode(r2) is not None
    got = dec.decode(r3)
    assert got is not None
    dst = ShardedStore(ranges, V)
    # rebuild from a fresh full resend to check final state equality
    enc2, dec2 = DeltaEncoder(compress=True), DeltaDecoder()
    rows = np.arange(4)
    blk = src.shards[0].extract_rows(rows)
    d = ShardDelta(host=0, seq=4, proc_start=0, block=blk)
    _apply(dec2.decode(enc2.encode(d)), dst)
    assert stores_equal(src, dst, V)


def test_reencoded_resend_reconverges_on_a_live_connection():
    """The livelock regression: after frame loss on a LIVE connection,
    resends travel through the connection's encoder (NOT as replayed
    bytes).  Re-encoded resends must come back as full rows — a diff
    against the encoder's latest cache names a base seq the decoder
    never received and would be rejected on every retry, stalling the
    stream until a connection reset."""
    rng = np.random.default_rng(17)
    ranges = shard_ranges(3, 1)
    src = ShardedStore(ranges, V)
    dst = ShardedStore(ranges, V)
    _fill(src, rng, range(3))
    enc = DeltaEncoder(compress=True)
    dec = DeltaDecoder()
    _apply(dec.decode(enc.encode(_flush(src.shards[0], 0, 1))), dst)

    # seq 2 is lost on the wire (resync ate its frame); seqs 3 and 4
    # arrive but their diff chains are broken at the decoder
    src.set_entry(0, 2, 5.0)
    d2 = _flush(src.shards[0], 0, 2)
    enc.encode(d2)                                 # never delivered
    src.set_entry(0, 2, 6.0)
    d3 = _flush(src.shards[0], 0, 3)
    assert dec.decode(enc.encode(d3)) is None
    src.set_entry(0, 3, 6.5)                       # row 0: chain broken
    src.set_entry(1, 4, 7.0)                       # row 1: chain intact
    d4 = _flush(src.shards[0], 0, 4)
    # ONE broken row rejects the whole delta, healthy row 1 included
    assert dec.decode(enc.encode(d4)) is None
    assert dec.stats["undecodable"] == 2

    # the stalled-ack resend replays the whole unacked buffer through
    # the same encoder; every delta must now decode and converge
    for d in (d2, d3, d4):
        out = dec.decode(enc.encode(d))
        assert out is not None, f"resend of seq {d.seq} undecodable"
        _apply(out, dst)
    assert stores_equal(src, dst, V)

    # and the connection is healthy again: new deltas diff as usual
    src.set_entry(2, 3, 8.0)
    d5 = _flush(src.shards[0], 0, 5)
    before = enc.stats.get("diff_rows", 0)
    out = dec.decode(enc.encode(d5))
    assert out is not None
    _apply(out, dst)
    assert enc.stats["diff_rows"] > before
    assert stores_equal(src, dst, V)


def test_decoder_survives_random_payload_bytes():
    rng = np.random.default_rng(5)
    dec = DeltaDecoder()
    for n in (0, 3, 40, 200):
        assert dec.decode(bytes(rng.integers(0, 256, n, dtype=np.uint8))) \
            is None
    assert dec.stats["malformed"] == 4


def test_encoder_reset_reseeds_from_full_rows():
    """After a reset (reconnect), the next delta is all full rows and a
    FRESH decoder accepts it."""
    rng = np.random.default_rng(9)
    ranges = shard_ranges(3, 1)
    src = ShardedStore(ranges, V)
    _fill(src, rng, range(3))
    enc = DeltaEncoder(compress=True)
    enc.encode(_flush(src.shards[0], 0, 1))
    src.set_entry(0, 1, 2.0)
    enc.encode(_flush(src.shards[0], 0, 2))
    assert enc.stats["diff_rows"] >= 1
    enc.reset()                                 # reconnect
    src.set_entry(0, 1, 3.0)
    d = _flush(src.shards[0], 0, 3)
    payload = enc.encode(d)
    fresh = DeltaDecoder()                      # new connection, new cache
    out = fresh.decode(payload)
    assert out is not None
    dst = ShardedStore(ranges, V)
    _apply(out, dst)
    got = dst.shards[0].time_at(0, 1)
    assert got == 3.0
