"""Fused one-launch detection kernels + historical-scale device cache.

Pins the fused ops (``repro.kernels.detect_fused``) three ways:

* PARITY — fused-jnp and Pallas-interpret modes against the pure-numpy
  oracle (``ref.py``): flags, winner order and counts EXACT, floats to
  1e-12 in f64 (XLA reassociates sums) and 1e-4 under
  ``SCALANA_DETECT_F32``; the fused-jnp stacked path is additionally
  pinned BITWISE against the legacy multi-dispatch kernel chain it
  replaced (same formulas, same executable shape).
* EDGE CASES — empty flag sets, an all-dead scale, degraded fleets
  through the padded live-mask kernel, jit-cache stability across
  live-set sizes (a flapping host must not retrace).
* CACHE — historical scales' merged columns stay device-resident across
  detect calls: a steady-state detect with one dirty live scale uploads
  ONLY the dirty rows and launches <= 2 fused kernels (asserted via the
  ``on_launch`` seam, not inferred from timings); writes, dtype flips
  and layout changes invalidate exactly the affected columns.

Everything here needs jax; the module skips cleanly without it.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import detect_abnormal, detect_non_scalable, detect_jax
from repro.core.inject import simulate
from repro.kernels.detect_fused import ops, ref

from tests.test_device_detect import _ab_key, _step_psg

if not detect_jax.HAS_JAX:                         # pragma: no cover
    pytest.skip("jax not importable", allow_module_level=True)

MODES = [(None, "jnp"), (True, "interpret")]
ARGS = dict(ideal_slope=0.0, slope_margin=0.05, min_share=0.01)


def _case(seed=0, S=3, P=37, V=11, dtype=np.float64):
    """Random stacked detection inputs with dead readings and absent
    vertices — the shapes deliberately off the tile sizes."""
    rng = np.random.default_rng(seed)
    t = rng.uniform(0, 2, (S, P, V))
    t[t < 0.3] = 0.0
    var = rng.uniform(0, 0.1, (S, P, V))
    present = rng.random((S, V)) > 0.1
    scales = [P // 4, P // 2, P][-S:]
    top = np.array([2, 7, 3], np.int32) % V
    return (t.astype(dtype), var.astype(dtype), present, scales, top)


# ---------------------------------------------------------------------------
# parity: fused (jnp + interpret) == numpy oracle, f64
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("interpret,tag", MODES)
def test_non_scalable_stacked_matches_oracle(interpret, tag):
    t, var, present, scales, top = _case()
    tmax = float(t[-1][:, top].max(axis=0, initial=0.0).sum())
    for total in (tmax, None):                     # external + in-kernel
        Mr, slr, shr, flr = ref.non_scalable_ref(
            scales, t, var, present, total_max=total,
            top=None if total is not None else top, **ARGS)
        with enable_x64():
            M, sl, sh, fl = ops.fused_non_scalable(
                jnp.asarray(t), jnp.asarray(var),
                jnp.asarray(np.log(np.asarray(scales, np.float64))),
                jnp.asarray(present), total_max=total,
                top_idx=jnp.asarray(top), interpret=interpret, **ARGS)
        np.testing.assert_allclose(np.asarray(M), Mr, rtol=0, atol=1e-12)
        np.testing.assert_allclose(np.asarray(sl), slr, rtol=0, atol=1e-12)
        np.testing.assert_allclose(np.asarray(sh), shr, rtol=0, atol=1e-12)
        np.testing.assert_array_equal(np.asarray(fl), flr)


def test_fused_jnp_bitwise_vs_legacy_stacked_kernel():
    """With an external total the fused-jnp op and the legacy kernel
    trace the exact same formulas — results must be BITWISE equal."""
    t, var, present, scales, top = _case(seed=3)
    tmax = float(t[-1][:, top].max(axis=0, initial=0.0).sum())
    with enable_x64():
        logp = jnp.asarray(np.log(np.asarray(scales, np.float64)))
        got = ops.fused_non_scalable(
            jnp.asarray(t), jnp.asarray(var), logp, jnp.asarray(present),
            total_max=tmax, interpret=None, **ARGS)
        want = detect_jax._non_scalable_kernel(
            jnp.asarray(t), jnp.asarray(var), logp, jnp.asarray(present),
            tmax, ARGS["ideal_slope"], ARGS["slope_margin"],
            ARGS["min_share"])
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("interpret,tag", MODES)
def test_non_scalable_live_blocks_plus_hist_matches_oracle(interpret, tag):
    """Steady-state shape: live scale as device blocks + historical
    merged columns spliced in — same answer as the full stacked merge."""
    t, var, present, scales, top = _case(seed=1)
    Mr, slr, shr, flr = ref.non_scalable_ref(scales, t, var, present,
                                             top=top, **ARGS)
    hist = ref.merge_all_ref(t[:-1], var[:-1])     # (4, S-1, V)
    cuts = [t.shape[1] // 3, 2 * t.shape[1] // 3]
    with enable_x64():
        M, sl, sh, fl = ops.fused_non_scalable_live(
            [jnp.asarray(b) for b in np.split(t[-1], cuts, axis=0)],
            [jnp.asarray(b) for b in np.split(var[-1], cuts, axis=0)],
            jnp.asarray(hist),
            jnp.asarray(np.log(np.asarray(scales, np.float64))),
            jnp.asarray(present), jnp.asarray(top),
            interpret=interpret, **ARGS)
    np.testing.assert_allclose(np.asarray(M), Mr, rtol=0, atol=1e-12)
    np.testing.assert_allclose(np.asarray(sl), slr, rtol=0, atol=1e-12)
    np.testing.assert_array_equal(np.asarray(fl), flr)


@pytest.mark.parametrize("interpret,tag", MODES)
def test_abnormal_matches_oracle_exactly(interpret, tag):
    """Winners, scores, count and typical all EXACT: the integer-key
    median reads the same order statistics as numpy, and the tournament
    reproduces the stable vid-major ranking including the -inf tail."""
    t, var, present, scales, top = _case(seed=2)
    k = 9
    cuts = [10, 20]
    orr, svr, cr, tyr = ref.abnormal_ref(t[-1], top, 1.5, 0.001, k)
    with enable_x64():
        blocks = [jnp.asarray(b) for b in np.split(t[-1], cuts, axis=0)]
        o, sv, c, ty = ops.fused_abnormal(blocks, jnp.asarray(top),
                                          1.5, 0.001, k,
                                          interpret=interpret)
    np.testing.assert_array_equal(np.asarray(o), orr)
    np.testing.assert_array_equal(np.asarray(sv), svr)
    assert int(c) == cr
    np.testing.assert_array_equal(np.asarray(ty), tyr)

    # external step time (the host-fed entry point's shape)
    orr2, _, cr2, _ = ref.abnormal_ref(t[-1], top, 1.5, 0.001, k,
                                       step_time=3.25)
    with enable_x64():
        o2, _, c2, _ = ops.fused_abnormal([jnp.asarray(t[-1])], None,
                                          1.5, 0.001, k, step_time=3.25,
                                          interpret=interpret)
    np.testing.assert_array_equal(np.asarray(o2), orr2)
    assert int(c2) == cr2


@pytest.mark.parametrize("interpret,tag", MODES)
def test_abnormal_live_masked_degraded_fleet(interpret, tag):
    """The padded live-gather variant: dead rows excluded from median,
    step time, flags and ranking — numpy row-subset semantics."""
    t, var, present, scales, top = _case(seed=4)
    P, k = t.shape[1], 7
    rng = np.random.default_rng(5)
    live = np.sort(rng.choice(P, size=P - 9, replace=False))
    lpad = np.zeros(P, np.int32)
    lpad[:live.size] = live
    vmask = np.zeros(P, bool)
    vmask[:live.size] = True
    orr, svr, cr, tyr = ref.abnormal_ref(t[-1][lpad], top, 1.5, 0.001, k,
                                         valid=vmask)
    cuts = [10, 20]
    with enable_x64():
        o, sv, c, ty = ops.fused_abnormal(
            [jnp.asarray(b) for b in np.split(t[-1], cuts, axis=0)],
            jnp.asarray(top), 1.5, 0.001, k, live=jnp.asarray(lpad),
            valid=jnp.asarray(vmask), interpret=interpret)
    np.testing.assert_array_equal(np.asarray(o), orr)
    assert int(c) == cr
    np.testing.assert_array_equal(np.asarray(ty), tyr)


def test_f32_parity_within_1e4(monkeypatch):
    """Accelerator-native precision: f32 fused results track the f64
    oracle to 1e-4; the flag set and winner order stay identical (the
    fixture keeps scores clear of the thresholds)."""
    monkeypatch.setenv("SCALANA_DETECT_F32", "1")
    t, var, present, scales, top = _case(seed=6, dtype=np.float32)
    t64, var64 = t.astype(np.float64), var.astype(np.float64)
    tmax = float(t64[-1][:, top].max(axis=0, initial=0.0).sum())
    Mr, slr, shr, flr = ref.non_scalable_ref(scales, t64, var64, present,
                                             total_max=tmax, **ARGS)
    orr, _, cr, tyr = ref.abnormal_ref(t64[-1], top, 1.5, 0.001, 9)
    for interpret, tag in MODES:
        M, sl, sh, fl = ops.fused_non_scalable(
            jnp.asarray(t), jnp.asarray(var),
            jnp.asarray(np.log(np.asarray(scales, np.float32))),
            jnp.asarray(present), total_max=tmax,
            interpret=interpret, **ARGS)
        np.testing.assert_allclose(np.asarray(M), Mr, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(sl), slr, rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_array_equal(np.asarray(fl), flr)
        o, _, c, ty = ops.fused_abnormal([jnp.asarray(t[-1])],
                                         jnp.asarray(top), 1.5, 0.001, 9,
                                         interpret=interpret)
        np.testing.assert_array_equal(np.asarray(o), orr)
        assert int(c) == cr
        np.testing.assert_allclose(np.asarray(ty), tyr, rtol=1e-4,
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("interpret,tag", MODES)
def test_abnormal_empty_flag_set(interpret, tag):
    """A perfectly uniform fleet flags nothing: count 0, every top-k
    slot holds the -inf-score tail in ascending vid-major order."""
    t = np.full((8, 5), 0.25)
    with enable_x64():
        o, sv, c, ty = ops.fused_abnormal(
            [jnp.asarray(t)], jnp.asarray(np.array([0, 1], np.int32)),
            1.5, 0.001, 4, interpret=interpret)
    assert int(c) == 0
    orr, svr, cr, _ = ref.abnormal_ref(t, np.array([0, 1]), 1.5, 0.001, 4)
    assert cr == 0
    np.testing.assert_array_equal(np.asarray(o), orr)
    np.testing.assert_array_equal(np.asarray(ty), np.full(5, 0.25))


def test_abnormal_k_zero_and_empty_entry_point():
    with enable_x64():
        o, sv, c, ty = ops.fused_abnormal([jnp.ones((4, 3))], None,
                                          1.5, 0.001, 0, step_time=1.0)
    assert o.shape == (0,) and int(c) == 0


@pytest.mark.parametrize("interpret,tag", MODES)
def test_all_dead_scale_keeps_finite(interpret, tag):
    """A scale whose every reading is zero (present vertices included)
    must produce the oracle's p0/mean fallbacks, zero share and no
    flags — never inf/nan (the unguarded-divide regression)."""
    t, var, present, scales, top = _case(seed=7)
    t[-1] = 0.0                                    # final scale all-dead
    Mr, slr, shr, flr = ref.non_scalable_ref(scales, t, var, present,
                                             top=top, **ARGS)
    assert not flr.any()
    with enable_x64():
        M, sl, sh, fl = ops.fused_non_scalable(
            jnp.asarray(t), jnp.asarray(var),
            jnp.asarray(np.log(np.asarray(scales, np.float64))),
            jnp.asarray(present), top_idx=jnp.asarray(top),
            interpret=interpret, **ARGS)
    assert np.isfinite(np.asarray(M)).all()
    assert np.isfinite(np.asarray(sl)).all()
    np.testing.assert_allclose(np.asarray(sh), shr, rtol=0, atol=1e-12)
    assert not np.asarray(fl).any()


def test_fused_live_path_no_retrace_across_live_set_sizes():
    """A flapping host hits ONE compiled fused executable: the live
    gather is padded to the fleet size, so traced shapes depend only on
    P.  (The legacy kernel has the same pin in test_device_detect.)"""
    t, var, present, scales, top = _case(seed=8)
    P = t.shape[1]
    with enable_x64():
        blocks = [jnp.asarray(t[-1])]
        topj = jnp.asarray(top)

        def run(n_dead):
            live = np.arange(P - n_dead, dtype=np.int32)
            lpad = np.zeros(P, np.int32)
            lpad[:live.size] = live
            vmask = np.zeros(P, bool)
            vmask[:live.size] = True
            return ops.fused_abnormal(blocks, topj, 1.5, 0.001, 5,
                                      live=jnp.asarray(lpad),
                                      valid=jnp.asarray(vmask))

        run(1)
        baseline = ops._ab_jnp._cache_size()
        for n_dead in (2, 5, 9, 3):
            run(n_dead)
        assert ops._ab_jnp._cache_size() == baseline


# ---------------------------------------------------------------------------
# fused == legacy through the view entry points
# ---------------------------------------------------------------------------

def _sharded_series(scales=(4, 8, 32), n_hosts=4, straggler=(3, 2, 6.0)):
    g = _step_psg(max(scales))
    p, vid, factor = straggler

    def base(proc, v, n):
        extra = factor * 0.01 if (proc, v) == (p, vid) else 0.0
        return 0.01 * (1 + proc % 3) + 0.001 * v + 0.02 / n + extra

    return g, {n: simulate(g, n, lambda pr, v, n=n: base(pr, v, n),
                           shards=min(n_hosts, n)).ppg for n in scales}


def test_view_entry_points_fused_equals_legacy():
    g, series = _sharded_series()
    scales = sorted(series)
    ref_ppg = series[scales[-1]]
    V = len(g.vertices)
    top = g.children(g.root)
    present = np.ones((len(scales), V), bool)
    views = [series[n].device_view() for n in scales]
    kw = dict(ideal_slope=0.0, slope_margin=0.05, min_share=0.0,
              strategy="mean")
    got = detect_jax.non_scalable_views(scales, views, V, present, top,
                                        kw["ideal_slope"],
                                        kw["slope_margin"],
                                        kw["min_share"], kw["strategy"],
                                        fused=True)
    want = detect_jax.non_scalable_views(scales, views, V, present, top,
                                         kw["ideal_slope"],
                                         kw["slope_margin"],
                                         kw["min_share"], kw["strategy"],
                                         fused=False)
    np.testing.assert_array_equal(got[3], want[3])          # flags
    np.testing.assert_allclose(got[0], want[0], rtol=0, atol=1e-12)
    np.testing.assert_allclose(got[1], want[1], rtol=0, atol=1e-12)

    for live_rows in (None, np.arange(1, ref_ppg.n_procs - 2)):
        got_ab = detect_jax.abnormal_topk_view(
            ref_ppg.device_view(), V, top, 1.5, 0.001, 8,
            live_rows=live_rows, fused=True)
        want_ab = detect_jax.abnormal_topk_view(
            ref_ppg.device_view(), V, top, 1.5, 0.001, 8,
            live_rows=live_rows, fused=False)
        np.testing.assert_array_equal(got_ab[0], want_ab[0])
        np.testing.assert_array_equal(got_ab[1], want_ab[1])
        assert got_ab[3] == want_ab[3]


# ---------------------------------------------------------------------------
# the historical-scale device cache
# ---------------------------------------------------------------------------

def test_steady_state_detect_dirty_rows_only_and_two_launches():
    """THE acceptance criterion, asserted via the counter seams: with
    all scales resident and a 16-row dirty write on the live scale, one
    full detect cycle (non-scalable + abnormal) uploads ONLY those 16
    rows and launches exactly 2 fused kernels — the historical merged
    columns are reused from the device cache, not recomputed."""
    g, series = _sharded_series()
    scales = sorted(series)
    live_ppg = series[scales[-1]]

    # warm-up: caches fill (one merge_column per historical scale)
    ops.reset_launch_counts()
    detect_non_scalable(series, backend="jax", min_share=0.0)
    detect_abnormal(live_ppg, backend="jax")
    assert ops.launch_counts["merge_column"] == len(scales) - 1
    hist_views = [series[n].device_view() for n in scales[:-1]]
    live_view = live_ppg.device_view()
    for v in hist_views:
        assert v.merged_column() is not None       # cache populated

    # a second clean detect: zero uploads, zero re-merges, <= 2 launches
    ops.reset_launch_counts()
    detect_non_scalable(series, backend="jax", min_share=0.0)
    detect_abnormal(live_ppg, backend="jax")
    assert dict(ops.launch_counts) == {"non_scalable_live": 1,
                                       "abnormal": 1}
    assert live_view.last_upload_rows == 0

    # 16-row dirty write on the LIVE scale only
    rows = np.arange(7, 23)
    live_ppg.perf.set_entries(rows, 2, 0.5)
    ops.reset_launch_counts()
    seen = []
    ops.on_launch = seen.append
    try:
        ns = detect_non_scalable(series, backend="jax", min_share=0.0)
        assert live_view.last_upload_rows == rows.size  # dirty rows only
        ab = detect_abnormal(live_ppg, backend="jax")
        assert live_view.last_upload_rows == 0     # already clean
    finally:
        ops.on_launch = None
    assert seen == ["non_scalable_live", "abnormal"]   # <= 2 launches
    for v in hist_views:
        assert v.last_upload_rows == 0             # historical: untouched
        assert v.merged_column() is not None

    # and the answers still match the numpy reference after the write
    assert any(a.vid == 2 for a in ab)             # the write is visible
    assert _ab_key(ab) == _ab_key(detect_abnormal(live_ppg,
                                                  backend="numpy"))
    assert [d.vid for d in ns] == \
        [d.vid for d in detect_non_scalable(series, backend="numpy",
                                            min_share=0.0)]


def test_historical_write_invalidates_exactly_that_column():
    """A write to ONE historical scale bumps its revision and refills
    only its merged column on the next detect."""
    _, series = _sharded_series()
    scales = sorted(series)
    detect_non_scalable(series, backend="jax", min_share=0.0)
    victim = series[scales[0]]
    other = series[scales[1]]
    rev = victim.device_view().revision
    victim.perf.set_entry(1, 1, 9.0)
    ops.reset_launch_counts()
    detect_non_scalable(series, backend="jax", min_share=0.0)
    assert victim.device_view().revision == rev + 1
    assert ops.launch_counts["merge_column"] == 1  # only the victim
    assert other.device_view().merged_column() is not None
    # stale column never served: the new reading lands in the result
    M, _, _, _ = detect_jax.non_scalable_views(
        scales, [series[n].device_view() for n in scales],
        len(victim.psg.vertices), np.ones((3, len(victim.psg.vertices)),
                                          bool),
        victim.psg.children(victim.psg.root), 0.0, 0.05, 0.0, "max")
    assert M[0, 1] == 9.0


def test_dtype_flip_invalidates_all_columns(monkeypatch):
    """SCALANA_DETECT_F32 mid-run: every view re-pins in full and every
    merged column refills — no stale f64 column feeds an f32 stack."""
    _, series = _sharded_series(scales=(4, 8, 16))
    detect_non_scalable(series, backend="jax", min_share=0.0)
    monkeypatch.setenv("SCALANA_DETECT_F32", "1")
    ops.reset_launch_counts()
    detect_non_scalable(series, backend="jax", min_share=0.0)
    assert ops.launch_counts["merge_column"] == len(series) - 1
    for n in sorted(series)[:-1]:
        col = series[n].device_view().merged_column()
        assert col is not None and col.dtype == jnp.float32


def test_kernel_launch_counter_on_views():
    """``view.kernel_launches`` counts detection launches fed from each
    view — cache fills on historical scales, every detect on the live
    one."""
    _, series = _sharded_series(scales=(4, 8, 16))
    scales = sorted(series)
    for _ in range(3):
        detect_non_scalable(series, backend="jax", min_share=0.0)
        detect_abnormal(series[scales[-1]], backend="jax")
    for n in scales[:-1]:
        assert series[n].device_view().kernel_launches == 1  # one merge
    # live scale: one ns + one ab launch per detect cycle
    assert series[scales[-1]].device_view().kernel_launches == 6
