"""Multi-run regression service: align, store, diff, cluster, report.

Covers the ISSUE 10 acceptance surface:

* PSG alignment edge cases — renamed vertices, added/removed subtrees,
  permuted insertion order, and runs recorded at different proc counts
  (alignment must be positional-free);
* run-store round trips through the shared checkpoint seam, including
  detect output and clustered (representative) runs;
* ``diff_runs`` flagging an injected regression on scenario-bank
  ground truth, with clean-vs-clean staying quiet;
* behavior clustering determinism, compression, and the regressed
  cluster pinpointing the true culprit processes;
* the monitor's ``archive_to`` recording its live fleet into the store.
"""
import numpy as np
import pytest

from repro.core import ShardedStore, shard_ranges
from repro.core.detect import Abnormal, NonScalable
from repro.core.graph import PPG, PSG
from repro.core.inject import simulate
from repro.monitor import Monitor, QueueTransport, ShardProducer, \
    build_chaos_psg
from repro.runs import (Alignment, RunStore, align_psgs, behavior_matrix,
                        cluster_procs, diff_runs, regressed_cluster,
                        render_regression_report, representative_ppg,
                        run_metadata, scaling_curves, vertex_signatures)
from repro.scenarios import bank
from repro.scenarios.faults import SerialFraction


# ---------------------------------------------------------------------------
# alignment
# ---------------------------------------------------------------------------

def _psg(spec):
    """Build a PSG from (kind, name, parent) triples; root is vid 0."""
    g = PSG()
    g.new_vertex("Root", "root")
    for kind, name, parent in spec:
        g.new_vertex(kind, name, parent=parent, source=f"{name}.py:1")
    return g


BASE_SPEC = [("Loop", "step", 0), ("Comp", "fwd", 1), ("Comp", "bwd", 1),
             ("Comm", "all-reduce", 1)]


def test_align_identical_graphs():
    a, b = _psg(BASE_SPEC), _psg(BASE_SPEC)
    al = align_psgs(a, b)
    assert al.pairs == [(i, i) for i in range(5)]
    assert al.a_only == [] and al.b_only == []


def test_align_renamed_vertex_is_explicit_not_positional():
    a = _psg(BASE_SPEC)
    b = _psg([("Loop", "step", 0), ("Comp", "fwd_fused", 1),
              ("Comp", "bwd", 1), ("Comm", "all-reduce", 1)])
    al = align_psgs(a, b)
    assert al.a_only == [2]               # old "fwd" removed...
    assert al.b_only == [2]               # ...new "fwd_fused" added
    assert (2, 2) not in al.pairs         # NOT silently matched by position
    assert al.a_to_b[3] == 3 and al.a_to_b[2] == -1


def test_align_added_and_removed_subtrees():
    a = _psg(BASE_SPEC)
    b = _psg(BASE_SPEC + [("Loop", "eval", 0), ("Comp", "logits", 5)])
    al = align_psgs(a, b)
    assert al.n_matched == 5
    assert al.b_only == [5, 6]
    back = align_psgs(b, a)
    assert back.a_only == [5, 6] and back.b_only == []


def test_align_permuted_insertion_order():
    a = _psg(BASE_SPEC)
    # same program, vertices inserted in a different order: vids differ
    b = PSG()
    b.new_vertex("Root", "root")
    b.new_vertex("Loop", "step", parent=0)
    b.new_vertex("Comm", "all-reduce", parent=1)
    b.new_vertex("Comp", "bwd", parent=1)
    b.new_vertex("Comp", "fwd", parent=1)
    al = align_psgs(a, b)
    assert al.a_only == [] and al.b_only == []
    m = dict(al.pairs)
    assert b.vertices[m[2]].name == "fwd"
    assert b.vertices[m[3]].name == "bwd"
    assert b.vertices[m[4]].name == "all-reduce"


def test_align_duplicate_names_match_by_occurrence_rank():
    spec = [("Loop", "step", 0), ("Comp", "comp", 1), ("Comp", "comp", 1)]
    a, b = _psg(spec), _psg(spec)
    al = align_psgs(a, b)
    assert al.pairs == [(0, 0), (1, 1), (2, 2), (3, 3)]
    sigs = vertex_signatures(a)
    assert sigs[2][1] == 0 and sigs[3][1] == 1      # occurrence ranks


# ---------------------------------------------------------------------------
# store round trips
# ---------------------------------------------------------------------------

def _sim_pair(n=32, scenario="amdahl_serial_fraction", scales=None):
    """(clean series, faulted series, plan) on scenario ground truth."""
    sc = bank.get_scenario(scenario)
    psg, plan, trace = sc.build(n)
    scales = scales or [n // 4, n // 2, n]
    bad = bank.simulate_series(psg, scales, plan.time_at_scale,
                               inject=plan.inject, seed=sc.seed)
    clean = SerialFraction(frac=0.0).plan(trace, psg, n, sc.seed)
    good = bank.simulate_series(psg, scales, clean.time_at_scale,
                                inject=clean.inject, seed=sc.seed)
    return good, bad, plan


def test_store_roundtrip_series_and_detect(tmp_path):
    good, bad, plan = _sim_pair()
    store = RunStore(str(tmp_path))
    detect = {
        "non_scalable": [NonScalable(vid=3, slope=-0.1, share=0.5,
                                     score=1.0, times={8: 0.2, 32: 0.19},
                                     kind="Comp", name="x", source="x.py:1")],
        "abnormal": [Abnormal(vid=2, proc=7, time=0.5, typical=0.1,
                              ratio=5.0, kind="Comp", name="y")],
    }
    rid = store.record(series=bad, detect=detect, meta={"label": "nightly"})
    assert store.runs() == [rid] and rid in store
    rec = store.load(rid)
    assert rec.meta["label"] == "nightly"
    assert rec.meta["schema_version"] == 1
    assert "commit" in rec.meta and "wall_time" in rec.meta
    assert rec.scale == 32
    assert rec.scales.tolist() == [8, 16, 32]
    # detect dataclasses come back as dataclasses, int keys restored
    ns = rec.detect["non_scalable"][0]
    assert isinstance(ns, NonScalable) and ns.times == {8: 0.2, 32: 0.19}
    ab = rec.detect["abnormal"][0]
    assert isinstance(ab, Abnormal) and ab.proc == 7
    # PPG reload is bit-identical through the seam
    top = bad[32]
    assert np.array_equal(np.asarray(rec.ppg.times_matrix()),
                          np.asarray(top.times_matrix()))
    assert rec.psg.to_json() == top.psg.to_json()


def test_store_ids_are_sequential_and_collision_checked(tmp_path):
    good, bad, _ = _sim_pair(n=16, scales=[8, 16])
    store = RunStore(str(tmp_path))
    r0 = store.record(ppg=good[16])
    r1 = store.record(ppg=bad[16])
    assert [r0, r1] == ["run_000000", "run_000001"] == store.runs()
    with pytest.raises(ValueError, match="already recorded"):
        store.record(ppg=good[16], run_id=r0)
    assert store.latest().run_id == r1


def test_store_clustered_record_compresses_rows(tmp_path):
    good, bad, plan = _sim_pair(n=32, scales=[32])
    store = RunStore(str(tmp_path))
    rid = store.record(ppg=bad[32], cluster=4)
    rec = store.load(rid)
    assert rec.clustering is not None
    assert rec.clustering.n_procs == 32            # original fleet size
    assert rec.clustering.n_clusters <= 4
    assert rec.ppg.n_procs == rec.clustering.n_clusters   # stored rows
    assert rec.scale == 32
    assert int(rec.clustering.counts.sum()) == 32


def test_run_metadata_stamp():
    m = run_metadata(extra_field=7)
    assert m["schema_version"] == 1
    assert m["extra_field"] == 7
    assert isinstance(m["wall_time"], float) and "timestamp" in m


# ---------------------------------------------------------------------------
# diff
# ---------------------------------------------------------------------------

def test_diff_flags_injected_fault_and_clean_is_quiet(tmp_path):
    good, bad, plan = _sim_pair()
    store = RunStore(str(tmp_path))
    a = store.load(store.record(series=good))
    b = store.load(store.record(series=bad))
    d = diff_runs(a, b)
    assert d.regressed_vids, "injected fault not flagged"
    truth = set(int(v) for v in plan.target_vids)
    k = max(1, len(truth))
    hits = sum(1 for v in d.regressed_vids[:k] if v in truth)
    assert hits / k >= 0.8
    top = d.regressions[0]
    assert top.ratio > 1.25 and top.slope_delta > 0.25
    # clean vs itself: nothing regresses
    a2 = store.load(store.record(series=good))
    quiet = diff_runs(a, a2)
    assert quiet.regressions == []
    assert quiet.alignment.a_only == [] and quiet.alignment.b_only == []


def test_diff_reports_graph_drift(tmp_path):
    good, _, _ = _sim_pair(n=16, scales=[8, 16])
    store = RunStore(str(tmp_path))
    a = store.load(store.record(series=good))
    # same perf data, but the candidate PSG grew an extra subtree
    top = good[16]
    psg2 = PSG.from_json(top.psg.to_json())
    extra = psg2.new_vertex("Loop", "eval", parent=psg2.root)
    ppg2 = PPG(psg2, top.n_procs, perf=top.perf)
    b = store.load(store.record(ppg=ppg2))
    d = diff_runs(a, b)
    assert d.added == ["Loop eval"]
    assert d.removed == []
    assert extra.vid in d.alignment.b_only


def test_diff_across_different_proc_counts(tmp_path):
    """Runs recorded at different scales still align and diff."""
    good16, _, _ = _sim_pair(n=16, scales=[8, 16])
    _, bad32, plan = _sim_pair(n=32, scales=[16, 32])
    store = RunStore(str(tmp_path))
    a = store.load(store.record(series=good16))
    b = store.load(store.record(series=bad32))
    # the runs share scale 16: that is the comparison point
    d = diff_runs(a, b)
    assert d.base_scale == 16 and d.cand_scale == 16
    assert d.alignment.n_matched == len(a.psg.vertices)
    assert set(int(v) for v in plan.target_vids) <= set(d.regressed_vids)
    # fully disjoint scales: each run compares at its own top scale
    _, bad24, _ = _sim_pair(n=24, scales=[12, 24])
    c = store.load(store.record(series=bad24))
    d2 = diff_runs(a, c)
    assert d2.base_scale == 16 and d2.cand_scale == 24
    assert d2.alignment.n_matched == len(a.psg.vertices)


def test_diff_peak_ratio_catches_few_proc_fault(tmp_path):
    """A fault on a handful of procs barely moves the mean curve; the
    peak-row ratio is what flags it."""
    sc = bank.get_scenario("serving_batch_skew")
    n = 64
    psg, plan, trace = sc.build(n)
    clean = SerialFraction(frac=0.0).plan(trace, psg, n, sc.seed)
    ppg_bad = simulate(psg, n, plan.base_fn, inject=plan.inject,
                       seed=sc.seed).ppg
    ppg_good = simulate(psg, n, clean.base_fn, inject=clean.inject,
                        seed=sc.seed).ppg
    store = RunStore(str(tmp_path))
    a = store.load(store.record(ppg=ppg_good))
    b = store.load(store.record(ppg=ppg_bad))
    d = diff_runs(a, b)
    assert set(int(v) for v in plan.target_vids) <= set(d.regressed_vids)
    flagged = {x.vid_cand: x for x in d.regressions}
    tv = int(sorted(plan.target_vids)[0])
    assert flagged[tv].peak_ratio >= 1.25


def test_scaling_curves_shape():
    good, _, _ = _sim_pair(n=16, scales=[8, 16])
    scales, M = scaling_curves(good)
    assert scales.tolist() == [8, 16]
    assert M.shape == (2, len(good[16].psg.vertices))
    assert (M >= 0).all() and M.max() > 0


# ---------------------------------------------------------------------------
# clustering
# ---------------------------------------------------------------------------

def test_cluster_identical_procs_collapse_to_one():
    good, _, _ = _sim_pair(n=16, scales=[16])
    cl = cluster_procs(good[16], max_clusters=8)
    assert cl.n_clusters == 1
    assert cl.membership.tolist() == [0] * 16
    assert cl.compression() == 16.0


def test_cluster_separates_culprits_and_is_deterministic():
    sc = bank.get_scenario("serving_batch_skew")
    n = 64
    psg, plan, _ = sc.build(n)
    ppg = simulate(psg, n, plan.base_fn, inject=plan.inject,
                   seed=sc.seed).ppg
    cl1 = cluster_procs(ppg, max_clusters=16)
    cl2 = cluster_procs(ppg, max_clusters=16)
    assert cl1.membership.tolist() == cl2.membership.tolist()
    assert np.array_equal(cl1.rep_procs, cl2.rep_procs)
    assert 1 < cl1.n_clusters <= 16
    # no cluster mixes culprit and clean procs
    culprits = set(int(p) for p in plan.culprit_procs)
    for k in range(cl1.n_clusters):
        members = set(cl1.members(k).tolist())
        assert not (members & culprits) or members <= culprits, k


def test_representative_ppg_rows_are_the_reps():
    sc = bank.get_scenario("serving_batch_skew")
    n = 32
    psg, plan, _ = sc.build(n)
    ppg = simulate(psg, n, plan.base_fn, inject=plan.inject,
                   seed=sc.seed).ppg
    cl = cluster_procs(ppg, max_clusters=8)
    rep = representative_ppg(ppg, cl)
    assert rep.n_procs == cl.n_clusters
    t_full = np.asarray(ppg.times_matrix(), float)
    t_rep = np.asarray(rep.times_matrix(), float)
    for row, proc in enumerate(cl.rep_procs.tolist()):
        assert np.array_equal(t_rep[row], t_full[proc])


def test_behavior_matrix_is_times_plus_counters():
    good, _, _ = _sim_pair(n=8, scales=[8])
    ppg = good[8]
    X = behavior_matrix(ppg)
    V = len(ppg.psg.vertices)
    assert X.shape[0] == 8 and X.shape[1] >= V
    assert np.array_equal(X[:, :V], np.asarray(ppg.times_matrix(), float))


# ---------------------------------------------------------------------------
# report + regressed cluster
# ---------------------------------------------------------------------------

def test_report_names_vertex_cluster_and_path(tmp_path):
    sc = bank.get_scenario("serving_batch_skew")
    n = 64
    psg, plan, trace = sc.build(n)
    clean = SerialFraction(frac=0.0).plan(trace, psg, n, sc.seed)
    ppg_bad = simulate(psg, n, plan.base_fn, inject=plan.inject,
                       seed=sc.seed).ppg
    ppg_good = simulate(psg, n, clean.base_fn, inject=clean.inject,
                        seed=sc.seed).ppg
    store = RunStore(str(tmp_path))
    a = store.load(store.record(ppg=ppg_good, cluster=16))
    b = store.load(store.record(ppg=ppg_bad, cluster=16))
    d = diff_runs(a, b)
    assert d.regressed_vids
    k = regressed_cluster(b, d)
    assert k is not None
    members = set(b.clustering.members(k).tolist())
    culprits = set(int(p) for p in plan.culprit_procs)
    assert members and members <= culprits     # regressed cluster is pure
    text = render_regression_report(a, b, d)
    assert "Regressed vertices" in text
    assert "Regressed cluster" in text
    assert f"cluster {k}" in text
    assert "Root-cause walk" in text
    tv = int(sorted(plan.target_vids)[0])
    assert psg.vertices[tv].name in text


def test_regressed_cluster_none_without_clustering(tmp_path):
    good, bad, _ = _sim_pair(n=16, scales=[8, 16])
    store = RunStore(str(tmp_path))
    a = store.load(store.record(series=good))
    b = store.load(store.record(series=bad))
    d = diff_runs(a, b)
    assert regressed_cluster(b, d) is None
    # report still renders, without the cluster section
    text = render_regression_report(a, b, d)
    assert "Regressed cluster" not in text


# ---------------------------------------------------------------------------
# monitor -> run store
# ---------------------------------------------------------------------------

def test_monitor_archive_to_run_store(tmp_path):
    psg = build_chaos_psg(6)
    n_procs, n_hosts = 12, 3
    ranges = shard_ranges(n_procs, n_hosts)
    sim = simulate(psg, n_procs,
                   lambda p, v: 0.0 if psg.vertices[v].kind == "Comm"
                   else 1.0 + 0.01 * v,
                   inject={(5, 2): 3.0}, comm_time=lambda *a: 0.05,
                   jitter=0.0, seed=0, shards=ranges)
    truth = sim.ppg
    tr = QueueTransport()
    mon = Monitor(psg, ranges, tr, comm=truth.comm, detect_every=1)
    prod = ShardedStore(ranges, len(psg.vertices))
    for h in range(n_hosts):
        sh = prod.shards[h]
        sh.apply_rows(truth.perf.shards[h].extract_rows(
            np.arange(sh.n_procs)))
        ShardProducer(h, sh, tr, sleep=lambda s: None).flush(heartbeat=False)
    mon.poll()
    store = RunStore(str(tmp_path))
    rid = mon.archive_to(store, meta={"label": "live"})
    rec = store.load(rid)
    assert rec.scale == n_procs
    assert rec.meta["label"] == "live"
    assert rec.meta["applied"] > 0
    # archived state is bit-identical to the live fleet's store
    V = len(psg.vertices)
    assert np.array_equal(np.asarray(rec.ppg.times_matrix()),
                          np.asarray(mon.store.time_matrix(V)))
    # the monitor's abnormal flags rode along as detect output
    assert rec.detect is not None
    assert {a.vid for a in rec.detect["abnormal"]} \
        == {a.vid for a in mon.reports[-1].abnormal}
