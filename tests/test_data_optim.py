"""Data pipeline + optimizer substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import SHAPES, get_smoke
from repro.data import SyntheticLMDataset, make_dataset
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         global_norm, warmup_cosine, warmup_linear)
from repro.optim.compress import (compress_leaf, decompress_leaf,
                                  error_feedback_update, init_residual)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def _ds(**kw):
    base = dict(vocab_size=1000, seq_len=16, global_batch=8, seed=3)
    base.update(kw)
    return SyntheticLMDataset(**base)


def test_batches_deterministic_and_distinct():
    ds = _ds()
    a, b = ds.batch(5)["tokens"], ds.batch(5)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(ds.batch(5)["tokens"], ds.batch(6)["tokens"])
    assert a.dtype == np.int32
    assert a.shape == (8, 17)
    assert a.min() >= 0 and a.max() < 1000


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 1000), shards=st.sampled_from([1, 2, 4, 8]))
def test_sharding_partitions_global_batch(step, shards):
    """Property: concatenated shards == the unsharded global batch."""
    ds = _ds()
    whole = ds.batch(step)["tokens"]
    parts = [ds.shard(shards, i).batch(step)["tokens"]
             for i in range(shards)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), whole)


def test_restart_regenerates_stream():
    """Elastic-restart guarantee: same (seed, step) -> same batch, on any
    shard topology."""
    a = _ds(num_shards=2, shard_index=1).batch(77)["tokens"]
    b = _ds(num_shards=2, shard_index=1).batch(77)["tokens"]
    np.testing.assert_array_equal(a, b)


def test_zipf_skew():
    toks = _ds(vocab_size=4096, global_batch=64).batch(0)["tokens"]
    low = np.mean(toks < 256)       # top 1/16 of id space
    assert low > 0.3                # heavily skewed vs uniform (0.0625)


def test_frontend_stub_for_encdec_and_vlm():
    for arch in ("seamless-m4t-medium", "internvl2-2b"):
        cfg = get_smoke(arch)
        ds = make_dataset(cfg, SHAPES["train_4k"], global_batch=4)
        key = "frames" if cfg.family == "encdec" else "patches"
        b = ds.batch(0)
        assert b[key].shape == (4, cfg.frontend_len, cfg.d_model)
        assert np.isfinite(b[key]).all()


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    state = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}        # d/dw ||w||^2
        params, state, _ = adamw_update(grads, state, params, lr=0.05,
                                        weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_weight_decay_shrinks_params():
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params)
    zero = {"w": jnp.zeros((4,))}
    p1, _, _ = adamw_update(zero, state, params, lr=0.1, weight_decay=0.5)
    assert float(p1["w"][0]) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert norm == pytest.approx(20.0)
    assert global_norm(clipped) == pytest.approx(1.0, rel=1e-5)
    # below max: untouched
    g2 = {"a": jnp.full((4,), 0.1)}
    c2, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(c2["a"], g2["a"], rtol=1e-6)


def test_schedules():
    f = warmup_cosine(1.0, 10, 100)
    assert float(f(0)) == pytest.approx(0.0)
    assert float(f(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(f(100)) == pytest.approx(0.1, rel=1e-2)
    g = warmup_linear(2.0, 5, 50)
    assert float(g(5)) == pytest.approx(2.0, rel=1e-3)
    assert float(g(50)) == pytest.approx(0.2, rel=1e-2)


# ---------------------------------------------------------------------------
# gradient compression with error feedback
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 2000), scale=st.floats(1e-4, 1e3))
def test_compress_roundtrip_bounded_error(n, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    codes, s = compress_leaf(x)
    y = decompress_leaf(codes, s, x.shape)
    # per-block max-abs quantization: error <= scale_block = max/127
    err = np.abs(np.asarray(x) - np.asarray(y))
    bound = np.abs(np.asarray(x)).max() / 127.0 + 1e-7
    assert err.max() <= bound * 1.0001


def test_error_feedback_preserves_signal():
    """Sum of EF-compressed grads converges to the sum of true grads."""
    rng = np.random.default_rng(0)
    true = [jnp.asarray(rng.standard_normal(64) * 0.1, jnp.float32)
            for _ in range(50)]
    params = {"w": jnp.zeros((64,))}
    residual = init_residual(params)
    acc_comp = jnp.zeros((64,))
    for g in true:
        comp, residual = error_feedback_update({"w": g}, residual)
        acc_comp = acc_comp + comp["w"]
    acc_true = sum(np.asarray(g) for g in true)
    # error feedback: accumulated compressed signal tracks the true sum
    # within one quantization step (residual carries the rest)
    diff = np.abs(acc_comp - acc_true)
    assert diff.max() < 0.05, diff.max()
