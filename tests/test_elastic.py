"""Elastic scaling: checkpoint/data-stream invariance across re-sharding.

The 1000-node story requires that a job can restart on a DIFFERENT
topology: the checkpoint is mesh-agnostic (saved logically unsharded) and
the data pipeline regenerates the identical global stream for any shard
count — together these make elastic restarts exact, not approximate."""
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import SHAPES, get_smoke
from repro.configs.base import RunConfig, ShapeConfig
from repro.data import make_dataset
from repro.training import Trainer


def test_global_stream_invariant_under_resharding():
    cfg = get_smoke("tinyllama-1.1b")
    shape = ShapeConfig("t", 32, 16, "train")
    for step in (0, 7, 123):
        global_batch = make_dataset(cfg, shape).batch(step)["tokens"]
        for shards in (2, 4, 8, 16):
            ds = make_dataset(cfg, shape)
            parts = [ds.shard(shards, i).batch(step)["tokens"]
                     for i in range(shards)]
            np.testing.assert_array_equal(np.concatenate(parts), global_batch)


def test_restart_on_different_topology_is_exact(tmp_path):
    """Train 4 steps 'on one topology', restart 'on another': losses equal
    an uninterrupted run (the simulated topology change = different shard
    views of the same global batch; single-controller CPU run consumes the
    full global batch either way, so exactness reduces to checkpoint+data
    determinism — asserted here end-to-end)."""
    def run(ckpt, steps, num_steps):
        run_cfg = RunConfig(arch="tinyllama-1.1b", total_steps=steps,
                            warmup_steps=2, learning_rate=1e-3,
                            checkpoint_dir=ckpt, checkpoint_every=100,
                            scalana=False)
        tr = Trainer(run_cfg, arch_cfg=get_smoke("tinyllama-1.1b"),
                     shape=ShapeConfig("t", 32, 4, "train"))
        tr.train(num_steps=num_steps)
        return [m["loss"] for m in tr.metrics_log]

    a = str(tmp_path / "a")
    once = run(str(tmp_path / "b"), 8, 8)
    run(a, 8, 4)
    resumed = run(a, 8, 4)
    np.testing.assert_allclose(resumed, once[4:], rtol=1e-5)


def test_checkpoint_roundtrip_independent_of_leaf_order(tmp_path):
    """Leaves are addressed by path, not position: a restarted process
    with a differently-ordered (but congruent) pytree restores correctly."""
    import jax.numpy as jnp
    tree = {"b": jnp.ones((3,)), "a": {"x": jnp.zeros((2, 2))}}
    save_checkpoint(str(tmp_path), 1, tree)
    reordered = {"a": {"x": jnp.full((2, 2), 9.0)}, "b": jnp.zeros((3,))}
    loaded, _ = load_checkpoint(str(tmp_path), 1, reordered)
    np.testing.assert_array_equal(np.asarray(loaded["b"]), np.ones((3,)))
    np.testing.assert_array_equal(np.asarray(loaded["a"]["x"]),
                                  np.zeros((2, 2)))
