"""Indexed graph core: indexes vs brute force, dense store vs dict API,
sparse counter columns vs a dense reference, implicit comm groups vs
materialized edges, detect/backtrack equivalence, and jitted detection vs
the numpy reference (including the all-jax-absent fallback path).

The brute-force references are verbatim ports of the pre-index scalar
implementations, so these properties pin the refactor to the old
semantics."""
import math
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (COMM, COMP, LOOP, PSG, backtrack, build_ppg, contract,
                        detect_abnormal, detect_non_scalable, root_causes)
from repro.core.backtrack import WAIT_COUNTER, _anomaly_score
from repro.core.detect import _merge, _merge_matrix
from repro.core.graph import PerfStore, PerfVector
from repro.core.inject import simulate, simulate_series


# ---------------------------------------------------------------------------
# random graph strategy
# ---------------------------------------------------------------------------

@st.composite
def random_indexed_psg(draw):
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    frontier = [root.vid]
    n = draw(st.integers(4, 30))
    for _ in range(n):
        parent = draw(st.sampled_from(frontier))
        kind = draw(st.sampled_from([COMP, COMP, LOOP, COMM]))
        v = g.new_vertex(kind, kind.lower(), parent=parent,
                         depth=g.vertices[parent].depth + 1)
        if kind == COMM:
            v.comm_kind, v.comm_bytes = "all_reduce", 1e4
        if kind == LOOP:
            frontier.append(v.vid)
    for parent in {v.parent for v in g.vertices if v.parent >= 0}:
        kids = g.children(parent)
        for a, b in zip(kids, kids[1:]):
            g.add_edge(a, b, "data")
        for k in kids:
            g.add_edge(parent, k, "control")
    # a few extra cross edges
    extra = draw(st.integers(0, 5))
    for _ in range(extra):
        a = draw(st.integers(1, len(g.vertices) - 1))
        b = draw(st.integers(1, len(g.vertices) - 1))
        kind = draw(st.sampled_from(["data", "control"]))
        g.add_edge(a, b, kind)
    return g


# ---------------------------------------------------------------------------
# PSG index vs brute force
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(psg=random_indexed_psg())
def test_indexes_match_brute_force(psg):
    edges = set(psg.edges)
    for v in psg.vertices:
        vid = v.vid
        assert sorted(psg.children(vid)) == sorted(
            u.vid for u in psg.vertices if u.parent == vid)
        for kind in (None, "data", "control"):
            assert sorted(psg.preds(vid, kind)) == sorted(
                s for (s, d, k) in edges
                if d == vid and (kind is None or k == kind))
            assert sorted(psg.succs(vid, kind)) == sorted(
                d for (s, d, k) in edges
                if s == vid and (kind is None or k == kind))
    for kind in ("Root", COMP, LOOP, COMM):
        assert [u.vid for u in psg.by_kind(kind)] == \
            [u.vid for u in psg.vertices if u.kind == kind]


@settings(max_examples=20, deadline=None)
@given(psg=random_indexed_psg())
def test_index_survives_contraction_and_roundtrip(psg):
    cpsg, _ = contract(psg, max_loop_depth=2)
    for v in cpsg.vertices:
        assert sorted(cpsg.children(v.vid)) == sorted(
            u.vid for u in cpsg.vertices if u.parent == v.vid)
    clone = PSG.from_json(cpsg.to_json())
    assert clone.edges == cpsg.edges
    assert clone.stats() == cpsg.stats()
    for v in clone.vertices:
        assert sorted(clone.children(v.vid)) == sorted(
            u.vid for u in clone.vertices if u.parent == v.vid)


def test_filter_does_not_alias_source_vertices():
    """Regression: contraction._filter shared prims/p2p_pairs/meta lists
    with the source PSG, so mutating the filtered graph corrupted it."""
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    a = g.new_vertex(COMP, "a", parent=root.vid)
    a.prims = ["dot"]
    a.flops = 5.0
    c = g.new_vertex(COMM, "ppermute", parent=root.vid)
    c.p2p_pairs = [(0, 1)]
    c.meta["replica_groups"] = [[0, 1]]
    zero = g.new_vertex(COMP, "zero", parent=root.vid)   # dropped by filter
    for v in (a, c, zero):
        g.add_edge(root.vid, v.vid, "control")
    cpsg, mapping = contract(g, min_comp_flops=1.0)
    nv = cpsg.vertices[mapping[a.vid]]
    nv.prims.append("mutated")
    cpsg.vertices[mapping[c.vid]].p2p_pairs.append((9, 9))
    cpsg.vertices[mapping[c.vid]].meta["x"] = 1
    assert g.vertices[a.vid].prims == ["dot"]
    assert g.vertices[c.vid].p2p_pairs == [(0, 1)]
    assert "x" not in g.vertices[c.vid].meta


# ---------------------------------------------------------------------------
# PerfStore mapping compatibility
# ---------------------------------------------------------------------------

def test_perfstore_mapping_api():
    s = PerfStore(4, 3)
    s[(1, 2)] = PerfVector(time=0.5, samples=2, counters={"wait_s": 0.1})
    s[(0, 0)] = PerfVector(time=0.25)
    assert len(s) == 2
    assert (1, 2) in s and (2, 2) not in s
    assert s[(1, 2)].time == 0.5
    assert s[(1, 2)].counters == {"wait_s": 0.1}
    assert s.get((3, 1)) is None
    assert sorted(s.keys()) == [(0, 0), (1, 2)]
    # overwrite clears stale counters — in the dict view AND the raw
    # matrices the vectorized detectors/backtracker read
    s[(1, 2)] = PerfVector(time=0.7)
    assert s[(1, 2)].counters == {}
    assert s.counter_at("wait_s", 1, 2) == 0.0
    assert float(s.counter_matrix("wait_s")[1, 2]) == 0.0
    # growth past the initial column count
    s[(2, 10)] = PerfVector(time=1.0, counters={"flops": 3.0})
    assert s[(2, 10)].counters["flops"] == 3.0
    assert s.time_matrix(11).shape == (4, 11)
    assert float(s.time_matrix(11)[2, 10]) == 1.0


def test_ppg_get_time_defaults_zero():
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    v = g.new_vertex(COMP, "a", parent=root.vid)
    ppg = build_ppg(g, 4)
    assert ppg.get_time(2, v.vid) == 0.0
    assert ppg.times_across_procs(v.vid) == [0.0] * 4


# ---------------------------------------------------------------------------
# sparse counter columns vs dense reference
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n_procs=st.integers(1, 8), n_vertices=st.integers(1, 10),
       seed=st.integers(0, 10**6), n_ops=st.integers(1, 40))
def test_sparse_counters_match_dense_reference(n_procs, n_vertices, seed,
                                               n_ops):
    """Random write sequences through every store entry point: the sparse
    column layout must be observationally identical to dense (P, V)
    matrices, via counter_matrix, counter_columns, counter_at and the
    mapping API."""
    rng = np.random.default_rng(seed)
    store = PerfStore(n_procs, n_vertices)
    names = ["wait_s", "flops", "bytes"]
    v_max = n_vertices + 6                      # exercise growth past init
    dense = {nm: np.zeros((n_procs, v_max)) for nm in names}
    dmask = {nm: np.zeros((n_procs, v_max), bool) for nm in names}
    for _ in range(n_ops):
        op = int(rng.integers(3))
        vid = int(rng.integers(v_max))
        p = int(rng.integers(n_procs))
        counters = {nm: float(rng.uniform(0.1, 10.0))
                    for nm in names if rng.uniform() < 0.6}
        if op == 0:
            store.set_entry(p, vid, float(rng.uniform()), counters=counters)
            for nm, val in counters.items():
                dense[nm][p, vid], dmask[nm][p, vid] = val, True
        elif op == 1:
            store.set_column(vid, rng.uniform(0.1, 1.0, n_procs),
                             counters=counters)
            for nm, val in counters.items():
                dense[nm][:, vid], dmask[nm][:, vid] = val, True
        else:                                   # overwrite clears stale
            store[(p, vid)] = PerfVector(time=float(rng.uniform()),
                                         counters=counters)
            for nm in names:
                dense[nm][p, vid], dmask[nm][p, vid] = 0.0, False
            for nm, val in counters.items():
                dense[nm][p, vid], dmask[nm][p, vid] = val, True
    for nm in names:
        ref = np.where(dmask[nm], dense[nm], 0.0)
        assert np.array_equal(store.counter_matrix(nm, v_max), ref)
        vids, values, mask = store.counter_columns(nm)
        assert len(set(vids.tolist())) == len(vids)      # one slot per vid
        recon = np.zeros((n_procs, v_max))
        recon[:, vids] = np.where(mask, values, 0.0)
        assert np.array_equal(recon, ref)
        for p in range(n_procs):
            for vid in range(v_max):
                want = dense[nm][p, vid] if dmask[nm][p, vid] else -1.0
                assert store.counter_at(nm, p, vid, default=-1.0) == want
    for key in store.keys():
        for nm, val in store[key].counters.items():
            assert dmask[nm][key] and dense[nm][key] == val


def test_counter_storage_tracks_defining_subset():
    """A counter written at 2 of 100 columns must cost ~2 columns, not a
    dense (P, 100) matrix — the V/|Comm| memory claim."""
    store = PerfStore(64, 100)
    for vid in (3, 97):
        store.set_column(vid, 1.0, counters={"wait_s": 0.5})
    assert store.counter_nbytes() < store.counter_dense_nbytes() / 10
    assert store.counter_names() == ["wait_s"]


# ---------------------------------------------------------------------------
# implicit comm groups vs materialized edges
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n_procs=st.integers(2, 12), n_groups=st.integers(1, 3))
def test_comm_partners_match_materialized_clique(n_procs, n_groups):
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    c = g.new_vertex(COMM, "psum", parent=root.vid)
    c.comm_kind = "all_reduce"
    procs = list(range(n_procs))
    groups = [procs[i::n_groups] for i in range(n_groups)]
    c.meta["replica_groups"] = groups
    p2p = g.new_vertex(COMM, "ppermute", parent=root.vid)
    p2p.p2p_pairs = [(p, (p + 1) % n_procs) for p in range(n_procs)]
    ppg = build_ppg(g, n_procs)

    edges = set()
    for grp in groups:
        for i in grp:
            for j in grp:
                if i != j:
                    edges.add(((i, c.vid), (j, c.vid)))
    for (s, d) in p2p.p2p_pairs:
        edges.add(((s, p2p.vid), (d, p2p.vid)))

    # the lazy view equals the materialized reference exactly
    assert set(ppg.comm_edges) == edges
    assert len(ppg.comm_edges) == len(edges)
    for e in edges:
        assert e in ppg.comm_edges
    for vid in (c.vid, p2p.vid):
        for p in range(n_procs):
            ref = sorted(src for (src, dst) in edges if dst == (p, vid))
            assert sorted(ppg.comm_partners(p, vid)) == ref


def test_comm_partners_unions_overlapping_groups():
    """Regression: a vertex carrying several groups (staged collectives)
    must union partners from every group containing the proc, deduplicated
    — exactly what the old materialized edge set produced."""
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    c = g.new_vertex(COMM, "psum", parent=root.vid)
    c.comm_kind = "all_reduce"
    from repro.core.graph import PPG
    ppg = PPG(g, 4)           # bare PPG: no auto full-range group
    ppg.add_collective_edges(c.vid, [0, 1])
    ppg.add_collective_edges(c.vid, [1, 2])
    ppg.add_collective_edges(c.vid, [0, 1, 3])    # overlaps the first group
    assert sorted(ppg.comm_partners(1, c.vid)) == \
        [(0, c.vid), (2, c.vid), (3, c.vid)]
    assert ((2, c.vid), (1, c.vid)) in ppg.comm_edges
    assert sorted(ppg.comm_partners(3, c.vid)) == [(0, c.vid), (1, c.vid)]


def test_collective_storage_is_linear_in_procs():
    def comm_bytes(n):
        g = PSG()
        root = g.new_vertex("Root", "root")
        g.root = root.vid
        c = g.new_vertex(COMM, "psum", parent=root.vid)
        c.comm_kind = "all_reduce"
        return build_ppg(g, n).comm.nbytes()
    b256, b1024 = comm_bytes(256), comm_bytes(1024)
    assert b1024 <= 4 * b256 + 64          # O(P), not O(P^2)


# ---------------------------------------------------------------------------
# detect: vectorized vs scalar reference
# ---------------------------------------------------------------------------

def _ref_merge(times, strategy):
    arr = np.asarray([t for t in times if t > 0.0])
    if arr.size == 0:
        return 0.0
    if strategy == "mean":
        return float(arr.mean())
    if strategy == "median":
        return float(np.median(arr))
    if strategy == "max":
        return float(arr.max())
    if strategy == "cluster":
        s = np.sort(arr)
        best_cut, best_gap = None, -1.0
        for i in range(1, s.size):
            gap = s[i] - s[i - 1]
            if gap > best_gap:
                best_gap, best_cut = gap, i
        hi = s[best_cut:] if best_cut is not None else s
        return float(hi.mean())
    raise ValueError(strategy)


@settings(max_examples=25, deadline=None)
@given(p=st.integers(1, 16), v=st.integers(1, 12), seed=st.integers(0, 10**6),
       strategy=st.sampled_from(["mean", "median", "max", "cluster"]))
def test_merge_matrix_matches_scalar(p, v, seed, strategy):
    rng = np.random.default_rng(seed)
    t = rng.uniform(0.0, 1.0, (p, v))
    t[rng.uniform(size=(p, v)) < 0.3] = 0.0       # dead readings
    got = _merge_matrix(t, strategy)
    for col in range(v):
        assert got[col] == pytest.approx(
            _ref_merge(t[:, col].tolist(), strategy), abs=1e-12)


def test_merge_p0_ignores_dead_proc0():
    """Regression: 'p0' returned times[0] without the >0 filter, so a dead
    proc-0 reading (0.0) silently dropped the vertex."""
    assert _merge([0.0, 0.2, 0.4], "p0") == pytest.approx(0.3)   # mean of live
    assert _merge([0.5, 0.2, 0.4], "p0") == 0.5                  # p0 alive
    got = _merge_matrix(np.array([[0.0, 0.5], [0.2, 0.1], [0.4, 0.3]]), "p0")
    assert got[0] == pytest.approx(0.3)
    assert got[1] == 0.5


def _ref_detect_abnormal(ppg, abnorm_thd=1.3, min_share=0.01, top_k=20):
    """Verbatim port of the pre-refactor scalar detector."""
    psg = ppg.psg
    step_time = max(
        sum(ppg.get_time(p, v.vid) for v in psg.vertices
            if v.parent == psg.root)
        for p in range(ppg.n_procs)) or 1e-12
    out = []
    for v in psg.vertices:
        arr = np.asarray(ppg.times_across_procs(v.vid))
        if arr.max() <= 0:
            continue
        typical = float(np.median(arr))
        for proc, t in enumerate(arr.tolist()):
            if typical > 0 and t > abnorm_thd * typical \
                    and (t - typical) / step_time >= min_share:
                out.append((v.vid, proc, t, typical))
            elif typical == 0 and t / step_time >= min_share:
                out.append((v.vid, proc, t, typical))
    out.sort(key=lambda d: -(d[2] - d[3]))
    return out[:top_k]


@settings(max_examples=20, deadline=None)
@given(n_procs=st.integers(2, 16), seed=st.integers(0, 10**6),
       thd=st.floats(1.1, 3.0))
def test_detect_abnormal_matches_reference(n_procs, seed, thd):
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    rng = np.random.default_rng(seed)
    vids = [g.new_vertex(COMP, f"c{i}", parent=root.vid).vid
            for i in range(6)]
    perf = {p: {vid: PerfVector(time=float(rng.uniform(0, 1))
                                if rng.uniform() > 0.2 else 0.0)
                for vid in vids} for p in range(n_procs)}
    ppg = build_ppg(g, n_procs, perf)
    got = [(a.vid, a.proc, a.time, a.typical)
           for a in detect_abnormal(ppg, abnorm_thd=thd)]
    ref = _ref_detect_abnormal(ppg, abnorm_thd=thd)
    assert [(v, p) for v, p, _, _ in got] == [(v, p) for v, p, _, _ in ref]
    for (gv, gp, gt, gy), (rv, rp, rt, ry) in zip(got, ref):
        assert gt == pytest.approx(rt, abs=1e-15)
        assert gy == pytest.approx(ry, abs=1e-15)


def _ref_detect_non_scalable(series, ideal_slope=-1.0, slope_margin=0.35,
                             min_share=0.02, strategy="mean"):
    """Verbatim port of the pre-refactor scalar detector (flag set only)."""
    scales = sorted(series)
    ref = series[scales[-1]]
    psg = ref.psg
    total_max = sum(max(ref.times_across_procs(v.vid) or [0.0])
                    for v in psg.vertices if v.parent == psg.root) or 1e-12
    flagged = []
    for v in psg.vertices:
        merged = {}
        for p in scales:
            ppg = series[p]
            if v.vid < len(ppg.psg.vertices):
                merged[p] = _ref_merge(ppg.times_across_procs(v.vid),
                                       strategy)
        if sum(merged.values()) <= 0:
            continue
        xs = [math.log(p) for p, t in merged.items() if t > 0]
        ys = [math.log(t) for t in merged.values() if t > 0]
        slope = float(np.polyfit(xs, ys, 1)[0]) if len(xs) >= 2 else 0.0
        share = merged.get(scales[-1], 0.0) / total_max
        if slope - ideal_slope > slope_margin and share >= min_share:
            flagged.append((v.vid, slope, share))
    return flagged


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6),
       strategy=st.sampled_from(["mean", "median", "max"]))
def test_detect_non_scalable_matches_reference(seed, strategy):
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    rng = np.random.default_rng(seed)
    bad = set(rng.choice(6, 2, replace=False).tolist())
    for i in range(6):
        g.add_edge(root.vid, g.new_vertex(COMP, f"c{i}",
                                          parent=root.vid).vid, "control")

    def time_at(p, vid, n):
        if vid - 1 in bad:                       # serial fraction (Amdahl)
            return 1.0 * (0.6 + 0.4 / n)
        return 1.0 / n

    series = simulate_series(g, [4, 8, 16, 32], time_at, jitter=0.01,
                             seed=seed)
    got = detect_non_scalable(series, strategy=strategy, top_k=100)
    ref = _ref_detect_non_scalable(series, strategy=strategy)
    assert sorted(d.vid for d in got) == sorted(v for v, _, _ in ref)
    ref_by_vid = {v: (s, sh) for v, s, sh in ref}
    for d in got:
        assert d.slope == pytest.approx(ref_by_vid[d.vid][0], rel=1e-9)
        assert d.share == pytest.approx(ref_by_vid[d.vid][1], rel=1e-9)


# ---------------------------------------------------------------------------
# jitted detection vs the numpy reference
# ---------------------------------------------------------------------------

from repro.core.detect import JIT_STRATEGIES as JIT_MERGES  # noqa: E402


def _random_series(seed):
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    rng = np.random.default_rng(seed)
    bad = set(rng.choice(6, 2, replace=False).tolist())
    for i in range(6):
        g.add_edge(root.vid, g.new_vertex(COMP, f"c{i}",
                                          parent=root.vid).vid, "control")

    def time_at(p, vid, n):
        if vid - 1 in bad:
            return 1.0 * (0.6 + 0.4 / n)
        return 1.0 / n

    return simulate_series(g, [4, 8, 16, 32], time_at, jitter=0.01,
                           seed=seed)


@settings(max_examples=15, deadline=None)
@given(p=st.integers(1, 16), v=st.integers(1, 12), seed=st.integers(0, 10**6),
       strategy=st.sampled_from(JIT_MERGES))
def test_merge_matrix_jax_matches_numpy(p, v, seed, strategy):
    pytest.importorskip("jax")
    from repro.core import detect_jax
    rng = np.random.default_rng(seed)
    t = rng.uniform(0.0, 1.0, (p, v))
    t[rng.uniform(size=(p, v)) < 0.3] = 0.0
    var = rng.uniform(0.0, 0.1, (p, v))
    got = detect_jax.merge_matrix(t, strategy, var=var)
    ref = _merge_matrix(t, strategy, var=var)
    assert np.allclose(got, ref, rtol=1e-12, atol=1e-15)


@settings(max_examples=15, deadline=None)
@given(p=st.integers(1, 12), v=st.integers(1, 8), seed=st.integers(0, 10**6))
def test_merge_var_matches_scalar(p, v, seed):
    rng = np.random.default_rng(seed)
    t = rng.uniform(0.0, 1.0, (p, v))
    t[rng.uniform(size=(p, v)) < 0.3] = 0.0
    var = rng.uniform(0.0, 0.1, (p, v))
    got = _merge_matrix(t, "var", var=var)
    for col in range(v):
        ref = _merge(t[:, col].tolist(), "var",
                     variances=var[:, col].tolist())
        assert got[col] == pytest.approx(ref, abs=1e-12)
    # without variance data every weight is equal: degrades to "mean"
    assert np.allclose(_merge_matrix(t, "var"), _merge_matrix(t, "mean"),
                       rtol=1e-9)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10**6), strategy=st.sampled_from(JIT_MERGES))
def test_detect_non_scalable_jax_matches_numpy(seed, strategy):
    pytest.importorskip("jax")
    series = _random_series(seed)
    a = detect_non_scalable(series, strategy=strategy, top_k=100,
                            backend="numpy")
    b = detect_non_scalable(series, strategy=strategy, top_k=100,
                            backend="jax")
    assert [d.vid for d in a] == [d.vid for d in b]
    for x, y in zip(a, b):
        assert y.slope == pytest.approx(x.slope, rel=1e-9)
        assert y.share == pytest.approx(x.share, rel=1e-9)
        assert sorted(y.times) == sorted(x.times)
        for scale, t in x.times.items():
            assert y.times[scale] == pytest.approx(t, rel=1e-9)


@settings(max_examples=10, deadline=None)
@given(n_procs=st.integers(2, 16), seed=st.integers(0, 10**6),
       thd=st.floats(1.1, 3.0))
def test_detect_abnormal_jax_matches_numpy(n_procs, seed, thd):
    pytest.importorskip("jax")
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    rng = np.random.default_rng(seed)
    vids = [g.new_vertex(COMP, f"c{i}", parent=root.vid).vid
            for i in range(6)]
    perf = {p: {vid: PerfVector(time=float(rng.uniform(0, 1))
                                if rng.uniform() > 0.2 else 0.0,
                                time_var=float(rng.uniform(0, 0.01)))
                for vid in vids} for p in range(n_procs)}
    ppg = build_ppg(g, n_procs, perf)
    a = detect_abnormal(ppg, abnorm_thd=thd, backend="numpy")
    b = detect_abnormal(ppg, abnorm_thd=thd, backend="jax")
    assert [(x.vid, x.proc) for x in a] == [(y.vid, y.proc) for y in b]
    for x, y in zip(a, b):
        assert y.time == pytest.approx(x.time, abs=1e-15)
        assert y.typical == pytest.approx(x.typical, abs=1e-12)


def test_analysis_layer_and_auto_backend_run_without_jax():
    """The jax-absent fallback path, end to end in a clean interpreter:
    importing the analysis layer and running both detectors with the
    default backend must never pull jax into the process."""
    code = textwrap.dedent("""
        import sys
        from repro.core import PSG, COMP, backtrack, detect_abnormal, \\
            detect_non_scalable
        from repro.core.detect import _resolve_backend
        from repro.core.inject import simulate, simulate_series
        assert "jax" not in sys.modules, "lazy analysis layer imported jax"
        assert _resolve_backend("auto") is None
        g = PSG()
        root = g.new_vertex("Root", "root")
        g.root = root.vid
        for i in range(4):
            v = g.new_vertex(COMP, f"c{i}", parent=root.vid)
            g.add_edge(root.vid, v.vid, "control")
        series = simulate_series(
            g, [2, 4, 8],
            lambda p, vid, n: 0.5 + 1.0 / n if vid == 1 else 1.0 / n)
        ns = detect_non_scalable(series)
        ab = detect_abnormal(series[8])
        paths = backtrack(series[8], ns, ab)
        assert ns and ns[0].vid == 1
        assert "jax" not in sys.modules, "detection pulled jax in"
        print("fallback-ok")
    """)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert "fallback-ok" in out.stdout


# ---------------------------------------------------------------------------
# backtrack equivalence on the straggler scenario
# ---------------------------------------------------------------------------

def _straggler_scenario(n_procs=8):
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    c0 = g.new_vertex(COMP, "load", parent=root.vid, source="app.py:10")
    p2p = g.new_vertex(COMM, "ppermute", parent=root.vid, source="app.py:30")
    p2p.comm_kind = "ppermute"
    p2p.p2p_pairs = [(i, (i + 1) % n_procs) for i in range(n_procs)]
    c2 = g.new_vertex(COMP, "solve", parent=root.vid, source="app.py:40")
    ar = g.new_vertex(COMM, "psum", parent=root.vid, source="app.py:50")
    ar.comm_kind, ar.comm_bytes = "all_reduce", 1e6
    for v in (c0, p2p, c2, ar):
        g.add_edge(root.vid, v.vid, "control")
    g.add_edge(c0.vid, p2p.vid, "data")
    g.add_edge(p2p.vid, c2.vid, "data")
    g.add_edge(c2.vid, ar.vid, "data")
    return g, c0.vid


def test_straggler_pipeline_end_to_end_deterministic():
    """detect + backtrack + root_causes on the injected-straggler scenario:
    the root cause is exactly the injected (proc, vertex), and a repeat run
    is node-for-node identical (index refactor kept walk order stable)."""
    g, c0 = _straggler_scenario()
    runs = []
    for _ in range(2):
        res = simulate(g, 8, lambda p, vid: 0.01, inject={(4, c0): 0.5})
        ab = detect_abnormal(res.ppg, abnorm_thd=1.3)
        paths = backtrack(res.ppg, [], ab)
        rcs = root_causes(paths, g, ppg=res.ppg)
        runs.append(([(a.proc, a.vid) for a in ab],
                     [p.nodes for p in paths], rcs))
    assert runs[0] == runs[1]
    ab_nodes, path_nodes, rcs = runs[0]
    assert any(node == (4, c0) for node, _, _ in rcs)


def test_anomaly_score_matches_scalar_reference():
    g, c0 = _straggler_scenario()
    res = simulate(g, 8, lambda p, vid: 0.01, inject={(4, c0): 0.5})
    ppg = res.ppg

    def ref_score(node):
        vec = ppg.perf.get(node)
        if vec is None:
            return 0.0

        def busy(p):
            v = ppg.perf.get((p, node[1]))
            if v is None:
                return 0.0
            return v.time - float(v.counters.get(WAIT_COUNTER, 0.0))

        mine = busy(node[0])
        others = sorted(b for p in range(ppg.n_procs)
                        if (b := busy(p)) > 0.0)
        if not others:
            return mine
        return mine - others[len(others) // 2]

    for vid in range(len(g.vertices)):
        for p in range(8):
            assert _anomaly_score(ppg, (p, vid)) == pytest.approx(
                ref_score((p, vid)), abs=1e-15)
