"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

SWEEP = [
    # (B, nq, nkv, Sq, Sk, h, causal, bq, bk, dtype)
    (1, 2, 2, 64, 64, 32, True, 32, 32, jnp.float32),
    (2, 4, 2, 128, 128, 64, True, 64, 64, jnp.float32),
    (1, 8, 1, 128, 128, 64, True, 128, 64, jnp.float32),   # MQA
    (2, 4, 4, 64, 128, 32, False, 64, 64, jnp.float32),    # cross-ish
    (1, 2, 2, 128, 128, 128, True, 64, 64, jnp.float32),   # big head
    (1, 4, 2, 64, 64, 64, True, 64, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("case", SWEEP)
def test_flash_attention_sweep(case):
    B, nq, nkv, Sq, Sk, h, causal, bq, bk, dt = case
    q = jnp.asarray(RNG.standard_normal((B, nq, Sq, h)), dt)
    k = jnp.asarray(RNG.standard_normal((B, nkv, Sk, h)), dt)
    v = jnp.asarray(RNG.standard_normal((B, nkv, Sk, h)), dt)
    out = flash_attention_kernel(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dt == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_models_layout():
    """ops wrapper takes the models' (B, S, heads, h) layout."""
    B, S, nq, nkv, h = 2, 64, 4, 2, 32
    q = jnp.asarray(RNG.standard_normal((B, S, nq, h)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, nkv, h)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, nkv, h)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                        jnp.swapaxes(v, 1, 2), causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(jnp.swapaxes(ref, 1, 2),
                                          np.float32), rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    B=st.integers(1, 2),
    nkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    blocks=st.integers(1, 3),
    h=st.sampled_from([32, 64]),
    causal=st.booleans(),
)
def test_flash_attention_property(B, nkv, group, blocks, h, causal):
    nq = nkv * group
    S = 32 * blocks
    rng = np.random.default_rng(B * 100 + nq * 10 + S + h)
    q = jnp.asarray(rng.standard_normal((B, nq, S, h)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, nkv, S, h)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, nkv, S, h)), jnp.float32)
    out = flash_attention_kernel(q, k, v, causal=causal, block_q=32,
                                 block_k=32, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_SWEEP = [
    # (B, S, H, P, N, chunk)
    (1, 64, 2, 32, 16, 32),
    (2, 128, 4, 64, 32, 64),
    (1, 96, 2, 32, 16, 32),
    (2, 100, 3, 16, 8, 64),      # ragged: padding path
    (1, 256, 1, 64, 64, 64),
]


def _ssd_inputs(B, S, H, P, N, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, H))) * 0.1,
                     jnp.float32)
    A = -jnp.asarray(np.abs(rng.standard_normal((H,))) + 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("case", SSD_SWEEP)
def test_ssd_scan_sweep(case):
    B, S, H, P, N, chunk = case
    x, dt, A, Bm, Cm = _ssd_inputs(B, S, H, P, N, seed=sum(case))
    y, hf = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, return_final=True,
                     interpret=True)
    yr, hr = ssd_ref(x, dt, A, Bm, Cm, chunk=chunk, return_final=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr),
                               rtol=2e-4, atol=2e-4)


def test_ssd_scan_matches_sequential_recurrence():
    """Chunked kernel == naive per-step recurrence (independent oracle)."""
    B, S, H, P, N = 1, 32, 2, 8, 4
    x, dt, A, Bm, Cm = _ssd_inputs(B, S, H, P, N, seed=3)
    y, hf = ssd_scan(x, dt, A, Bm, Cm, chunk=16, return_final=True,
                     interpret=True)
    h = np.zeros((B, H, N, P))
    ys = np.zeros((B, S, H, P))
    xn, dtn, An = np.asarray(x), np.asarray(dt), np.asarray(A)
    Bn, Cn = np.asarray(Bm), np.asarray(Cm)
    for t in range(S):
        dA = np.exp(dtn[:, t] * An)                        # (B,H)
        dBx = np.einsum("bh,bn,bhp->bhnp", dtn[:, t], Bn[:, t], xn[:, t])
        h = h * dA[..., None, None] + dBx
        ys[:, t] = np.einsum("bn,bhnp->bhp", Cn[:, t], h)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), h, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    S=st.sampled_from([32, 48, 64, 96]),
    H=st.integers(1, 3),
    P=st.sampled_from([8, 16]),
    N=st.sampled_from([4, 8]),
    chunk=st.sampled_from([16, 32]),
)
def test_ssd_scan_property(S, H, P, N, chunk):
    x, dt, A, Bm, Cm = _ssd_inputs(1, S, H, P, N, seed=S + H + P + N)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr = ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-4, atol=3e-4)
