import os
import sys

# Tests must see the real single CPU device (the dry-run's 512 placeholder
# devices are set ONLY inside repro.launch.dryrun).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401  (the real library, when installed)
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback as _hf
    _hyp = type(sys)("hypothesis")
    _hyp.given = _hf.given
    _hyp.settings = _hf.settings
    _hyp.strategies = _hf.strategies
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _hf.strategies

try:
    import jax
    HAS_JAX = True
except ImportError:                                # the jax-absent CI job
    jax = None
    HAS_JAX = False

import numpy as np
import pytest

# Modules whose imports need jax (models, configs with jnp dtypes, the
# profiler/launch/serving layers).  Without jax they are skipped at
# COLLECTION, so the rest of the suite — the pure-numpy analysis layer
# and its lazy-import seam — runs and must pass with jax uninstalled.
# A jax-free test file gaining a top-level jax dependency shows up in
# the jax-absent CI job as a collection error, which is the point.
_NEEDS_JAX = [
    "test_checkpoint_trainer.py",
    "test_commdep.py",
    "test_configs.py",
    "test_data_optim.py",
    "test_elastic.py",
    "test_hlo_shardings.py",
    "test_kernels.py",
    "test_launch.py",
    "test_models_smoke.py",
    "test_profiler_sim.py",
    "test_psg.py",
    "test_serving.py",
]
if not HAS_JAX:
    collect_ignore = list(_NEEDS_JAX)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


_BUNDLE_CACHE = {}


def smoke_bundle(arch: str):
    """Cached (cfg, model, params) at smoke scale (jax tests only —
    imports resolve lazily so this module loads without jax)."""
    from repro.configs import get_smoke
    from repro.models.api import build_model
    if arch not in _BUNDLE_CACHE:
        cfg = get_smoke(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _BUNDLE_CACHE[arch] = (cfg, model, params)
    return _BUNDLE_CACHE[arch]


def smoke_batch(cfg, batch=2, seq=32, train=True):
    import jax.numpy as jnp
    toks = (jnp.arange(batch * (seq + (1 if train else 0)), dtype=jnp.int32)
            .reshape(batch, -1) * 7919) % cfg.vocab_size
    out = {"tokens": toks}
    if cfg.family == "encdec":
        out["frames"] = jnp.ones((batch, cfg.frontend_len, cfg.d_model),
                                 cfg.cdtype()) * 0.02
    if cfg.family == "vlm":
        out["patches"] = jnp.ones((batch, cfg.frontend_len, cfg.d_model),
                                  cfg.cdtype()) * 0.02
    return out
