"""Backtracking root-cause detection (Algorithm 1): the paper's core."""
import pytest

from repro.core import (COMM, COMP, PSG, backtrack, build_ppg,
                        detect_abnormal, detect_non_scalable, root_causes)
from repro.core.backtrack import WAIT_COUNTER, backtrack_one
from repro.core.graph import PerfVector
from repro.core.inject import simulate, simulate_series


def _pipeline_psg():
    """comp0 -> comp1 -> p2p(0->1,2->3,...) -> comp2 -> allreduce."""
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    c0 = g.new_vertex(COMP, "load", parent=root.vid, source="app.py:10")
    c1 = g.new_vertex(COMP, "halo", parent=root.vid, source="app.py:20")
    p2p = g.new_vertex(COMM, "ppermute", parent=root.vid, source="app.py:30")
    p2p.comm_kind = "ppermute"
    p2p.comm_bytes = 1e5
    p2p.p2p_pairs = [(i, (i + 1) % 8) for i in range(8)]
    c2 = g.new_vertex(COMP, "solve", parent=root.vid, source="app.py:40")
    ar = g.new_vertex(COMM, "psum", parent=root.vid, source="app.py:50")
    ar.comm_kind, ar.comm_bytes = "all_reduce", 1e6
    for v in (c0, c1, p2p, c2, ar):
        g.add_edge(root.vid, v.vid, "control")
    g.add_edge(c0.vid, c1.vid, "data")
    g.add_edge(c1.vid, p2p.vid, "data")
    g.add_edge(p2p.vid, c2.vid, "data")
    g.add_edge(c2.vid, ar.vid, "data")
    return g, (c0.vid, c1.vid, p2p.vid, c2.vid, ar.vid)


def test_straggler_propagates_and_backtracks_to_root_cause():
    """The paper's NPB-CG experiment in miniature: a delay injected into one
    process propagates through p2p dependence and surfaces at the
    all-reduce; Algorithm 1 walks it back to the injected computation."""
    g, (c0, c1, p2p, c2, ar) = _pipeline_psg()
    res = simulate(g, 8, lambda p, vid: 0.01,
                   inject={(4, c0): 0.5})       # straggler: proc 4 at 'load'
    ab = detect_abnormal(res.ppg, abnorm_thd=1.3)
    assert ab, "propagated delay must create abnormal vertices"
    paths = backtrack(res.ppg, [], ab)
    assert paths
    rcs = root_causes(paths, g, ppg=res.ppg)
    assert any(node == (4, c0) for node, _, _ in rcs), \
        f"root cause must be (proc 4, load); got {rcs}"


def test_backtrack_prunes_nonwaiting_p2p():
    """p2p edges without waiting events are pruned (search-space opt)."""
    g, (c0, c1, p2p, c2, ar) = _pipeline_psg()
    perf = {p: {} for p in range(4)}
    for p in range(4):
        for vid in (c0, c1, c2):
            perf[p][vid] = PerfVector(time=0.01)
        # p2p with NO waiting
        perf[p][p2p] = PerfVector(time=0.001,
                                  counters={WAIT_COUNTER: 0.0})
        perf[p][ar] = PerfVector(time=0.001)
    ppg = build_ppg(g, 4, perf)
    path = backtrack_one(ppg, (0, c2), reason="abnormal", scanned=set())
    # must walk straight through data deps within proc 0, never jumping
    procs = {n[0] for n in path.nodes}
    assert procs == {0}


def test_backtrack_follows_waiting_p2p():
    g, (c0, c1, p2p, c2, ar) = _pipeline_psg()
    perf = {p: {} for p in range(4)}
    for p in range(4):
        for vid in (c0, c1, c2):
            perf[p][vid] = PerfVector(time=0.3 if (p, vid) == (1, c1)
                                      else 0.01)
        perf[p][p2p] = PerfVector(
            time=0.3 if p == 2 else 0.001,
            counters={WAIT_COUNTER: 0.29 if p == 2 else 0.0})
        perf[p][ar] = PerfVector(time=0.001)
    ppg = build_ppg(g, 4, perf)
    # proc 2 waited on the p2p; its cause is proc 1 (pairs 1->2)
    path = backtrack_one(ppg, (2, c2), reason="abnormal", scanned=set())
    procs = {n[0] for n in path.nodes}
    assert 1 in procs, f"walk must cross to proc 1: {path.nodes}"


def test_backtrack_terminates_and_covers_all_abnormal():
    g, ids = _pipeline_psg()
    res = simulate(g, 8, lambda p, vid: 0.01,
                   inject={(2, ids[0]): 0.3, (6, ids[3]): 0.2})
    ab = detect_abnormal(res.ppg)
    paths = backtrack(res.ppg, [], ab)
    # Algorithm 1 main loop: every abnormal vertex scanned or started from
    scanned = set()
    for p in paths:
        scanned.update(p.nodes)
    for a in ab:
        assert (a.proc, a.vid) in scanned
    for p in paths:
        assert len(p.nodes) <= 256            # termination bound


def test_non_scalable_plus_backtrack_end_to_end():
    g, (c0, c1, p2p, c2, ar) = _pipeline_psg()

    def time_at(p, vid, n):
        if vid == c1:
            return 0.1 * (0.7 + 0.3 / n) + (0.2 if p == 1 else 0.0)
        if g.vertices[vid].kind == COMM:
            return 0.0
        return 0.1 / n

    series = simulate_series(g, [4, 8, 16], time_at)
    ns = detect_non_scalable(series, min_share=0.01)
    assert ns
    ab = detect_abnormal(series[16])
    paths = backtrack(series[16], ns, ab)
    assert paths
    rcs = root_causes(paths, g, ppg=series[16])
    assert rcs
