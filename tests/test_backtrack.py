"""Backtracking root-cause detection (Algorithm 1): the paper's core."""
import numpy as np
import pytest

from repro.core import (COMM, COMP, PSG, backtrack, build_ppg,
                        detect_abnormal, detect_non_scalable, root_causes)
from repro.core.backtrack import (WAIT_COUNTER, backtrack_batched,
                                  backtrack_one, backtrack_scalar)
from repro.core.graph import PerfVector
from repro.core.inject import simulate, simulate_series


def _pipeline_psg():
    """comp0 -> comp1 -> p2p(0->1,2->3,...) -> comp2 -> allreduce."""
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    c0 = g.new_vertex(COMP, "load", parent=root.vid, source="app.py:10")
    c1 = g.new_vertex(COMP, "halo", parent=root.vid, source="app.py:20")
    p2p = g.new_vertex(COMM, "ppermute", parent=root.vid, source="app.py:30")
    p2p.comm_kind = "ppermute"
    p2p.comm_bytes = 1e5
    p2p.p2p_pairs = [(i, (i + 1) % 8) for i in range(8)]
    c2 = g.new_vertex(COMP, "solve", parent=root.vid, source="app.py:40")
    ar = g.new_vertex(COMM, "psum", parent=root.vid, source="app.py:50")
    ar.comm_kind, ar.comm_bytes = "all_reduce", 1e6
    for v in (c0, c1, p2p, c2, ar):
        g.add_edge(root.vid, v.vid, "control")
    g.add_edge(c0.vid, c1.vid, "data")
    g.add_edge(c1.vid, p2p.vid, "data")
    g.add_edge(p2p.vid, c2.vid, "data")
    g.add_edge(c2.vid, ar.vid, "data")
    return g, (c0.vid, c1.vid, p2p.vid, c2.vid, ar.vid)


def test_straggler_propagates_and_backtracks_to_root_cause():
    """The paper's NPB-CG experiment in miniature: a delay injected into one
    process propagates through p2p dependence and surfaces at the
    all-reduce; Algorithm 1 walks it back to the injected computation."""
    g, (c0, c1, p2p, c2, ar) = _pipeline_psg()
    res = simulate(g, 8, lambda p, vid: 0.01,
                   inject={(4, c0): 0.5})       # straggler: proc 4 at 'load'
    ab = detect_abnormal(res.ppg, abnorm_thd=1.3)
    assert ab, "propagated delay must create abnormal vertices"
    paths = backtrack(res.ppg, [], ab)
    assert paths
    rcs = root_causes(paths, g, ppg=res.ppg)
    assert any(node == (4, c0) for node, _, _ in rcs), \
        f"root cause must be (proc 4, load); got {rcs}"


def test_backtrack_prunes_nonwaiting_p2p():
    """p2p edges without waiting events are pruned (search-space opt)."""
    g, (c0, c1, p2p, c2, ar) = _pipeline_psg()
    perf = {p: {} for p in range(4)}
    for p in range(4):
        for vid in (c0, c1, c2):
            perf[p][vid] = PerfVector(time=0.01)
        # p2p with NO waiting
        perf[p][p2p] = PerfVector(time=0.001,
                                  counters={WAIT_COUNTER: 0.0})
        perf[p][ar] = PerfVector(time=0.001)
    ppg = build_ppg(g, 4, perf)
    path = backtrack_one(ppg, (0, c2), reason="abnormal", scanned=set())
    # must walk straight through data deps within proc 0, never jumping
    procs = {n[0] for n in path.nodes}
    assert procs == {0}


def test_backtrack_follows_waiting_p2p():
    g, (c0, c1, p2p, c2, ar) = _pipeline_psg()
    perf = {p: {} for p in range(4)}
    for p in range(4):
        for vid in (c0, c1, c2):
            perf[p][vid] = PerfVector(time=0.3 if (p, vid) == (1, c1)
                                      else 0.01)
        perf[p][p2p] = PerfVector(
            time=0.3 if p == 2 else 0.001,
            counters={WAIT_COUNTER: 0.29 if p == 2 else 0.0})
        perf[p][ar] = PerfVector(time=0.001)
    ppg = build_ppg(g, 4, perf)
    # proc 2 waited on the p2p; its cause is proc 1 (pairs 1->2)
    path = backtrack_one(ppg, (2, c2), reason="abnormal", scanned=set())
    procs = {n[0] for n in path.nodes}
    assert 1 in procs, f"walk must cross to proc 1: {path.nodes}"


def test_backtrack_terminates_and_covers_all_abnormal():
    g, ids = _pipeline_psg()
    res = simulate(g, 8, lambda p, vid: 0.01,
                   inject={(2, ids[0]): 0.3, (6, ids[3]): 0.2})
    ab = detect_abnormal(res.ppg)
    paths = backtrack(res.ppg, [], ab)
    # Algorithm 1 main loop: every abnormal vertex scanned or started from
    scanned = set()
    for p in paths:
        scanned.update(p.nodes)
    for a in ab:
        assert (a.proc, a.vid) in scanned
    for p in paths:
        assert len(p.nodes) <= 256            # termination bound


def _paths_key(paths):
    return [(p.nodes, p.start_reason) for p in paths]


def _random_psg(rng, n_procs):
    """Random PSG mixing comp chains, p2p rings, global and grouped
    collectives, loops and diamond data edges."""
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    prev = None
    for i in range(int(rng.integers(4, 12))):
        r = rng.random()
        if r < 0.35:
            v = g.new_vertex(COMP, f"c{i}", parent=root.vid)
        elif r < 0.5 and prev is not None:
            lp = g.new_vertex("Loop", f"loop{i}", parent=root.vid)
            g.add_edge(root.vid, lp.vid, "control")
            b0 = g.new_vertex(COMP, f"b{i}a", parent=lp.vid)
            b1 = g.new_vertex(COMP, f"b{i}b", parent=lp.vid)
            g.add_edge(b0.vid, b1.vid, "data")
            g.add_edge(prev, lp.vid, "data")
            prev = lp.vid
            continue
        elif r < 0.75:
            v = g.new_vertex(COMM, f"pp{i}", parent=root.vid)
            v.comm_kind, v.comm_bytes = "ppermute", 1e5
            off = int(rng.integers(1, max(n_procs, 2)))
            v.p2p_pairs = [(p, (p + off) % n_procs) for p in range(n_procs)]
        else:
            v = g.new_vertex(COMM, f"ar{i}", parent=root.vid)
            v.comm_kind, v.comm_bytes = "all_reduce", 1e6
            gs = int(rng.choice([2, 4, n_procs]))
            if gs < n_procs:
                v.meta["replica_groups"] = [
                    list(range(a, min(a + gs, n_procs)))
                    for a in range(0, n_procs, gs)]
        g.add_edge(root.vid, v.vid, "control")
        if prev is not None:
            g.add_edge(prev, v.vid, "data")
        if prev is not None and v.vid >= 3 and rng.random() < 0.3:
            g.add_edge(max(1, v.vid - 2), v.vid, "data")   # diamond
        prev = v.vid
    return g


def test_batched_equals_scalar_on_random_ppgs():
    """The frontier-batched walk returns EXACTLY the scalar reference's
    paths — overlapping starts, ties (jitter-free waits), grouped and
    global collectives, p2p chains, loops and diamonds included."""
    rng = np.random.default_rng(42)
    for trial in range(40):
        n_procs = int(rng.integers(4, 28))
        g = _random_psg(rng, n_procs)
        inj = {}
        for _ in range(int(rng.integers(1, 7))):
            inj[(int(rng.integers(0, n_procs)),
                 int(rng.integers(1, len(g.vertices))))] = \
                float(rng.uniform(0.05, 0.5))
        # every other trial jitter-free: exact ties stress the stable
        # first-min/first-max ordering
        res = simulate(g, n_procs, lambda p, vid: 0.01, inject=inj,
                       jitter=0.1 if trial % 2 else 0.0, seed=trial)
        ab = detect_abnormal(res.ppg, top_k=500)
        series = simulate_series(g, [max(n_procs // 2, 2), n_procs],
                                 lambda p, vid, n: 0.02 * (0.5 + 0.5 / n),
                                 seed=trial)
        ns = detect_non_scalable(series, min_share=0.0, top_k=20)
        assert _paths_key(backtrack_batched(res.ppg, ns, ab)) == \
            _paths_key(backtrack_scalar(res.ppg, ns, ab)), trial


def test_batched_equals_scalar_overlapping_straggler_block():
    """Many starts flagged at the SAME vertices: the acceptance pass must
    reproduce the sequential scanned-set pruning exactly."""
    rng = np.random.default_rng(7)
    for trial in range(10):
        n_procs = 12
        g = _random_psg(rng, n_procs)
        vid = int(rng.integers(1, len(g.vertices)))
        inj = {(p, vid): 0.3 for p in range(0, n_procs, 2)}
        res = simulate(g, n_procs, lambda p, vid_: 0.01, inject=inj,
                       seed=trial)
        ab = detect_abnormal(res.ppg, top_k=500)
        assert _paths_key(backtrack_batched(res.ppg, [], ab)) == \
            _paths_key(backtrack_scalar(res.ppg, [], ab)), trial


def test_backtrack_export_survives_submodule_import():
    # a direct `import repro.core.backtrack` (as repro.scenarios.bank does)
    # must not shadow the package-level function export with the submodule
    import importlib
    import sys

    importlib.import_module("repro.core.backtrack")
    from repro.core import backtrack as fn
    assert callable(fn) and fn is sys.modules["repro.core.backtrack"].backtrack


def test_backtrack_mode_dispatch():
    g, (c0, c1, p2p, c2, ar) = _pipeline_psg()
    res = simulate(g, 8, lambda p, vid: 0.01, inject={(4, c0): 0.5})
    ab = detect_abnormal(res.ppg)
    keys = {mode: _paths_key(backtrack(res.ppg, [], ab, mode=mode))
            for mode in ("auto", "batched", "scalar")}
    assert keys["auto"] == keys["batched"] == keys["scalar"]
    with pytest.raises(ValueError):
        backtrack(res.ppg, [], ab, mode="nope")


def test_non_scalable_plus_backtrack_end_to_end():
    g, (c0, c1, p2p, c2, ar) = _pipeline_psg()

    def time_at(p, vid, n):
        if vid == c1:
            return 0.1 * (0.7 + 0.3 / n) + (0.2 if p == 1 else 0.0)
        if g.vertices[vid].kind == COMM:
            return 0.0
        return 0.1 / n

    series = simulate_series(g, [4, 8, 16], time_at)
    ns = detect_non_scalable(series, min_share=0.01)
    assert ns
    ab = detect_abnormal(series[16])
    paths = backtrack(series[16], ns, ab)
    assert paths
    rcs = root_causes(paths, g, ppg=series[16])
    assert rcs


# ---------------------------------------------------------------------------
# backtrack_one: non-copying scanned-union view (regression)
# ---------------------------------------------------------------------------

def _backtrack_one_copying(ppg, start, *, reason, scanned, max_len=256):
    """The pre-fix reference walk: rebuilds ``scanned | set(path)`` on
    every step.  Retained here to pin the union-view rewrite to the old
    semantics exactly."""
    from repro.core.backtrack import (Path, WAIT_EPS, _comm_partner,
                                      _control_end, _data_pred,
                                      _is_collective, _is_p2p,
                                      _latest_participant, _wait_of)
    from repro.core.graph import BRANCH, CALL, LOOP
    psg = ppg.psg
    path = []
    v = start
    first = True
    while v is not None and len(path) < max_len:
        proc, vid = v
        vert = psg.vertices[vid]
        if vert.kind == "Root":
            break
        if _is_collective(psg, vid) and not first:
            path.append(v)
            break
        path.append(v)
        nxt = None
        visited = scanned | set(path)            # the quadratic copy
        if _is_collective(psg, vid):
            late = _latest_participant(ppg, v)
            if late is not None and late not in visited:
                nxt = _data_pred(ppg, late, visited) or late
            else:
                nxt = _data_pred(ppg, v, visited)
        elif _is_p2p(psg, vid):
            if _wait_of(ppg, v) > WAIT_EPS:
                nxt = _comm_partner(ppg, v, visited)
            if nxt is None:
                nxt = _data_pred(ppg, v, visited)
        elif vert.kind in (LOOP, BRANCH, CALL) and v not in scanned:
            nxt = _control_end(ppg, v, visited) or _data_pred(ppg, v,
                                                              visited)
        else:
            nxt = _data_pred(ppg, v, visited)
        first = False
        v = nxt
    scanned.update(path)
    return Path(nodes=path, start_reason=reason)


def test_backtrack_one_union_view_matches_copying_reference():
    """The union-view walk must equal the old per-step-copy walk node for
    node — including evolving shared scanned sets across many starts on
    conflict-heavy random PPGs."""
    rng = np.random.default_rng(11)
    for trial in range(20):
        n_procs = int(rng.integers(4, 20))
        g = _random_psg(rng, n_procs)
        vid = int(rng.integers(1, len(g.vertices)))
        inj = {(p, vid): 0.2 + 0.01 * p for p in range(0, n_procs, 2)}
        for _ in range(int(rng.integers(0, 5))):
            inj[(int(rng.integers(0, n_procs)),
                 int(rng.integers(1, len(g.vertices))))] = \
                float(rng.uniform(0.05, 0.5))
        res = simulate(g, n_procs, lambda p, v: 0.01, inject=inj,
                       seed=trial)
        ab = detect_abnormal(res.ppg, top_k=500)
        scanned_new, scanned_ref = set(), set()
        for a in ab:
            got = backtrack_one(res.ppg, (a.proc, a.vid),
                                reason="abnormal", scanned=scanned_new)
            ref = _backtrack_one_copying(res.ppg, (a.proc, a.vid),
                                         reason="abnormal",
                                         scanned=scanned_ref)
            assert got.nodes == ref.nodes, trial
        assert scanned_new == scanned_ref
