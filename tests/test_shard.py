"""Sharded perf store + streamed multi-host assembly.

Pins the tentpole refactor to the old single-controller semantics:

* ``PerfStore.from_shards`` / ``assemble_streamed`` must be bit-identical
  to writing the same entries into one store through ``set_entries``
  directly — including uneven shard proc-ranges, disjoint counter sets,
  per-row counter signatures and overlapping shards;
* ``ShardedStore``-backed replay (``simulate(..., shards=...)``) must be
  bit-identical to the unsharded replay, and its stacked read views must
  equal the merged store's matrices;
* the cross-scale stacked collective leg must be bit-identical to the
  retained per-lane reference;
* ``build_ppg`` must accept shard iterables (streamed, one at a time).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (COMM, COMP, PSG, PerfShard, PerfStore, ShardedStore,
                        build_ppg, detect_abnormal, shard_ranges)
from repro.core.graph import PerfVector
from repro.core.inject import (_collective, _collective_stacked, _make_lane,
                               simulate, simulate_series)

COUNTER_SETS = [(), ("wait_s",), ("flops", "bytes"), ("wait_s", "comm_bytes"),
                ("flops",)]


# ---------------------------------------------------------------------------
# shard-merge == direct set_entries assembly
# ---------------------------------------------------------------------------

@st.composite
def entry_plan(draw):
    """Random (n_procs, ranges, entries): entries are (proc, vid,
    counter-set-index) triples with deterministic values derived below."""
    n_procs = draw(st.integers(3, 24))
    n_hosts = draw(st.integers(1, 5))
    uneven = draw(st.booleans())
    if uneven:
        # uneven ranges: random cut points
        cuts = sorted({draw(st.integers(1, n_procs - 1))
                       for _ in range(n_hosts - 1)} | {0, n_procs})
        ranges = list(zip(cuts, cuts[1:]))
    else:
        ranges = shard_ranges(n_procs, n_hosts)
    n_entries = draw(st.integers(0, 40))
    entries = [(draw(st.integers(0, n_procs - 1)), draw(st.integers(0, 9)),
                draw(st.integers(0, len(COUNTER_SETS) - 1)))
               for _ in range(n_entries)]
    return n_procs, ranges, entries


def _value(p, vid, i):
    return 0.25 + 0.125 * p + 17.0 * vid + 0.0625 * i


def _apply(store, entries, off=0):
    """Write (global_index, (proc, vid, counter-set)) entries through
    set_entries, one call per entry (the reference single-store assembly;
    proc indices shifted by -off).  Values derive from the GLOBAL entry
    index so shard-local and direct writes agree."""
    for i, (p, vid, ci) in entries:
        names = COUNTER_SETS[ci]
        store.set_entries(
            np.asarray([p - off]), vid, _value(p, vid, i),
            time_var=0.5 * _value(p, vid, i), samples=1 + (i % 3),
            counters={nm: _value(p, vid, i) + 100.0 * j
                      for j, nm in enumerate(names)})


def _stores_equal(a, b, V=12):
    assert np.array_equal(a.time_matrix(V), b.time_matrix(V))
    assert np.array_equal(a.var_matrix(V), b.var_matrix(V))
    names = set(a.counter_names()) | set(b.counter_names())
    for nm in names:
        assert np.array_equal(a.counter_matrix(nm, V),
                              b.counter_matrix(nm, V)), nm
    keys_a = sorted((p, v) for p, v in a.keys())
    keys_b = sorted((p, v) for p, v in b.keys())
    assert keys_a == keys_b
    for key in keys_a:
        assert a[key] == b[key], key


@given(entry_plan())
@settings(max_examples=40, deadline=None)
def test_from_shards_equals_direct_assembly(plan):
    n_procs, ranges, entries = plan
    entries = list(enumerate(entries))
    direct = PerfStore(n_procs)
    _apply(direct, entries)
    shards = []
    for lo, hi in ranges:
        sh = PerfShard(lo, hi - lo)
        _apply(sh, [(i, e) for i, e in entries if lo <= e[0] < hi], off=lo)
        shards.append(sh)
    merged = PerfStore.from_shards(shards, n_procs=n_procs)
    _stores_equal(merged, direct)
    # streamed (iterator) form: one shard at a time, same result
    streamed = PerfStore.assemble_streamed(iter(shards))
    _stores_equal(streamed, direct)


def test_from_shards_disjoint_counter_sets_and_uneven_ranges():
    """Hosts that measured entirely different counters still merge into
    one column-sparse store equal to direct assembly."""
    direct = PerfStore(7)
    a = PerfShard(0, 2)      # [0, 2): wait_s only
    b = PerfShard(2, 5)      # [2, 7): flops only, different vertices
    for p in (0, 1):
        direct.set_entries([p], 3, 1.0 + p, counters={"wait_s": 0.5 * p})
        a.set_entries([p], 3, 1.0 + p, counters={"wait_s": 0.5 * p})
    for p in (2, 4, 6):
        direct.set_entries([p], 5, 2.0 + p, counters={"flops": 1e9 * p})
        b.set_entries([p - 2], 5, 2.0 + p, counters={"flops": 1e9 * p})
    merged = PerfStore.from_shards([a, b])
    assert merged.n_procs == 7
    _stores_equal(merged, direct)
    assert sorted(merged.counter_names()) == ["flops", "wait_s"]


def test_from_shards_overlap_last_writer_wins():
    """Overlapping ranges behave like repeated set_entries calls: the
    later shard overwrites."""
    a = PerfShard(0, 4)
    b = PerfShard(2, 4)
    a.set_entries(np.arange(4), 1, 1.0)
    b.set_entries(np.arange(4), 1, 2.0)
    merged = PerfStore.from_shards([a, b])
    assert merged.n_procs == 6
    np.testing.assert_array_equal(merged.time_column(1),
                                  [1.0, 1.0, 2.0, 2.0, 2.0, 2.0])


def test_shard_ranges_tile():
    assert shard_ranges(10, 3) == [(0, 4), (4, 7), (7, 10)]
    assert shard_ranges(8, 8) == [(i, i + 1) for i in range(8)]
    assert shard_ranges(4, 16) == [(i, i + 1) for i in range(4)]
    with pytest.raises(ValueError):
        shard_ranges(8, 0)


def test_shard_ranges_zero_procs_rejected():
    """Regression: shard_ranges(0, h) used to return [(0, 0)], which
    ShardedStore then rejected with a confusing contiguity error — the
    two now agree: sharding zero processes is an explicit ValueError at
    both layers, and in ``simulate(..., shards=)``."""
    with pytest.raises(ValueError, match="0 processes"):
        shard_ranges(0, 4)
    with pytest.raises(ValueError):
        ShardedStore([(0, 0)])
    with pytest.raises(ValueError):
        simulate(_pipeline_psg(4), 0, lambda p, vid: 0.01, shards=2)


def test_build_ppg_empty_shard_iterable():
    """No hosts reported yet: streamed assembly of an empty iterable is a
    valid (empty) n_procs-row store, and detection runs clean on it."""
    g = _pipeline_psg(4)
    ppg = build_ppg(g, 4, iter([]))
    assert isinstance(ppg.perf, PerfStore)
    assert ppg.perf.n_procs == 4 and len(ppg.perf) == 0
    assert ppg.times_matrix().shape == (4, len(g.vertices))
    assert detect_abnormal(ppg, backend="numpy") == []


# ---------------------------------------------------------------------------
# contiguous-block merge fast path == grouped reference
# ---------------------------------------------------------------------------

def _grouped_merge(shards, n_procs):
    """Reference assembly through the retained per-(vertex, signature)
    path only (the pre-fast-path behavior)."""
    store = PerfStore(n_procs)
    for sh in shards:
        store.ensure_rows(sh.proc_start + sh.n_procs)
        store.ensure_columns(sh._cols)
        store._merge_shard_grouped(sh, sh.proc_start)
    return store


@given(entry_plan())
@settings(max_examples=40, deadline=None)
def test_merge_block_fast_path_equals_grouped(plan):
    """Fresh-target merges take the whole-block fast path; it must be
    bit-identical to the grouped set_entries reference on uneven ranges,
    disjoint counter sets and per-row signatures."""
    n_procs, ranges, entries = plan
    entries = list(enumerate(entries))
    shards = []
    for lo, hi in ranges:
        sh = PerfShard(lo, hi - lo)
        _apply(sh, [(i, e) for i, e in entries if lo <= e[0] < hi], off=lo)
        shards.append(sh)
    fast = PerfStore.from_shards(shards, n_procs=n_procs)
    slow = _grouped_merge(shards, n_procs)
    _stores_equal(fast, slow)
    np.testing.assert_array_equal(fast._mask, slow._mask[:, :fast._cols])
    assert sorted(fast.dirty_rows()) == sorted(slow.dirty_rows())


def test_merge_block_fast_path_disjoint_counters_uneven_ranges():
    a = PerfShard(0, 3)      # wait_s only, vids {1, 5}
    b = PerfShard(3, 2)      # flops only, vid 2; row signatures differ
    a.set_entries([0, 2], 1, 1.5, counters={"wait_s": [0.1, 0.2]})
    a.set_entries([1], 5, 2.5, counters={"wait_s": 0.3})
    b.set_entries([0], 2, 3.5, counters={"flops": 1e9})
    b.set_entries([1], 2, 4.5)                   # same vid, no counter
    fast = PerfStore.from_shards([a, b])
    slow = _grouped_merge([a, b], 5)
    _stores_equal(fast, slow, V=6)
    # overlap forces the grouped fallback and stays last-writer-wins
    c = PerfShard(2, 2)
    c.set_entries([0, 1], 1, 9.0)
    fast.merge_shard(c)
    slow.merge_shard(c)
    _stores_equal(fast, slow, V=6)
    np.testing.assert_array_equal(fast.time_column(1),
                                  [1.5, 0.0, 9.0, 9.0, 0.0])


# ---------------------------------------------------------------------------
# sharded build_ppg (device-resident detection threading)
# ---------------------------------------------------------------------------

def test_build_ppg_sharded_keeps_blocks():
    """``sharded=True`` adopts per-host shards AS the ShardedStore blocks
    (no merge), producing the same detection as the merged store."""
    g = _pipeline_psg(6)
    res = simulate(g, 6, lambda p, vid: 0.01, inject={(2, 1): 0.4},
                   shards=3)
    ppg = build_ppg(g, 6, list(res.shards), sharded=True)
    assert isinstance(ppg.perf, ShardedStore)
    assert ppg.perf.shards[0] is res.shards[0]   # adopted, not copied
    merged = build_ppg(g, 6, iter(res.shards))
    assert np.array_equal(ppg.times_matrix(), merged.times_matrix())
    assert [(x.proc, x.vid) for x in detect_abnormal(ppg, backend="numpy")] \
        == [(x.proc, x.vid) for x in detect_abnormal(merged,
                                                     backend="numpy")]
    # hosts may report out of order: blocks are sorted by range
    shuffled = build_ppg(g, 6, [res.shards[2], res.shards[0],
                                res.shards[1]], sharded=True)
    assert np.array_equal(shuffled.times_matrix(), merged.times_matrix())
    with pytest.raises(ValueError):              # ranges must tile n_procs
        build_ppg(g, 8, list(res.shards), sharded=True)
    with pytest.raises(ValueError):              # gap in the tiling
        build_ppg(g, 6, [PerfShard(0, 2), PerfShard(4, 2)], sharded=True)
    with pytest.raises(ValueError):              # not a shard iterable
        build_ppg(g, 6, {0: {1: PerfVector(time=0.1)}}, sharded=True)
    with pytest.raises(ValueError):              # already-merged store
        build_ppg(g, 6, PerfStore(6), sharded=True)
    with pytest.raises(ValueError):              # no perf data at all
        build_ppg(g, 6, None, sharded=True)
    with pytest.raises(ValueError):              # ready store, wrong size
        build_ppg(g, 8, ppg.perf, sharded=True)
    with pytest.raises(ValueError):              # same check, sharded=False
        build_ppg(g, 8, ppg.perf)


# ---------------------------------------------------------------------------
# ShardedStore: routed writes + stacked views == plain store
# ---------------------------------------------------------------------------

@given(entry_plan(), st.booleans())
@settings(max_examples=25, deadline=None)
def test_sharded_store_routes_like_plain_store(plan, accumulate):
    n_procs, ranges, entries = plan
    plain = PerfStore(n_procs)
    sharded = ShardedStore(ranges)
    for i, (p, vid, ci) in enumerate(entries):
        names = COUNTER_SETS[ci]
        kw = dict(time_var=0.25 * i, samples=1 + (i % 2),
                  counters={nm: _value(p, vid, i) for nm in names},
                  accumulate=accumulate)
        plain.set_entries([p], vid, _value(p, vid, i), **kw)
        sharded.set_entries([p], vid, _value(p, vid, i), **kw)
    _stores_equal(sharded, plain)
    _stores_equal(sharded.merge(), plain)
    # stacked counter_columns view == plain columns at the shared vids
    for nm in plain.counter_names():
        vp, valp, mp = plain.counter_columns(nm)
        vs, vals, ms = sharded.counter_columns(nm)
        order_p, order_s = np.argsort(vp), np.argsort(vs)
        assert np.array_equal(vp[order_p], vs[order_s])
        assert np.array_equal(valp[:, order_p] * mp[:, order_p],
                              vals[:, order_s] * ms[:, order_s])
        assert np.array_equal(mp[:, order_p], ms[:, order_s])


def test_sharded_store_requires_contiguous_ranges():
    with pytest.raises(ValueError):
        ShardedStore([(0, 2), (3, 5)])
    with pytest.raises(ValueError):
        ShardedStore([])
    with pytest.raises(ValueError):
        ShardedStore([(0, 2), (2, 2)])


def test_simulate_rejects_partial_shard_ranges():
    """Explicit ranges must tile [0, n_procs) — a partial tiling would
    silently drop processes from the perf store."""
    g = _pipeline_psg(8)
    for bad in ([(0, 4)], [(0, 4), (4, 16)], []):
        with pytest.raises(ValueError):
            simulate(g, 8, lambda p, vid: 0.01, shards=bad)
    ok = simulate(g, 8, lambda p, vid: 0.01, shards=[(0, 5), (5, 8)])
    assert [s.n_procs for s in ok.shards] == [5, 3]


# ---------------------------------------------------------------------------
# multi-host replay == single-host replay
# ---------------------------------------------------------------------------

def _pipeline_psg(n_procs):
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    c0 = g.new_vertex(COMP, "load", parent=root.vid, source="app.py:10")
    p2p = g.new_vertex(COMM, "ppermute", parent=root.vid, source="app.py:30")
    p2p.comm_kind, p2p.comm_bytes = "ppermute", 1e5
    p2p.p2p_pairs = [(i, (i + 1) % n_procs) for i in range(n_procs)]
    c2 = g.new_vertex(COMP, "solve", parent=root.vid, source="app.py:40")
    ar = g.new_vertex(COMM, "psum", parent=root.vid, source="app.py:50")
    ar.comm_kind, ar.comm_bytes = "all_reduce", 1e6
    half = n_procs // 2 or 1
    ar.meta["replica_groups"] = [list(range(half)),
                                 list(range(half, n_procs))]
    for v in (c0, p2p, c2, ar):
        g.add_edge(root.vid, v.vid, "control")
    g.add_edge(c0.vid, p2p.vid, "data")
    g.add_edge(p2p.vid, c2.vid, "data")
    g.add_edge(c2.vid, ar.vid, "data")
    return g


@given(st.integers(4, 24), st.integers(1, 6), st.booleans())
@settings(max_examples=20, deadline=None)
def test_sharded_simulate_bit_identical(n_procs, n_hosts, jitter):
    g = _pipeline_psg(n_procs)
    kw = dict(inject={(1, 1): 0.4}, jitter=0.05 if jitter else 0.0, seed=3)
    ref = simulate(g, n_procs, lambda p, vid: 0.01, **kw)
    res = simulate(g, n_procs, lambda p, vid: 0.01, shards=n_hosts, **kw)
    assert res.shards is not None
    assert len(res.shards) == min(n_hosts, n_procs)
    assert ref.clocks == res.clocks
    V = len(g.vertices)
    assert np.array_equal(ref.ppg.times_matrix(), res.ppg.times_matrix())
    assert np.array_equal(ref.ppg.var_matrix(), res.ppg.var_matrix())
    for nm in ("wait_s", "comm_bytes", "flops", "bytes"):
        assert np.array_equal(ref.ppg.counter_matrix(nm),
                              res.ppg.counter_matrix(nm)), nm
    # the sharded PPG drives detection identically (stacked shard views)
    ab_ref = detect_abnormal(ref.ppg, backend="numpy")
    ab_sh = detect_abnormal(res.ppg, backend="numpy")
    assert [(a.proc, a.vid, a.time) for a in ab_ref] == \
           [(a.proc, a.vid, a.time) for a in ab_sh]
    # merged blocks == the unsharded store
    _stores_equal(PerfStore.from_shards(res.shards), ref.ppg.perf, V)


def test_build_ppg_accepts_shard_iterable():
    """Per-host shards stream into build_ppg one at a time."""
    g = _pipeline_psg(6)
    res = simulate(g, 6, lambda p, vid: 0.01, shards=3)
    ppg = build_ppg(g, 6, iter(res.shards))
    assert isinstance(ppg.perf, PerfStore)
    assert np.array_equal(ppg.times_matrix(), res.ppg.times_matrix())
    assert np.array_equal(ppg.counter_matrix("wait_s"),
                          res.ppg.counter_matrix("wait_s"))


def test_sharded_ppg_mapping_api_and_report():
    """Mapping reads + render_report work on a sharded store."""
    from repro.core import backtrack, render_report
    g = _pipeline_psg(8)
    res = simulate(g, 8, lambda p, vid: 0.01, inject={(4, 1): 0.5}, shards=4)
    ab = detect_abnormal(res.ppg)
    paths = backtrack(res.ppg, [], ab)
    text = render_report(res.ppg, [], ab, paths)
    assert "Root causes" in text
    vec = res.ppg.perf.get((4, 1))
    assert vec is not None and vec.time > 0.4
    assert (4, 1) in res.ppg.perf


# ---------------------------------------------------------------------------
# cross-scale stacked collective == per-lane reference
# ---------------------------------------------------------------------------

@given(st.integers(2, 5), st.integers(2, 16), st.booleans())
@settings(max_examples=25, deadline=None)
def test_collective_stacked_equals_per_lane(S, n_max, grouped):
    """One cross-scale masked max == the retained per-scale reference,
    bitwise, for global and grouped collectives at uneven scales."""
    rng = np.random.default_rng(S * 100 + n_max)
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    v = g.new_vertex(COMM, "psum", parent=root.vid)
    v.comm_kind, v.comm_bytes = "all_reduce", 1e6
    if grouped:
        half = n_max // 2 or 1
        v.meta["replica_groups"] = [list(range(half)),
                                    list(range(half, n_max))]
    ns = sorted(int(x) for x in rng.integers(2, n_max + 1, size=S))
    P_max = max(ns)
    clocks_a = rng.uniform(0.0, 1.0, (S, P_max))
    clocks_b = clocks_a.copy()
    lanes_a = [_make_lane(g, n, lambda p, vid: 0.0, 0, None, clocks_a[i])
               for i, n in enumerate(ns)]
    lanes_b = [_make_lane(g, n, lambda p, vid: 0.0, 0, None, clocks_b[i])
               for i, n in enumerate(ns)]
    from repro.core.inject import default_comm_time
    _collective_stacked(lanes_a, clocks_a, v, v.vid, default_comm_time)
    for lane in lanes_b:
        _collective(lane, v, v.vid, default_comm_time)
    assert np.array_equal(clocks_a, clocks_b)
    for la, lb in zip(lanes_a, lanes_b):
        assert np.array_equal(la.store.time_matrix(2), lb.store.time_matrix(2))
        vids_a, val_a, m_a = la.store.counter_columns("wait_s")
        vids_b, val_b, m_b = lb.store.counter_columns("wait_s")
        assert np.array_equal(vids_a, vids_b)
        assert np.array_equal(val_a, val_b)
        assert np.array_equal(m_a, m_b)


def test_series_with_grouped_collectives_matches_per_scale():
    """End-to-end: the one-pass stacked series (stacked collective legs
    included) stays bit-identical to independent per-scale simulates."""
    g = _pipeline_psg(16)
    series = simulate_series(g, [4, 8, 16],
                             lambda p, vid, n: 0.01 * (1 + p % 3))
    for n in (4, 8, 16):
        one = simulate(g, n, lambda p, vid: 0.01 * (1 + p % 3), seed=n)
        assert np.array_equal(series[n].times_matrix(),
                              one.ppg.times_matrix())
        assert np.array_equal(series[n].counter_matrix("wait_s"),
                              one.ppg.counter_matrix("wait_s"))


# ---------------------------------------------------------------------------
# profiler shard emission (jax-dependent, kept minimal)
# ---------------------------------------------------------------------------

def test_profiler_perf_shard_roundtrip():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.core import GraphProfiler

    def step(x):
        return jnp.tanh(x @ x).sum()

    prof = GraphProfiler(step, (np.ones((4, 4), np.float32),),
                         sample_every=1)
    prof.step(np.ones((4, 4), np.float32))
    vecs = prof.perf_vectors()
    assert vecs
    # host 1 of 2, covering procs [3, 6)
    shard = prof.perf_shard(proc_start=3, n_procs=3)
    assert shard.proc_start == 3 and shard.n_procs == 3
    merged = PerfStore.from_shards([PerfShard(0, 3), shard])
    assert merged.n_procs == 6
    for vid, vec in vecs.items():
        assert merged[(4, vid)].time == vec.time
        assert merged[(4, vid)].counters == vec.counters
    assert (0, next(iter(vecs))) not in merged
