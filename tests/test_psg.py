"""PSG construction + contraction: unit and property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (COMM, COMP, LOOP, PSG, build_psg, contract)
from repro.core.graph import Vertex
from repro.core.psg import top_level_order


def _example_fn(x, w):
    def body(c, _):
        c = jnp.tanh(c @ w)
        return c, None
    c, _ = jax.lax.scan(body, x, None, length=4)
    z = jnp.where(jnp.sum(c) > 0, jnp.sum(c * c), jnp.sum(c))
    return z


def test_build_psg_kinds_and_structure():
    x = jnp.ones((4, 8))
    w = jnp.ones((8, 8))
    psg = build_psg(_example_fn, x, w)
    stats = psg.stats()
    assert stats["Loop"] >= 1            # the scan
    assert stats["Comp"] >= 2
    assert stats["total"] == len(psg.vertices)
    # loop body vertices are children of the Loop vertex
    loop = psg.by_kind(LOOP)[0]
    kids = psg.children(loop.vid)
    assert kids, "loop must have children"
    # flops rolled up: loop flops = trips x body flops
    body_flops = sum(psg.vertices[k].flops for k in kids)
    assert loop.flops == pytest.approx(4 * body_flops)


def test_psg_source_attribution():
    x, w = jnp.ones((4, 8)), jnp.ones((8, 8))
    psg = build_psg(_example_fn, x, w)
    srcs = [v.source for v in psg.vertices if v.source]
    assert any("test_psg.py" in s for s in srcs)


def test_psg_json_roundtrip():
    x, w = jnp.ones((4, 8)), jnp.ones((8, 8))
    psg = build_psg(_example_fn, x, w)
    clone = PSG.from_json(psg.to_json())
    assert clone.stats() == psg.stats()
    assert clone.edges == psg.edges
    assert [v.kind for v in clone.vertices] == [v.kind for v in psg.vertices]


def test_contraction_reduces_and_preserves():
    x, w = jnp.ones((4, 8)), jnp.ones((8, 8))
    psg = build_psg(_example_fn, x, w)
    cpsg, mapping = contract(psg, max_loop_depth=10)
    assert len(cpsg.vertices) <= len(psg.vertices)
    # every original vertex maps somewhere
    assert set(mapping) >= {v.vid for v in psg.vertices}
    # total flops conserved at the top level
    orig = sum(v.flops for v in psg.vertices if v.parent == psg.root)
    got = sum(v.flops for v in cpsg.vertices if v.parent == cpsg.root)
    assert got == pytest.approx(orig)


def test_contraction_depth_pruning():
    def nested(x):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2) * 1.5, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        c, _ = jax.lax.scan(outer, x, None, length=2)
        return jnp.sum(c)

    psg = build_psg(nested, jnp.ones((4,)))
    deep, _ = contract(psg, max_loop_depth=10)
    shallow, _ = contract(psg, max_loop_depth=1)
    assert shallow.stats()["Loop"] < deep.stats()["Loop"]
    # pruning folds, not drops: flops conserved
    f_deep = sum(v.flops for v in deep.vertices if v.parent == deep.root)
    f_shallow = sum(v.flops for v in shallow.vertices
                    if v.parent == shallow.root)
    assert f_shallow == pytest.approx(f_deep)


# ---------------------------------------------------------------------------
# property: contraction invariants on random synthetic PSGs
# ---------------------------------------------------------------------------

@st.composite
def random_psg(draw):
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    frontier = [root.vid]
    n = draw(st.integers(5, 40))
    for i in range(n):
        parent = draw(st.sampled_from(frontier))
        kind = draw(st.sampled_from([COMP, COMP, COMP, LOOP, COMM]))
        depth = g.vertices[parent].depth + (1 if parent != root.vid else 0)
        v = g.new_vertex(kind, kind.lower(), parent=parent, depth=depth)
        if kind == COMP:
            v.flops = float(draw(st.integers(0, 1000)))
        if kind == COMM:
            v.comm_bytes = float(draw(st.integers(1, 10_000)))
            v.comm_kind = "all_reduce"
        if kind == LOOP:
            frontier.append(v.vid)
    # chain data edges among siblings
    for parent in {v.parent for v in g.vertices if v.parent >= 0}:
        kids = g.children(parent)
        for a, b in zip(kids, kids[1:]):
            g.add_edge(a, b, "data")
        for k in kids:
            g.add_edge(parent, k, "control")

    # roll up Loop counters (mirrors build_psg._rollup with trip=1)
    def rollup(vid):
        v = g.vertices[vid]
        kids = g.children(vid)
        for k in kids:
            rollup(k)
        if v.kind == LOOP:
            v.flops = sum(g.vertices[k].flops for k in kids)
            v.comm_bytes = sum(g.vertices[k].comm_bytes for k in kids)
    for k in g.children(root.vid):
        rollup(k)
    return g


@settings(max_examples=30, deadline=None)
@given(psg=random_psg(), depth=st.integers(0, 4))
def test_contract_properties(psg, depth):
    cpsg, mapping = contract(psg, max_loop_depth=depth)
    # 1. all Comm vertices preserved verbatim
    assert len(cpsg.by_kind(COMM)) == len(psg.by_kind(COMM))
    assert (sum(v.comm_bytes for v in cpsg.by_kind(COMM))
            == pytest.approx(sum(v.comm_bytes for v in psg.by_kind(COMM))))
    # 2. never grows
    assert len(cpsg.vertices) <= len(psg.vertices)
    # 3. mapping total
    assert set(mapping) >= {v.vid for v in psg.vertices}
    # 4. top-level flops conserved
    def subtree_flops(g, vid):
        v = g.vertices[vid]
        kids = g.children(vid)
        if v.kind == LOOP and kids:
            return v.flops                  # already rolled up
        if kids:
            return v.flops + sum(subtree_flops(g, k) for k in kids)
        return v.flops
    orig = sum(subtree_flops(psg, k) for k in psg.children(psg.root))
    got = sum(subtree_flops(cpsg, k) for k in cpsg.children(cpsg.root))
    assert got == pytest.approx(orig, rel=1e-6)
