"""Communication-dependence capture: HLO annotation + graph-guided
compression (the paper's PMPI-interception and §III-B2 mechanisms)."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import COMM, PSG, build_psg, build_ppg
from repro.core.commdep import CommLog, annotate_from_hlo
from repro.core.graph import LOOP


HLO_SAMPLE = """
  %all-gather = f32[32,32]{0,1} all-gather(%p), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}, metadata={op_name="jit(step)/while/body/dot_general"}
  %all-reduce = f32[64]{0} all-reduce(%q), channel_id=2, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add, metadata={op_name="jit(step)/transpose/dot_general"}
  %collective-permute = bf16[8]{0} collective-permute(%r), source_target_pairs={{0,1},{1,0}}, metadata={op_name="jit(step)/while/body/split"}
"""


def _loop_psg():
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    loop = g.new_vertex(LOOP, "while", parent=root.vid, depth=0)
    g.add_edge(root.vid, loop.vid, "control")
    return g, loop.vid


def test_annotate_from_hlo_attaches_comm_vertices():
    g, loop_vid = _loop_psg()
    new = annotate_from_hlo(g, HLO_SAMPLE)
    assert len(new) == 3
    kinds = [g.vertices[v].comm_kind for v in new]
    assert kinds == ["all-gather", "all-reduce", "collective-permute"]
    # scope matching: 'while'-scoped ops land under the Loop vertex
    assert g.vertices[new[0]].parent == loop_vid
    assert g.vertices[new[2]].parent == loop_vid
    assert g.vertices[new[0]].comm_bytes == 32 * 32 * 4
    assert g.vertices[new[2]].p2p_pairs == [(0, 1), (1, 0)]
    assert g.vertices[new[0]].meta["replica_groups"] == [[0, 1, 2, 3],
                                                         [4, 5, 6, 7]]


def test_annotated_psg_builds_ppg_with_group_edges():
    g, _ = _loop_psg()
    new = annotate_from_hlo(g, HLO_SAMPLE)
    ppg = build_ppg(g, 8)
    ar = new[1]
    # all-reduce with two replica groups of 4: edges stay within groups
    partners = ppg.comm_partners(0, ar)
    assert set(p for p, _ in partners) == {1, 2, 3}
    # p2p edges follow source_target_pairs
    cp = new[2]
    assert ((0, cp), (1, cp)) in ppg.comm_edges


def test_commlog_compression():
    log = CommLog()
    for step in range(100):              # same signature every iteration
        log.record(vertex=7, kind="all_reduce", nbytes=1024,
                   group=range(8))
    assert log.events_seen == 100
    assert len(log.records) == 1
    assert log.records[(7, "all_reduce", 1024, tuple(range(8)))].count == 100
    assert log.compression_ratio() > 50


def test_commlog_distinct_signatures_kept():
    log = CommLog()
    for nb in (64, 128, 256):
        log.record(1, "all_gather", nb, [0, 1])
    assert len(log.records) == 3


@settings(max_examples=20, deadline=None)
@given(prob=st.floats(0.05, 1.0), n_sig=st.integers(1, 30))
def test_commlog_sampling_bounded(prob, n_sig):
    """Sampled instrumentation: retained records <= signatures seen, and
    repeats of a retained signature are always counted."""
    log = CommLog(sample_prob=prob, seed=42)
    for rep in range(3):
        for s in range(n_sig):
            log.record(s, "all_reduce", 64 * (s + 1), [0, 1, 2])
    assert len(log.records) <= n_sig
    assert log.events_seen == 3 * n_sig
    for r in log.records.values():
        # repeats after admission always fold into the record
        assert 1 <= r.count <= 3
    if prob == 1.0:
        assert all(r.count == 3 for r in log.records.values())


def test_annotate_from_real_compiled_hlo():
    """End-to-end: PSG from jaxpr + Comm vertices from the compiled HLO of
    the same function under a (1,1) mesh (no collectives expected) and a
    text with synthetic ones (above) — exercises the full refinement path
    the dry-run uses."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=3)
        return jnp.sum(c)

    x, w = jnp.ones((8, 16)), jnp.ones((16, 16))
    psg = build_psg(f, x, w)
    compiled = jax.jit(f).lower(x, w).compile()
    before = len(psg.vertices)
    annotate_from_hlo(psg, compiled.as_text())   # 1-device: no collectives
    assert len(psg.vertices) == before
