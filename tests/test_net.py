"""Real-socket transport: loopback end-to-end, reconnect, chaos proxy.

The acceptance contract (ISSUE 8): producers streaming over REAL TCP —
through a fault-injecting proxy tearing frames, resetting connections
and spraying garbage, optionally with the seeded ``FaultyTransport``
stacked on top — leave the monitor's converged store bit-identical to
the producers' shards and its rendered report bit-identical to the
fault-free one-shot run.  Timing logic runs on the injectable clock, so
nothing here is ``time.sleep``-calibrated except bounded convergence
deadlines.
"""
import random
import socket
import time

import numpy as np
import pytest

from repro.core.inject import simulate
from repro.core.shard import ShardedStore, shard_ranges
from repro.monitor import (FaultyTransport, Heartbeat, ManualClock, Monitor,
                           ProducerLink, ShardProducer, SocketChaosProxy,
                           SocketServer, SocketTransport, Transport,
                           TransportError, build_chaos_psg, encode_message,
                           socket_chaos_run, stores_equal)

DEADLINE = 20.0     # hard cap on any convergence wait (loopback is ~ms)


def _fleet(n_procs=8, n_hosts=2, n_comp=6, seed=0):
    psg = build_chaos_psg(n_comp)
    V = len(psg.vertices)
    ranges = shard_ranges(n_procs, n_hosts)

    def base(p, vid):
        v = psg.vertices[vid]
        return 0.0 if v.kind == "Comm" else 1.0 + 0.01 * vid

    sim = simulate(psg, n_procs, base, inject={(1, 2): 4.0},
                   comm_time=lambda *a: 0.05, jitter=0.0, seed=seed,
                   shards=ranges)
    return psg, V, ranges, sim.ppg


def _converge(monitor, producers, links, server, *, extra=lambda: None,
              ack=True):
    """Drive flush/tick/poll until every stream is applied.  ``ack=False``
    models an aggregator that dies before durably committing anything —
    producers must keep their unacked buffers."""
    deadline = time.monotonic() + DEADLINE
    hosts = list(producers)
    while not all(monitor.high[h] >= producers[h].seq
                  and not monitor.parked[h] for h in hosts):
        assert time.monotonic() < deadline, \
            (monitor.high, {h: p.seq for h, p in producers.items()},
             server.stats())
        extra()
        for link in links:
            link.tick()
        monitor.poll()
        if ack:
            server.send_acks({h: monitor.acked_seq(h) for h in hosts})
        time.sleep(0.002)
    monitor.poll()
    if ack:
        server.send_acks({h: monitor.acked_seq(h) for h in hosts})


# ---------------------------------------------------------------------------
# knob validation (satellite: clear ValueErrors naming the argument)
# ---------------------------------------------------------------------------

def test_socket_transport_knob_validation():
    with pytest.raises(ValueError, match="address port"):
        SocketTransport(("127.0.0.1", 0))       # 0 is not connectable
    with pytest.raises(ValueError, match="address port"):
        SocketTransport(("127.0.0.1", 99999))
    with pytest.raises(ValueError, match="backoff_max.*backoff_base"):
        SocketTransport(("127.0.0.1", 1234), backoff_base=1.0,
                        backoff_max=0.5)
    with pytest.raises(ValueError, match="jitter"):
        SocketTransport(("127.0.0.1", 1234), jitter=1.5)
    with pytest.raises(ValueError, match="connect_attempts"):
        SocketTransport(("127.0.0.1", 1234), connect_attempts=0)


def test_monitor_and_producer_knob_validation():
    psg, V, ranges, _ = _fleet()
    import repro.monitor.transport as tmod
    q = tmod.QueueTransport()
    with pytest.raises(ValueError, match="detect_every"):
        Monitor(psg, ranges, q, detect_every=0)
    with pytest.raises(ValueError, match="snapshot_every"):
        Monitor(psg, ranges, q, snapshot_every=-3)
    with pytest.raises(ValueError, match="stale_after"):
        Monitor(psg, ranges, q, stale_after=0.0)
    with pytest.raises(ValueError, match="drift_threshold"):
        Monitor(psg, ranges, q, drift_threshold=2.0)
    with pytest.raises(ValueError, match="backend"):
        Monitor(psg, ranges, q, backend="cuda")
    store = ShardedStore(ranges, V)
    with pytest.raises(ValueError, match="max_backoff.*base_backoff"):
        ShardProducer(0, store.shards[0], q, base_backoff=2.0,
                      max_backoff=1.0)
    with pytest.raises(ValueError, match="max_retries"):
        ShardProducer(0, store.shards[0], q, max_retries=-1)
    with pytest.raises(ValueError, match="host"):
        ShardProducer(-2, store.shards[0], q)


def test_chaos_proxy_knob_validation():
    with pytest.raises(ValueError, match="p_reset"):
        SocketChaosProxy(("127.0.0.1", 1234), p_reset=-0.1)
    with pytest.raises(ValueError, match="target port"):
        SocketChaosProxy(("127.0.0.1", 0))
    with pytest.raises(ValueError, match="garbage_max"):
        SocketChaosProxy(("127.0.0.1", 1234), garbage_max=0)


# ---------------------------------------------------------------------------
# reconnect backoff (satellite: deterministic on the clock seam)
# ---------------------------------------------------------------------------

def _dead_port():
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def test_reconnect_backoff_schedule_is_deterministic():
    """Against a dead port, the connect loop sleeps the exact jittered
    exponential schedule its seed dictates — asserted on a ManualClock,
    no real time involved."""
    clock = ManualClock()
    tr = SocketTransport(("127.0.0.1", _dead_port()), seed=42,
                         connect_attempts=4, backoff_base=0.01,
                         backoff_max=0.04, jitter=0.5, clock=clock,
                         connect_timeout=0.5)
    with pytest.raises(TransportError, match="cannot connect"):
        tr.send(Heartbeat(host=0, seq=0, time=0.0))
    rng = random.Random(42)
    want, delay = [], 0.01
    for _ in range(3):                        # sleeps between 4 attempts
        want.append(delay * (1.0 + 0.5 * rng.random()))
        delay = min(2 * delay, 0.04)
    assert clock.slept == pytest.approx(want)
    assert tr.stats["connect_failures"] == 4


# ---------------------------------------------------------------------------
# loopback end-to-end
# ---------------------------------------------------------------------------

def test_clean_loopback_store_bit_identical_and_acked():
    psg, V, ranges, truth = _fleet()
    with SocketServer() as srv:
        mon = Monitor(psg, ranges, srv, comm=truth.comm, detect_every=None,
                      backend="numpy")
        prod_store = ShardedStore(ranges, V)
        producers, links, transports = {}, [], []
        for h in range(2):
            tr = SocketTransport(srv.address, seed=h)
            transports.append(tr)
            p = ShardProducer(h, prod_store.shards[h], tr)
            producers[h] = p
            links.append(ProducerLink(p, tr, resend_after=0.05))
        for h in range(2):
            rows = np.arange(prod_store.shards[h].n_procs)
            prod_store.shards[h].apply_rows(
                truth.perf.shards[h].extract_rows(rows))
            producers[h].flush()
        _converge(mon, producers, links, srv)
        assert stores_equal(mon.store, prod_store, V)
        # acks flowed back over the same sockets and pruned the buffers
        deadline = time.monotonic() + DEADLINE
        while any(producers[h].acked < producers[h].seq for h in range(2)):
            assert time.monotonic() < deadline
            srv.send_acks({h: mon.acked_seq(h) for h in range(2)})
            for tr in transports:
                tr.recv()                      # pumps acks
            time.sleep(0.002)
        assert all(not producers[h].unacked for h in range(2))
        for tr in transports:
            tr.close()


def test_send_side_max_frame_surfaces_as_transport_error():
    """A frame the receiver would discard as oversize must fail loudly
    at send time, not be silently dropped and resent forever."""
    with SocketServer() as srv:
        tr = SocketTransport(srv.address, max_frame=8, seed=0)
        with pytest.raises(TransportError, match="max_frame"):
            tr.send(Heartbeat(host=0, seq=1, time=0.0))   # 20-byte payload
        tr.close()


def test_server_send_is_not_a_thing():
    with SocketServer() as srv:
        with pytest.raises(RuntimeError, match="receive side"):
            srv.send(Heartbeat(host=0, seq=0, time=0.0))


def test_server_resyncs_after_raw_garbage_bytes():
    """Bytes that never came from our client — the reader walks to the
    next magic and the following frame still lands."""
    with SocketServer() as srv:
        s = socket.create_connection(srv.address)
        try:
            hb = encode_message(Heartbeat(host=0, seq=1, time=2.0))
            s.sendall(b"\x01\xffnot a frame at all" + hb)
            deadline = time.monotonic() + DEADLINE
            while srv.pending() == 0:
                assert time.monotonic() < deadline, srv.stats()
                time.sleep(0.002)
            msgs = srv.recv()
            assert len(msgs) == 1 and isinstance(msgs[0], Heartbeat)
            assert msgs[0].seq == 1
            stats = srv.stats()
            assert stats["resyncs"] >= 1
            assert stats["skipped_bytes"] >= 20
        finally:
            s.close()


def test_server_restart_client_reconnects_and_resends_unacked():
    """Kill the server mid-stream; a fresh one on the same port gets the
    whole unacked buffer replayed on reconnect and converges."""
    psg, V, ranges, truth = _fleet()
    srv1 = SocketServer().start()
    addr = srv1.address
    mon1 = Monitor(psg, ranges, srv1, comm=truth.comm, detect_every=None)
    prod_store = ShardedStore(ranges, V)
    producers, links = {}, []
    transports = []
    for h in range(2):
        tr = SocketTransport(addr, seed=h, connect_attempts=20,
                             backoff_base=0.002, backoff_max=0.02,
                             connect_timeout=1.0, send_timeout=1.0)
        transports.append(tr)
        p = ShardProducer(h, prod_store.shards[h], tr, max_retries=10,
                          base_backoff=0.001, max_backoff=0.01)
        producers[h] = p
        links.append(ProducerLink(p, tr, resend_after=0.05))
    # round 1 reaches server 1 — NEVER acked (the aggregator will die
    # before durably committing), so it stays in the unacked buffers
    for h in range(2):
        rows = np.arange(prod_store.shards[h].n_procs)
        prod_store.shards[h].apply_rows(
            truth.perf.shards[h].extract_rows(rows))
        producers[h].flush(heartbeat=False)
    _converge(mon1, producers, links, srv1, ack=False)
    assert all(1 in producers[h].unacked for h in range(2))
    srv1.stop()

    srv2 = SocketServer(addr).start()          # same port, fresh monitor
    mon2 = Monitor(psg, ranges, srv2, comm=truth.comm, detect_every=None)
    # round 2: the dead sockets surface as TransportErrors, the clients
    # reconnect (jittered backoff), replay seq 1 and then deliver seq 2
    for h in range(2):
        prod_store.set_entry(ranges[h][0], 1, 7.25 + h)
        producers[h].flush(heartbeat=False)
    try:
        _converge(mon2, producers, links, srv2,
                  extra=lambda: [producers[h].flush(heartbeat=False)
                                 for h in range(2)])
        assert stores_equal(mon2.store, prod_store, V)
        assert any(tr.stats.get("reconnects", 0) >= 1 for tr in transports)
    finally:
        for tr in transports:
            tr.close()
        srv2.stop()


def test_producer_link_tick_resends_on_ack_stall():
    psg, V, ranges, truth = _fleet()
    clock = ManualClock()
    with SocketServer() as srv:
        mon = Monitor(psg, ranges, srv, comm=truth.comm, detect_every=None)
        prod_store = ShardedStore(ranges, V)
        tr = SocketTransport(srv.address, seed=0)
        p = ShardProducer(0, prod_store.shards[0], tr)
        link = ProducerLink(p, tr, resend_after=1.0, clock=clock)
        prod_store.set_entry(0, 1, 3.0)
        p.flush(heartbeat=False)
        assert link.tick() == 0                # not stalled yet
        clock.advance(1.5)                     # ack never came
        assert link.tick() == 1                # unacked delta resent
        deadline = time.monotonic() + DEADLINE
        while mon.duplicates == 0:             # dup absorbed by seq window
            assert time.monotonic() < deadline
            mon.poll()
            time.sleep(0.002)
        tr.close()


# ---------------------------------------------------------------------------
# FaultyTransport composed OVER SocketTransport (satellite)
# ---------------------------------------------------------------------------

def test_faulty_transport_over_socket_transport_converges():
    """Seeded in-process faults stacked on a real socket: drops and ack
    losses trigger producer retries (each retry a fresh socket send),
    delays release through recv — the store still converges exactly."""
    psg, V, ranges, truth = _fleet(n_procs=12, n_hosts=3)
    with SocketServer() as srv:
        mon = Monitor(psg, ranges, srv, comm=truth.comm, detect_every=None)
        prod_store = ShardedStore(ranges, V)
        producers, links, fts = {}, [], []
        for h in range(3):
            tr = SocketTransport(srv.address, seed=h)
            ft = FaultyTransport(tr, seed=100 + h, p_drop=0.3,
                                 p_ack_loss=0.2, p_dup=0.2, p_delay=0.25,
                                 max_delay=2)
            fts.append(ft)
            p = ShardProducer(h, prod_store.shards[h], ft, max_retries=20,
                              base_backoff=0.0005, max_backoff=0.005)
            producers[h] = p
            links.append(ProducerLink(p, tr, resend_after=0.05))
        rng = np.random.default_rng(0)
        for _ in range(4):                     # several flush rounds
            for h in range(3):
                lo, hi = ranges[h]
                for pr in range(lo, hi):
                    if rng.random() < 0.7:
                        prod_store.set_entry(
                            pr, int(rng.integers(1, V)),
                            float(rng.random() * 5),
                            counters={"PAPI_TOT_CYC":
                                      float(rng.integers(1, 99))})
                producers[h].flush(heartbeat=False)

        def release_held():
            for ft in fts:
                try:
                    ft.flush_held()
                    ft.recv()
                except TransportError:
                    pass

        _converge(mon, producers, links, srv, extra=release_held)
        assert stores_equal(mon.store, prod_store, V)
        total = {}
        for ft in fts:
            for k, v in ft.stats.items():
                total[k] = total.get(k, 0) + v
        assert total.get("dropped", 0) > 0     # the schedule really fired
        assert mon.duplicates > 0              # and the windows absorbed


# ---------------------------------------------------------------------------
# the proxy scenario end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_socket_chaos_converges_bit_identical(seed):
    r = socket_chaos_run(seed=seed)
    assert r.abnormal_match and r.paths_match, r.transport_stats
    assert r.store_match          # converged store == producers' shards
    assert r.report_match         # rendered text == fault-free render
    assert r.converged


def test_socket_chaos_with_heavy_faults_and_stacked_faulty():
    r = socket_chaos_run(seed=3, p_reset=0.25, p_tear=0.2, p_garbage=0.3,
                         p_stall=0.1, rounds=4,
                         faulty_wrap=dict(p_drop=0.2, p_ack_loss=0.15,
                                          p_dup=0.15, p_delay=0.2,
                                          max_delay=2))
    assert r.converged, r.transport_stats
    s = r.transport_stats
    fired = sum(s.get(k, 0) for k in ("resets", "torn", "garbage", "stalls"))
    assert fired > 0              # the proxy really misbehaved
    assert r.duplicates_absorbed > 0


def test_socket_chaos_garbage_only_recovers_on_the_live_connection():
    """Garbage-only faults (no resets, no tears): frames eaten by
    resyncs must come back via stalled-ack tick resends re-encoded on
    the SAME live connection — the livelock regression where re-encoded
    resends diffed against a base seq the decoder never received and
    were rejected on every retry (only a reset could rescue them)."""
    r = socket_chaos_run(seed=5, p_reset=0.0, p_tear=0.0, p_garbage=0.3,
                         p_stall=0.0, rounds=4)
    assert r.converged, r.transport_stats
    assert r.store_match and r.report_match
    assert r.transport_stats.get("garbage", 0) > 0


def test_socket_chaos_uncompressed_also_converges():
    r = socket_chaos_run(seed=1, compress=False)
    assert r.converged
