"""Serving engine: batching, determinism, slot isolation."""
import jax
import numpy as np
import pytest

from conftest import smoke_bundle
from repro.serving import Request, ServingEngine


def _engine(arch, slots=3, max_seq=48):
    cfg, model, params = smoke_bundle(arch)
    return ServingEngine(model, params, batch_slots=slots, max_seq=max_seq)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m",
                                  "zamba2-2.7b", "moonshot-v1-16b-a3b"])
def test_serves_all_requests(arch):
    eng = _engine(arch)
    reqs = [Request(uid=i, prompt=np.arange(1, 5 + i), max_new_tokens=4)
            for i in range(6)]
    results = eng.run(reqs)
    assert [r.uid for r in results] == list(range(6))
    assert all(len(r.tokens) == 4 for r in results)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m"])
def test_batching_invariance(arch):
    """Greedy output is identical whether a request runs alone or batched
    with arbitrary other traffic (slot isolation; SSM state hygiene)."""
    prompt = np.arange(1, 7)
    alone = _engine(arch, slots=1).run(
        [Request(uid=0, prompt=prompt, max_new_tokens=5)])[0].tokens

    eng = _engine(arch, slots=3)
    traffic = [Request(uid=i, prompt=np.arange(2, 9 + i), max_new_tokens=6,
                       temperature=0.9, seed=i) for i in range(1, 5)]
    mixed = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=5)]
                    + traffic)
    batched = [r for r in mixed if r.uid == 0][0].tokens
    assert batched == alone


def test_temperature_sampling_reproducible():
    eng1 = _engine("tinyllama-1.1b")
    eng2 = _engine("tinyllama-1.1b")
    req = lambda: Request(uid=9, prompt=np.arange(1, 6), max_new_tokens=6,
                          temperature=0.7, seed=123)
    t1 = eng1.run([req()])[0].tokens
    t2 = eng2.run([req()])[0].tokens
    assert t1 == t2


def test_slot_reuse_after_completion():
    eng = _engine("mamba2-130m", slots=2)
    results = eng.run([Request(uid=i, prompt=np.arange(1, 4),
                               max_new_tokens=3) for i in range(5)])
    assert len(results) == 5            # 5 requests through 2 slots
    # same greedy prompt => same tokens regardless of which slot served it
    assert len({tuple(r.tokens) for r in results}) == 1


def test_max_seq_respected():
    eng = _engine("tinyllama-1.1b", slots=1, max_seq=16)
    r = eng.run([Request(uid=0, prompt=np.arange(1, 30),
                         max_new_tokens=40)])[0]
    assert len(r.tokens) <= 40
    assert eng.slot_pos.max() <= 16
