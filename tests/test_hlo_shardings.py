"""HLO parsing (collectives, trip-count walker) + sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo import (CollectiveOp, collective_bytes_total,
                            parse_collectives, shape_bytes)
from repro.core.hlo_walk import analyze_hlo, _split_computations
from repro.distributed import axes as ax


# ---------------------------------------------------------------------------
# hlo text parsing
# ---------------------------------------------------------------------------

def test_shape_bytes():
    assert shape_bytes("f32[4,8]") == 128
    assert shape_bytes("bf16[10]{0}") == 20
    assert shape_bytes("(f32[2,2], s32[3])") == 28
    assert shape_bytes("pred[16]") == 16
    assert shape_bytes("f32[]") == 4


SAMPLE = """
  %all-gather = f32[32,32]{0,1} all-gather(%copy), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}, use_global_device_ids=true
  %all-reduce.1 = f32[128]{0} all-reduce(%x), channel_id=2, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %collective-permute.2 = bf16[64]{0} collective-permute(%y), source_target_pairs={{0,1},{1,2},{2,3}}
  %reduce-scatter.3 = f32[16]{0} reduce-scatter(%z), channel_id=4, replica_groups=[1,8]<=[8], dimensions={0}, to_apply=%add
"""


def test_parse_collectives_kinds_and_bytes():
    ops = parse_collectives(SAMPLE)
    kinds = [o.kind for o in ops]
    assert kinds == ["all-gather", "all-reduce", "collective-permute",
                     "reduce-scatter"]
    assert ops[0].bytes == 32 * 32 * 4
    assert ops[0].replica_groups == [[0, 1, 2, 3], [4, 5, 6, 7]]
    assert ops[1].replica_groups == [[0, 1, 2, 3], [4, 5, 6, 7]]
    assert ops[2].p2p_pairs == [(0, 1), (1, 2), (2, 3)]
    totals = collective_bytes_total(SAMPLE)
    assert totals["total"] == (32 * 32 * 4 + 128 * 4 + 64 * 2 + 16 * 4)


def test_iota_replica_groups_with_transpose():
    line = ("  %ar = f32[8]{0} all-reduce(%x), "
            "replica_groups=[4,2]<=[2,4]T(1,0), to_apply=%add")
    ops = parse_collectives(line)
    arr = np.arange(8).reshape(2, 4).transpose(1, 0).reshape(4, 2)
    assert ops[0].replica_groups == arr.tolist()


def test_analyze_hlo_trip_count_exact():
    """Walker multiplies while bodies by known_trip_count (vs raw XLA)."""
    L, D = 6, 16

    def f(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(h)

    ws = jnp.ones((L, D, D))
    x = jnp.ones((D, D))
    compiled = jax.jit(f).lower(ws, x).compile()
    cost = analyze_hlo(compiled.as_text())
    analytic = L * 2 * D * D * D
    assert cost.dot_flops == pytest.approx(analytic, rel=0.05)


def test_split_computations_finds_entry():
    compiled = jax.jit(lambda x: jnp.sum(x * x)).lower(
        jnp.ones((8,))).compile()
    comps = _split_computations(compiled.as_text())
    assert any(e for _, e in comps.values())


# ---------------------------------------------------------------------------
# logical sharding rules
# ---------------------------------------------------------------------------

def _mesh22():
    # AxisType only exists in newer jax; Auto is the default behavior anyway
    from repro.launch.mesh import _mesh
    return _mesh((1, 1), ("data", "model"))


def test_spec_for_divisibility_opt_out():
    mesh = _mesh22()
    rules = {"vocab": "model", "embed": "data"}
    # divisible: sharded;  mesh axes are size 1 so everything divides —
    # use resolve_axis contract directly
    assert ax.resolve_axis("vocab", 100, mesh, rules) == "model"
    # non-divisible opt-out needs axis >1: simulate via rule product check
    spec = ax.spec_for(("vocab", "embed"), (100, 64), mesh, rules)
    assert spec == jax.sharding.PartitionSpec("model", "data")


def test_spec_for_no_double_axis_use():
    mesh = _mesh22()
    rules = {"a": "model", "b": "model"}
    spec = ax.spec_for(("a", "b"), (8, 8), mesh, rules)
    # second dim must not reuse 'model'
    assert spec[0] == "model"
    assert len(spec) < 2 or spec[1] is None


def test_logical_constraint_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = ax.logical_constraint(x, "batch", "embed")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_rules_for_shape_long_context():
    from repro.launch.shardings import rules_for_shape
    from repro.configs import SHAPES
    r_short = rules_for_shape(SHAPES["decode_32k"])
    r_long = rules_for_shape(SHAPES["long_500k"])
    assert r_short["kv_seq"] is None
    assert r_long["kv_seq"] == ("pod", "data")


def test_shardings_from_axes_cache_tree():
    from repro.launch.shardings import shardings_from_axes
    from conftest import smoke_bundle
    cfg, model, _ = smoke_bundle("tinyllama-1.1b")
    mesh = _mesh22()
    import dataclasses
    from repro.configs.base import ShapeConfig
    shape = ShapeConfig("t", 16, 2, "decode")
    cache_abs = model.cache_specs(2, 16)
    axes_tree = model.input_logical_axes(shape)["cache"]
    sh = shardings_from_axes(axes_tree, cache_abs, mesh)
    flat_sh = jax.tree.leaves(sh)
    flat_abs = jax.tree.leaves(cache_abs)
    assert len(flat_sh) == len(flat_abs)
    for s in flat_sh:
        assert isinstance(s, jax.sharding.NamedSharding)
