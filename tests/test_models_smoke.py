"""Per-architecture smoke tests: one forward/train step on CPU with a
reduced same-family config — asserts output shapes + no NaNs (assignment
requirement), plus prefill/decode consistency for the serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke_batch, smoke_bundle
from repro.configs import ARCHS


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch):
    cfg, model, params = smoke_bundle(arch)
    batch = smoke_batch(cfg)
    loss, metrics = model.train_loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, float(loss))
    assert float(loss) > 0.0
    assert "loss" in metrics
    # one full optimizer step, gradients finite
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), arch
    assert any(float(jnp.abs(g).max()) > 0 for g in flat), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_decode(arch):
    """Prefill and decode agree on next-token logits.

    * dense/ssm/hybrid: prefill(prompt) == token-by-token decode from an
      empty cache (full-path equivalence).
    * encdec/vlm: the frontend context (cross-attn cache / patch prefix)
      only exists via prefill, so we check prefill(S-1) + one decode step
      == prefill(S) — continuation consistency.
    * moe: skipped — batched prefill and stepwise decode see different
      routing-group boundaries, so capacity drops legitimately differ;
      serving consistency for MoE is covered by the engine's batching-
      invariance test instead.
    """
    cfg, model, params = smoke_bundle(arch)
    if cfg.family == "moe":
        pytest.skip("MoE capacity drops differ across batching (see doc)")
    B, S = 2, 8
    batch = smoke_batch(cfg, batch=B, seq=S, train=False)
    logits_p, cache_p = model.prefill(params, batch, max_len=32)
    assert logits_p.shape[0] == B and logits_p.shape[-1] == cfg.vocab_size
    toks = batch["tokens"]

    if cfg.family in ("encdec", "vlm"):
        short = dict(batch)
        short["tokens"] = toks[:, :-1]
        _, cache = model.prefill(params, short, max_len=32)
        logits_d, _ = model.decode_step(params, cache, toks[:, -1:])
    else:
        cache = model.init_cache(B, 32)
        logits_d = None
        for i in range(S):
            logits_d, cache = model.decode_step(params, cache,
                                                toks[:, i:i + 1])
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1], np.float32),
        np.asarray(logits_d[:, -1], np.float32),
        rtol=2e-2, atol=2e-2, err_msg=arch)


@pytest.mark.parametrize("arch", ARCHS)
def test_batch_row_independence(arch):
    """Row 0's loss gradient path doesn't leak into row 1 (SPMD sanity)."""
    cfg, model, params = smoke_bundle(arch)
    b1 = smoke_batch(cfg, batch=2, seq=16)
    b2 = {k: v.copy() for k, v in b1.items()}
    b2["tokens"] = b2["tokens"].at[1].set((b2["tokens"][1] + 11)
                                          % cfg.vocab_size)
    # decode row 0 with different row-1 contents: logits row 0 unchanged
    l1, c1 = model.prefill(params, {k: v[:, :8] if k == "tokens" else v
                                    for k, v in b1.items()}, max_len=16)
    l2, c2 = model.prefill(params, {k: v[:, :8] if k == "tokens" else v
                                    for k, v in b2.items()}, max_len=16)
    np.testing.assert_allclose(np.asarray(l1[0], np.float32),
                               np.asarray(l2[0], np.float32),
                               rtol=1e-5, atol=1e-5, err_msg=arch)


def test_kernel_paths_match_xla_paths():
    for arch in ("tinyllama-1.1b", "mamba2-130m"):
        cfg, model, params = smoke_bundle(arch)
        from repro.models.api import build_model
        mk = build_model(cfg.replace(use_kernels=True))
        batch = smoke_batch(cfg)
        l0 = float(model.train_loss(params, batch)[0])
        l1 = float(mk.train_loss(params, batch)[0])
        assert abs(l0 - l1) < 1e-3, (arch, l0, l1)


def test_moe_sort_strategy_close_to_einsum():
    cfg, _, params = smoke_bundle("dbrx-132b")
    from repro.models.api import build_model
    me = build_model(cfg, moe_strategy="einsum")
    ms = build_model(cfg, moe_strategy="sort")
    batch = smoke_batch(cfg)
    le = float(me.train_loss(params, batch)[0])
    ls = float(ms.train_loss(params, batch)[0])
    assert abs(le - ls) < 5e-3, (le, ls)
