"""The ground-truth scenario bank and its scoring layer (jax-free).

Three layers under test:

* ``score_nodes`` — the precision / recall / path-hit-rate core, pinned
  at its edge conventions (empty report, empty truth, masked-out culprit
  sets, tie handling, the culprit-process path clause);
* ``score_result`` + ``proc_mask`` — degraded fleets shrink the culprit
  set to its live intersection (a diagnosis must not report dead procs);
* the bank itself — every committed scenario resolves, runs end-to-end
  from its fixed seed, REPRODUCES bit-identically (``ScenarioResult.key``
  across two runs), reports root causes of the declared vertex kinds,
  and nails precision/recall 1.0 at test scale.  The scale-dependent
  path-hit floors are asserted at bench scale by
  ``benchmarks/bench_casestudy.py`` / ``make scenario-smoke``.

Trace source-layer invariants ride along: scale-free group patterns
round-trip through classification and re-materialize correctly at any
target scale, and ``instantiate_psg`` never mutates the cached trace.
"""
import numpy as np
import pytest

from repro.core.graph import COMM
from repro.scenarios import (SCENARIOS, SMOKE_SCENARIOS, GroundTruth,
                             ProcSpec, Score, VertexSel, classify_groups,
                             get_scenario, instantiate_psg, run_and_score,
                             score_nodes, score_result)
from repro.scenarios.bank import _trace
from repro.scenarios.source import GroupPattern


# ---------------------------------------------------------------------------
# score_nodes edge conventions
# ---------------------------------------------------------------------------

def test_score_empty_report_claims_nothing():
    s = score_nodes([], truth_vids=[3], truth_procs=[1, 2])
    assert s.precision == 1.0          # nothing wrong was claimed
    assert s.recall == 0.0             # but the truth went unfound
    assert s.path_hit_rate == 0.0      # no paths reached it either


def test_score_empty_truth_is_vacuous():
    s = score_nodes([(0, 1), (2, 3)], truth_vids=[], truth_procs=[1])
    assert (s.precision, s.recall, s.path_hit_rate) == (1.0, 1.0, 1.0)


def test_score_all_flagged_correct():
    s = score_nodes([(1, 3), (2, 3)], truth_vids=[3], truth_procs=[1, 2],
                    paths=[[(1, 3)], [(2, 3)]])
    assert (s.precision, s.recall, s.path_hit_rate) == (1.0, 1.0, 1.0)


def test_score_mixed_report_and_vertex_proc_conjunction():
    # (5, 3): right vertex, wrong proc -> NOT correct when procs matter
    s = score_nodes([(1, 3), (5, 3), (1, 9)], truth_vids=[3],
                    truth_procs=[1, 2])
    assert s.precision == pytest.approx(1 / 3)
    assert s.recall == 1.0
    loose = score_nodes([(1, 3), (5, 3), (1, 9)], truth_vids=[3],
                        truth_procs=None)      # procs don't matter
    assert loose.precision == pytest.approx(2 / 3)


def test_score_recall_counts_vertices_not_reports():
    # two truth vertices, only one covered (twice) -> recall 0.5
    s = score_nodes([(1, 3), (2, 3)], truth_vids=[3, 7],
                    truth_procs=[1, 2])
    assert s.recall == 0.5
    assert s.precision == 1.0


def test_score_path_hits_vertex_or_culprit_process():
    truth = dict(truth_vids=[3], truth_procs=[7])
    vertex_hit = [[(0, 1), (5, 3)]]            # touches truth vid 3
    proc_hit = [[(7, 40), (7, 41)]]            # walks on culprit proc 7
    miss = [[(0, 1), (1, 2)]]
    s = score_nodes([], paths=vertex_hit + proc_hit + miss, **truth)
    assert s.path_hit_rate == pytest.approx(2 / 3)
    # without the proc clause, the culprit-proc walk no longer counts
    s2 = score_nodes([], truth_vids=[3], truth_procs=None,
                     paths=vertex_hit + proc_hit + miss)
    assert s2.path_hit_rate == pytest.approx(1 / 3)


def test_score_masked_out_culprits_are_vacuous():
    # the whole culprit set died: nothing left to find -> all 1.0
    s = score_nodes([(0, 5)], truth_vids=[3], truth_procs=[])
    assert (s.precision, s.recall, s.path_hit_rate) == (1.0, 1.0, 1.0)


def test_score_passes_floors():
    truth = GroundTruth(min_precision=0.8, min_recall=0.8, min_path_hit=0.5)
    assert Score(0.9, 1.0, 0.5, 1, 1).passes(truth)
    assert not Score(0.79, 1.0, 1.0, 1, 1).passes(truth)
    assert not Score(1.0, 0.5, 1.0, 1, 1).passes(truth)
    assert not Score(1.0, 1.0, 0.49, 1, 1).passes(truth)


# ---------------------------------------------------------------------------
# selection DSL determinism
# ---------------------------------------------------------------------------

def test_procspec_modes_resolve_deterministically():
    assert ProcSpec("all").resolve(8, 0).tolist() == list(range(8))
    assert ProcSpec("modrem", stride=4, rem=1).resolve(12, 0).tolist() \
        == [1, 5, 9]
    assert ProcSpec("single", frac=0.5).resolve(10, 0).tolist() == [5]
    a = ProcSpec("random", frac=0.25).resolve(64, seed=3)
    b = ProcSpec("random", frac=0.25).resolve(64, seed=3)
    np.testing.assert_array_equal(a, b)        # same seed, same set
    assert a.size == 16 and np.all(np.diff(a) > 0)
    assert not np.array_equal(a, ProcSpec("random", frac=0.25)
                              .resolve(64, seed=4))
    with pytest.raises(ValueError):
        ProcSpec("bogus").resolve(4, 0)


def test_vertexsel_rankings():
    trace = _trace("tinyllama_train")
    psg = instantiate_psg(trace, 8)
    by_time = VertexSel(rank_by="time").resolve(psg, trace.base)
    assert trace.base[by_time] == max(
        trace.base.get(v, 0.0) for v in psg.children(psg.root))
    first = VertexSel(rank_by="order", index=0).resolve(psg, trace.base)
    assert first == min(v for v in psg.children(psg.root)
                        if psg.vertices[v].kind in ("Comp", "Loop"))


# ---------------------------------------------------------------------------
# trace source layer
# ---------------------------------------------------------------------------

def test_group_patterns_rematerialize_at_scale():
    cons = classify_groups([[0, 1], [2, 3], [4, 5], [6, 7]], 8)
    assert (cons.layout, cons.size) == ("consecutive", 2)
    assert cons.groups_at(6) == [[0, 1], [2, 3], [4, 5]]
    strided = classify_groups([[0, 2, 4, 6], [1, 3, 5, 7]], 8)
    assert (strided.layout, strided.size) == ("strided", 2)
    assert strided.groups_at(8) == [[0, 2, 4, 6], [1, 3, 5, 7]]
    glob = classify_groups([[0, 1, 2, 3, 4, 5, 6, 7]], 8)
    assert glob.layout == "global"
    assert classify_groups([[0, 3], [1, 2]], 4).layout == "global"  # degrade
    ring = GroupPattern("ring")
    assert ring.pairs_at(4) == [(0, 1), (1, 2), (2, 3), (3, 0)]


def test_instantiate_psg_appends_comms_and_keeps_trace_pristine():
    trace = _trace("tinyllama_train")
    n_before = len(trace.psg.vertices)
    psg = instantiate_psg(trace, 32)
    assert len(trace.psg.vertices) == n_before         # cache untouched
    added = [v for v in psg.vertices[n_before:]]
    assert len(added) == len(trace.collectives)
    assert all(v.kind == COMM for v in added)
    # every appended comm depends on the compute anchor, and comms chain
    anchor_preds = [psg.preds(v.vid, "data") for v in added]
    assert all(p for p in anchor_preds)
    for prev, cur in zip(added, added[1:]):
        assert prev.vid in psg.preds(cur.vid, "data")
    # groups / pairs are at the TARGET scale
    for v in added:
        if v.p2p_pairs:
            assert len(v.p2p_pairs) == 32
        else:
            procs = sorted(p for g in v.meta["replica_groups"] for p in g)
            assert procs == list(range(32))


# ---------------------------------------------------------------------------
# the bank, end to end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_bank_scenario_diagnoses_at_test_scale(name):
    sc = get_scenario(name)
    result, score = run_and_score(sc, 64)
    assert result.truth_vids, "fault resolved no target"
    for vid in result.truth_vids:
        assert result.psg.vertices[vid].kind in sc.truth.expect_kinds
    # the headline diagnosis must be exact even at test scale; path-hit
    # floors are scale-dependent and asserted at bench scale instead
    assert score.precision == 1.0 and score.recall == 1.0, score.row()
    assert result.paths, "backtrack produced no symptom paths"


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_bank_scenario_reproduces_bit_identically(name):
    sc = get_scenario(name)
    assert sc.run(64).key() == sc.run(64).key()


def test_bank_smoke_subset_is_in_bank():
    assert set(SMOKE_SCENARIOS) <= set(SCENARIOS)
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("nope")


def test_degraded_fleet_masks_culprits_out_of_truth():
    sc = get_scenario("data_pipeline_stall")
    full = sc.run(64)
    culprits = np.asarray(full.truth_procs)
    assert culprits.size >= 2

    # half the culprits die: reports must avoid them, score vs live half
    mask = np.ones(64, bool)
    mask[culprits[: culprits.size // 2]] = False
    res, score = run_and_score(sc, 64, proc_mask=mask)
    assert all(mask[p] for (p, _), _, _ in res.reported)
    assert score.precision == 1.0 and score.recall == 1.0

    # the WHOLE culprit set dies: nothing left to find -> vacuous 1.0
    mask_all = np.ones(64, bool)
    mask_all[culprits] = False
    _, vac = run_and_score(sc, 64, proc_mask=mask_all)
    assert (vac.precision, vac.recall, vac.path_hit_rate) == (1.0, 1.0, 1.0)


def test_backend_seam_numpy_vs_jax_identical():
    jax = pytest.importorskip("jax")  # noqa: F841
    for name in SMOKE_SCENARIOS:
        sc = get_scenario(name)
        assert sc.run(64, backend="numpy").key() \
            == sc.run(64, backend="jax").key()


def test_score_result_intersects_truth_with_live_mask():
    sc = get_scenario("serving_batch_skew")
    res = sc.run(64)
    dead = int(np.asarray(res.truth_procs)[0])
    mask = np.ones(64, bool)
    mask[dead] = False
    s = score_result(res, proc_mask=mask)      # re-score same run, masked
    assert isinstance(s, Score)
    # reports on the dead proc no longer count as correct
    full = score_result(res)
    assert s.precision <= full.precision
