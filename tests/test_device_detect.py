"""Device-resident shard buffers feeding the jitted detectors (PR 5).

Pins the tentpole to the host-fed semantics:

* device-fed detection (per-host blocks pinned as device buffers,
  blockwise merge/median/top-k kernels) must pick exactly the same
  vertices as the host-fed jitted path and the numpy reference — f64
  results bitwise where the math is order-independent (max merge, median,
  winner sets), ~1e-12 for blockwise-reassociated sums, ~1e-4 under
  ``SCALANA_DETECT_F32``;
* the incremental upload must transfer exactly the rows written since
  the previous detect call, and the device buffers must equal the host
  blocks after every refresh — interleaved writes/detects included;
* a ShardedStore-backed PPG must run detection WITHOUT materializing the
  stacked host matrix (asserted by making the stacked views explode);
* regression: an all-dead final scale (``total_max <= 0``) yields share
  0 / no flags — never inf/nan (the unguarded-divide bug).

Everything jax-dependent skips cleanly when jax is absent.
"""
import numpy as np
import pytest

from repro.core import (COMM, COMP, PSG, DeviceShardView, PerfShard,
                        PerfStore, ShardedStore, build_ppg, detect_abnormal,
                        detect_non_scalable)
from repro.core.graph import PerfVector
from repro.core.inject import simulate


def _step_psg(n_procs, n_comp=6):
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    prev = None
    for i in range(n_comp):
        v = g.new_vertex(COMP, f"c{i}", parent=root.vid,
                         source=f"m.py:{i}")
        g.add_edge(root.vid, v.vid, "control")
        if prev is not None:
            g.add_edge(prev, v.vid, "data")
        prev = v.vid
    p2p = g.new_vertex(COMM, "ppermute", parent=root.vid, source="m.py:h")
    p2p.comm_kind, p2p.comm_bytes = "ppermute", 1e5
    p2p.p2p_pairs = [(p, (p + 1) % n_procs) for p in range(n_procs)]
    g.add_edge(prev, p2p.vid, "data")
    g.add_edge(root.vid, p2p.vid, "control")
    ar = g.new_vertex(COMM, "psum", parent=root.vid, source="m.py:ar")
    ar.comm_kind, ar.comm_bytes = "all_reduce", 1e6
    g.add_edge(p2p.vid, ar.vid, "data")
    g.add_edge(root.vid, ar.vid, "control")
    return g


def _base(p, vid):
    return 0.01 * (1 + p % 3) + 0.001 * vid


def _sim_pair(n_procs, n_hosts, inject=None, seed=0):
    """(plain, sharded) bit-identical replays of the same scenario."""
    g = _step_psg(n_procs)
    plain = simulate(g, n_procs, _base, inject=inject, seed=seed)
    sharded = simulate(g, n_procs, _base, inject=inject, seed=seed,
                       shards=n_hosts)
    return g, plain.ppg, sharded.ppg


def _ab_key(ab):
    return [(a.proc, a.vid, a.time, a.typical) for a in ab]


# ---------------------------------------------------------------------------
# device-fed == host-fed == numpy
# ---------------------------------------------------------------------------

def test_abnormal_device_equals_host_and_numpy():
    pytest.importorskip("jax")
    for n_procs, n_hosts, seed in [(12, 3, 0), (16, 4, 1), (9, 2, 2),
                                   (24, 5, 3)]:
        _, plain, sharded = _sim_pair(n_procs, n_hosts,
                                      inject={(4, 2): 0.5}, seed=seed)
        ab_np = detect_abnormal(plain, backend="numpy")
        ab_host = detect_abnormal(plain, backend="jax")
        ab_dev = detect_abnormal(sharded, backend="jax")
        # winners, times AND typical (device median) bitwise vs numpy
        assert _ab_key(ab_dev) == _ab_key(ab_np) == _ab_key(ab_host)


def test_non_scalable_device_equals_host_and_numpy():
    pytest.importorskip("jax")
    g = _step_psg(16)

    def t_at(p, vid, n):
        return 0.08 if vid == 3 else 0.4 / n       # vid 3 does not scale

    series_plain, series_sh = {}, {}
    for n in (4, 8, 16):
        series_plain[n] = simulate(g, n, lambda p, v, n=n: t_at(p, v, n)).ppg
        series_sh[n] = simulate(g, n, lambda p, v, n=n: t_at(p, v, n),
                                shards=min(4, n)).ppg
    for strategy in ("mean", "max", "p0", "var"):
        ns_np = detect_non_scalable(series_plain, backend="numpy",
                                    strategy=strategy)
        ns_host = detect_non_scalable(series_plain, backend="jax",
                                      strategy=strategy)
        ns_dev = detect_non_scalable(series_sh, backend="jax",
                                     strategy=strategy)
        assert [d.vid for d in ns_dev] == [d.vid for d in ns_np] \
            == [d.vid for d in ns_host], strategy
        assert ns_dev and ns_dev[0].vid == 3
        for a, b in zip(ns_host, ns_dev):
            # blockwise reassociation: sums agree to reduction-order
            # rounding; the "max" merge is order-independent, so its
            # merged times and slope land bitwise (share still divides by
            # the blockwise-summed total step time)
            tol = 0 if strategy == "max" else 1e-12
            assert abs(a.slope - b.slope) <= tol * max(abs(a.slope), 1)
            assert abs(a.share - b.share) <= 1e-12 * max(abs(a.share), 1)
            for scale in a.times:
                assert abs(a.times[scale] - b.times[scale]) <= \
                    tol * max(abs(a.times[scale]), 1)


def test_device_detection_f32_parity(monkeypatch):
    pytest.importorskip("jax")
    monkeypatch.setenv("SCALANA_DETECT_F32", "1")
    g = _step_psg(12)
    series_sh = {n: simulate(g, n, _base, shards=3).ppg for n in (6, 12)}
    series_plain = {n: simulate(g, n, _base).ppg for n in (6, 12)}
    ns_np = detect_non_scalable(series_plain, backend="numpy",
                                min_share=0.0)
    ns_dev = detect_non_scalable(series_sh, backend="jax", min_share=0.0)
    assert [d.vid for d in ns_dev] == [d.vid for d in ns_np]
    for a, b in zip(ns_np, ns_dev):
        assert np.isclose(a.slope, b.slope, rtol=1e-4, atol=1e-4)
        assert np.isclose(a.share, b.share, rtol=1e-4, atol=1e-4)
    # abnormal: unambiguous stragglers (uniform base, distinct injects) —
    # f32 rounding must not reorder clearly-separated winners
    g2 = _step_psg(12)
    inject = {(5, 1): 0.4, (2, 3): 0.2, (8, 2): 0.1}
    plain = simulate(g2, 12, lambda p, vid: 0.01, inject=inject).ppg
    sharded = simulate(g2, 12, lambda p, vid: 0.01, inject=inject,
                       shards=3).ppg
    ab_np = detect_abnormal(plain, backend="numpy")
    ab_dev = detect_abnormal(sharded, backend="jax")
    assert [(a.proc, a.vid) for a in ab_dev] == \
        [(a.proc, a.vid) for a in ab_np]
    for a, b in zip(ab_np, ab_dev):
        assert np.isclose(a.typical, b.typical, rtol=1e-4, atol=1e-6)


def test_device_path_never_stacks_host_matrix(monkeypatch):
    """The acceptance criterion, asserted directly: detection on a
    ShardedStore-backed PPG must not touch the stacked (P, V) host views.
    """
    pytest.importorskip("jax")
    g = _step_psg(12)
    sharded = simulate(g, 12, _base, inject={(3, 2): 0.5}, shards=3).ppg
    series_sh = {n: simulate(g, n, _base, shards=3).ppg for n in (6, 12)}

    def boom(*a, **k):                                 # pragma: no cover
        raise AssertionError("stacked host matrix materialized")

    monkeypatch.setattr(ShardedStore, "time_matrix", boom)
    monkeypatch.setattr(ShardedStore, "var_matrix", boom)
    ab = detect_abnormal(sharded, backend="jax")
    assert ab and ab[0].proc == 3 and ab[0].vid == 2
    ns = detect_non_scalable(series_sh, backend="jax", min_share=0.0)
    assert [d.vid for d in ns] == [d.vid for d in
                                   detect_non_scalable(
                                       {n: simulate(g, n, _base).ppg
                                        for n in (6, 12)},
                                       backend="numpy", min_share=0.0)]


# ---------------------------------------------------------------------------
# dirty-row incremental upload
# ---------------------------------------------------------------------------

def _assert_buffers_match(view, V):
    """Every device buffer equals its host block (padded to V columns)."""
    for i, blk in enumerate(view.blocks):
        np.testing.assert_array_equal(np.asarray(view.time_blocks()[i]),
                                      blk.time_matrix(V))
        np.testing.assert_array_equal(np.asarray(view.var_blocks()[i]),
                                      blk.var_matrix(V))
        for name in blk.counter_names():
            vids, values, mask = blk.counter_columns(name)
            key, buf = view.counter_blocks(name)[i]
            assert key == tuple(vids.tolist())
            np.testing.assert_array_equal(np.asarray(buf),
                                          np.where(mask, values, 0.0))


def test_incremental_upload_after_interleaved_writes():
    pytest.importorskip("jax")
    g = _step_psg(16)
    ppg = simulate(g, 16, _base, shards=[(0, 5), (5, 11), (11, 16)]).ppg
    V = len(g.vertices)
    view = ppg.device_view()
    assert view is ppg.device_view()                   # cached, one per PPG

    view.refresh(V)                                    # first: full upload
    assert view.full_uploads == 1 and view.last_upload_rows == 16
    _assert_buffers_match(view, V)
    full_bytes = view.last_upload_bytes

    view.refresh(V)                                    # clean: no transfer
    assert view.last_upload_rows == 0 and view.last_upload_bytes == 0

    rng = np.random.default_rng(0)
    for round_ in range(4):
        rows = np.unique(rng.integers(0, 16, size=rng.integers(1, 5)))
        vid = int(rng.integers(0, V))
        ppg.perf.set_entries(rows, vid, 1.0 + round_,
                             counters={"wait_s": 0.25})
        if round_ == 2:                                # scalar write path
            ppg.perf.set_entry(2, 1, 3.5, accumulate=True)
            rows = np.union1d(rows, [2])
        view.refresh(V)
        assert view.full_uploads == 1                  # still incremental
        assert view.last_upload_rows == rows.size
        assert view.last_upload_bytes < full_bytes
        _assert_buffers_match(view, V)
        # detection agrees with the numpy reference after every round
        assert _ab_key(detect_abnormal(ppg, backend="jax")) == \
            _ab_key(detect_abnormal(ppg, backend="numpy"))

    # a dtype flip re-pins in full (no stale f64 buffers feed f32 runs)
    view.refresh(V, dtype=np.float32)
    assert view.full_uploads == 2 and view.last_upload_rows == 16


def test_device_view_single_store_and_errors():
    pytest.importorskip("jax")
    store = PerfStore(6, 4)
    store.set_column(2, np.arange(6.0))
    view = DeviceShardView(store)
    with pytest.raises(RuntimeError):                  # read before refresh
        view.time_blocks()
    view.refresh(4)
    assert len(view.time_blocks()) == 1
    np.testing.assert_array_equal(np.asarray(view.time_blocks()[0]),
                                  store.time_matrix(4))
    assert view.row_ranges() == [(0, 6)]
    with pytest.raises(TypeError):
        DeviceShardView({})


# ---------------------------------------------------------------------------
# regression: unguarded share divide (total_max <= 0)
# ---------------------------------------------------------------------------

def _dead_top_series():
    """Final scale whose root children are ALL dead (t == 0) while a
    nested vertex still has time: total_max == 0."""
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    loop = g.new_vertex("Loop", "loop", parent=root.vid)
    g.add_edge(root.vid, loop.vid, "control")
    body = g.new_vertex(COMP, "body", parent=loop.vid, source="m.py:9")
    series = {}
    for n in (2, 4, 8):
        perf = {loop.vid: PerfVector(time=0.0 if n == 8 else 0.05,
                                     samples=1),
                body.vid: PerfVector(time=0.04, samples=1)}
        series[n] = build_ppg(g, n, perf)
    return series


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_total_max_zero_yields_zero_share_no_flags(backend):
    if backend == "jax":
        pytest.importorskip("jax")
    series = _dead_top_series()
    with np.errstate(all="raise"):                     # inf/nan would raise
        out = detect_non_scalable(series, backend=backend, min_share=0.01)
    assert out == []                                   # share 0: nothing


def test_non_scalable_kernel_guards_total_max_directly():
    detect_jax = pytest.importorskip("repro.core.detect_jax")
    if not detect_jax.HAS_JAX:
        pytest.skip("jax not importable")
    S, P, V = 2, 3, 4
    rng = np.random.default_rng(1)
    t = rng.uniform(0.1, 1.0, (S, P, V))
    M, slope, share, flagged = detect_jax.non_scalable_arrays(
        [2, 4], t, np.zeros_like(t), np.ones((S, V), bool), 0.0,
        -1.0, 0.35, 0.01, "mean")
    assert np.all(share == 0.0) and not flagged.any()
    assert np.isfinite(M).all() and np.isfinite(slope).all()


# ---------------------------------------------------------------------------
# measured-profile threading: profiler shards -> sharded PPG -> device path
# ---------------------------------------------------------------------------

def test_profiler_shards_feed_device_detection():
    """Per-host ``GraphProfiler.perf_shard`` blocks adopted via
    ``build_ppg(sharded=True)`` run device-fed detection equal to the
    merged-store numpy reference."""
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.core import GraphProfiler

    def step(x):
        return jnp.tanh(x @ x).sum()

    prof = GraphProfiler(step, (np.ones((4, 4), np.float32),),
                         sample_every=1)
    prof.step(np.ones((4, 4), np.float32))
    shards = [prof.perf_shard(proc_start=lo, n_procs=hi - lo)
              for lo, hi in [(0, 3), (3, 5), (5, 8)]]
    shards[1].set_entry(1, 1, 7.5)                 # host 1's straggler
    ppg = build_ppg(prof.psg, 8, shards, sharded=True)
    assert isinstance(ppg.perf, ShardedStore)
    merged = build_ppg(prof.psg, 8, iter(shards))
    ab_dev = detect_abnormal(ppg, backend="jax")
    ab_ref = detect_abnormal(merged, backend="numpy")
    assert _ab_key(ab_dev) == _ab_key(ab_ref)
    assert any(a.proc == 4 and a.vid == 1 for a in ab_dev)


# ---------------------------------------------------------------------------
# degraded-fleet row masks on the device path (PR 6)
# ---------------------------------------------------------------------------

def test_abnormal_device_proc_mask_equals_numpy_masked():
    """Masked device detection == masked numpy == one-shot on a fleet
    that never contained the dead rows (exclusion, not zero-pollution)."""
    pytest.importorskip("jax")
    n_procs, n_hosts = 16, 4
    _, plain, sharded = _sim_pair(n_procs, n_hosts,
                                  inject={(2, 2): 6.0, (9, 3): 6.0}, seed=0)
    mask = np.ones(n_procs, bool)
    mask[8:12] = False                 # host 2 dead (incl. straggler p9)
    live = np.nonzero(mask)[0]

    got_dev = detect_abnormal(sharded, backend="jax", proc_mask=mask)
    got_np = detect_abnormal(plain, backend="numpy", proc_mask=mask)
    assert _ab_key(got_dev) == _ab_key(got_np)
    assert any(a.proc == 2 for a in got_dev)       # live straggler found
    assert all(a.proc != 9 for a in got_dev)       # dead one is silent
    assert all(mask[a.proc] for a in got_dev)      # procs are GLOBAL

    # reference: a store that simply never had the dead rows
    restricted = PerfStore(live.size, len(plain.psg.vertices))
    restricted.apply_rows(plain.perf.extract_rows(live),
                          rows=np.arange(live.size))
    sub = build_ppg(plain.psg, live.size, restricted)
    want = detect_abnormal(sub, backend="numpy")
    assert _ab_key(got_np) == [(int(live[p]), v, t, m)
                               for p, v, t, m in _ab_key(want)]


def test_live_kernel_no_retrace_across_live_set_sizes():
    """A flapping host — a different live COUNT every detect call — must
    hit one compiled executable: the live gather is padded to the fleet
    size with a validity mask, so traced shapes depend on P alone.
    (Regression: the unpadded gather made every live-set size a fresh
    trace.)"""
    pytest.importorskip("jax")
    from repro.core import detect_jax

    n_procs = 16
    _, plain, sharded = _sim_pair(n_procs, 4, inject={(2, 2): 6.0}, seed=0)
    kern = detect_jax._abnormal_topk_blocks_live_kernel
    masks = []
    for dead in [(3,), (3, 7), (1, 5, 9, 13), (0,), (8, 9, 10, 11, 12)]:
        mask = np.ones(n_procs, bool)
        mask[list(dead)] = False
        masks.append(mask)
    detect_abnormal(sharded, backend="jax", proc_mask=masks[0])
    baseline = kern._cache_size()
    for mask in masks[1:]:
        got = detect_abnormal(sharded, backend="jax", proc_mask=mask)
        # parity with the numpy row-subset reference on every mask shape
        assert _ab_key(got) == _ab_key(
            detect_abnormal(plain, backend="numpy", proc_mask=mask))
    assert kern._cache_size() == baseline      # zero retraces


def test_device_proc_mask_reuses_buffers_across_masks():
    """Changing the mask between detects must not force a re-upload —
    the live gather happens on device, the pinned buffers stand."""
    pytest.importorskip("jax")
    n_procs = 12
    _, _, sharded = _sim_pair(n_procs, 3, inject={(1, 2): 5.0}, seed=1)
    full = detect_abnormal(sharded, backend="jax")
    view = sharded.device_view()
    uploads = view.total_upload_bytes
    for dead in (0, 4, 8):
        mask = np.ones(n_procs, bool)
        mask[dead] = False
        detect_abnormal(sharded, backend="jax", proc_mask=mask)
    assert view.total_upload_bytes == uploads      # no re-transfer
    again = detect_abnormal(sharded, backend="jax")
    assert _ab_key(again) == _ab_key(full)         # full-fleet path intact


# ---------------------------------------------------------------------------
# refresh atomicity: a failed upload must not eat the dirty flags (PR 6)
# ---------------------------------------------------------------------------

def test_refresh_failure_keeps_dirty_rows_for_retry(monkeypatch):
    """A device upload that raises mid-refresh leaves the dirty flags and
    the pinned buffers untouched; the retried refresh re-uploads exactly
    the rows the failed call lost.  (Regression: clearing dirty flags
    eagerly dropped those rows forever.)"""
    pytest.importorskip("jax")
    n_procs = 12
    _, _, sharded = _sim_pair(n_procs, 3, seed=2)
    view = sharded.perf.device_view() if hasattr(sharded.perf, "device_view") \
        else DeviceShardView(sharded.perf)
    view.refresh()                                  # clean baseline upload
    assert all(not b.dirty_rows().size for b in view.blocks)

    # dirty a couple of rows, then make the upload die mid-flight
    sharded.perf.set_entry(1, 1, 9.0)
    sharded.perf.set_entry(7, 2, 9.5)
    calls = {"n": 0}
    real = DeviceShardView._rows_slab

    def dying(self, mat, rows, V, dtype):
        calls["n"] += 1
        if calls["n"] >= 2:
            raise RuntimeError("injected device OOM")
        return real(self, mat, rows, V, dtype)

    monkeypatch.setattr(DeviceShardView, "_rows_slab", dying)
    before_time = [np.asarray(t).copy() for t in view.time_blocks()]
    with pytest.raises(RuntimeError, match="injected device OOM"):
        view.refresh()
    monkeypatch.undo()

    # the failed refresh changed NOTHING: flags intact, buffers intact
    dirty = np.concatenate([b.dirty_rows() + b.proc_start
                            for b in view.blocks])
    assert sorted(dirty.tolist()) == [1, 7]
    for buf, ref in zip(view.time_blocks(), before_time):
        np.testing.assert_array_equal(np.asarray(buf), ref)

    # the retry re-uploads exactly those rows and converges to the hosts
    view.refresh()
    assert view.last_upload_rows == 2
    assert all(not b.dirty_rows().size for b in view.blocks)
    host = np.concatenate([b.time for b in view.blocks], axis=0)
    dev = np.concatenate([np.asarray(t) for t in view.time_blocks()], axis=0)
    np.testing.assert_array_equal(dev, host)


def test_refresh_failure_on_full_upload_leaves_view_unprimed(monkeypatch):
    """Same contract on the FULL-upload branch: a fresh view whose first
    refresh dies stays unprimed (reads still refuse) and the stores stay
    fully dirty for the retry."""
    pytest.importorskip("jax")
    _, _, sharded = _sim_pair(8, 2, seed=3)
    view = DeviceShardView(sharded.perf)

    def dying(self, mat, rows, V, dtype):
        raise RuntimeError("boom on first slab")

    monkeypatch.setattr(DeviceShardView, "_rows_slab", dying)
    with pytest.raises(RuntimeError, match="boom on first slab"):
        view.refresh()
    monkeypatch.undo()
    with pytest.raises(RuntimeError):
        view.time_blocks()                          # still unprimed
    assert all(b.dirty_rows().size == b.n_procs for b in view.blocks)
    view.refresh()                                  # retry fully recovers
    host = np.concatenate([b.time for b in view.blocks], axis=0)
    dev = np.concatenate([np.asarray(t) for t in view.time_blocks()], axis=0)
    np.testing.assert_array_equal(dev, host)
