"""Minimal stand-in for `hypothesis` when it isn't installed.

The real library is declared in pyproject.toml and is used when available
(conftest.py only installs this shim on ImportError).  The shim covers the
subset this test suite uses — ``given``/``settings`` decorators and the
``floats`` / ``integers`` / ``sampled_from`` / ``booleans`` / ``composite``
strategies — drawing examples from a seeded PRNG so runs are deterministic.
No shrinking, no database, no stateful testing.
"""
from __future__ import annotations

import functools
import random
import types
from typing import Any, Callable, Sequence

_DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    """A value generator: ``example(rng)`` draws one value."""

    def __init__(self, draw_fn: Callable[[random.Random], Any]):
        self._draw = draw_fn

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)


def floats(min_value: float, max_value: float) -> Strategy:
    # bias the first draws toward the endpoints, like hypothesis does
    def draw(rng):
        r = rng.random()
        if r < 0.05:
            return float(min_value)
        if r < 0.10:
            return float(max_value)
        return rng.uniform(min_value, max_value)
    return Strategy(draw)


def integers(min_value: int, max_value: int) -> Strategy:
    def draw(rng):
        r = rng.random()
        if r < 0.05:
            return int(min_value)
        if r < 0.10:
            return int(max_value)
        return rng.randint(min_value, max_value)
    return Strategy(draw)


def sampled_from(elements: Sequence[Any]) -> Strategy:
    elements = list(elements)
    return Strategy(lambda rng: elements[rng.randrange(len(elements))])


def booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5)


def lists(element: Strategy, *, min_size: int = 0,
          max_size: int = 10) -> Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [element.example(rng) for _ in range(n)]
    return Strategy(draw)


def composite(fn: Callable) -> Callable[..., Strategy]:
    """``@composite`` — fn(draw, *args) becomes a strategy factory."""
    @functools.wraps(fn)
    def factory(*args, **kwargs) -> Strategy:
        def draw_value(rng):
            return fn(lambda strat: strat.example(rng), *args, **kwargs)
        return Strategy(draw_value)
    return factory


def given(*arg_strategies: Strategy, **kw_strategies: Strategy):
    """Run the test once per generated example (deterministic seed)."""
    def decorate(test_fn):
        @functools.wraps(test_fn)
        def wrapper(*fixture_args, **fixture_kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"shim:{test_fn.__module__}."
                                f"{test_fn.__qualname__}")
            for i in range(n):
                args = tuple(s.example(rng) for s in arg_strategies)
                kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    test_fn(*fixture_args, *args,
                            **{**fixture_kwargs, **kwargs})
                except Exception as e:
                    e.args = (f"[hypothesis-shim example {i}: args={args} "
                              f"kwargs={kwargs}] {e.args[0] if e.args else ''}",
                              *e.args[1:])
                    raise
        # pytest must not see the original signature (it would treat the
        # strategy params as fixtures), so drop the wraps() breadcrumb
        del wrapper.__wrapped__
        wrapper._hypothesis_shim = True
        return wrapper
    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Applied above @given: records max_examples on the wrapped test."""
    def decorate(fn):
        fn._max_examples = max_examples
        return fn
    return decorate


strategies = types.ModuleType("hypothesis.strategies")
strategies.floats = floats
strategies.integers = integers
strategies.sampled_from = sampled_from
strategies.booleans = booleans
strategies.lists = lists
strategies.composite = composite
