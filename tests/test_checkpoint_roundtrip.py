"""Bit-identity of the versioned to_tree/from_tree persistence seam.

ONE persistence path: monitor snapshots and the run store both push
objects through ``to_tree()`` into ``repro.checkpoint.store`` and
rebuild with ``from_tree()``.  These tests drive random stores through
an ACTUAL disk checkpoint (save_checkpoint -> load_checkpoint_tree),
not just an in-memory tree copy, and assert the reload is bit-identical
— dtypes included — with counters staying column-sparse throughout.

Also pins the checkpoint-layer bugs the seam exposed: empty dict/list
nodes used to vanish through a save/load round trip (a counter-less
store lost its ``"counters": {}``), and slashed dict keys used to
corrupt the manifest path namespace silently.
"""
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.store import (load_checkpoint_tree, save_checkpoint)
from repro.core import PSG, PerfShard, PerfStore, ShardedStore, shard_ranges
from repro.core.graph import PPG, CommIndex, check_tree_format

COUNTER_SETS = [(), ("wait_s",), ("flops", "bytes"), ("wait_s", "comm_bytes")]


def _tree_equal(a, b, path=""):
    """Recursive bit-identity: same structure, arrays equal with equal
    dtype (int64 reloading as float64 is a FAIL, not a pass)."""
    if isinstance(a, dict) or isinstance(b, dict):
        assert isinstance(a, dict) and isinstance(b, dict), path
        assert sorted(a) == sorted(b), path
        for k in a:
            _tree_equal(a[k], b[k], f"{path}/{k}")
        return
    aa, bb = np.asarray(a), np.asarray(b)
    assert aa.dtype == bb.dtype, f"{path}: {aa.dtype} vs {bb.dtype}"
    assert np.array_equal(aa, bb), path


def _disk_roundtrip(tree, meta):
    """Push (tree, meta) through a real checkpoint directory."""
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 0, tree, extra_meta={"seam": meta})
        tree2, extra = load_checkpoint_tree(d, 0)
    return tree2, extra["seam"]


def _fill(store, entries, n_procs):
    for i, (p, vid, ci) in enumerate(entries):
        store.set_entries(
            np.asarray([p % n_procs]), vid, 0.5 + 0.25 * i,
            time_var=0.125 * i, samples=1 + (i % 4),
            counters={nm: 3.0 * i + 100.0 * j
                      for j, nm in enumerate(COUNTER_SETS[ci])})


@st.composite
def store_plan(draw):
    n_procs = draw(st.integers(1, 10))
    n_vertices = draw(st.integers(1, 8))
    n_entries = draw(st.integers(0, 30))
    entries = [(draw(st.integers(0, 9)), draw(st.integers(0, 7)),
                draw(st.integers(0, len(COUNTER_SETS) - 1)))
               for _ in range(n_entries)]
    return n_procs, n_vertices, entries


@settings(deadline=None, max_examples=30)
@given(store_plan())
def test_perfstore_disk_roundtrip_bit_identical(plan):
    n_procs, n_vertices, entries = plan
    store = PerfStore(n_procs, n_vertices)
    _fill(store, [(p, v % n_vertices, c) for p, v, c in entries], n_procs)
    tree, meta = store.to_tree()
    tree2, meta2 = _disk_roundtrip(tree, meta)
    other = PerfStore.from_tree(tree2, meta2)
    _tree_equal(tree, other.to_tree()[0])
    assert meta == meta2 == other.to_tree()[1]
    for nm in store.counter_names():
        v1 = store.counter_columns(nm)
        v2 = other.counter_columns(nm)
        for x, y in zip(v1, v2):
            assert np.array_equal(x, y) and x.dtype == y.dtype


@settings(deadline=None, max_examples=20)
@given(store_plan(), st.integers(1, 4))
def test_shardedstore_disk_roundtrip_bit_identical(plan, n_hosts):
    n_procs, n_vertices, entries = plan
    shards = []
    for lo, hi in shard_ranges(n_procs, n_hosts):
        sh = PerfShard(lo, hi - lo, n_vertices)
        _fill(sh, [(p % (hi - lo), v % n_vertices, c)
                   for p, v, c in entries], hi - lo)
        shards.append(sh)
    store = ShardedStore.of(shards)
    tree, meta = store.to_tree()
    tree2, meta2 = _disk_roundtrip(tree, meta)
    other = ShardedStore.from_tree(tree2, meta2)
    assert meta2 == meta
    V = n_vertices
    assert np.array_equal(store.time_matrix(V), other.time_matrix(V))
    assert np.array_equal(store.var_matrix(V), other.var_matrix(V))
    for nm in store.counter_names():
        assert np.array_equal(store.counter_matrix(nm, V),
                              other.counter_matrix(nm, V))
    _tree_equal(tree, other.to_tree()[0])


def test_counters_stay_column_sparse_on_disk():
    """The checkpoint must hold (P, k) counter blocks, never (P, V)."""
    store = PerfStore(6, 50)
    store.set_entries(np.asarray([1, 3]), 7, 1.0, counters={"wait_s": 2.0})
    store.set_entries(np.asarray([2]), 31, 1.0, counters={"wait_s": 4.0})
    tree, meta = store.to_tree()
    block = tree["counters"]["c0"]
    assert block["values"].shape == (6, 2)        # two written vids, not 50
    assert block["mask"].shape == (6, 2)
    assert set(block["vids"].tolist()) == {7, 31}
    tree2, meta2 = _disk_roundtrip(tree, meta)
    other = PerfStore.from_tree(tree2, meta2)
    assert other.counter_columns("wait_s")[1].shape[1] == 2


def test_counterless_store_roundtrips():
    """Regression: ``"counters": {}`` used to vanish through the
    template-free loader (empty containers produce no leaves)."""
    store = PerfStore(4, 3)
    store.set_entries(np.asarray([0, 2]), 1, 2.5)
    tree, meta = store.to_tree()
    assert tree["counters"] == {}
    tree2, meta2 = _disk_roundtrip(tree, meta)
    assert "counters" in tree2 and tree2["counters"] == {}
    other = PerfStore.from_tree(tree2, meta2)
    assert np.array_equal(store.time_matrix(3), other.time_matrix(3))
    assert other.counter_names() == []


def test_empty_containers_survive_checkpoint():
    tree = {"a": {}, "b": [], "c": {"d": np.arange(3), "e": {}}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 0, tree)
        tree2, _ = load_checkpoint_tree(d, 0)
    assert tree2["a"] == {}
    assert tree2["b"] == []
    assert tree2["c"]["e"] == {}
    assert np.array_equal(tree2["c"]["d"], np.arange(3))


def test_wholly_empty_tree_roundtrips():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 0, {})
        tree2, _ = load_checkpoint_tree(d, 0)
    assert tree2 == {}


def test_slashed_dict_key_rejected():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="contains '/'"):
            save_checkpoint(d, 0, {"a/b": np.zeros(2)})


def test_psg_and_comm_roundtrip():
    psg = PSG()
    psg.new_vertex("Root", "root")
    loop = psg.new_vertex("Loop", "step", parent=0, source="m.py:1")
    psg.new_vertex("Comp", "matmul", parent=loop.vid, source="m.py:2")
    psg.new_vertex("Comm", "all-reduce", parent=loop.vid, source="m.py:3")
    tree, meta = psg.to_tree()
    tree2, meta2 = _disk_roundtrip(tree, meta)
    other = PSG.from_tree(tree2, meta2)
    assert other.to_json() == psg.to_json()

    comm = CommIndex()
    comm.add_p2p((0, 3), (1, 3))
    comm.add_p2p((1, 3), (2, 3))
    comm.add_group(3, (0, 1, 2))
    ct, cm = comm.to_tree()
    ct2, cm2 = _disk_roundtrip(ct, cm)
    comm2 = CommIndex.from_tree(ct2, cm2)
    _tree_equal(ct, comm2.to_tree()[0])
    assert cm2 == cm


def test_ppg_roundtrip_composes_subtrees():
    psg = PSG()
    psg.new_vertex("Root", "root")
    psg.new_vertex("Comp", "comp", parent=0)
    ppg = PPG(psg, 3)
    ppg.perf.set_entries(np.asarray([0, 1, 2]), 1, 1.5,
                         counters={"wait_s": 0.25})
    ppg.comm.add_group(1, (0, 1, 2))
    tree, meta = ppg.to_tree()
    tree2, meta2 = _disk_roundtrip(tree, meta)
    other = PPG.from_tree(tree2, meta2)
    assert other.n_procs == 3
    assert other.psg.to_json() == psg.to_json()
    assert np.array_equal(other.times_matrix(), ppg.times_matrix())
    _tree_equal(ppg.to_tree()[0], other.to_tree()[0])


def test_version_header_checked():
    store = PerfStore(2, 2)
    tree, meta = store.to_tree()
    bad = dict(meta)
    bad["format"] = "something-else"
    with pytest.raises(ValueError, match="format"):
        PerfStore.from_tree(tree, bad)
    future = dict(meta)
    future["version"] = 99
    with pytest.raises(ValueError, match="version"):
        PerfStore.from_tree(tree, future)
    # headerless metadata is the legacy (pre-versioning) snapshot form
    assert check_tree_format(None, "perfstore", 1) == 1
    assert check_tree_format({}, "perfstore", 1) == 1
