"""render_report edge cases + the monitor's streamed-report golden shape.

jax-free; exercises the exact listing-cap / "… and N more" / coverage
contract the always-on monitor renders its stream through.
"""
import dataclasses

import numpy as np

from repro.core import (PPG, PerfStore, build_ppg, detect_abnormal,
                        render_report)
from repro.core.backtrack import backtrack
from repro.core.detect import Abnormal
from repro.core.inject import simulate
from repro.core.shard import shard_ranges
from repro.monitor import Monitor, QueueTransport, ShardProducer
from repro.core.shard import ShardedStore
from repro.monitor.chaos import build_chaos_psg


def _ppg(n_procs=8, inject=None):
    psg = build_chaos_psg(6)
    sim = simulate(psg, n_procs,
                   lambda p, v: 0.0 if psg.vertices[v].kind == "Comm"
                   else 1.0 + 0.01 * v,
                   inject=inject or {}, comm_time=lambda *a: 0.05,
                   jitter=0.0, seed=0)
    return psg, sim.ppg


def _fake_abnormal(psg, n):
    v = psg.vertices[1]
    return [Abnormal(vid=1, proc=p, kind=v.kind, name=v.name,
                     time=2.0, typical=1.0, ratio=2.0,
                     source=v.source or "") for p in range(n)]


def test_empty_report_renders_every_section_with_none():
    _, ppg = _ppg()
    text = render_report(ppg, [], [], [])
    assert "## Non-scalable vertices" in text
    assert "## Abnormal vertices" in text
    assert "## Backtracking root-cause paths" in text
    assert "## Root causes" in text
    assert text.count("(none)") == 3          # every list section is empty
    assert "… and" not in text


def test_max_abnormal_caps_listing_with_exact_remainder():
    psg, ppg = _ppg()
    ab = _fake_abnormal(psg, 7)
    text = render_report(ppg, [], ab, [], max_abnormal=3)
    listed = [l for l in text.splitlines() if l.startswith("  - v1 p")]
    assert len(listed) == 3
    assert "… and 4 more" in text

    # exactly at the cap: no remainder line
    text = render_report(ppg, [], ab, [], max_abnormal=7)
    assert "… and" not in text
    assert len([l for l in text.splitlines()
                if l.startswith("  - v1 p")]) == 7


def test_max_abnormal_zero_lists_nothing_but_counts_all():
    psg, ppg = _ppg()
    ab = _fake_abnormal(psg, 5)
    text = render_report(ppg, [], ab, [], max_abnormal=0)
    assert not [l for l in text.splitlines() if l.startswith("  - v1 p")]
    assert "… and 5 more" in text


def test_coverage_line_sits_under_the_header_counts():
    _, ppg = _ppg()
    cov = "fleet coverage: 6/8 procs, 3/4 hosts live (DEGRADED: host h1 excluded)"
    text = render_report(ppg, [], [], [], coverage=cov)
    lines = text.splitlines()
    i = next(i for i, l in enumerate(lines) if l.startswith("processes:"))
    assert lines[i + 1] == cov
    # and absent by default
    assert "fleet coverage" not in render_report(ppg, [], [], [])


def test_monitor_report_stream_golden_shape():
    """The monitor's streamed reports carry the same render contract."""
    psg = build_chaos_psg(6)
    n_procs, n_hosts = 8, 2
    ranges = shard_ranges(n_procs, n_hosts)
    sim = simulate(psg, n_procs,
                   lambda p, v: 0.0 if psg.vertices[v].kind == "Comm"
                   else 1.0 + 0.01 * v,
                   inject={(1, 2): 4.0}, comm_time=lambda *a: 0.05,
                   jitter=0.0, seed=0, shards=ranges)
    truth = sim.ppg
    tr = QueueTransport()
    mon = Monitor(psg, ranges, tr, comm=truth.comm, detect_every=None,
                  max_abnormal=1, title="monitor stream")
    prod = ShardedStore(ranges, len(psg.vertices))
    for h in range(n_hosts):
        p = ShardProducer(h, prod.shards[h], tr, sleep=lambda s: None)
        prod.shards[h].apply_rows(truth.perf.shards[h].extract_rows(
            np.arange(prod.shards[h].n_procs)))
        p.flush(heartbeat=False)
    mon.poll()
    rep = mon.force_detect()

    assert rep.text.splitlines()[0] == "monitor stream"
    assert "fleet coverage: 8/8 procs, 2/2 hosts live" in rep.text
    assert "DEGRADED" not in rep.text
    # the cap applies to the stream: one listed, the rest counted
    if len(rep.abnormal) > 1:
        assert f"… and {len(rep.abnormal) - 1} more" in rep.text
    # the one-shot pipeline renders the identical body (minus coverage)
    ab = detect_abnormal(truth, backend="numpy")
    paths = backtrack(truth, [], ab)
    one_shot = render_report(truth, [], ab, paths, title="monitor stream",
                             max_abnormal=1)
    stripped = "\n".join(l for l in rep.text.splitlines()
                         if not l.startswith("fleet coverage:"))
    assert stripped == one_shot
