"""Trainer: distributed training loop with ScalAna as a first-class feature.

Responsibilities:
  * build model + optimizer + data from a RunConfig;
  * one jitted ``train_step`` (grad accumulation via ``lax.scan`` over
    microbatches, optional int8 error-feedback gradient compression);
  * sharding: params/opt-state via logical rules, batch over ('pod','data');
  * fault tolerance: async checkpoints + auto-resume; step timeout guard;
  * ScalAna hooks: static PSG at build time, sampled per-vertex profiling
    every K steps (GraphProfiler), per-step wall times feeding abnormal/
    straggler detection, optional injected per-rank delay for case studies.

On CPU this runs real smoke-scale training; on a pod the same code lowers
with NamedShardings (the dry-run compiles exactly ``make_train_step``'s
function for the production meshes).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgbase
from repro.configs import get as get_config
from repro.configs import SHAPES
from repro.core.profiler import GraphProfiler
from repro.checkpoint import CheckpointManager
from repro.data import make_dataset
from repro.distributed.axes import spec_for, use_rules
from repro.models.api import ModelBundle, build_model
from repro.optim import adamw_init, adamw_update, warmup_cosine
from repro.optim.compress import error_feedback_update, init_residual

Pytree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Pytree
    opt: Any                      # AdamWState
    residual: Optional[Pytree]    # error-feedback residual (or None)
    step: jax.Array               # i32


def make_train_step(model: ModelBundle, run: cfgbase.RunConfig,
                    lr_fn: Callable[[jax.Array], jax.Array]
                    ) -> Callable[[TrainState, Dict[str, jax.Array]],
                                  Tuple[TrainState, Dict[str, jax.Array]]]:
    """Build the pure train-step function (grad-accum + AdamW [+ EF-int8])."""
    nmicro = max(int(run.microbatch), 1)
    compress = bool(getattr(run, "grad_compress", False))

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single_grads(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accum_grads(params, batch):
        # split leading batch dim into (nmicro, B/nmicro, ...); scan
        def split(x):
            b = x.shape[0]
            assert b % nmicro == 0, (b, nmicro)
            return x.reshape((nmicro, b // nmicro) + x.shape[1:])

        micro = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            acc, lsum = carry
            loss, metrics, grads = single_grads(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / nmicro,
                               acc, grads)
            return (acc, lsum + loss / nmicro), metrics

        (grads, loss), metrics = jax.lax.scan(body, (zero, jnp.zeros(())),
                                              micro)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        fn = accum_grads if nmicro > 1 else single_grads
        loss, metrics, grads = fn(state.params, batch)
        residual = state.residual
        if compress and residual is not None:
            grads, residual = error_feedback_update(grads, residual)
        lr = lr_fn(state.step)
        params, opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, lr=lr,
            weight_decay=run.weight_decay)
        # "loss" last: under grad accumulation `metrics` carries the last
        # microbatch's values, but the step loss is the microbatch mean
        out = {**metrics, **opt_metrics, "loss": loss}
        return TrainState(params=params, opt=opt, residual=residual,
                          step=state.step + 1), out

    return train_step


class Trainer:
    """End-to-end training driver (data + step + ckpt + ScalAna)."""

    def __init__(self, run: cfgbase.RunConfig, *,
                 mesh=None, rules=None,
                 arch_cfg: Optional[cfgbase.ArchConfig] = None,
                 shape: Optional[cfgbase.ShapeConfig] = None,
                 global_batch: Optional[int] = None,
                 inject_delay: Optional[Dict[int, float]] = None):
        self.run = run
        self.mesh = mesh
        self.rules = rules
        self.cfg = arch_cfg if arch_cfg is not None else get_config(run.arch)
        self.shape = shape if shape is not None else SHAPES[run.shape]
        self.model = build_model(self.cfg)
        self.lr_fn = warmup_cosine(run.learning_rate, run.warmup_steps,
                                   run.total_steps)
        self.train_step_fn = make_train_step(self.model, run, self.lr_fn)
        self.dataset = make_dataset(self.cfg, self.shape, seed=run.seed,
                                    global_batch=global_batch)
        self.ckpt = (CheckpointManager(run.checkpoint_dir,
                                       keep=run.keep_checkpoints)
                     if run.checkpoint_dir else None)
        # ScalAna channels
        self.profiler: Optional[GraphProfiler] = None
        self.step_wall_times: list = []
        self.metrics_log: list = []
        # case-study hook: {rank: extra seconds} host-side injected delay
        self.inject_delay = dict(inject_delay or {})
        self._compiled = None

    # ------------------------------------------------------------------
    def init_state(self, seed: Optional[int] = None) -> TrainState:
        key = jax.random.PRNGKey(self.run.seed if seed is None else seed)
        params = self.model.init(key)
        residual = (init_residual(params)
                    if getattr(self.run, "grad_compress", False) else None)
        return TrainState(params=params, opt=adamw_init(params),
                          residual=residual, step=jnp.zeros((), jnp.int32))

    def state_shardings(self, state_shape) -> Any:
        """NamedShardings for TrainState (params rules; opt mirrors)."""
        if self.mesh is None:
            return None
        from jax.sharding import NamedSharding
        pspecs = self.model.param_partition_specs()

        def like_params(tree):
            flat_p, treedef = jax.tree.flatten(pspecs)
            flat_t = treedef.flatten_up_to(tree)
            return treedef.unflatten(flat_p)

        import jax.sharding as shd
        scalar = shd.NamedSharding(self.mesh, shd.PartitionSpec())
        return TrainState(
            params=jax.tree.map(
                lambda s: shd.NamedSharding(self.mesh, s), pspecs),
            opt=type(state_shape.opt)(
                step=scalar,
                mu=jax.tree.map(lambda s: shd.NamedSharding(self.mesh, s),
                                pspecs),
                nu=jax.tree.map(lambda s: shd.NamedSharding(self.mesh, s),
                                pspecs)),
            residual=(jax.tree.map(
                lambda s: shd.NamedSharding(self.mesh, s), pspecs)
                if state_shape.residual is not None else None),
            step=scalar,
        )

    # ------------------------------------------------------------------
    def _put_batch(self, np_batch: Dict[str, np.ndarray]):
        return jax.tree.map(jnp.asarray, np_batch)

    def enable_scalana(self, state: TrainState,
                       example_batch: Dict[str, jax.Array]) -> None:
        """Build PSG + profiler over the real train-step jaxpr."""
        self.profiler = GraphProfiler(
            self.train_step_fn, (state, example_batch),
            sample_every=self.run.scalana_sample_every,
            max_loop_depth=self.run.max_loop_depth)

    # ------------------------------------------------------------------
    def train(self, num_steps: Optional[int] = None,
              state: Optional[TrainState] = None,
              resume: bool = True,
              step_timeout_s: float = 0.0) -> TrainState:
        num_steps = num_steps or self.run.total_steps
        start_step = 0
        if state is None:
            state = self.init_state()
            if resume and self.ckpt is not None:
                restored = self.ckpt.restore_latest(
                    jax.tree.map(np.asarray, jax.device_get(state)))
                if restored is not None:
                    start_step, tree, _ = restored
                    state = jax.tree.map(jnp.asarray, tree)

        if self.run.scalana and self.profiler is None:
            batch0 = self._put_batch(self.dataset.batch(start_step))
            self.enable_scalana(state, batch0)

        step_fn = (self.profiler.step if self.profiler is not None
                   else jax.jit(self.train_step_fn))

        rank = jax.process_index()
        for i in range(start_step, start_step + num_steps):
            batch = self._put_batch(self.dataset.batch(i))
            t0 = time.perf_counter()
            if self.inject_delay.get(rank):
                time.sleep(self.inject_delay[rank])   # straggler case study
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_wall_times.append(dt)
            if step_timeout_s and dt > step_timeout_s:
                # straggler mitigation: surface instead of hanging the job
                self.metrics_log.append({"step": i, "timeout": dt})
            self.metrics_log.append(
                {"step": i,
                 "loss": float(metrics["loss"]),
                 "grad_norm": float(metrics.get("grad_norm", 0.0)),
                 "wall_s": dt})
            if (self.ckpt is not None and self.run.checkpoint_every
                    and (i + 1) % self.run.checkpoint_every == 0):
                self.ckpt.save(i + 1, jax.device_get(state))
        if self.ckpt is not None:
            self.ckpt.save(start_step + num_steps, jax.device_get(state),
                           blocking=True)
        return state

    # ------------------------------------------------------------------
    def scalana_artifacts(self):
        """(contracted PSG, per-vertex perf vectors, storage bytes)."""
        if self.profiler is None:
            return None
        return (self.profiler.psg, self.profiler.perf_vectors(),
                self.profiler.storage_bytes())
