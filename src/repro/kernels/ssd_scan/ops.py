"""Public SSD-scan op: padding + dispatch + CPU-interpret fallback."""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array,
             Bm: jax.Array, Cm: jax.Array, *, chunk: int = 64,
             return_final: bool = False,
             interpret: Optional[bool] = None
             ) -> Union[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Same contract as repro.models.mamba2.ssd_chunked (the oracle)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    B, S, H, P = x.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:                       # dt = 0 -> exp(0·A) = 1: state-neutral
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y, h = ssd_scan_kernel(x, dt, A, Bm, Cm, chunk=Q, interpret=interp)
    y = y[:, :S]
    return (y, h) if return_final else y
