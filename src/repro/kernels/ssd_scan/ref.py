"""Pure-jnp oracle for the SSD chunked-scan kernel.

Delegates to the model's own chunked SSD implementation
(repro.models.mamba2.ssd_chunked) — a single source of truth for the SSD
semantics: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T, y_t = C_t · h_t.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.mamba2 import ssd_chunked


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
            Bm: jnp.ndarray, Cm: jnp.ndarray, *, chunk: int = 64,
            return_final: bool = False):
    """x: (B,S,H,P); dt: (B,S,H) post-softplus; A: (H,) negative;
    Bm, Cm: (B,S,N).  Returns y (B,S,H,P) [, final state (B,H,N,P)]."""
    return ssd_chunked(x.astype(jnp.float32), dt.astype(jnp.float32),
                       A.astype(jnp.float32), Bm.astype(jnp.float32),
                       Cm.astype(jnp.float32), chunk,
                       return_final=return_final)
