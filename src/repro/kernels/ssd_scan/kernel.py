"""Mamba2 SSD chunked-scan Pallas TPU kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060 §6).  The GPU
implementation leans on warp-level primitives for the intra-chunk scan;
on TPU we restructure the whole computation as chunk-local *matmuls*
(MXU) plus a sequential inter-chunk state carry in VMEM scratch:

* Grid ``(B, H, NC)`` — NC (chunks) is the innermost, sequential TPU grid
  dimension; the (N, P) SSM state lives in VMEM scratch and carries from
  chunk c to c+1 (zero-initialized at c == 0 of every (b, h)).
* Per chunk, everything is dense linear algebra on (Q, ·) tiles:
    s        = cumsum(dt * A)                    (Q,)    VPU
    CB       = C · Bᵀ                            (Q, Q)  MXU
    M        = CB ⊙ exp(s_i - s_j) ⊙ dt_j  (causal)      VPU
    y_intra  = M · x                             (Q, P)  MXU
    y_inter  = (C ⊙ exp(s)) · h_prev             (Q, P)  MXU
    h_new    = exp(s_Q) h_prev + Bᵀ·(decay⊙dt⊙x) (N, P)  MXU
* Q (chunk) and P (head dim) are 64/128 — MXU-aligned; state N ∈ {64,128}.

VMEM per step: x,y (Q·P) + B,C (Q·N) + state (N·P) floats — KBs, far
under the ~16 MB/core budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref,
                h_scr, *, chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    A = a_ref[0].astype(jnp.float32)                   # scalar
    Bm = b_ref[0].astype(jnp.float32)                  # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)                  # (Q, N)

    s = jnp.cumsum(dt * A)                             # (Q,) inclusive
    # intra-chunk: M[i,j] = (C_i·B_j) exp(s_i - s_j) dt_j  for j <= i
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    L = s[:, None] - s[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    M = jnp.where(cols <= rows, CB * jnp.exp(L) * dt[None, :], 0.0)
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # inter-chunk: y += (C ⊙ exp(s)) · h_prev
    h_prev = h_scr[...]                                # (N, P)
    y = y + jax.lax.dot_general(Cm * jnp.exp(s)[:, None], h_prev,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: h = exp(s_Q) h_prev + Bᵀ · (decay_to_end ⊙ dt ⊙ x)
    decay_end = jnp.exp(s[-1] - s)                     # (Q,)
    w = (decay_end * dt)[:, None] * x                  # (Q, P)
    st = jax.lax.dot_general(Bm, w, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (N, P)
    h_new = jnp.exp(s[-1]) * h_prev + st
    h_scr[...] = h_new

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array, *, chunk: int = 64,
                    interpret: bool = False
                    ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm, Cm: (B,S,N).

    Returns (y (B,S,H,P) f32, final state (B,H,N,P) f32).  S % chunk == 0
    (the ops wrapper pads with dt=0 steps, which are state-neutral).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    NC = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=NC)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, H, NC),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, h
