"""Public flash-attention op: jit'd wrapper with CPU-interpret fallback.

``flash_attention(q, k, v)`` takes the models' (B, S, heads, h) layout,
transposes to the kernel's (B, heads, S, h), and dispatches to the Pallas
kernel — ``interpret=True`` automatically off-TPU so the same call works
in tests/CPU smoke runs and compiles to the real kernel on TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    softmax_scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, Sq, nq, h); k, v: (B, Sk, nkv, h) -> (B, Sq, nq, h)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_kernel(
        qt, kt, vt, causal=causal, softmax_scale=softmax_scale,
        block_q=block_q, block_k=block_k, interpret=interp)
    return jnp.swapaxes(out, 1, 2)
