"""Pure-jnp oracle for the flash-attention kernel (GQA, optional causal)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True,
                  softmax_scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, nq, Sq, h); k, v: (B, nkv, Sk, h); nq % nkv == 0.

    Materializes the full (Sq, Sk) score matrix — the memory-bound baseline
    the kernel replaces.  Float32 softmax, output in q.dtype.
    """
    B, nq, Sq, h = q.shape
    nkv, Sk = k.shape[1], k.shape[2]
    assert nq % nkv == 0, (nq, nkv)
    g = nq // nkv
    scale = softmax_scale if softmax_scale is not None else h ** -0.5
    qg = q.reshape(B, nkv, g, Sq, h).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgsh,bkth->bkgst", qg, kf) * scale
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bkth->bkgsh", w, vf)
    return out.reshape(B, nq, Sq, h).astype(q.dtype)
