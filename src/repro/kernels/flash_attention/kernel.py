"""Flash-attention Pallas TPU kernel (GQA, causal or full).

TPU-native tiling, not a CUDA port:

* Grid ``(B, nq, Sq/BQ, Sk/BK)`` — the last (K) dimension is innermost and
  *sequential* on TPU, so the online-softmax running state (m, l, acc)
  lives in VMEM scratch carried across K iterations; output is written
  once, on the final K block (output BlockSpec revisits the same tile).
* BlockSpecs keep one (BQ, h) query tile, one (BK, h) key/value tile in
  VMEM; all matmuls are (BQ×h)·(h×BK) and (BQ×BK)·(BK×h) — MXU-shaped,
  128-aligned for h ∈ {64, 128, 256}.
* GQA is an *index-map* property: the K/V BlockSpec maps query head
  ``qh -> qh // group`` so no KV replication is materialized in HBM or
  VMEM (the CUDA trick of shared-memory broadcast becomes pure indexing).
* Causal skipping: K blocks strictly above the diagonal are skipped via
  ``pl.when`` (compute-masked); the fully-unmasked interior skips the
  per-element mask entirely.

Accumulation in f32 regardless of input dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref,
                 m_scr, l_scr, acc_scr, *,
                 softmax_scale: float, causal: bool,
                 block_q: int, block_k: int, num_k_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # causal: skip K blocks entirely above the diagonal
    def compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (BQ, h)
        k = k_ref[0, 0].astype(jnp.float32)            # (BK, h)
        v = v_ref[0, 0].astype(jnp.float32)            # (BK, h)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * softmax_scale  # (BQ, BK)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_scr[...]                             # (BQ,)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])                 # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # block needed iff some (row >= col): k_start <= q_start + BQ - 1
        needed = k_start <= q_start + block_q - 1
        pl.when(needed)(compute)
    else:
        compute()

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        # rows with no valid keys (can't happen for causal Sq==Sk) -> 0
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "softmax_scale", "block_q", "block_k",
                     "interpret"))
def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           softmax_scale: Optional[float] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, nq, Sq, h); k, v: (B, nkv, Sk, h) -> (B, nq, Sq, h)."""
    B, nq, Sq, h = q.shape
    nkv, Sk = k.shape[1], k.shape[2]
    assert nq % nkv == 0, (nq, nkv)
    group = nq // nkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0, (Sq, block_q)
    assert Sk % block_k == 0, (Sk, block_k)
    nQ, nK = Sq // block_q, Sk // block_k
    scale = softmax_scale if softmax_scale is not None else h ** -0.5

    kernel = functools.partial(
        _attn_kernel, softmax_scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, num_k_blocks=nK)

    return pl.pallas_call(
        kernel,
        grid=(B, nq, nQ, nK),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, h),
                         lambda b, qh, qi, ki: (b, qh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, h),
                         lambda b, qh, qi, ki: (b, qh // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, h),
                         lambda b, qh, qi, ki: (b, qh // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, h),
                               lambda b, qh, qi, ki: (b, qh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq, Sq, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, h), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
