"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships three files: ``kernel.py`` (pl.pallas_call + BlockSpec
VMEM tiling), ``ops.py`` (the jit'd public wrapper with CPU-interpret
fallback), ``ref.py`` (the pure-jnp oracle tests assert against).
"""
from repro.kernels.detect_fused.ops import (
    fused_abnormal, fused_non_scalable, fused_non_scalable_live)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.ssd_scan.ops import ssd_scan

__all__ = ["flash_attention", "ssd_scan", "fused_abnormal",
           "fused_non_scalable", "fused_non_scalable_live"]
