"""Fused detection Pallas kernels: merge -> slope -> median -> top-k.

Two kernels cover the whole detection tail in one launch each:

* ``ns_fused_kernel`` — the non-scalable half.  Grid ``(S, NP)`` with NP
  (row tiles) innermost/sequential: per-scale merge accumulators (count,
  sum, max, p0, inverse-variance sums) live in VMEM scratch and reduce
  across row tiles; when a scale's last tile lands its (4, V) merged
  column is written into the M scratch stack, and the final grid step
  appends the (optional) device-cached historical columns, derives the
  reference step time from the "max" row, and runs the closed-form
  log-log slope fit + share/deviation flagging — all before leaving the
  kernel.  One launch replaces the merge/stack/slope dispatch chain.
* ``ab_fused_kernel`` — the abnormal half over one (P, V) time matrix
  (live-gathered and zero-padded by ``ops``).  Grid ``(2, NV)``: phase 0
  accumulates per-row step-time partials across column tiles; phase 1
  computes the masked cross-process median per column via bitwise radix
  *selection* (TPU Pallas has no sort primitive — the two middle order
  statistics are found in ``nbits`` counting passes on the order-
  preserving integer keys), flags abnormal entries, and runs a
  tournament top-k (k max/argmin passes per tile, merged across tiles
  through VMEM scratch) that reproduces the reference ranking exactly:
  descending score, ties broken by ascending vid-major flat index.

The pure-jnp merge/slope/flag formulas shared by the legacy stacked
kernels (``repro.core.detect_jax``), the fused jnp fast path
(``ops.py``), and the kernel bodies themselves are defined at the top of
this module — single source of truth, so the three paths cannot drift.

Everything is dtype-generic over f32/f64 (``SCALANA_DETECT_F32``); the
float<->ordered-integer key bridge picks uint32/uint64 to match.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.detect import JIT_STRATEGIES, VAR_EPS

_IMAX = JIT_STRATEGIES.index("max")
_ROW_TILE = 1024          # ns kernel: rows per grid step
_COL_TILE = 128           # ab kernel: vertex columns per grid step (lanes)
_STEP_EPS = 1e-12         # step-time clamp, matches the host reference


# -- shared detection math (jnp; used by legacy kernels, fused jnp path,
# -- and inside the Pallas kernel bodies) -------------------------------

def merge_all_stack(t: jax.Array, var: jax.Array) -> jax.Array:
    """(S, P, V) times + variances -> (4, S, V) merged, rows ordered as
    JIT_STRATEGIES.  Non-positive readings are dead (excluded)."""
    pos = t > 0.0
    cnt = pos.sum(axis=1)                              # (S, V)
    any_pos = cnt > 0
    total = jnp.where(pos, t, 0.0).sum(axis=1)
    mean = jnp.where(any_pos, total / jnp.maximum(cnt, 1), 0.0)
    mx = jnp.where(any_pos, t.max(axis=1), 0.0)
    p0 = t[:, 0, :]
    p0 = jnp.where(p0 > 0.0, p0, mean)
    w = jnp.where(pos, 1.0 / (var + VAR_EPS), 0.0)
    wsum = w.sum(axis=1)
    varm = jnp.where(wsum > 0,
                     (w * t).sum(axis=1) / jnp.where(wsum > 0, wsum, 1.0),
                     0.0)
    return jnp.stack([mean, mx, p0, varm])             # (4, S, V)


def merge_blocks(ts, vs) -> jax.Array:
    """One scale's per-host blocks -> its (4, V) merged column.

    ``ts`` / ``vs`` are tuples of (n_local, V) blocks in global proc
    order.  Every merge is an associative block-level reduction, so the
    stacked matrix never materializes."""
    pos = [t > 0.0 for t in ts]
    cnt = sum(p.sum(axis=0) for p in pos)              # (V,)
    total = sum(jnp.where(p, t, 0.0).sum(axis=0)
                for p, t in zip(pos, ts))
    mx_raw = jnp.stack([t.max(axis=0) for t in ts]).max(axis=0)
    w = [jnp.where(p, 1.0 / (v + VAR_EPS), 0.0)
         for p, v in zip(pos, vs)]
    wsum = sum(wi.sum(axis=0) for wi in w)
    wt = sum((wi * t).sum(axis=0) for wi, t in zip(w, ts))
    any_pos = cnt > 0
    mean = jnp.where(any_pos, total / jnp.maximum(cnt, 1), 0.0)
    mx = jnp.where(any_pos, mx_raw, 0.0)
    p0 = ts[0][0, :]
    p0 = jnp.where(p0 > 0.0, p0, mean)
    varm = jnp.where(wsum > 0,
                     wt / jnp.where(wsum > 0, wsum, 1.0), 0.0)
    return jnp.stack([mean, mx, p0, varm])             # (4, V)


def slope_share_flag(M, logp, present, total_max,
                     ideal_slope, slope_margin, min_share):
    """(4, S, V) merged stack -> (slope, share, flagged), each (4, V).

    ``share`` is guarded: an all-dead final scale (``total_max <= 0``)
    yields share 0 — and so flags nothing — instead of inf/nan."""
    valid = (M > 0.0) & present[None]
    x = logp[None, :, None]                            # (1, S, 1)
    Y = jnp.where(valid, jnp.log(jnp.where(valid, M, 1.0)), 0.0)
    n = valid.sum(axis=1)                              # (4, V)
    Sx = (x * valid).sum(axis=1)
    Sy = Y.sum(axis=1)
    Sxx = (x * x * valid).sum(axis=1)
    Sxy = (x * Y).sum(axis=1)
    denom = n * Sxx - Sx ** 2
    num = n * Sxy - Sx * Sy
    slope = jnp.where((denom != 0) & (n >= 2),
                      num / jnp.where(denom != 0, denom, 1.0), 0.0)
    share = jnp.where(total_max > 0.0,
                      M[:, -1, :] / jnp.where(total_max > 0.0,
                                              total_max, 1.0), 0.0)
    flagged = ((M.sum(axis=1) > 0.0)
               & (slope - ideal_slope > slope_margin)
               & (share >= min_share))
    return slope, share, flagged


def abnormal_flags(t, typical, abnorm_thd, min_share, step_time):
    """(P, V) times + (V,) typical -> (P, V) abnormal-entry mask."""
    active = t.max(axis=0) > 0.0
    over = ((t > abnorm_thd * typical) & (typical > 0.0)
            & ((t - typical) / step_time >= min_share))
    dead_typical = (typical == 0.0) & (t / step_time >= min_share)
    return (over | dead_typical) & active


# -- float <-> order-preserving integer keys ---------------------------

def key_info(dtype) -> Tuple[jnp.dtype, int]:
    """Unsigned key dtype + bit width for a float dtype."""
    if jnp.dtype(dtype) == jnp.dtype(jnp.float64):
        return jnp.dtype(jnp.uint64), 64
    if jnp.dtype(dtype) == jnp.dtype(jnp.float32):
        return jnp.dtype(jnp.uint32), 32
    raise TypeError(f"unsupported detect dtype {dtype}")


def to_key(x: jax.Array) -> jax.Array:
    """Bitcast floats to unsigned keys whose integer order matches the
    float total order (-inf < ... < +inf; only NaN maps to key 0/max).

    Integer keys are the whole trick: XLA's single-operand integer sort
    is ~13x faster than a float sort on CPU, and the Pallas median runs
    bitwise radix selection, which needs integer keys anyway."""
    u, bits = key_info(x.dtype)
    b = jax.lax.bitcast_convert_type(x, u)
    one = jnp.array(1, u)
    sign = jnp.array(bits - 1, u)
    return jnp.where((b >> sign) != 0, ~b, b | (one << sign))


def from_key(k: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`to_key`."""
    u, bits = key_info(dtype)
    one = jnp.array(1, u)
    sign = jnp.array(bits - 1, u)
    b = jnp.where((k >> sign) == 0, ~k, k & ~(one << sign))
    return jax.lax.bitcast_convert_type(b, jnp.dtype(dtype))


# -- non-scalable kernel ------------------------------------------------

def _ns_kernel(t_ref, var_ref, hist_ref, logp_ref, present_ref, top_ref,
               par_ref, m_out, slope_out, share_out, flag_out,
               cnt, total, mx, wsum, wt, p0, m_scr,
               *, n_data: int, n_hist: int):
    s = pl.program_id(0)
    p = pl.program_id(1)
    np_ = pl.num_programs(1)
    t = t_ref[0]                                       # (TP, V)
    v = var_ref[0]

    @pl.when(p == 0)
    def _init_scale():
        cnt[...] = jnp.zeros_like(cnt)
        total[...] = jnp.zeros_like(total)
        mx[...] = jnp.full_like(mx, -jnp.inf)
        wsum[...] = jnp.zeros_like(wsum)
        wt[...] = jnp.zeros_like(wt)
        p0[...] = t[0:1, :]                            # true row 0: pad
                                                       # rows are appended
    pos = t > 0.0
    cnt[...] += pos.astype(t.dtype).sum(axis=0, keepdims=True)
    total[...] += jnp.where(pos, t, 0.0).sum(axis=0, keepdims=True)
    mx[...] = jnp.maximum(mx[...], t.max(axis=0, keepdims=True))
    w = jnp.where(pos, 1.0 / (v + VAR_EPS), 0.0)
    wsum[...] += w.sum(axis=0, keepdims=True)
    wt[...] += (w * t).sum(axis=0, keepdims=True)

    @pl.when(p == np_ - 1)
    def _scale_column():
        any_pos = cnt[...] > 0
        mean = jnp.where(any_pos, total[...] / jnp.maximum(cnt[...], 1.0),
                         0.0)
        mxv = jnp.where(any_pos, mx[...], 0.0)
        p0v = jnp.where(p0[...] > 0.0, p0[...], mean)
        varm = jnp.where(wsum[...] > 0,
                         wt[...] / jnp.where(wsum[...] > 0, wsum[...], 1.0),
                         0.0)
        col = jnp.concatenate([mean, mxv, p0v, varm], axis=0)  # (4, V)
        m_scr[:, pl.ds(s, 1), :] = col[:, None, :]

    @pl.when((s == n_data - 1) & (p == np_ - 1))
    def _tail():
        M = m_scr[...]                                 # (4, n_data, V)
        if n_hist:
            M = jnp.concatenate([hist_ref[...], M], axis=1)
        m_out[...] = M
        par = par_ref[0]
        internal = (M[_IMAX, -1, :] * top_ref[0]).sum()
        total_max = jnp.where(par[4] > 0.0, par[3], internal)
        slope, share, flagged = slope_share_flag(
            M, logp_ref[...][:, 0], present_ref[...] > 0.0,
            total_max, par[0], par[1], par[2])
        slope_out[...] = slope
        share_out[...] = share
        flag_out[...] = flagged.astype(slope.dtype)


@functools.partial(jax.jit, static_argnames=("n_hist", "interpret"))
def ns_fused_kernel(t: jax.Array, var: jax.Array, hist: jax.Array,
                    logp: jax.Array, present: jax.Array,
                    top_mask: jax.Array, params: jax.Array,
                    *, n_hist: int, interpret: bool = False):
    """One-launch non-scalable detection.

    t, var: (S_d, P, V) data scales (P padded to a row-tile multiple
    with zero = dead rows; V padded to the lane tile).  hist: (4, H, V)
    device-cached merged columns of completed scales, prepended to the
    freshly merged data scales (pass a (4, 1, V) dummy with n_hist=0
    when uncached).  logp: (S, 1) log process counts over ALL S =
    n_hist + S_d scales; present: (S, V) 0/1; top_mask: (1, V) 0/1 root-
    children columns; params: (1, 8) [ideal_slope, slope_margin,
    min_share, total_max, use_total, 0, 0, 0].  Returns (M (4, S, V),
    slope, share, flagged-as-float (4, V))."""
    S_d, P, V = t.shape
    TP = P if P <= _ROW_TILE else _ROW_TILE
    assert P % TP == 0, (P, TP)
    NP = P // TP
    S_t = n_hist + S_d
    dt = t.dtype
    kernel = functools.partial(_ns_kernel, n_data=S_d, n_hist=n_hist)
    return pl.pallas_call(
        kernel,
        grid=(S_d, NP),
        in_specs=[
            pl.BlockSpec((1, TP, V), lambda s, p: (s, p, 0)),
            pl.BlockSpec((1, TP, V), lambda s, p: (s, p, 0)),
            pl.BlockSpec((4, max(n_hist, 1), V), lambda s, p: (0, 0, 0)),
            pl.BlockSpec((S_t, 1), lambda s, p: (0, 0)),
            pl.BlockSpec((S_t, V), lambda s, p: (0, 0)),
            pl.BlockSpec((1, V), lambda s, p: (0, 0)),
            pl.BlockSpec((1, 8), lambda s, p: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((4, S_t, V), lambda s, p: (0, 0, 0)),
            pl.BlockSpec((4, V), lambda s, p: (0, 0)),
            pl.BlockSpec((4, V), lambda s, p: (0, 0)),
            pl.BlockSpec((4, V), lambda s, p: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((4, S_t, V), dt),
            jax.ShapeDtypeStruct((4, V), dt),
            jax.ShapeDtypeStruct((4, V), dt),
            jax.ShapeDtypeStruct((4, V), dt),
        ],
        scratch_shapes=[pltpu.VMEM((1, V), dt) for _ in range(6)]
        + [pltpu.VMEM((4, S_d, V), dt)],
        interpret=interpret,
    )(t, var, hist, logp, present, top_mask, params)


# -- abnormal kernel ----------------------------------------------------

def _select_rank(keys: jax.Array, rank: jax.Array, nbits: int) -> jax.Array:
    """Per-column rank-``rank`` order statistic of integer keys.

    MSB-first radix selection: ``eq`` tracks the rows still matching the
    decided high bits; each pass counts how many of those have the
    current bit clear and descends left or right.  ``nbits`` counting
    passes over the (P, TV) tile — no sort primitive needed, which is
    what lets the median run inside a TPU Pallas kernel at all."""
    u = keys.dtype
    one = jnp.array(1, u)
    prefix = jnp.zeros((1, keys.shape[1]), u)
    rr = jnp.full((1, keys.shape[1]), rank, jnp.int32)
    eq = jnp.ones(keys.shape, jnp.bool_)

    def body(i, st):
        prefix, rr, eq = st
        bit = jnp.array(nbits - 1, jnp.int32) - i
        kb = ((keys >> bit.astype(u)) & one) != 0      # (P, TV)
        cnt0 = (eq & ~kb).sum(axis=0, keepdims=True, dtype=jnp.int32)
        go = rr >= cnt0                                # (1, TV)
        prefix = jnp.where(go, prefix | (one << bit.astype(u)), prefix)
        rr = jnp.where(go, rr - cnt0, rr)
        eq = eq & (kb == go)
        return prefix, rr, eq

    prefix, _, _ = jax.lax.fori_loop(0, nbits, body, (prefix, rr, eq))
    return prefix                                      # (1, TV)


def _extract_topk(skeys, sidx, seed_keys, seed_idx, k: int):
    """k rounds of (max key, min index among maxes) extraction, seeded
    with the running cross-tile best; extracted entries drop to key 0
    (strictly below every real score key, -inf included)."""
    u = skeys.dtype
    imax = jnp.iinfo(jnp.int32).max

    def body(i, st):
        sk, si, ok, oi = st
        m = sk.max()
        pick = jnp.where(sk == m, si, imax).min()
        sk = jnp.where((sk == m) & (si == pick), jnp.array(0, u), sk)
        return sk, si, ok.at[i].set(m), oi.at[i].set(pick)

    ok = jnp.zeros((k,), u)
    oi = jnp.full((k,), imax, jnp.int32)
    sk = jnp.concatenate([skeys.reshape(-1), seed_keys])
    si = jnp.concatenate([sidx.reshape(-1), seed_idx])
    _, _, ok, oi = jax.lax.fori_loop(0, k, body, (sk, si, ok, oi))
    return ok, oi


def _ab_kernel(t_ref, valid_ref, top_ref, par_ref,
               order_out, score_out, count_out, typ_out,
               step_scr, step_val, best_k, best_i, cnt_scr,
               *, k: int, nv: int, tv: int, nbits: int):
    ph = pl.program_id(0)
    cv = pl.program_id(1)
    t = t_ref[...]                                     # (P, TV)
    validf = valid_ref[...]                            # (P, 1)
    vb = validf > 0.0
    dt = t.dtype
    u, _ = key_info(dt)

    @pl.when((ph == 0) & (cv == 0))
    def _init_step():
        step_scr[...] = jnp.zeros_like(step_scr)

    @pl.when(ph == 0)
    def _accum_step():
        step_scr[...] += (t * top_ref[...]).sum(axis=1, keepdims=True)

    @pl.when((ph == 0) & (cv == nv - 1))
    def _finish_step():
        par = par_ref[0]
        sv = jnp.where(vb[:, 0], step_scr[...][:, 0], 0.0).max()
        sv = jnp.where(sv > 0.0, sv, jnp.array(_STEP_EPS, dt))
        step_val[0, 0] = jnp.where(par[3] > 0.0, par[2], sv)

    @pl.when(ph == 1)
    def _detect():
        par = par_ref[0]
        abnorm_thd, min_share = par[0], par[1]
        step = step_val[0, 0]
        n_live = jnp.maximum(validf.sum(), 1.0).astype(jnp.int32)
        keys = jnp.where(vb, to_key(t), to_key(jnp.full_like(t, jnp.inf)))
        lo = from_key(_select_rank(keys, (n_live - 1) // 2, nbits), dt)
        hi = from_key(_select_rank(keys, n_live // 2, nbits), dt)
        typical = 0.5 * (lo + hi)                      # (1, TV)
        typ_out[...] = typical
        tm = jnp.where(vb, t, 0.0)
        flags = abnormal_flags(tm, typical[0], abnorm_thd, min_share,
                               step) & vb
        add = flags.sum(dtype=jnp.int32)
        cnt_scr[0, 0] = jnp.where(cv == 0, add, cnt_scr[0, 0] + add)

        neg = to_key(jnp.full_like(t, -jnp.inf))
        skeys = jnp.where(flags, to_key(tm - typical), neg)
        P = t.shape[0]
        rows = jax.lax.broadcasted_iota(jnp.int32, skeys.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, skeys.shape, 1)
        lidx = (cv * tv + cols) * P + rows             # global vid-major

        imax = jnp.iinfo(jnp.int32).max
        seed_k = jnp.where(cv == 0, jnp.zeros((k,), u), best_k[0])
        seed_i = jnp.where(cv == 0, jnp.full((k,), imax, jnp.int32),
                           best_i[0])
        ok, oi = _extract_topk(skeys, lidx, seed_k, seed_i, k)
        best_k[...] = ok[None]
        best_i[...] = oi[None]

        @pl.when(cv == nv - 1)
        def _emit():
            order_out[...] = best_i[...]
            score_out[...] = from_key(best_k[...], dt)
            count_out[0, 0] = cnt_scr[0, 0]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ab_fused_kernel(t: jax.Array, valid: jax.Array, top_mask: jax.Array,
                    params: jax.Array, *, k: int, interpret: bool = False):
    """One-launch abnormal detection over a (P, V) time matrix.

    valid: (P, 1) 0/1 live-row mask (degraded fleets; all-ones
    otherwise).  top_mask: (1, V) 0/1 step-time columns.  params: (1, 8)
    [abnorm_thd, min_share, step_time, use_step, 0...].  V must be a
    lane-tile multiple (ops pads with zero columns — dead, never
    flagged, and their -inf scores rank after every real entry).
    Returns (order (1, k) int32 flat vid-major, scores (1, k), count
    (1, 1) int32, typical (1, V)); entries past the flagged count are
    the reference's -inf tail, exactly as the stable argsort yields.

    The whole fleet's rows sit in one VMEM block per column tile —
    (P, 128) f32 at 64k procs is 32 MB, so beyond ~32k procs use f32 or
    shrink the column tile; row-tiled median is future work."""
    P, V = t.shape
    tv = V if V <= _COL_TILE else _COL_TILE
    assert V % tv == 0, (V, tv)
    nv = V // tv
    dt = t.dtype
    u, nbits = key_info(dt)
    kernel = functools.partial(_ab_kernel, k=k, nv=nv, tv=tv, nbits=nbits)
    return pl.pallas_call(
        kernel,
        grid=(2, nv),
        in_specs=[
            pl.BlockSpec((P, tv), lambda ph, cv: (0, cv)),
            pl.BlockSpec((P, 1), lambda ph, cv: (0, 0)),
            pl.BlockSpec((1, tv), lambda ph, cv: (0, cv)),
            pl.BlockSpec((1, 8), lambda ph, cv: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda ph, cv: (0, 0)),
            pl.BlockSpec((1, k), lambda ph, cv: (0, 0)),
            pl.BlockSpec((1, 1), lambda ph, cv: (0, 0)),
            pl.BlockSpec((1, tv), lambda ph, cv: (0, cv)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.int32),
            jax.ShapeDtypeStruct((1, k), dt),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, V), dt),
        ],
        scratch_shapes=[
            pltpu.VMEM((P, 1), dt),
            pltpu.VMEM((1, 1), dt),
            pltpu.VMEM((1, k), u),
            pltpu.VMEM((1, k), jnp.int32),
            pltpu.VMEM((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(t, valid, top_mask, params)
