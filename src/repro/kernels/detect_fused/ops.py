"""Public fused-detection ops: dispatch, jnp fast path, launch counting.

Three ops cover the detection tail, each ONE logical launch:

* :func:`fused_non_scalable` — stacked (S, P, V) merge + slope + flag.
* :func:`fused_non_scalable_live` — the steady-state variant: merge only
  the LIVE scale's blocks, splice in the device-cached historical (4, H,
  V) merged columns, then slope + flag.  This is what makes incremental
  detect O(live scale), not O(all scales).
* :func:`fused_abnormal` — step time + masked median + flags + stable
  top-k over the (P, V) matrix (blockwise and degraded-fleet variants).

Dispatch (``interpret`` argument):

* ``None``  — compiled Pallas on TPU, else the fused-jnp fast path (one
  ``jax.jit`` executable per op; Pallas interpret mode is far slower
  than plain XLA on CPU, so it is never the default).
* ``True``  — Pallas in interpret mode (the CI parity path).
* ``False`` — compiled Pallas, forced.

The jnp fast path exists because the op chain it replaces was dispatch-
bound on CPU (~10 device calls per detect); it leans on two tricks
shared with the Pallas kernels via :mod:`.kernel`'s integer-key bridge:
XLA's single-operand *integer* sort (~13x faster than a float sort on
CPU) yields the exact masked median as two middle order statistics, and
a block tournament extracts the top-k without the 45ms stable argsort —
while reproducing the reference ranking bit-for-bit (descending score,
ties by ascending vid-major flat index).

Every op bumps ``launch_counts`` and calls the monkeypatchable
``on_launch`` hook once per logical kernel launch, so tests and benches
can ASSERT "steady-state detect = 1 non-scalable + 1 abnormal launch"
instead of inferring it from timings.
"""
from __future__ import annotations

import collections
from functools import partial
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.detect_fused.kernel import (
    _COL_TILE, _ROW_TILE, _STEP_EPS, ab_fused_kernel, abnormal_flags,
    from_key, key_info, merge_all_stack, merge_blocks, ns_fused_kernel,
    slope_share_flag, to_key)
from repro.core.detect import JIT_STRATEGIES

_IMAX = JIT_STRATEGIES.index("max")

# -- launch counting seam ----------------------------------------------
# One logical launch == one fused op call.  ``launch_counts`` accumulates
# per-op totals; ``on_launch`` (monkeypatchable) sees each launch name.
launch_counts: collections.Counter = collections.Counter()
on_launch: Optional[Callable[[str], None]] = None


def _note_launch(name: str) -> None:
    launch_counts[name] += 1
    hook = on_launch
    if hook is not None:
        hook(name)


def reset_launch_counts() -> None:
    launch_counts.clear()


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:                                  # pragma: no cover
        return False


def _mode(interpret: Optional[bool]) -> str:
    if interpret is None:
        return "pallas" if _on_tpu() else "jnp"
    return "interpret" if interpret else "pallas"


# -- fused jnp fast path ------------------------------------------------

def _topk_tournament(score: jax.Array, k: int):
    """Exact replacement for ``argsort(-flat, stable=True)[:k]`` over the
    vid-major flattening: block maxima + k extraction rounds on integer
    keys.  Ties rank by ascending flat index (argmax returns the FIRST
    max), and extracted entries drop to key 0 — strictly below every
    real score key, -inf included, so the -inf tail fills in ascending
    index order exactly like the stable argsort."""
    flat = score.T.reshape(-1)
    n = flat.shape[0]
    keys = to_key(flat)
    B = 128
    nb = -(-n // B)
    kp = jnp.pad(keys, (0, nb * B - n)).reshape(nb, B)

    def body(i, st):
        kb, order, vals = st
        j = jnp.argmax(kb.max(axis=1))
        row = kb[j]
        i2 = jnp.argmax(row)
        gidx = j.astype(jnp.int32) * B + i2.astype(jnp.int32)
        kb = kb.at[j, i2].set(jnp.array(0, kb.dtype))
        return kb, order.at[i].set(gidx), vals.at[i].set(row[i2])

    order = jnp.zeros((k,), jnp.int32)
    vals = jnp.zeros((k,), keys.dtype)
    _, order, vals = jax.lax.fori_loop(0, k, body, (kp, order, vals))
    return order, from_key(vals, score.dtype)


@partial(jax.jit, static_argnames=("k", "use_step", "use_live",
                                   "use_valid"))
def _ab_jnp(ts, live, valid, top_idx, params, *, k, use_step, use_live,
            use_valid):
    t = ts[0] if len(ts) == 1 else jnp.concatenate(ts, axis=0)
    if use_live:
        t = t[live]
    P = t.shape[0]
    if use_valid:
        vcol = valid[:, None]
        n_live = jnp.maximum(valid.sum(), 1)
        tm = jnp.where(vcol, t, 0.0)
        lo_r, hi_r = (n_live - 1) // 2, n_live // 2
        keys = to_key(jnp.where(vcol, t, jnp.inf).T)
    else:
        tm = t
        lo_r, hi_r = (P - 1) // 2, P // 2
        keys = to_key(t.T)
    if use_step:
        step = params[2]
    else:
        srow = t[:, top_idx].sum(axis=1)
        if use_valid:
            srow = jnp.where(valid, srow, 0.0)
        step = srow.max()
        step = jnp.where(step > 0.0, step, _STEP_EPS)
    srt = jax.lax.sort(keys, dimension=1, is_stable=False)
    lo = from_key(jnp.take(srt, lo_r, axis=1), t.dtype)
    hi = from_key(jnp.take(srt, hi_r, axis=1), t.dtype)
    typical = 0.5 * (lo + hi)
    flags = abnormal_flags(tm, typical, params[0], params[1], step)
    if use_valid:
        flags = flags & vcol
    score = jnp.where(flags, tm - typical, -jnp.inf)
    order, svals = _topk_tournament(score, k)
    return order, svals, flags.sum(), typical


@partial(jax.jit, static_argnames=("use_total",))
def _ns_jnp(t, var, logp, present, top_idx, params, *, use_total):
    M = merge_all_stack(t, var)
    total = params[3] if use_total else M[_IMAX, -1, top_idx].sum()
    slope, share, flagged = slope_share_flag(
        M, logp, present, total, params[0], params[1], params[2])
    return M, slope, share, flagged


@jax.jit
def _ns_live_jnp(ts, vs, hist, logp, present, top_idx, params):
    col = merge_blocks(ts, vs)
    M = jnp.concatenate([hist, col[:, None, :]], axis=1)
    total = M[_IMAX, -1, top_idx].sum()
    slope, share, flagged = slope_share_flag(
        M, logp, present, total, params[0], params[1], params[2])
    return M, slope, share, flagged


# -- padding helpers for the Pallas path -------------------------------

def _pad_cols(a: jax.Array, V: int) -> jax.Array:
    Vp = V if V <= _COL_TILE else -(-V // _COL_TILE) * _COL_TILE
    if Vp == V:
        return a
    pad = [(0, 0)] * (a.ndim - 1) + [(0, Vp - V)]
    return jnp.pad(a, pad)


def _pad_rows(a: jax.Array, P: int, axis: int) -> jax.Array:
    TP = P if P <= _ROW_TILE else _ROW_TILE
    Pp = -(-P // TP) * TP
    if Pp == P:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, Pp - P)
    return jnp.pad(a, pad)                             # zero rows = dead


def _top_mask(top_idx, V: int, dtype) -> jax.Array:
    Vp = V if V <= _COL_TILE else -(-V // _COL_TILE) * _COL_TILE
    m = jnp.zeros((1, Vp), dtype)
    if top_idx is not None and top_idx.shape[0]:
        m = m.at[0, top_idx].set(1.0)
    return m


# -- public ops ---------------------------------------------------------

def fused_abnormal(ts: Sequence[jax.Array], top_idx: Optional[jax.Array],
                   abnorm_thd: float, min_share: float, k: int, *,
                   step_time: Optional[float] = None,
                   live: Optional[jax.Array] = None,
                   valid: Optional[jax.Array] = None,
                   interpret: Optional[bool] = None
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One-launch abnormal detection over device time blocks.

    ``ts``: tuple of (n_local, V) blocks in global proc order (a single
    block for the host-stacked path).  ``top_idx``: int32 step-time
    column indices (unused when ``step_time`` is given).  ``live`` /
    ``valid``: padded live-row gather indices + real-row mask for
    degraded fleets (fixed shapes — one executable per fleet size, not
    per live count).  Returns ``(order, scores, count, typical)`` device
    arrays: flat vid-major indices and scores of the top ``k`` entries
    (reference ranking: descending ``time - typical``, stable ascending-
    index ties, -inf tail), the total flagged count, and the (V,)
    typical vector."""
    ts = tuple(ts)
    V = ts[0].shape[1]
    P = live.shape[0] if live is not None else sum(b.shape[0] for b in ts)
    dtype = ts[0].dtype
    k_eff = max(min(int(k), P * V), 0)
    if k_eff == 0:
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), dtype),
                jnp.zeros((), jnp.int32), jnp.zeros((V,), dtype))
    mode = _mode(interpret)
    _note_launch("abnormal")
    use_step = step_time is not None
    if mode == "jnp":
        params = jnp.asarray(
            [abnorm_thd, min_share, step_time if use_step else 0.0, 0.0],
            dtype)
        return _ab_jnp(
            ts,
            live if live is not None else jnp.zeros((0,), jnp.int32),
            valid if valid is not None else jnp.zeros((0,), bool),
            top_idx if top_idx is not None else jnp.zeros((0,), jnp.int32),
            params, k=k_eff, use_step=use_step, use_live=live is not None,
            use_valid=valid is not None)
    t = ts[0] if len(ts) == 1 else jnp.concatenate(ts, axis=0)
    if live is not None:
        t = t[live]
    t = _pad_cols(t, V)
    vcol = (valid.astype(dtype)[:, None] if valid is not None
            else jnp.ones((P, 1), dtype))
    params = jnp.asarray([[abnorm_thd, min_share,
                           step_time if use_step else 0.0,
                           1.0 if use_step else 0.0, 0.0, 0.0, 0.0, 0.0]],
                         dtype)
    order, scores, count, typical = ab_fused_kernel(
        t, vcol, _top_mask(top_idx, V, dtype), params, k=k_eff,
        interpret=(mode == "interpret"))
    return order[0], scores[0], count[0, 0], typical[0, :V]


def fused_non_scalable(t: jax.Array, var: jax.Array, logp: jax.Array,
                       present: jax.Array, *, ideal_slope: float,
                       slope_margin: float, min_share: float,
                       total_max: Optional[float] = None,
                       top_idx: Optional[jax.Array] = None,
                       interpret: Optional[bool] = None
                       ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                  jax.Array]:
    """One-launch non-scalable detection over the stacked (S, P, V)
    time/variance matrices.  ``total_max`` (host-derived reference step
    time) wins over the in-kernel derivation from ``top_idx``.  Returns
    (M (4, S, V), slope (4, V), share (4, V), flagged (4, V) bool)."""
    mode = _mode(interpret)
    _note_launch("non_scalable")
    dtype = t.dtype
    use_total = total_max is not None
    if mode == "jnp":
        params = jnp.asarray(
            [ideal_slope, slope_margin, min_share,
             total_max if use_total else 0.0], dtype)
        return _ns_jnp(
            t, var, logp, present,
            top_idx if top_idx is not None else jnp.zeros((0,), jnp.int32),
            params, use_total=use_total)
    S, P, V = t.shape
    tp = _pad_rows(t, P, axis=1)
    vp = _pad_rows(var, P, axis=1)
    params = jnp.asarray([[ideal_slope, slope_margin, min_share,
                           total_max if use_total else 0.0,
                           1.0 if use_total else 0.0, 0.0, 0.0, 0.0]],
                         dtype)
    M, slope, share, flagged = ns_fused_kernel(
        tp, vp, jnp.zeros((4, 1, V), dtype), logp[:, None],
        present.astype(dtype), _top_mask(top_idx, V, dtype)[:, :V],
        params, n_hist=0, interpret=(mode == "interpret"))
    return M, slope, share, flagged > 0.0


def fused_non_scalable_live(ts: Sequence[jax.Array],
                            vs: Sequence[jax.Array], hist: jax.Array,
                            logp: jax.Array, present: jax.Array,
                            top_idx: jax.Array, *, ideal_slope: float,
                            slope_margin: float, min_share: float,
                            interpret: Optional[bool] = None
                            ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                       jax.Array]:
    """Steady-state non-scalable detection: merge only the LIVE scale's
    (n_local, V) blocks, append the merged column to the device-cached
    historical (4, H, V) stack, and run the slope/share/flag tail — all
    one launch.  ``logp`` / ``present`` cover all H + 1 scales (live
    last).  Returns (M (4, H + 1, V), slope, share, flagged bool)."""
    mode = _mode(interpret)
    _note_launch("non_scalable_live")
    ts, vs = tuple(ts), tuple(vs)
    dtype = ts[0].dtype
    if mode == "jnp":
        params = jnp.asarray([ideal_slope, slope_margin, min_share, 0.0],
                             dtype)
        return _ns_live_jnp(ts, vs, hist, logp, present, top_idx, params)
    V = ts[0].shape[1]
    t = ts[0] if len(ts) == 1 else jnp.concatenate(ts, axis=0)
    v = vs[0] if len(vs) == 1 else jnp.concatenate(vs, axis=0)
    P = t.shape[0]
    n_hist = int(hist.shape[1])
    t = _pad_rows(t, P, axis=0)[None]
    v = _pad_rows(v, P, axis=0)[None]
    hist_in = hist if n_hist else jnp.zeros((4, 1, V), dtype)
    params = jnp.asarray([[ideal_slope, slope_margin, min_share,
                           0.0, 0.0, 0.0, 0.0, 0.0]], dtype)
    M, slope, share, flagged = ns_fused_kernel(
        t, v, hist_in, logp[:, None], present.astype(dtype),
        _top_mask(top_idx, V, dtype)[:, :V], params, n_hist=n_hist,
        interpret=(mode == "interpret"))
    return M, slope, share, flagged > 0.0


def merge_scale_column(ts: Sequence[jax.Array], vs: Sequence[jax.Array]
                       ) -> jax.Array:
    """One scale's blocks -> its (4, V) merged column (one launch).

    The cache-fill op: historical scales run through this once, then
    their columns stay device-resident until the underlying blocks
    change (see ``DeviceShardView.merged_column``)."""
    _note_launch("merge_column")
    return _merge_blocks_kernel(tuple(ts), tuple(vs))


@jax.jit
def _merge_blocks_kernel(ts, vs):
    return merge_blocks(ts, vs)
