"""Fused detection kernels: merge -> slope -> median -> top-k, one or
two launches, with device-cached historical-scale columns (see
``kernel.py`` for the Pallas kernels, ``ops.py`` for dispatch + the jnp
fast path + launch counting, ``ref.py`` for the numpy oracle)."""
from repro.kernels.detect_fused.ops import (
    fused_abnormal, fused_non_scalable, fused_non_scalable_live,
    launch_counts, merge_scale_column, reset_launch_counts)

__all__ = [
    "fused_abnormal", "fused_non_scalable", "fused_non_scalable_live",
    "launch_counts", "merge_scale_column", "reset_launch_counts",
]
