"""Pure-numpy oracle for the fused detection kernels.

Independent re-derivation of the detection math from
``repro.core.detect``'s numpy path, in the exact shapes the fused
kernels consume, so the parity tests pin three implementations against
each other: this reference, the legacy stacked-jnp kernels in
``repro.core.detect_jax``, and the fused kernels (both the jnp fast
path and the Pallas interpret mode).

Everything here is host numpy and float64 unless the caller passes
other dtypes; nothing imports jax.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.detect import JIT_STRATEGIES, VAR_EPS


def merge_all_ref(t: np.ndarray, var: np.ndarray) -> np.ndarray:
    """(S, P, V) times + variances -> (4, S, V) merged stack.

    Rows ordered as ``JIT_STRATEGIES``; non-positive readings are dead
    (excluded from every merge, exactly like the numpy detect path)."""
    pos = t > 0.0
    cnt = pos.sum(axis=1)
    any_pos = cnt > 0
    total = np.where(pos, t, 0.0).sum(axis=1)
    mean = np.where(any_pos, total / np.maximum(cnt, 1), 0.0)
    mx = np.where(any_pos, t.max(axis=1), 0.0)
    p0 = t[:, 0, :]
    p0 = np.where(p0 > 0.0, p0, mean)
    w = np.where(pos, 1.0 / (var + VAR_EPS), 0.0)
    wsum = w.sum(axis=1)
    varm = np.where(wsum > 0,
                    (w * t).sum(axis=1) / np.where(wsum > 0, wsum, 1.0),
                    0.0)
    return np.stack([mean, mx, p0, varm])


def slope_share_flag_ref(M: np.ndarray, logp: np.ndarray,
                         present: np.ndarray, total_max: float,
                         ideal_slope: float, slope_margin: float,
                         min_share: float
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(4, S, V) merged stack -> (slope, share, flagged), each (4, V).

    ``share`` is guarded: a non-positive ``total_max`` (all-dead final
    scale) yields share 0 and flags nothing."""
    valid = (M > 0.0) & present[None]
    x = logp[None, :, None]
    Y = np.where(valid, np.log(np.where(valid, M, 1.0)), 0.0)
    n = valid.sum(axis=1)
    Sx = (x * valid).sum(axis=1)
    Sy = Y.sum(axis=1)
    Sxx = (x * x * valid).sum(axis=1)
    Sxy = (x * Y).sum(axis=1)
    denom = n * Sxx - Sx ** 2
    num = n * Sxy - Sx * Sy
    slope = np.where((denom != 0) & (n >= 2),
                     num / np.where(denom != 0, denom, 1.0), 0.0)
    share = np.where(total_max > 0.0,
                     M[:, -1, :] / np.where(total_max > 0.0, total_max, 1.0),
                     0.0)
    flagged = ((M.sum(axis=1) > 0.0)
               & (slope - ideal_slope > slope_margin)
               & (share >= min_share))
    return slope, share, flagged


def non_scalable_ref(scales: Sequence[int], t: np.ndarray, var: np.ndarray,
                     present: np.ndarray, ideal_slope: float,
                     slope_margin: float, min_share: float,
                     total_max: Optional[float] = None,
                     top: Optional[Sequence[int]] = None
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray]:
    """Full fused non-scalable reference over a stacked (S, P, V) input.

    ``total_max`` defaults to the kernel-internal derivation: the "max"
    merge row at the final scale summed over the ``top`` columns."""
    M = merge_all_ref(t, var)
    if total_max is None:
        top = [] if top is None else list(top)
        total_max = float(M[JIT_STRATEGIES.index("max"), -1, top].sum())
    logp = np.log(np.asarray(scales, t.dtype))
    slope, share, flagged = slope_share_flag_ref(
        M, logp, present, total_max, ideal_slope, slope_margin, min_share)
    return M, slope, share, flagged


def abnormal_ref(t: np.ndarray, top: Sequence[int], abnorm_thd: float,
                 min_share: float, k: int,
                 valid: Optional[np.ndarray] = None,
                 step_time: Optional[float] = None
                 ) -> Tuple[np.ndarray, np.ndarray, int, np.ndarray]:
    """Abnormal-detection reference: (order, scores, count, typical).

    ``t`` is the (P, V) time matrix (already live-gathered and padded on
    the degraded path); ``valid`` marks real rows (None = all live).
    ``order`` are flat vid-major indices (``vid * P + proc``) of the top
    ``k`` scoring entries, ranked by descending ``time - typical`` with
    stable ascending-index ties — exactly the legacy kernel contract.
    """
    P, V = t.shape
    if valid is None:
        valid = np.ones(P, bool)
    vcol = valid[:, None]
    n_live = max(int(valid.sum()), 1)
    tm = np.where(vcol, t, 0.0)
    if step_time is None:
        step_time = float(np.where(valid, tm[:, list(top)].sum(axis=1),
                                   0.0).max()) if P else 0.0
        step_time = step_time if step_time > 0.0 else 1e-12
    srt = np.sort(np.where(vcol, t, np.inf), axis=0)
    lo = srt[(n_live - 1) // 2]
    hi = srt[n_live // 2]
    typical = 0.5 * (lo + hi)
    active = tm.max(axis=0) > 0.0
    over = ((typical > 0.0) & (tm > abnorm_thd * typical)
            & ((tm - typical) / step_time >= min_share))
    dead_typical = (typical == 0.0) & (tm / step_time >= min_share)
    flags = (over | dead_typical) & active & vcol
    score = np.where(flags, tm - typical, -np.inf)
    flat = score.T.reshape(-1)
    order = np.argsort(-flat, kind="stable")[:k]
    return order, flat[order], int(flags.sum()), typical
