"""Cross-run PSG alignment by stable structural signatures.

Two runs of the same job rarely have identical graphs: a refactor
renames a vertex, a new fusion adds a subtree, the tracer visits loops
in a different order.  Diffing per-vertex data across runs therefore
needs an explicit vertex correspondence — and it must NOT be positional
(vid i in run A is not vid i in run B once anything drifted).

A vertex's signature is ``(structural key, occurrence rank)``:

* the **structural key** is the (kind, name) path from the root to the
  vertex along parent links — the program's nesting structure, which
  survives vid renumbering and insertion-order permutation outright;
* the **occurrence rank** disambiguates true duplicates (two identical
  ``Comp matmul`` children of the same loop): the i-th occurrence in
  program (insertion) order on one side matches the i-th on the other.

A renamed vertex changes its key, so it lands in the explicit
``a_only``/``b_only`` sets instead of silently matching something else;
the same applies to added/removed subtrees.  Alignment is a property of
the PSGs alone — runs recorded at different process counts align
exactly the same way.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.graph import PSG

Signature = Tuple[Tuple[Tuple[str, str], ...], int]


def vertex_signatures(psg: PSG) -> List[Signature]:
    """Per-vid stable signatures: ((kind, name) root path, occurrence).

    O(V) via memoized parent-chain walk; robust to permuted insertion
    order because the key depends only on the parent chain, and
    occurrence ranks are assigned in vid order (program order).
    """
    memo: Dict[int, Tuple[Tuple[str, str], ...]] = {}

    def key_of(vid: int) -> Tuple[Tuple[str, str], ...]:
        k = memo.get(vid)
        if k is None:
            v = psg.vertices[vid]
            above = key_of(v.parent) if v.parent >= 0 else ()
            k = memo[vid] = above + ((v.kind, v.name),)
        return k

    seen: Dict[Tuple, int] = {}
    sigs: List[Signature] = []
    for v in psg.vertices:
        k = key_of(v.vid)
        rank = seen.get(k, 0)
        seen[k] = rank + 1
        sigs.append((k, rank))
    return sigs


@dataclasses.dataclass
class Alignment:
    """Vertex correspondence between two PSGs.

    ``pairs`` lists matched ``(a_vid, b_vid)``; ``a_to_b`` is the (V_a,)
    lookup with -1 where unmatched.  ``a_only``/``b_only`` are the
    explicit removed/added vertex sets — nothing matches silently.
    """
    pairs: List[Tuple[int, int]]
    a_to_b: np.ndarray
    a_only: List[int]
    b_only: List[int]

    @property
    def n_matched(self) -> int:
        return len(self.pairs)

    def __repr__(self) -> str:
        return (f"Alignment({self.n_matched} matched, "
                f"{len(self.a_only)} removed, {len(self.b_only)} added)")


def align_psgs(a: PSG, b: PSG) -> Alignment:
    """Match vertices of ``a`` and ``b`` by structural signature.

    Signatures are unique per graph by construction (occurrence ranks),
    so the match is a plain dict join: same signature -> matched pair,
    anything else -> ``a_only`` (in ``a``, gone from ``b``) or
    ``b_only`` (new in ``b``)."""
    sig_a = vertex_signatures(a)
    sig_b = vertex_signatures(b)
    index_b = {sig: vid for vid, sig in enumerate(sig_b)}
    pairs: List[Tuple[int, int]] = []
    a_only: List[int] = []
    a_to_b = np.full(len(sig_a), -1, np.int64)
    matched_b = set()
    for vid, sig in enumerate(sig_a):
        bv = index_b.get(sig)
        if bv is None:
            a_only.append(vid)
        else:
            pairs.append((vid, bv))
            a_to_b[vid] = bv
            matched_b.add(bv)
    b_only = [vid for vid in range(len(sig_b)) if vid not in matched_b]
    return Alignment(pairs=pairs, a_to_b=a_to_b, a_only=a_only,
                     b_only=b_only)
