"""Cross-run regression diff: per-vertex scaling-curve deltas + flags.

``diff_runs(base, cand)`` aligns the two runs' PSGs
(:func:`repro.runs.align.align_psgs`), then compares each matched
vertex's scaling curve:

* **ratio** — candidate vs base merged time at the comparison scale
  (the largest scale both runs recorded; falls back to each run's own
  top scale when their scale sets are disjoint, e.g. a run recorded at
  a different proc count);
* **slope delta** — candidate minus base log-log scaling slope, fitted
  with the SAME batched least-squares machinery detection uses
  (``detect.fit_slopes``; the jax twin engages through
  ``detect._resolve_backend``, exactly like ``detect_non_scalable``);
* **regression flag** — time ratio above ``ratio_thd`` or slope
  degradation above ``slope_margin``, gated on a minimum share of the
  candidate step time so noise vertices cannot flood the report.

Unmatched vertices are reported as added/removed, never diffed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.detect import _merge_matrix, _resolve_backend, fit_slopes
from repro.core.graph import PPG
from repro.runs.align import Alignment, align_psgs
from repro.runs.store import RunRecord


def scaling_curves(series: Mapping[int, PPG], *, strategy: str = "mean"
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """(scales (S,), M (S, V)): merged per-vertex times across a
    ``{n_procs: PPG}`` series — the curve block a run records.

    Columns are padded to the widest graph in the series; absent
    vertices merge to 0.0, which the slope fit treats as invalid."""
    scales = np.asarray(sorted(series), np.int64)
    V = max(len(series[int(s)].psg.vertices) for s in scales)
    M = np.zeros((scales.size, V))
    for i, s in enumerate(scales.tolist()):
        ppg = series[s]
        row = _merge_matrix(np.asarray(ppg.times_matrix(), float), strategy,
                            np.asarray(ppg.var_matrix(), float))
        M[i, :row.size] = row
    return scales, M


@dataclasses.dataclass
class VertexDelta:
    """One matched vertex's cross-run comparison."""
    vid_base: int
    vid_cand: int
    kind: str
    name: str
    source: str
    base_time: float             # merged time at the comparison scale
    cand_time: float
    ratio: float                 # cand / base (inf when base was 0)
    share: float                 # of the candidate run's step time
    base_slope: float            # log-log scaling slope (0 when < 2 pts)
    cand_slope: float
    slope_delta: float           # cand - base (positive = scales worse)
    base_peak: float             # slowest stored row (per-proc outlier)
    cand_peak: float
    peak_ratio: float            # cand_peak / base_peak (0 when unused)
    regressed: bool
    score: float                 # ranking key: excess time x share

    def describe(self) -> str:
        tag = f"{self.kind} {self.name}"
        if self.source:
            tag += f" @ {self.source}"
        peak = f", peak x{self.peak_ratio:.2f}" if self.peak_ratio else ""
        return (f"{tag}: {self.base_time:.3e}s -> {self.cand_time:.3e}s "
                f"(x{self.ratio:.2f}, slope {self.base_slope:+.2f} -> "
                f"{self.cand_slope:+.2f}{peak}, share {self.share:.1%})")


@dataclasses.dataclass
class RunDiff:
    """The full cross-run comparison ``diff_runs`` returns."""
    base_id: str
    cand_id: str
    alignment: Alignment
    deltas: List[VertexDelta]            # every matched vertex with data
    regressions: List[VertexDelta]       # flagged, sorted by score desc
    removed: List[str]                   # vertices only in base
    added: List[str]                     # vertices only in cand
    base_scale: int                      # comparison scales per side
    cand_scale: int
    backend: str                         # slope-fit backend used

    @property
    def regressed_vids(self) -> List[int]:
        """Candidate-side vids of the flagged regressions, best first."""
        return [d.vid_cand for d in self.regressions]

    def __repr__(self) -> str:
        return (f"RunDiff({self.base_id} -> {self.cand_id}: "
                f"{len(self.regressions)} regressed of "
                f"{len(self.deltas)} matched, +{len(self.added)} "
                f"-{len(self.removed)})")


def _curves(rec: RunRecord) -> Tuple[np.ndarray, np.ndarray]:
    """A record's (scales, (S, V) curve matrix), derived from the PPG
    when the run recorded no explicit series (single-scale run)."""
    if rec.curves is not None and rec.scales is not None:
        return np.asarray(rec.scales, np.int64), np.asarray(rec.curves, float)
    if rec.ppg is None:
        raise ValueError(f"run {rec.run_id!r} has neither curves nor a PPG")
    t = np.asarray(rec.ppg.times_matrix(), float)
    if rec.clustering is not None:
        # representative rows stand for whole clusters: weight by size
        w = rec.clustering.counts.astype(float)[:, None]
        pos = t > 0.0
        wsum = (w * pos).sum(axis=0)
        row = np.divide((w * t).sum(axis=0, where=pos), wsum,
                        out=np.zeros(t.shape[1]), where=wsum > 0)
        n_procs = int(rec.clustering.n_procs)
    else:
        row = _merge_matrix(t, "mean", np.asarray(rec.ppg.var_matrix(),
                                                  float))
        n_procs = int(rec.ppg.n_procs)
    return np.asarray([n_procs], np.int64), row[None]


def _peak_row(rec: RunRecord) -> Optional[np.ndarray]:
    """Per-vertex max over the record's stored rows — the slowest
    process (or cluster representative) at each vertex.  A fault on 64
    of 65536 procs moves the mean by 0.1% but the peak by its full
    magnitude, so cross-run peak ratios catch abnormal-channel
    regressions the merged curve dilutes away."""
    if rec.ppg is None:
        return None
    return np.asarray(rec.ppg.times_matrix(), float).max(axis=0)


def _total_step_time(rec: RunRecord, curve_row: np.ndarray) -> float:
    """Step time for share normalization: the curve summed over the
    root's top-level vertices (children don't double-count parents)."""
    psg = rec.psg
    tops = [vid for vid in psg.children(psg.root)
            if vid < curve_row.size]
    total = float(curve_row[tops].sum()) if tops else float(curve_row.sum())
    return total if total > 0.0 else float(curve_row.sum())


def diff_runs(base: RunRecord, cand: RunRecord, *,
              ratio_thd: float = 1.25,
              slope_margin: float = 0.25,
              peak_thd: Optional[float] = None,
              min_share: float = 0.01,
              top_k: int = 0,
              backend: Optional[str] = None) -> RunDiff:
    """Compare two stored runs; see module docstring.

    ``peak_thd`` flags on the slowest-row ratio (see :func:`_peak_row`;
    catches few-proc faults a merged curve averages away); it defaults
    to ``ratio_thd`` and only applies when both runs were recorded at
    the same scale with a stored PPG.
    ``top_k`` > 0 truncates the flagged regression list; 0 keeps all.
    ``backend`` routes the slope fits exactly like detection's knob
    ("numpy" / "jax" / "auto" / None -> SCALANA_DETECT_BACKEND)."""
    if base.psg is None or cand.psg is None:
        raise ValueError("both runs need a stored PSG to diff")
    alignment = align_psgs(base.psg, cand.psg)
    scales_a, M_a = _curves(base)
    scales_b, M_b = _curves(cand)

    jx = _resolve_backend(backend)
    fit = fit_slopes if jx is None else jx.fit_slopes
    backend_name = "numpy" if jx is None else "jax"
    slopes_a = (fit(scales_a, M_a, M_a > 0.0) if scales_a.size >= 2
                else np.zeros(M_a.shape[1]))
    slopes_b = (fit(scales_b, M_b, M_b > 0.0) if scales_b.size >= 2
                else np.zeros(M_b.shape[1]))

    # comparison scale: largest scale recorded by BOTH; if the runs share
    # none (different proc counts), compare each at its own top scale
    shared = np.intersect1d(scales_a, scales_b)
    if shared.size:
        ia = int(np.nonzero(scales_a == shared[-1])[0][0])
        ib = int(np.nonzero(scales_b == shared[-1])[0][0])
    else:
        ia, ib = scales_a.size - 1, scales_b.size - 1
    row_a, row_b = M_a[ia], M_b[ib]
    total_b = _total_step_time(cand, row_b)
    multi = scales_a.size >= 2 and scales_b.size >= 2
    peaks_a, peaks_b = _peak_row(base), _peak_row(cand)
    use_peaks = (peaks_a is not None and peaks_b is not None
                 and base.scale == cand.scale)
    pthd = ratio_thd if peak_thd is None else peak_thd

    deltas: List[VertexDelta] = []
    for va, vb in alignment.pairs:
        ta = float(row_a[va]) if va < row_a.size else 0.0
        tb = float(row_b[vb]) if vb < row_b.size else 0.0
        if ta <= 0.0 and tb <= 0.0:
            continue
        ratio = tb / ta if ta > 0.0 else float("inf")
        share = tb / total_b
        sa = float(slopes_a[va]) if va < slopes_a.size else 0.0
        sb = float(slopes_b[vb]) if vb < slopes_b.size else 0.0
        slope_delta = sb - sa
        pa = float(peaks_a[va]) if use_peaks and va < peaks_a.size else 0.0
        pb = float(peaks_b[vb]) if use_peaks and vb < peaks_b.size else 0.0
        peak_ratio = (pb / pa if pa > 0.0
                      else (float("inf") if pb > 0.0 else 0.0)) \
            if use_peaks else 0.0
        regressed = share >= min_share and (
            ratio >= ratio_thd
            or (multi and slope_delta >= slope_margin)
            or (use_peaks and peak_ratio >= pthd))
        v = cand.psg.vertices[vb]
        deltas.append(VertexDelta(
            vid_base=va, vid_cand=vb, kind=v.kind, name=v.name,
            source=v.source, base_time=ta, cand_time=tb, ratio=ratio,
            share=share, base_slope=sa, cand_slope=sb,
            slope_delta=slope_delta, base_peak=pa, cand_peak=pb,
            peak_ratio=peak_ratio, regressed=regressed,
            score=max(tb - ta, pb - pa, 0.0) * share))
    regressions = sorted((d for d in deltas if d.regressed),
                         key=lambda d: -d.score)
    if top_k > 0:
        regressions = regressions[:top_k]
    name_of = lambda psg, vid: (f"{psg.vertices[vid].kind} "
                                f"{psg.vertices[vid].name}")
    return RunDiff(
        base_id=base.run_id, cand_id=cand.run_id, alignment=alignment,
        deltas=deltas, regressions=regressions,
        removed=[name_of(base.psg, v) for v in alignment.a_only],
        added=[name_of(cand.psg, v) for v in alignment.b_only],
        base_scale=int(scales_a[ia]), cand_scale=int(scales_b[ib]),
        backend=backend_name)
