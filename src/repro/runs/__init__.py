"""Multi-run regression store: persist, align, diff, cluster, report.

The fleet question behind this package is "why did today's run get
slower than yesterday's, and which of my processes behave differently" —
one-shot detection (detect/backtrack over a single PPG) answers neither.
The pieces:

* :class:`~repro.runs.store.RunStore` — persists (PSG, perf store,
  comm index, detect output, scaling curves, metadata) per run through
  the ``to_tree``/``from_tree`` seam and ``repro.checkpoint.store`` —
  the SAME persistence path the monitor's crash snapshots use.
* :func:`~repro.runs.align.align_psgs` — matches vertices across runs
  whose graphs drifted, by stable (kind, name, path-from-root)
  signatures with explicit added/removed sets — never positionally.
* :func:`~repro.runs.diff.diff_runs` — per-vertex scaling-curve deltas
  and regression flags, reusing the detect slope machinery (numpy and
  jax backends behind ``detect._resolve_backend``).
* :func:`~repro.runs.cluster.cluster_procs` — groups processes by
  behavior vector (per-vertex time + counter signature) so an 8k–64k
  proc run stores and diffs as K representatives + a membership map.
* :func:`~repro.runs.report.render_regression_report` — names the top
  regressed vertices and the regressed cluster, and backtracks the
  regressed representative through the existing ``backtrack`` path.

Everything here is jax-free at import; the jax detect backend engages
only through ``diff_runs(backend=...)``.
"""
from repro.runs.align import Alignment, align_psgs, vertex_signatures
from repro.runs.cluster import (Clustering, behavior_matrix, cluster_procs,
                                representative_ppg)
from repro.runs.diff import RunDiff, VertexDelta, diff_runs, scaling_curves
from repro.runs.report import regressed_cluster, render_regression_report
from repro.runs.store import (RUN_SCHEMA_VERSION, RunRecord, RunStore,
                              run_metadata)

__all__ = [
    "Alignment", "align_psgs", "vertex_signatures",
    "Clustering", "behavior_matrix", "cluster_procs", "representative_ppg",
    "RunDiff", "VertexDelta", "diff_runs", "scaling_curves",
    "regressed_cluster", "render_regression_report",
    "RUN_SCHEMA_VERSION", "RunRecord", "RunStore", "run_metadata",
]
