"""Behavior clustering: compress P processes into K representatives.

The SPMD observation (Liu & Zhan's automatic-debugging line): processes
of a data-parallel job fall into a handful of behavior classes, so a
64k-proc run can be stored and diffed as K representative rows plus a
membership map.  A process's behavior vector is its per-vertex time row
concatenated with its column-sparse counter signature (``wait_s`` at the
Comm vertices) — exactly the data the detectors consume, so two procs
with the same vector are indistinguishable to detection.

Clustering is deterministic greedy k-centers (farthest-point
traversal): the first center is proc 0, each next center is the proc
farthest from every existing center, until either ``max_clusters``
centers exist or the farthest distance drops under ``tol`` times the
data scale.  Deterministic, O(P · K · F), no RNG — the same store
always clusters identically, which the run store's reproducibility
relies on.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Tuple

import numpy as np

from repro.core.graph import PPG, check_tree_format


def behavior_matrix(ppg: PPG, *, normalize: bool = False) -> np.ndarray:
    """(P, F) behavior vectors: per-vertex times + counter columns.

    Counter blocks stay column-sparse (k written columns each, not V),
    so F = V + sum_k — the vector is exactly the data detection sees.

    ``normalize`` scales each feature BLOCK by a max-abs so blocks are
    comparable: counters run many orders of magnitude hotter than
    seconds (flops ~1e9 vs times ~1e-2), so raw distances cluster on
    counter magnitude while a 2x time skew vanishes.  Blocks measured
    in SECONDS (the times block and ``*_s`` counters like ``wait_s``)
    share ONE common scale — a clean run's ~1e-5 s scheduling residue
    in ``wait_s`` must stay negligible next to ~1e-2 s step times, not
    get blown up to full spread by its own tiny block max.  Unit-less
    counter blocks are scaled by their own max (relative imbalance is
    the signal there)."""
    perf = ppg.perf
    times = np.asarray(ppg.times_matrix(), float)
    feats = [times]
    seconds = [True]
    for name in sorted(perf.counter_names()):
        vids, values, mask = perf.counter_columns(name)
        if vids.size:
            feats.append(np.where(mask, values, 0.0))
            seconds.append(name.endswith("_s"))
    if normalize:
        sec_max = max((float(np.abs(f).max())
                       for f, s in zip(feats, seconds) if s), default=0.0)
        out = []
        for f, s in zip(feats, seconds):
            m = sec_max if s else float(np.abs(f).max())
            out.append(f / m if m > 0.0 else f)
        feats = out
    return np.hstack(feats)


@dataclasses.dataclass
class Clustering:
    """K behavior clusters over P processes.

    ``membership[p]`` is the cluster of proc p; ``rep_procs[k]`` the
    global proc id of cluster k's representative (its center — an
    actual process, never an average); ``counts[k]`` the member count.
    ``rep_procs`` is sorted ascending so a representative sub-PPG built
    from it (:func:`representative_ppg`) has row r = rep of cluster r.
    """
    membership: np.ndarray           # (P,) int64
    rep_procs: np.ndarray            # (K,) int64, sorted
    counts: np.ndarray               # (K,) int64
    max_center_dist: float           # farthest member-to-center distance

    @property
    def n_procs(self) -> int:
        return int(self.membership.size)

    @property
    def n_clusters(self) -> int:
        return int(self.rep_procs.size)

    def members(self, k: int) -> np.ndarray:
        return np.nonzero(self.membership == k)[0]

    def compression(self) -> float:
        """Row-compression factor: P stored rows become K."""
        return self.n_procs / max(self.n_clusters, 1)

    def __repr__(self) -> str:
        return (f"Clustering({self.n_procs} procs -> {self.n_clusters} "
                f"clusters, max dist {self.max_center_dist:.3g})")

    # -- checkpoint-tree seam ------------------------------------------
    def to_tree(self) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        tree = {"membership": self.membership.copy(),
                "rep_procs": self.rep_procs.copy(),
                "counts": self.counts.copy()}
        meta = {"format": "clustering", "version": 1,
                "max_center_dist": float(self.max_center_dist)}
        return tree, meta

    @classmethod
    def from_tree(cls, tree: Mapping[str, Any],
                  meta: Optional[Mapping[str, Any]] = None) -> "Clustering":
        check_tree_format(meta, "clustering", 1)
        return cls(membership=np.asarray(tree["membership"], np.int64),
                   rep_procs=np.asarray(tree["rep_procs"], np.int64),
                   counts=np.asarray(tree["counts"], np.int64),
                   max_center_dist=float((meta or {}).get(
                       "max_center_dist", 0.0)))


def cluster_procs(ppg: PPG, *, max_clusters: int = 64,
                  tol: float = 0.01) -> Clustering:
    """Group processes by behavior vector; see module docstring.

    ``tol`` is relative: center selection stops early once the farthest
    proc sits within ``tol * max_row_norm`` of an existing center (all
    procs behaviorally identical -> 1 cluster, not ``max_clusters``).
    """
    if max_clusters < 1:
        raise ValueError(f"max_clusters must be positive: {max_clusters}")
    X = behavior_matrix(ppg, normalize=True)
    P = X.shape[0]
    norms = np.linalg.norm(X, axis=1)
    stop = float(tol) * float(norms.max(initial=0.0))

    def dist_to(p: int) -> np.ndarray:
        d = X - X[p]
        return np.sqrt(np.einsum("ij,ij->i", d, d))

    centers = [0]
    dmin = dist_to(0)
    nearest = np.zeros(P, np.int64)
    while len(centers) < min(max_clusters, P):
        far = int(np.argmax(dmin))
        if dmin[far] <= stop:
            break
        k = len(centers)
        centers.append(far)
        d = dist_to(far)
        closer = d < dmin
        nearest[closer] = k
        dmin = np.where(closer, d, dmin)
    # sort centers by proc id so representative-PPG row order is stable
    order = np.argsort(np.asarray(centers))
    relabel = np.empty(len(centers), np.int64)
    relabel[order] = np.arange(len(centers))
    membership = relabel[nearest]
    rep_procs = np.asarray(centers, np.int64)[order]
    counts = np.bincount(membership, minlength=rep_procs.size).astype(np.int64)
    return Clustering(membership=membership, rep_procs=rep_procs,
                      counts=counts, max_center_dist=float(dmin.max()))


def representative_ppg(ppg: PPG, clustering: Clustering) -> PPG:
    """The K-representative sub-PPG: row k is cluster k's center.

    Reuses the degraded-fleet compaction
    (:func:`repro.monitor.degraded.live_subppg`): perf rows extracted
    through the RowBlock seam, collective groups intersected with the
    representative set, p2p edges remapped — so backtracking the
    representative graph walks real comm structure, not a stub."""
    from repro.monitor.degraded import live_subppg
    return live_subppg(ppg, clustering.rep_procs)
