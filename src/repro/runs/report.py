"""Regression report: name the vertices, the cluster, and the path.

Turns a :class:`~repro.runs.diff.RunDiff` into the text a fleet
operator reads after "today got slower":

1. the top regressed vertices (ranked by excess time x share),
2. the **regressed cluster** — which behavior class of processes the
   regression lives in, when the candidate run was recorded clustered,
3. a root-cause walk from the regressed representative through the
   EXISTING :func:`repro.core.backtrack.backtrack` — the representative
   sub-PPG carries real comm structure (collective groups intersected,
   p2p remapped), so the walk crosses dependence edges exactly like a
   one-shot diagnosis would.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.backtrack import backtrack
from repro.core.detect import Abnormal
from repro.core.report import _fmt_node
from repro.runs.diff import RunDiff, VertexDelta
from repro.runs.store import RunRecord


def regressed_cluster(cand: RunRecord, diff: RunDiff, *,
                      rank: int = 0) -> Optional[int]:
    """Cluster id carrying the ``rank``-th flagged regression.

    The candidate's stored rows are cluster representatives; the
    regressed cluster is the one whose representative is slowest —
    relative to the base run's merged time — at the flagged vertex.
    Returns None when the run was not clustered or nothing regressed."""
    if cand.clustering is None or cand.ppg is None:
        return None
    if rank >= len(diff.regressions):
        return None
    d = diff.regressions[rank]
    row, _ = _worst_row(cand, d)
    return row


def _worst_row(cand: RunRecord, d: VertexDelta) -> Tuple[int, float]:
    """(row, time) of the stored row slowest at the flagged vertex."""
    t = np.asarray(cand.ppg.times_matrix(), float)[:, d.vid_cand]
    row = int(np.argmax(t))
    return row, float(t[row])


def _cluster_lines(cand: RunRecord, diff: RunDiff) -> List[str]:
    cl = cand.clustering
    k = regressed_cluster(cand, diff)
    if cl is None or k is None:
        return []
    members = cl.members(k)
    sample = ", ".join(f"p{p}" for p in members[:8].tolist())
    if members.size > 8:
        sample += f", … and {members.size - 8} more"
    return [
        "## Regressed cluster",
        f"  cluster {k} of {cl.n_clusters} "
        f"(representative p{int(cl.rep_procs[k])}, "
        f"{members.size}/{cl.n_procs} procs, "
        f"{cl.compression():.0f}x row compression)",
        f"  members: {sample}",
        "",
    ]


def _backtrack_lines(cand: RunRecord, diff: RunDiff, *,
                     max_paths: int) -> List[str]:
    """Root-cause walks from the worst stored row of each flagged
    vertex, as synthetic abnormal starts over the candidate PPG."""
    ppg = cand.ppg
    t = np.asarray(ppg.times_matrix(), float)
    starts: List[Abnormal] = []
    for d in diff.regressions[:max_paths]:
        col = t[:, d.vid_cand]
        row = int(np.argmax(col))
        pos = col[col > 0.0]
        typical = float(np.median(pos)) if pos.size else 0.0
        v = ppg.psg.vertices[d.vid_cand]
        starts.append(Abnormal(
            vid=d.vid_cand, proc=row, time=float(col[row]),
            typical=typical,
            ratio=float(col[row]) / typical if typical > 0 else float("inf"),
            kind=v.kind, name=v.name, source=v.source))
    if not starts:
        return []
    cl = cand.clustering
    label = (lambda r: int(cl.rep_procs[r])) if cl is not None \
        else (lambda r: r)
    lines = ["## Root-cause walk (from regressed representatives)"]
    for i, p in enumerate(backtrack(ppg, [], starts)):
        lines.append(f"  path {i} [{p.start_reason}]:")
        for proc, vid in p.nodes:
            lines.append(f"    <- {_fmt_node(ppg.psg, (label(proc), vid))}")
    lines.append("")
    return lines


def render_regression_report(base: RunRecord, cand: RunRecord,
                             diff: RunDiff, *, top_k: int = 10,
                             max_paths: int = 3,
                             title: str = "Cross-run regression report"
                             ) -> str:
    """Text regression report; see module docstring."""
    lines: List[str] = [title, "=" * len(title), ""]
    meta_bits = []
    for tag, rec in (("base", base), ("cand", cand)):
        commit = str(rec.meta.get("commit", ""))[:12]
        bit = f"{tag} {rec.run_id} (scale {rec.scale}"
        if commit:
            bit += f", commit {commit}"
        meta_bits.append(bit + ")")
    lines.append("  ".join(meta_bits))
    lines.append(f"compared at {diff.base_scale} -> {diff.cand_scale} procs"
                 f"   slope backend: {diff.backend}")
    lines.append("")

    if diff.added or diff.removed:
        lines.append("## Graph drift")
        for name in diff.added:
            lines.append(f"  + {name}")
        for name in diff.removed:
            lines.append(f"  - {name}")
        lines.append("")

    lines.append(f"## Regressed vertices "
                 f"({len(diff.regressions)} of {len(diff.deltas)} matched)")
    if not diff.regressions:
        lines.append("  (none)")
    for d in diff.regressions[:top_k]:
        lines.append(f"  - {d.describe()}")
    if len(diff.regressions) > top_k:
        lines.append(f"  … and {len(diff.regressions) - top_k} more")
    lines.append("")

    lines.extend(_cluster_lines(cand, diff))
    if diff.regressions and cand.ppg is not None:
        lines.extend(_backtrack_lines(cand, diff, max_paths=max_paths))
    return "\n".join(lines).rstrip() + "\n"
