"""Persistent run store: one directory per run, ONE persistence path.

A run is recorded as a checkpoint directory under the store root,
written through :mod:`repro.checkpoint.store` — the exact atomic
npz-plus-manifest machinery the monitor's crash snapshots use.  The
payload is the object's own ``to_tree``/``from_tree`` seam (PPG -> PSG
+ perf store + comm index), so anything the monitor can snapshot the
run store can persist, bit for bit.

What a run holds:

* the **PPG** (full, or K representative rows + a
  :class:`~repro.runs.cluster.Clustering` when recorded with
  ``cluster=K``),
* optional **scaling curves** — the (S, V) merged-time matrix across a
  ``{n_procs: PPG}`` series, which is what ``diff_runs`` fits slopes on,
* the **detect output** (NonScalable/Abnormal lists, JSON in the
  manifest),
* **metadata**: scale, git commit, wall time, schema version, plus
  anything the caller adds.

Run ids are zero-padded sequence numbers (``run_000003``) unless the
caller names the run; ``runs()`` lists them in recording order.
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import time
from typing import Any, Dict, List, Mapping, Optional

import numpy as np

from repro.checkpoint.store import load_checkpoint_tree, save_checkpoint
from repro.core.detect import Abnormal, NonScalable
from repro.core.graph import PPG, PSG
from repro.runs.cluster import Clustering, cluster_procs, representative_ppg

RUN_SCHEMA_VERSION = 1

_RUN_PREFIX = "run_"


def git_commit(cwd: Optional[str] = None) -> str:
    """Current git commit hash, or "" when not in a repo / no git."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, capture_output=True,
            text=True, timeout=10)
        return out.stdout.strip() if out.returncode == 0 else ""
    except (OSError, subprocess.SubprocessError):
        return ""


def run_metadata(**extra: Any) -> Dict[str, Any]:
    """Standard run stamp: schema version, commit, wall time.

    The same stamp ``benchmarks/run.py`` writes into BENCH JSON lines,
    so bench payloads are ingestible as run metadata without mapping."""
    meta: Dict[str, Any] = {
        "schema_version": RUN_SCHEMA_VERSION,
        "commit": git_commit(),
        "wall_time": time.time(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S", time.gmtime()),
    }
    meta.update(extra)
    return meta


def _detect_to_json(detect: Mapping[str, Any]) -> Dict[str, Any]:
    """Detect output -> JSON-safe dict (int ``times`` keys -> pairs)."""
    out: Dict[str, Any] = {}
    for key, items in detect.items():
        rows = []
        for it in items:
            d = dataclasses.asdict(it) if dataclasses.is_dataclass(it) \
                else dict(it)
            if isinstance(d.get("times"), dict):
                d["times"] = [[int(s), float(t)]
                              for s, t in sorted(d["times"].items())]
            rows.append(d)
        out[str(key)] = rows
    return out


_DETECT_CLS = {"non_scalable": NonScalable, "abnormal": Abnormal}


def _detect_from_json(obj: Mapping[str, Any]) -> Dict[str, List[Any]]:
    """Inverse of :func:`_detect_to_json`: rebuild the dataclasses."""
    out: Dict[str, List[Any]] = {}
    for key, rows in obj.items():
        cls = _DETECT_CLS.get(key)
        items: List[Any] = []
        for d in rows:
            d = dict(d)
            if isinstance(d.get("times"), list):
                d["times"] = {int(s): float(t) for s, t in d["times"]}
            if cls is not None:
                fields = {f.name for f in dataclasses.fields(cls)}
                items.append(cls(**{k: v for k, v in d.items()
                                    if k in fields}))
            else:
                items.append(d)
        out[key] = items
    return out


@dataclasses.dataclass
class RunRecord:
    """One reloaded run. ``ppg`` is the stored graph — representative
    rows when the run was recorded with ``cluster=K`` (``clustering``
    then maps every original proc to its representative)."""
    run_id: str
    meta: Dict[str, Any]
    ppg: Optional[PPG]
    curves: Optional[np.ndarray]         # (S, V) merged times, or None
    scales: Optional[np.ndarray]         # (S,) proc counts, or None
    detect: Optional[Dict[str, List[Any]]]
    clustering: Optional[Clustering]
    path: str = ""

    @property
    def psg(self) -> Optional[PSG]:
        return self.ppg.psg if self.ppg is not None else None

    @property
    def scale(self) -> int:
        """The run's proc count (original fleet, not representatives)."""
        if self.clustering is not None:
            return self.clustering.n_procs
        if "scale" in self.meta:
            return int(self.meta["scale"])
        if self.scales is not None and len(self.scales):
            return int(np.max(self.scales))
        return int(self.ppg.n_procs) if self.ppg is not None else 0

    def __repr__(self) -> str:
        bits = [f"scale={self.scale}"]
        if self.scales is not None:
            bits.append(f"curves over {list(np.asarray(self.scales))}")
        if self.clustering is not None:
            bits.append(f"{self.clustering.n_clusters} reps")
        return f"RunRecord({self.run_id}: {', '.join(bits)})"


class RunStore:
    """Directory of recorded runs; see module docstring."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    # -- listing -------------------------------------------------------
    def runs(self) -> List[str]:
        """Run ids in recording (lexicographic) order."""
        if not os.path.isdir(self.root):
            return []
        return sorted(
            name for name in os.listdir(self.root)
            if os.path.isfile(os.path.join(self.root, name, "step_0",
                                           "manifest.json")))

    def __len__(self) -> int:
        return len(self.runs())

    def __contains__(self, run_id: str) -> bool:
        return run_id in self.runs()

    def _next_id(self) -> str:
        top = -1
        for name in self.runs():
            if name.startswith(_RUN_PREFIX):
                try:
                    top = max(top, int(name[len(_RUN_PREFIX):]))
                except ValueError:
                    pass
        return f"{_RUN_PREFIX}{top + 1:06d}"

    # -- record --------------------------------------------------------
    def record(self, *, ppg: Optional[PPG] = None,
               series: Optional[Mapping[int, PPG]] = None,
               curves: Optional[np.ndarray] = None,
               scales: Optional[Any] = None,
               detect: Optional[Mapping[str, Any]] = None,
               cluster: int = 0,
               strategy: str = "mean",
               run_id: Optional[str] = None,
               meta: Optional[Mapping[str, Any]] = None) -> str:
        """Persist one run; returns its id.

        Give either a single ``ppg``, or a ``series`` ({n_procs: PPG},
        scaling curves are computed and the top-scale PPG is stored), or
        a ``ppg`` plus precomputed ``curves``/``scales``.  ``cluster=K``
        compresses the stored PPG to at most K behavior representatives
        (full fleet recoverable per-cluster via the membership map)."""
        if series is not None:
            from repro.runs.diff import scaling_curves  # avoid cycle
            sc, cv = scaling_curves(series, strategy=strategy)
            scales = sc if scales is None else scales
            curves = cv if curves is None else curves
            if ppg is None:
                ppg = series[int(max(series))]
        if ppg is None:
            raise ValueError("record() needs a ppg or a series")
        if (curves is None) != (scales is None):
            raise ValueError("curves and scales come together")

        run_meta = run_metadata(scale=int(ppg.n_procs))
        if meta:
            run_meta.update(meta)

        clustering = None
        stored = ppg
        if cluster:
            clustering = cluster_procs(ppg, max_clusters=int(cluster))
            stored = representative_ppg(ppg, clustering)

        ppg_tree, ppg_meta = stored.to_tree()
        tree: Dict[str, Any] = {"ppg": ppg_tree}
        extra: Dict[str, Any] = {
            "schema_version": RUN_SCHEMA_VERSION,
            "run_id": "",                    # filled below
            "run_meta": dict(run_meta),
            "ppg": ppg_meta,
        }
        if curves is not None:
            tree["curves"] = np.asarray(curves, float)
            tree["scales"] = np.asarray(scales, np.int64)
        if clustering is not None:
            cl_tree, cl_meta = clustering.to_tree()
            tree["clustering"] = cl_tree
            extra["clustering"] = cl_meta
        if detect is not None:
            extra["detect"] = _detect_to_json(detect)

        rid = run_id if run_id is not None else self._next_id()
        if os.path.isdir(os.path.join(self.root, rid, "step_0")):
            raise ValueError(f"run {rid!r} already recorded")
        extra["run_id"] = rid
        save_checkpoint(os.path.join(self.root, rid), 0, tree,
                        extra_meta=extra)
        return rid

    # -- load ----------------------------------------------------------
    def load(self, run_id: str) -> RunRecord:
        path = os.path.join(self.root, run_id)
        tree, extra = load_checkpoint_tree(path, 0)
        schema = int(extra.get("schema_version", 1))
        if schema > RUN_SCHEMA_VERSION:
            raise ValueError(f"run {run_id!r} has schema {schema}, "
                             f"newer than supported {RUN_SCHEMA_VERSION}")
        ppg = PPG.from_tree(tree["ppg"], extra.get("ppg")) \
            if "ppg" in tree else None
        curves = np.asarray(tree["curves"], float) \
            if "curves" in tree else None
        scales = np.asarray(tree["scales"], np.int64) \
            if "scales" in tree else None
        clustering = Clustering.from_tree(tree["clustering"],
                                          extra.get("clustering")) \
            if "clustering" in tree else None
        detect = _detect_from_json(extra["detect"]) \
            if "detect" in extra else None
        return RunRecord(run_id=run_id, meta=dict(extra.get("run_meta", {})),
                         ppg=ppg, curves=curves, scales=scales,
                         detect=detect, clustering=clustering, path=path)

    def latest(self) -> Optional[RunRecord]:
        ids = self.runs()
        return self.load(ids[-1]) if ids else None

    def __repr__(self) -> str:
        return f"RunStore({self.root!r}: {len(self)} runs)"
