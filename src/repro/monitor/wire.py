"""Versioned wire protocol for the monitor's socket transport.

Everything a :class:`~repro.monitor.producer.ShardDelta` / ``Heartbeat``
needs to cross a real network, pure python + numpy (no pickle — frames
are explicit, versioned, and checksummed):

* **Framing** — every message travels as one length-prefixed frame::

      offset  size  field
      0       4     magic  b"SCAW"
      4       1     protocol version (1)
      5       1     message type (1=delta, 2=heartbeat, 3=ack)
      6       4     payload length (u32, little-endian)
      10      4     CRC32 of the payload
      14      N     payload

  :class:`FrameReader` reassembles frames from an arbitrary byte stream
  and RESYNCS after corruption: on a bad magic, bad version, oversized
  length or CRC mismatch it scans forward for the next magic and keeps
  count (``stats``), so injected garbage or a torn frame costs the
  frames it overlapped, never the connection's sanity.

* **Serialization** — numpy payloads travel as typed byte blocks
  (little-endian dtype + raw bytes); counters as (vid, value, mask)
  triples trimmed to entries that carry data.

* **Delta compression** — :class:`DeltaEncoder` keeps the last
  transmitted state of every row it has sent; a steady-state flush
  re-encodes only the CHANGED columns of each dirty row (time / var /
  samples / mask at changed column indices, plus changed counter
  (vid, value, mask) triples), falling back to the full row whenever
  the diff is denser.  :class:`DeltaDecoder` mirrors the cache and
  reconstructs the full row state, so the aggregator still ingests
  full-state :class:`~repro.core.graph.RowBlock` deltas — the Monitor
  is unchanged and the exactness contract (bit-identical convergence)
  is preserved.

  Correctness under loss: every diff row names the ``seq`` its base row
  was last encoded at; if the decoder's cache disagrees (frames were
  lost to a resync), the delta is REJECTED rather than mis-applied —
  the producer's unacked buffer resends it, and because the encoder
  emits a FULL row whenever a delta's seq has not advanced past the
  last seq it encoded for that row (i.e. a resend), the replay re-seeds
  the decoder's cache even on a live connection whose earlier frames
  were eaten by a resync.  Encoder and decoder caches are
  per-connection and reset on reconnect, so a fresh connection always
  starts from full rows.
"""
from __future__ import annotations

import collections
import dataclasses
import struct
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import RowBlock
from repro.monitor.producer import Heartbeat, ShardDelta

MAGIC = b"SCAW"
VERSION = 1
MSG_DELTA = 1
MSG_HEARTBEAT = 2
MSG_ACK = 3

HEADER = struct.Struct("<4sBBII")          # magic, version, type, len, crc
_DELTA_HEAD = struct.Struct("<iqqII")      # host, seq, proc_start, cols, rows
_ROW_HEAD = struct.Struct("<IB")           # local row, mode
_HEARTBEAT = struct.Struct("<iqd")         # host, seq, time
_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")
_I64 = struct.Struct("<q")

ROW_FULL = 0
ROW_DIFF = 1

DEFAULT_MAX_FRAME = 64 * 1024 * 1024


@dataclasses.dataclass
class Ack:
    """Aggregator -> producer: cumulative durable sequence per host."""
    acks: Dict[int, int]


class WireError(ValueError):
    """A payload that framed correctly but does not parse."""


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def encode_frame(msg_type: int, payload: bytes, *,
                 max_frame: int = DEFAULT_MAX_FRAME) -> bytes:
    """One wire frame: header (magic, version, type, length, CRC32) +
    payload.

    Raises :class:`WireError` when the payload exceeds ``max_frame`` —
    the receiver's :class:`FrameReader` would discard such a frame as
    oversize on every delivery, so silently sending it guarantees an
    endless resend loop; failing loudly on the send side surfaces the
    misconfiguration instead."""
    if len(payload) > max_frame:
        raise WireError(
            f"{len(payload)}-byte payload exceeds max_frame={max_frame}; "
            f"the receiver would discard it as oversize")
    return HEADER.pack(MAGIC, VERSION, msg_type, len(payload),
                       zlib.crc32(payload) & 0xFFFFFFFF) + payload


class FrameReader:
    """Incremental frame reassembly with resynchronization.

    ``feed(data)`` returns every complete, checksum-valid frame the
    stream now covers as ``(msg_type, payload)`` pairs.  Corruption
    (garbage bytes, torn frames, flipped bits) never raises: the reader
    skips to the next magic and records what it survived in ``stats``
    (``frames``, ``resyncs``, ``skipped_bytes``, ``crc_errors``,
    ``bad_version``, ``oversize``).
    """

    def __init__(self, max_frame: int = DEFAULT_MAX_FRAME):
        self.max_frame = int(max_frame)
        self._buf = bytearray()
        self.stats: Dict[str, int] = collections.Counter()

    def _resync(self) -> None:
        """Drop bytes up to the next possible frame start: the next full
        magic at offset >= 1, else a trailing proper prefix of the magic
        (the rest of it may still be in flight — dropping it would tear
        the healthy frame straddling the chunk boundary)."""
        idx = self._buf.find(MAGIC, 1)
        if idx < 0:
            idx = len(self._buf)
            for k in range(len(MAGIC) - 1, 0, -1):
                if idx - k >= 1 and self._buf[idx - k:idx] == MAGIC[:k]:
                    idx -= k
                    break
        del self._buf[:idx]
        self.stats["resyncs"] += 1
        self.stats["skipped_bytes"] += idx

    def feed(self, data: bytes) -> List[Tuple[int, bytes]]:
        self._buf.extend(data)
        out: List[Tuple[int, bytes]] = []
        while True:
            if len(self._buf) < HEADER.size:
                # a buffered prefix that can no longer start a frame is
                # garbage — drop it so it cannot absorb the next magic
                if self._buf and not MAGIC.startswith(
                        bytes(self._buf[:len(MAGIC)])):
                    self._resync()
                    continue
                return out
            magic, version, msg_type, length, crc = \
                HEADER.unpack_from(self._buf)
            if magic != MAGIC:
                self._resync()
                continue
            if version != VERSION:
                self.stats["bad_version"] += 1
                self._resync()
                continue
            if length > self.max_frame:
                self.stats["oversize"] += 1
                self._resync()
                continue
            end = HEADER.size + length
            if len(self._buf) < end:
                return out
            payload = bytes(self._buf[HEADER.size:end])
            if zlib.crc32(payload) & 0xFFFFFFFF != crc:
                self.stats["crc_errors"] += 1
                self._resync()
                continue
            del self._buf[:end]
            self.stats["frames"] += 1
            out.append((msg_type, payload))

    def pending_bytes(self) -> int:
        return len(self._buf)

    def close(self) -> None:
        """Connection closed: a buffered partial frame is torn, count it."""
        if self._buf:
            self.stats["truncated"] += 1
            self._buf.clear()


# ---------------------------------------------------------------------------
# primitive packers
# ---------------------------------------------------------------------------

def _pack_arr(out: bytearray, a: np.ndarray, dtype: str) -> None:
    out += np.ascontiguousarray(a, dtype=dtype).tobytes()


def _take(payload: bytes, off: int, n: int, dtype: str,
          count: int) -> Tuple[np.ndarray, int]:
    a = np.frombuffer(payload, dtype=dtype, count=count, offset=off)
    return a, off + n


def _pack_str(out: bytearray, s: str) -> None:
    b = s.encode("utf-8")
    if len(b) > 0xFFFF:
        raise WireError(f"counter name too long for the wire: {s[:32]!r}...")
    out += _U16.pack(len(b))
    out += b


def _unpack_str(payload: bytes, off: int) -> Tuple[str, int]:
    (n,) = _U16.unpack_from(payload, off)
    off += _U16.size
    return payload[off:off + n].decode("utf-8"), off + n


# ---------------------------------------------------------------------------
# row state (the codec's unit of caching)
# ---------------------------------------------------------------------------

class _RowState:
    """Full transmitted state of one shard row: the core column arrays
    plus trimmed counter entries {name: {vid: (value, mask)}}."""

    __slots__ = ("seq", "n_cols", "time", "var", "samples", "mask",
                 "counters")

    def __init__(self, seq: int, n_cols: int, time: np.ndarray,
                 var: np.ndarray, samples: np.ndarray, mask: np.ndarray,
                 counters: Dict[str, Dict[int, Tuple[float, bool]]]):
        self.seq = seq
        self.n_cols = n_cols
        self.time = time
        self.var = var
        self.samples = samples
        self.mask = mask
        self.counters = counters


def _row_counters(block: RowBlock, i: int
                  ) -> Dict[str, Dict[int, Tuple[float, bool]]]:
    """Row ``i``'s counter entries, trimmed to (value != 0) or masked —
    the entries that can affect the reconstructed store."""
    out: Dict[str, Dict[int, Tuple[float, bool]]] = {}
    for name, (vids, values, mask) in block.counters.items():
        row: Dict[int, Tuple[float, bool]] = {}
        v, m = values[i], mask[i]
        keep = np.nonzero(m | (v != 0.0))[0]
        for j in keep:
            row[int(vids[j])] = (float(v[j]), bool(m[j]))
        if row:
            out[name] = row
    return out


def _row_state(delta: ShardDelta, i: int) -> _RowState:
    b = delta.block
    return _RowState(delta.seq, int(b.n_cols),
                     np.ascontiguousarray(b.time[i], "<f8"),
                     np.ascontiguousarray(b.time_var[i], "<f8"),
                     np.ascontiguousarray(b.samples[i], "<i8"),
                     np.ascontiguousarray(b.mask[i], "?"),
                     _row_counters(b, i))


def _encode_counter_entries(out: bytearray,
                            entries: Dict[str, List[Tuple[int, float, bool]]]
                            ) -> None:
    out += _U16.pack(len(entries))
    for name, triples in entries.items():
        _pack_str(out, name)
        out += _U32.pack(len(triples))
        vids = np.array([t[0] for t in triples], "<i8")
        vals = np.array([t[1] for t in triples], "<f8")
        msk = np.array([t[2] for t in triples], "?")
        out += vids.tobytes() + vals.tobytes() + msk.tobytes()


def _decode_counter_entries(payload: bytes, off: int
                            ) -> Tuple[Dict[str, List[Tuple[int, float,
                                                            bool]]], int]:
    (n_names,) = _U16.unpack_from(payload, off)
    off += _U16.size
    out: Dict[str, List[Tuple[int, float, bool]]] = {}
    for _ in range(n_names):
        name, off = _unpack_str(payload, off)
        (k,) = _U32.unpack_from(payload, off)
        off += _U32.size
        vids, off = _take(payload, off, 8 * k, "<i8", k)
        vals, off = _take(payload, off, 8 * k, "<f8", k)
        msk, off = _take(payload, off, k, "?", k)
        out[name] = [(int(vids[j]), float(vals[j]), bool(msk[j]))
                     for j in range(k)]
    return out, off


def _encode_full_row(state: _RowState) -> bytes:
    out = bytearray()
    _pack_arr(out, state.time, "<f8")
    _pack_arr(out, state.var, "<f8")
    _pack_arr(out, state.samples, "<i8")
    _pack_arr(out, state.mask, "u1")
    _encode_counter_entries(out, {
        name: [(vid, v, m) for vid, (v, m) in sorted(row.items())]
        for name, row in sorted(state.counters.items())})
    return bytes(out)


def _encode_diff_row(prev: _RowState, cur: _RowState) -> bytes:
    out = bytearray()
    out += _I64.pack(prev.seq)
    changed = np.nonzero((prev.time != cur.time) | (prev.var != cur.var)
                         | (prev.samples != cur.samples)
                         | (prev.mask != cur.mask))[0]
    out += _U32.pack(len(changed))
    _pack_arr(out, changed, "<u4")
    _pack_arr(out, cur.time[changed], "<f8")
    _pack_arr(out, cur.var[changed], "<f8")
    _pack_arr(out, cur.samples[changed], "<i8")
    _pack_arr(out, cur.mask[changed], "u1")
    entries: Dict[str, List[Tuple[int, float, bool]]] = {}
    for name in sorted(set(prev.counters) | set(cur.counters)):
        p = prev.counters.get(name, {})
        c = cur.counters.get(name, {})
        triples = []
        for vid in sorted(set(p) | set(c)):
            want = c.get(vid, (0.0, False))
            if p.get(vid, (0.0, False)) != want:
                triples.append((vid, want[0], want[1]))
        if triples:
            entries[name] = triples
    _encode_counter_entries(out, entries)
    return bytes(out)


# ---------------------------------------------------------------------------
# the delta codec
# ---------------------------------------------------------------------------

class DeltaEncoder:
    """Serialize :class:`ShardDelta`\\ s, diffing rows against the last
    state transmitted on this connection.

    One encoder per connection (its cache and the peer
    :class:`DeltaDecoder`'s advance in lockstep with the byte stream);
    call :meth:`reset` on reconnect so the fresh connection re-seeds
    from full rows.  A send that fails mid-frame MUST tear the
    connection down (the socket transport does) — the caches tolerate
    lost frames via the per-row base-seq check, not mid-frame rewinds.

    A RESEND — a delta whose seq is not past the last seq this
    connection encoded for a row — always carries that row in full
    (``stats["resend_full_rows"]``): the cached state may belong to a
    frame the peer lost, so diffing against it could never decode.

    ``compress=False`` always emits full rows (the wire-bytes baseline
    the benchmark reports against).
    """

    def __init__(self, *, compress: bool = True):
        self.compress = bool(compress)
        self._rows: Dict[Tuple[int, int], _RowState] = {}
        self.stats: Dict[str, int] = collections.Counter()
        self.last_bytes = 0

    def reset(self) -> None:
        self._rows.clear()
        self.stats["resets"] += 1

    def encode(self, delta: ShardDelta) -> bytes:
        """The delta's frame payload (pass to ``encode_frame(MSG_DELTA,
        ...)``)."""
        b = delta.block
        rows = np.asarray(b.rows, np.int64)
        out = bytearray()
        out += _DELTA_HEAD.pack(delta.host, delta.seq, delta.proc_start,
                                int(b.n_cols), len(rows))
        for i, row in enumerate(rows.tolist()):
            cur = _row_state(delta, i)
            full = _encode_full_row(cur)
            enc, mode = full, ROW_FULL
            prev = self._rows.get((delta.host, row))
            # a RESEND (seq not past the last seq encoded for this row)
            # must go out full: the frame that advanced the cache may be
            # the very one the peer lost, so a diff against it would be
            # rejected on every retry — the stream would never reconverge
            if self.compress and prev is not None \
                    and prev.n_cols == cur.n_cols \
                    and delta.seq > prev.seq:
                diff = _encode_diff_row(prev, cur)
                if len(diff) < len(full):      # fall back when denser
                    enc, mode = diff, ROW_DIFF
            elif prev is not None and delta.seq <= prev.seq:
                self.stats["resend_full_rows"] += 1
            out += _ROW_HEAD.pack(row, mode)
            out += enc
            self._rows[(delta.host, row)] = cur
            self.stats["diff_rows" if mode == ROW_DIFF else "full_rows"] += 1
        self.stats["deltas"] += 1
        self.last_bytes = len(out)
        self.stats["payload_bytes"] += len(out)
        return bytes(out)


class DeltaDecoder:
    """Reconstruct full-state :class:`ShardDelta`\\ s from
    :class:`DeltaEncoder` payloads.

    Mirrors the encoder's per-row cache.  A diff row whose base seq does
    not match the cache (frames lost between the peers) makes the WHOLE
    delta undecodable — :meth:`decode` returns None and counts it in
    ``stats["undecodable"]`` — because applying it would silently
    corrupt the row.  The producer's unacked-resend machinery redelivers
    it as (or after) full rows.
    """

    def __init__(self):
        self._rows: Dict[Tuple[int, int], _RowState] = {}
        self.stats: Dict[str, int] = collections.Counter()

    def reset(self) -> None:
        self._rows.clear()

    def decode(self, payload: bytes) -> Optional[ShardDelta]:
        try:
            return self._decode(payload)
        except (struct.error, WireError, IndexError, UnicodeDecodeError,
                ValueError):
            self.stats["malformed"] += 1
            return None

    def _decode(self, payload: bytes) -> Optional[ShardDelta]:
        host, seq, proc_start, n_cols, n_rows = \
            _DELTA_HEAD.unpack_from(payload)
        off = _DELTA_HEAD.size
        states: List[Tuple[int, _RowState]] = []
        for _ in range(n_rows):
            row, mode = _ROW_HEAD.unpack_from(payload, off)
            off += _ROW_HEAD.size
            if mode == ROW_FULL:
                time, off = _take(payload, off, 8 * n_cols, "<f8", n_cols)
                var, off = _take(payload, off, 8 * n_cols, "<f8", n_cols)
                smp, off = _take(payload, off, 8 * n_cols, "<i8", n_cols)
                msk, off = _take(payload, off, n_cols, "u1", n_cols)
                entries, off = _decode_counter_entries(payload, off)
                counters = {name: {vid: (v, m) for vid, v, m in triples}
                            for name, triples in entries.items()}
                states.append((row, _RowState(
                    seq, n_cols, time.copy(), var.copy(),
                    smp.copy(), msk.astype(bool), counters)))
            elif mode == ROW_DIFF:
                (base_seq,) = _I64.unpack_from(payload, off)
                off += _I64.size
                (k,) = _U32.unpack_from(payload, off)
                off += _U32.size
                idx, off = _take(payload, off, 4 * k, "<u4", k)
                time, off = _take(payload, off, 8 * k, "<f8", k)
                var, off = _take(payload, off, 8 * k, "<f8", k)
                smp, off = _take(payload, off, 8 * k, "<i8", k)
                msk, off = _take(payload, off, k, "u1", k)
                entries, off = _decode_counter_entries(payload, off)
                prev = self._rows.get((host, row))
                if prev is None or prev.seq != base_seq \
                        or prev.n_cols != n_cols:
                    # broken diff chain: reject the delta, never guess
                    self.stats["undecodable"] += 1
                    return None
                nxt = _RowState(seq, n_cols, prev.time.copy(),
                                prev.var.copy(), prev.samples.copy(),
                                prev.mask.copy(),
                                {n: dict(r)
                                 for n, r in prev.counters.items()})
                nxt.time[idx] = time
                nxt.var[idx] = var
                nxt.samples[idx] = smp
                nxt.mask[idx] = msk.astype(bool)
                for name, triples in entries.items():
                    rowc = nxt.counters.setdefault(name, {})
                    for vid, v, m in triples:
                        if v == 0.0 and not m:
                            rowc.pop(vid, None)
                        else:
                            rowc[vid] = (v, m)
                    if not rowc:
                        del nxt.counters[name]
                states.append((row, nxt))
            else:
                raise WireError(f"unknown row mode {mode}")
        if off != len(payload):
            raise WireError(f"{len(payload) - off} trailing payload bytes")
        # all rows decoded: commit the cache, then assemble the block
        for row, st in states:
            self._rows[(host, row)] = st
        self.stats["deltas"] += 1
        block = self._assemble(n_cols, states)
        return ShardDelta(host=host, seq=seq, proc_start=proc_start,
                          block=block)

    @staticmethod
    def _assemble(n_cols: int,
                  states: List[Tuple[int, _RowState]]) -> RowBlock:
        k = len(states)
        rows = np.array([r for r, _ in states], np.intp)
        time = np.zeros((k, n_cols))
        var = np.zeros((k, n_cols))
        samples = np.zeros((k, n_cols), np.int64)
        mask = np.zeros((k, n_cols), bool)
        names: Dict[str, set] = {}
        for i, (_, st) in enumerate(states):
            time[i] = st.time
            var[i] = st.var
            samples[i] = st.samples
            mask[i] = st.mask
            for name, rowc in st.counters.items():
                names.setdefault(name, set()).update(rowc)
        counters: Dict[str, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        for name in sorted(names):
            vids = np.array(sorted(names[name]), np.int64)
            slot = {int(v): j for j, v in enumerate(vids)}
            vals = np.zeros((k, len(vids)))
            msk = np.zeros((k, len(vids)), bool)
            for i, (_, st) in enumerate(states):
                for vid, (v, m) in st.counters.get(name, {}).items():
                    j = slot[vid]
                    vals[i, j] = v
                    msk[i, j] = m
            counters[name] = (vids, vals, msk)
        return RowBlock(rows=rows, n_cols=n_cols, time=time, time_var=var,
                        samples=samples, mask=mask, counters=counters)


# ---------------------------------------------------------------------------
# whole-message encode/decode
# ---------------------------------------------------------------------------

def encode_message(msg, encoder: Optional[DeltaEncoder] = None, *,
                   max_frame: int = DEFAULT_MAX_FRAME) -> bytes:
    """``msg`` (ShardDelta / Heartbeat / Ack) as one complete frame.
    Deltas need the connection's :class:`DeltaEncoder`.  Raises
    :class:`WireError` when the payload exceeds ``max_frame`` (see
    :func:`encode_frame`)."""
    if isinstance(msg, ShardDelta):
        if encoder is None:
            encoder = DeltaEncoder(compress=False)
        return encode_frame(MSG_DELTA, encoder.encode(msg),
                            max_frame=max_frame)
    if isinstance(msg, Heartbeat):
        return encode_frame(MSG_HEARTBEAT, _HEARTBEAT.pack(
            msg.host, msg.seq, msg.time), max_frame=max_frame)
    if isinstance(msg, Ack):
        out = bytearray(_U32.pack(len(msg.acks)))
        for host, seq in sorted(msg.acks.items()):
            out += struct.pack("<iq", host, seq)
        return encode_frame(MSG_ACK, bytes(out), max_frame=max_frame)
    raise TypeError(f"cannot put {type(msg).__name__} on the wire")


def decode_message(msg_type: int, payload: bytes,
                   decoder: Optional[DeltaDecoder] = None):
    """Inverse of :func:`encode_message` for one framed payload; returns
    None for an undecodable delta (see :class:`DeltaDecoder`) and raises
    :class:`WireError` for unknown types / malformed payloads."""
    if msg_type == MSG_DELTA:
        if decoder is None:
            decoder = DeltaDecoder()
        return decoder.decode(payload)
    if msg_type == MSG_HEARTBEAT:
        try:
            host, seq, t = _HEARTBEAT.unpack(payload)
        except struct.error as e:
            raise WireError(f"bad heartbeat payload: {e}") from None
        return Heartbeat(host=host, seq=seq, time=t)
    if msg_type == MSG_ACK:
        try:
            (n,) = _U32.unpack_from(payload)
            acks = {}
            off = _U32.size
            for _ in range(n):
                host, seq = struct.unpack_from("<iq", payload, off)
                off += 12
                acks[host] = seq
            if off != len(payload):
                raise WireError("trailing ack bytes")
        except struct.error as e:
            raise WireError(f"bad ack payload: {e}") from None
        return Ack(acks=acks)
    raise WireError(f"unknown message type {msg_type}")
