"""Constructor-knob validation shared across the monitor stack.

Every check raises ``ValueError`` naming the offending argument and the
value it got (mirroring the ``SCALANA_DETECT_BACKEND`` style in
``repro.core.detect``), so a mistyped knob fails at construction with a
message that says which knob — not three layers down with an opaque
type error.
"""
from __future__ import annotations

from typing import Optional


def positive_int(name: str, value, *, allow_none: bool = False
                 ) -> Optional[int]:
    """``value`` as a positive int (``None`` passes when allowed)."""
    if value is None:
        if allow_none:
            return None
        raise ValueError(f"{name} must be a positive integer, got None")
    try:
        v = int(value)
    except (TypeError, ValueError):
        raise ValueError(
            f"{name} must be a positive integer, got {value!r}") from None
    if v <= 0:
        raise ValueError(f"{name} must be a positive integer, got {value!r}")
    return v


def non_negative_int(name: str, value) -> int:
    try:
        v = int(value)
    except (TypeError, ValueError):
        raise ValueError(
            f"{name} must be a non-negative integer, got {value!r}") from None
    if v < 0:
        raise ValueError(
            f"{name} must be a non-negative integer, got {value!r}")
    return v


def positive_float(name: str, value, *, allow_none: bool = False
                   ) -> Optional[float]:
    if value is None:
        if allow_none:
            return None
        raise ValueError(f"{name} must be a positive number, got None")
    try:
        v = float(value)
    except (TypeError, ValueError):
        raise ValueError(
            f"{name} must be a positive number, got {value!r}") from None
    if not v > 0:
        raise ValueError(f"{name} must be a positive number, got {value!r}")
    return v


def probability(name: str, value) -> float:
    try:
        v = float(value)
    except (TypeError, ValueError):
        raise ValueError(
            f"{name} must be a probability in [0, 1], got {value!r}") \
            from None
    if not 0.0 <= v <= 1.0:
        raise ValueError(
            f"{name} must be a probability in [0, 1], got {value!r}")
    return v


def fraction(name: str, value, *, allow_none: bool = False
             ) -> Optional[float]:
    """A detection-trigger fraction: in (0, 1]."""
    if value is None:
        if allow_none:
            return None
        raise ValueError(f"{name} must be a fraction in (0, 1], got None")
    try:
        v = float(value)
    except (TypeError, ValueError):
        raise ValueError(
            f"{name} must be a fraction in (0, 1], got {value!r}") from None
    if not 0.0 < v <= 1.0:
        raise ValueError(f"{name} must be a fraction in (0, 1], got {value!r}")
    return v


def port_number(name: str, value, *, allow_zero: bool = True) -> int:
    """A TCP port: 1..65535, or 0 for "pick a free one" when allowed."""
    try:
        v = int(value)
    except (TypeError, ValueError):
        raise ValueError(
            f"{name} must be a TCP port in "
            f"{'0' if allow_zero else '1'}..65535, got {value!r}") from None
    lo = 0 if allow_zero else 1
    if not lo <= v <= 65535:
        raise ValueError(f"{name} must be a TCP port in {lo}..65535, "
                         f"got {value!r}")
    return v


def backoff_bounds(base_name: str, base, max_name: str, max_value
                   ) -> tuple:
    """Validate an exponential-backoff (base, cap) pair together."""
    b = positive_float(base_name, base)
    m = positive_float(max_name, max_value)
    if m < b:
        raise ValueError(
            f"{max_name} must be >= {base_name} "
            f"({max_name}={max_value!r} < {base_name}={base!r})")
    return b, m
