"""Degraded-fleet compaction: the PPG restricted to LIVE processes.

When hosts die or go stale, the monitor must keep producing correct
results for the sub-fleet that is still reporting.  Detection handles
this with row masks (``detect_abnormal(..., proc_mask=)`` — exact
row-subsetting, threaded down to the device kernels); backtracking walks
the explicit graph, so here the graph itself is compacted:
:func:`live_subppg` gathers the live rows into a dense store (via the
``extract_rows``/``apply_rows`` seam), intersects every collective
participant group with the live set, filters p2p edges touching dead
processes, and remaps the surviving procs to ``0..n_live-1``.  The
result is exactly the PPG a one-shot run would build over a fleet that
never contained the dead hosts — the acceptance contract for degraded
monitoring — and :func:`remap_paths` lifts the walk's local proc indices
back to global ones for reporting.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.backtrack import Path
from repro.core.graph import CommIndex, PPG, PerfStore
from repro.core.shard import ShardedStore


def live_subppg(ppg: PPG, live_idx: np.ndarray) -> PPG:
    """The PPG restricted to the (sorted, global) ``live_idx`` processes.

    Perf rows are gathered through the row-block seam (sharded stores
    extract per shard; the stacked matrix is never built), comm groups
    are intersected with the live set (groups left with < 2 members
    vanish — a collective with one live participant constrains nothing),
    and p2p edges keep only live-to-live pairs.  Proc ``live_idx[i]``
    becomes proc ``i`` of the sub-PPG."""
    live_idx = np.asarray(live_idx, np.intp)
    n_live = int(live_idx.size)
    psg = ppg.psg
    V = len(psg.vertices)
    pos = np.full(ppg.n_procs, -1, np.intp)
    pos[live_idx] = np.arange(n_live)

    sub = PerfStore(max(n_live, 1), V)
    perf = ppg.perf
    if isinstance(perf, ShardedStore):
        for sh in perf.shards:
            sel = (live_idx >= sh.proc_start) & (live_idx < sh.proc_stop)
            if sel.any():
                blk = sh.extract_rows(live_idx[sel] - sh.proc_start)
                sub.apply_rows(blk, rows=np.nonzero(sel)[0])
    elif n_live:
        sub.apply_rows(perf.extract_rows(live_idx), rows=np.arange(n_live))
    sub.clear_dirty()

    comm = CommIndex()
    for vid in range(V):
        for group in ppg.comm.groups_of(vid):
            kept = [int(pos[p]) for p in group if pos[p] >= 0]
            if len(kept) >= 2:
                comm.add_group(vid, kept)
    for (sp, sv), (dp, dv) in ppg.comm.p2p_edges():
        if pos[sp] >= 0 and pos[dp] >= 0:
            comm.add_p2p((int(pos[sp]), sv), (int(pos[dp]), dv))

    out = PPG(psg, n_live, sub, meta=dict(ppg.meta))
    out.comm = comm
    return out


def remap_paths(paths: Sequence[Path], live_idx: np.ndarray) -> List[Path]:
    """Lift sub-PPG paths (local procs) back to global proc indices."""
    live_idx = np.asarray(live_idx, np.intp)
    return [Path(nodes=[(int(live_idx[p]), v) for p, v in path.nodes],
                 start_reason=path.start_reason) for path in paths]
