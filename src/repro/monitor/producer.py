"""Per-host shard producers: dirty rows -> sequence-numbered deltas.

Each host owns one :class:`~repro.core.shard.PerfShard` (its proc-range
block) that its profiler/replay writes into; :class:`ShardProducer`
periodically flushes the shard's DIRTY rows as a :class:`ShardDelta` —
the full current state of those rows (a ``PerfStore.extract_rows``
:class:`~repro.core.graph.RowBlock`), stamped with a per-host monotone
sequence number.  Full row state + strictly in-order application on the
aggregator side make the protocol exactly idempotent: duplicates are
dropped by sequence, reordering is parked, and the replica converges
bit-identically to the source shard (see ``repro.monitor.aggregator``).

Reliability is send-side: a failed send (:class:`~repro.monitor.
transport.TransportError`) retries with exponential backoff; deltas stay
in the UNACKED buffer until the aggregator acknowledges their sequence
number (which it only does once they are safely snapshotted, when
snapshotting is on), so ``resend_unacked()`` replays everything a crashed
aggregator may have lost.  Both the clock and the backoff sleep are
injectable, keeping chaos tests deterministic and instant.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.core.graph import RowBlock
from repro.core.shard import PerfShard
from repro.monitor.clock import as_clock
from repro.monitor.transport import Transport, TransportError
from repro.monitor.validate import backoff_bounds, non_negative_int


@dataclasses.dataclass
class ShardDelta:
    """One flush of a host's dirty rows.  ``block.rows`` are LOCAL shard
    rows (global proc = ``proc_start + row``); ``seq`` is per-host,
    starting at 1, with no gaps."""
    host: int
    seq: int
    proc_start: int
    block: RowBlock

    def nbytes(self) -> int:
        return self.block.nbytes()


@dataclasses.dataclass
class Heartbeat:
    """I-am-alive marker: refreshes the aggregator's staleness clock even
    when the host has nothing to flush.  ``seq`` is the last delta seq
    this host produced (0 before the first)."""
    host: int
    seq: int
    time: float


class ShardProducer:
    """One host's flush/retry/ack loop over the transport seam."""

    def __init__(self, host: int, shard: PerfShard, transport: Transport, *,
                 max_retries: int = 8, base_backoff: float = 0.01,
                 max_backoff: float = 1.0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Optional[Callable[[float], None]] = None):
        self.host = non_negative_int("host", host)
        self.shard = shard
        self.transport = transport
        self.max_retries = non_negative_int("max_retries", max_retries)
        self.base_backoff, self.max_backoff = backoff_bounds(
            "base_backoff", base_backoff, "max_backoff", max_backoff)
        # one Clock behind the legacy knob pair (see repro.monitor.clock)
        self._clock = as_clock(clock, sleep)
        self.clock = self._clock.monotonic
        self.sleep = self._clock.sleep
        self.seq = 0                          # last produced delta seq
        self.acked = 0                        # last seq the aggregator owns
        self.unacked: Dict[int, ShardDelta] = {}
        self._unsent: List[int] = []          # seqs never sent successfully
        self.retries = 0
        self.send_failures = 0
        self.heartbeats_lost = 0

    # -- flushing ------------------------------------------------------
    def flush(self, *, heartbeat: bool = True) -> Optional[ShardDelta]:
        """Package the shard's dirty rows as the next delta and send it
        (with retry/backoff), then send a heartbeat.  Returns the delta,
        or None when nothing was dirty.  Previously-unsendable deltas are
        retried first, in sequence order, so a recovered link drains the
        backlog before new data."""
        for seq in list(self._unsent):
            delta = self.unacked.get(seq)
            if delta is None:                 # acked mid-drain (a socket
                if seq in self._unsent:       # send pumps acks inline)
                    self._unsent.remove(seq)
                continue
            if self._send_with_retry(delta) and seq in self._unsent:
                self._unsent.remove(seq)
        delta = None
        rows = self.shard.dirty_rows()
        if rows.size:
            block = self.shard.extract_rows(rows)
            self.shard.clear_dirty()
            self.seq += 1
            delta = ShardDelta(host=self.host, seq=self.seq,
                               proc_start=self.shard.proc_start, block=block)
            self.unacked[self.seq] = delta
            if not self._send_with_retry(delta):
                self._unsent.append(self.seq)
        if heartbeat:
            self.send_heartbeat()
        return delta

    def send_heartbeat(self) -> None:
        """Single-attempt (heartbeats are cheap and periodic; the next one
        covers for a lost one)."""
        try:
            self.transport.send(
                Heartbeat(host=self.host, seq=self.seq, time=self.clock()))
        except TransportError:
            self.heartbeats_lost += 1

    def _send_with_retry(self, msg) -> bool:
        delay = self.base_backoff
        for _ in range(self.max_retries + 1):
            try:
                self.transport.send(msg)
                return True
            except TransportError:
                self.retries += 1
                self.sleep(delay)
                delay = min(2.0 * delay, self.max_backoff)
        self.send_failures += 1
        return False

    # -- durability ----------------------------------------------------
    def ack(self, upto_seq: int) -> None:
        """The aggregator durably owns everything up to ``upto_seq``."""
        if upto_seq <= self.acked:
            return
        self.acked = int(upto_seq)
        for seq in [s for s in self.unacked if s <= upto_seq]:
            del self.unacked[seq]
            if seq in self._unsent:
                self._unsent.remove(seq)

    def resend_unacked(self) -> int:
        """Replay every unacked delta (aggregator crash recovery).  The
        restored aggregator's sequence windows drop whatever it already
        has.  Returns the number of deltas resent."""
        n = 0
        for seq in sorted(self.unacked):
            delta = self.unacked.get(seq)
            if delta is None:                 # acked mid-replay
                continue
            if self._send_with_retry(delta):
                n += 1
                if seq in self._unsent:
                    self._unsent.remove(seq)
            elif seq not in self._unsent:
                self._unsent.append(seq)
        return n
