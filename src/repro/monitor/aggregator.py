"""The always-on aggregator: fold shard deltas, detect, survive the fleet.

:class:`Monitor` turns the one-shot detect/backtrack pipeline into a
resident service over the transport seam:

* **Exact idempotent ingestion** — deltas carry the FULL state of their
  rows and apply strictly in per-host sequence order: each host has a
  high-water mark (last applied seq); a delta at ``seq <= high`` or
  already parked is a duplicate and is dropped, a future seq is PARKED
  until the gap fills.  Under any schedule of duplication, reordering
  and delay with eventual delivery, the rolling
  :class:`~repro.core.shard.ShardedStore` converges bit-identically to
  the producers' shards — so the monitor's detection output equals a
  one-shot run on the fully-assembled store, exactly.
* **Heartbeats / staleness** — every delta or heartbeat refreshes its
  host's ``last_seen``; hosts silent for ``stale_after`` seconds are
  excluded from detection.
* **Graceful degradation** — with stale/dead hosts, detection runs on
  the live sub-fleet: row masks thread through ``detect_abnormal`` down
  to the device kernels (masked rows are EXCLUDED, not zero-polluted),
  backtracking walks the live-compacted PPG
  (:func:`~repro.monitor.degraded.live_subppg`), and every report is
  annotated with fleet coverage.
* **Crash recovery** — the store + sequence windows snapshot to
  ``checkpoint/store.py`` every ``snapshot_every`` applied deltas;
  producers are acked only up to the last snapshotted seq, so
  :meth:`Monitor.restore` + ``producer.resend_unacked()`` converge to
  the same result as a crash-free run.
* **Detection cadence** — a report is produced when any trigger fires:
  ``detect_every`` applied deltas, ``drift_threshold`` fraction of procs
  updated, or ``interval`` seconds elapsed (injectable clock).  Reports
  stream through ``render_report(max_abnormal=)`` plus an optional
  ``on_report`` callback; :meth:`start`/:meth:`stop` run the poll loop
  in a daemon thread for always-on use.

The module (like the whole monitor package) never imports jax; the jax
detection backends engage through ``detect``'s backend resolution
exactly as in one-shot use.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint.store import (CheckpointManager, latest_step,
                                    load_checkpoint_tree)
from repro.core.backtrack import Path, backtrack
from repro.core.detect import Abnormal, detect_abnormal
from repro.core.graph import CommIndex, PPG, PSG
from repro.core.report import render_report
from repro.core.shard import ShardedStore
from repro.monitor.clock import as_clock
from repro.monitor.degraded import live_subppg, remap_paths
from repro.monitor.producer import Heartbeat, ShardDelta
from repro.monitor.transport import Transport
from repro.monitor.validate import (fraction, positive_float, positive_int,
                                    probability)


@dataclasses.dataclass
class HostStatus:
    host: int
    high: int                  # last applied seq
    acked: int                 # last seq durably owned (<= high)
    parked: int                # out-of-order deltas waiting for a gap
    last_seen: float
    live: bool


@dataclasses.dataclass
class FleetStatus:
    hosts: List[HostStatus]
    live_hosts: int
    total_hosts: int
    live_procs: int
    total_procs: int


@dataclasses.dataclass
class MonitorReport:
    """One incremental detection result from the stream."""
    index: int
    text: str
    abnormal: List[Abnormal]
    paths: List[Path]
    coverage: str
    live_procs: int
    total_procs: int
    live_hosts: int
    total_hosts: int
    applied: int               # deltas applied so far (monitor lifetime)
    duplicates: int            # duplicates absorbed so far
    parked: int                # deltas currently parked

    @property
    def degraded(self) -> bool:
        return self.live_procs < self.total_procs


class Monitor:
    """Async ingestion/detection daemon over a rolling sharded store."""

    def __init__(self, psg: PSG, ranges: Sequence[Tuple[int, int]],
                 transport: Transport, *,
                 comm: Optional[CommIndex] = None,
                 detect_every: Optional[int] = 8,
                 drift_threshold: Optional[float] = None,
                 interval: Optional[float] = None,
                 stale_after: Optional[float] = None,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 16,
                 keep_snapshots: int = 3,
                 backend: Optional[str] = None,
                 abnorm_thd: float = 1.3, min_share: float = 0.01,
                 top_k: int = 20, max_abnormal: int = 10,
                 max_reports: int = 64,
                 on_report: Optional[Callable[[MonitorReport], None]] = None,
                 clock: Callable[[], float] = time.monotonic,
                 title: str = "ScalAna monitor report"):
        self.psg = psg
        self.transport = transport
        self.store = ShardedStore(ranges, len(psg.vertices))
        self.ppg = PPG(psg, self.store.n_procs, self.store)
        if comm is not None:
            self.ppg.comm = comm
        self.detect_every = positive_int("detect_every", detect_every,
                                         allow_none=True)
        self.drift_threshold = fraction("drift_threshold", drift_threshold,
                                        allow_none=True)
        self.interval = positive_float("interval", interval,
                                       allow_none=True)
        self.stale_after = positive_float("stale_after", stale_after,
                                          allow_none=True)
        if backend not in (None, "numpy", "jax", "auto"):
            raise ValueError(f"unknown detect backend: {backend!r}; "
                             f"valid values are 'numpy', 'jax', 'auto'")
        self.backend = backend
        self.abnorm_thd = positive_float("abnorm_thd", abnorm_thd)
        self.min_share = probability("min_share", min_share)
        self.top_k = positive_int("top_k", top_k)
        self.max_abnormal = positive_int("max_abnormal", max_abnormal)
        self.max_reports = positive_int("max_reports", max_reports)
        self.on_report = on_report
        # one Clock behind the legacy callable knob (repro.monitor.clock)
        self._clock = as_clock(clock)
        self.clock = self._clock.monotonic
        self.title = title

        H = len(self.store.shards)
        self.high: Dict[int, int] = {h: 0 for h in range(H)}
        self.acked: Dict[int, int] = {h: 0 for h in range(H)}
        self.parked: Dict[int, Dict[int, ShardDelta]] = \
            {h: {} for h in range(H)}
        now = self.clock()
        self.last_seen: Dict[int, float] = {h: now for h in range(H)}

        self.applied = 0
        self.duplicates = 0
        self.detects = 0
        self.reports: List[MonitorReport] = []
        self._applied_since_detect = 0
        self._touched = np.zeros(self.store.n_procs, bool)
        self._last_detect_time = now

        self.snapshot_dir = snapshot_dir
        self.snapshot_every = positive_int("snapshot_every", snapshot_every)
        self._applied_since_snapshot = 0
        self._snap_step = 0
        self._ckpt = CheckpointManager(
            snapshot_dir, keep=positive_int("keep_snapshots",
                                            keep_snapshots)) \
            if snapshot_dir else None

        self._lock = threading.RLock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- ingestion -----------------------------------------------------
    def poll(self, max_messages: Optional[int] = None
             ) -> Optional[MonitorReport]:
        """Drain the transport, fold deltas, detect if a trigger fired.
        Returns the new report, or None."""
        with self._lock:
            for msg in self.transport.recv(max_messages):
                if isinstance(msg, ShardDelta):
                    self._ingest(msg)
                elif isinstance(msg, Heartbeat):
                    if msg.host in self.high:
                        self.last_seen[msg.host] = self.clock()
            if self._should_detect():
                return self._detect_locked()
            return None

    def _ingest(self, d: ShardDelta) -> None:
        host = d.host
        if host not in self.high:
            return                           # unknown host: ignore
        if d.seq <= self.high[host] or d.seq in self.parked[host]:
            self.duplicates += 1             # absorbed exactly, by sequence
            return
        self.parked[host][d.seq] = d
        # apply the in-order run the parking lot now covers
        while self.high[host] + 1 in self.parked[host]:
            nxt = self.parked[host].pop(self.high[host] + 1)
            self._apply(nxt)
            self.high[host] += 1
        self.last_seen[host] = self.clock()
        if self._ckpt is None:
            # no snapshots: delivery itself is as durable as we get
            self.acked[host] = self.high[host]
        elif self._applied_since_snapshot >= self.snapshot_every:
            self._snapshot_locked()

    def _apply(self, d: ShardDelta) -> None:
        sh = self.store.shards[d.host]
        sh.ensure_columns(d.block.n_cols)
        sh.apply_rows(d.block)               # block.rows are shard-local
        self.applied += 1
        self._applied_since_detect += 1
        self._applied_since_snapshot += 1
        self._touched[d.block.rows + sh.proc_start] = True

    # -- fleet health --------------------------------------------------
    def live_hosts(self) -> List[int]:
        if self.stale_after is None:
            return sorted(self.high)
        now = self.clock()
        return [h for h in sorted(self.high)
                if now - self.last_seen[h] <= self.stale_after]

    def proc_mask(self) -> np.ndarray:
        """(n_procs,) bool: True where the owning host is live."""
        mask = np.zeros(self.store.n_procs, bool)
        live = set(self.live_hosts())
        for h, sh in enumerate(self.store.shards):
            if h in live:
                mask[sh.proc_start:sh.proc_stop] = True
        return mask

    def fleet_status(self) -> FleetStatus:
        with self._lock:
            live = set(self.live_hosts())
            hosts = [HostStatus(host=h, high=self.high[h],
                                acked=self.acked[h],
                                parked=len(self.parked[h]),
                                last_seen=self.last_seen[h],
                                live=h in live)
                     for h in sorted(self.high)]
            mask = self.proc_mask()
            return FleetStatus(hosts=hosts, live_hosts=len(live),
                               total_hosts=len(hosts),
                               live_procs=int(mask.sum()),
                               total_procs=self.store.n_procs)

    # -- detection -----------------------------------------------------
    def _should_detect(self) -> bool:
        if self._applied_since_detect <= 0:
            return False
        if self.detect_every is not None \
                and self._applied_since_detect >= self.detect_every:
            return True
        if self.drift_threshold is not None \
                and self._touched.mean() >= self.drift_threshold:
            return True
        if self.interval is not None \
                and self.clock() - self._last_detect_time >= self.interval:
            return True
        return False

    def force_detect(self) -> MonitorReport:
        """Detect now, regardless of triggers (end-of-run / on-demand)."""
        with self._lock:
            return self._detect_locked()

    def _detect_locked(self) -> MonitorReport:
        mask = self.proc_mask()
        live_hosts = self.live_hosts()
        n_live = int(mask.sum())
        H = len(self.store.shards)
        degraded = n_live < self.store.n_procs
        coverage = (f"fleet coverage: {n_live}/{self.store.n_procs} procs, "
                    f"{len(live_hosts)}/{H} hosts live")
        if degraded:
            dead = sorted(set(self.high) - set(live_hosts))
            coverage += " (DEGRADED: host" + ("s " if len(dead) > 1 else " ") \
                + ", ".join(f"h{h}" for h in dead) + " excluded)"

        if not degraded:
            ab = detect_abnormal(self.ppg, abnorm_thd=self.abnorm_thd,
                                 min_share=self.min_share, top_k=self.top_k,
                                 backend=self.backend)
            paths = backtrack(self.ppg, [], ab)
        elif n_live == 0:
            ab, paths = [], []
        else:
            live_idx = np.nonzero(mask)[0]
            # masked detection: stale rows excluded down in the kernels
            ab = detect_abnormal(self.ppg, abnorm_thd=self.abnorm_thd,
                                 min_share=self.min_share, top_k=self.top_k,
                                 backend=self.backend, proc_mask=mask)
            # backtracking walks the live-compacted graph; its local proc
            # indices lift back to global ones for the report
            pos = np.full(self.store.n_procs, -1, np.intp)
            pos[live_idx] = np.arange(live_idx.size)
            sub = live_subppg(self.ppg, live_idx)
            ab_local = [dataclasses.replace(a, proc=int(pos[a.proc]))
                        for a in ab]
            paths = remap_paths(backtrack(sub, [], ab_local), live_idx)

        text = render_report(self.ppg, [], ab, paths, title=self.title,
                             max_abnormal=self.max_abnormal,
                             coverage=coverage)
        report = MonitorReport(
            index=self.detects, text=text, abnormal=ab, paths=paths,
            coverage=coverage, live_procs=n_live,
            total_procs=self.store.n_procs, live_hosts=len(live_hosts),
            total_hosts=H, applied=self.applied, duplicates=self.duplicates,
            parked=sum(len(p) for p in self.parked.values()))
        self.detects += 1
        self._applied_since_detect = 0
        self._touched[:] = False
        self._last_detect_time = self.clock()
        self.reports.append(report)
        del self.reports[:-self.max_reports]
        if self.on_report is not None:
            self.on_report(report)
        return report

    # -- snapshots / recovery ------------------------------------------
    def snapshot(self) -> None:
        """Snapshot the store + sequence windows now (normally automatic
        every ``snapshot_every`` applied deltas)."""
        with self._lock:
            if self._ckpt is None:
                raise RuntimeError("monitor has no snapshot_dir")
            self._snapshot_locked()

    def _snapshot_locked(self) -> None:
        # the store serializes through the one to_tree seam (same path
        # the run store persists with); the snapshot keeps its original
        # on-disk layout — per-shard trees under "shards", layout metas
        # under "shard_meta" — so pre-seam snapshots restore unchanged
        store_tree, store_meta = self.store.to_tree()
        tree: Dict[str, Dict] = {"shards": store_tree["shards"]}
        extra = {
            "ranges": store_meta["ranges"],
            "high": {str(h): int(s) for h, s in self.high.items()},
            "applied": self.applied,
            "duplicates": self.duplicates,
            "detects": self.detects,
            "shard_meta": {f"s{i}": m
                           for i, m in enumerate(store_meta["shards"])},
        }
        self._ckpt.save(self._snap_step, tree, blocking=True,
                        extra_meta=extra)
        self._snap_step += 1
        self._applied_since_snapshot = 0
        # the snapshot commit is the durability point: ack up to it
        for h in self.high:
            self.acked[h] = self.high[h]

    @classmethod
    def restore(cls, psg: PSG, transport: Transport, snapshot_dir: str,
                **kwargs) -> "Monitor":
        """Rebuild a crashed aggregator from its latest snapshot.

        The store contents and per-host sequence high-water marks come
        back exactly; parked (not-yet-applied) deltas were never acked,
        so producers' ``resend_unacked()`` replays them and the sequence
        windows drop whatever the snapshot already contained."""
        step = latest_step(snapshot_dir)
        if step is None:
            raise FileNotFoundError(
                f"no committed snapshot under {snapshot_dir!r}")
        tree, meta = load_checkpoint_tree(snapshot_dir, step)
        ranges = [tuple(r) for r in meta["ranges"]]
        mon = cls(psg, ranges, transport, snapshot_dir=snapshot_dir,
                  **kwargs)
        for i, sh in enumerate(mon.store.shards):
            key = f"s{i}"
            sh.load_tree(tree["shards"][key], meta["shard_meta"][key])
        mon.high = {int(h): int(s) for h, s in meta["high"].items()}
        mon.acked = dict(mon.high)
        mon.applied = int(meta["applied"])
        mon.duplicates = int(meta["duplicates"])
        mon.detects = int(meta["detects"])
        mon._snap_step = step + 1
        return mon

    def acked_seq(self, host: int) -> int:
        """What this host's producer may safely forget up to."""
        with self._lock:
            return self.acked.get(host, 0)

    # -- run-store archival --------------------------------------------
    def archive_to(self, run_store, *, run_id: Optional[str] = None,
                   meta: Optional[Dict] = None) -> str:
        """Record the current fleet state as one run in a
        :class:`repro.runs.RunStore` — the always-on service accumulates
        history instead of discarding each report.

        The full PPG (sharded store, comm index, PSG) and the latest
        report's abnormal set go through the same ``to_tree`` seam the
        crash snapshot uses.  Returns the new run id."""
        with self._lock:
            report = self.reports[-1] if self.reports else None
            detect = {"abnormal": list(report.abnormal)} if report else None
            run_meta = {"scale": int(self.store.n_procs),
                        "applied": int(self.applied),
                        "detects": int(self.detects)}
            run_meta.update(meta or {})
            return run_store.record(ppg=self.ppg, detect=detect,
                                    run_id=run_id, meta=run_meta)

    # -- always-on service mode ----------------------------------------
    def start(self, poll_interval: float = 0.05) -> None:
        """Run the poll loop in a daemon thread until :meth:`stop`."""
        if self._thread is not None:
            raise RuntimeError("monitor already started")
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self.poll()
                self._stop.wait(poll_interval)

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
