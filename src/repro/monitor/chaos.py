"""Seeded chaos harness: stream a known workload through a faulty fleet.

:func:`chaos_run` is both the monitor's acceptance test and a user-facing
rehearsal tool: it simulates a straggler workload into per-host truth
shards, replays that state to a :class:`~repro.monitor.aggregator.Monitor`
as multiple rounds of row deltas per host (each round widens the column
prefix, so out-of-order application would leave visibly stale rows)
through a :class:`~repro.monitor.transport.FaultyTransport` with seeded
drop/duplicate/delay/ack-loss schedules, then checks the convergence
contract:

* clean fleet — the monitor's final detect/backtrack output is
  BIT-IDENTICAL to a one-shot run on the fully-assembled store;
* with permanently dead hosts — identical to a one-shot run restricted
  to the live rows, and the report states fleet coverage;
* with an aggregator crash mid-run — :meth:`Monitor.restore` from the
  latest snapshot plus producer ``resend_unacked()`` converges to the
  same result.

Everything is deterministic: seeded faults, an injected virtual clock,
and no-op backoff sleeps.  ``tools/chaos_smoke.py`` wires this into
``make check``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.backtrack import Path, backtrack
from repro.core.detect import Abnormal, detect_abnormal
from repro.core.graph import COMM, COMP, PSG, RowBlock
from repro.core.inject import simulate
from repro.core.shard import shard_ranges
from repro.monitor.aggregator import Monitor, MonitorReport
from repro.monitor.degraded import live_subppg, remap_paths
from repro.monitor.producer import ShardProducer
from repro.monitor.transport import FaultyTransport


def build_chaos_psg(n_comp: int = 12) -> PSG:
    """A step-shaped workload: comp chain + one all-reduce (the straggler
    sink every backtrack path should reach)."""
    g = PSG()
    root = g.new_vertex("Root", "root")
    g.root = root.vid
    prev = None
    for i in range(n_comp):
        v = g.new_vertex(COMP, f"comp{i}", parent=root.vid,
                         source=f"model.py:{10 + i}")
        v.flops = 100.0
        if prev is not None:
            g.add_edge(prev, v.vid, "data")
        g.add_edge(root.vid, v.vid, "control")
        prev = v.vid
    c = g.new_vertex(COMM, "all_reduce", parent=root.vid, source="step.py:7")
    c.comm_kind, c.comm_bytes = "all_reduce", 1e6
    g.add_edge(prev, c.vid, "data")
    g.add_edge(root.vid, c.vid, "control")
    return g


def _truncated(block: RowBlock, n_cols: int) -> RowBlock:
    """The block as if only the first ``n_cols`` columns existed yet —
    the intermediate rounds' row state (the final round sends the full
    block, so in-order convergence reproduces the truth exactly)."""
    time = block.time.copy()
    var = block.time_var.copy()
    samples = block.samples.copy()
    mask = block.mask.copy()
    time[:, n_cols:] = 0.0
    var[:, n_cols:] = 0.0
    samples[:, n_cols:] = 0
    mask[:, n_cols:] = False
    counters = {}
    for name, (vids, values, cmask) in block.counters.items():
        keep = vids < n_cols
        if keep.any():
            counters[name] = (vids[keep].copy(), values[:, keep].copy(),
                              cmask[:, keep].copy())
    return RowBlock(rows=block.rows.copy(), n_cols=block.n_cols,
                    time=time, time_var=var, samples=samples, mask=mask,
                    counters=counters)


@dataclasses.dataclass
class ChaosResult:
    report: MonitorReport          # the monitor's final (converged) report
    abnormal_ref: List[Abnormal]   # one-shot reference output
    paths_ref: List[Path]
    abnormal_match: bool           # bit-identical detection?
    paths_match: bool
    coverage_stated: bool          # report text carries the coverage line
    transport_stats: Dict[str, int]
    duplicates_absorbed: int
    deltas_applied: int
    rounds: int
    # the socket scenario also proves the stronger invariants; the
    # queue-transport run leaves them True (they are implied by
    # abnormal/paths matching on identical stores)
    store_match: bool = True       # converged store == producers' shards
    report_match: bool = True      # rendered text == one-shot render

    @property
    def converged(self) -> bool:
        return self.abnormal_match and self.paths_match \
            and self.coverage_stated and self.store_match \
            and self.report_match


def _ab_key(a: Abnormal) -> tuple:
    return (a.vid, a.proc, a.time, a.typical, a.ratio)


def chaos_run(*, n_procs: int = 64, n_hosts: int = 8, rounds: int = 4,
              seed: int = 0, p_drop: float = 0.2, p_ack_loss: float = 0.1,
              p_dup: float = 0.15, p_delay: float = 0.3, max_delay: int = 3,
              outages: Sequence[Tuple[int, int]] = (),
              dead_hosts: Sequence[int] = (),
              snapshot_dir: Optional[str] = None,
              crash_after_round: Optional[int] = None,
              backend: Optional[str] = "numpy",
              detect_every: Optional[int] = 4,
              n_comp: int = 12) -> ChaosResult:
    """Run the full chaos scenario; see the module docstring.

    ``dead_hosts`` never send anything and go stale; ``crash_after_round``
    (requires ``snapshot_dir``) discards the aggregator after that round
    and restores it from the latest snapshot.  The faulty schedule is
    fully determined by ``seed``.
    """
    if crash_after_round is not None and snapshot_dir is None:
        raise ValueError("crash_after_round requires snapshot_dir")
    psg = build_chaos_psg(n_comp)
    V = len(psg.vertices)
    comm_vid = V - 1
    rng = np.random.default_rng(seed)
    straggler = int(rng.integers(n_procs))
    slow_vid = int(rng.integers(1, V - 1))

    def base(p, vid):
        v = psg.vertices[vid]
        return 0.0 if v.kind == COMM else 1.0 + 0.01 * vid

    ranges = shard_ranges(n_procs, n_hosts)
    sim = simulate(psg, n_procs, base,
                   inject={(straggler, slow_vid): 4.0},
                   comm_time=lambda *a: 0.05, jitter=0.0, seed=seed,
                   shards=ranges)
    truth_ppg = sim.ppg

    dead = set(int(h) for h in dead_hosts)
    H = len(truth_ppg.perf.shards)
    live_hosts = [h for h in range(H) if h not in dead]

    # -- one-shot reference ---------------------------------------------
    if dead:
        live_idx = np.concatenate(
            [np.arange(truth_ppg.perf.shards[h].proc_start,
                       truth_ppg.perf.shards[h].proc_stop)
             for h in live_hosts])
        sub = live_subppg(truth_ppg, live_idx)
        ab_local = detect_abnormal(sub, backend=backend)
        abnormal_ref = [dataclasses.replace(a, proc=int(live_idx[a.proc]))
                        for a in ab_local]
        paths_ref = remap_paths(backtrack(sub, [], ab_local), live_idx)
    else:
        abnormal_ref = detect_abnormal(truth_ppg, backend=backend)
        paths_ref = backtrack(truth_ppg, [], abnormal_ref)

    # -- the streaming fleet --------------------------------------------
    vclock = [0.0]
    clock = lambda: vclock[0]                           # noqa: E731
    transport = FaultyTransport(seed=seed, p_drop=p_drop,
                                p_ack_loss=p_ack_loss, p_dup=p_dup,
                                p_delay=p_delay, max_delay=max_delay,
                                outages=outages)
    monitor = Monitor(psg, ranges, transport, comm=truth_ppg.comm,
                      detect_every=detect_every, stale_after=2.5,
                      snapshot_dir=snapshot_dir, snapshot_every=n_hosts,
                      backend=backend, clock=clock)
    producers = {}
    from repro.core.shard import ShardedStore
    prod_store = ShardedStore(ranges, V)
    for h in live_hosts:
        producers[h] = ShardProducer(h, prod_store.shards[h], transport,
                                     clock=clock, sleep=lambda s: None)

    every: Dict[int, np.ndarray] = {
        h: np.arange(prod_store.shards[h].n_procs) for h in live_hosts}
    for r in range(1, rounds + 1):
        c_r = max(1, (V * r) // rounds)
        for h in live_hosts:
            truth_block = truth_ppg.perf.shards[h].extract_rows(every[h])
            block = truth_block if r == rounds \
                else _truncated(truth_block, c_r)
            prod_store.shards[h].apply_rows(block)
            producers[h].flush()
        vclock[0] += 1.0
        monitor.poll()
        for h, p in producers.items():
            p.ack(monitor.acked_seq(h))
        if crash_after_round is not None and r == crash_after_round:
            # the aggregator dies with whatever its PERIODIC snapshots
            # captured; everything after the last commit was never acked,
            # so the producers still hold it
            del monitor
            monitor = Monitor.restore(psg, transport, snapshot_dir,
                                      comm=truth_ppg.comm,
                                      detect_every=detect_every,
                                      stale_after=2.5, backend=backend,
                                      clock=clock)
            monitor.last_seen = {h: clock() for h in monitor.last_seen}
            for p in producers.values():
                p.resend_unacked()

    # eventual delivery: release held messages, flush retry backlogs, and
    # poll until every live host's stream is fully applied
    for _ in range(64):
        transport.flush_held()
        for h, p in producers.items():
            p.flush(heartbeat=False)
        monitor.poll()
        if all(monitor.high[h] >= producers[h].seq
               and not monitor.parked[h] for h in live_hosts):
            break
    else:
        raise RuntimeError("chaos run did not converge: "
                           f"high={monitor.high} "
                           f"seqs={ {h: p.seq for h, p in producers.items()} }")
    vclock[0] += 5.0                         # dead hosts go stale
    for _ in range(64):                      # heartbeats are lossy too:
        for h in live_hosts:                 # repeat until every live host
            producers[h].send_heartbeat()    # is seen fresh
        monitor.poll()
        if monitor.live_hosts() == live_hosts:
            break
    else:
        raise RuntimeError(f"live set never settled: "
                           f"{monitor.live_hosts()} != {live_hosts}")

    report = monitor.force_detect()
    got = [_ab_key(a) for a in report.abnormal]
    want = [_ab_key(a) for a in abnormal_ref]
    paths_got = [(p.start_reason, p.nodes) for p in report.paths]
    paths_want = [(p.start_reason, p.nodes) for p in paths_ref]
    return ChaosResult(
        report=report, abnormal_ref=abnormal_ref, paths_ref=paths_ref,
        abnormal_match=got == want, paths_match=paths_got == paths_want,
        coverage_stated="fleet coverage:" in report.text,
        transport_stats=dict(transport.stats),
        duplicates_absorbed=monitor.duplicates,
        deltas_applied=monitor.applied, rounds=rounds)
