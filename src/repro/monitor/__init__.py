"""Always-on monitoring: streaming shard ingestion + resident detection.

The package turns the one-shot detect/backtrack pipeline into a
fault-tolerant service (ROADMAP: "always-on monitor"):

* :mod:`~repro.monitor.transport` — the pluggable delivery seam
  (``Transport`` / ``QueueTransport``) and the seeded fault injector
  (``FaultyTransport``).
* :mod:`~repro.monitor.producer` — per-host dirty-row flushing with
  sequence numbers, retry/backoff, and an unacked buffer
  (``ShardProducer`` / ``ShardDelta`` / ``Heartbeat``).
* :mod:`~repro.monitor.aggregator` — the resident ``Monitor``:
  idempotent sequence-window ingestion, heartbeats/staleness, degraded
  (live-subfleet) detection, snapshot/restore, report streaming.
* :mod:`~repro.monitor.degraded` — live-subfleet PPG compaction.
* :mod:`~repro.monitor.chaos` — the end-to-end chaos harness
  (``chaos_run``), used by tests, ``make chaos-smoke`` and benchmarks.
* :mod:`~repro.monitor.wire` — the versioned wire protocol: CRC-checked
  length-prefixed frames plus the delta-compression codec
  (``DeltaEncoder`` / ``DeltaDecoder`` / ``FrameReader``).
* :mod:`~repro.monitor.net` — the real-network transport:
  ``SocketTransport`` (reconnecting TCP client) / ``SocketServer``
  (aggregator accept/drain loop) / ``SocketChaosProxy`` (real-socket
  fault injection) and the end-to-end ``socket_chaos_run`` scenario.
* :mod:`~repro.monitor.clock` — the injectable time seam
  (``Clock`` / ``SystemClock`` / ``ManualClock``).

Imports stay jax-free (detection backends resolve lazily, exactly as in
one-shot use).
"""
from repro.monitor.aggregator import (FleetStatus, HostStatus, Monitor,
                                      MonitorReport)
from repro.monitor.chaos import ChaosResult, build_chaos_psg, chaos_run
from repro.monitor.clock import Clock, ManualClock, SystemClock, as_clock
from repro.monitor.degraded import live_subppg, remap_paths
from repro.monitor.net import (ProducerLink, SocketChaosProxy, SocketServer,
                               SocketTransport, socket_chaos_run,
                               stores_equal)
from repro.monitor.producer import Heartbeat, ShardDelta, ShardProducer
from repro.monitor.transport import (FaultyTransport, QueueTransport,
                                     Transport, TransportError)
from repro.monitor.wire import (Ack, DeltaDecoder, DeltaEncoder, FrameReader,
                                WireError, decode_message, encode_frame,
                                encode_message)

__all__ = [
    "Ack", "ChaosResult", "Clock", "DeltaDecoder", "DeltaEncoder",
    "FaultyTransport", "FleetStatus", "FrameReader", "Heartbeat",
    "HostStatus", "ManualClock", "Monitor", "MonitorReport", "ProducerLink",
    "QueueTransport", "ShardDelta", "ShardProducer", "SocketChaosProxy",
    "SocketServer", "SocketTransport", "SystemClock", "Transport",
    "TransportError", "WireError", "as_clock", "build_chaos_psg",
    "chaos_run", "decode_message", "encode_frame", "encode_message",
    "live_subppg", "remap_paths", "socket_chaos_run", "stores_equal",
]
