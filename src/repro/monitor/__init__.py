"""Always-on monitoring: streaming shard ingestion + resident detection.

The package turns the one-shot detect/backtrack pipeline into a
fault-tolerant service (ROADMAP: "always-on monitor"):

* :mod:`~repro.monitor.transport` — the pluggable delivery seam
  (``Transport`` / ``QueueTransport``) and the seeded fault injector
  (``FaultyTransport``).
* :mod:`~repro.monitor.producer` — per-host dirty-row flushing with
  sequence numbers, retry/backoff, and an unacked buffer
  (``ShardProducer`` / ``ShardDelta`` / ``Heartbeat``).
* :mod:`~repro.monitor.aggregator` — the resident ``Monitor``:
  idempotent sequence-window ingestion, heartbeats/staleness, degraded
  (live-subfleet) detection, snapshot/restore, report streaming.
* :mod:`~repro.monitor.degraded` — live-subfleet PPG compaction.
* :mod:`~repro.monitor.chaos` — the end-to-end chaos harness
  (``chaos_run``), used by tests, ``make chaos-smoke`` and benchmarks.

Imports stay jax-free (detection backends resolve lazily, exactly as in
one-shot use).
"""
from repro.monitor.aggregator import (FleetStatus, HostStatus, Monitor,
                                      MonitorReport)
from repro.monitor.chaos import ChaosResult, build_chaos_psg, chaos_run
from repro.monitor.degraded import live_subppg, remap_paths
from repro.monitor.producer import Heartbeat, ShardDelta, ShardProducer
from repro.monitor.transport import (FaultyTransport, QueueTransport,
                                     Transport, TransportError)

__all__ = [
    "ChaosResult", "FaultyTransport", "FleetStatus", "Heartbeat",
    "HostStatus", "Monitor", "MonitorReport", "QueueTransport",
    "ShardDelta", "ShardProducer", "Transport", "TransportError",
    "build_chaos_psg", "chaos_run", "live_subppg", "remap_paths",
]
