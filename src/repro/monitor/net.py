"""Real-socket monitor transport: TCP client/server over the wire protocol.

The third implementation of the three-method :class:`~repro.monitor.
transport.Transport` seam (after ``QueueTransport`` and
``FaultyTransport``), carrying the monitor across a real network:

* :class:`SocketTransport` — the producer-side TCP client.  ``send``
  frames the message through :mod:`repro.monitor.wire` (delta
  compression on by default) and writes it; a dead link tears the
  connection down and raises :class:`~repro.monitor.transport.
  TransportError` (the producer's retry/backoff handles it); the next
  send reconnects with jittered exponential backoff and fires
  ``on_reconnect`` hooks — :class:`ProducerLink` uses them to resend
  the producer's unacked deltas, and the fresh connection's encoder
  re-seeds the compression cache from full rows.  Acks stream back on
  the same socket and are applied opportunistically on every send.

* :class:`SocketServer` — the aggregator-side accept/drain loop (one
  ``selectors`` thread for all connections).  Decoded messages queue up
  behind the standard ``recv()``/``pending()`` API, so the resident
  :class:`~repro.monitor.aggregator.Monitor` consumes a socket fleet
  unchanged.  ``send_acks`` pushes cumulative per-host acks back to
  each host's latest connection.

* :class:`SocketChaosProxy` — a seeded TCP fault injector sitting
  between clients and server, exercising the failures an in-process
  ``FaultyTransport`` cannot: connection RESETS, TORN frames (a prefix
  of a chunk delivered, then reset mid-write), injected GARBAGE bytes
  (frame resync on the server), and stalls.

* :func:`socket_chaos_run` — the end-to-end acceptance scenario: a
  known workload streamed through the proxy must leave the monitor's
  converged store AND rendered report bit-identical to the fault-free
  one-shot run.

Everything here is stdlib + numpy; jax never enters.
"""
from __future__ import annotations

import collections
import dataclasses
import random
import selectors
import socket
import struct
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.monitor.clock import Clock, as_clock
from repro.monitor.transport import Transport, TransportError
from repro.monitor.producer import Heartbeat, ShardDelta, ShardProducer
from repro.monitor.validate import (backoff_bounds, port_number,
                                    positive_float, positive_int,
                                    probability)
from repro.monitor.wire import (Ack, DEFAULT_MAX_FRAME, DeltaDecoder,
                                DeltaEncoder, FrameReader, WireError,
                                decode_message, encode_message)

_RECV_CHUNK = 1 << 16


# ---------------------------------------------------------------------------
# client
# ---------------------------------------------------------------------------

class SocketTransport(Transport):
    """Producer-side TCP transport with reconnect + delta compression.

    One instance per connection; several producers may share it (host
    ids travel inside the messages).  Thread-safe; all socket work
    happens inside the caller's ``send``/``recv``, no background
    thread.

    Reconnect policy: the first ``send`` after a teardown retries the
    TCP connect up to ``connect_attempts`` times with jittered
    exponential backoff (``backoff_base`` doubling to ``backoff_max``,
    each sleep stretched by up to ``jitter`` of itself, seeded) through
    the injected clock — deterministic under a
    :class:`~repro.monitor.clock.ManualClock`.  If every attempt fails,
    ``send`` raises :class:`TransportError` and the producer's own
    backoff takes over.
    """

    def __init__(self, address: Tuple[str, int], *,
                 compress: bool = True,
                 connect_attempts: int = 5,
                 connect_timeout: float = 5.0,
                 send_timeout: float = 5.0,
                 backoff_base: float = 0.05,
                 backoff_max: float = 2.0,
                 jitter: float = 0.5,
                 seed: int = 0,
                 max_frame: int = DEFAULT_MAX_FRAME,
                 clock: Optional[Clock] = None):
        host, port = address
        self.address = (str(host), port_number("address port", port,
                                               allow_zero=False))
        self.compress = bool(compress)
        self.connect_attempts = positive_int("connect_attempts",
                                             connect_attempts)
        self.connect_timeout = positive_float("connect_timeout",
                                              connect_timeout)
        self.send_timeout = positive_float("send_timeout", send_timeout)
        self.backoff_base, self.backoff_max = backoff_bounds(
            "backoff_base", backoff_base, "backoff_max", backoff_max)
        self.jitter = probability("jitter", jitter)
        self.max_frame = positive_int("max_frame", max_frame)
        self.clock = as_clock(clock)
        self.rng = random.Random(seed)
        self.acks: Dict[int, int] = {}
        self.on_reconnect: List[Callable[[], None]] = []
        self.on_ack: List[Callable[[Dict[int, int]], None]] = []
        self.stats: Dict[str, int] = collections.Counter()
        self._sock: Optional[socket.socket] = None
        self._encoder = DeltaEncoder(compress=self.compress)
        self._ack_reader = FrameReader(self.max_frame)
        self._ever_connected = False
        self._in_reconnect_hooks = False
        self._lock = threading.RLock()

    # -- connection lifecycle ------------------------------------------
    def _teardown(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None
            self.stats["disconnects"] += 1

    def _ensure_connected(self) -> socket.socket:
        if self._sock is not None:
            return self._sock
        delay = self.backoff_base
        last_err: Optional[Exception] = None
        for attempt in range(self.connect_attempts):
            if attempt:
                self.clock.sleep(delay * (1.0 + self.jitter
                                          * self.rng.random()))
                delay = min(2.0 * delay, self.backoff_max)
            try:
                s = socket.create_connection(
                    self.address, timeout=self.connect_timeout)
            except OSError as e:
                last_err = e
                self.stats["connect_failures"] += 1
                continue
            s.settimeout(self.send_timeout)
            try:
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass
            self._sock = s
            self._encoder = DeltaEncoder(compress=self.compress)
            self._ack_reader = FrameReader(self.max_frame)
            self.stats["connects"] += 1
            was_reconnect = self._ever_connected
            self._ever_connected = True
            if was_reconnect:
                self.stats["reconnects"] += 1
                self._fire_reconnect_hooks()
            if self._sock is not None:
                return self._sock
            # a reconnect hook's own send died and tore the fresh
            # connection down; keep retrying with backoff
            last_err = TransportError("connection lost while replaying "
                                      "unacked deltas")
        raise TransportError(
            f"cannot connect to {self.address[0]}:{self.address[1]} "
            f"after {self.connect_attempts} attempts: {last_err}")

    def _fire_reconnect_hooks(self) -> None:
        # hooks resend unacked deltas, which re-enters send(); guard so
        # a reconnect during that resend does not recurse
        if self._in_reconnect_hooks:
            return
        self._in_reconnect_hooks = True
        try:
            for cb in list(self.on_reconnect):
                cb()
        finally:
            self._in_reconnect_hooks = False

    # -- Transport -----------------------------------------------------
    def send(self, msg) -> None:
        with self._lock:
            sock = self._ensure_connected()
            try:
                data = encode_message(msg, self._encoder,
                                      max_frame=self.max_frame)
            except WireError as e:
                # the receiver would discard the frame as oversize and
                # it would be resent forever; the encoder cache is also
                # ahead of a frame that never left — tear down so both
                # codec caches reset, and fail loudly
                self._teardown()
                raise TransportError(
                    f"frame for {self.address[0]}:{self.address[1]} "
                    f"exceeds max_frame: {e}") from None
            try:
                sock.sendall(data)
            except (OSError, ValueError) as e:
                # the encoder cache is ahead of the wire now; tearing the
                # connection down resets both sides to full rows
                self._teardown()
                raise TransportError(f"send to {self.address[0]}:"
                                     f"{self.address[1]} failed: {e}") \
                    from None
            self.stats["sent"] += 1
            self.stats["sent_bytes"] += len(data)
            if isinstance(msg, ShardDelta):
                self.stats["delta_bytes"] += len(data)
            self._pump_acks_locked()

    def recv(self, max_messages: Optional[int] = None) -> List:
        """The client side delivers nothing; draining it just pumps acks
        (so a composed ``FaultyTransport.recv`` keeps working)."""
        with self._lock:
            if self._sock is not None:
                self._pump_acks_locked()
        return []

    def pending(self) -> int:
        return 0

    def close(self) -> None:
        with self._lock:
            self._teardown()

    # -- acks ----------------------------------------------------------
    def _pump_acks_locked(self) -> None:
        sock = self._sock
        if sock is None:
            return
        import select
        while True:
            try:
                ready, _, _ = select.select([sock], [], [], 0)
            except (OSError, ValueError):
                self._teardown()
                return
            if not ready:
                return
            try:
                data = sock.recv(_RECV_CHUNK)
            except (OSError, ValueError):
                self._teardown()
                return
            if not data:
                self._teardown()
                return
            for msg_type, payload in self._ack_reader.feed(data):
                try:
                    m = decode_message(msg_type, payload)
                except WireError:
                    self.stats["bad_acks"] += 1
                    continue
                if isinstance(m, Ack):
                    self.acks.update(m.acks)
                    self.stats["acks"] += 1
                    for cb in list(self.on_ack):
                        cb(m.acks)


class ProducerLink:
    """Wire one :class:`ShardProducer` to a :class:`SocketTransport`.

    * acks arriving on the socket advance ``producer.ack`` (durable
      forgetting);
    * a successful RE-connect replays the producer's unacked buffer
      (the server's sequence windows drop whatever it already owns);
    * :meth:`tick` resends the unacked buffer when acks have stalled
      for ``resend_after`` seconds — the recovery path for deltas that
      died on the wire without killing the connection (e.g. frames
      lost to a garbage resync).
    """

    def __init__(self, producer: ShardProducer, transport: SocketTransport,
                 *, resend_after: Optional[float] = None,
                 clock: Optional[Clock] = None):
        self.producer = producer
        self.transport = transport
        self.resend_after = positive_float("resend_after", resend_after,
                                           allow_none=True)
        self.clock = as_clock(clock) if clock is not None \
            else transport.clock
        self._last_progress = self.clock.monotonic()
        transport.on_ack.append(self._on_ack)
        transport.on_reconnect.append(self._on_reconnect)

    def _on_ack(self, acks: Dict[int, int]) -> None:
        seq = acks.get(self.producer.host)
        if seq is None:
            return
        if seq > self.producer.acked:
            self._last_progress = self.clock.monotonic()
        self.producer.ack(seq)

    def _on_reconnect(self) -> None:
        self._last_progress = self.clock.monotonic()
        self.producer.resend_unacked()

    def tick(self) -> int:
        """Resend unacked deltas if acks have stalled; returns resent
        count."""
        if self.resend_after is None or not self.producer.unacked:
            return 0
        if self.clock.monotonic() - self._last_progress < self.resend_after:
            return 0
        self._last_progress = self.clock.monotonic()
        return self.producer.resend_unacked()


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------

class _Conn:
    __slots__ = ("sock", "reader", "decoder", "outbuf", "events")

    def __init__(self, sock: socket.socket, max_frame: int):
        self.sock = sock
        self.reader = FrameReader(max_frame)
        self.decoder = DeltaDecoder()
        self.outbuf = bytearray()
        self.events = selectors.EVENT_READ


class SocketServer(Transport):
    """Aggregator-side TCP endpoint implementing the Transport seam.

    ``start()`` spawns ONE IO thread: a ``selectors`` loop that accepts
    connections, reassembles + decodes frames per connection, and queues
    the decoded :class:`ShardDelta` / :class:`Heartbeat` messages for
    ``recv()`` — the resident :class:`~repro.monitor.aggregator.Monitor`
    polls a socket fleet exactly as it polls a ``QueueTransport``.

    ``send_acks({host: seq})`` pushes cumulative acknowledgements back
    over each host's most recent connection; the driver typically calls
    it with ``monitor.acked_seq`` after each poll.

    Usable as a context manager (``with SocketServer() as srv:``).
    """

    def __init__(self, address: Tuple[str, int] = ("127.0.0.1", 0), *,
                 backlog: int = 128,
                 max_frame: int = DEFAULT_MAX_FRAME):
        host, port = address
        port = port_number("address port", port)
        self.backlog = positive_int("backlog", backlog)
        self.max_frame = positive_int("max_frame", max_frame)
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(self.backlog)
        self._listener.setblocking(False)
        self.address: Tuple[str, int] = self._listener.getsockname()
        self._sel = selectors.DefaultSelector()
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._messages: collections.deque = collections.deque()
        self._conns: Dict[socket.socket, _Conn] = {}
        self._host_conn: Dict[int, _Conn] = {}
        self._closed_stats: Dict[str, int] = collections.Counter()
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- lifecycle -----------------------------------------------------
    def start(self) -> "SocketServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._wake()
        self._thread.join()
        self._thread = None
        with self._lock:
            for conn in list(self._conns.values()):
                self._close_conn(conn)
            try:
                self._listener.close()
            except OSError:
                pass
            for s in (self._wake_r, self._wake_w):
                try:
                    s.close()
                except OSError:
                    pass

    def __enter__(self) -> "SocketServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _wake(self) -> None:
        try:
            self._wake_w.send(b"x")
        except OSError:
            pass

    # -- Transport -----------------------------------------------------
    def send(self, msg) -> None:
        raise RuntimeError("SocketServer is the receive side of the "
                           "transport; producers connect with "
                           "SocketTransport")

    def recv(self, max_messages: Optional[int] = None) -> List:
        out: List = []
        with self._lock:
            while self._messages and (max_messages is None
                                      or len(out) < max_messages):
                out.append(self._messages.popleft())
        return out

    def pending(self) -> int:
        with self._lock:
            return len(self._messages)

    # -- acks ----------------------------------------------------------
    def send_acks(self, acks: Dict[int, int]) -> int:
        """Queue cumulative acks to each host's latest connection.
        Returns how many hosts had a connection to ack on (hosts whose
        connection died are skipped — the cumulative ack reaches them
        next call, on their new connection)."""
        by_conn: Dict[int, Tuple[_Conn, Dict[int, int]]] = {}
        with self._lock:
            for host, seq in acks.items():
                conn = self._host_conn.get(int(host))
                if conn is None or conn.sock not in self._conns:
                    continue
                entry = by_conn.setdefault(id(conn), (conn, {}))
                entry[1][int(host)] = int(seq)
            for conn, payload in by_conn.values():
                conn.outbuf += encode_message(Ack(payload))
        if by_conn:
            self._wake()
        return sum(len(p) for _, p in by_conn.values())

    # -- stats ---------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        """Aggregated wire statistics across live and closed
        connections (frames, resyncs, crc_errors, truncated,
        undecodable, connections, ...)."""
        with self._lock:
            out = collections.Counter(self._closed_stats)
            for conn in self._conns.values():
                out.update(conn.reader.stats)
                out.update(conn.decoder.stats)
        return dict(out)

    # -- the IO loop ---------------------------------------------------
    def _loop(self) -> None:
        self._sel.register(self._listener, selectors.EVENT_READ, "accept")
        self._sel.register(self._wake_r, selectors.EVENT_READ, "wake")
        try:
            while not self._stop.is_set():
                self._update_write_interest()
                for key, events in self._sel.select(timeout=0.2):
                    if key.data == "accept":
                        self._accept()
                    elif key.data == "wake":
                        try:
                            while self._wake_r.recv(4096):
                                pass
                        except (BlockingIOError, OSError):
                            pass
                    else:
                        conn: _Conn = key.data
                        if events & selectors.EVENT_READ:
                            self._read(conn)
                        if events & selectors.EVENT_WRITE \
                                and conn.sock in self._conns:
                            self._write(conn)
        finally:
            try:
                self._sel.close()
            except OSError:
                pass

    def _update_write_interest(self) -> None:
        with self._lock:
            conns = list(self._conns.values())
        for conn in conns:
            want = selectors.EVENT_READ
            if conn.outbuf:
                want |= selectors.EVENT_WRITE
            if want != conn.events:
                conn.events = want
                try:
                    self._sel.modify(conn.sock, want, conn)
                except (KeyError, ValueError, OSError):
                    pass

    def _accept(self) -> None:
        while True:
            try:
                sock, _ = self._listener.accept()
            except (BlockingIOError, OSError):
                return
            sock.setblocking(False)
            try:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass
            conn = _Conn(sock, self.max_frame)
            with self._lock:
                self._conns[sock] = conn
                self._closed_stats["connections"] += 1
            try:
                self._sel.register(sock, conn.events, conn)
            except (KeyError, ValueError):
                pass

    def _read(self, conn: _Conn) -> None:
        try:
            data = conn.sock.recv(_RECV_CHUNK)
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            self._drop_conn(conn)
            return
        if not data:
            self._drop_conn(conn)
            return
        for msg_type, payload in conn.reader.feed(data):
            try:
                msg = decode_message(msg_type, payload, conn.decoder)
            except WireError:
                conn.decoder.stats["malformed"] += 1
                continue
            if msg is None:                  # undecodable delta: resent
                continue                     # later via the unacked buffer
            host = getattr(msg, "host", None)
            with self._lock:
                if host is not None:
                    self._host_conn[int(host)] = conn
                self._messages.append(msg)

    def _write(self, conn: _Conn) -> None:
        if not conn.outbuf:
            return
        try:
            n = conn.sock.send(bytes(conn.outbuf))
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            self._drop_conn(conn)
            return
        del conn.outbuf[:n]

    def _drop_conn(self, conn: _Conn) -> None:
        try:
            self._sel.unregister(conn.sock)
        except (KeyError, ValueError, OSError):
            pass
        with self._lock:
            self._close_conn(conn)

    def _close_conn(self, conn: _Conn) -> None:
        """Caller holds the lock."""
        if conn.sock not in self._conns:
            return
        del self._conns[conn.sock]
        conn.reader.close()
        self._closed_stats.update(conn.reader.stats)
        self._closed_stats.update(conn.decoder.stats)
        self._closed_stats["disconnects"] += 1
        for host in [h for h, c in self._host_conn.items() if c is conn]:
            del self._host_conn[host]
        try:
            conn.sock.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# chaos proxy
# ---------------------------------------------------------------------------

class SocketChaosProxy:
    """Seeded TCP fault injector between producers and the server.

    Listens on its own port and pipes every inbound connection to
    ``target``.  The producer->server direction misbehaves, per
    forwarded chunk (faults drawn from one seeded ``random.Random``):

    * ``p_reset`` — both sides are closed with an RST (SO_LINGER 0):
      a crashed peer / middlebox reset.  The client's next send fails
      and reconnects.
    * ``p_tear`` — only a PREFIX of the chunk is forwarded, then the
      connection is reset: a frame torn mid-write.  The server's frame
      reader discards the torn tail.
    * ``p_garbage`` — 1..``garbage_max`` random bytes are injected into
      the stream before the chunk: the server must resync to the next
      frame boundary (frames overlapping the garbage are lost and come
      back via the producers' unacked buffers).
    * ``p_stall`` — delivery of the chunk is delayed ``stall_s``
      seconds.

    The server->producer (ack) direction is forwarded untouched.
    ``stats`` counts every fault fired.
    """

    def __init__(self, target: Tuple[str, int], *,
                 address: Tuple[str, int] = ("127.0.0.1", 0),
                 seed: int = 0,
                 p_reset: float = 0.0, p_tear: float = 0.0,
                 p_garbage: float = 0.0, p_stall: float = 0.0,
                 garbage_max: int = 64, stall_s: float = 0.005,
                 chunk: int = 4096):
        t_host, t_port = target
        self.target = (str(t_host), port_number("target port", t_port,
                                                allow_zero=False))
        self.p_reset = probability("p_reset", p_reset)
        self.p_tear = probability("p_tear", p_tear)
        self.p_garbage = probability("p_garbage", p_garbage)
        self.p_stall = probability("p_stall", p_stall)
        self.garbage_max = positive_int("garbage_max", garbage_max)
        self.stall_s = positive_float("stall_s", stall_s)
        self.chunk = positive_int("chunk", chunk)
        self.rng = random.Random(seed)
        self.stats: Dict[str, int] = collections.Counter()
        self._rng_lock = threading.Lock()
        l_host, l_port = address
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((str(l_host), port_number("listen port",
                                                      l_port)))
        self._listener.listen(128)
        self._listener.settimeout(0.2)
        self.address: Tuple[str, int] = self._listener.getsockname()
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._socks: List[socket.socket] = []
        self._socks_lock = threading.Lock()
        self._accept_thread: Optional[threading.Thread] = None

    def start(self) -> "SocketChaosProxy":
        if self._accept_thread is not None:
            raise RuntimeError("proxy already started")
        self._stop.clear()
        self._accept_thread = threading.Thread(target=self._accept_loop,
                                               daemon=True)
        self._accept_thread.start()
        return self

    def stop(self) -> None:
        if self._accept_thread is None:
            return
        self._stop.set()
        self._accept_thread.join()
        self._accept_thread = None
        with self._socks_lock:
            socks, self._socks = self._socks, []
        for s in socks:
            try:
                s.close()
            except OSError:
                pass
        for t in self._threads:
            t.join(timeout=2.0)
        self._threads = []
        try:
            self._listener.close()
        except OSError:
            pass

    def __enter__(self) -> "SocketChaosProxy":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                inbound, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            try:
                upstream = socket.create_connection(self.target,
                                                    timeout=2.0)
            except OSError:
                self.stats["upstream_refused"] += 1
                self._reset(inbound)
                continue
            for s in (inbound, upstream):
                s.settimeout(0.2)
            with self._socks_lock:
                self._socks += [inbound, upstream]
            self.stats["connections"] += 1
            t1 = threading.Thread(target=self._pump, daemon=True,
                                  args=(inbound, upstream, True))
            t2 = threading.Thread(target=self._pump, daemon=True,
                                  args=(upstream, inbound, False))
            self._threads += [t1, t2]
            t1.start()
            t2.start()

    @staticmethod
    def _reset(sock: socket.socket) -> None:
        """Close with an RST instead of FIN (SO_LINGER 0)."""
        try:
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                            struct.pack("ii", 1, 0))
        except OSError:
            pass
        try:
            sock.close()
        except OSError:
            pass

    def _draw(self) -> Tuple[str, float]:
        with self._rng_lock:
            u = self.rng.random()
            aux = self.rng.random()
        if u < self.p_reset:
            return "reset", aux
        u -= self.p_reset
        if u < self.p_tear:
            return "tear", aux
        u -= self.p_tear
        if u < self.p_garbage:
            return "garbage", aux
        u -= self.p_garbage
        if u < self.p_stall:
            return "stall", aux
        return "forward", aux

    def _garbage_bytes(self) -> bytes:
        with self._rng_lock:
            n = self.rng.randint(1, self.garbage_max)
            return bytes(self.rng.getrandbits(8) for _ in range(n))

    def _pump(self, src: socket.socket, dst: socket.socket,
              faulty: bool) -> None:
        try:
            while not self._stop.is_set():
                try:
                    data = src.recv(self.chunk)
                except socket.timeout:
                    continue
                except OSError:
                    break
                if not data:
                    break
                if not faulty:
                    try:
                        dst.sendall(data)
                    except OSError:
                        break
                    continue
                fault, aux = self._draw()
                if fault == "reset":
                    self.stats["resets"] += 1
                    break
                if fault == "tear":
                    self.stats["torn"] += 1
                    cut = max(1, int(len(data) * aux))
                    try:
                        dst.sendall(data[:cut])
                    except OSError:
                        pass
                    break
                if fault == "garbage":
                    self.stats["garbage"] += 1
                    try:
                        dst.sendall(self._garbage_bytes() + data)
                    except OSError:
                        break
                    continue
                if fault == "stall":
                    self.stats["stalls"] += 1
                    time.sleep(self.stall_s)
                try:
                    dst.sendall(data)
                except OSError:
                    break
                self.stats["forwarded"] += 1
        finally:
            self._reset(src)
            self._reset(dst)


# ---------------------------------------------------------------------------
# the socket acceptance scenario
# ---------------------------------------------------------------------------

def _dense_counter(store, name: str, n_vertices: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """(values, mask) dense views of one counter; masked-off entries are
    0.0, so entries that are (0.0, unmasked) on one side and absent on
    the other compare equal — they are indistinguishable to every
    reader."""
    vids, values, mask = store.counter_columns(name)
    dv = np.zeros((store.n_procs, n_vertices))
    dm = np.zeros((store.n_procs, n_vertices), bool)
    if len(vids):
        dv[:, vids] = np.where(mask, values, 0.0)
        dm[:, vids] = mask
    return dv, dm


def stores_equal(a, b, n_vertices: int) -> bool:
    """Bit-identical sharded stores: time, variance, and every counter
    (dense semantics — see :func:`_dense_counter`)."""
    if not np.array_equal(a.time_matrix(n_vertices),
                          b.time_matrix(n_vertices)):
        return False
    if not np.array_equal(a.var_matrix(n_vertices),
                          b.var_matrix(n_vertices)):
        return False
    for name in sorted(set(a.counter_names()) | set(b.counter_names())):
        va, ma = _dense_counter(a, name, n_vertices)
        vb, mb = _dense_counter(b, name, n_vertices)
        if not (np.array_equal(va, vb) and np.array_equal(ma, mb)):
            return False
    return True


def socket_chaos_run(*, n_procs: int = 32, n_hosts: int = 4,
                     rounds: int = 3, seed: int = 0,
                     p_reset: float = 0.1, p_tear: float = 0.08,
                     p_garbage: float = 0.12, p_stall: float = 0.05,
                     stall_s: float = 0.002,
                     compress: bool = True,
                     faulty_wrap: Optional[Dict[str, float]] = None,
                     backend: Optional[str] = "numpy",
                     detect_every: Optional[int] = 4,
                     n_comp: int = 12,
                     deadline_s: float = 60.0):
    """Stream a known workload through REAL sockets + the chaos proxy
    and assert the convergence contract (see :mod:`repro.monitor.chaos`
    for the queue-transport sibling): the monitor's converged store and
    rendered report must be bit-identical to the fault-free one-shot
    run.

    ``faulty_wrap`` additionally stacks the seeded in-process
    :class:`~repro.monitor.transport.FaultyTransport` faults (drops,
    dup, ack loss, delay kwargs) OVER each host's socket transport —
    both fault layers at once.  Returns a
    :class:`~repro.monitor.chaos.ChaosResult`.
    """
    from repro.core.backtrack import backtrack
    from repro.core.detect import detect_abnormal
    from repro.core.inject import simulate
    from repro.core.report import render_report
    from repro.core.shard import ShardedStore, shard_ranges
    from repro.monitor.aggregator import Monitor
    from repro.monitor.chaos import (ChaosResult, _ab_key, _truncated,
                                     build_chaos_psg)
    from repro.monitor.transport import FaultyTransport

    psg = build_chaos_psg(n_comp)
    V = len(psg.vertices)
    rng = np.random.default_rng(seed)
    straggler = int(rng.integers(n_procs))
    slow_vid = int(rng.integers(1, V - 1))

    def base(p, vid):
        v = psg.vertices[vid]
        return 0.0 if v.kind == "Comm" else 1.0 + 0.01 * vid

    ranges = shard_ranges(n_procs, n_hosts)
    sim = simulate(psg, n_procs, base,
                   inject={(straggler, slow_vid): 4.0},
                   comm_time=lambda *a: 0.05, jitter=0.0, seed=seed,
                   shards=ranges)
    truth_ppg = sim.ppg
    abnormal_ref = detect_abnormal(truth_ppg, backend=backend)
    paths_ref = backtrack(truth_ppg, [], abnormal_ref)

    server = SocketServer().start()
    proxy = SocketChaosProxy(server.address, seed=seed, p_reset=p_reset,
                             p_tear=p_tear, p_garbage=p_garbage,
                             p_stall=p_stall, stall_s=stall_s).start()
    monitor = Monitor(psg, ranges, server, comm=truth_ppg.comm,
                      detect_every=detect_every, backend=backend)
    prod_store = ShardedStore(ranges, V)
    transports: List[SocketTransport] = []
    producers: Dict[int, ShardProducer] = {}
    links: List[ProducerLink] = []
    try:
        for h in range(n_hosts):
            tr = SocketTransport(proxy.address, compress=compress,
                                 seed=seed * 1000 + h,
                                 connect_attempts=8, connect_timeout=2.0,
                                 send_timeout=2.0, backoff_base=0.002,
                                 backoff_max=0.05)
            transports.append(tr)
            outer: Transport = tr
            if faulty_wrap:
                outer = FaultyTransport(tr, seed=seed * 7 + h,
                                        **faulty_wrap)
            p = ShardProducer(h, prod_store.shards[h], outer,
                              max_retries=6, base_backoff=0.001,
                              max_backoff=0.01)
            producers[h] = p
            links.append(ProducerLink(p, tr, resend_after=0.05))

        every = {h: np.arange(prod_store.shards[h].n_procs)
                 for h in range(n_hosts)}
        deadline = time.monotonic() + deadline_s
        for r in range(1, rounds + 1):
            c_r = max(1, (V * r) // rounds)
            for h in range(n_hosts):
                truth_block = truth_ppg.perf.shards[h].extract_rows(
                    every[h])
                block = truth_block if r == rounds \
                    else _truncated(truth_block, c_r)
                prod_store.shards[h].apply_rows(block)
                producers[h].flush(heartbeat=False)
            monitor.poll()
            server.send_acks({h: monitor.acked_seq(h)
                              for h in range(n_hosts)})

        # convergence: keep flushing retry backlogs, ticking stalled-ack
        # resends and polling until every stream is fully applied
        while True:
            done = all(monitor.high[h] >= producers[h].seq
                       and not monitor.parked[h] for h in range(n_hosts))
            if done:
                break
            if time.monotonic() > deadline:
                raise RuntimeError(
                    "socket chaos run did not converge: "
                    f"high={monitor.high} "
                    f"seqs={ {h: p.seq for h, p in producers.items()} } "
                    f"proxy={dict(proxy.stats)} server={server.stats()}")
            for h in range(n_hosts):
                producers[h].flush(heartbeat=False)
            for link in links:
                link.tick()
            if isinstance(producers[0].transport, FaultyTransport):
                for p in producers.values():
                    try:
                        p.transport.flush_held()   # release delayed msgs
                        p.transport.recv()
                    except TransportError:
                        pass                       # still unacked: resent
            monitor.poll()
            server.send_acks({h: monitor.acked_seq(h)
                              for h in range(n_hosts)})
            time.sleep(0.002)

        report = monitor.force_detect()
    finally:
        for tr in transports:
            tr.close()
        proxy.stop()
        server.stop()

    got = [_ab_key(a) for a in report.abnormal]
    want = [_ab_key(a) for a in abnormal_ref]
    paths_got = [(p.start_reason, p.nodes) for p in report.paths]
    paths_want = [(p.start_reason, p.nodes) for p in paths_ref]

    # converged STORE bit-identical to the producers' shards
    store_match = stores_equal(monitor.store, prod_store, V)

    # rendered report bit-identical to the fault-free one-shot render
    ref_text = render_report(truth_ppg, [], abnormal_ref, paths_ref,
                             title=monitor.title,
                             max_abnormal=monitor.max_abnormal,
                             coverage=report.coverage)
    stats = collections.Counter(proxy.stats)
    stats.update(server.stats())
    return ChaosResult(
        report=report, abnormal_ref=abnormal_ref, paths_ref=paths_ref,
        abnormal_match=got == want, paths_match=paths_got == paths_want,
        coverage_stated="fleet coverage:" in report.text,
        transport_stats=dict(stats),
        duplicates_absorbed=monitor.duplicates,
        deltas_applied=monitor.applied, rounds=rounds,
        store_match=store_match,
        report_match=report.text == ref_text)
