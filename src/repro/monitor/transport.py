"""The monitor's transport seam: how shard deltas travel host -> aggregator.

:class:`Transport` is deliberately tiny — ``send`` one message, ``recv``
a batch — so the in-process queue used by tests and single-node runs, a
socket/RPC transport, and the fault-injection wrapper are interchangeable.
Messages are the producer dataclasses (:class:`~repro.monitor.producer.
ShardDelta` / ``Heartbeat``); the transport never inspects them.

Delivery contract the aggregator is built against (and the ONLY one a
transport must honor): messages may be dropped at send time — signalled
by :class:`TransportError`, the producer's retry/backoff loop handles it
— and delivered messages may arrive late, duplicated, or out of order.
:class:`FaultyTransport` exercises exactly that contract with seeded,
reproducible fault schedules; it is both the chaos-test harness and a
user-facing tool for rehearsing fleet misbehavior.
"""
from __future__ import annotations

import collections
import random
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple


class TransportError(RuntimeError):
    """A send failed (message NOT delivered unless stated otherwise).

    Producers treat this as retryable: back off exponentially and resend.
    The ack-loss fault delivers the message AND raises — the resend then
    produces a duplicate, which the aggregator's sequence windows absorb.
    """


class Transport:
    """Abstract one-way message channel, producer(s) -> aggregator."""

    def send(self, msg: Any) -> None:
        raise NotImplementedError

    def recv(self, max_messages: Optional[int] = None) -> List[Any]:
        """Drain up to ``max_messages`` delivered messages (all, if None)."""
        raise NotImplementedError

    def pending(self) -> int:
        """Messages delivered but not yet received."""
        raise NotImplementedError


class QueueTransport(Transport):
    """In-process FIFO transport (thread-safe) — the reliable baseline."""

    def __init__(self):
        self._q: collections.deque = collections.deque()
        self._lock = threading.Lock()

    def send(self, msg: Any) -> None:
        with self._lock:
            self._q.append(msg)

    def recv(self, max_messages: Optional[int] = None) -> List[Any]:
        out: List[Any] = []
        with self._lock:
            while self._q and (max_messages is None
                               or len(out) < max_messages):
                out.append(self._q.popleft())
        return out

    def pending(self) -> int:
        with self._lock:
            return len(self._q)


class FaultyTransport(Transport):
    """Seeded fault-injection wrapper around another transport.

    Per-send faults, each drawn independently from one ``random.Random``
    seeded at construction (identical seeds replay identical schedules):

    * ``p_drop`` — the message is NOT delivered and ``send`` raises
      :class:`TransportError` (the producer retries).
    * ``p_ack_loss`` — the message IS delivered but ``send`` still raises
      (a lost acknowledgment): the producer's retry creates a duplicate.
    * ``p_dup`` — the message is delivered twice.
    * ``p_delay`` — delivery is held for 1..``max_delay`` ``recv`` calls,
      letting later sends overtake it (reordering).
    * ``outages`` — (start, stop) send-index windows in which every send
      raises (a dead link / crashed receiver window).

    ``stats`` counts every fault fired, so tests can assert the schedule
    actually exercised what it claims to.
    """

    def __init__(self, inner: Optional[Transport] = None, *, seed: int = 0,
                 p_drop: float = 0.0, p_ack_loss: float = 0.0,
                 p_dup: float = 0.0, p_delay: float = 0.0,
                 max_delay: int = 3,
                 outages: Sequence[Tuple[int, int]] = ()):
        self.inner = inner if inner is not None else QueueTransport()
        self.rng = random.Random(seed)
        self.p_drop = float(p_drop)
        self.p_ack_loss = float(p_ack_loss)
        self.p_dup = float(p_dup)
        self.p_delay = float(p_delay)
        self.max_delay = int(max_delay)
        self.outages = [(int(lo), int(hi)) for lo, hi in outages]
        self.stats: Dict[str, int] = collections.Counter()
        self._held: List[List[Any]] = []       # [countdown, msg]
        self._sends = 0
        # re-entrant: a socket inner transport's reconnect hook replays
        # unacked deltas through THIS send while it holds the lock
        self._lock = threading.RLock()

    # -- the faulty side -----------------------------------------------
    def send(self, msg: Any) -> None:
        with self._lock:
            i = self._sends
            self._sends += 1
            self.stats["sends"] += 1
            for lo, hi in self.outages:
                if lo <= i < hi:
                    self.stats["outage"] += 1
                    raise TransportError(
                        f"outage window [{lo}, {hi}) swallowed send {i}")
            if self.rng.random() < self.p_drop:
                self.stats["dropped"] += 1
                raise TransportError(f"send {i} dropped")
            copies = 1
            if self.rng.random() < self.p_dup:
                self.stats["duplicated"] += 1
                copies = 2
            for _ in range(copies):
                if self.rng.random() < self.p_delay:
                    self.stats["delayed"] += 1
                    self._held.append(
                        [self.rng.randint(1, self.max_delay), msg])
                else:
                    self.inner.send(msg)
            if self.rng.random() < self.p_ack_loss:
                self.stats["ack_lost"] += 1
                raise TransportError(f"ack for send {i} lost "
                                     f"(message delivered)")

    def recv(self, max_messages: Optional[int] = None) -> List[Any]:
        with self._lock:
            still: List[List[Any]] = []
            for h in self._held:
                h[0] -= 1
                if h[0] <= 0:
                    self.inner.send(h[1])      # released: arrives late
                else:
                    still.append(h)
            self._held = still
        return self.inner.recv(max_messages)

    def pending(self) -> int:
        with self._lock:
            return self.inner.pending() + len(self._held)

    def flush_held(self) -> None:
        """Release every held message now (end-of-run eventual delivery)."""
        with self._lock:
            for _, msg in self._held:
                self.inner.send(msg)
            self._held = []
