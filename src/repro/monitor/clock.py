"""The monitor's time seam: one object that answers "what time is it"
and "wait this long".

Every piece of timing logic in the monitor stack — producer retry
backoff, heartbeat staleness, detection intervals, socket reconnect
backoff — reads time and sleeps through a :class:`Clock`, never through
``time`` directly.  Production uses :class:`SystemClock` (monotonic
time, real sleeps); tests use :class:`ManualClock`, where ``sleep``
*advances* virtual time instantly, so backoff schedules and staleness
windows are asserted exactly instead of calibrated against real
``time.sleep`` — timing tests cannot flake on a loaded CI box.

:func:`as_clock` adapts the historical ``clock=callable, sleep=callable``
pair (still accepted everywhere) into a Clock, so both styles keep
working.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional, Union


class Clock:
    """Monotonic time + sleep, as one injectable seam."""

    def monotonic(self) -> float:
        raise NotImplementedError

    def sleep(self, seconds: float) -> None:
        raise NotImplementedError

    # clocks are callable so they slot into the legacy ``clock=`` knob
    def __call__(self) -> float:
        return self.monotonic()


class SystemClock(Clock):
    """Real time: ``time.monotonic`` + ``time.sleep``."""

    def monotonic(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class ManualClock(Clock):
    """Virtual time for deterministic tests.

    Starts at ``start``; ``sleep(d)`` advances time by ``d`` instantly
    (and records it in ``slept``, so backoff schedules are asserted
    exactly); ``advance(d)`` moves time without recording a sleep
    (the "wall clock passed" side of staleness tests).  Thread-safe:
    socket tests advance it from the main thread while IO threads read.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._lock = threading.Lock()
        self.slept: list = []

    def monotonic(self) -> float:
        with self._lock:
            return self._now

    def sleep(self, seconds: float) -> None:
        with self._lock:
            self.slept.append(seconds)
            self._now += max(float(seconds), 0.0)

    def advance(self, seconds: float) -> None:
        with self._lock:
            self._now += float(seconds)


class _CallableClock(Clock):
    """Adapter for the legacy (clock-callable, sleep-callable) pair."""

    def __init__(self, monotonic_fn: Callable[[], float],
                 sleep_fn: Optional[Callable[[float], None]]):
        self._monotonic = monotonic_fn
        self._sleep = sleep_fn if sleep_fn is not None else time.sleep

    def monotonic(self) -> float:
        return self._monotonic()

    def sleep(self, seconds: float) -> None:
        self._sleep(seconds)


def as_clock(clock: Union[Clock, Callable[[], float], None],
             sleep: Optional[Callable[[float], None]] = None) -> Clock:
    """Normalize the injectable-time knobs into one :class:`Clock`.

    ``clock`` may be a Clock (returned as-is; a separate ``sleep``
    override still wins), a bare time callable (paired with ``sleep``,
    defaulting to ``time.sleep``), or None (system clock, or a system
    clock with the given ``sleep``)."""
    if isinstance(clock, Clock):
        if sleep is None:
            return clock
        return _CallableClock(clock.monotonic, sleep)
    if clock is None:
        if sleep is None:
            return SystemClock()
        return _CallableClock(time.monotonic, sleep)
    return _CallableClock(clock, sleep)
