from repro.data.pipeline import SyntheticLMDataset, make_dataset

__all__ = ["SyntheticLMDataset", "make_dataset"]
