"""Device meshes.

``make_production_mesh`` is the deployment target: 16x16 (one v5e pod,
256 chips) or 2x16x16 (two pods, 512 chips).  It is a FUNCTION, not a
module-level constant — importing this module never touches jax device
state (device count is locked at first jax init, and smoke tests must see
the real single-CPU device, not the dry-run's 512 placeholders).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax

try:                              # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:               # older jax: Auto is the only behavior
    AxisType = None


def _mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist (CPU smoke / small hosts)."""
    n = jax.device_count()
    assert n % model_axis == 0, (n, model_axis)
    return _mesh((n // model_axis, model_axis), ("data", "model"))


def mesh_chip_count(mesh) -> int:
    return mesh.devices.size


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """compiled.cost_analysis() as a flat dict across jax versions
    (older jax returns a list with one dict per device)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


# TPU v5e hardware constants for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BANDWIDTH = 819e9           # B/s
ICI_BANDWIDTH = 50e9            # B/s per link
