"""Per-cell distribution auto-tuner.

The §Perf hillclimb showed the best option set is cell-dependent (SP wins
on every dense/MoE train cell, is neutral-to-negative on SSM prefill).
This tool reads every dry-run artifact variant produced for a cell and
emits the recommended configuration per (arch × shape × mesh) — the
roofline-bound-minimizing variant — as JSON the launcher can consume.

    python -m repro.launch.autotune                 # report
    python -m repro.launch.autotune --write plan.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
from typing import Dict, List, Tuple

VARIANT_DIRS = {
    "baseline": "artifacts/dryrun",
    "seq_shard": "artifacts/dryrun_final",
}


def bound_seconds(rec: dict) -> float:
    r = rec["roofline"]
    return max(r["compute_s"], r["memory_s"], r["collective_s"])


def load_variants() -> Dict[Tuple[str, str, str], Dict[str, dict]]:
    cells: Dict[Tuple[str, str, str], Dict[str, dict]] = {}
    for variant, d in VARIANT_DIRS.items():
        for path in glob.glob(os.path.join(d, "*.json")):
            name = os.path.basename(path)
            if "__opt-" in name and variant == "baseline":
                # ad-hoc per-iteration artifacts: label by their options
                m = re.search(r"__opt-([\w\-]+)\.json$", name)
                label = m.group(1) if m else variant
            else:
                label = variant
            with open(path) as f:
                rec = json.load(f)
            if rec.get("status") != "ok":
                continue
            key = (rec["arch"], rec["shape"], rec["mesh"])
            cells.setdefault(key, {})[label] = rec
    return cells


def plan(cells) -> List[dict]:
    out = []
    for (arch, shape, mesh), variants in sorted(cells.items()):
        best = min(variants, key=lambda v: bound_seconds(variants[v]))
        base = variants.get("baseline")
        rec = variants[best]
        out.append({
            "arch": arch, "shape": shape, "mesh": mesh,
            "recommended": best,
            "bound_s": bound_seconds(rec),
            "baseline_bound_s": bound_seconds(base) if base else None,
            "speedup": (bound_seconds(base) / bound_seconds(rec)
                        if base and bound_seconds(rec) > 0 else 1.0),
            "bottleneck": rec["roofline"]["bottleneck"],
        })
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", default="")
    args = ap.parse_args()
    cells = load_variants()
    p = plan(cells)
    for row in p:
        print(f"{row['arch']:22s} {row['shape']:12s} {row['mesh']:11s} "
              f"-> {row['recommended']:12s} bound={row['bound_s']:8.3f}s "
              f"speedup={row['speedup']:.2f}x [{row['bottleneck']}]")
    if p:
        mean = sum(r["speedup"] for r in p) / len(p)
        print(f"\nmean speedup with per-cell tuning: {mean:.2f}x "
              f"over {len(p)} cells")
    if args.write:
        with open(args.write, "w") as f:
            json.dump(p, f, indent=1)
        print(f"wrote {args.write}")


if __name__ == "__main__":
    main()
