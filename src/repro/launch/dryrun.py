import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init).  512 placeholder host devices exist ONLY in
# this process so jax.make_mesh can build the production meshes; smoke
# tests and benchmarks see the real single CPU device.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) cell and both production meshes
(16x16 single-pod, 2x16x16 multi-pod) this driver:

    lowered  = jax.jit(step, in_shardings=..., out_shardings=...).lower(...)
    compiled = lowered.compile()
    print(compiled.memory_analysis())   # proves it fits
    print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

and records per-cell artifacts (memory stats, cost analysis, per-kind
collective payload bytes parsed from the compiled HLO) into JSON files
that EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/bench_roofline.py
read.  A failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the framework.

Usage:
    python -m repro.launch.dryrun --arch all --shape all --mesh both
    python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k \
        --mesh single --hlo-out artifacts/hlo
"""
import argparse
import json
import time
import traceback
from typing import Dict, List, Optional

import jax

from repro.configs import ARCHS, SHAPES, get as get_config, shape_applicable
from repro.core.hlo_walk import analyze_hlo
from repro.launch.mesh import (HBM_BANDWIDTH, ICI_BANDWIDTH, PEAK_FLOPS_BF16,
                               cost_analysis_dict, make_production_mesh,
                               mesh_chip_count)
from repro.launch.shardings import build_cell

ARTIFACT_DIR = "artifacts/dryrun"


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = ARTIFACT_DIR,
             hlo_out: Optional[str] = None,
             skip_existing: bool = True,
             verbose: bool = True,
             options: Optional[Dict[str, bool]] = None) -> Dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    opts = {k: v for k, v in (options or {}).items() if v}
    suffix = ("__opt-" + "-".join(sorted(opts))) if opts else ""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, SHAPES[shape_name])
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": why}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch, shape_name, mesh, options=opts)
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled)
    hlo_text = compiled.as_text()
    hw = analyze_hlo(hlo_text)          # trip-count-exact per-device costs

    # three-term roofline (seconds, per step, per device)
    t_compute = hw.dot_flops / PEAK_FLOPS_BF16
    t_memory = hw.mem_bytes / HBM_BANDWIDTH
    t_collective = hw.total_coll_bytes / ICI_BANDWIDTH
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    bottleneck = max(terms, key=terms.get)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": cell.kind, "status": "ok",
        "options": sorted(opts),
        "chips": mesh_chip_count(mesh),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0)),
        },
        "cost": {
            # raw XLA aggregate (counts while bodies once; kept for
            # reference) vs. trip-count-exact hlo_walk numbers
            "xla_flops_raw": float(ca.get("flops", 0.0)),
            "xla_bytes_raw": float(ca.get("bytes accessed", 0.0)),
            "dot_flops_per_device": hw.dot_flops,
            "mem_bytes_per_device": hw.mem_bytes,
            "collective_bytes_per_device": hw.total_coll_bytes,
        },
        "collectives": {
            "bytes_by_kind": hw.coll_bytes,
            "counts_by_kind": hw.coll_counts,
        },
        "roofline": {**terms, "bottleneck": bottleneck},
    }
    if hlo_out:
        os.makedirs(hlo_out, exist_ok=True)
        hp = os.path.join(hlo_out,
                          f"{arch}__{shape_name}__{mesh_name}{suffix}"
                          ".hlo.txt")
        with open(hp, "w") as f:
            f.write(hlo_text)
        rec["hlo_path"] = hp
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        dev_bytes = (rec["memory"]["argument_bytes"]
                     + rec["memory"]["temp_bytes"])
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"({rec['kind']}; {dev_bytes/2**30:.2f} GiB/dev args+temp, "
              f"{hw.dot_flops/1e9:.1f} GFLOP/dev, "
              f"bottleneck={bottleneck}, compile {t_compile:.1f}s)",
              flush=True)
        print(f"  memory_analysis: {ma}", flush=True)
        print(f"  cost_analysis: flops={ca.get('flops')} "
              f"bytes={ca.get('bytes accessed')}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--hlo-out", default=None)
    ap.add_argument("--no-skip", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma-separated optimization options "
                         "(gather_weights,seq_shard) — see §Perf")
    args = ap.parse_args()
    options = {name: True for name in args.opt.split(",") if name}

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures: List[str] = []
    n_ok = n_skip = 0
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    rec = run_cell(arch, shape, multi_pod=multi,
                                   out_dir=args.out, hlo_out=args.hlo_out,
                                   skip_existing=not args.no_skip,
                                   options=options)
                    if rec["status"] == "ok":
                        n_ok += 1
                    else:
                        n_skip += 1
                        print(f"[dryrun] {arch} x {shape}: skipped "
                              f"({rec['reason']})", flush=True)
                except Exception:
                    failures.append(f"{arch} x {shape} x multi={multi}")
                    traceback.print_exc()
    print(f"\n[dryrun] {n_ok} ok, {n_skip} skipped, "
          f"{len(failures)} FAILED", flush=True)
    if failures:
        for f in failures:
            print("  FAIL:", f)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
