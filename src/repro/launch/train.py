"""Training launcher.

Smoke-scale on CPU (reduced config, real training) or full-scale on a pod
(the same code path the dry-run compiles).  ScalAna profiling is on by
default: every run produces a PSG + per-vertex perf vectors, and
``--report`` renders the scaling-loss report at exit.

Examples:
    python -m repro.launch.train --arch tinyllama-1.1b --smoke --steps 20
    python -m repro.launch.train --arch mamba2-130m --smoke --steps 50 \
        --ckpt-dir /tmp/ckpt --report
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import SHAPES, get as get_config, get_smoke
from repro.configs.base import RunConfig, ShapeConfig
from repro.training import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config + small shape (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--no-scalana", action="store_true")
    ap.add_argument("--sample-every", type=int, default=8)
    ap.add_argument("--inject-delay", type=float, default=0.0,
                    help="injected per-step delay on this process (case study)")
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args()

    run = RunConfig(
        arch=args.arch, shape=args.shape, total_steps=args.steps,
        learning_rate=args.lr, microbatch=args.microbatch,
        warmup_steps=max(args.steps // 10, 1),
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every or max(args.steps // 2, 1),
        scalana=not args.no_scalana,
        scalana_sample_every=args.sample_every,
        grad_compress=args.grad_compress,
    )
    if args.smoke:
        cfg = get_smoke(args.arch)
        shape = ShapeConfig("smoke", args.seq, args.batch, "train")
    else:
        cfg = get_config(args.arch)
        shape = SHAPES[args.shape]

    inject = {0: args.inject_delay} if args.inject_delay else None
    tr = Trainer(run, arch_cfg=cfg, shape=shape, inject_delay=inject)
    t0 = time.time()
    tr.train(num_steps=args.steps, step_timeout_s=run.step_timeout_s)
    wall = time.time() - t0

    losses = [m["loss"] for m in tr.metrics_log if "loss" in m]
    print(f"[train] {args.arch} ({'smoke' if args.smoke else 'full'}): "
          f"{args.steps} steps in {wall:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    if run.scalana and tr.profiler is not None:
        psg, perf, storage = tr.scalana_artifacts()
        ov = tr.profiler.overhead_estimate()
        print(f"[scalana] PSG: {psg.stats()}; storage {storage/1024:.1f} KiB; "
              f"overhead {100*ov.get('overhead_frac', 0):.2f}%")
        if args.report:
            from repro.core import build_ppg, detect_abnormal, backtrack, \
                render_report, detect_non_scalable
            ppg = build_ppg(psg, jax.process_count() or 1, perf)
            ab = detect_abnormal(ppg, abnorm_thd=run.abnorm_thd)
            paths = backtrack(ppg, [], ab)
            print(render_report(ppg, [], ab, paths))

    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(tr.metrics_log, f)


if __name__ == "__main__":
    main()
