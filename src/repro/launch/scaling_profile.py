"""ScalAna end-user workflow: measured multi-scale profiling -> root cause.

This is the paper's four-step usage (§V) mapped to JAX:

  1. *ScalAna-static*  — PSG from the train-step jaxpr (compile time).
  2. *ScalAna-prof*    — run the instrumented step at several job scales
     (worker subprocesses with different ``--xla_force_host_platform_
     device_count``; each runs the REAL sharded train step and records
     per-PSG-vertex times via GraphProfiler).
  3. *ScalAna-detect*  — fit per-vertex log-log scaling curves across the
     measured series, flag non-scalable + abnormal vertices, run
     backtracking root-cause detection.
  4. *Report*          — source-line report (the ScalAna-viewer analogue).

Example:
    python -m repro.launch.scaling_profile --arch tinyllama-1.1b \
        --scales 1,2,4,8 --steps 12
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict

ARTIFACT_DIR = "artifacts/scaling"


# ---------------------------------------------------------------------------
# worker: one scale, one process
# ---------------------------------------------------------------------------

def worker(args) -> None:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.core.profiler import GraphProfiler
    from repro.distributed.axes import use_rules
    from repro.launch.mesh import make_host_mesh
    from repro.models.api import build_model
    from repro.optim.schedule import constant
    from repro.training.trainer import make_train_step, TrainState
    from repro.optim.adamw import adamw_init

    n = jax.device_count()
    cfg = get_smoke(args.arch).replace(remat=False)
    run = RunConfig(arch=args.arch)
    model = build_model(cfg)
    mesh = make_host_mesh()              # (n, 1) data-parallel
    shape = ShapeConfig("scale", args.seq, args.batch, "train")
    step_fn = make_train_step(model, run, constant(1e-3))

    with use_rules(mesh):
        params = model.init(jax.random.PRNGKey(0))
        state = TrainState(params=params, opt=adamw_init(params),
                           residual=None, step=jnp.zeros((), jnp.int32))
        batch = {"tokens": jnp.zeros((args.batch, args.seq + 1), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((args.batch, cfg.frontend_len,
                                         cfg.d_model), cfg.cdtype())
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros((args.batch, cfg.frontend_len,
                                          cfg.d_model), cfg.cdtype())
        prof = GraphProfiler(step_fn, (state, batch),
                             sample_every=args.sample_every)
        for i in range(args.steps):
            state, _ = prof.step(state, batch)

    perf = prof.perf_vectors()
    out = {
        "n_procs": n,
        "psg": prof.psg.to_json(),
        "perf": {str(vid): {"time": v.time, "samples": v.samples,
                            "counters": v.counters}
                 for vid, v in perf.items()},
        "storage_bytes": prof.storage_bytes(),
        "overhead": prof.overhead_estimate(),
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f)
    print(f"[worker n={n}] wrote {args.out}", flush=True)


# ---------------------------------------------------------------------------
# driver: spawn scales, detect, report
# ---------------------------------------------------------------------------

def load_series(arch: str, scales, out_dir: str):
    from repro.core import PSG, PerfVector, build_ppg
    series = {}
    psg = None
    for n in scales:
        path = os.path.join(out_dir, arch, f"scale_{n}.json")
        with open(path) as f:
            raw = json.load(f)
        psg = PSG.from_json(raw["psg"])
        perf = {int(vid): PerfVector(time=d["time"], samples=d["samples"],
                                     counters=d["counters"])
                for vid, d in raw["perf"].items()}
        series[raw["n_procs"]] = build_ppg(psg, raw["n_procs"], perf)
    return psg, series


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--scales", default="1,2,4,8")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--sample-every", type=int, default=4)
    ap.add_argument("--out", default="")
    ap.add_argument("--out-dir", default=ARTIFACT_DIR)
    args = ap.parse_args()

    if args.worker:
        worker(args)
        return

    scales = [int(s) for s in args.scales.split(",")]
    for n in scales:
        out = os.path.join(args.out_dir, args.arch, f"scale_{n}.json")
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        cmd = [sys.executable, "-m", "repro.launch.scaling_profile",
               "--worker", "--arch", args.arch, "--steps", str(args.steps),
               "--batch", str(args.batch), "--seq", str(args.seq),
               "--sample-every", str(args.sample_every), "--out", out]
        print(f"[scaling_profile] scale {n}...", flush=True)
        subprocess.run(cmd, check=True, env=env)

    from repro.core import (backtrack, detect_abnormal, detect_non_scalable,
                            render_report)
    psg, series = load_series(args.arch, scales, args.out_dir)
    ns = detect_non_scalable(series, min_share=0.01)
    top = series[max(series)]
    ab = detect_abnormal(top)
    paths = backtrack(top, ns, ab)
    print(render_report(top, ns, ab, paths))


if __name__ == "__main__":
    main()
