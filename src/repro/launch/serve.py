"""Serving launcher: batched decode over the slot engine (CPU smoke or pod).

Example:
    python -m repro.launch.serve --arch tinyllama-1.1b --requests 8 \
        --max-new 16 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke, get as get_config
from repro.models.api import build_model
from repro.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--full", action="store_true",
                    help="published config (default: smoke config)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServingEngine(model, params, batch_slots=args.slots,
                           max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=args.prompt_len),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature, seed=args.seed)
            for i in range(args.requests)]
    t0 = time.time()
    results = engine.run(reqs)
    wall = time.time() - t0
    toks = sum(len(r.tokens) for r in results)
    lat = [r.latency_s for r in results]
    print(f"[serve] {args.arch}: {len(results)} requests, {toks} tokens in "
          f"{wall:.2f}s ({toks/wall:.1f} tok/s); "
          f"latency p50={np.median(lat)*1e3:.0f}ms "
          f"p99={np.percentile(lat, 99)*1e3:.0f}ms; "
          f"decode steps={engine.decode_steps}")
    for r in results[:3]:
        print(f"  uid={r.uid} tokens={r.tokens[:8]}...")


if __name__ == "__main__":
    main()
