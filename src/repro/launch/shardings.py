"""Cell assembly: (architecture x input shape x mesh) -> lowerable step.

``build_cell`` returns the jitted-with-shardings callable plus abstract
inputs for exactly what would run on the real cluster:

  * ``train_*``   -> the full train step (fwd + bwd + AdamW update),
  * ``prefill_*`` -> the prefill function (prompt -> primed KV cache),
  * ``decode_*`` / ``long_*`` -> one serve_step token with a seq_len cache.

Used by the multi-pod dry-run, the roofline benchmark and the launcher —
one source of truth for distribution config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs import SHAPES, get as get_config, shape_applicable
from repro.configs.base import ArchConfig, RunConfig, ShapeConfig
from repro.distributed import axes as ax
from repro.models.api import ModelBundle, build_model
from repro.optim.adamw import AdamWState
from repro.training.trainer import TrainState, make_train_step
from repro.optim.schedule import warmup_cosine

Pytree = Any


# ---------------------------------------------------------------------------
# axes-tree -> NamedSharding-tree
# ---------------------------------------------------------------------------

def _is_axes_leaf(x) -> bool:
    return x is None or (
        isinstance(x, tuple) and not hasattr(x, "_fields")
        and all(a is None or isinstance(a, str) for a in x))


def shardings_from_axes(axes_tree: Pytree, abstract_tree: Pytree,
                        mesh: Mesh, rules=None) -> Pytree:
    ax_leaves = jax.tree.flatten(axes_tree, is_leaf=_is_axes_leaf)[0]
    abs_leaves, treedef = jax.tree.flatten(abstract_tree)
    assert len(ax_leaves) == len(abs_leaves), (len(ax_leaves), len(abs_leaves))
    out = []
    for axs, leaf in zip(ax_leaves, abs_leaves):
        spec = (PartitionSpec() if axs is None
                else ax.spec_for(axs, leaf.shape, mesh, rules))
        out.append(NamedSharding(mesh, spec))
    return jax.tree.unflatten(treedef, out)


def rules_for_shape(shape: ShapeConfig,
                    cfg: Optional[ArchConfig] = None,
                    mesh: Optional[Mesh] = None) -> Dict[str, Any]:
    rules = dict(ax.DEFAULT_RULES)
    if shape.seq_len >= 262_144:
        # long-context serving: the KV/state cache is the dominant tensor
        # and batch=1 leaves 'data' idle -> shard the cache seq dim on it.
        rules["kv_seq"] = ("pod", "data")
    elif (cfg is not None and mesh is not None
          and shape.kind in ("prefill", "decode") and cfg.n_kv_heads):
        # GQA head-count fallback: when kv_heads doesn't divide the model
        # axis the cache would replicate across it (e.g. internvl2's 8 KV
        # heads on a 16-way axis: 412 GB cache -> 26 GB/device).  Shard
        # the cache seq dim on 'model' instead — attention contracts over
        # seq, so GSPMD lowers it to a flash-decode-style partial softmax
        # with two tiny all-reduces per layer.
        if cfg.n_kv_heads % mesh.shape["model"] != 0:
            rules["kv_seq"] = "model"
            rules["kv_heads"] = None
    return rules


# ---------------------------------------------------------------------------
# abstract state + shardings per cell kind
# ---------------------------------------------------------------------------

def abstract_train_state(model: ModelBundle) -> TrainState:
    params = model.abstract_params()
    f32 = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    return TrainState(params=params,
                      opt=AdamWState(step=i32, mu=f32, nu=f32),
                      residual=None, step=i32)


def train_state_shardings(model: ModelBundle, mesh: Mesh,
                          rules=None) -> TrainState:
    pspecs = model.param_partition_specs()     # resolved under use_rules
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    scalar = NamedSharding(mesh, PartitionSpec())
    return TrainState(params=sh,
                      opt=AdamWState(step=scalar, mu=sh, nu=sh),
                      residual=None, step=scalar)


@dataclasses.dataclass
class Cell:
    """Everything needed to lower one (arch x shape x mesh) combination."""
    arch: str
    shape: ShapeConfig
    kind: str                      # train | prefill | decode
    fn: Callable                   # jit-wrapped with shardings
    args: Tuple[Pytree, ...]       # abstract inputs
    mesh: Mesh
    rules: Dict[str, Any]
    model: ModelBundle
    options: Dict[str, bool] = dataclasses.field(default_factory=dict)

    def lower(self):
        with ax.use_rules(self.mesh, self.rules, self.options):
            return self.fn.lower(*self.args)


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               run: Optional[RunConfig] = None,
               cfg: Optional[ArchConfig] = None,
               shape: Optional[ShapeConfig] = None,
               donate: bool = True,
               options: Optional[Dict[str, bool]] = None) -> Cell:
    """Assemble the lowerable step for one cell (raises if inapplicable).

    ``options`` are beyond-paper optimizations (EXPERIMENTS.md §Perf):
      * ``gather_weights`` — ZeRO-3-style FSDP gather-at-use;
      * ``seq_shard``      — sequence parallelism: residual-stream
        activations sharded on 'model' between blocks.

    ``shape`` overrides the ``SHAPES[shape_name]`` registry lookup — the
    scenario recorder lowers smoke-scale cells (tiny seq/batch on host
    devices) whose collective MIX still matches the production shape's.
    """
    shape = SHAPES[shape_name] if shape is None else shape
    cfg = cfg if cfg is not None else get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} x {shape_name}: {why}")
    # production numerics: bf16 params/compute, f32 optimizer moments
    cfg = cfg.replace(param_dtype="bfloat16", compute_dtype="bfloat16")
    run = run or RunConfig(arch=arch, shape=shape_name)
    options = dict(options or {})
    rules = rules_for_shape(shape, cfg, mesh)
    if options.get("seq_shard"):
        # Megatron-style sequence parallelism: ONLY the residual stream
        # between blocks is seq-sharded on 'model' (AG at attention/MLP
        # entry, RS at exit); interiors keep heads/mlp tensor parallelism.
        rules["res_seq"] = "model"
    model = build_model(cfg, moe_strategy=(
        "sort" if options.get("moe_sort") else "einsum"))
    # modality frontends prepend patch/frame positions to the decoder
    # sequence: the serve cache must hold them too
    extra_ctx = cfg.frontend_len if cfg.family == "vlm" else 0

    with ax.use_rules(mesh, rules, options):
        if shape.kind == "train":
            lr_fn = warmup_cosine(run.learning_rate, run.warmup_steps,
                                  run.total_steps)
            step_fn = make_train_step(model, run, lr_fn)
            state = abstract_train_state(model)
            state_sh = train_state_shardings(model, mesh, rules)
            batch = model.input_specs(shape)
            batch_sh = shardings_from_axes(
                model.input_logical_axes(shape), batch, mesh, rules)
            scalar = NamedSharding(mesh, PartitionSpec())
            fn = jax.jit(step_fn,
                         in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, scalar),
                         donate_argnums=(0,) if donate else ())
            return Cell(arch, shape, "train", fn, (state, batch), mesh,
                        rules, model, options)

        params = model.abstract_params()
        param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                model.param_partition_specs())

        if shape.kind == "prefill":
            batch = model.input_specs(shape)
            batch_sh = shardings_from_axes(
                model.input_logical_axes(shape), batch, mesh, rules)
            max_len = shape.seq_len + extra_ctx
            cache_abs = model.cache_specs(shape.global_batch, max_len)
            dec_shape = dataclasses.replace(shape, kind="decode")
            cache_ax = model.input_logical_axes(dec_shape)["cache"]
            cache_sh = shardings_from_axes(cache_ax, cache_abs, mesh, rules)
            logits_sh = NamedSharding(
                mesh, ax.spec_for(("batch", None, "vocab"),
                                  (shape.global_batch, 1, cfg.vocab_size),
                                  mesh, rules))

            def prefill_fn(p, b):
                return model.prefill(p, b, max_len)

            fn = jax.jit(prefill_fn,
                         in_shardings=(param_sh, batch_sh),
                         out_shardings=(logits_sh, cache_sh))
            return Cell(arch, shape, "prefill", fn, (params, batch), mesh,
                        rules, model, options)

        # decode: one new token against a seq_len cache
        inputs = model.input_specs(shape)
        tokens, cache_abs = inputs["tokens"], inputs["cache"]
        in_ax = model.input_logical_axes(shape)
        tok_sh = shardings_from_axes(in_ax["tokens"], tokens, mesh, rules)
        cache_sh = shardings_from_axes(in_ax["cache"], cache_abs, mesh, rules)
        logits_sh = NamedSharding(
            mesh, ax.spec_for(("batch", None, "vocab"),
                              (shape.global_batch, 1, cfg.vocab_size),
                              mesh, rules))

        def serve_step(p, cache, tok):
            return model.decode_step(p, cache, tok)

        fn = jax.jit(serve_step,
                     in_shardings=(param_sh, cache_sh, tok_sh),
                     out_shardings=(logits_sh, cache_sh),
                     donate_argnums=(1,) if donate else ())
        return Cell(arch, shape, "decode", fn, (params, cache_abs, tokens),
                    mesh, rules, model, options)
