"""Compiled-HLO introspection: collective ops, bytes, replica groups.

This is ScalAna's PMPI-interception analogue: in SPMD JAX the collectives
are inserted by GSPMD partitioning, so the *compiled* HLO is the ground
truth for communication structure.  We parse the per-device HLO module text
for collective ops, their payload bytes, replica groups and op-name scopes,
and (a) attach them to the PSG as Comm vertices, (b) feed the roofline's
collective term, (c) drive PPG inter-process edges.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(" + "|".join(COLLECTIVE_OPS) + r")\(",
)
_GROUPS_LITERAL_RE = re.compile(r"replica_groups=\{\{([^}]*(?:\},\{[^}]*)*)\}\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
_SOURCE_RE = re.compile(r'source_file="([^"]*)"\s+source_line=(\d+)')
_PERM_RE = re.compile(r"source_target_pairs=\{([0-9,{} ]*)\}")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string; tuples summed."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_groups(line: str) -> Optional[List[List[int]]]:
    m = _GROUPS_LITERAL_RE.search(line)
    if m:
        return [[int(x) for x in grp.split(",") if x.strip()]
                for grp in m.group(1).split("},{")]
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g0, g1 = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(4).split(",")]
                if m.group(4) else list(range(len(dims))))
        arr = np.arange(int(np.prod(dims))).reshape(dims).transpose(perm)
        return arr.reshape(g0, g1).tolist()
    return None


@dataclasses.dataclass
class CollectiveOp:
    kind: str                       # all-reduce / all-gather / ...
    bytes: int                      # per-device payload (result tuple bytes)
    replica_groups: Optional[List[List[int]]]
    op_name: str                    # scope path, e.g. jit(step)/while/body/...
    source: str = ""                # file:line when present
    p2p_pairs: List[Tuple[int, int]] = dataclasses.field(default_factory=list)

    @property
    def group_size(self) -> int:
        if self.replica_groups:
            return max(len(g) for g in self.replica_groups)
        return 0


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    """All collective ops in an HLO module text, in program order."""
    out: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        nbytes = shape_bytes(type_str)
        groups = _parse_groups(line)
        op_name = (_OPNAME_RE.search(line) or [None, ""])[1] \
            if _OPNAME_RE.search(line) else ""
        sm = _SOURCE_RE.search(line)
        source = f"{sm.group(1)}:{sm.group(2)}" if sm else ""
        pairs: List[Tuple[int, int]] = []
        pm = _PERM_RE.search(line)
        if pm:
            nums = [int(x) for x in re.findall(r"\d+", pm.group(1))]
            pairs = list(zip(nums[::2], nums[1::2]))
        out.append(CollectiveOp(kind, nbytes, groups, op_name, source, pairs))
    return out


def collective_bytes_total(hlo_text: str) -> Dict[str, float]:
    """Per-kind and total collective payload bytes (per device)."""
    totals: Dict[str, float] = {}
    for op in parse_collectives(hlo_text):
        totals[op.kind] = totals.get(op.kind, 0.0) + op.bytes
        totals["total"] = totals.get("total", 0.0) + op.bytes
    return totals


def collective_bytes_by_kind_and_size(hlo_text: str) -> Dict[str, Dict]:
    """Rich per-kind summary: op count, payload bytes, max group size.

    NOTE: ops inside ``while`` loop bodies appear once in the text; the
    roofline multiplies loop-body collectives by the trip count separately
    (see bench_roofline) — here we report static per-execution-of-body
    sums plus a 'in_loop' marker via computation scope when derivable.
    """
    out: Dict[str, Dict] = {}
    total = 0.0
    for op in parse_collectives(hlo_text):
        d = out.setdefault(op.kind, {"count": 0, "bytes": 0.0,
                                     "max_group": 0})
        d["count"] += 1
        d["bytes"] += op.bytes
        d["max_group"] = max(d["max_group"], op.group_size)
        total += op.bytes
    out["total_bytes"] = total
    return out


def scope_tokens(op_name: str) -> List[str]:
    """op_name scope split into structural tokens ('while', 'body', ...)."""
    return [t for t in re.split(r"[/()]", op_name) if t]
