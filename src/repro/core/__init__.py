"""ScalAna core: graph-based scaling-loss detection (the paper's contribution).

Pipeline:  build_psg (static, jaxpr) -> contract -> [GraphProfiler runtime
sampling | annotate_from_hlo comm refinement] -> build_ppg -> detect
(non-scalable + abnormal) -> backtrack (Algorithm 1) -> render_report.
"""
from repro.core.backtrack import Path, backtrack, backtrack_one, root_causes
from repro.core.commdep import CommLog, add_comm_edges, annotate_from_hlo
from repro.core.contraction import contract
from repro.core.detect import (
    Abnormal,
    NonScalable,
    detect_abnormal,
    detect_non_scalable,
    fit_loglog,
)
from repro.core.graph import (
    BRANCH, CALL, COMM, COMP, LOOP, ROOT,
    PPG, PSG, PerfVector, Vertex,
)
from repro.core.hlo import collective_bytes_total, parse_collectives
from repro.core.inject import simulate, simulate_series
from repro.core.ppg import build_ppg
from repro.core.profiler import GraphProfiler
from repro.core.psg import build_psg
from repro.core.report import render_report

__all__ = [
    "PSG", "PPG", "Vertex", "PerfVector",
    "LOOP", "BRANCH", "CALL", "COMP", "COMM", "ROOT",
    "build_psg", "contract", "GraphProfiler",
    "annotate_from_hlo", "CommLog", "add_comm_edges",
    "parse_collectives", "collective_bytes_total",
    "build_ppg", "simulate", "simulate_series",
    "detect_non_scalable", "detect_abnormal", "NonScalable", "Abnormal",
    "fit_loglog", "backtrack", "backtrack_one", "root_causes", "Path",
    "render_report",
]
