"""ScalAna core: graph-based scaling-loss detection (the paper's contribution).

Pipeline:  build_psg (static, jaxpr) -> contract -> [GraphProfiler runtime
sampling | annotate_from_hlo comm refinement] -> build_ppg -> detect
(non-scalable + abnormal) -> backtrack (Algorithm 1) -> render_report.

Exports resolve lazily (PEP 562) so the pure-numpy analysis layer (graph /
detect / backtrack / inject / contraction) can be imported without paying
for — or even having — jax, which only the static/profiling channels
(psg.build_psg, GraphProfiler) need.
"""
from typing import TYPE_CHECKING

# export name -> submodule that defines it
_EXPORTS = {
    "Path": "backtrack", "backtrack": "backtrack",
    "backtrack_batched": "backtrack", "backtrack_one": "backtrack",
    "backtrack_scalar": "backtrack", "root_causes": "backtrack",
    "CommLog": "commdep", "add_comm_edges": "commdep",
    "annotate_from_hlo": "commdep",
    "contract": "contraction",
    "Abnormal": "detect", "NonScalable": "detect",
    "MERGE_STRATEGIES": "detect", "JIT_STRATEGIES": "detect",
    "detect_abnormal": "detect", "detect_non_scalable": "detect",
    "fit_loglog": "detect",
    "BRANCH": "graph", "CALL": "graph", "COMM": "graph", "COMP": "graph",
    "LOOP": "graph", "ROOT": "graph",
    "CommIndex": "graph", "CounterColumns": "graph", "EdgeSet": "graph",
    "PPG": "graph", "PSG": "graph",
    "PerfStore": "graph", "PerfVector": "graph", "Vertex": "graph",
    "collective_bytes_total": "hlo", "parse_collectives": "hlo",
    "simulate": "inject", "simulate_series": "inject",
    "p2p_rounds": "inject", "seeded_base_times": "inject",
    "vectorized_base_times": "inject",
    "DeviceShardView": "shard", "PerfShard": "shard",
    "ShardedStore": "shard", "shard_ranges": "shard",
    "build_ppg": "ppg",
    "GraphProfiler": "profiler",
    "build_psg": "psg",
    "render_report": "report",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    target = _EXPORTS.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    module = importlib.import_module(f"{__name__}.{target}")
    value = getattr(module, name)
    globals()[name] = value           # cache: resolve each name once
    return value


def __dir__():
    return __all__


# `backtrack` is the one export whose name collides with its defining
# submodule.  A direct `import repro.core.backtrack` binds the *module*
# onto this package, and because the attribute then exists, __getattr__
# never fires and `from repro.core import backtrack` hands back the
# module instead of the function — silently, and dependent on which
# import ran first.  Pin the function eagerly (the submodule is pure
# numpy, so this costs nothing and keeps the jax-needing channels lazy).
from repro.core.backtrack import backtrack  # noqa: E402


if TYPE_CHECKING:                     # static analyzers see eager imports
    from repro.core.backtrack import (Path, backtrack, backtrack_batched,
                                      backtrack_one, backtrack_scalar,
                                      root_causes)
    from repro.core.commdep import CommLog, add_comm_edges, annotate_from_hlo
    from repro.core.contraction import contract
    from repro.core.detect import (Abnormal, JIT_STRATEGIES,
                                   MERGE_STRATEGIES, NonScalable,
                                   detect_abnormal, detect_non_scalable,
                                   fit_loglog)
    from repro.core.graph import (BRANCH, CALL, COMM, COMP, LOOP, ROOT,
                                  CommIndex, CounterColumns, EdgeSet, PPG,
                                  PSG, PerfStore, PerfVector, Vertex)
    from repro.core.hlo import collective_bytes_total, parse_collectives
    from repro.core.inject import (p2p_rounds, seeded_base_times, simulate,
                                   simulate_series, vectorized_base_times)
    from repro.core.ppg import build_ppg
    from repro.core.profiler import GraphProfiler
    from repro.core.shard import (DeviceShardView, PerfShard, ShardedStore,
                                  shard_ranges)
    from repro.core.psg import build_psg
    from repro.core.report import render_report
