"""PPG assembly: per-process PSG replicas + perf vectors + comm edges."""
from __future__ import annotations

from collections.abc import Mapping as ABCMapping
from typing import (Dict, Iterable, List, Mapping, Optional, Sequence, Tuple,
                    Union)

import numpy as np

from repro.core.commdep import add_comm_edges
from repro.core.graph import PPG, PSG, PerfStore, PerfVector
from repro.core.shard import ShardedStore

PerfByProc = Mapping[int, Mapping[int, PerfVector]]
PerfInput = Union[Mapping[int, PerfVector], "PerfByProc", PerfStore,
                  Iterable[PerfStore]]


def _store_by_proc(store: PerfStore, perf: "PerfByProc") -> None:
    """Land {proc: {vid: PerfVector}} data as batched column scatters.

    Entries are grouped by (vid, counter-name set) and written with one
    :meth:`PerfStore.set_entries` call per group — the same seam a
    streamed per-host shard merge uses — instead of one mapping-API write
    per (proc, vid)."""
    by_vid: Dict[int, List[Tuple[int, PerfVector]]] = {}
    for p, d in perf.items():
        for vid, vec in d.items():
            by_vid.setdefault(vid, []).append((p, vec))
    for vid, entries in by_vid.items():
        groups: Dict[Tuple[str, ...], List[Tuple[int, PerfVector]]] = {}
        for p, vec in entries:
            groups.setdefault(tuple(sorted(vec.counters)), []).append((p, vec))
        for names, es in groups.items():
            procs = np.asarray([p for p, _ in es], np.intp)
            store.set_entries(
                procs, vid,
                np.asarray([v.time for _, v in es]),
                time_var=np.asarray([v.time_var for _, v in es]),
                samples=np.asarray([v.samples for _, v in es]),
                counters={nm: np.asarray([v.counters[nm] for _, v in es])
                          for nm in names})


def build_ppg(psg: PSG, n_procs: int, perf: Optional[PerfInput] = None,
              *, replicate: bool = True, meta: Optional[dict] = None,
              sharded: bool = False) -> PPG:
    """Assemble a PPG.

    ``perf`` is a ready :class:`PerfStore` or
    :class:`~repro.core.shard.ShardedStore` (the simulator fast paths —
    a sharded store is kept AS the PPG's perf store, so detection reads
    stacked shard views), or an iterable of per-host shards
    (:class:`~repro.core.shard.PerfShard` blocks, consumed one at a time
    through ``PerfStore.assemble_streamed`` — the streamed multi-host
    channel), or {vid: PerfVector} (replicated to all processes — the
    single-controller measured channel), or {proc: {vid: PerfVector}} for
    per-process data.  Either way counters land in the store's
    column-sparse layout (one column block per counter, only at the
    vertices that carry it).

    ``sharded=True`` keeps an iterable of per-host shards AS the blocks
    of a :class:`~repro.core.shard.ShardedStore` (their ranges must tile
    ``[0, n_procs)``) instead of merging them — the device-resident
    detection path: e.g. per-host ``GraphProfiler.perf_shard`` blocks
    feed the jitted detectors through ``ppg.device_view()`` without a
    controller-side merge.  An empty shard iterable (no hosts reported
    yet) with ``sharded=False`` assembles an empty ``n_procs``-row store.
    """
    store: Optional[PerfStore] = None
    if isinstance(perf, (PerfStore, ShardedStore)):
        if sharded and not isinstance(perf, ShardedStore):
            raise ValueError("sharded=True needs an iterable of per-host "
                             "shards (or a ready ShardedStore), got a "
                             "merged PerfStore")
        if isinstance(perf, ShardedStore) and perf.n_procs != n_procs:
            # a mismatched sharded store would route out-of-range procs
            # into the last shard's local rows — fail here, like the
            # shard-iterable path does
            raise ValueError(f"ShardedStore tiles [0, {perf.n_procs}) "
                             f"but n_procs is {n_procs}")
        store = perf
    elif perf is not None and not isinstance(perf, ABCMapping):
        if sharded:
            # adopt the blocks as a ShardedStore — no merge, detection
            # feeds from per-host (device-residable) blocks
            store = ShardedStore.of(perf)
            if store.n_procs != n_procs:
                raise ValueError(f"shards tile [0, {store.n_procs}) but "
                                 f"n_procs is {n_procs}")
        else:
            # iterable of per-host shards: streamed block-concat merge
            store = PerfStore.assemble_streamed(
                perf, n_procs=n_procs, n_vertices=len(psg.vertices))
    elif sharded:
        raise ValueError("sharded=True needs an iterable of per-host "
                         "shards, not mapping/None perf data")
    ppg = PPG(psg=psg, n_procs=n_procs, perf=store, meta=dict(meta or {}))
    if perf and store is None:
        first = next(iter(perf.values()))
        if isinstance(first, PerfVector):        # {vid: vec}
            if replicate:
                # one column write per vertex instead of P x V set_perf calls
                for vid, vec in perf.items():
                    ppg.perf.set_column(
                        vid, vec.time, time_var=vec.time_var,
                        samples=vec.samples, counters=vec.counters)
            else:
                for vid, vec in perf.items():
                    ppg.set_perf(0, vid, vec)
        else:                                    # {proc: {vid: vec}}
            _store_by_proc(ppg.perf, perf)
    add_comm_edges(ppg)
    return ppg
