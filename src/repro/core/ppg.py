"""PPG assembly: per-process PSG replicas + perf vectors + comm edges."""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Sequence, Union

from repro.core.commdep import add_comm_edges
from repro.core.graph import PPG, PSG, PerfVector

PerfByProc = Mapping[int, Mapping[int, PerfVector]]


def build_ppg(psg: PSG, n_procs: int,
              perf: Optional[Union[Mapping[int, PerfVector], PerfByProc]] = None,
              *, replicate: bool = True, meta: Optional[dict] = None) -> PPG:
    """Assemble a PPG.

    ``perf`` is either {vid: PerfVector} (replicated to all processes — the
    single-controller measured channel) or {proc: {vid: PerfVector}} for
    per-process data (simulator / per-shard timing).
    """
    ppg = PPG(psg=psg, n_procs=n_procs, meta=dict(meta or {}))
    if perf:
        first = next(iter(perf.values()))
        if isinstance(first, PerfVector):        # {vid: vec}
            if replicate:
                for p in range(n_procs):
                    for vid, vec in perf.items():
                        ppg.set_perf(p, vid, vec)
            else:
                for vid, vec in perf.items():
                    ppg.set_perf(0, vid, vec)
        else:                                    # {proc: {vid: vec}}
            for p, d in perf.items():
                for vid, vec in d.items():
                    ppg.set_perf(p, vid, vec)
    add_comm_edges(ppg)
    return ppg
