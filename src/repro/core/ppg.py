"""PPG assembly: per-process PSG replicas + perf vectors + comm edges."""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core.commdep import add_comm_edges
from repro.core.graph import PPG, PSG, PerfStore, PerfVector

PerfByProc = Mapping[int, Mapping[int, PerfVector]]
PerfInput = Union[Mapping[int, PerfVector], "PerfByProc", PerfStore]


def build_ppg(psg: PSG, n_procs: int, perf: Optional[PerfInput] = None,
              *, replicate: bool = True, meta: Optional[dict] = None) -> PPG:
    """Assemble a PPG.

    ``perf`` is a ready :class:`PerfStore` (the simulator fast path), or
    {vid: PerfVector} (replicated to all processes — the single-controller
    measured channel), or {proc: {vid: PerfVector}} for per-process data
    (per-shard timing).  Either way counters land in the store's
    column-sparse layout (one column block per counter, only at the
    vertices that carry it).
    """
    store: Optional[PerfStore] = None
    if isinstance(perf, PerfStore):
        store = perf
    ppg = PPG(psg=psg, n_procs=n_procs, perf=store, meta=dict(meta or {}))
    if perf and store is None:
        first = next(iter(perf.values()))
        if isinstance(first, PerfVector):        # {vid: vec}
            if replicate:
                # one column write per vertex instead of P x V set_perf calls
                for vid, vec in perf.items():
                    ppg.perf.set_column(
                        vid, vec.time, time_var=vec.time_var,
                        samples=vec.samples, counters=vec.counters)
            else:
                for vid, vec in perf.items():
                    ppg.set_perf(0, vid, vec)
        else:                                    # {proc: {vid: vec}}
            for p, d in perf.items():
                for vid, vec in d.items():
                    ppg.set_perf(p, vid, vec)
    add_comm_edges(ppg)
    return ppg
