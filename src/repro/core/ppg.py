"""PPG assembly: per-process PSG replicas + perf vectors + comm edges."""
from __future__ import annotations

from collections.abc import Mapping as ABCMapping
from typing import (Dict, Iterable, List, Mapping, Optional, Sequence, Tuple,
                    Union)

import numpy as np

from repro.core.commdep import add_comm_edges
from repro.core.graph import PPG, PSG, PerfStore, PerfVector
from repro.core.shard import ShardedStore

PerfByProc = Mapping[int, Mapping[int, PerfVector]]
PerfInput = Union[Mapping[int, PerfVector], "PerfByProc", PerfStore,
                  Iterable[PerfStore]]


def _store_by_proc(store: PerfStore, perf: "PerfByProc") -> None:
    """Land {proc: {vid: PerfVector}} data as batched column scatters.

    Entries are grouped by (vid, counter-name set) and written with one
    :meth:`PerfStore.set_entries` call per group — the same seam a
    streamed per-host shard merge uses — instead of one mapping-API write
    per (proc, vid)."""
    by_vid: Dict[int, List[Tuple[int, PerfVector]]] = {}
    for p, d in perf.items():
        for vid, vec in d.items():
            by_vid.setdefault(vid, []).append((p, vec))
    for vid, entries in by_vid.items():
        groups: Dict[Tuple[str, ...], List[Tuple[int, PerfVector]]] = {}
        for p, vec in entries:
            groups.setdefault(tuple(sorted(vec.counters)), []).append((p, vec))
        for names, es in groups.items():
            procs = np.asarray([p for p, _ in es], np.intp)
            store.set_entries(
                procs, vid,
                np.asarray([v.time for _, v in es]),
                time_var=np.asarray([v.time_var for _, v in es]),
                samples=np.asarray([v.samples for _, v in es]),
                counters={nm: np.asarray([v.counters[nm] for _, v in es])
                          for nm in names})


def build_ppg(psg: PSG, n_procs: int, perf: Optional[PerfInput] = None,
              *, replicate: bool = True, meta: Optional[dict] = None) -> PPG:
    """Assemble a PPG.

    ``perf`` is a ready :class:`PerfStore` or
    :class:`~repro.core.shard.ShardedStore` (the simulator fast paths —
    a sharded store is kept AS the PPG's perf store, so detection reads
    stacked shard views), or an iterable of per-host shards
    (:class:`~repro.core.shard.PerfShard` blocks, consumed one at a time
    through ``PerfStore.assemble_streamed`` — the streamed multi-host
    channel), or {vid: PerfVector} (replicated to all processes — the
    single-controller measured channel), or {proc: {vid: PerfVector}} for
    per-process data.  Either way counters land in the store's
    column-sparse layout (one column block per counter, only at the
    vertices that carry it).
    """
    store: Optional[PerfStore] = None
    if isinstance(perf, (PerfStore, ShardedStore)):
        store = perf
    elif perf is not None and not isinstance(perf, ABCMapping):
        # iterable of per-host shards: streamed block-concatenation merge
        store = PerfStore.assemble_streamed(
            perf, n_procs=n_procs, n_vertices=len(psg.vertices))
    ppg = PPG(psg=psg, n_procs=n_procs, perf=store, meta=dict(meta or {}))
    if perf and store is None:
        first = next(iter(perf.values()))
        if isinstance(first, PerfVector):        # {vid: vec}
            if replicate:
                # one column write per vertex instead of P x V set_perf calls
                for vid, vec in perf.items():
                    ppg.perf.set_column(
                        vid, vec.time, time_var=vec.time_var,
                        samples=vec.samples, counters=vec.counters)
            else:
                for vid, vec in perf.items():
                    ppg.set_perf(0, vid, vec)
        else:                                    # {proc: {vid: vec}}
            _store_by_proc(ppg.perf, perf)
    add_comm_edges(ppg)
    return ppg
