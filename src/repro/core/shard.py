"""Sharded performance-data layer: per-host PerfStore blocks.

The PPG's perf data no longer has to be assembled by a single controller:
each host records its own proc-range block (:class:`PerfShard` — a
:class:`~repro.core.graph.PerfStore` whose rows are local processes offset
by ``proc_start``), and the blocks merge late, either

* into one global store — ``PerfStore.from_shards(shards)`` /
  ``PerfStore.assemble_streamed(shards)`` concatenate the blocks through
  the ``set_entries`` write seam, bit-identical to single-store assembly —
  or
* not at all — :class:`ShardedStore` keeps the per-host blocks and serves
  the PerfStore API on top: writes route to the owning shard by proc
  range, matrix reads are STACKED VIEWS (per-shard blocks concatenated on
  demand), so the detectors consume multi-host data without ever
  densifying it into a merged store.

``repro.core.inject.simulate(..., shards=...)`` executes the replay engine
straight into a ShardedStore (multi-host replay), and
``GraphProfiler.perf_shard`` emits a measured per-host block; both feed
``build_ppg`` unchanged.
"""
from __future__ import annotations

from typing import (Any, Dict, Iterator, List, Mapping, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.graph import PerfStore, PerfVector


def shard_ranges(n_procs: int, n_hosts: int) -> List[Tuple[int, int]]:
    """Split ``[0, n_procs)`` into ``n_hosts`` contiguous (start, stop)
    ranges, as even as possible (first ranges take the remainder)."""
    n_procs, n_hosts = int(n_procs), int(n_hosts)
    if n_hosts <= 0:
        raise ValueError(f"n_hosts must be positive: {n_hosts}")
    n_hosts = min(n_hosts, max(n_procs, 1))
    base, rem = divmod(n_procs, n_hosts)
    out, lo = [], 0
    for h in range(n_hosts):
        hi = lo + base + (1 if h < rem else 0)
        out.append((lo, hi))
        lo = hi
    return out


class PerfShard(PerfStore):
    """One host's proc-range block of a PerfStore.

    Rows are LOCAL processes; row ``i`` is global process
    ``proc_start + i``.  Everything else — dense time/var/sample matrices,
    column-sparse counters, the ``set_entries`` seam — is the plain
    :class:`PerfStore` layout, so a shard is just a store that knows where
    its rows land in the global proc space.
    """

    __slots__ = ("proc_start",)

    def __init__(self, proc_start: int, n_procs: int, n_vertices: int = 0):
        super().__init__(n_procs, n_vertices)
        self.proc_start = int(proc_start)

    @property
    def proc_stop(self) -> int:
        return self.proc_start + self.n_procs

    def to_local(self, procs) -> np.ndarray:
        """Global proc indices -> this shard's local row indices."""
        return np.asarray(procs, np.intp) - self.proc_start

    def __repr__(self) -> str:
        return (f"PerfShard([{self.proc_start}, {self.proc_stop}), "
                f"{len(self)} entries)")


class ShardedStore:
    """Per-host :class:`PerfShard` blocks behind the PerfStore API.

    Writes (``set_column`` / ``set_entries`` / ``set_entry``) route each
    proc index to the shard owning its range — a row's writes keep their
    order, so accumulate-mode scatters are bit-identical to the unsharded
    store.  Matrix reads (``time_matrix`` / ``var_matrix`` /
    ``counter_columns``) are stacked shard views: per-host blocks
    concatenated on demand, never scattered into a merged store.  Use
    :meth:`merge` when a genuinely single store is needed.

    Ranges must tile ``[0, n_procs)`` contiguously (the replay engine
    writes every process).
    """

    __slots__ = ("shards", "n_procs", "_starts")

    def __init__(self, ranges: Sequence[Tuple[int, int]], n_vertices: int = 0):
        ranges = [(int(lo), int(hi)) for lo, hi in ranges]
        if not ranges:
            raise ValueError("ShardedStore needs at least one range")
        lo0 = 0
        for lo, hi in ranges:
            if lo != lo0 or hi <= lo:
                raise ValueError(f"ranges must tile [0, P) contiguously: "
                                 f"{ranges}")
            lo0 = hi
        self.shards: List[PerfShard] = [PerfShard(lo, hi - lo, n_vertices)
                                        for lo, hi in ranges]
        self.n_procs = ranges[-1][1]
        self._starts = np.asarray([lo for lo, _ in ranges], np.intp)

    # -- routing -------------------------------------------------------
    def shard_of(self, proc: int) -> PerfShard:
        """The shard owning global process ``proc``."""
        i = int(np.searchsorted(self._starts, proc, side="right")) - 1
        return self.shards[i]

    def _route(self, procs: np.ndarray) -> Iterator[Tuple[PerfShard,
                                                          np.ndarray]]:
        """Yield (shard, selector) for each shard with rows in ``procs``;
        selectors preserve the original order of a row's occurrences."""
        sidx = np.searchsorted(self._starts, procs, side="right") - 1
        for i in np.unique(sidx).tolist():
            yield self.shards[i], sidx == i

    # -- write API (the replay engine's surface) -----------------------
    def ensure_columns(self, n_vertices: int) -> None:
        for sh in self.shards:
            sh.ensure_columns(n_vertices)

    def set_column(self, vid: int, time, *, time_var=0.0, samples=1,
                   counters: Optional[Mapping[str, Any]] = None,
                   procs: Optional[np.ndarray] = None) -> None:
        if procs is not None:
            procs = np.asarray(procs, np.intp)
            if procs.size == 0:
                return
            for sh, sel in self._route(procs):
                local = procs[sel] - sh.proc_start
                sh.set_column(vid, _take(time, sel), procs=local,
                              time_var=_take(time_var, sel),
                              samples=_take(samples, sel),
                              counters={k: _take(v, sel)
                                        for k, v in (counters or {}).items()})
            return
        for sh in self.shards:
            blk = slice(sh.proc_start, sh.proc_stop)
            sh.set_column(vid, _slice(time, blk),
                          time_var=_slice(time_var, blk),
                          samples=_slice(samples, blk),
                          counters={k: _slice(v, blk)
                                    for k, v in (counters or {}).items()})

    def set_entries(self, procs, vid: int, time, *, time_var=0.0, samples=1,
                    counters: Optional[Mapping[str, Any]] = None,
                    accumulate: bool = False) -> None:
        procs = np.asarray(procs, np.intp)
        if procs.size == 0:
            return
        t = np.broadcast_to(np.asarray(time, float), procs.shape)
        tv = np.broadcast_to(np.asarray(time_var), procs.shape)
        sm = np.broadcast_to(np.asarray(samples), procs.shape)
        cs = {k: np.broadcast_to(np.asarray(v, float), procs.shape)
              for k, v in (counters or {}).items()}
        for sh, sel in self._route(procs):
            sh.set_entries(procs[sel] - sh.proc_start, vid, t[sel],
                           time_var=tv[sel], samples=sm[sel],
                           counters={k: v[sel] for k, v in cs.items()},
                           accumulate=accumulate)

    def set_entry(self, p: int, vid: int, time: float, *, time_var=0.0,
                  samples=1, counters: Optional[Mapping[str, float]] = None,
                  accumulate: bool = False) -> None:
        sh = self.shard_of(p)
        sh.set_entry(p - sh.proc_start, vid, time, time_var=time_var,
                     samples=samples, counters=counters,
                     accumulate=accumulate)

    def __setitem__(self, key: Tuple[int, int], vec: PerfVector) -> None:
        p, vid = key
        sh = self.shard_of(p)
        sh[(p - sh.proc_start, vid)] = vec

    # -- stacked read views --------------------------------------------
    @property
    def _cols(self) -> int:
        return max(sh._cols for sh in self.shards)

    def time_matrix(self, n_vertices: Optional[int] = None) -> np.ndarray:
        n = self._cols if n_vertices is None else n_vertices
        return np.vstack([sh.time_matrix(n) for sh in self.shards])

    def var_matrix(self, n_vertices: Optional[int] = None) -> np.ndarray:
        n = self._cols if n_vertices is None else n_vertices
        return np.vstack([sh.var_matrix(n) for sh in self.shards])

    def counter_matrix(self, name: str,
                       n_vertices: Optional[int] = None) -> np.ndarray:
        n = self._cols if n_vertices is None else n_vertices
        return np.vstack([sh.counter_matrix(name, n) for sh in self.shards])

    def counter_columns(self, name: str
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Stacked compressed view: the union of the shards' written
        columns, each shard's block placed at its row range."""
        per = [sh.counter_columns(name) for sh in self.shards]
        vids = np.unique(np.concatenate([v for v, _, _ in per]))
        values = np.zeros((self.n_procs, vids.size))
        mask = np.zeros((self.n_procs, vids.size), bool)
        for sh, (v, val, m) in zip(self.shards, per):
            if not v.size:
                continue
            slots = np.searchsorted(vids, v)
            values[sh.proc_start:sh.proc_stop, slots] = val
            mask[sh.proc_start:sh.proc_stop, slots] = m
        return vids, values, mask

    def time_column(self, vid: int) -> np.ndarray:
        return np.concatenate([sh.time_column(vid) for sh in self.shards])

    def time_at(self, p: int, vid: int) -> float:
        sh = self.shard_of(p)
        return sh.time_at(p - sh.proc_start, vid)

    def counter_at(self, name: str, p: int, vid: int,
                   default: float = 0.0) -> float:
        sh = self.shard_of(p)
        return sh.counter_at(name, p - sh.proc_start, vid, default)

    def counter_names(self) -> List[str]:
        seen: Dict[str, None] = {}
        for sh in self.shards:
            for name in sh.counter_names():
                seen.setdefault(name)
        return list(seen)

    # -- mapping API (back compat) -------------------------------------
    def __getitem__(self, key: Tuple[int, int]) -> PerfVector:
        p, vid = key
        sh = self.shard_of(p)
        return sh[(p - sh.proc_start, vid)]

    def get(self, key: Tuple[int, int],
            default: Optional[PerfVector] = None) -> Optional[PerfVector]:
        try:
            return self[key]
        except (KeyError, IndexError):
            return default

    def __contains__(self, key: Tuple[int, int]) -> bool:
        p, vid = key
        sh = self.shard_of(p)
        return (p - sh.proc_start, vid) in sh

    def __len__(self) -> int:
        return sum(len(sh) for sh in self.shards)

    def keys(self) -> Iterator[Tuple[int, int]]:
        for sh in self.shards:
            for p, vid in sh.keys():
                yield (p + sh.proc_start, vid)

    __iter__ = keys

    def values(self) -> Iterator[PerfVector]:
        for key in self.keys():
            yield self[key]

    def items(self) -> Iterator[Tuple[Tuple[int, int], PerfVector]]:
        for key in self.keys():
            yield key, self[key]

    # -- storage / merge -----------------------------------------------
    def counter_nbytes(self) -> int:
        return sum(sh.counter_nbytes() for sh in self.shards)

    def counter_dense_nbytes(self) -> int:
        return sum(sh.counter_dense_nbytes() for sh in self.shards)

    def nbytes(self) -> int:
        return sum(sh.nbytes() for sh in self.shards)

    def merge(self) -> PerfStore:
        """Concatenate the blocks into one global PerfStore (the
        ``from_shards`` seam)."""
        return PerfStore.from_shards(self.shards, n_procs=self.n_procs)


def _take(val, sel: np.ndarray):
    """Index broadcastable-or-scalar ``val`` by a boolean selector."""
    arr = np.asarray(val)
    return arr[sel] if arr.ndim else val


def _slice(val, blk: slice):
    arr = np.asarray(val)
    return arr[blk] if arr.ndim else val
