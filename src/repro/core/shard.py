"""Sharded performance-data layer: per-host PerfStore blocks.

The PPG's perf data no longer has to be assembled by a single controller:
each host records its own proc-range block (:class:`PerfShard` — a
:class:`~repro.core.graph.PerfStore` whose rows are local processes offset
by ``proc_start``), and the blocks merge late, either

* into one global store — ``PerfStore.from_shards(shards)`` /
  ``PerfStore.assemble_streamed(shards)`` concatenate the blocks through
  the ``set_entries`` write seam, bit-identical to single-store assembly —
  or
* not at all — :class:`ShardedStore` keeps the per-host blocks and serves
  the PerfStore API on top: writes route to the owning shard by proc
  range, matrix reads are STACKED VIEWS (per-shard blocks concatenated on
  demand), so the detectors consume multi-host data without ever
  densifying it into a merged store.

``repro.core.inject.simulate(..., shards=...)`` executes the replay engine
straight into a ShardedStore (multi-host replay), and
``GraphProfiler.perf_shard`` emits a measured per-host block; both feed
``build_ppg`` unchanged.

:class:`DeviceShardView` closes the online-detection loop: it pins the
per-host blocks as jax device buffers with dirty-row incremental upload,
so the jitted detectors consume device-resident inputs instead of a
re-stacked, re-transferred host matrix on every call.  This module itself
never imports jax (the view imports it lazily inside ``refresh``).
"""
from __future__ import annotations

from typing import (Any, Dict, Iterator, List, Mapping, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.graph import PerfStore, PerfVector


_jit_row_scatter = None


def _row_scatter():
    """Cached jitted ``buf.at[rows].set(vals)``.

    The eager ``at[].set`` path re-runs jax's python scatter lowering on
    every call (~1ms each on CPU); with 8 blocks x (time + var + counter)
    buffers per refresh that dominated the steady-state detect cycle.
    One jitted helper turns each upload into a cached-executable dispatch.
    """
    global _jit_row_scatter
    if _jit_row_scatter is None:
        import jax
        _jit_row_scatter = jax.jit(
            lambda buf, rows, vals: buf.at[rows].set(vals))
    return _jit_row_scatter


def shard_ranges(n_procs: int, n_hosts: int) -> List[Tuple[int, int]]:
    """Split ``[0, n_procs)`` into ``n_hosts`` contiguous (start, stop)
    ranges, as even as possible (first ranges take the remainder).

    ``n_procs == 0`` is an explicit error: the empty store has no valid
    tiling (:class:`ShardedStore` rejects empty ranges), so callers that
    might shard zero processes fail loudly here instead of at the store."""
    n_procs, n_hosts = int(n_procs), int(n_hosts)
    if n_hosts <= 0:
        raise ValueError(f"n_hosts must be positive: {n_hosts}")
    if n_procs <= 0:
        raise ValueError(f"cannot shard {n_procs} processes: ranges must "
                         f"tile a non-empty [0, n_procs)")
    n_hosts = min(n_hosts, n_procs)
    base, rem = divmod(n_procs, n_hosts)
    out, lo = [], 0
    for h in range(n_hosts):
        hi = lo + base + (1 if h < rem else 0)
        out.append((lo, hi))
        lo = hi
    return out


class PerfShard(PerfStore):
    """One host's proc-range block of a PerfStore.

    Rows are LOCAL processes; row ``i`` is global process
    ``proc_start + i``.  Everything else — dense time/var/sample matrices,
    column-sparse counters, the ``set_entries`` seam — is the plain
    :class:`PerfStore` layout, so a shard is just a store that knows where
    its rows land in the global proc space.
    """

    __slots__ = ("proc_start",)

    def __init__(self, proc_start: int, n_procs: int, n_vertices: int = 0):
        super().__init__(n_procs, n_vertices)
        self.proc_start = int(proc_start)

    @property
    def proc_stop(self) -> int:
        return self.proc_start + self.n_procs

    def to_local(self, procs) -> np.ndarray:
        """Global proc indices -> this shard's local row indices."""
        return np.asarray(procs, np.intp) - self.proc_start

    def _tree_meta(self) -> Dict[str, Any]:
        meta = super()._tree_meta()
        meta["proc_start"] = int(self.proc_start)
        return meta

    @classmethod
    def from_tree(cls, tree: Mapping[str, Any],
                  meta: Mapping[str, Any]) -> "PerfShard":
        shard = cls(int(meta.get("proc_start", 0)),
                    int(meta["n_procs"]), int(meta["n_cols"]))
        shard.load_tree(tree, meta)
        return shard

    def __repr__(self) -> str:
        return (f"PerfShard([{self.proc_start}, {self.proc_stop}), "
                f"{len(self)} entries)")


class ShardedStore:
    """Per-host :class:`PerfShard` blocks behind the PerfStore API.

    Writes (``set_column`` / ``set_entries`` / ``set_entry``) route each
    proc index to the shard owning its range — a row's writes keep their
    order, so accumulate-mode scatters are bit-identical to the unsharded
    store.  Matrix reads (``time_matrix`` / ``var_matrix`` /
    ``counter_columns``) are stacked shard views: per-host blocks
    concatenated on demand, never scattered into a merged store.  Use
    :meth:`merge` when a genuinely single store is needed.

    Ranges must tile ``[0, n_procs)`` contiguously (the replay engine
    writes every process).
    """

    __slots__ = ("shards", "n_procs", "_starts")

    def __init__(self, ranges: Sequence[Tuple[int, int]], n_vertices: int = 0):
        ranges = [(int(lo), int(hi)) for lo, hi in ranges]
        if not ranges:
            raise ValueError("ShardedStore needs at least one range")
        lo0 = 0
        for lo, hi in ranges:
            if lo != lo0 or hi <= lo:
                raise ValueError(f"ranges must tile [0, P) contiguously: "
                                 f"{ranges}")
            lo0 = hi
        self.shards: List[PerfShard] = [PerfShard(lo, hi - lo, n_vertices)
                                        for lo, hi in ranges]
        self.n_procs = ranges[-1][1]
        self._starts = np.asarray([lo for lo, _ in ranges], np.intp)

    @classmethod
    def of(cls, shards) -> "ShardedStore":
        """Adopt existing :class:`PerfShard` blocks AS the store (no copy,
        no merge) — e.g. per-host measured blocks from
        ``GraphProfiler.perf_shard``.  The blocks' ranges must tile
        ``[0, n_procs)`` contiguously; hosts may report in any order
        (blocks are sorted by range, like the streamed merge accepts any
        arrival order)."""
        shards = sorted(shards, key=lambda s: s.proc_start)
        store = cls([(s.proc_start, s.proc_stop) for s in shards])
        store.shards = shards
        return store

    # -- routing -------------------------------------------------------
    def shard_of(self, proc: int) -> PerfShard:
        """The shard owning global process ``proc``."""
        i = int(np.searchsorted(self._starts, proc, side="right")) - 1
        return self.shards[i]

    def _route(self, procs: np.ndarray) -> Iterator[Tuple[PerfShard,
                                                          np.ndarray]]:
        """Yield (shard, selector) for each shard with rows in ``procs``;
        selectors preserve the original order of a row's occurrences."""
        sidx = np.searchsorted(self._starts, procs, side="right") - 1
        for i in np.unique(sidx).tolist():
            yield self.shards[i], sidx == i

    # -- write API (the replay engine's surface) -----------------------
    def ensure_columns(self, n_vertices: int) -> None:
        for sh in self.shards:
            sh.ensure_columns(n_vertices)

    def set_column(self, vid: int, time, *, time_var=0.0, samples=1,
                   counters: Optional[Mapping[str, Any]] = None,
                   procs: Optional[np.ndarray] = None) -> None:
        if procs is not None:
            procs = np.asarray(procs, np.intp)
            if procs.size == 0:
                return
            for sh, sel in self._route(procs):
                local = procs[sel] - sh.proc_start
                sh.set_column(vid, _take(time, sel), procs=local,
                              time_var=_take(time_var, sel),
                              samples=_take(samples, sel),
                              counters={k: _take(v, sel)
                                        for k, v in (counters or {}).items()})
            return
        for sh in self.shards:
            blk = slice(sh.proc_start, sh.proc_stop)
            sh.set_column(vid, _slice(time, blk),
                          time_var=_slice(time_var, blk),
                          samples=_slice(samples, blk),
                          counters={k: _slice(v, blk)
                                    for k, v in (counters or {}).items()})

    def set_entries(self, procs, vid: int, time, *, time_var=0.0, samples=1,
                    counters: Optional[Mapping[str, Any]] = None,
                    accumulate: bool = False) -> None:
        procs = np.asarray(procs, np.intp)
        if procs.size == 0:
            return
        t = np.broadcast_to(np.asarray(time, float), procs.shape)
        tv = np.broadcast_to(np.asarray(time_var), procs.shape)
        sm = np.broadcast_to(np.asarray(samples), procs.shape)
        cs = {k: np.broadcast_to(np.asarray(v, float), procs.shape)
              for k, v in (counters or {}).items()}
        for sh, sel in self._route(procs):
            sh.set_entries(procs[sel] - sh.proc_start, vid, t[sel],
                           time_var=tv[sel], samples=sm[sel],
                           counters={k: v[sel] for k, v in cs.items()},
                           accumulate=accumulate)

    def set_entry(self, p: int, vid: int, time: float, *, time_var=0.0,
                  samples=1, counters: Optional[Mapping[str, float]] = None,
                  accumulate: bool = False) -> None:
        sh = self.shard_of(p)
        sh.set_entry(p - sh.proc_start, vid, time, time_var=time_var,
                     samples=samples, counters=counters,
                     accumulate=accumulate)

    def __setitem__(self, key: Tuple[int, int], vec: PerfVector) -> None:
        p, vid = key
        sh = self.shard_of(p)
        sh[(p - sh.proc_start, vid)] = vec

    # -- stacked read views --------------------------------------------
    @property
    def _cols(self) -> int:
        return max(sh._cols for sh in self.shards)

    def time_matrix(self, n_vertices: Optional[int] = None) -> np.ndarray:
        n = self._cols if n_vertices is None else n_vertices
        return np.vstack([sh.time_matrix(n) for sh in self.shards])

    def var_matrix(self, n_vertices: Optional[int] = None) -> np.ndarray:
        n = self._cols if n_vertices is None else n_vertices
        return np.vstack([sh.var_matrix(n) for sh in self.shards])

    def counter_matrix(self, name: str,
                       n_vertices: Optional[int] = None) -> np.ndarray:
        n = self._cols if n_vertices is None else n_vertices
        return np.vstack([sh.counter_matrix(name, n) for sh in self.shards])

    def counter_columns(self, name: str
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Stacked compressed view: the union of the shards' written
        columns, each shard's block placed at its row range."""
        per = [sh.counter_columns(name) for sh in self.shards]
        vids = np.unique(np.concatenate([v for v, _, _ in per]))
        values = np.zeros((self.n_procs, vids.size))
        mask = np.zeros((self.n_procs, vids.size), bool)
        for sh, (v, val, m) in zip(self.shards, per):
            if not v.size:
                continue
            slots = np.searchsorted(vids, v)
            values[sh.proc_start:sh.proc_stop, slots] = val
            mask[sh.proc_start:sh.proc_stop, slots] = m
        return vids, values, mask

    def time_column(self, vid: int) -> np.ndarray:
        return np.concatenate([sh.time_column(vid) for sh in self.shards])

    def time_at(self, p: int, vid: int) -> float:
        sh = self.shard_of(p)
        return sh.time_at(p - sh.proc_start, vid)

    def counter_at(self, name: str, p: int, vid: int,
                   default: float = 0.0) -> float:
        sh = self.shard_of(p)
        return sh.counter_at(name, p - sh.proc_start, vid, default)

    def counter_names(self) -> List[str]:
        seen: Dict[str, None] = {}
        for sh in self.shards:
            for name in sh.counter_names():
                seen.setdefault(name)
        return list(seen)

    # -- mapping API (back compat) -------------------------------------
    def __getitem__(self, key: Tuple[int, int]) -> PerfVector:
        p, vid = key
        sh = self.shard_of(p)
        return sh[(p - sh.proc_start, vid)]

    def get(self, key: Tuple[int, int],
            default: Optional[PerfVector] = None) -> Optional[PerfVector]:
        try:
            return self[key]
        except (KeyError, IndexError):
            return default

    def __contains__(self, key: Tuple[int, int]) -> bool:
        p, vid = key
        sh = self.shard_of(p)
        return (p - sh.proc_start, vid) in sh

    def __len__(self) -> int:
        return sum(len(sh) for sh in self.shards)

    def keys(self) -> Iterator[Tuple[int, int]]:
        for sh in self.shards:
            for p, vid in sh.keys():
                yield (p + sh.proc_start, vid)

    __iter__ = keys

    def values(self) -> Iterator[PerfVector]:
        for key in self.keys():
            yield self[key]

    def items(self) -> Iterator[Tuple[Tuple[int, int], PerfVector]]:
        for key in self.keys():
            yield key, self[key]

    # -- storage / merge -----------------------------------------------
    def counter_nbytes(self) -> int:
        return sum(sh.counter_nbytes() for sh in self.shards)

    def counter_dense_nbytes(self) -> int:
        return sum(sh.counter_dense_nbytes() for sh in self.shards)

    def nbytes(self) -> int:
        return sum(sh.nbytes() for sh in self.shards)

    def merge(self) -> PerfStore:
        """Concatenate the blocks into one global PerfStore (the
        ``from_shards`` seam)."""
        return PerfStore.from_shards(self.shards, n_procs=self.n_procs)

    # -- checkpoint-tree seam ------------------------------------------
    def to_tree(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """(tree, meta): every per-host block through the one
        :meth:`PerfStore.to_tree` seam — the sharded layout (ranges,
        per-shard metas) lives in meta, so a reload rebuilds the same
        blocks without merging or densifying anything."""
        tree: Dict[str, Any] = {"shards": {}}
        shard_meta = []
        for i, sh in enumerate(self.shards):
            sh_tree, sh_meta = sh.to_tree()
            tree["shards"][f"s{i}"] = sh_tree
            shard_meta.append(sh_meta)
        meta = {"format": "shardedstore", "version": 1,
                "n_procs": int(self.n_procs),
                "ranges": [[sh.proc_start, sh.proc_stop]
                           for sh in self.shards],
                "shards": shard_meta}
        return tree, meta

    @classmethod
    def from_tree(cls, tree: Mapping[str, Any],
                  meta: Mapping[str, Any]) -> "ShardedStore":
        from repro.core.graph import check_tree_format
        check_tree_format(meta, "shardedstore", 1)
        shards = [PerfShard.from_tree(tree["shards"][f"s{i}"], sh_meta)
                  for i, sh_meta in enumerate(meta["shards"])]
        return cls.of(shards)


class DeviceShardView:
    """Per-host perf blocks pinned as jax device buffers, incrementally.

    The missing half of online detection: :class:`ShardedStore` keeps the
    (P, V) time matrix as per-host blocks on the HOST, and every jitted
    detect call used to re-assemble and re-transfer the whole stacked
    matrix.  A view pins each block — time, time-variance, and the
    column-sparse counter blocks — as device buffers once, then
    :meth:`refresh` re-uploads only the rows written since the last
    refresh (the store's dirty-row tracking, see
    :meth:`~repro.core.graph.PerfStore.dirty_rows`), so the steady-state
    per-detect transfer is O(dirty rows · V), not O(P · V).

    Buffer lifecycle:

    * construction stores only host references — no jax import, no
      transfer (the analysis layer stays importable without jax);
    * the first :meth:`refresh` uploads every block in full and clears
      the dirty flags;
    * subsequent refreshes upload ``store.dirty_rows()`` per block via an
      on-device row scatter (``buf.at[rows].set``); a changed column
      count, row count, dtype, or counter layout re-pins the affected
      buffers in full;
    * ``time_blocks()`` / ``var_blocks()`` hand the jitted detectors the
      per-block device arrays — the detection kernels reduce them
      blockwise, and only (V,)-sized results ever come back to the host.

    One view per store: refresh consumes the store's dirty flags, so two
    views over the same store would starve each other (``PPG.device_view``
    caches exactly one).  Transfer accounting (``last_upload_rows`` /
    ``last_upload_bytes`` / ``total_upload_bytes``) is asserted by
    ``bench_graph_scale`` to scale with dirty rows.

    Two seams serve the fused detectors (``repro.kernels.detect_fused``):

    * ``revision`` increments whenever a refresh actually changed device
      data (any dirty-row or full upload).  ``merged_column()`` /
      ``cache_merged_column()`` key a device-resident (4, V) merged
      column on (revision, columns, dtype) — historical scales are
      immutable once their run completes, so their merge runs ONCE and
      the cached column feeds every later detect; any write, re-pin,
      layout or dtype change invalidates it automatically.
    * ``kernel_launches`` counts detection kernel launches fed from this
      view (bumped by the ``detect_jax`` entry points), so tests and
      benches can assert "steady-state detect = <=2 launches" directly.
    """

    __slots__ = ("blocks", "_time", "_var", "_counters", "_cols", "_dtype",
                 "last_upload_rows", "last_upload_bytes",
                 "total_upload_bytes", "refreshes", "full_uploads",
                 "revision", "kernel_launches", "_merged_cache")

    def __init__(self, store):
        if isinstance(store, ShardedStore):
            self.blocks: List[PerfStore] = list(store.shards)
        elif isinstance(store, PerfStore):
            self.blocks = [store]
        else:
            raise TypeError(f"DeviceShardView needs a PerfStore or "
                            f"ShardedStore: {type(store).__name__}")
        self._time: Optional[list] = None      # per-block device buffers
        self._var: Optional[list] = None
        self._counters: Optional[list] = None  # per-block {name: (vids, buf)}
        self._cols = -1
        self._dtype: Optional[np.dtype] = None
        self.last_upload_rows = 0
        self.last_upload_bytes = 0
        self.total_upload_bytes = 0
        self.refreshes = 0
        self.full_uploads = 0
        self.revision = 0
        self.kernel_launches = 0
        self._merged_cache: Optional[tuple] = None

    @property
    def n_procs(self) -> int:
        return sum(b.n_procs for b in self.blocks)

    def row_ranges(self) -> List[Tuple[int, int]]:
        """Each block's (start, stop) global proc range, in block order."""
        out, lo = [], 0
        for b in self.blocks:
            start = int(getattr(b, "proc_start", lo))
            out.append((start, start + b.n_procs))
            lo = start + b.n_procs
        return out

    # -- upload --------------------------------------------------------
    def _rows_slab(self, mat: np.ndarray, rows, V: int,
                   dtype: np.dtype) -> np.ndarray:
        """``mat[rows]`` padded/sliced to V columns, in ``dtype``.

        The dtype is passed in rather than read from ``self._dtype``
        because refresh commits the view dtype only after every upload
        succeeded — mid-refresh, ``self._dtype`` is still the OLD one."""
        n = mat.shape[1]
        if n >= V:
            slab = mat[rows, :V]
        else:
            slab = np.zeros((len(rows), V))
            slab[:, :n] = mat[rows]
        return np.ascontiguousarray(slab, dtype)

    def refresh(self, n_vertices: Optional[int] = None,
                dtype=np.float64) -> int:
        """Bring the device buffers up to date; returns bytes uploaded.

        ``n_vertices`` fixes the column count every block is padded or
        sliced to (defaults to the widest block).  ``dtype`` is the buffer
        precision — float64 buffers are created under a thread-local
        ``enable_x64`` so the upload never silently downcasts."""
        import contextlib

        import jax.numpy as jnp
        dtype = np.dtype(dtype)
        if n_vertices is None:
            n_vertices = max(b._cols for b in self.blocks)
        V = int(n_vertices)
        if dtype == np.float64:
            from jax.experimental import enable_x64
            ctx = enable_x64()
        else:
            ctx = contextlib.nullcontext()
        full = (self._time is None or self._cols != V
                or self._dtype != dtype
                or any(buf.shape[0] != b.n_procs
                       for buf, b in zip(self._time, self.blocks)))
        rows_up = bytes_up = 0
        # Every upload is STAGED: new buffers build up in local lists and
        # commit — together with the stores' dirty-flag clears — only
        # after every transfer succeeded.  A device upload that raises
        # mid-refresh (OOM, backend error inside ``at[].set``) therefore
        # leaves the view's buffers AND the dirty flags untouched, so a
        # retried refresh re-uploads the very rows the failed call lost;
        # clearing eagerly used to drop them forever.
        with ctx:
            if full:
                new_time, new_var, new_counters = [], [], []
                for b in self.blocks:
                    every = np.arange(b.n_procs)
                    t = self._rows_slab(b.time, every, V, dtype)
                    v = self._rows_slab(b.time_var, every, V, dtype)
                    new_time.append(jnp.asarray(t))
                    new_var.append(jnp.asarray(v))
                    rows_up += b.n_procs
                    bytes_up += t.nbytes + v.nbytes
                    pinned = {}
                    for name in b.counter_names():
                        vids, values, mask = b.counter_columns(name)
                        slab = np.ascontiguousarray(
                            np.where(mask, values, 0.0), dtype)
                        pinned[name] = (tuple(vids.tolist()),
                                        jnp.asarray(slab))
                        bytes_up += slab.nbytes
                    new_counters.append(pinned)
                self._time, self._var = new_time, new_var
                self._counters = new_counters
                self.full_uploads += 1
                for b in self.blocks:
                    b.clear_dirty()
            else:
                new_time = list(self._time)
                new_var = list(self._var)
                new_counters = [dict(p) for p in self._counters]
                touched = []
                for i, b in enumerate(self.blocks):
                    rows = b.dirty_rows()
                    if not rows.size:
                        continue
                    touched.append(b)
                    scatter = _row_scatter()
                    t = self._rows_slab(b.time, rows, V, dtype)
                    v = self._rows_slab(b.time_var, rows, V, dtype)
                    new_time[i] = scatter(new_time[i], rows, t)
                    new_var[i] = scatter(new_var[i], rows, v)
                    rows_up += rows.size
                    bytes_up += t.nbytes + v.nbytes
                    pinned = new_counters[i]
                    for name in b.counter_names():
                        vids, values, mask = b.counter_columns(name)
                        key = tuple(vids.tolist())
                        have = pinned.get(name)
                        if have is not None and have[0] == key:
                            slab = np.ascontiguousarray(
                                np.where(mask[rows], values[rows], 0.0),
                                dtype)
                            pinned[name] = (key,
                                            scatter(have[1], rows, slab))
                        else:       # new counter / new columns: re-pin
                            slab = np.ascontiguousarray(
                                np.where(mask, values, 0.0), dtype)
                            pinned[name] = (key, jnp.asarray(slab))
                        bytes_up += slab.nbytes
                self._time, self._var = new_time, new_var
                self._counters = new_counters
                for b in touched:
                    b.clear_dirty()
        self._cols, self._dtype = V, dtype
        if full or rows_up:
            self.revision += 1
        self.last_upload_rows = rows_up
        self.last_upload_bytes = bytes_up
        self.total_upload_bytes += bytes_up
        self.refreshes += 1
        return bytes_up

    # -- device reads (what the jitted detectors consume) --------------
    def time_blocks(self) -> list:
        """Per-block (n_local, V) device time matrices, in row order."""
        if self._time is None:
            raise RuntimeError("DeviceShardView.refresh() before reading")
        return list(self._time)

    def var_blocks(self) -> list:
        if self._var is None:
            raise RuntimeError("DeviceShardView.refresh() before reading")
        return list(self._var)

    def merged_column(self):
        """The cached (4, V) merged column, or None if stale/absent.

        Valid only while nothing about the device data changed since
        :meth:`cache_merged_column`: same revision (no dirty-row or full
        upload), same column count, same dtype.  Completed scales never
        write again, so their cache hits on every steady-state detect;
        the live scale's misses by construction."""
        cached = self._merged_cache
        if cached is None:
            return None
        rev, cols, dtype, col = cached
        if (rev != self.revision or cols != self._cols
                or dtype != self._dtype):
            return None
        return col

    def cache_merged_column(self, col) -> None:
        """Pin ``col`` (a (4, V) device array) as this view's merged
        column for the CURRENT (revision, columns, dtype) state."""
        self._merged_cache = (self.revision, self._cols, self._dtype, col)

    def counter_blocks(self, name: str) -> List[Tuple[Tuple[int, ...], Any]]:
        """Per-block ``(vids, (n_local, k) device values)`` for one
        counter (masked-off entries are 0.0); empty vids where a block
        never wrote it."""
        if self._counters is None:
            raise RuntimeError("DeviceShardView.refresh() before reading")
        return [pinned.get(name, ((), None)) for pinned in self._counters]

    def __repr__(self) -> str:
        state = "unpinned" if self._time is None else \
            f"{self._cols} cols, {np.dtype(self._dtype).name}"
        return (f"DeviceShardView({len(self.blocks)} blocks, "
                f"{self.n_procs} procs, {state})")


def _take(val, sel: np.ndarray):
    """Index broadcastable-or-scalar ``val`` by a boolean selector."""
    arr = np.asarray(val)
    return arr[sel] if arr.ndim else val


def _slice(val, blk: slice):
    arr = np.asarray(val)
    return arr[blk] if arr.ndim else val
