"""Dependence-driven performance simulation + delay injection.

Reproduces the paper's evaluation methodology (§II motivating example: a
delay injected into process 4 of NPB-CG propagates through communication
dependence until an MPI_Allreduce exposes it as scaling loss).  Given a PSG
with Comm vertices, per-vertex base times, and injected per-(process,vertex)
delays, the simulator executes the dependence graph: processes advance
clocks through Comp vertices, block at p2p edges until the partner arrives
and at collectives until the whole replica group arrives.  Waiting time is
recorded in the 'wait_s' counter — exactly the signal Algorithm 1's pruning
keys on.

The replay engine is array-level end to end:

* ``base_times`` is a vectorized channel — ``fn(procs_array, vid) ->
  per-process seconds`` — so a Comp vertex costs O(1) Python calls, not
  O(P).  Scalar callables (``fn(proc, vid) -> float``) are auto-detected
  and shimmed (see :class:`_BaseTimes` / :func:`vectorized_base_times`).
* p2p pairs are decomposed into *wavefront rounds* (:func:`p2p_rounds`):
  a greedy topological coloring of the pair list in which no process
  appears twice per round, so each round is one numpy gather/scatter
  clock update plus one batched ``PerfStore.set_entries`` write while
  bit-matching the order-dependent sequential semantics.  The per-pair
  reference implementation is retained (``p2p="sequential"``) as the
  property-test oracle; the default ``"auto"`` falls back to it for
  degenerate chains where rounds cannot batch.
* :func:`simulate_series` is a single stacked pass: the per-scale clocks
  form an (S, P_max) masked matrix advanced once per scheduled vertex for
  all scales simultaneously, writing into per-scale PerfStores — the
  vertex schedule is walked exactly once for the whole series.

The same machinery generates multi-scale series for non-scalable-vertex
detection, with per-vertex scaling laws (ideal 1/p compute, logarithmic
collectives, serial fractions, ...).  Measured single-scale profiles from
GraphProfiler can seed ``base_times`` (:func:`seeded_base_times`,
``GraphProfiler.base_times``) so case studies run on real models.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import (BRANCH, CALL, COMM, COMP, LOOP, PPG, PSG,
                              PerfStore, PerfVector, pairs_array,
                              vertex_pairs_array)
from repro.core.ppg import build_ppg
from repro.core.shard import ShardedStore, shard_ranges

# default comm model constants (tunable; roughly ICI-like)
LATENCY_S = 1e-6
BANDWIDTH = 50e9

P2P_MODES = ("auto", "wavefront", "sequential")


def _subtree_has_comm(psg: PSG, vid: int, cache: Dict[int, bool]) -> bool:
    if vid in cache:
        return cache[vid]
    v = psg.vertices[vid]
    r = v.kind == COMM or any(_subtree_has_comm(psg, c, cache)
                              for c in psg.children(vid))
    cache[vid] = r
    return r


def schedule(psg: PSG) -> List[int]:
    """Flattened execution schedule: control structures containing comm are
    expanded so communication ordering is visible; others are atomic."""
    cache: Dict[int, bool] = {}
    out: List[int] = []

    def walk(vid: int):
        for c in psg.children(vid):
            v = psg.vertices[c]
            if v.kind in (LOOP, BRANCH, CALL) and _subtree_has_comm(psg, c,
                                                                    cache):
                walk(c)
            else:
                out.append(c)

    walk(psg.root)
    return out


def default_comm_time(v, n_procs: int, group: Sequence[int]) -> float:
    g = max(len(group), 2)
    steps = max(int(np.ceil(np.log2(g))), 1)
    return LATENCY_S * steps + float(v.comm_bytes) / BANDWIDTH


@dataclasses.dataclass
class SimResult:
    ppg: PPG
    clocks: List[float]                    # final per-process time
    sched: List[int]

    @property
    def makespan(self) -> float:
        return max(self.clocks) if self.clocks else 0.0

    @property
    def shards(self):
        """Per-host PerfShard blocks when the replay ran sharded
        (``simulate(..., shards=...)``), else None."""
        return getattr(self.ppg.perf, "shards", None)


# ---------------------------------------------------------------------------
# base_times channel: vectorized contract + scalar-callable shim
# ---------------------------------------------------------------------------

def vectorized_base_times(fn):
    """Mark ``fn`` as vectorized: ``fn(procs_array, vid) -> seconds`` where
    the result broadcasts to ``procs_array.shape``.  Skips the shim's
    auto-detection probe (set ``fn.scalana_vectorized = False`` to force
    the scalar per-process loop instead)."""
    fn.scalana_vectorized = True
    return fn


def seeded_base_times(times, n_vertices: Optional[int] = None) -> Callable:
    """Vectorized ``base_times`` from a per-vertex time table.

    ``times`` is a ``{vid: seconds}`` mapping (e.g. from
    ``GraphProfiler.perf_vectors()``) or a dense per-vertex array; vertices
    outside the table replay at 0.0 seconds.
    """
    if isinstance(times, Mapping):
        n = (max(times, default=-1) + 1) if n_vertices is None else n_vertices
        table = np.zeros(max(int(n), 0))
        for vid, t in times.items():
            if 0 <= vid < table.size:
                table[vid] = t
    else:
        table = np.asarray(times, float)

    @vectorized_base_times
    def base(procs, vid):
        return float(table[vid]) if 0 <= vid < table.size else 0.0

    return base


class _BaseTimes:
    """Resolved per-process base-times channel for one scale.

    The public contract is vectorized — ``fn(procs_array, vid)`` returns
    per-process seconds broadcastable to ``(n_procs,)`` — which turns the
    former O(P·V) Python callbacks into O(V) array calls.  Scalar
    callables (``fn(proc, vid) -> float``) are auto-detected on the first
    vertex: elementwise bodies that happen to accept arrays are used
    vectorized directly; bodies that raise on arrays (e.g. ``if p == 2``)
    fall back to a per-process loop.  A ``scalana_vectorized`` attribute
    (see :func:`vectorized_base_times`) skips the probe.
    """

    __slots__ = ("fn", "n", "procs", "mode")

    def __init__(self, fn: Callable, n_procs: int):
        self.fn = fn
        self.n = int(n_procs)
        self.procs = np.arange(self.n)
        flag = getattr(fn, "scalana_vectorized", None)
        self.mode = ("vector" if flag
                     else "scalar" if flag is False else "auto")

    def _vector(self, vid: int) -> np.ndarray:
        out = np.asarray(self.fn(self.procs, vid), float)
        return np.array(np.broadcast_to(out, (self.n,)), float)

    def _scalar(self, vid: int) -> np.ndarray:
        return np.fromiter((self.fn(p, vid) for p in range(self.n)),
                           float, count=self.n)

    def __call__(self, vid: int) -> np.ndarray:
        if self.mode == "scalar":
            return self._scalar(vid)
        if self.mode == "vector":
            return self._vector(vid)
        # auto: try vectorized; a body that rejects arrays (possibly only
        # on some vertices — branches like ``if p == 2``) demotes the
        # callable to the scalar loop for the rest of the replay
        try:
            return self._vector(vid)
        except Exception:
            self.mode = "scalar"
            return self._scalar(vid)


# ---------------------------------------------------------------------------
# wavefront decomposition of ordered p2p pair lists
# ---------------------------------------------------------------------------

def _p2p_rounds_greedy(pairs: Sequence[Tuple[int, int]], n_procs: int
                       ) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Scalar reference for :func:`p2p_rounds`: greedy next-free-round
    assignment over the pair list (the property tests pin peel == greedy).
    """
    next_round: Dict[int, int] = {}
    rounds: List[Tuple[List[int], List[int]]] = []
    for s, d in pairs:
        if s >= n_procs or d >= n_procs:
            continue
        r = max(next_round.get(s, 0), next_round.get(d, 0))
        if r == len(rounds):
            rounds.append(([], []))
        rounds[r][0].append(s)
        rounds[r][1].append(d)
        next_round[s] = next_round[d] = r + 1
    return [(np.asarray(sa, np.intp), np.asarray(da, np.intp))
            for sa, da in rounds]


def p2p_rounds(pairs: Sequence[Tuple[int, int]], n_procs: int,
               bail: bool = False
               ) -> Optional[List[Tuple[np.ndarray, np.ndarray]]]:
    """Decompose an ordered p2p pair list into wavefront rounds.

    Topological coloring over the sender/receiver multigraph: each pair
    lands in the earliest round strictly after every earlier pair it
    shares a process with.  Within a round no process appears twice (a
    self-pair ``(p, p)`` occupies ``p`` once in both roles), so the
    per-pair clock updates commute and a round executes as one numpy
    gather/scatter; replaying rounds in order bit-matches the sequential
    per-pair semantics.  Pairs touching processes ``>= n_procs`` are
    dropped, consistent with the simulator.

    Computed by vectorized peeling — each iteration selects every pair
    that is the first remaining pair for BOTH its processes (identical
    rounds to the greedy scalar scan, which layers the same
    immediate-predecessor-per-process DAG).  ``bail=True`` returns None
    as soon as a round batches poorly (a degenerate chain like a ring in
    natural order colors one pair per round — O(pairs) rounds — where the
    per-pair reference loop is the better executor).  Returns a
    ``(senders, receivers)`` index-array tuple per round.
    """
    if not len(pairs):
        return []
    arr = pairs_array(pairs)
    keep = (arr[:, 0] < n_procs) & (arr[:, 1] < n_procs)
    s, d = arr[keep, 0], arr[keep, 1]
    order = np.arange(s.size)
    sentinel = s.size                   # > any original pair index
    rounds: List[Tuple[np.ndarray, np.ndarray]] = []
    first = np.empty(n_procs, np.intp)
    while s.size:
        first[:] = sentinel
        np.minimum.at(first, s, order)
        np.minimum.at(first, d, order)
        sel = (first[s] == order) & (first[d] == order)
        if bail and s.size > 64 and 8 * int(sel.sum()) < s.size:
            return None
        rounds.append((s[sel], d[sel]))
        rest = ~sel
        s, d, order = s[rest], d[rest], order[rest]
    return rounds


# ---------------------------------------------------------------------------
# the replay engine: per-scale lanes over one stacked clock matrix
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Lane:
    """Per-scale replay state — one row of the stacked (S, P_max) clock
    matrix plus that scale's store / rng / injection table."""
    n: int
    base: _BaseTimes
    store: PerfStore
    rng: np.random.Generator
    inj: Dict[int, Dict[int, float]]
    clocks: np.ndarray                 # length-P_max row view; [n:] masked


def _inject_by_vid(inject: Optional[Mapping[Tuple[int, int], float]],
                   n_procs: int) -> Dict[int, Dict[int, float]]:
    out: Dict[int, Dict[int, float]] = {}
    for (p, vid), extra in (inject or {}).items():
        if p < n_procs:
            out.setdefault(vid, {})[p] = extra
    return out


def _p2p_wavefront(lane: _Lane, v, vid: int, tc: float,
                   rounds: List[Tuple[np.ndarray, np.ndarray]]) -> None:
    """One gather/scatter clock update + one batched store write per round."""
    clocks, store = lane.clocks, lane.store
    for sa, da in rounds:
        cs = clocks[sa]                              # fancy index: copies
        cd = clocks[da]
        wait = np.maximum(cs - cd, 0.0)
        procs = np.concatenate([da, sa])             # receiver adds first
        times = np.concatenate([wait + tc, np.full(sa.size, tc)])
        waits = np.concatenate([wait, np.zeros(sa.size)])
        store.set_entries(procs, vid, times,
                          counters={"wait_s": waits,
                                    "comm_bytes": v.comm_bytes},
                          accumulate=True)
        clocks[da] = np.maximum(cd, cs) + tc
        clocks[sa] = cs + tc


def _p2p_sequential(lane: _Lane, v, vid: int, tc: float) -> None:
    """Retained per-pair reference implementation (the property-test
    oracle, and the faster path for degenerate chains where rounds cannot
    batch).  Entries accumulate: a process participating in several pairs
    records its TOTAL time at the vertex (each receive adds wait + tc,
    each send adds tc), matching its clock advance."""
    clocks, store = lane.clocks, lane.store
    for s, d in v.p2p_pairs:
        if s >= lane.n or d >= lane.n:
            continue
        cs, cd = float(clocks[s]), float(clocks[d])
        wait = max(0.0, cs - cd)
        store.set_entry(d, vid, wait + tc,
                        counters={"wait_s": wait,
                                  "comm_bytes": v.comm_bytes},
                        accumulate=True)
        store.set_entry(s, vid, tc,
                        counters={"wait_s": 0.0,
                                  "comm_bytes": v.comm_bytes},
                        accumulate=True)
        clocks[d] = max(cd, cs) + tc
        clocks[s] = cs + tc


def _collective(lane: _Lane, v, vid: int, comm_time: Callable) -> None:
    """Per-lane reference for :func:`_collective_stacked` (property-tested
    bit-identical; the replay engine itself runs the stacked path)."""
    clocks = lane.clocks
    groups = v.meta.get("replica_groups") or [list(range(lane.n))]
    for g in groups:
        gi = np.asarray([p for p in g if p < lane.n], int)
        if gi.size == 0:
            continue
        tc = comm_time(v, lane.n, gi.tolist())
        sync = float(clocks[gi].max())
        wait = sync - clocks[gi]
        lane.store.set_column(vid, wait + tc, procs=gi,
                              counters={"wait_s": wait,
                                        "comm_bytes": v.comm_bytes})
        clocks[gi] = sync + tc


def _collective_stacked(lanes: List[_Lane], clocks: np.ndarray, v, vid: int,
                        comm_time: Callable) -> None:
    """Advance EVERY scale through one collective leg together.

    Per replica group, the synchronization point of all S scales is one
    cross-scale masked max over the stacked (S, P_max) clock matrix —
    previously one masked row reduction per scale.  Store writes and the
    per-lane ``comm_time`` stay per scale (tc depends on the lane's clipped
    group); results are bit-identical to :func:`_collective` per lane.
    """
    S, P_max = clocks.shape
    groups = v.meta.get("replica_groups")
    for g in (groups if groups else [None]):
        member = np.zeros((S, P_max), bool)
        gis: List[np.ndarray] = []
        for si, lane in enumerate(lanes):
            if g is None:
                gi = np.arange(lane.n, dtype=np.intp)
            else:
                garr = np.asarray(g, np.intp)
                gi = garr[garr < lane.n]          # keeps the group's order
            gis.append(gi)
            member[si, gi] = True
        # ONE matrix op for all S scales (the former per-scale row max)
        sync = np.where(member, clocks, -np.inf).max(axis=1, initial=-np.inf)
        for si, lane in enumerate(lanes):
            gi = gis[si]
            if gi.size == 0:
                continue
            tc = comm_time(v, lane.n, gi.tolist())
            wait = sync[si] - clocks[si, gi]
            lane.store.set_column(vid, wait + tc, procs=gi,
                                  counters={"wait_s": wait,
                                            "comm_bytes": v.comm_bytes})
            clocks[si, gi] = sync[si] + tc


def _replay(psg: PSG, lanes: List[_Lane], clocks: np.ndarray,
            comm_time: Callable, jitter: float, p2p: str) -> List[int]:
    """Advance every lane through the vertex schedule in ONE pass.

    ``clocks`` is the stacked (S, P_max) clock matrix; ``lanes[si].clocks``
    is row ``si`` and entries ``>= lane.n`` are masked (never read or
    written).  Comp legs advance the whole matrix in one add; collective
    legs synchronize all scales in one cross-scale masked max
    (:func:`_collective_stacked`); only p2p legs stay per-scale (their
    wavefront rounds depend on the lane's proc count).
    """
    if p2p not in P2P_MODES:
        raise ValueError(f"p2p mode must be one of {P2P_MODES}: {p2p!r}")
    sched = schedule(psg)
    S, P_max = clocks.shape
    rounds_cache: Dict[Tuple[int, int], List] = {}
    t_stack = np.zeros((S, P_max))

    for vid in sched:
        v = psg.vertices[vid]
        if v.kind == COMM:
            if v.p2p_pairs:
                for lane in lanes:
                    tc = comm_time(v, lane.n, [0, 1])
                    rounds = None
                    if p2p != "sequential":
                        key = (vid, lane.n)
                        rounds = rounds_cache.get(key, False)
                        if rounds is False:
                            # "auto" bails out of peeling on degenerate
                            # chains (one pair per round) — the per-pair
                            # reference loop is the better executor there
                            rounds = rounds_cache[key] = p2p_rounds(
                                vertex_pairs_array(v), lane.n,
                                bail=(p2p == "auto"))
                    if rounds is None:
                        _p2p_sequential(lane, v, vid, tc)
                    else:
                        _p2p_wavefront(lane, v, vid, tc, rounds)
            else:
                _collective_stacked(lanes, clocks, v, vid, comm_time)
            continue
        # Comp / atomic control: one stacked clock advance for all scales
        t_stack[:] = 0.0
        for si, lane in enumerate(lanes):
            t = lane.base(vid)
            np.maximum(t, 0.0, out=t)
            for p, extra in lane.inj.get(vid, {}).items():
                t[p] += extra
            if jitter:
                t *= 1.0 + jitter * lane.rng.standard_normal(lane.n)
                np.maximum(t, 0.0, out=t)
            lane.store.set_column(vid, t, counters={"flops": v.flops,
                                                    "bytes": v.bytes})
            t_stack[si, :lane.n] = t
        clocks += t_stack
    return sched


def _resolve_shards(shards, n_procs: int):
    """``shards=`` argument -> list of (start, stop) host ranges or None."""
    if shards is None:
        return None
    if isinstance(shards, (int, np.integer)):
        return shard_ranges(n_procs, int(shards))
    ranges = [(int(lo), int(hi)) for lo, hi in shards]
    if not ranges or ranges[-1][1] != n_procs:
        # the replay writes every process; a partial tiling would silently
        # drop rows (ShardedStore checks contiguity-from-0, not the end)
        raise ValueError(f"shard ranges must cover [0, {n_procs}): {ranges}")
    return ranges


def _make_lane(psg: PSG, n_procs: int, base_times: Callable, seed: int,
               inject, clocks_row: np.ndarray, shards=None) -> _Lane:
    ranges = _resolve_shards(shards, n_procs)
    store = PerfStore(n_procs, len(psg.vertices)) if ranges is None else \
        ShardedStore(ranges, len(psg.vertices))
    return _Lane(n=n_procs, base=_BaseTimes(base_times, n_procs),
                 store=store,
                 rng=np.random.default_rng(seed),
                 inj=_inject_by_vid(inject, n_procs),
                 clocks=clocks_row)


def _finish(psg: PSG, lane: _Lane) -> PPG:
    ppg = build_ppg(psg, lane.n, lane.store)
    ppg.meta["makespan"] = float(lane.clocks[:lane.n].max()) if lane.n \
        else 0.0
    return ppg


def simulate(psg: PSG, n_procs: int,
             base_times: Callable,
             *,
             inject: Optional[Mapping[Tuple[int, int], float]] = None,
             comm_time: Callable = default_comm_time,
             jitter: float = 0.0,
             seed: int = 0,
             p2p: str = "auto",
             shards=None) -> SimResult:
    """Run the dependence simulation.

    ``base_times(procs_array, vid) -> per-process seconds`` for
    Comp/atomic-control vertices (vectorized; scalar ``(proc, vid) ->
    float`` callables are auto-detected and shimmed).
    ``inject``: ``{(proc, vid): extra_seconds}`` delay injection.
    ``p2p``: ``"auto"`` (default) | ``"wavefront"`` | ``"sequential"`` —
    all three produce bit-identical results; "sequential" is the retained
    per-pair reference loop, "wavefront" replays disjoint rounds as
    batched gather/scatters, and "auto" picks per vertex.
    ``shards``: multi-host replay — a host count or explicit (start, stop)
    proc ranges.  Perf writes land in per-host
    :class:`~repro.core.shard.PerfShard` blocks behind a
    :class:`~repro.core.shard.ShardedStore` (the PPG keeps the sharded
    store; ``result.shards`` exposes the blocks), bit-identical to the
    unsharded store entry for entry.

    Perf data is written straight into a :class:`PerfStore` — whole
    (proc,)-columns for Comp/collective legs, batched
    :meth:`PerfStore.set_entries` scatters per p2p wavefront round — so
    simulation cost is O(V) vectorized steps, not O(P*V) Python object
    churn.  Counter writes go through the store's column-sparse layout:
    ``wait_s``/``comm_bytes`` materialize only at Comm vertices,
    ``flops``/``bytes`` only at Comp vertices, so counter memory tracks
    the defining vertex subset, not (P, V).
    """
    n_procs = int(n_procs)
    clocks = np.zeros((1, max(n_procs, 1)))
    lane = _make_lane(psg, n_procs, base_times, seed, inject, clocks[0],
                      shards=shards)
    sched = _replay(psg, [lane], clocks, comm_time, jitter, p2p)
    return SimResult(ppg=_finish(psg, lane),
                     clocks=lane.clocks[:n_procs].tolist(), sched=sched)


# ---------------------------------------------------------------------------
# Multi-scale series generation (non-scalable vertex detection input)
# ---------------------------------------------------------------------------

def ideal_strong_scaling(t1: float):
    return lambda p: t1 / p


def serial_fraction(t1: float, frac: float):
    """Amdahl: a fraction of the vertex does not parallelize."""
    return lambda p: t1 * (frac + (1.0 - frac) / p)


def _scale_base(time_at_scale: Callable, n: int) -> Callable:
    """Bind the scale argument, propagating the vectorization marker."""
    def fn(p, vid):
        return time_at_scale(p, vid, n)
    flag = getattr(time_at_scale, "scalana_vectorized", None)
    if flag is not None:
        fn.scalana_vectorized = flag
    return fn


def simulate_series(psg: PSG, scales: Sequence[int],
                    time_at_scale: Callable,
                    *,
                    inject: Optional[Mapping[Tuple[int, int], float]] = None,
                    comm_time: Callable = default_comm_time,
                    jitter: float = 0.0, seed: int = 0,
                    p2p: str = "auto") -> Dict[int, PPG]:
    """{n_procs: PPG} series in ONE stacked pass.

    ``time_at_scale(procs_array, vid, n_procs) -> per-process seconds``
    encodes the scaling law (scalar ``(proc, vid, n) -> float`` callables
    are shimmed like :func:`simulate`'s).  The vertex schedule is walked
    exactly once: per-scale clocks form an (S, P_max) masked matrix
    advanced per scheduled vertex for all scales simultaneously, and each
    scale writes into its own :class:`PerfStore`.  Results are
    bit-identical to S independent :func:`simulate` calls with
    ``seed=seed + n``.
    """
    ns = [int(n) for n in scales]
    if not ns:
        return {}
    clocks = np.zeros((len(ns), max(max(ns), 1)))
    lanes = [_make_lane(psg, n, _scale_base(time_at_scale, n), seed + n,
                        inject, clocks[si])
             for si, n in enumerate(ns)]
    _replay(psg, lanes, clocks, comm_time, jitter, p2p)
    return {lane.n: _finish(psg, lane) for lane in lanes}
