"""Dependence-driven performance simulation + delay injection.

Reproduces the paper's evaluation methodology (§II motivating example: a
delay injected into process 4 of NPB-CG propagates through communication
dependence until an MPI_Allreduce exposes it as scaling loss).  Given a PSG
with Comm vertices, per-vertex base times, and injected per-(process,vertex)
delays, the simulator executes the dependence graph: processes advance
clocks through Comp vertices, block at p2p edges until the partner arrives
and at collectives until the whole replica group arrives.  Waiting time is
recorded in the 'wait_s' counter — exactly the signal Algorithm 1's pruning
keys on.

The same machinery generates multi-scale series for non-scalable-vertex
detection, with per-vertex scaling laws (ideal 1/p compute, logarithmic
collectives, serial fractions, ...).  Measured single-scale profiles from
GraphProfiler can seed ``base_times`` so case studies run on real models.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import (BRANCH, CALL, COMM, COMP, LOOP, PPG, PSG,
                              PerfStore, PerfVector)
from repro.core.ppg import build_ppg

# default comm model constants (tunable; roughly ICI-like)
LATENCY_S = 1e-6
BANDWIDTH = 50e9


def _subtree_has_comm(psg: PSG, vid: int, cache: Dict[int, bool]) -> bool:
    if vid in cache:
        return cache[vid]
    v = psg.vertices[vid]
    r = v.kind == COMM or any(_subtree_has_comm(psg, c, cache)
                              for c in psg.children(vid))
    cache[vid] = r
    return r


def schedule(psg: PSG) -> List[int]:
    """Flattened execution schedule: control structures containing comm are
    expanded so communication ordering is visible; others are atomic."""
    cache: Dict[int, bool] = {}
    out: List[int] = []

    def walk(vid: int):
        for c in psg.children(vid):
            v = psg.vertices[c]
            if v.kind in (LOOP, BRANCH, CALL) and _subtree_has_comm(psg, c,
                                                                    cache):
                walk(c)
            else:
                out.append(c)

    walk(psg.root)
    return out


def default_comm_time(v, n_procs: int, group: Sequence[int]) -> float:
    g = max(len(group), 2)
    steps = max(int(np.ceil(np.log2(g))), 1)
    return LATENCY_S * steps + float(v.comm_bytes) / BANDWIDTH


@dataclasses.dataclass
class SimResult:
    ppg: PPG
    clocks: List[float]                    # final per-process time
    sched: List[int]

    @property
    def makespan(self) -> float:
        return max(self.clocks) if self.clocks else 0.0


def simulate(psg: PSG, n_procs: int,
             base_times: Callable[[int, int], float],
             *,
             inject: Optional[Mapping[Tuple[int, int], float]] = None,
             comm_time: Callable = default_comm_time,
             jitter: float = 0.0,
             seed: int = 0) -> SimResult:
    """Run the dependence simulation.

    base_times(proc, vid) -> seconds for Comp/atomic-control vertices.
    inject: {(proc, vid): extra_seconds} delay injection.

    Perf data is written straight into a :class:`PerfStore` — whole
    (proc,)-columns at a time — so simulation cost is O(V) vectorized steps,
    not O(P*V) Python object churn; only p2p pairs are walked sequentially
    (their clock updates are order-dependent).  Counter writes go through
    the store's column-sparse layout: ``wait_s``/``comm_bytes`` materialize
    only at Comm vertices, ``flops``/``bytes`` only at Comp vertices, so
    counter memory tracks the defining vertex subset, not (P, V).
    """
    inject = dict(inject or {})
    inj_by_vid: Dict[int, Dict[int, float]] = {}
    for (p, vid), extra in inject.items():
        if p < n_procs:
            inj_by_vid.setdefault(vid, {})[p] = extra
    rng = np.random.default_rng(seed)
    sched = schedule(psg)
    clocks = np.zeros(n_procs)
    store = PerfStore(n_procs, len(psg.vertices))

    for vid in sched:
        v = psg.vertices[vid]
        if v.kind == COMM:
            groups = v.meta.get("replica_groups") or [list(range(n_procs))]
            if v.p2p_pairs:
                tc = comm_time(v, n_procs, [0, 1])
                for (s, d) in v.p2p_pairs:
                    if s >= n_procs or d >= n_procs:
                        continue
                    cs, cd = float(clocks[s]), float(clocks[d])
                    wait = max(0.0, cs - cd)
                    store.set_entry(d, vid, wait + tc,
                                    counters={"wait_s": wait,
                                              "comm_bytes": v.comm_bytes})
                    if (s, vid) not in store:
                        store.set_entry(s, vid, tc,
                                        counters={"wait_s": 0.0,
                                                  "comm_bytes": v.comm_bytes})
                    clocks[d] = max(cd, cs) + tc
                    clocks[s] = cs + tc
            else:
                for g in groups:
                    gi = np.asarray([p for p in g if p < n_procs], int)
                    if gi.size == 0:
                        continue
                    tc = comm_time(v, n_procs, gi.tolist())
                    sync = float(clocks[gi].max())
                    wait = sync - clocks[gi]
                    store.set_column(vid, wait + tc, procs=gi,
                                     counters={"wait_s": wait,
                                               "comm_bytes": v.comm_bytes})
                    clocks[gi] = sync + tc
            continue
        t = np.fromiter((base_times(p, vid) for p in range(n_procs)),
                        float, count=n_procs)
        np.maximum(t, 0.0, out=t)
        for p, extra in inj_by_vid.get(vid, {}).items():
            t[p] += extra
        if jitter:
            t *= 1.0 + jitter * rng.standard_normal(n_procs)
            np.maximum(t, 0.0, out=t)
        store.set_column(vid, t,
                         counters={"flops": v.flops, "bytes": v.bytes})
        clocks += t

    ppg = build_ppg(psg, n_procs, store)
    ppg.meta["makespan"] = float(clocks.max()) if n_procs else 0.0
    return SimResult(ppg=ppg, clocks=clocks.tolist(), sched=sched)


# ---------------------------------------------------------------------------
# Multi-scale series generation (non-scalable vertex detection input)
# ---------------------------------------------------------------------------

def ideal_strong_scaling(t1: float):
    return lambda p: t1 / p


def serial_fraction(t1: float, frac: float):
    """Amdahl: a fraction of the vertex does not parallelize."""
    return lambda p: t1 * (frac + (1.0 - frac) / p)


def simulate_series(psg: PSG, scales: Sequence[int],
                    time_at_scale: Callable[[int, int, int], float],
                    *,
                    inject: Optional[Mapping[Tuple[int, int], float]] = None,
                    comm_time: Callable = default_comm_time,
                    jitter: float = 0.0, seed: int = 0) -> Dict[int, PPG]:
    """{n_procs: PPG} series. time_at_scale(proc, vid, n_procs) -> seconds."""
    out: Dict[int, PPG] = {}
    for n in scales:
        res = simulate(
            psg, n, lambda p, vid: time_at_scale(p, vid, n),
            inject=inject, comm_time=comm_time, jitter=jitter, seed=seed + n)
        out[n] = res.ppg
    return out
