"""Trip-count-exact HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
scan-over-layers ``while`` body (where ~all FLOPs and collective traffic
live) is counted at 1/n_layers of its true cost.  This module re-derives
roofline inputs from the compiled HLO *text*, walking the computation call
graph with multipliers:

  * ``while`` ops carry ``backend_config={"known_trip_count":{"n": N}}`` —
    body and condition computations are scaled by N (nested whiles
    multiply);
  * ``fusion``/``to_apply`` interiors contribute FLOPs but not memory
    traffic (they are register/VMEM-resident by construction);
  * ``call``/``conditional`` propagate both.

Per computation we count:
  * dot FLOPs: 2 x |out| x contraction size (the MXU term; elementwise
    VPU flops are reported separately by cost_analysis and are negligible
    for these models);
  * memory traffic: sum of operand + result buffer bytes over non-trivial
    ops (parameter/constant/tuple/get-tuple-element/bitcast excluded) —
    an upper bound consistent with fused scheduling;
  * collective payload bytes per kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), from
    repro.core.hlo.parse_collectives.

Everything is per-device: the HLO module is the per-device SPMD program.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.core.hlo import parse_collectives, shape_bytes

_COMP_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_OP_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\([^()]*\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_SHAPE_DIMS_RE = re.compile(r"[a-z0-9]+\[([0-9,]*)\]")

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id", "iota"}


def _fusion_root_is_dus(line: str, root_map: Dict[str, str]) -> bool:
    m = _CALLS_RE.search(line)
    return bool(m) and root_map.get(m.group(1)) == "dynamic-update-slice"


def _operand_names(line: str, opcode: str) -> List[str]:
    """%names inside the op's argument parens."""
    start = line.find(opcode + "(")
    if start < 0:
        return []
    rest = line[start + len(opcode) + 1:]
    depth = 1
    buf = []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    return _OPERAND_RE.findall("".join(buf))


def _dims_of(type_str: str) -> List[int]:
    m = _SHAPE_DIMS_RE.search(type_str)
    if not m:
        return []
    return [int(x) for x in m.group(1).split(",") if x.strip()]


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    # (child, multiplier, flops_only)
    edges: List[Tuple[str, float, bool]] = dataclasses.field(
        default_factory=list)


def _split_computations(text: str) -> Dict[str, Tuple[List[str], bool]]:
    """name -> (op lines, is_entry)."""
    comps: Dict[str, Tuple[List[str], bool]] = {}
    cur: Optional[str] = None
    cur_lines: List[str] = []
    is_entry = False
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line)
            if m:
                cur = m.group(2)
                is_entry = bool(m.group(1))
                cur_lines = []
        else:
            if line.strip() == "}":
                comps[cur] = (cur_lines, is_entry)
                cur = None
            else:
                cur_lines.append(line)
    return comps


def _root_opcode(lines: List[str]) -> str:
    for line in lines:
        if line.lstrip().startswith("ROOT"):
            m = _OP_LINE_RE.match(line)
            if m:
                return m.group(3)
    return ""


def _analyze_computation(lines: List[str],
                         root_map: Optional[Dict[str, str]] = None
                         ) -> CompStats:
    root_map = root_map or {}
    st = CompStats()
    symtab: Dict[str, str] = {}
    for line in lines:
        m = _OP_LINE_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.group(1), m.group(2), m.group(3)
        symtab[name] = type_str

        # --- call-graph edges -----------------------------------------
        if opcode == "while":
            trip = 1.0
            tm = _TRIP_RE.search(line)
            if tm:
                trip = float(tm.group(1))
            bm, cm = _BODY_RE.search(line), _COND_RE.search(line)
            if bm:
                st.edges.append((bm.group(1), trip, False))
            if cm:
                st.edges.append((cm.group(1), trip, False))
        elif opcode == "fusion":
            cm = _CALLS_RE.search(line)
            if cm:
                st.edges.append((cm.group(1), 1.0, True))
        elif opcode == "conditional":
            bm = _BRANCHES_RE.search(line)
            if bm:
                for b in _OPERAND_RE.findall(bm.group(1)):
                    st.edges.append((b, 1.0, False))
        else:
            am = _APPLY_RE.search(line)
            if am:
                st.edges.append((am.group(1), 1.0, True))

        # --- flops ------------------------------------------------------
        if opcode == "dot":
            paren = line[line.index("dot(") + 4:]
            args = paren[:paren.index(")")]
            operands = _OPERAND_RE.findall(args)
            out_dims = _dims_of(type_str)
            n_out = 1
            for d in out_dims:
                n_out *= d
            contract = 1
            dm = _DOT_DIMS_RE.search(line)
            if dm and operands:
                lhs_type = symtab.get(operands[0], "")
                lhs_dims = _dims_of(lhs_type)
                for idx in (int(x) for x in dm.group(1).split(",")
                            if x.strip()):
                    if idx < len(lhs_dims):
                        contract *= lhs_dims[idx]
            st.dot_flops += 2.0 * n_out * contract

        # --- memory traffic ----------------------------------------------
        # Per-op HBM traffic model.  The scheduled CPU HLO is post-fusion,
        # so op lines are real buffer accesses — with three exceptions
        # where naive operand counting wildly overstates traffic:
        #   * dynamic-slice reads only the slice, not the source buffer;
        #   * dynamic-update-slice writes only the updated region (XLA
        #     updates in place; the big destination is aliased);
        #   * while/conditional/call lines move nothing themselves (their
        #     bodies are walked separately with trip multipliers).
        if opcode in ("while", "conditional", "call"):
            pass
        elif opcode == "dynamic-slice":
            st.mem_bytes += 2.0 * shape_bytes(type_str)
        elif opcode == "dynamic-update-slice":
            operands = _operand_names(line, opcode)
            upd = (shape_bytes(symtab[operands[1]])
                   if len(operands) > 1 and operands[1] in symtab
                   else shape_bytes(type_str))
            st.mem_bytes += 2.0 * upd
        elif opcode == "fusion" and _fusion_root_is_dus(line, root_map):
            # in-place update fusion: traffic = read+write of the update
            # region (the smallest non-scalar operand), not the aliased
            # destination stack
            sizes = sorted(shape_bytes(symtab[o])
                           for o in _operand_names(line, opcode)
                           if o in symtab and shape_bytes(symtab[o]) > 64)
            st.mem_bytes += 2.0 * (sizes[0] if sizes
                                   else shape_bytes(type_str))
        elif opcode not in _FREE_OPS:
            nbytes = shape_bytes(type_str)
            for op_name in _operand_names(line, opcode):
                if op_name in symtab:
                    nbytes += shape_bytes(symtab[op_name])
            st.mem_bytes += nbytes

    # --- collectives (line-based parser reused) --------------------------
    for op in parse_collectives("\n".join(lines)):
        st.coll_bytes[op.kind] = st.coll_bytes.get(op.kind, 0.0) + op.bytes
        st.coll_counts[op.kind] = st.coll_counts.get(op.kind, 0) + 1
    return st


@dataclasses.dataclass
class HloCost:
    dot_flops: float
    mem_bytes: float
    coll_bytes: Dict[str, float]
    coll_counts: Dict[str, float]

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def analyze_hlo(text: str) -> HloCost:
    """Trip-count-corrected per-device cost of a compiled HLO module."""
    comps = _split_computations(text)
    root_map = {name: _root_opcode(lines)
                for name, (lines, _) in comps.items()}
    stats = {name: _analyze_computation(lines, root_map)
             for name, (lines, _) in comps.items()}
    entry = next((n for n, (_, e) in comps.items() if e), None)
    if entry is None:                      # fall back: largest computation
        entry = max(stats, key=lambda n: stats[n].dot_flops, default=None)

    memo: Dict[Tuple[str, bool], Tuple[float, float, Dict[str, float],
                                       Dict[str, float]]] = {}

    def total(name: str, flops_only: bool, depth: int = 0):
        if depth > 64 or name not in stats:
            return 0.0, 0.0, {}, {}
        key = (name, flops_only)
        if key in memo:
            return memo[key]
        st = stats[name]
        flops = st.dot_flops
        mem = 0.0 if flops_only else st.mem_bytes
        coll = {} if flops_only else dict(st.coll_bytes)
        cnt = {} if flops_only else {k: float(v)
                                     for k, v in st.coll_counts.items()}
        for child, mult, child_flops_only in st.edges:
            f, b, cb, cc = total(child, flops_only or child_flops_only,
                                 depth + 1)
            flops += mult * f
            mem += mult * b
            for k, v in cb.items():
                coll[k] = coll.get(k, 0.0) + mult * v
            for k, v in cc.items():
                cnt[k] = cnt.get(k, 0.0) + mult * v
        memo[key] = (flops, mem, coll, cnt)
        return memo[key]

    f, b, cb, cc = total(entry, False) if entry else (0.0, 0.0, {}, {})
    return HloCost(dot_flops=f, mem_bytes=b, coll_bytes=cb, coll_counts=cc)
