"""Backtracking root-cause detection (paper §IV-B, Algorithm 1).

All edges are traversed in reverse (dependence direction).  From each
problematic vertex we walk backward:

  * at a p2p Comm vertex with a waiting event — follow the inter-process
    communication-dependence edge to the partner process (edges without a
    waiting event are pruned, the paper's search-space optimization);
  * at an unscanned Loop/Branch vertex — follow the control-dependence edge
    into the structure (continue from its *end* vertex);
  * otherwise — follow the data-dependence edge to the predecessor (the
    max-time predecessor when several exist);
  * stop at the root or at a collective-communication vertex, except a
    collective *start* vertex, where the walk jumps to the process whose
    late arrival everyone waited on.

The result is a set of causal paths over (process, vertex) pairs whose
endpoints are the root-cause candidates, reported with source locations.

Two engines produce identical paths:

* the scalar walk (``backtrack_scalar`` / ``backtrack_one``) — a direct
  transcription of Algorithm 1, retained as the property-tested reference;
* the frontier-batched walk (``backtrack_batched``, the default) — ALL
  flagged (proc, vertex) start nodes advance in lockstep, one step per
  iteration: data-dependence predecessors for the whole frontier are one
  padded gather + argmax over the time matrix, collective late-arriver
  lookups are one cached per-vertex argmin over the participant group
  (``CommIndex``), and waiting-p2p partners resolve through the explicit
  reverse-edge index.  Algorithm 1's sequential ``scanned``-set semantics
  (earlier paths prune later ones) are restored afterwards by an
  acceptance pass: paths are admitted in start order, and any path whose
  nodes — or whose branch-deciding probe nodes — touch an already-scanned
  node is recomputed with the scalar walk against the true scanned set.
  Disjoint paths (the overwhelmingly common case) keep their batched
  result, so root-cause detection at 8k processes with hundreds of
  flagged vertices is no longer bound by per-node Python scans.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.detect import Abnormal, NonScalable
from repro.core.graph import BRANCH, CALL, COMM, LOOP, PPG, PSG, ROOT

Node = Tuple[int, int]                     # (proc, vid)

WAIT_COUNTER = "wait_s"
WAIT_EPS = 1e-9


@dataclasses.dataclass
class Path:
    nodes: List[Node]
    start_reason: str                      # "non_scalable" | "abnormal"

    @property
    def root_cause(self) -> Node:
        return self.nodes[-1]

    def __iter__(self):
        return iter(self.nodes)


class _SeenUnion:
    """Non-copying membership view over (scanned, own-path) node sets.

    ``backtrack_one`` used to rebuild ``scanned | set(path)`` on every
    step — an O(|scanned| + |path|) copy per node that goes quadratic
    when hundreds of conflicting paths fall back to the scalar walk over
    a large scanned set.  The walk only ever asks ``node in visited``, so
    a chained-membership wrapper over the two live sets (the path set
    updated incrementally on append) is semantically identical and O(1)
    per probe."""

    __slots__ = ("scanned", "path")

    def __init__(self, scanned: Set[Node], path: Set[Node]):
        self.scanned = scanned
        self.path = path

    def __contains__(self, node) -> bool:
        return node in self.scanned or node in self.path


def _wait_of(ppg: PPG, node: Node) -> float:
    return ppg.perf.counter_at(WAIT_COUNTER, *node)


def _is_collective(psg: PSG, vid: int) -> bool:
    v = psg.vertices[vid]
    return v.kind == COMM and not v.p2p_pairs


def _is_p2p(psg: PSG, vid: int) -> bool:
    v = psg.vertices[vid]
    return v.kind == COMM and bool(v.p2p_pairs)


def _data_pred(ppg: PPG, node: Node, visited) -> Optional[Node]:
    proc, vid = node
    preds = ppg.psg.preds(vid, "data")
    cands = [(proc, p) for p in preds if (proc, p) not in visited]
    if not cands:
        return None
    return max(cands, key=lambda n: ppg.get_time(*n))


def _control_end(ppg: PPG, node: Node, visited) -> Optional[Node]:
    """Continue from the end (last child) of a Loop/Branch structure."""
    proc, vid = node
    kids = ppg.psg.children(vid)
    for k in reversed(kids):
        if (proc, k) not in visited:
            return (proc, k)
    return None


def _comm_partner(ppg: PPG, node: Node, visited) -> Optional[Node]:
    partners = [p for p in ppg.comm_partners(*node) if p not in visited]
    if not partners:
        return None
    # the cause is the partner we waited for: latest/most loaded one
    return max(partners, key=lambda n: ppg.get_time(*n))


def _latest_participant(ppg: PPG, node: Node) -> Optional[Node]:
    """For a collective start vertex: the process everyone waited on —
    the participant with the smallest wait (it arrived last)."""
    proc, vid = node
    group = [p for p in ppg.comm_partners(proc, vid)] + [node]
    if len(group) <= 1:
        return None
    return min(group, key=lambda n: _wait_of(ppg, n))


def backtrack_one(ppg: PPG, start: Node, *, reason: str,
                  scanned: Set[Node], max_len: int = 256) -> Path:
    psg = ppg.psg
    path: List[Node] = []
    path_set: Set[Node] = set()
    # visited == scanned | set(path) at every step, without the per-step
    # union copy (quadratic over many conflicting scalar-fallback paths)
    visited = _SeenUnion(scanned, path_set)
    v: Optional[Node] = start
    first = True
    while v is not None and len(path) < max_len:
        proc, vid = v
        vert = psg.vertices[vid]
        if vert.kind == "Root":
            break
        if _is_collective(psg, vid) and not first:
            path.append(v)                  # terminal collective
            break
        path.append(v)
        path_set.add(v)
        nxt: Optional[Node] = None
        if _is_collective(psg, vid):        # collective start vertex
            late = _latest_participant(ppg, v)
            if late is not None and late not in visited:
                nxt = _data_pred(ppg, late, visited) or late
            else:
                nxt = _data_pred(ppg, v, visited)
        elif _is_p2p(psg, vid):
            if _wait_of(ppg, v) > WAIT_EPS:     # pruning: only waiting edges
                nxt = _comm_partner(ppg, v, visited)
            if nxt is None:
                nxt = _data_pred(ppg, v, visited)
        elif vert.kind in (LOOP, BRANCH, CALL) and v not in scanned:
            nxt = _control_end(ppg, v, visited) or _data_pred(ppg, v, visited)
        else:
            nxt = _data_pred(ppg, v, visited)
        first = False
        v = nxt
    scanned.update(path)
    return Path(nodes=path, start_reason=reason)


def _start_nodes(ppg: PPG, non_scalable: Sequence[NonScalable],
                 abnormal: Sequence[Abnormal]) -> List[Tuple[Node, str]]:
    """Algorithm 1 Main()'s start order: non-scalable vertices (walked from
    their slowest process) first, then abnormal (proc, vertex) pairs."""
    tm = ppg.times_matrix()
    starts: List[Tuple[Node, str]] = []
    for n in non_scalable:
        proc = int(tm[:, n.vid].argmax()) if tm.size else 0
        starts.append(((proc, n.vid), "non_scalable"))
    for a in abnormal:
        starts.append(((a.proc, a.vid), "abnormal"))
    return starts


def backtrack_scalar(ppg: PPG, non_scalable: Sequence[NonScalable],
                     abnormal: Sequence[Abnormal]) -> List[Path]:
    """Algorithm 1 Main(), one sequential scalar walk per start node: the
    retained reference implementation (``backtrack_batched`` must — and is
    property-tested to — return exactly these paths)."""
    scanned: Set[Node] = set()
    paths: List[Path] = []
    for node, reason in _start_nodes(ppg, non_scalable, abnormal):
        if reason == "abnormal" and node in scanned:
            continue
        p = backtrack_one(ppg, node, reason=reason, scanned=scanned)
        if p.nodes:
            paths.append(p)
    return paths


# ---------------------------------------------------------------------------
# frontier-batched walk
# ---------------------------------------------------------------------------

# per-vertex walk categories (process-independent, computed once per call)
_K_ROOT, _K_COLL, _K_P2P, _K_CTRL, _K_DATA = range(5)


class _Frontier:
    """Array context for the batched walk: the time/wait matrices, padded
    data-predecessor table, per-vertex category codes, and a lazy cache of
    per-collective late-arriver lookups (one vectorized argmin over the
    participant group per vertex, shared by every path that reaches it)."""

    __slots__ = ("ppg", "psg", "T", "W", "kcode", "PRED", "_late")

    def __init__(self, ppg: PPG):
        self.ppg = ppg
        self.psg = psg = ppg.psg
        V = len(psg.vertices)
        self.T = ppg.times_matrix()
        self.W = _wait_matrix(ppg)
        kcode = np.full(V, _K_DATA, np.int8)
        for v in psg.vertices:
            if v.kind == ROOT:
                kcode[v.vid] = _K_ROOT
            elif v.kind == COMM:
                kcode[v.vid] = _K_P2P if v.p2p_pairs else _K_COLL
            elif v.kind in (LOOP, BRANCH, CALL):
                kcode[v.vid] = _K_CTRL
        self.kcode = kcode
        plists = [psg.preds(v.vid, "data") for v in psg.vertices]
        kp = max((len(p) for p in plists), default=1) or 1
        self.PRED = np.full((V, kp), -1, np.intp)
        for vid, ps in enumerate(plists):
            self.PRED[vid, :len(ps)] = ps
        self._late: Dict[int, Tuple] = {}

    def late_info(self, vid: int) -> Tuple:
        """Cached late-arriver lookup for one collective vertex.

        Returns ("map", gid_of, per_group): ``gid_of`` maps proc -> group
        index (-1: not a participant) and ``per_group[gid]`` is the
        group's (first_min_wait_proc, second_min_wait_proc | None) — one
        vectorized argmin over each participant group, shared by every
        path that reaches the vertex.  ("none", ...) when the vertex has
        no groups; ("complex", ...) when groups overlap or name unknown
        procs (those paths fall back to the scalar walk)."""
        info = self._late.get(vid)
        if info is None:
            groups = self.ppg.comm.groups_of(vid)
            if not groups:
                info = ("none", None, None)
            else:
                gid_of = np.full(self.ppg.n_procs, -1, np.intp)
                per: List[Tuple[int, Optional[int]]] = []
                info = None
                for gi, g in enumerate(groups):
                    garr = np.asarray(g, np.intp)
                    if garr.size and (garr.min() < 0
                                      or garr.max() >= gid_of.size) \
                            or (gid_of[garr] != -1).any():
                        info = ("complex", None, None)
                        break
                    gid_of[garr] = gi
                    w = self.W[garr, vid]
                    m = w.min()
                    firsts = np.flatnonzero(w == m)
                    q1 = int(garr[firsts[0]])
                    q2 = int(garr[firsts[1]]) if firsts.size > 1 else None
                    per.append((q1, q2))
                if info is None:
                    info = ("map", gid_of, per)
            self._late[vid] = info
        return info


def backtrack_batched(ppg: PPG, non_scalable: Sequence[NonScalable],
                      abnormal: Sequence[Abnormal], *,
                      max_len: int = 256) -> List[Path]:
    """Frontier-batched Algorithm 1: identical paths to
    :func:`backtrack_scalar`, computed by advancing every start node in
    lockstep over array gathers (see the module docstring).

    Batched paths exclude only their OWN nodes while walking; the
    sequential cross-path pruning is restored by the acceptance pass
    below, which recomputes — with the exact scalar walk — any path that
    touched a node (or probed a late-arriver) already scanned by an
    earlier path.  A selector over candidates not in ``scanned | path``
    picks the same node as one over candidates not in ``path`` whenever
    the pick is unscanned, so untouched batched paths are exact.
    """
    starts = _start_nodes(ppg, non_scalable, abnormal)
    N = len(starts)
    if N == 0:
        return []
    ctx = _Frontier(ppg)
    comm = ppg.comm
    paths: List[List[Node]] = [[] for _ in range(N)]
    probes: List[List[Node]] = [[] for _ in range(N)]
    visited: List[Set[Node]] = [set() for _ in range(N)]
    conflict = np.zeros(N, bool)
    cur_p = np.fromiter((s[0][0] for s in starts), np.intp, N)
    cur_v = np.fromiter((s[0][1] for s in starts), np.intp, N)
    alive = np.ones(N, bool)
    first = np.ones(N, bool)

    while alive.any():
        idx = np.nonzero(alive)[0]
        lens = np.fromiter((len(paths[i]) for i in idx), np.intp, idx.size)
        over = lens >= max_len
        if over.any():
            alive[idx[over]] = False
            idx = idx[~over]
            if idx.size == 0:
                break
        vs, ps = cur_v[idx], cur_p[idx]
        kc = ctx.kcode[vs]
        mroot = kc == _K_ROOT
        alive[idx[mroot]] = False
        mterm = (kc == _K_COLL) & ~first[idx]
        for i, p, v in zip(idx[mterm].tolist(), ps[mterm].tolist(),
                           vs[mterm].tolist()):
            paths[i].append((p, v))             # terminal collective
            alive[i] = False
        live = ~mroot & ~mterm
        lidx, lps, lvs, lkc = idx[live], ps[live], vs[live], kc[live]
        for i, p, v in zip(lidx.tolist(), lps.tolist(), lvs.tolist()):
            paths[i].append((p, v))
            visited[i].add((p, v))

        # -- choose the next node per path ------------------------------
        # data-pred requests accumulate and resolve in ONE padded
        # gather+argmax over the time matrix for the whole frontier
        nxt: List[Optional[Node]] = [None] * lidx.size
        req: List[Tuple[int, int, int, Optional[Node]]] = []
        for k in range(lidx.size):
            i = int(lidx[k])
            p, v, code = int(lps[k]), int(lvs[k]), int(lkc[k])
            if code == _K_COLL:                 # collective start vertex
                tag, gid_of, per = ctx.late_info(v)
                if tag == "complex" or comm.p2p_preds_of((p, v)):
                    conflict[i] = True          # scalar walk handles it
                    alive[i] = False
                    continue
                late: Optional[Node] = None
                if tag == "map" and gid_of[p] >= 0:
                    q1, q2 = per[gid_of[p]]
                    lp = q1 if q1 != p else (q2 if q2 is not None else p)
                    late = (lp, v)
                    if late != (p, v):
                        probes[i].append(late)  # scanned-sensitive branch
                if late is not None and late not in visited[i]:
                    req.append((k, late[0], v, late))   # pred-of-late|late
                else:
                    req.append((k, p, v, None))         # pred-of-v | stop
            elif code == _K_P2P:
                chosen = None
                if ctx.W[p, v] > WAIT_EPS:      # pruning: waiting edges only
                    if comm.has_groups(v):
                        conflict[i] = True
                        alive[i] = False
                        continue
                    best_t = -np.inf
                    for q in comm.p2p_preds_of((p, v)):
                        if q in visited[i]:
                            continue
                        tq = ctx.T[q[0], q[1]]
                        if tq > best_t:
                            chosen, best_t = q, tq
                if chosen is not None:
                    nxt[k] = chosen
                else:
                    req.append((k, p, v, None))
            elif code == _K_CTRL:               # continue from structure end
                chosen = None
                for c in reversed(ctx.psg.children(v)):
                    if (p, c) not in visited[i]:
                        chosen = (p, c)
                        break
                if chosen is not None:
                    nxt[k] = chosen
                else:
                    req.append((k, p, v, None))
            else:
                req.append((k, p, v, None))

        if req:
            rp = np.fromiter((r[1] for r in req), np.intp, len(req))
            rv = np.fromiter((r[2] for r in req), np.intp, len(req))
            cand = ctx.PRED[rv]                             # (M, Kp)
            valid = cand >= 0
            t = np.where(valid,
                         ctx.T[rp[:, None], np.where(valid, cand, 0)],
                         -np.inf)
            ji = np.argmax(t, axis=1)                       # first max
            has = valid[np.arange(len(req)), ji]
            for m, (k, _, _, fallback) in enumerate(req):
                i = int(lidx[k])
                if not alive[i]:
                    continue
                chosen = None
                if has[m]:
                    node = (int(rp[m]), int(cand[m, ji[m]]))
                    if node not in visited[i]:
                        chosen = node
                    else:      # rare: rescan candidates minus own path
                        best_t = -np.inf
                        for c in cand[m][valid[m]].tolist():
                            node = (int(rp[m]), int(c))
                            if node in visited[i]:
                                continue
                            tc = ctx.T[node[0], c]
                            if tc > best_t:
                                chosen, best_t = node, tc
                if chosen is None and fallback is not None \
                        and fallback not in visited[i]:
                    chosen = fallback                       # the `or late`
                nxt[k] = chosen

        for k in range(lidx.size):
            i = int(lidx[k])
            if not alive[i]:
                continue
            node = nxt[k]
            if node is None:
                alive[i] = False
            else:
                cur_p[i], cur_v[i] = node
        first[idx] = False

    # -- acceptance: restore the sequential scanned-set semantics -------
    scanned: Set[Node] = set()
    out: List[Path] = []
    for j, (node, reason) in enumerate(starts):
        if reason == "abnormal" and node in scanned:
            continue
        if conflict[j] or any(n in scanned for n in paths[j]) \
                or any(q in scanned for q in probes[j]):
            p = backtrack_one(ppg, node, reason=reason, scanned=scanned,
                              max_len=max_len)
        else:
            p = Path(nodes=paths[j], start_reason=reason)
            scanned.update(paths[j])
        if p.nodes:
            out.append(p)
    return out


BACKTRACK_MODES = ("auto", "batched", "scalar")


def backtrack(ppg: PPG, non_scalable: Sequence[NonScalable],
              abnormal: Sequence[Abnormal], *,
              mode: str = "auto") -> List[Path]:
    """Algorithm 1 Main(): non-scalable starts first, then unscanned
    abnormal vertices.

    ``mode``: "scalar" (the per-start reference walk), "batched" (the
    frontier-batched engine, opt-in), or "auto" (default — scalar).
    Batched was the "auto" pick while the scalar walk's per-step
    scanned-set copies went quadratic; with the non-copying union view
    the scalar walk wins or ties across BENCH_graph_scale.json
    (0.62-1.12x), so the simpler engine is the default and batched is
    kept for workloads with very many long disjoint walks.  All modes
    return identical paths."""
    if mode not in BACKTRACK_MODES:
        raise ValueError(f"mode must be one of {BACKTRACK_MODES}: {mode!r}")
    if mode == "batched":
        return backtrack_batched(ppg, non_scalable, abnormal)
    return backtrack_scalar(ppg, non_scalable, abnormal)


def _anomaly_score(ppg: PPG, node: Node,
                   busy: Optional[np.ndarray] = None) -> float:
    """BUSY time above the cross-process typical for this vertex.

    A propagated delay leaves every downstream vertex time-NORMAL (they
    run at base speed, just later) and surfaces as WAITING at comm
    vertices — which are symptoms, not causes.  Scoring busy time
    (time - wait) makes the most anomalous node on a causal path the
    worker that actually ran long, i.e. the root-cause candidate.

    ``busy`` is the precomputed (n_procs, V) time-minus-wait matrix; pass
    it when scoring many nodes so each call is one column reduction."""
    if node not in ppg.perf:
        return 0.0
    if busy is None:
        busy = _busy_matrix(ppg)
    proc, vid = node
    col = busy[:, vid]
    mine = float(col[proc])
    others = np.sort(col[col > 0.0])           # unset entries are 0: excluded
    if others.size == 0:
        return mine
    return mine - float(others[others.size // 2])


def _wait_matrix(ppg: PPG) -> np.ndarray:
    """Dense (n_procs, V) ``wait_s`` (0.0 where unset) from the compressed
    counter columns — works unchanged on sharded stores, whose
    ``counter_columns`` is the stacked per-host view."""
    n = len(ppg.psg.vertices)
    out = np.zeros((ppg.n_procs, n))
    vids, values, mask = ppg.perf.counter_columns(WAIT_COUNTER)
    keep = vids < n
    if keep.any():
        out[:, vids[keep]] = np.where(mask[:, keep], values[:, keep], 0.0)
    return out


def _busy_matrix(ppg: PPG) -> np.ndarray:
    """time minus wait, (n_procs, V) — expanded from the column-sparse
    ``wait_s`` counter (see :func:`_wait_matrix`)."""
    return ppg.times_matrix() - _wait_matrix(ppg)


def root_causes(paths: Sequence[Path], psg: PSG, top_k: int = 5,
                ppg: Optional[PPG] = None) -> List[Tuple[Node, str, str]]:
    """Deduplicated root-cause vertices (node, name, source).

    With a PPG, each path contributes its most ANOMALOUS node (see
    _anomaly_score); without perf data, its terminal node (the paper's
    raw Algorithm-1 endpoint).  Ranked by path count, then score."""
    counts: Dict[Node, int] = {}
    scores: Dict[Node, float] = {}
    busy = _busy_matrix(ppg) if ppg is not None else None
    memo: Dict[Node, float] = {}

    def score(n: Node) -> float:
        if n not in memo:
            memo[n] = _anomaly_score(ppg, n, busy)
        return memo[n]

    for p in paths:
        if ppg is not None and p.nodes:
            node = max(p.nodes, key=score)
            scores[node] = max(scores.get(node, 0.0), score(node))
        else:
            node = p.root_cause
        counts[node] = counts.get(node, 0) + 1
    ranked = sorted(counts,
                    key=lambda n: (-counts[n], -scores.get(n, 0.0)))[:top_k]
    out = []
    for node in ranked:
        v = psg.vertices[node[1]]
        out.append((node, v.name, v.source))
    return out
