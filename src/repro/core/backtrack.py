"""Backtracking root-cause detection (paper §IV-B, Algorithm 1).

All edges are traversed in reverse (dependence direction).  From each
problematic vertex we walk backward:

  * at a p2p Comm vertex with a waiting event — follow the inter-process
    communication-dependence edge to the partner process (edges without a
    waiting event are pruned, the paper's search-space optimization);
  * at an unscanned Loop/Branch vertex — follow the control-dependence edge
    into the structure (continue from its *end* vertex);
  * otherwise — follow the data-dependence edge to the predecessor (the
    max-time predecessor when several exist);
  * stop at the root or at a collective-communication vertex, except a
    collective *start* vertex, where the walk jumps to the process whose
    late arrival everyone waited on.

The result is a set of causal paths over (process, vertex) pairs whose
endpoints are the root-cause candidates, reported with source locations.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.detect import Abnormal, NonScalable
from repro.core.graph import BRANCH, CALL, COMM, LOOP, PPG, PSG

Node = Tuple[int, int]                     # (proc, vid)

WAIT_COUNTER = "wait_s"
WAIT_EPS = 1e-9


@dataclasses.dataclass
class Path:
    nodes: List[Node]
    start_reason: str                      # "non_scalable" | "abnormal"

    @property
    def root_cause(self) -> Node:
        return self.nodes[-1]

    def __iter__(self):
        return iter(self.nodes)


def _wait_of(ppg: PPG, node: Node) -> float:
    return ppg.perf.counter_at(WAIT_COUNTER, *node)


def _is_collective(psg: PSG, vid: int) -> bool:
    v = psg.vertices[vid]
    return v.kind == COMM and not v.p2p_pairs


def _is_p2p(psg: PSG, vid: int) -> bool:
    v = psg.vertices[vid]
    return v.kind == COMM and bool(v.p2p_pairs)


def _data_pred(ppg: PPG, node: Node, visited: Set[Node]) -> Optional[Node]:
    proc, vid = node
    preds = ppg.psg.preds(vid, "data")
    cands = [(proc, p) for p in preds if (proc, p) not in visited]
    if not cands:
        return None
    return max(cands, key=lambda n: ppg.get_time(*n))


def _control_end(ppg: PPG, node: Node, visited: Set[Node]) -> Optional[Node]:
    """Continue from the end (last child) of a Loop/Branch structure."""
    proc, vid = node
    kids = ppg.psg.children(vid)
    for k in reversed(kids):
        if (proc, k) not in visited:
            return (proc, k)
    return None


def _comm_partner(ppg: PPG, node: Node, visited: Set[Node]) -> Optional[Node]:
    partners = [p for p in ppg.comm_partners(*node) if p not in visited]
    if not partners:
        return None
    # the cause is the partner we waited for: latest/most loaded one
    return max(partners, key=lambda n: ppg.get_time(*n))


def _latest_participant(ppg: PPG, node: Node) -> Optional[Node]:
    """For a collective start vertex: the process everyone waited on —
    the participant with the smallest wait (it arrived last)."""
    proc, vid = node
    group = [p for p in ppg.comm_partners(proc, vid)] + [node]
    if len(group) <= 1:
        return None
    return min(group, key=lambda n: _wait_of(ppg, n))


def backtrack_one(ppg: PPG, start: Node, *, reason: str,
                  scanned: Set[Node], max_len: int = 256) -> Path:
    psg = ppg.psg
    path: List[Node] = []
    v: Optional[Node] = start
    first = True
    while v is not None and len(path) < max_len:
        proc, vid = v
        vert = psg.vertices[vid]
        if vert.kind == "Root":
            break
        if _is_collective(psg, vid) and not first:
            path.append(v)                  # terminal collective
            break
        path.append(v)
        nxt: Optional[Node] = None
        visited = scanned | set(path)
        if _is_collective(psg, vid):        # collective start vertex
            late = _latest_participant(ppg, v)
            if late is not None and late not in visited:
                nxt = _data_pred(ppg, late, visited) or late
            else:
                nxt = _data_pred(ppg, v, visited)
        elif _is_p2p(psg, vid):
            if _wait_of(ppg, v) > WAIT_EPS:     # pruning: only waiting edges
                nxt = _comm_partner(ppg, v, visited)
            if nxt is None:
                nxt = _data_pred(ppg, v, visited)
        elif vert.kind in (LOOP, BRANCH, CALL) and v not in scanned:
            nxt = _control_end(ppg, v, visited) or _data_pred(ppg, v, visited)
        else:
            nxt = _data_pred(ppg, v, visited)
        first = False
        v = nxt
    scanned.update(path)
    return Path(nodes=path, start_reason=reason)


def backtrack(ppg: PPG, non_scalable: Sequence[NonScalable],
              abnormal: Sequence[Abnormal]) -> List[Path]:
    """Algorithm 1 Main(): non-scalable starts first, then unscanned
    abnormal vertices."""
    scanned: Set[Node] = set()
    paths: List[Path] = []
    tm = ppg.times_matrix()
    for n in non_scalable:
        proc = int(tm[:, n.vid].argmax()) if tm.size else 0
        p = backtrack_one(ppg, (proc, n.vid), reason="non_scalable",
                          scanned=scanned)
        if p.nodes:
            paths.append(p)
    for a in abnormal:
        if (a.proc, a.vid) in scanned:
            continue
        p = backtrack_one(ppg, (a.proc, a.vid), reason="abnormal",
                          scanned=scanned)
        if p.nodes:
            paths.append(p)
    return paths


def _anomaly_score(ppg: PPG, node: Node,
                   busy: Optional[np.ndarray] = None) -> float:
    """BUSY time above the cross-process typical for this vertex.

    A propagated delay leaves every downstream vertex time-NORMAL (they
    run at base speed, just later) and surfaces as WAITING at comm
    vertices — which are symptoms, not causes.  Scoring busy time
    (time - wait) makes the most anomalous node on a causal path the
    worker that actually ran long, i.e. the root-cause candidate.

    ``busy`` is the precomputed (n_procs, V) time-minus-wait matrix; pass
    it when scoring many nodes so each call is one column reduction."""
    if node not in ppg.perf:
        return 0.0
    if busy is None:
        busy = _busy_matrix(ppg)
    proc, vid = node
    col = busy[:, vid]
    mine = float(col[proc])
    others = np.sort(col[col > 0.0])           # unset entries are 0: excluded
    if others.size == 0:
        return mine
    return mine - float(others[others.size // 2])


def _busy_matrix(ppg: PPG) -> np.ndarray:
    """time minus wait, (n_procs, V).  ``wait_s`` is column-sparse (it only
    exists at Comm vertices), so subtract its compressed columns instead of
    materializing a dense (n_procs, V) counter matrix."""
    busy = ppg.times_matrix().copy()
    vids, values, mask = ppg.perf.counter_columns(WAIT_COUNTER)
    keep = vids < busy.shape[1]
    if keep.any():
        busy[:, vids[keep]] -= np.where(mask[:, keep], values[:, keep], 0.0)
    return busy


def root_causes(paths: Sequence[Path], psg: PSG, top_k: int = 5,
                ppg: Optional[PPG] = None) -> List[Tuple[Node, str, str]]:
    """Deduplicated root-cause vertices (node, name, source).

    With a PPG, each path contributes its most ANOMALOUS node (see
    _anomaly_score); without perf data, its terminal node (the paper's
    raw Algorithm-1 endpoint).  Ranked by path count, then score."""
    counts: Dict[Node, int] = {}
    scores: Dict[Node, float] = {}
    busy = _busy_matrix(ppg) if ppg is not None else None
    memo: Dict[Node, float] = {}

    def score(n: Node) -> float:
        if n not in memo:
            memo[n] = _anomaly_score(ppg, n, busy)
        return memo[n]

    for p in paths:
        if ppg is not None and p.nodes:
            node = max(p.nodes, key=score)
            scores[node] = max(scores.get(node, 0.0), score(node))
        else:
            node = p.root_cause
        counts[node] = counts.get(node, 0) + 1
    ranked = sorted(counts,
                    key=lambda n: (-counts[n], -scores.get(n, 0.0)))[:top_k]
    out = []
    for node in ranked:
        v = psg.vertices[node[1]]
        out.append((node, v.name, v.source))
    return out
