"""Root-cause reporting (the ScalAna-viewer analogue, text mode).

Renders detections + backtracking paths with source locations and the
PMU-analogue counters, in the spirit of the paper's GUI: root-cause
vertices, their calling paths, and the code snippets they map to.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.backtrack import Path, root_causes
from repro.core.detect import Abnormal, NonScalable
from repro.core.graph import PPG, PSG


def _fmt_node(psg: PSG, node) -> str:
    proc, vid = node
    v = psg.vertices[vid]
    loc = f" @ {v.source}" if v.source else ""
    return f"[p{proc}] {v.kind}:{v.name}{loc}"


def render_report(ppg: PPG, non_scalable: Sequence[NonScalable],
                  abnormal: Sequence[Abnormal], paths: Sequence[Path],
                  *, title: str = "ScalAna scaling-loss report",
                  max_abnormal: int = 10,
                  coverage: Optional[str] = None) -> str:
    """Text report of the full diagnosis.

    ``max_abnormal`` caps the abnormal-vertex listing; when more were
    flagged, the listing ends with an explicit "… and N more" line
    instead of truncating silently.

    ``coverage`` is an optional fleet-coverage annotation (the always-on
    monitor's degraded-fleet contract: every report states how much of
    the fleet it covers), rendered right under the header counts."""
    psg = ppg.psg
    lines: List[str] = [title, "=" * len(title), ""]

    lines.append(f"processes: {ppg.n_procs}   vertices: "
                 f"{len(psg.vertices)}   comm edges: {len(ppg.comm_edges)}")
    if coverage is not None:
        lines.append(coverage)
    lines.append("")

    lines.append("## Non-scalable vertices (log-log slope vs ideal -1.0)")
    if not non_scalable:
        lines.append("  (none)")
    for d in non_scalable:
        lines.append(
            f"  - v{d.vid} {d.kind}:{d.name} slope={d.slope:+.2f} "
            f"share={100 * d.share:.1f}% {d.source}")
    lines.append("")

    lines.append("## Abnormal vertices (AbnormThd exceeded)")
    if not abnormal:
        lines.append("  (none)")
    for a in abnormal[:max_abnormal]:
        lines.append(
            f"  - v{a.vid} p{a.proc} {a.kind}:{a.name} "
            f"t={1e3 * a.time:.3f}ms typical={1e3 * a.typical:.3f}ms "
            f"x{a.ratio:.2f} {a.source}")
    if len(abnormal) > max_abnormal:
        lines.append(f"  … and {len(abnormal) - max_abnormal} more")
    lines.append("")

    lines.append("## Backtracking root-cause paths")
    if not paths:
        lines.append("  (none)")
    for i, p in enumerate(paths):
        lines.append(f"  path {i} [{p.start_reason}]:")
        for node in p.nodes:
            proc, vid = node
            vec = ppg.perf.get(node)
            t = f" t={1e3 * vec.time:.3f}ms" if vec else ""
            w = (f" wait={1e3 * vec.counters['wait_s']:.3f}ms"
                 if vec and vec.counters.get("wait_s") else "")
            lines.append(f"    <- {_fmt_node(psg, node)}{t}{w}")
    lines.append("")

    lines.append("## Root causes")
    for node, name, source in root_causes(paths, psg, ppg=ppg):
        proc, vid = node
        vec = ppg.perf.get(node)
        counters = ""
        if vec and vec.counters:
            keys = [k for k in ("flops", "bytes", "comm_bytes") if
                    vec.counters.get(k)]
            counters = "  " + " ".join(
                f"{k}={vec.counters[k]:.3g}" for k in keys)
        lines.append(f"  * p{proc} v{vid} {name} @ {source or '<unknown>'}"
                     f"{counters}")
    return "\n".join(lines)
