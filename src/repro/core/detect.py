"""Location-aware problematic vertex detection (paper §IV-A).

* Non-scalable vertices: per-vertex performance across job scales, merged
  across processes (mean/median/max/cluster strategies), fitted with a
  log-log model t ~ a * p^b; vertices whose growth rate deviates from the
  ideal slope and whose share of total time is significant are flagged.

* Abnormal vertices: per-vertex times across processes at one scale;
  processes above AbnormThd x median are flagged.

Complexity: both detectors are vectorized over the PPG's dense (n_procs,
n_vertices) time matrices — cross-process merges, the log-log slope fit,
and abnormality thresholding are batched numpy reductions, O(P*V) work
with no per-(proc, vertex) Python loops.  Only flagged entries (<= top_k
in practice) materialize Python objects.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import COMM, COMP, LOOP, PPG


@dataclasses.dataclass
class NonScalable:
    vid: int
    slope: float                 # d log t / d log p  (ideal strong-scaling: -1)
    share: float                 # fraction of total step time at max scale
    score: float                 # ranking key
    times: Dict[int, float]      # scale -> merged time
    kind: str = ""
    name: str = ""
    source: str = ""


@dataclasses.dataclass
class Abnormal:
    vid: int
    proc: int
    time: float
    typical: float               # median across processes
    ratio: float
    kind: str = ""
    name: str = ""
    source: str = ""


def _merge(times: Sequence[float], strategy: str) -> float:
    """Scalar reference merge (see ``_merge_matrix`` for the batched path)."""
    arr = np.asarray([t for t in times if t > 0.0])
    if arr.size == 0:
        return 0.0
    if strategy == "mean":
        return float(arr.mean())
    if strategy == "median":
        return float(np.median(arr))
    if strategy == "max":
        return float(arr.max())
    if strategy == "p0":
        # proc-0's reading when alive; a dead proc-0 (t == 0) falls back to
        # the mean of live readings instead of silently dropping the vertex
        return float(times[0]) if times[0] > 0.0 else float(arr.mean())
    if strategy == "cluster":
        # 2-means along sorted values; report the larger cluster's mean
        s = np.sort(arr)
        best_cut, best_gap = None, -1.0
        for i in range(1, s.size):
            gap = s[i] - s[i - 1]
            if gap > best_gap:
                best_gap, best_cut = gap, i
        hi = s[best_cut:] if best_cut is not None else s
        return float(hi.mean())
    raise ValueError(strategy)


def _merge_matrix(t: np.ndarray, strategy: str) -> np.ndarray:
    """Columnwise ``_merge`` over a (n_procs, V) time matrix -> (V,)."""
    n_procs, V = t.shape
    pos = t > 0.0
    cnt = pos.sum(axis=0)
    any_pos = cnt > 0
    if strategy in ("mean", "p0"):
        s = t.sum(axis=0, where=pos)
        mean = np.divide(s, cnt, out=np.zeros(V), where=any_pos)
        if strategy == "mean":
            return mean
        p0 = t[0] if n_procs else np.zeros(V)
        return np.where(p0 > 0.0, p0, mean)
    if strategy == "median":
        masked = np.where(pos, t, np.nan)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            med = np.nanmedian(masked, axis=0)
        return np.where(any_pos, med, 0.0)
    if strategy == "max":
        return np.where(any_pos, t.max(axis=0, initial=0.0), 0.0)
    if strategy == "cluster":
        out = np.zeros(V)
        for v in np.nonzero(any_pos)[0]:
            s = np.sort(t[pos[:, v], v])
            if s.size == 1:
                out[v] = s[0]
            else:
                cut = int(np.argmax(np.diff(s))) + 1
                out[v] = s[cut:].mean()
        return out
    raise ValueError(strategy)


def fit_loglog(scales: Sequence[int], times: Sequence[float]
               ) -> Tuple[float, float]:
    """Least-squares fit log t = log a + b log p. Returns (a, b)."""
    xs, ys = [], []
    for p, t in zip(scales, times):
        if t > 0:
            xs.append(math.log(p))
            ys.append(math.log(t))
    if len(xs) < 2:
        return (times[-1] if times else 0.0), 0.0
    b, loga = np.polyfit(xs, ys, 1)
    return math.exp(loga), float(b)


def _fit_slopes(scales: Sequence[int], M: np.ndarray,
                valid: np.ndarray) -> np.ndarray:
    """Batched least-squares slope of log t vs log p per column.

    M is (S, V) merged times, valid the (S, V) mask of usable points;
    columns with < 2 valid points get slope 0.0 (matching ``fit_loglog``).
    """
    S, V = M.shape
    x = np.log(np.asarray(scales, float))[:, None]          # (S, 1)
    Y = np.where(valid, np.log(np.where(valid, M, 1.0)), 0.0)
    n = valid.sum(axis=0)
    Sx = (x * valid).sum(axis=0)
    Sy = Y.sum(axis=0)
    Sxx = (x * x * valid).sum(axis=0)
    Sxy = (x * Y).sum(axis=0)
    denom = n * Sxx - Sx ** 2
    num = n * Sxy - Sx * Sy
    slope = np.divide(num, denom, out=np.zeros(V), where=denom != 0)
    return np.where(n >= 2, slope, 0.0)


def detect_non_scalable(series: Mapping[int, PPG], *,
                        ideal_slope: float = -1.0,
                        slope_margin: float = 0.35,
                        min_share: float = 0.02,
                        top_k: int = 10,
                        strategy: str = "mean") -> List[NonScalable]:
    """series: {n_procs: PPG}. Flags vertices whose scaling slope deviates
    from ideal by > slope_margin and whose time share is significant."""
    scales = sorted(series)
    if not scales:
        return []
    ref = series[scales[-1]]
    psg = ref.psg
    V = len(psg.vertices)
    top = psg.children(psg.root)
    t_ref = ref.times_matrix()
    total_max = float(np.sum(t_ref[:, top].max(axis=0, initial=0.0))) \
        if top else 0.0                       # initial: safe at n_procs == 0
    total_max = total_max or 1e-12

    S = len(scales)
    M = np.zeros((S, V))                     # merged time per (scale, vertex)
    present = np.zeros((S, V), bool)         # vertex exists at that scale
    for si, p in enumerate(scales):
        ppg = series[p]
        vp = min(len(ppg.psg.vertices), V)
        if vp:
            M[si, :vp] = _merge_matrix(ppg.times_matrix()[:, :vp], strategy)
            present[si, :vp] = True

    slope = _fit_slopes(scales, M, (M > 0.0) & present)
    share = M[-1] / total_max
    deviation = slope - ideal_slope
    flagged = (M.sum(axis=0) > 0.0) & (deviation > slope_margin) \
        & (share >= min_share)

    out: List[NonScalable] = []
    for vid in np.nonzero(flagged)[0]:
        v = psg.vertices[vid]
        merged = {scales[si]: float(M[si, vid])
                  for si in range(S) if present[si, vid]}
        out.append(NonScalable(
            vid=int(vid), slope=float(slope[vid]), share=float(share[vid]),
            score=float(deviation[vid] * share[vid]), times=merged,
            kind=v.kind, name=v.name, source=v.source))
    out.sort(key=lambda d: -d.score)
    return out[:top_k]


def detect_abnormal(ppg: PPG, *, abnorm_thd: float = 1.3,
                    min_share: float = 0.01,
                    top_k: int = 20) -> List[Abnormal]:
    psg = ppg.psg
    if not len(psg.vertices) or not ppg.n_procs:
        return []
    t = ppg.times_matrix()                             # (P, V)
    top = psg.children(psg.root)
    step_time = float(t[:, top].sum(axis=1).max()) if top else 0.0
    step_time = step_time or 1e-12

    typical = np.median(t, axis=0)                     # (V,)
    active = t.max(axis=0) > 0.0
    over = (typical > 0.0) & (t > abnorm_thd * typical) \
        & ((t - typical) / step_time >= min_share)
    dead_typical = (typical == 0.0) & (t / step_time >= min_share)
    flags = (over | dead_typical) & active

    out: List[Abnormal] = []
    # (vid, proc) iteration order mirrors the scalar reference loop so the
    # stable sort below ranks ties identically
    for vid, proc in np.argwhere(flags.T):
        tv, ty = float(t[proc, vid]), float(typical[vid])
        out.append(Abnormal(
            vid=int(vid), proc=int(proc), time=tv, typical=ty,
            ratio=tv / ty if ty > 0 else float("inf"),
            kind=psg.vertices[vid].kind, name=psg.vertices[vid].name,
            source=psg.vertices[vid].source))
    out.sort(key=lambda d: -(d.time - d.typical))
    return out[:top_k]
