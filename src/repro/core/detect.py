"""Location-aware problematic vertex detection (paper §IV-A).

* Non-scalable vertices: per-vertex performance across job scales, merged
  across processes (mean/median/max/cluster strategies), fitted with a
  log-log model t ~ a * p^b; vertices whose growth rate deviates from the
  ideal slope and whose share of total time is significant are flagged.

* Abnormal vertices: per-vertex times across processes at one scale;
  processes above AbnormThd x median are flagged.

Complexity: both detectors are vectorized over the PPG's dense (n_procs,
n_vertices) time matrices — cross-process merges, the log-log slope fit,
and abnormality thresholding are batched reductions, O(P*V) work with no
per-(proc, vertex) Python loops.  Only flagged entries (<= top_k in
practice) materialize Python objects.

Backends: the detection math runs either as numpy on the host or as fused
``jax.jit`` kernels (:mod:`repro.core.detect_jax` — all jittable merge
strategies batched into one stacked (S, P, V) computation).  ``backend=``
on each detector selects it explicitly ("numpy" / "jax"); the default
"auto" uses the jitted path only when jax is ALREADY imported in the
process, so the pure-numpy analysis layer never pays the jax import (the
jax-free ``--smoke`` canary stays jax-free).  The ``SCALANA_DETECT_BACKEND``
environment variable overrides the default.

Merge strategies (``MERGE_STRATEGIES``): "mean", "median", "max", "p0",
"cluster", and variance-weighted "var" (readings weighted 1/time_var —
noisy processes count less).  "median"/"cluster" need data-dependent
per-column cuts and always run on the numpy path.
"""
from __future__ import annotations

import dataclasses
import math
import os
import sys
import warnings
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import COMM, COMP, LOOP, PPG
from repro.core.shard import ShardedStore

MERGE_STRATEGIES = ("mean", "median", "max", "p0", "cluster", "var")

# strategies the jitted backend computes; the tuple order defines the row
# layout of detect_jax's stacked merge output (detect_jax imports this)
JIT_STRATEGIES = ("mean", "max", "p0", "var")

# inverse-variance weights are 1/(var + VAR_EPS): a zero-variance reading
# gets (effectively infinite) weight, all-zero variance degrades to "mean"
VAR_EPS = 1e-18


def _resolve_backend(backend: Optional[str], device_live: bool = False):
    """Return the detect_jax module for the jitted path, or None for numpy.

    "auto" (the default) only opts into jax when it would plausibly win:
    jax must already be imported by something else in the process AND
    either the caller's data is device-resident (``device_live``, i.e. a
    sharded store feeding the zero-copy DeviceShardView path) or a
    non-CPU accelerator is the default jax backend.  On CPU-only jax with
    host-side stores the dispatch overhead makes the jitted path ~10x
    slower than numpy, so auto stays on numpy there; "jax" (explicitly or
    via SCALANA_DETECT_BACKEND) still forces the jitted path, and
    "numpy" never touches jax.
    """
    from_env = backend is None
    if from_env:
        backend = os.environ.get("SCALANA_DETECT_BACKEND", "auto")
    backend = str(backend).strip().lower()
    if backend not in ("numpy", "jax", "auto"):
        origin = " (from SCALANA_DETECT_BACKEND)" if from_env else ""
        raise ValueError(
            f"unknown detect backend{origin}: {backend!r}; valid values "
            f"are 'numpy', 'jax', 'auto'")
    if backend == "numpy":
        return None
    if backend == "auto":
        if "jax" not in sys.modules:
            return None
        if not device_live:
            try:
                import jax
                if jax.default_backend() == "cpu":
                    return None
            except Exception:
                return None
    try:
        from repro.core import detect_jax
    except ImportError:        # only jax-absence falls back; bugs surface
        if backend == "jax":
            raise
        return None
    if not detect_jax.HAS_JAX:
        if backend == "jax":
            raise ImportError("backend='jax' requested but jax is not "
                              "importable")
        return None
    return detect_jax


def _norm_mask(proc_mask, n_procs: int) -> Optional[np.ndarray]:
    """Validate a live-process mask; return the live row indices.

    ``None`` (or an all-live mask) means no degradation and returns None.
    Masked detection is exact ROW-SUBSETTING, not zeroing: a dead host's
    rows may hold stale non-zero readings, and the cross-process median
    counts zeros, so only excluding the rows outright reproduces a
    one-shot run over a store that never contained them.
    """
    if proc_mask is None:
        return None
    m = np.asarray(proc_mask, bool)
    if m.shape != (n_procs,):
        raise ValueError(f"proc_mask shape {m.shape} != ({n_procs},)")
    if m.all():
        return None
    return np.nonzero(m)[0]


@dataclasses.dataclass
class NonScalable:
    vid: int
    slope: float                 # d log t / d log p  (ideal strong-scaling: -1)
    share: float                 # fraction of total step time at max scale
    score: float                 # ranking key
    times: Dict[int, float]      # scale -> merged time
    kind: str = ""
    name: str = ""
    source: str = ""


@dataclasses.dataclass
class Abnormal:
    vid: int
    proc: int
    time: float
    typical: float               # median across processes
    ratio: float
    kind: str = ""
    name: str = ""
    source: str = ""


def _merge(times: Sequence[float], strategy: str,
           variances: Optional[Sequence[float]] = None) -> float:
    """Scalar reference merge (see ``_merge_matrix`` for the batched path)."""
    arr = np.asarray([t for t in times if t > 0.0])
    if arr.size == 0:
        return 0.0
    if strategy == "mean":
        return float(arr.mean())
    if strategy == "median":
        return float(np.median(arr))
    if strategy == "max":
        return float(arr.max())
    if strategy == "p0":
        # proc-0's reading when alive; a dead proc-0 (t == 0) falls back to
        # the mean of live readings instead of silently dropping the vertex
        return float(times[0]) if times[0] > 0.0 else float(arr.mean())
    if strategy == "var":
        # inverse-variance weighting: noisy processes count less; with no
        # variance data every weight is equal and this degrades to "mean"
        var = np.zeros(len(times)) if variances is None \
            else np.asarray(variances, float)
        live = np.asarray(times) > 0.0
        w = 1.0 / (var[live] + VAR_EPS)
        return float((w * np.asarray(times)[live]).sum() / w.sum())
    if strategy == "cluster":
        # 2-means along sorted values; report the larger cluster's mean
        s = np.sort(arr)
        best_cut, best_gap = None, -1.0
        for i in range(1, s.size):
            gap = s[i] - s[i - 1]
            if gap > best_gap:
                best_gap, best_cut = gap, i
        hi = s[best_cut:] if best_cut is not None else s
        return float(hi.mean())
    raise ValueError(strategy)


def _merge_matrix(t: np.ndarray, strategy: str,
                  var: Optional[np.ndarray] = None) -> np.ndarray:
    """Columnwise ``_merge`` over a (n_procs, V) time matrix -> (V,).

    ``var`` is the matching (n_procs, V) time-variance matrix, used only by
    the variance-weighted "var" strategy."""
    n_procs, V = t.shape
    pos = t > 0.0
    cnt = pos.sum(axis=0)
    any_pos = cnt > 0
    if strategy in ("mean", "p0"):
        s = t.sum(axis=0, where=pos)
        mean = np.divide(s, cnt, out=np.zeros(V), where=any_pos)
        if strategy == "mean":
            return mean
        p0 = t[0] if n_procs else np.zeros(V)
        return np.where(p0 > 0.0, p0, mean)
    if strategy == "median":
        masked = np.where(pos, t, np.nan)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            med = np.nanmedian(masked, axis=0)
        return np.where(any_pos, med, 0.0)
    if strategy == "max":
        return np.where(any_pos, t.max(axis=0, initial=0.0), 0.0)
    if strategy == "var":
        var = np.zeros_like(t) if var is None else var
        w = np.where(pos, 1.0 / (var + VAR_EPS), 0.0)
        wsum = w.sum(axis=0)
        return np.divide((w * t).sum(axis=0), wsum, out=np.zeros(V),
                         where=wsum > 0)
    if strategy == "cluster":
        out = np.zeros(V)
        for v in np.nonzero(any_pos)[0]:
            s = np.sort(t[pos[:, v], v])
            if s.size == 1:
                out[v] = s[0]
            else:
                cut = int(np.argmax(np.diff(s))) + 1
                out[v] = s[cut:].mean()
        return out
    raise ValueError(strategy)


def fit_loglog(scales: Sequence[int], times: Sequence[float]
               ) -> Tuple[float, float]:
    """Least-squares fit log t = log a + b log p. Returns (a, b)."""
    xs, ys = [], []
    for p, t in zip(scales, times):
        if t > 0:
            xs.append(math.log(p))
            ys.append(math.log(t))
    if len(xs) < 2:
        return (times[-1] if times else 0.0), 0.0
    b, loga = np.polyfit(xs, ys, 1)
    return math.exp(loga), float(b)


def _fit_slopes(scales: Sequence[int], M: np.ndarray,
                valid: np.ndarray) -> np.ndarray:
    """Batched least-squares slope of log t vs log p per column.

    M is (S, V) merged times, valid the (S, V) mask of usable points;
    columns with < 2 valid points get slope 0.0 (matching ``fit_loglog``).
    """
    S, V = M.shape
    x = np.log(np.asarray(scales, float))[:, None]          # (S, 1)
    Y = np.where(valid, np.log(np.where(valid, M, 1.0)), 0.0)
    n = valid.sum(axis=0)
    Sx = (x * valid).sum(axis=0)
    Sy = Y.sum(axis=0)
    Sxx = (x * x * valid).sum(axis=0)
    Sxy = (x * Y).sum(axis=0)
    denom = n * Sxx - Sx ** 2
    num = n * Sxy - Sx * Sy
    slope = np.divide(num, denom, out=np.zeros(V), where=denom != 0)
    return np.where(n >= 2, slope, 0.0)


def fit_slopes(scales: Sequence[int], M: np.ndarray,
               valid: np.ndarray) -> np.ndarray:
    """Public batched slope fit: (S, V) merged times -> (V,) log-log
    slopes.  The cross-run diff (``repro.runs.diff``) reuses this exact
    machinery per run; the jax backend provides the same contract as
    ``detect_jax.fit_slopes`` behind :func:`_resolve_backend`."""
    return _fit_slopes(scales, np.asarray(M, float), np.asarray(valid, bool))


def detect_non_scalable(series: Mapping[int, PPG], *,
                        ideal_slope: float = -1.0,
                        slope_margin: float = 0.35,
                        min_share: float = 0.02,
                        top_k: int = 10,
                        strategy: str = "mean",
                        backend: Optional[str] = None,
                        proc_mask: Optional[np.ndarray] = None
                        ) -> List[NonScalable]:
    """series: {n_procs: PPG}. Flags vertices whose scaling slope deviates
    from ideal by > slope_margin and whose time share is significant.

    ``backend``: "numpy" (host), "jax" (fused jitted kernel), or None/"auto"
    (jax iff already imported).  Strategies outside ``JIT_STRATEGIES`` run
    on numpy regardless.  On the jax backend, a series whose reference
    (largest) scale is backed by a :class:`~repro.core.shard.ShardedStore`
    is fed from device-resident shard buffers (each PPG's cached
    ``device_view()``; only dirty rows re-upload) — the stacked host
    matrix is never materialized.

    ``proc_mask``: optional (n_procs,) bool over the REFERENCE (largest)
    scale's processes; False rows (dead/stale hosts) are excluded from
    the merge exactly as if the reference store never contained them
    (see :func:`_norm_mask`).  A masked sharded reference falls back to
    the stacked host path."""
    scales = sorted(series)
    if not scales:
        return []
    ref = series[scales[-1]]
    psg = ref.psg
    V = len(psg.vertices)
    top = psg.children(psg.root)
    live_idx = _norm_mask(proc_mask, ref.n_procs)
    if live_idx is not None and live_idx.size == 0:
        return []

    S = len(scales)
    present = np.zeros((S, V), bool)         # vertex exists at that scale
    device_ok = isinstance(ref.perf, ShardedStore) and live_idx is None
    jx = (_resolve_backend(backend, device_live=device_ok)
          if strategy in JIT_STRATEGIES else None)
    if jx is not None and device_ok:
        # device-fed: each scale's per-host blocks feed the kernels from
        # its cached DeviceShardView (dirty rows re-upload, nothing
        # else); neither the stacked (S, Pmax, V) tensor nor the sharded
        # reference's (P, V) matrix is ever assembled on the host, and
        # the total step time reduces blockwise on the device
        for si, p in enumerate(scales):
            vp = min(len(series[p].psg.vertices), V)
            if vp:
                present[si, :vp] = True
        views = [series[p].device_view() for p in scales]
        M, slope, share, flagged = jx.non_scalable_views(
            scales, views, V, present, top, ideal_slope, slope_margin,
            min_share, strategy)
    else:
        t_ref = ref.times_matrix()
        if live_idx is not None:
            t_ref = t_ref[live_idx]          # exact row-subset, not zeroed
        # share guards against total_max <= 0 (an all-dead final scale)
        # in every backend: share is 0 there, flagging nothing, instead
        # of the inf/nan garbage an unguarded divide produced
        total_max = float(np.sum(t_ref[:, top].max(axis=0, initial=0.0))) \
            if top else 0.0                   # initial: safe at n_procs == 0
        if jx is not None:
            # stacked (S, Pmax, V) layout: scales with fewer processes are
            # padded with dead (0.0) readings, which every merge ignores
            sizes = [series[p].n_procs for p in scales]
            sizes[-1] = t_ref.shape[0]
            p_max = max(sizes)
            T = np.zeros((S, p_max, V))
            VAR = np.zeros((S, p_max, V))
            for si, p in enumerate(scales):
                ppg = series[p]
                vp = min(len(ppg.psg.vertices), V)
                if vp:
                    tm = t_ref if si == S - 1 else ppg.times_matrix()
                    vm = ppg.var_matrix()
                    if si == S - 1 and live_idx is not None:
                        vm = vm[live_idx]
                    T[si, :tm.shape[0], :vp] = tm[:, :vp]
                    VAR[si, :vm.shape[0], :vp] = vm[:, :vp]
                    present[si, :vp] = True
            M, slope, share, flagged = jx.non_scalable_arrays(
                scales, T, VAR, present, total_max, ideal_slope,
                slope_margin, min_share, strategy)
        else:
            M = np.zeros((S, V))             # merged time per (scale, vertex)
            for si, p in enumerate(scales):
                ppg = series[p]
                vp = min(len(ppg.psg.vertices), V)
                if vp:
                    tm = t_ref if si == S - 1 else ppg.times_matrix()
                    var = None
                    if strategy == "var":
                        var = ppg.var_matrix()
                        if si == S - 1 and live_idx is not None:
                            var = var[live_idx]
                        var = var[:, :vp]
                    M[si, :vp] = _merge_matrix(tm[:, :vp],
                                               strategy, var=var)
                    present[si, :vp] = True
            slope = _fit_slopes(scales, M, (M > 0.0) & present)
            share = np.divide(M[-1], total_max, out=np.zeros(V),
                              where=total_max > 0)
            flagged = (M.sum(axis=0) > 0.0) \
                & (slope - ideal_slope > slope_margin) & (share >= min_share)

    deviation = slope - ideal_slope
    out: List[NonScalable] = []
    for vid in np.nonzero(flagged)[0]:
        v = psg.vertices[vid]
        merged = {scales[si]: float(M[si, vid])
                  for si in range(S) if present[si, vid]}
        out.append(NonScalable(
            vid=int(vid), slope=float(slope[vid]), share=float(share[vid]),
            score=float(deviation[vid] * share[vid]), times=merged,
            kind=v.kind, name=v.name, source=v.source))
    out.sort(key=lambda d: -d.score)
    return out[:top_k]


def detect_abnormal(ppg: PPG, *, abnorm_thd: float = 1.3,
                    min_share: float = 0.01,
                    top_k: int = 20,
                    backend: Optional[str] = None,
                    proc_mask: Optional[np.ndarray] = None) -> List[Abnormal]:
    """Per-process outliers at one scale (AbnormThd x cross-process median).

    ``backend`` as in :func:`detect_non_scalable`.  On the jax backend, a
    :class:`~repro.core.shard.ShardedStore`-backed PPG runs entirely from
    device-resident shard buffers (incremental dirty-row upload; median,
    flags, and top-k device-side) — the online-detection fast path.

    ``proc_mask``: optional (n_procs,) bool of LIVE processes (the
    monitor's degraded-fleet contract).  False rows are excluded from the
    step time, the median and the flagging by exact row-subsetting (see
    :func:`_norm_mask`); reported ``proc`` indices stay global.  On the
    device path the live rows are gathered on the device."""
    psg = ppg.psg
    if not len(psg.vertices) or not ppg.n_procs:
        return []
    live_idx = _norm_mask(proc_mask, ppg.n_procs)
    if live_idx is not None and live_idx.size == 0:
        return []
    top = psg.children(psg.root)

    # both backends produce the same <= top_k (vid, proc) winners, ranked
    # by descending time-over-typical with stable vid-major ties, and only
    # those materialize Python objects (a straggler can flag thousands of
    # (proc, vertex) pairs; building objects for all of them dominated
    # detection cost at 8k procs)
    device_ok = isinstance(ppg.perf, ShardedStore)
    jx = _resolve_backend(backend, device_live=device_ok)
    if jx is not None and device_ok:
        # device-fed: the per-host blocks live on the device (dirty rows
        # re-upload per call), concatenate there, and the step time,
        # median, flagging and ranking all run device-side — the stacked
        # (P, V) host matrix is never materialized
        vids, procs, typical, _ = jx.abnormal_topk_view(
            ppg.device_view(), len(psg.vertices), top, abnorm_thd,
            min_share, top_k, live_rows=live_idx)
        picks = list(zip(vids.tolist(), procs.tolist()))
    else:
        t = ppg.times_matrix()                         # (P, V)
        if live_idx is not None:
            t = t[live_idx]                  # exact row-subset, not zeroed
        step_time = float(t[:, top].sum(axis=1).max()) if top else 0.0
        step_time = step_time or 1e-12
        if jx is not None:
            # fused flags + device-side top-k: the (P, V) flag matrix and
            # the ranking scores never round-trip to the host — only the
            # winning indices transfer
            vids, procs, typical, _ = jx.abnormal_topk(
                t, abnorm_thd, min_share, step_time, top_k)
            picks = list(zip(vids.tolist(), procs.tolist()))
        else:
            typical = np.median(t, axis=0)             # (V,)
            active = t.max(axis=0) > 0.0
            over = (typical > 0.0) & (t > abnorm_thd * typical) \
                & ((t - typical) / step_time >= min_share)
            dead_typical = (typical == 0.0) & (t / step_time >= min_share)
            flags = (over | dead_typical) & active
            idx = np.argwhere(flags.T)                 # vid-major
            picks = []
            if idx.size:
                score = t[idx[:, 1], idx[:, 0]] - typical[idx[:, 0]]
                picks = [(int(idx[j, 0]), int(idx[j, 1]))
                         for j in np.argsort(-score, kind="stable")[:top_k]]

    out: List[Abnormal] = []
    for vid, proc in picks:
        if live_idx is not None:             # local (live-subset) -> global
            proc = int(live_idx[proc])
        v = psg.vertices[vid]
        tv, ty = float(ppg.get_time(proc, vid)), float(typical[vid])
        out.append(Abnormal(
            vid=vid, proc=proc, time=tv, typical=ty,
            ratio=tv / ty if ty > 0 else float("inf"),
            kind=v.kind, name=v.name, source=v.source))
    return out
