"""Location-aware problematic vertex detection (paper §IV-A).

* Non-scalable vertices: per-vertex performance across job scales, merged
  across processes (mean/median/max/cluster strategies), fitted with a
  log-log model t ~ a * p^b; vertices whose growth rate deviates from the
  ideal slope and whose share of total time is significant are flagged.

* Abnormal vertices: per-vertex times across processes at one scale;
  processes above AbnormThd x median are flagged.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import COMM, COMP, LOOP, PPG


@dataclasses.dataclass
class NonScalable:
    vid: int
    slope: float                 # d log t / d log p  (ideal strong-scaling: -1)
    share: float                 # fraction of total step time at max scale
    score: float                 # ranking key
    times: Dict[int, float]      # scale -> merged time
    kind: str = ""
    name: str = ""
    source: str = ""


@dataclasses.dataclass
class Abnormal:
    vid: int
    proc: int
    time: float
    typical: float               # median across processes
    ratio: float
    kind: str = ""
    name: str = ""
    source: str = ""


def _merge(times: Sequence[float], strategy: str) -> float:
    arr = np.asarray([t for t in times if t > 0.0])
    if arr.size == 0:
        return 0.0
    if strategy == "mean":
        return float(arr.mean())
    if strategy == "median":
        return float(np.median(arr))
    if strategy == "max":
        return float(arr.max())
    if strategy == "p0":
        return float(times[0])
    if strategy == "cluster":
        # 2-means along sorted values; report the larger cluster's mean
        s = np.sort(arr)
        best_cut, best_gap = None, -1.0
        for i in range(1, s.size):
            gap = s[i] - s[i - 1]
            if gap > best_gap:
                best_gap, best_cut = gap, i
        hi = s[best_cut:] if best_cut is not None else s
        return float(hi.mean())
    raise ValueError(strategy)


def fit_loglog(scales: Sequence[int], times: Sequence[float]
               ) -> Tuple[float, float]:
    """Least-squares fit log t = log a + b log p. Returns (a, b)."""
    xs, ys = [], []
    for p, t in zip(scales, times):
        if t > 0:
            xs.append(math.log(p))
            ys.append(math.log(t))
    if len(xs) < 2:
        return (times[-1] if times else 0.0), 0.0
    b, loga = np.polyfit(xs, ys, 1)
    return math.exp(loga), float(b)


def detect_non_scalable(series: Mapping[int, PPG], *,
                        ideal_slope: float = -1.0,
                        slope_margin: float = 0.35,
                        min_share: float = 0.02,
                        top_k: int = 10,
                        strategy: str = "mean") -> List[NonScalable]:
    """series: {n_procs: PPG}. Flags vertices whose scaling slope deviates
    from ideal by > slope_margin and whose time share is significant."""
    scales = sorted(series)
    if not scales:
        return []
    ref = series[scales[-1]]
    psg = ref.psg
    total_max = sum(max(ref.times_across_procs(v.vid) or [0.0])
                    for v in psg.vertices if v.parent == psg.root) or 1e-12

    out: List[NonScalable] = []
    for v in psg.vertices:
        merged: Dict[int, float] = {}
        for p in scales:
            ppg = series[p]
            if v.vid < len(ppg.psg.vertices):
                merged[p] = _merge(ppg.times_across_procs(v.vid), strategy)
        if sum(merged.values()) <= 0:
            continue
        _, slope = fit_loglog(list(merged), list(merged.values()))
        share = merged.get(scales[-1], 0.0) / total_max
        deviation = slope - ideal_slope
        if deviation > slope_margin and share >= min_share:
            out.append(NonScalable(
                vid=v.vid, slope=slope, share=share,
                score=deviation * share, times=merged,
                kind=v.kind, name=v.name, source=v.source))
    out.sort(key=lambda d: -d.score)
    return out[:top_k]


def detect_abnormal(ppg: PPG, *, abnorm_thd: float = 1.3,
                    min_share: float = 0.01,
                    top_k: int = 20) -> List[Abnormal]:
    psg = ppg.psg
    step_time = max(
        sum(ppg.get_time(p, v.vid) for v in psg.vertices
            if v.parent == psg.root)
        for p in range(ppg.n_procs)) or 1e-12
    out: List[Abnormal] = []
    for v in psg.vertices:
        times = ppg.times_across_procs(v.vid)
        arr = np.asarray(times)
        if arr.max() <= 0:
            continue
        typical = float(np.median(arr))
        for proc, t in enumerate(times):
            if typical > 0 and t > abnorm_thd * typical \
                    and (t - typical) / step_time >= min_share:
                out.append(Abnormal(
                    vid=v.vid, proc=proc, time=t, typical=typical,
                    ratio=t / typical, kind=v.kind, name=v.name,
                    source=v.source))
            elif typical == 0 and t / step_time >= min_share:
                out.append(Abnormal(vid=v.vid, proc=proc, time=t,
                                    typical=typical, ratio=float("inf"),
                                    kind=v.kind, name=v.name,
                                    source=v.source))
    out.sort(key=lambda d: -(d.time - d.typical))
    return out[:top_k]
