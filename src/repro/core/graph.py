"""Program Structure Graph (PSG) and Program Performance Graph (PPG).

Vertex kinds follow the paper (§III-A): Loop, Branch, Call, Comp, plus Comm
(the MPI-vertex analogue: XLA/JAX collectives).  Edges carry a dependence
kind: 'data' (sequential data flow), 'control' (enclosing control structure)
and — on the PPG — 'comm' (inter-process communication dependence).

Complexity guarantees (the indexed graph core):

* ``PSG.children`` / ``preds`` / ``succs`` / ``by_kind`` are O(result) — the
  adjacency and kind indexes are maintained incrementally by ``new_vertex``,
  ``add_edge`` and ``set_parent``, never by rescanning all V vertices or E
  edges.
* ``PPG.perf`` is an array store (:class:`PerfStore`): time / variance /
  sample matrices of shape (n_procs, n_vertices), counters column-sparse
  (:class:`CounterColumns` — a counter only materializes at the vertex
  subset that defines it, e.g. ``wait_s`` at Comm vertices).
  ``times_across_procs`` and the detectors' cross-process reductions are
  numpy slices, O(P) memory with no per-entry Python objects.
* Collective communication dependence is implicit: ``add_collective_edges``
  records the participant *group* (O(|group|) storage) instead of
  materializing the O(|group|²) clique.  ``comm_partners`` resolves partners
  lazily; only p2p edges are stored explicitly.  At 8192 processes a single
  all-reduce costs one 8192-entry tuple, not 67M edge tuples.
"""
from __future__ import annotations

import dataclasses
import json
import weakref
from dataclasses import dataclass, field
from typing import (Any, Dict, Iterable, Iterator, List, Mapping, Optional,
                    Sequence, Set, Tuple)

import numpy as np

LOOP = "Loop"
BRANCH = "Branch"
CALL = "Call"
COMP = "Comp"
COMM = "Comm"
ROOT = "Root"

KINDS = (LOOP, BRANCH, CALL, COMP, COMM, ROOT)


def pairs_array(pairs) -> np.ndarray:
    """(n, 2) intp array from a p2p pair list."""
    if isinstance(pairs, np.ndarray):
        return pairs.reshape(-1, 2).astype(np.intp, copy=False)
    return np.asarray(pairs, np.intp).reshape(-1, 2)


def check_tree_format(meta: Optional[Mapping[str, Any]], expect: str,
                      latest: int) -> int:
    """Validate a ``to_tree`` meta header and return its version.

    Every serializable graph object stamps its meta with
    ``{"format": <name>, "version": <int>}``; loaders call this first so
    a tree saved by a NEWER layout fails loudly instead of reloading
    garbage.  ``meta`` may be ``None`` or headerless (snapshots written
    before the seam was versioned): those are treated as version 1 of
    the expected format — the pre-versioning layout is identical.
    """
    if not meta:
        return 1
    fmt = meta.get("format", expect)
    if fmt != expect:
        raise ValueError(f"tree format {fmt!r}, expected {expect!r}")
    version = int(meta.get("version", 1))
    if version < 1 or version > latest:
        raise ValueError(f"{expect} tree version {version} unsupported "
                         f"(latest known: {latest})")
    return version

# collective primitives / HLO ops treated as Comm vertices
COLLECTIVE_PRIMS = {
    "psum", "pmax", "pmin", "all_gather", "all_gather_invariant",
    "reduce_scatter", "all_to_all", "ppermute", "psum_scatter",
}
P2P_PRIMS = {"ppermute"}     # point-to-point-like (explicit src->dst pairs)


# per-Vertex cache of the array form of p2p_pairs: converting an 8k-tuple
# list costs milliseconds, and the replay engine + PPG assembly both need
# it every call.  Keyed by id() with a weakref guard (Vertex is an
# eq-dataclass, so not hashable); validated by CONTENT equality against a
# snapshot copy — ~60x cheaper than reconversion (the snapshot shares the
# tuple objects, so == short-circuits on identity) and sound under any
# mutation, in-place element edits included.  Entries are dropped when
# their vertex dies.
_PAIRS_ARRAYS: Dict[int, Tuple] = {}


def vertex_pairs_array(v: "Vertex") -> np.ndarray:
    """Cached :func:`pairs_array` of ``v.p2p_pairs``."""
    pairs = v.p2p_pairs
    key = id(v)
    hit = _PAIRS_ARRAYS.get(key)
    if hit is not None and hit[0]() is v and hit[1] == pairs:
        return hit[2]
    arr = pairs_array(pairs)

    def _drop(_ref, _key=key):
        _PAIRS_ARRAYS.pop(_key, None)

    _PAIRS_ARRAYS[key] = (weakref.ref(v, _drop), list(pairs), arr)
    return arr


@dataclass
class Vertex:
    vid: int
    kind: str
    name: str                         # primitive / structure name
    source: str = ""                  # "file.py:123" best user frame
    parent: int = -1                  # enclosing Loop/Branch/Call vid
    depth: int = 0                    # control-nest depth
    prims: List[str] = field(default_factory=list)
    # static "hardware counters" (PAPI analogue), per single execution:
    flops: float = 0.0
    bytes: float = 0.0
    comm_bytes: float = 0.0
    comm_kind: str = ""               # all_reduce | all_gather | ...
    p2p_pairs: List[Tuple[int, int]] = field(default_factory=list)
    meta: Dict[str, Any] = field(default_factory=dict)

    @property
    def is_comm(self) -> bool:
        return self.kind == COMM

    @property
    def is_control(self) -> bool:
        return self.kind in (LOOP, BRANCH, CALL)


class EdgeSet:
    """Set of (src, dst, kind) edges with incrementally-maintained per-vertex
    adjacency lists, so ``preds``/``succs`` are O(degree) not O(E)."""

    __slots__ = ("_set", "_preds", "_succs")

    def __init__(self, items: Iterable[Tuple[int, int, str]] = ()):
        self._set: Set[Tuple[int, int, str]] = set()
        self._preds: Dict[int, List[Tuple[int, str]]] = {}
        self._succs: Dict[int, List[Tuple[int, str]]] = {}
        for e in items:
            self.add((e[0], e[1], e[2]))

    def add(self, edge: Tuple[int, int, str]) -> None:
        if edge in self._set:
            return
        self._set.add(edge)
        s, d, k = edge
        self._preds.setdefault(d, []).append((s, k))
        self._succs.setdefault(s, []).append((d, k))

    def preds(self, vid: int, kind: Optional[str] = None) -> List[int]:
        lst = self._preds.get(vid, ())
        if kind is None:
            return [s for s, _ in lst]
        return [s for s, k in lst if k == kind]

    def succs(self, vid: int, kind: Optional[str] = None) -> List[int]:
        lst = self._succs.get(vid, ())
        if kind is None:
            return [d for d, _ in lst]
        return [d for d, k in lst if k == kind]

    def __contains__(self, edge) -> bool:
        return tuple(edge) in self._set

    def __iter__(self) -> Iterator[Tuple[int, int, str]]:
        return iter(self._set)

    def __len__(self) -> int:
        return len(self._set)

    def __eq__(self, other) -> bool:
        if isinstance(other, EdgeSet):
            return self._set == other._set
        if isinstance(other, (set, frozenset)):
            return self._set == other
        return NotImplemented

    def __repr__(self) -> str:
        return f"EdgeSet({sorted(self._set)!r})"


class PSG:
    """Per-process program structure graph.

    ``order`` is program (execution) order of vertex ids.  Data-dependence
    edges are implied by consecutive order within the same parent; control
    edges connect a control vertex to its children.  Both are materialized
    in ``edges`` for analysis/serialization.

    Adjacency (children-by-parent, preds/succs-by-kind) and kind indexes are
    maintained incrementally; reparent vertices with :meth:`set_parent` so
    the children index stays consistent.
    """

    def __init__(self, vertices: Optional[Iterable[Vertex]] = None,
                 edges: Iterable[Tuple[int, int, str]] = (), root: int = 0):
        self.vertices: List[Vertex] = []
        self._edges = EdgeSet(edges)
        self.root = root
        self._children: Dict[int, List[int]] = {}
        self._kind_index: Dict[str, List[int]] = {}
        for v in vertices or ():
            self._append_vertex(v)

    # ------------------------------------------------------------------
    @property
    def edges(self) -> EdgeSet:
        return self._edges

    @edges.setter
    def edges(self, items: Iterable[Tuple[int, int, str]]) -> None:
        self._edges = items if isinstance(items, EdgeSet) else EdgeSet(items)

    def _append_vertex(self, v: Vertex) -> None:
        self.vertices.append(v)
        self._kind_index.setdefault(v.kind, []).append(v.vid)
        if v.parent >= 0:
            self._children.setdefault(v.parent, []).append(v.vid)

    def new_vertex(self, kind: str, name: str, *, source: str = "",
                   parent: int = -1, depth: int = 0, **meta) -> Vertex:
        v = Vertex(vid=len(self.vertices), kind=kind, name=name, source=source,
                   parent=parent, depth=depth)
        for k, val in meta.items():
            setattr(v, k, val) if hasattr(v, k) else v.meta.__setitem__(k, val)
        self._append_vertex(v)
        return v

    def set_parent(self, vid: int, parent: int) -> None:
        """Reparent a vertex, keeping the children index consistent."""
        v = self.vertices[vid]
        if v.parent == parent:
            return
        if v.parent >= 0:
            kids = self._children.get(v.parent)
            if kids is not None and vid in kids:
                kids.remove(vid)
        v.parent = parent
        if parent >= 0:
            self._children.setdefault(parent, []).append(vid)

    def add_edge(self, src: int, dst: int, kind: str = "data") -> None:
        if src != dst:
            self._edges.add((src, dst, kind))

    def children(self, vid: int) -> List[int]:
        return list(self._children.get(vid, ()))

    def preds(self, vid: int, kind: Optional[str] = None) -> List[int]:
        return self._edges.preds(vid, kind)

    def succs(self, vid: int, kind: Optional[str] = None) -> List[int]:
        return self._edges.succs(vid, kind)

    def by_kind(self, kind: str) -> List[Vertex]:
        return [self.vertices[i] for i in self._kind_index.get(kind, ())]

    def stats(self) -> Dict[str, int]:
        out = {k: 0 for k in KINDS}
        for k, vids in self._kind_index.items():
            out[k] = len(vids)
        out["total"] = len(self.vertices)
        return out

    # ------------------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "vertices": [dataclasses.asdict(v) for v in self.vertices],
            "edges": sorted(self._edges),
            "root": self.root,
        })

    @classmethod
    def from_json(cls, text: str) -> "PSG":
        raw = json.loads(text)
        g = cls(root=raw["root"])
        for d in raw["vertices"]:
            d["p2p_pairs"] = [tuple(p) for p in d.get("p2p_pairs", [])]
            g._append_vertex(Vertex(**d))
        g.edges = {(s, d, k) for s, d, k in raw["edges"]}
        return g

    def nbytes(self) -> int:
        """Serialized storage footprint (paper Table I 'storage cost')."""
        return len(self.to_json().encode())

    # -- checkpoint-tree seam ------------------------------------------
    def to_tree(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """(tree, meta): the graph as a checkpoint-friendly pytree.

        The JSON form rides in a single uint8 leaf (checkpoint leaves
        are arrays, not strings); meta carries the versioned format
        header.  Round-trips through :meth:`from_tree` bit-identically.
        """
        data = np.frombuffer(self.to_json().encode(), np.uint8).copy()
        return {"json": data}, {"format": "psg", "version": 1}

    @classmethod
    def from_tree(cls, tree: Mapping[str, Any],
                  meta: Optional[Mapping[str, Any]] = None) -> "PSG":
        check_tree_format(meta, "psg", 1)
        data = np.asarray(tree["json"], np.uint8)
        return cls.from_json(data.tobytes().decode())


# ---------------------------------------------------------------------------
# PPG
# ---------------------------------------------------------------------------

@dataclass
class PerfVector:
    """Per-(process, vertex) performance vector (paper §III-B1)."""
    time: float = 0.0                 # seconds (mean over samples)
    time_var: float = 0.0
    samples: int = 0
    counters: Dict[str, float] = field(default_factory=dict)  # PAPI analogue


@dataclass
class RowBlock:
    """A self-contained copy of a row subset of a :class:`PerfStore`.

    The wire/snapshot unit of the streaming monitor: a per-host producer
    packages its shard's dirty rows as a RowBlock
    (:meth:`PerfStore.extract_rows`), and the aggregator overwrites the
    same rows of its replica with it (:meth:`PerfStore.apply_rows`) —
    full row STATE, not an increment, so re-applying a block is
    idempotent and applying blocks in sequence order reproduces the
    source store bit for bit.

    ``rows`` are row indices local to the source store; ``counters``
    maps name -> (vids, (k, m) values, (k, m) mask) restricted to the
    columns carrying data at these rows.
    """
    rows: np.ndarray                  # (k,) row indices
    n_cols: int                       # column count the matrices cover
    time: np.ndarray                  # (k, n_cols)
    time_var: np.ndarray              # (k, n_cols)
    samples: np.ndarray               # (k, n_cols) int64
    mask: np.ndarray                  # (k, n_cols) bool
    counters: Dict[str, Tuple[np.ndarray, np.ndarray, np.ndarray]] = \
        field(default_factory=dict)

    def nbytes(self) -> int:
        n = (self.rows.nbytes + self.time.nbytes + self.time_var.nbytes
             + self.samples.nbytes + self.mask.nbytes)
        for vids, values, mask in self.counters.values():
            n += vids.nbytes + values.nbytes + mask.nbytes
        return n


class CounterColumns:
    """Column-sparse per-counter storage (a CSC layout over vertex ids).

    A counter like ``wait_s`` only exists at the vertex subset that defines
    it (Comm vertices), so its matrix is stored as a dense (n_procs, k)
    block over only the k columns ever written, plus a vid -> slot map.
    Dense (n_procs, V) views are materialized on demand; ``columns()``
    exposes the compressed block directly for hot paths (backtrack's busy
    matrix subtracts ``wait_s`` at k Comm columns, not V).
    """

    __slots__ = ("n_procs", "slot_of", "vids", "values", "mask")

    def __init__(self, n_procs: int):
        self.n_procs = int(n_procs)
        self.slot_of: Dict[int, int] = {}
        self.vids: List[int] = []
        self.values = np.zeros((self.n_procs, 4))
        self.mask = np.zeros((self.n_procs, 4), bool)

    def ensure_rows(self, n_procs: int) -> None:
        """Grow the proc dimension exactly (streamed assembly adds hosts
        late; ``n_procs`` stays the logical row count, so growth is exact,
        one realloc per newly-seen host range)."""
        if n_procs <= self.n_procs:
            return
        values = np.zeros((n_procs, self.values.shape[1]))
        values[:self.n_procs] = self.values
        mask = np.zeros((n_procs, self.mask.shape[1]), bool)
        mask[:self.n_procs] = self.mask
        self.values, self.mask, self.n_procs = values, mask, n_procs

    def slot(self, vid: int) -> int:
        """Slot of ``vid``, allocating (and growing by doubling) if new."""
        s = self.slot_of.get(vid)
        if s is not None:
            return s
        s = len(self.vids)
        if s >= self.values.shape[1]:
            cap = 2 * self.values.shape[1]
            values = np.zeros((self.n_procs, cap))
            values[:, :s] = self.values[:, :s]
            mask = np.zeros((self.n_procs, cap), bool)
            mask[:, :s] = self.mask[:, :s]
            self.values, self.mask = values, mask
        self.slot_of[vid] = s
        self.vids.append(vid)
        return s

    def columns(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(vids, values, mask): the compressed (n_procs, k) block."""
        k = len(self.vids)
        return (np.asarray(self.vids, np.int64),
                self.values[:, :k], self.mask[:, :k])

    def dense(self, n_vertices: int) -> np.ndarray:
        """Materialize the (n_procs, n_vertices) view; unset entries 0.0."""
        out = np.zeros((self.n_procs, n_vertices))
        vids, values, mask = self.columns()
        keep = vids < n_vertices
        if keep.any():
            out[:, vids[keep]] = np.where(mask[:, keep], values[:, keep], 0.0)
        return out

    # -- checkpoint-tree seam ------------------------------------------
    def to_tree(self) -> Dict[str, np.ndarray]:
        """The compressed block as a pytree: (k,) vids + (n_procs, k)
        values/mask — the column-sparse layout goes to disk as-is, never
        densified to (n_procs, V)."""
        vids, values, mask = self.columns()
        return {"vids": vids.copy(), "values": values.copy(),
                "mask": mask.copy()}

    def load_tree(self, tree: Mapping[str, Any]) -> None:
        """Replace this counter's columns with a :meth:`to_tree` block
        (``n_procs`` stays; saved rows beyond it grow the store first)."""
        vids = np.asarray(tree["vids"], np.int64)
        values = np.asarray(tree["values"], float)
        mask = np.asarray(tree["mask"], bool)
        k = int(vids.size)
        rows = values.shape[0]
        self.vids = [int(v) for v in vids.tolist()]
        self.slot_of = {v: i for i, v in enumerate(self.vids)}
        cap = max(k, 4)
        self.values = np.zeros((self.n_procs, cap))
        self.mask = np.zeros((self.n_procs, cap), bool)
        if k:
            self.values[:rows, :k] = values
            self.mask[:rows, :k] = mask

    def nbytes(self) -> int:
        k = len(self.vids)
        return self.n_procs * k * 9 + 8 * k      # f64 value + bool mask + vid


class PerfStore:
    """Per-(process, vertex) performance store.

    Time / variance / sample-count data live in dense (n_procs, n_vertices)
    numpy matrices, so cross-process reductions are array slices.  Counters
    (the PAPI analogue: ``wait_s``, ``flops``, ...) are column-sparse
    :class:`CounterColumns` — each materializes only at the vertex subset
    that defines it, cutting counter memory ~V/|Comm| for comm-only
    counters at scale.  The old ``{(proc, vid): PerfVector}`` mapping API
    is preserved on top: ``store[(p, vid)]`` materializes a PerfVector view
    on demand.  Columns grow automatically when vertices are added after
    construction.
    """

    __slots__ = ("n_procs", "_cols", "time", "time_var", "samples",
                 "_mask", "_counters", "_count", "_dirty")

    def __init__(self, n_procs: int, n_vertices: int = 0):
        self.n_procs = int(n_procs)
        self._cols = max(int(n_vertices), 1)
        shape = (self.n_procs, self._cols)
        self.time = np.zeros(shape)
        self.time_var = np.zeros(shape)
        self.samples = np.zeros(shape, np.int64)
        self._mask = np.zeros(shape, bool)
        self._counters: Dict[str, CounterColumns] = {}
        self._count = 0
        # rows written since the last clear_dirty() — the device-resident
        # buffer layer (shard.DeviceShardView) re-uploads only these
        self._dirty = np.zeros(self.n_procs, bool)

    # -- storage management --------------------------------------------
    def _grow(self, arr: np.ndarray, cols: int) -> np.ndarray:
        out = np.zeros((self.n_procs, cols), arr.dtype)
        out[:, :arr.shape[1]] = arr
        return out

    def ensure_columns(self, n_vertices: int) -> None:
        if n_vertices <= self._cols:
            return
        cols = max(n_vertices, 2 * self._cols)
        self.time = self._grow(self.time, cols)
        self.time_var = self._grow(self.time_var, cols)
        self.samples = self._grow(self.samples, cols)
        self._mask = self._grow(self._mask, cols)
        self._cols = cols

    def ensure_rows(self, n_procs: int) -> None:
        """Grow the proc dimension exactly to ``n_procs`` (streamed shard
        assembly registers host ranges as they arrive).  ``n_procs`` is the
        logical row count everywhere, so growth is exact — one realloc per
        newly-seen host range, not doubling."""
        if n_procs <= self.n_procs:
            return

        def grow_rows(arr: np.ndarray) -> np.ndarray:
            out = np.zeros((n_procs, arr.shape[1]), arr.dtype)
            out[:arr.shape[0]] = arr
            return out

        self.time = grow_rows(self.time)
        self.time_var = grow_rows(self.time_var)
        self.samples = grow_rows(self.samples)
        self._mask = grow_rows(self._mask)
        dirty = np.zeros(n_procs, bool)
        dirty[:self._dirty.size] = self._dirty
        self._dirty = dirty
        for cc in self._counters.values():
            cc.ensure_rows(n_procs)
        self.n_procs = int(n_procs)

    # -- dirty-row tracking (device-resident buffer feed) --------------
    def dirty_rows(self) -> np.ndarray:
        """Row indices written since the last :meth:`clear_dirty` — what an
        incremental device upload must re-transfer."""
        return np.nonzero(self._dirty)[0]

    def clear_dirty(self) -> None:
        self._dirty[:] = False

    def _counter_cols(self, name: str) -> CounterColumns:
        cc = self._counters.get(name)
        if cc is None:
            cc = self._counters[name] = CounterColumns(self.n_procs)
        return cc

    # -- matrix views (the fast path) ----------------------------------
    def time_matrix(self, n_vertices: Optional[int] = None) -> np.ndarray:
        """(n_procs, n_vertices) seconds; unset entries are 0.0."""
        if n_vertices is None or n_vertices == self._cols:
            return self.time
        if n_vertices <= self._cols:
            return self.time[:, :n_vertices]
        out = np.zeros((self.n_procs, n_vertices))
        out[:, :self._cols] = self.time
        return out

    def var_matrix(self, n_vertices: Optional[int] = None) -> np.ndarray:
        """(n_procs, n_vertices) time-variance; unset entries are 0.0."""
        if n_vertices is None or n_vertices == self._cols:
            return self.time_var
        if n_vertices <= self._cols:
            return self.time_var[:, :n_vertices]
        out = np.zeros((self.n_procs, n_vertices))
        out[:, :self._cols] = self.time_var
        return out

    def time_column(self, vid: int) -> np.ndarray:
        """(n_procs,) time at one vertex; zeros when the column is unset."""
        if vid >= self._cols:
            return np.zeros(self.n_procs)
        return self.time[:, vid]

    def time_at(self, p: int, vid: int) -> float:
        """O(1) time read; 0.0 where unset (the ``get_time`` fast path)."""
        if vid >= self._cols:
            return 0.0
        return float(self.time[p, vid])

    def counter_matrix(self, name: str,
                       n_vertices: Optional[int] = None) -> np.ndarray:
        """(n_procs, n_vertices) counter values; unset entries are 0.0.

        A dense view materialized from the sparse columns — prefer
        :meth:`counter_columns` in hot paths that touch few vertices."""
        n = self._cols if n_vertices is None else n_vertices
        cc = self._counters.get(name)
        if cc is None:
            return np.zeros((self.n_procs, n))
        return cc.dense(n)

    def counter_columns(self, name: str
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Compressed (vids, (n_procs, k) values, (n_procs, k) mask) view of
        one counter — only the k columns the counter was ever written at."""
        cc = self._counters.get(name)
        if cc is None:
            return (np.zeros(0, np.int64),
                    np.zeros((self.n_procs, 0)),
                    np.zeros((self.n_procs, 0), bool))
        return cc.columns()

    def counter_names(self) -> List[str]:
        return list(self._counters)

    # -- bulk columns (simulator / replicated-profile fast path) -------
    def set_column(self, vid: int, time, *, time_var=0.0, samples=1,
                   counters: Optional[Mapping[str, Any]] = None,
                   procs: Optional[np.ndarray] = None) -> None:
        """Set a whole vertex column (optionally a proc subset) at once."""
        self.ensure_columns(vid + 1)
        idx = slice(None) if procs is None else procs
        newly = np.count_nonzero(~self._mask[idx, vid])
        self._count += int(newly)
        self._mask[idx, vid] = True
        self._dirty[idx] = True
        self.time[idx, vid] = time
        self.time_var[idx, vid] = time_var
        self.samples[idx, vid] = samples
        for name, val in (counters or {}).items():
            cc = self._counter_cols(name)
            s = cc.slot(vid)
            cc.values[idx, s] = val
            cc.mask[idx, s] = True

    def set_entries(self, procs, vid: int, time, *, time_var=0.0, samples=1,
                    counters: Optional[Mapping[str, Any]] = None,
                    accumulate: bool = False) -> None:
        """Batched scatter write at rows ``procs`` of one vertex column.

        ``procs`` is an integer index array; ``time`` / ``time_var`` /
        ``samples`` / counter values are scalars or arrays broadcast
        against it.  With ``accumulate=True``, ``time`` and counter values
        ADD onto the existing entries — repeated indices accumulate in
        index order (``np.add.at``), which is the replay engine's per-round
        scatter; an unset entry accumulates from 0.0.  ``time_var`` and
        ``samples`` are always assigned, and the entry mask is set either
        way.  This is also the write seam for streamed/multi-host PPG
        assembly: a shard's (procs, values) block lands in one call.
        """
        procs = np.asarray(procs, np.intp)
        if procs.size == 0:
            return
        self.ensure_columns(vid + 1)
        # O(P) boolean scatter instead of an O(k log k) unique-sort: count
        # newly-set entries (duplicate indices once) and detect duplicates
        touched = np.zeros(self.n_procs, bool)
        touched[procs] = True
        unique = int(np.count_nonzero(touched)) == procs.size
        col_mask = self._mask[:, vid]
        self._count += int(np.count_nonzero(touched & ~col_mask))
        col_mask |= touched
        self._dirty |= touched
        t = np.broadcast_to(np.asarray(time, float), procs.shape)
        if not accumulate:
            self.time[procs, vid] = t
        elif unique:                           # no duplicates: gather-add
            self.time[procs, vid] += t
        else:
            np.add.at(self.time[:, vid], procs, t)
        self.time_var[procs, vid] = time_var
        self.samples[procs, vid] = samples
        for name, val in (counters or {}).items():
            cc = self._counter_cols(name)
            s = cc.slot(vid)
            va = np.broadcast_to(np.asarray(val, float), procs.shape)
            if not accumulate:
                cc.values[procs, s] = va
            elif unique:
                cc.values[procs, s] += va
            else:
                np.add.at(cc.values[:, s], procs, va)
            cc.mask[procs, s] = True

    def counter_at(self, name: str, p: int, vid: int,
                   default: float = 0.0) -> float:
        """O(1) counter read; ``default`` when the entry/counter is unset."""
        cc = self._counters.get(name)
        if cc is None:
            return default
        s = cc.slot_of.get(vid)
        if s is None or not cc.mask[p, s]:
            return default
        return float(cc.values[p, s])

    def set_entry(self, p: int, vid: int, time: float, *, time_var=0.0,
                  samples=1, counters: Optional[Mapping[str, float]] = None,
                  accumulate: bool = False) -> None:
        """Scalar write without PerfVector churn (counters merge in place).

        ``accumulate=True`` adds ``time`` / counter values onto the
        existing entry (from 0.0 when unset) — the scalar form of
        :meth:`set_entries`' accumulate mode."""
        self.ensure_columns(vid + 1)
        if not self._mask[p, vid]:
            self._count += 1
            self._mask[p, vid] = True
        self._dirty[p] = True
        if accumulate:
            self.time[p, vid] += time
        else:
            self.time[p, vid] = time
        self.time_var[p, vid] = time_var
        self.samples[p, vid] = samples
        for name, val in (counters or {}).items():
            cc = self._counter_cols(name)
            s = cc.slot(vid)
            if accumulate:
                cc.values[p, s] += val
            else:
                cc.values[p, s] = val
            cc.mask[p, s] = True

    # -- row-state transfer (the streaming monitor's delta seam) -------
    def extract_rows(self, rows) -> RowBlock:
        """Copy the full state of a row subset into a :class:`RowBlock`.

        The block carries everything those rows hold — time / variance /
        samples / entry mask, plus each counter's columns restricted to
        the ones with data at these rows — so applying it elsewhere
        (:meth:`apply_rows`) reproduces the rows exactly.  This is the
        per-host producer's flush unit: ``extract_rows(dirty_rows())``
        is a sequence-numbered shard delta."""
        rows = np.asarray(rows, np.intp)
        counters: Dict[str, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        for name, cc in self._counters.items():
            vids, values, mask = cc.columns()
            keep = mask[rows].any(axis=0)
            if keep.any():
                counters[name] = (vids[keep].copy(),
                                  values[np.ix_(rows, np.nonzero(keep)[0])],
                                  mask[np.ix_(rows, np.nonzero(keep)[0])])
        return RowBlock(rows=rows.copy(), n_cols=self._cols,
                        time=self.time[rows].copy(),
                        time_var=self.time_var[rows].copy(),
                        samples=self.samples[rows].copy(),
                        mask=self._mask[rows].copy(),
                        counters=counters)

    def apply_rows(self, block: RowBlock,
                   rows: Optional[np.ndarray] = None) -> None:
        """Overwrite a row subset with a :class:`RowBlock`'s state.

        Target ``rows`` default to ``block.rows`` (the aggregator replica
        case: same local indices); pass explicit rows to land the block
        at a different row range (the live-subfleet compaction).  The
        rows' prior state — entries AND counters — is fully replaced, so
        applying the same block twice is idempotent, and applying a
        host's blocks in sequence order leaves the replica bit-identical
        to the source shard.  Applied rows are marked dirty (a device
        view over this store re-uploads them)."""
        rows = block.rows if rows is None else np.asarray(rows, np.intp)
        if rows.size == 0:
            return
        self.ensure_columns(block.n_cols)
        c = block.n_cols
        old = int(np.count_nonzero(self._mask[rows]))
        self._mask[rows] = False
        self._mask[rows, :c] = block.mask
        self._count += int(np.count_nonzero(block.mask)) - old
        self.time[rows] = 0.0
        self.time[rows, :c] = block.time
        self.time_var[rows] = 0.0
        self.time_var[rows, :c] = block.time_var
        self.samples[rows] = 0
        self.samples[rows, :c] = block.samples
        self._dirty[rows] = True
        for cc in self._counters.values():
            k = len(cc.vids)
            cc.values[rows, :k] = 0.0
            cc.mask[rows, :k] = False
        for name, (vids, values, mask) in block.counters.items():
            cc = self._counter_cols(name)
            slots = np.asarray([cc.slot(v) for v in vids.tolist()], np.intp)
            cc.values[np.ix_(rows, slots)] = values
            cc.mask[np.ix_(rows, slots)] = mask

    # -- whole-store state (the ONE persistence seam) ------------------
    TREE_FORMAT = "perfstore"
    TREE_VERSION = 1

    def to_tree(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """(tree, meta): the store's full state as plain numpy arrays.

        ``tree`` is a nested dict (checkpoint-friendly pytree) of
        copies, counters column-sparse; ``meta`` holds the
        JSON-serializable layout (versioned format header, row/column
        counts, counter names by index).  Together they round-trip
        through :meth:`load_tree` / :meth:`from_tree` bit-identically.
        This is the single persistence path: the monitor's crash
        snapshot and the run store both write one ``to_tree()`` per
        store/shard through ``repro.checkpoint.store``.
        """
        names = list(self._counters)
        tree: Dict[str, Any] = {
            "time": self.time.copy(), "time_var": self.time_var.copy(),
            "samples": self.samples.copy(), "mask": self._mask.copy(),
            "counters": {f"c{i}": self._counters[name].to_tree()
                         for i, name in enumerate(names)},
        }
        meta = self._tree_meta()
        meta["counter_names"] = names
        return tree, meta

    def _tree_meta(self) -> Dict[str, Any]:
        return {"format": self.TREE_FORMAT, "version": self.TREE_VERSION,
                "n_procs": int(self.n_procs), "n_cols": int(self._cols)}

    def load_tree(self, tree: Mapping[str, Any],
                  meta: Mapping[str, Any]) -> None:
        """Restore the state captured by :meth:`to_tree` into this store
        (dimensions grow as needed; prior contents are replaced).
        Restored rows are all marked dirty, so a fresh device view
        re-uploads everything on its first refresh."""
        check_tree_format(meta, self.TREE_FORMAT, self.TREE_VERSION)
        time = np.asarray(tree["time"])
        rows, cols = time.shape
        self.ensure_rows(rows)
        self.ensure_columns(cols)
        self.time[:, :] = 0.0
        self.time_var[:, :] = 0.0
        self.samples[:, :] = 0
        self._mask[:, :] = False
        self.time[:rows, :cols] = time
        self.time_var[:rows, :cols] = tree["time_var"]
        self.samples[:rows, :cols] = tree["samples"]
        self._mask[:rows, :cols] = tree["mask"]
        self._count = int(np.count_nonzero(self._mask))
        self._dirty[:] = True
        self._counters = {}
        # a store with zero counters serializes "counters" as an empty
        # dict, which some tree transports drop — counter_names is the
        # authority, so absence is only legal when it says "none"
        blocks = tree.get("counters", {})
        for i, name in enumerate(meta["counter_names"]):
            cc = self._counter_cols(name)
            cc.load_tree(blocks[f"c{i}"])

    @classmethod
    def from_tree(cls, tree: Mapping[str, Any],
                  meta: Mapping[str, Any]) -> "PerfStore":
        store = cls(int(meta["n_procs"]), int(meta["n_cols"]))
        store.load_tree(tree, meta)
        return store

    # -- shard merge (streamed multi-host assembly) --------------------
    def merge_shard(self, shard: "PerfStore") -> None:
        """Merge one per-host shard — a PerfStore whose rows map to global
        processes ``proc_start + local`` (``proc_start`` defaults to 0; see
        :class:`repro.core.shard.PerfShard`).

        When the shard's contiguous row block ``[proc_start, proc_stop)``
        is still untouched in this store (the streamed-assembly common
        case: each host range lands once), the whole block copies in with
        ONE masked assignment per matrix plus one scatter per counter —
        identical entries to the grouped path, without the
        per-(vertex, counter-signature) ``set_entries`` loop.  Overlapping
        or revisited ranges fall back to :meth:`_merge_shard_grouped`, the
        retained per-signature reference, so last-writer-wins semantics
        are unchanged."""
        off = int(getattr(shard, "proc_start", 0))
        self.ensure_rows(off + shard.n_procs)
        self.ensure_columns(shard._cols)
        rows = slice(off, off + shard.n_procs)
        if not self._mask[rows].any():
            self._merge_shard_block(shard, off)
        else:
            self._merge_shard_grouped(shard, off)

    def _merge_shard_block(self, shard: "PerfStore", off: int) -> None:
        """Whole-block masked copy of one shard into untouched target rows
        — bit-identical to the grouped path (same assignments, no
        accumulation is involved because the rows carry no prior entries).
        """
        rows = slice(off, off + shard.n_procs)
        cols = shard._cols
        msk = shard._mask
        np.copyto(self.time[rows, :cols], shard.time, where=msk)
        np.copyto(self.time_var[rows, :cols], shard.time_var, where=msk)
        np.copyto(self.samples[rows, :cols], shard.samples, where=msk)
        self._mask[rows, :cols] |= msk
        self._count += int(np.count_nonzero(msk))
        self._dirty[rows] |= msk.any(axis=1)
        for name, scc in shard._counters.items():
            svids, svals, smask = scc.columns()
            if not svids.size:
                continue
            cc = self._counter_cols(name)
            slots = np.asarray([cc.slot(v) for v in svids.tolist()], np.intp)
            r, c = np.nonzero(smask)
            cc.values[off + r, slots[c]] = svals[r, c]
            cc.mask[off + r, slots[c]] = True

    def _merge_shard_grouped(self, shard: "PerfStore", off: int) -> None:
        """Per-(vertex, counter-signature) shard merge: every written
        entry lands through :meth:`set_entries` — the one write seam — as
        one batched scatter per signature group.  The reference the block
        fast path is tested against, and the fallback for overlapping
        ranges."""
        for vid in np.nonzero(shard._mask.any(axis=0))[0].tolist():
            rows = np.nonzero(shard._mask[:, vid])[0]
            named = [(name, cc, cc.slot_of[vid])
                     for name, cc in shard._counters.items()
                     if vid in cc.slot_of]
            if named:
                # rows sharing a counter signature (which counters are set
                # at this vertex) land in one set_entries call each; within
                # one shard the signature is almost always uniform
                bits = np.stack([cc.mask[rows, s] for _, cc, s in named])
                _, inv = np.unique(bits.T, axis=0, return_inverse=True)
            else:
                bits = np.zeros((0, rows.size), bool)
                inv = np.zeros(rows.size, np.intp)
            for gi in range(int(inv.max()) + 1):
                sel = inv == gi
                r = rows[sel]
                sig = bits[:, sel][:, 0] if named else ()
                counters = {name: cc.values[r, s]
                            for (name, cc, s), on in zip(named, sig) if on}
                self.set_entries(off + r, vid, shard.time[r, vid],
                                 time_var=shard.time_var[r, vid],
                                 samples=shard.samples[r, vid],
                                 counters=counters)

    @classmethod
    def assemble_streamed(cls, shards: Iterable["PerfStore"], *,
                          n_procs: int = 0, n_vertices: int = 0
                          ) -> "PerfStore":
        """Merge an iterable of per-host shards ONE AT A TIME.

        The streamed form of :meth:`from_shards`: shards are consumed from
        the iterator and merged immediately (block concatenation through
        the :meth:`set_entries` seam), so a controller never holds more
        than one shard plus the growing result — no single-controller
        gather of all hosts.  ``n_procs`` / ``n_vertices`` pre-size the
        result when known; otherwise both dimensions grow as host ranges
        stream in."""
        store = PerfStore(n_procs, n_vertices)
        for shard in shards:
            store.merge_shard(shard)
        return store

    @classmethod
    def from_shards(cls, shards: Iterable["PerfStore"], *,
                    n_procs: Optional[int] = None,
                    n_vertices: Optional[int] = None) -> "PerfStore":
        """Assemble one store from per-host shards by block concatenation.

        Shards are PerfStore-like blocks with a ``proc_start`` row offset
        (:class:`repro.core.shard.PerfShard`); ranges may be uneven, may
        carry disjoint counter sets, and may overlap (later shards
        overwrite, exactly like repeated ``set_entries`` calls)."""
        shards = list(shards)
        if n_procs is None:
            n_procs = max((int(getattr(s, "proc_start", 0)) + s.n_procs
                           for s in shards), default=0)
        if n_vertices is None:
            n_vertices = max((s._cols for s in shards), default=0)
        return cls.assemble_streamed(shards, n_procs=n_procs,
                                     n_vertices=n_vertices)

    # -- mapping API (back compat) -------------------------------------
    def __setitem__(self, key: Tuple[int, int], vec: PerfVector) -> None:
        p, vid = key
        self.ensure_columns(vid + 1)
        if not self._mask[p, vid]:
            self._count += 1
        self._mask[p, vid] = True
        self._dirty[p] = True
        self.time[p, vid] = vec.time
        self.time_var[p, vid] = vec.time_var
        self.samples[p, vid] = vec.samples
        # clear stale counters — value AND mask, so counter_matrix (which
        # reads the sparse columns) never sees a leftover from the old entry
        for cc in self._counters.values():
            s = cc.slot_of.get(vid)
            if s is not None:
                cc.mask[p, s] = False
                cc.values[p, s] = 0.0
        for name, val in vec.counters.items():
            cc = self._counter_cols(name)
            s = cc.slot(vid)
            cc.values[p, s] = val
            cc.mask[p, s] = True

    def __getitem__(self, key: Tuple[int, int]) -> PerfVector:
        p, vid = key
        if vid >= self._cols or not self._mask[p, vid]:
            raise KeyError(key)
        counters = {}
        for name, cc in self._counters.items():
            s = cc.slot_of.get(vid)
            if s is not None and cc.mask[p, s]:
                counters[name] = float(cc.values[p, s])
        return PerfVector(time=float(self.time[p, vid]),
                          time_var=float(self.time_var[p, vid]),
                          samples=int(self.samples[p, vid]),
                          counters=counters)

    def get(self, key: Tuple[int, int],
            default: Optional[PerfVector] = None) -> Optional[PerfVector]:
        try:
            return self[key]
        except (KeyError, IndexError):
            return default

    def __contains__(self, key: Tuple[int, int]) -> bool:
        p, vid = key
        return vid < self._cols and bool(self._mask[p, vid])

    def __len__(self) -> int:
        return self._count

    def keys(self) -> Iterator[Tuple[int, int]]:
        for p, vid in np.argwhere(self._mask):
            yield (int(p), int(vid))

    __iter__ = keys

    def values(self) -> Iterator[PerfVector]:
        for key in self.keys():
            yield self[key]

    def items(self) -> Iterator[Tuple[Tuple[int, int], PerfVector]]:
        for key in self.keys():
            yield key, self[key]

    def counter_nbytes(self) -> int:
        """Sparse counter storage (used columns only)."""
        return sum(cc.nbytes() for cc in self._counters.values())

    def counter_dense_nbytes(self) -> int:
        """What the counters would cost as dense (n_procs, V) matrices —
        the pre-sparsification layout, for storage-win reporting."""
        per = self.n_procs * self._cols * 9        # f64 value + bool mask
        return per * len(self._counters)

    def nbytes(self) -> int:
        base = (self.time.nbytes + self.time_var.nbytes + self.samples.nbytes
                + self._mask.nbytes)
        return base + self.counter_nbytes()


class CommIndex:
    """Inter-process communication dependence, stored O(P) per collective.

    p2p edges are explicit ((proc, vid) -> (proc, vid)) with a reverse
    index; collectives are participant *groups* per vertex, from which
    clique edges are resolved lazily.  Provides the old ``comm_edges`` set
    API (membership / len / iteration) without materializing cliques.
    """

    __slots__ = ("_p2p", "_p2p_preds", "_groups", "_group_sets",
                 "_p2p_batches")

    def __init__(self):
        self._p2p: Set[Tuple[Tuple[int, int], Tuple[int, int]]] = set()
        self._p2p_preds: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
        self._groups: Dict[int, List[Tuple[int, ...]]] = {}
        self._group_sets: Dict[int, List[frozenset]] = {}
        # bulk-registered (vid, src_procs, dst_procs) edge blocks, folded
        # into the explicit set/preds indexes lazily on first query — PPG
        # assembly over an 8k-pair halo ring costs one array append, not
        # 8k Python set inserts
        self._p2p_batches: List[Tuple[int, np.ndarray, np.ndarray]] = []

    # -- construction --------------------------------------------------
    def add_p2p(self, src: Tuple[int, int], dst: Tuple[int, int]) -> None:
        edge = (src, dst)
        if edge in self._p2p:
            return
        self._p2p.add(edge)
        self._p2p_preds.setdefault(dst, []).append(src)

    def add_p2p_batch(self, vid: int, src_procs, dst_procs) -> None:
        """Register p2p edges ``(src, vid) -> (dst, vid)`` in bulk, O(1)
        until first queried (then folded in registration order, with the
        same dedup as repeated :meth:`add_p2p` calls)."""
        src = np.asarray(src_procs, np.intp)
        dst = np.asarray(dst_procs, np.intp)
        if src.size:
            self._p2p_batches.append((int(vid), src, dst))

    def _materialize_p2p(self) -> None:
        if not self._p2p_batches:
            return
        batches, self._p2p_batches = self._p2p_batches, []
        for vid, src, dst in batches:
            for s, d in zip(src.tolist(), dst.tolist()):
                self.add_p2p((s, vid), (d, vid))

    def add_group(self, vid: int, procs: Sequence[int]) -> None:
        group = tuple(procs)
        if len(group) < 2:
            return
        gs = frozenset(group)
        if any(gs == s for s in self._group_sets.get(vid, ())):
            return
        self._groups.setdefault(vid, []).append(group)
        self._group_sets.setdefault(vid, []).append(gs)

    # -- queries -------------------------------------------------------
    def groups_of(self, vid: int) -> List[Tuple[int, ...]]:
        return list(self._groups.get(vid, ()))

    def group_of(self, proc: int, vid: int) -> Optional[Tuple[int, ...]]:
        """The participant group containing ``proc`` at ``vid`` (if any)."""
        for group, gs in zip(self._groups.get(vid, ()),
                             self._group_sets.get(vid, ())):
            if proc in gs:
                return group
        return None

    def partners(self, proc: int, vid: int) -> List[Tuple[int, int]]:
        """Reverse-edge sources of (proc, vid): p2p preds + peers from
        EVERY group containing proc (deduplicated, like the old edge set —
        a vertex can carry several groups, e.g. staged collectives)."""
        self._materialize_p2p()
        out = list(self._p2p_preds.get((proc, vid), ()))
        seen = set(out)
        for group, gs in zip(self._groups.get(vid, ()),
                             self._group_sets.get(vid, ())):
            if proc not in gs:
                continue
            for q in group:
                if q != proc and (q, vid) not in seen:
                    seen.add((q, vid))
                    out.append((q, vid))
        return out

    def p2p_preds_of(self, dst: Tuple[int, int]) -> List[Tuple[int, int]]:
        """Explicit p2p reverse-edge sources of ``dst`` in registration
        order (the internal list — treat as read-only).  The batched
        backtracker's per-node gather; ``partners`` additionally resolves
        collective group peers."""
        self._materialize_p2p()
        return self._p2p_preds.get(dst, [])

    def has_groups(self, vid: int) -> bool:
        return bool(self._groups.get(vid))

    def p2p_edges(self) -> Set[Tuple[Tuple[int, int], Tuple[int, int]]]:
        self._materialize_p2p()
        return self._p2p

    # -- set-compatible view -------------------------------------------
    def __contains__(self, edge) -> bool:
        try:
            (sp, sv), (dp, dv) = edge
        except (TypeError, ValueError):
            return False
        self._materialize_p2p()
        if (tuple(edge[0]), tuple(edge[1])) in self._p2p:
            return True
        if sv != dv or sp == dp:
            return False
        for gs in self._group_sets.get(dv, ()):
            if sp in gs and dp in gs:
                return True
        return False

    def __len__(self) -> int:
        self._materialize_p2p()
        n = len(self._p2p)
        for groups in self._groups.values():
            n += sum(len(g) * (len(g) - 1) for g in groups)
        return n

    def __iter__(self):
        """Lazily generated edges — O(P²) to exhaust for a clique; use
        ``partners``/``groups_of`` in hot paths."""
        self._materialize_p2p()
        yield from self._p2p
        for vid, groups in self._groups.items():
            for g in groups:
                for i in g:
                    for j in g:
                        if i != j:
                            yield ((i, vid), (j, vid))

    def nbytes(self) -> int:
        """O(P) comm-dependence storage: 16B per explicit p2p edge + 8B per
        collective participant (vs 16B x |g|² for a materialized clique)."""
        self._materialize_p2p()
        n = 16 * len(self._p2p)
        for groups in self._groups.values():
            n += sum(8 * len(g) for g in groups)
        return n

    # -- checkpoint-tree seam ------------------------------------------
    def to_tree(self) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        """(tree, meta): the O(P) comm index as flat int64 arrays.

        p2p edges become one (n, 4) ``[sp, sv, dp, dv]`` block (sorted,
        so the tree is a canonical form of the edge SET); collective
        groups become a ragged (vid, size, flat-members) triple in
        per-vid registration order.  Cliques are never materialized.
        """
        self._materialize_p2p()
        p2p = np.asarray(
            [[sp, sv, dp, dv] for (sp, sv), (dp, dv) in sorted(self._p2p)],
            np.int64).reshape(-1, 4)
        vids: List[int] = []
        sizes: List[int] = []
        members: List[int] = []
        for vid in sorted(self._groups):
            for group in self._groups[vid]:
                vids.append(vid)
                sizes.append(len(group))
                members.extend(group)
        tree = {"p2p": p2p,
                "group_vids": np.asarray(vids, np.int64),
                "group_sizes": np.asarray(sizes, np.int64),
                "group_members": np.asarray(members, np.int64)}
        return tree, {"format": "commindex", "version": 1}

    @classmethod
    def from_tree(cls, tree: Mapping[str, Any],
                  meta: Optional[Mapping[str, Any]] = None) -> "CommIndex":
        check_tree_format(meta, "commindex", 1)
        ci = cls()
        p2p = np.asarray(tree["p2p"], np.int64).reshape(-1, 4)
        for sp, sv, dp, dv in p2p.tolist():
            # rows are pre-deduplicated (serialized from a set), so the
            # add_p2p membership probe is skipped
            edge = ((sp, sv), (dp, dv))
            ci._p2p.add(edge)
            ci._p2p_preds.setdefault(edge[1], []).append(edge[0])
        vids = np.asarray(tree["group_vids"], np.int64).tolist()
        sizes = np.asarray(tree["group_sizes"], np.int64).tolist()
        members = np.asarray(tree["group_members"], np.int64).tolist()
        off = 0
        for vid, size in zip(vids, sizes):
            ci.add_group(vid, members[off:off + size])
            off += size
        return ci


class PPG:
    """Program performance graph: the PSG replicated across ``n_procs``
    SPMD processes + inter-process communication dependence + perf data.

    PPG vertex id = (proc, vid).  Perf data lives in a :class:`PerfStore`
    (dense time/var/sample matrices, column-sparse counters); collective
    comm dependence is implicit (participant groups in a
    :class:`CommIndex`), p2p edges explicit.
    """

    def __init__(self, psg: PSG, n_procs: int,
                 perf: Optional[PerfStore] = None,
                 meta: Optional[Dict[str, Any]] = None):
        self.psg = psg
        self.n_procs = int(n_procs)
        self.perf = perf if perf is not None else \
            PerfStore(n_procs, len(psg.vertices))
        self.comm = CommIndex()
        self.meta: Dict[str, Any] = dict(meta or {})
        self._device_view = None

    # -- perf ----------------------------------------------------------
    def set_perf(self, proc: int, vid: int, vec: PerfVector) -> None:
        self.perf[(proc, vid)] = vec

    def get_time(self, proc: int, vid: int) -> float:
        return self.perf.time_at(proc, vid)

    def times_across_procs(self, vid: int) -> List[float]:
        return self.perf.time_column(vid).tolist()

    def times_matrix(self) -> np.ndarray:
        """(n_procs, n_vertices) time matrix — the detectors' input.  For a
        sharded perf store this is the stacked shard view (per-host blocks
        concatenated, never scattered through a merged store)."""
        return self.perf.time_matrix(len(self.psg.vertices))

    def var_matrix(self) -> np.ndarray:
        """(n_procs, n_vertices) time-variance matrix (zero where unset) —
        input to the variance-weighted ("var") merge strategy."""
        return self.perf.var_matrix(len(self.psg.vertices))

    def device_view(self):
        """This PPG's cached :class:`~repro.core.shard.DeviceShardView` —
        the perf store's per-host blocks pinned as jax device buffers with
        dirty-row incremental upload.  Created lazily (jax imports happen
        on first refresh, never here); the jitted detectors feed from it
        so a ShardedStore-backed PPG never materializes the stacked
        (P, V) host matrix."""
        if self._device_view is None:
            from repro.core.shard import DeviceShardView
            self._device_view = DeviceShardView(self.perf)
        return self._device_view

    def counter_matrix(self, name: str) -> np.ndarray:
        return self.perf.counter_matrix(name, len(self.psg.vertices))

    # -- comm dependence ------------------------------------------------
    @property
    def comm_edges(self) -> CommIndex:
        """Set-like view of all comm edges (cliques resolved lazily)."""
        return self.comm

    def add_collective_edges(self, vid: int,
                             procs: Optional[Sequence[int]] = None) -> None:
        """Register the participant group (implicit clique, O(|group|))."""
        procs = range(self.n_procs) if procs is None else procs
        self.comm.add_group(vid, list(procs))

    def add_p2p_edge(self, src_proc: int, src_vid: int,
                     dst_proc: int, dst_vid: int) -> None:
        self.comm.add_p2p((src_proc, src_vid), (dst_proc, dst_vid))

    def comm_partners(self, proc: int, vid: int) -> List[Tuple[int, int]]:
        """Processes/vertices this (proc, vid) depends on (reverse edges)."""
        return self.comm.partners(proc, vid)

    def nbytes(self) -> int:
        return self.psg.nbytes() + self.perf.nbytes() + self.comm.nbytes()

    # -- checkpoint-tree seam ------------------------------------------
    def to_tree(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """(tree, meta): PSG + perf store + comm index as one pytree.

        The perf component serializes through whatever store backs this
        PPG — a plain :class:`PerfStore` or a
        :class:`~repro.core.shard.ShardedStore` (its meta ``format``
        records which, and :meth:`from_tree` rebuilds the same kind).
        """
        psg_tree, psg_meta = self.psg.to_tree()
        perf_tree, perf_meta = self.perf.to_tree()
        comm_tree, comm_meta = self.comm.to_tree()
        tree = {"psg": psg_tree, "perf": perf_tree, "comm": comm_tree}
        meta = {"format": "ppg", "version": 1,
                "n_procs": int(self.n_procs),
                "psg": psg_meta, "perf": perf_meta, "comm": comm_meta}
        return tree, meta

    @classmethod
    def from_tree(cls, tree: Mapping[str, Any],
                  meta: Mapping[str, Any]) -> "PPG":
        check_tree_format(meta, "ppg", 1)
        psg = PSG.from_tree(tree["psg"], meta.get("psg"))
        perf_meta = meta["perf"]
        if perf_meta.get("format") == "shardedstore":
            from repro.core.shard import ShardedStore
            perf = ShardedStore.from_tree(tree["perf"], perf_meta)
        else:
            perf = PerfStore.from_tree(tree["perf"], perf_meta)
        ppg = cls(psg, int(meta["n_procs"]), perf)
        ppg.comm = CommIndex.from_tree(tree["comm"], meta.get("comm"))
        return ppg
