"""Program Structure Graph (PSG) and Program Performance Graph (PPG).

Vertex kinds follow the paper (§III-A): Loop, Branch, Call, Comp, plus Comm
(the MPI-vertex analogue: XLA/JAX collectives).  Edges carry a dependence
kind: 'data' (sequential data flow), 'control' (enclosing control structure)
and — on the PPG — 'comm' (inter-process communication dependence).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

LOOP = "Loop"
BRANCH = "Branch"
CALL = "Call"
COMP = "Comp"
COMM = "Comm"
ROOT = "Root"

KINDS = (LOOP, BRANCH, CALL, COMP, COMM, ROOT)

# collective primitives / HLO ops treated as Comm vertices
COLLECTIVE_PRIMS = {
    "psum", "pmax", "pmin", "all_gather", "all_gather_invariant",
    "reduce_scatter", "all_to_all", "ppermute", "psum_scatter",
}
P2P_PRIMS = {"ppermute"}     # point-to-point-like (explicit src->dst pairs)


@dataclass
class Vertex:
    vid: int
    kind: str
    name: str                         # primitive / structure name
    source: str = ""                  # "file.py:123" best user frame
    parent: int = -1                  # enclosing Loop/Branch/Call vid
    depth: int = 0                    # control-nest depth
    prims: List[str] = field(default_factory=list)
    # static "hardware counters" (PAPI analogue), per single execution:
    flops: float = 0.0
    bytes: float = 0.0
    comm_bytes: float = 0.0
    comm_kind: str = ""               # all_reduce | all_gather | ...
    p2p_pairs: List[Tuple[int, int]] = field(default_factory=list)
    meta: Dict[str, Any] = field(default_factory=dict)

    @property
    def is_comm(self) -> bool:
        return self.kind == COMM

    @property
    def is_control(self) -> bool:
        return self.kind in (LOOP, BRANCH, CALL)


@dataclass
class PSG:
    """Per-process program structure graph.

    ``order`` is program (execution) order of vertex ids.  Data-dependence
    edges are implied by consecutive order within the same parent; control
    edges connect a control vertex to its children.  Both are materialized
    in ``edges`` for analysis/serialization.
    """
    vertices: List[Vertex] = field(default_factory=list)
    edges: Set[Tuple[int, int, str]] = field(default_factory=set)  # (src,dst,kind)
    root: int = 0

    # ------------------------------------------------------------------
    def new_vertex(self, kind: str, name: str, *, source: str = "",
                   parent: int = -1, depth: int = 0, **meta) -> Vertex:
        v = Vertex(vid=len(self.vertices), kind=kind, name=name, source=source,
                   parent=parent, depth=depth)
        for k, val in meta.items():
            setattr(v, k, val) if hasattr(v, k) else v.meta.__setitem__(k, val)
        self.vertices.append(v)
        return v

    def add_edge(self, src: int, dst: int, kind: str = "data") -> None:
        if src != dst:
            self.edges.add((src, dst, kind))

    def children(self, vid: int) -> List[int]:
        return [v.vid for v in self.vertices if v.parent == vid]

    def preds(self, vid: int, kind: Optional[str] = None) -> List[int]:
        return [s for (s, d, k) in self.edges
                if d == vid and (kind is None or k == kind)]

    def succs(self, vid: int, kind: Optional[str] = None) -> List[int]:
        return [d for (s, d, k) in self.edges
                if s == vid and (kind is None or k == kind)]

    def by_kind(self, kind: str) -> List[Vertex]:
        return [v for v in self.vertices if v.kind == kind]

    def stats(self) -> Dict[str, int]:
        out = {k: 0 for k in KINDS}
        for v in self.vertices:
            out[v.kind] += 1
        out["total"] = len(self.vertices)
        return out

    # ------------------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "vertices": [dataclasses.asdict(v) for v in self.vertices],
            "edges": sorted(self.edges),
            "root": self.root,
        })

    @classmethod
    def from_json(cls, text: str) -> "PSG":
        raw = json.loads(text)
        g = cls(root=raw["root"])
        for d in raw["vertices"]:
            d["p2p_pairs"] = [tuple(p) for p in d.get("p2p_pairs", [])]
            g.vertices.append(Vertex(**d))
        g.edges = {(s, d, k) for s, d, k in raw["edges"]}
        return g

    def nbytes(self) -> int:
        """Serialized storage footprint (paper Table I 'storage cost')."""
        return len(self.to_json().encode())


# ---------------------------------------------------------------------------
# PPG
# ---------------------------------------------------------------------------

@dataclass
class PerfVector:
    """Per-(process, vertex) performance vector (paper §III-B1)."""
    time: float = 0.0                 # seconds (mean over samples)
    time_var: float = 0.0
    samples: int = 0
    counters: Dict[str, float] = field(default_factory=dict)  # PAPI analogue


@dataclass
class PPG:
    """Program performance graph: the PSG replicated across ``n_procs``
    SPMD processes + inter-process communication dependence + perf data.

    PPG vertex id = (proc, vid).  Comm edges: for collectives an edge set
    over all participants; for p2p explicit (src_proc, dst_proc) pairs.
    """
    psg: PSG
    n_procs: int
    perf: Dict[Tuple[int, int], PerfVector] = field(default_factory=dict)
    comm_edges: Set[Tuple[Tuple[int, int], Tuple[int, int]]] = \
        field(default_factory=set)    # ((proc,vid) -> (proc,vid))
    meta: Dict[str, Any] = field(default_factory=dict)

    def set_perf(self, proc: int, vid: int, vec: PerfVector) -> None:
        self.perf[(proc, vid)] = vec

    def get_time(self, proc: int, vid: int) -> float:
        v = self.perf.get((proc, vid))
        return v.time if v else 0.0

    def times_across_procs(self, vid: int) -> List[float]:
        return [self.get_time(p, vid) for p in range(self.n_procs)]

    def add_collective_edges(self, vid: int,
                             procs: Optional[Sequence[int]] = None) -> None:
        """Clique edges among participants (collective comm dependence)."""
        procs = range(self.n_procs) if procs is None else procs
        procs = list(procs)
        for i in procs:
            for j in procs:
                if i != j:
                    self.comm_edges.add(((i, vid), (j, vid)))

    def add_p2p_edge(self, src_proc: int, src_vid: int,
                     dst_proc: int, dst_vid: int) -> None:
        self.comm_edges.add(((src_proc, src_vid), (dst_proc, dst_vid)))

    def comm_partners(self, proc: int, vid: int) -> List[Tuple[int, int]]:
        """Processes/vertices this (proc, vid) depends on (reverse edges)."""
        return [src for (src, dst) in self.comm_edges
                if dst == (proc, vid)]

    def nbytes(self) -> int:
        per_vec = 8 * (3 + 2 * max((len(v.counters) for v in
                                    self.perf.values()), default=0))
        return (self.psg.nbytes() + len(self.perf) * per_vec
                + 16 * len(self.comm_edges))
