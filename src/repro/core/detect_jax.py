"""Jitted detection kernels — the on-accelerator half of ``detect``.

The detection math (cross-process merges, log-log slope fits, abnormality
thresholding) is pure array arithmetic over :class:`PerfStore` matrices, so
it can run under ``jax.jit`` next to the training job instead of on the
host.  Three kernels cover the pipeline:

* ``_merge_all_kernel`` — ALL jittable merge strategies ("mean" / "max" /
  "p0" / variance-weighted "var") batched into one stacked (S, P, V)
  computation: one fused executable produces the (4, S, V) merged-time
  stack, so switching strategies costs an index, not a recompile.
* ``_non_scalable_kernel`` — the merge stack + batched least-squares
  log-log slopes + share/deviation flagging, fused under one ``jax.jit``.
* ``_abnormal_kernel`` — AbnormThd thresholding against the cross-process
  median (the median itself — an order statistic — is computed on the
  host, where numpy's introselect beats XLA's CPU sort).

All kernels run in float64 (``jax.experimental.enable_x64`` — thread-local,
so the rest of the process keeps jax's float32 default) and match the
numpy reference in ``repro.core.detect`` to reduction-order rounding
(~1e-15 relative).  Setting ``SCALANA_DETECT_F32=1`` switches the kernels
to float32 (no x64 context; the jit cache traces a separate f32 variant) —
the accelerator-native precision, parity-tested against the f64 numpy
reference to ~1e-4.  "median" and "cluster" merges are per-column sorts
with data-dependent cuts; they stay on the numpy path.

This module imports jax at module level and is therefore ONLY imported by
``detect``'s backend resolution — never from the lazy ``repro.core``
namespace — so the analysis layer stays importable without jax.
"""
from __future__ import annotations

import contextlib
import os
from functools import partial
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.detect import JIT_STRATEGIES, VAR_EPS

try:
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    HAS_JAX = True
except ImportError:                                # pragma: no cover
    HAS_JAX = False


if HAS_JAX:

    def _merge_all(t: "jax.Array", var: "jax.Array") -> "jax.Array":
        """(S, P, V) times + variances -> (4, S, V) merged, rows ordered as
        JIT_STRATEGIES.  Non-positive readings are dead (excluded)."""
        pos = t > 0.0
        cnt = pos.sum(axis=1)                              # (S, V)
        any_pos = cnt > 0
        total = jnp.where(pos, t, 0.0).sum(axis=1)
        mean = jnp.where(any_pos, total / jnp.maximum(cnt, 1), 0.0)
        mx = jnp.where(any_pos, t.max(axis=1), 0.0)
        p0 = t[:, 0, :]
        p0 = jnp.where(p0 > 0.0, p0, mean)
        w = jnp.where(pos, 1.0 / (var + VAR_EPS), 0.0)
        wsum = w.sum(axis=1)
        varm = jnp.where(wsum > 0,
                         (w * t).sum(axis=1) / jnp.where(wsum > 0, wsum, 1.0),
                         0.0)
        return jnp.stack([mean, mx, p0, varm])             # (4, S, V)

    @jax.jit
    def _merge_all_kernel(t, var):
        return _merge_all(t, var)

    @jax.jit
    def _non_scalable_kernel(t, var, logp, present, total_max,
                             ideal_slope, slope_margin, min_share):
        """Fused detect math: merge stack + slope fit + flagging.

        t, var: (S, P, V) stacked per-scale matrices (P padded to the max
        scale; padding rows are dead readings).  logp: (S,) log process
        counts.  present: (S, V) vertex-exists-at-scale mask.  Returns
        (M_all (4, S, V), slope (4, V), share (4, V), flagged (4, V))."""
        M = _merge_all(t, var)                             # (4, S, V)
        valid = (M > 0.0) & present[None]
        x = logp[None, :, None]                            # (1, S, 1)
        Y = jnp.where(valid, jnp.log(jnp.where(valid, M, 1.0)), 0.0)
        n = valid.sum(axis=1)                              # (4, V)
        Sx = (x * valid).sum(axis=1)
        Sy = Y.sum(axis=1)
        Sxx = (x * x * valid).sum(axis=1)
        Sxy = (x * Y).sum(axis=1)
        denom = n * Sxx - Sx ** 2
        num = n * Sxy - Sx * Sy
        slope = jnp.where((denom != 0) & (n >= 2),
                          num / jnp.where(denom != 0, denom, 1.0), 0.0)
        share = M[:, -1, :] / total_max
        flagged = ((M.sum(axis=1) > 0.0)
                   & (slope - ideal_slope > slope_margin)
                   & (share >= min_share))
        return M, slope, share, flagged

    def _abnormal_flags(t, typical, abnorm_thd, min_share, step_time):
        """(P, V) times + (V,) typical -> (P, V) flag mask.

        ``typical`` (the cross-process median) is computed on the host:
        it is an order statistic, and XLA's column sort is the one piece
        of the detection math that is slower under jit on CPU than the
        numpy introselect."""
        active = t.max(axis=0) > 0.0
        over = ((typical > 0.0) & (t > abnorm_thd * typical)
                & ((t - typical) / step_time >= min_share))
        dead_typical = (typical == 0.0) & (t / step_time >= min_share)
        return (over | dead_typical) & active

    @jax.jit
    def _abnormal_kernel(t, typical, abnorm_thd, min_share, step_time):
        return _abnormal_flags(t, typical, abnorm_thd, min_share, step_time)

    @partial(jax.jit, static_argnums=(5,))
    def _abnormal_topk_kernel(t, typical, abnorm_thd, min_share, step_time,
                              k):
        """Fused flags + device-side top-k selection.

        The (P, V) flag matrix and the excess-over-typical scores never
        leave the device: flagged entries are ranked by a stable
        descending argsort over the vid-major flattening (matching the
        numpy path's ``argwhere(flags.T)`` enumeration plus stable sort,
        so ties rank identically) and only the best ``k`` flat indices,
        their scores, and the flagged count are transferred."""
        flags = _abnormal_flags(t, typical, abnorm_thd, min_share, step_time)
        score = jnp.where(flags, t - typical, -jnp.inf)
        flat = score.T.reshape(-1)                    # vid-major
        order = jnp.argsort(-flat, stable=True)[:k]
        return order, flat[order], flags.sum()


def _precision():
    """(dtype, x64-context) for the kernel wrappers.

    float64 under a thread-local ``enable_x64`` by default; float32 with
    no x64 context when ``SCALANA_DETECT_F32`` is set (truthy) — the
    accelerator-native variant (checked per call so tests can toggle)."""
    if os.environ.get("SCALANA_DETECT_F32", "").lower() in (
            "1", "true", "on", "yes"):
        return np.float32, contextlib.nullcontext()
    return np.float64, enable_x64()


def merge_matrix(t: np.ndarray, strategy: str,
                 var: Optional[np.ndarray] = None) -> np.ndarray:
    """Jitted columnwise merge over one (n_procs, V) matrix -> (V,).

    All strategies are computed in one stacked kernel call; ``strategy``
    only selects the output row.  Reference-parity entry point for tests
    and small hosts; detection uses the fused kernels directly."""
    si = JIT_STRATEGIES.index(strategy)
    dtype, ctx = _precision()
    with ctx:
        td = jnp.asarray(np.asarray(t, dtype)[None])
        vd = jnp.asarray(np.zeros_like(t, dtype)[None] if var is None
                         else np.asarray(var, dtype)[None])
        out = _merge_all_kernel(td, vd)
    return np.asarray(out)[si, 0]


def non_scalable_arrays(scales: Sequence[int], t: np.ndarray, var: np.ndarray,
                        present: np.ndarray, total_max: float,
                        ideal_slope: float, slope_margin: float,
                        min_share: float, strategy: str
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray]:
    """Run the fused non-scalable kernel; returns the ``strategy`` row of
    (M (S, V), slope (V,), share (V,), flagged (V,))."""
    si = JIT_STRATEGIES.index(strategy)
    dtype, ctx = _precision()
    logp = np.log(np.asarray(scales, dtype))
    with ctx:
        M, slope, share, flagged = _non_scalable_kernel(
            jnp.asarray(np.asarray(t, dtype)),
            jnp.asarray(np.asarray(var, dtype)),
            jnp.asarray(logp), jnp.asarray(present),
            float(total_max), float(ideal_slope), float(slope_margin),
            float(min_share))
    return (np.asarray(M)[si], np.asarray(slope)[si],
            np.asarray(share)[si], np.asarray(flagged)[si])


def abnormal_arrays(t: np.ndarray, abnorm_thd: float, min_share: float,
                    step_time: float) -> Tuple[np.ndarray, np.ndarray]:
    """Run the abnormal kernel; returns ((P, V) flags, (V,) typical).

    Materializes the full flag matrix on the host — parity/test entry
    point; detection itself uses :func:`abnormal_topk`, which keeps the
    flags device-resident."""
    dtype, ctx = _precision()
    typical = np.median(np.asarray(t, dtype), axis=0)
    with ctx:
        flags = _abnormal_kernel(
            jnp.asarray(np.asarray(t, dtype)), jnp.asarray(typical),
            float(abnorm_thd), float(min_share), float(step_time))
    return np.asarray(flags), typical


def abnormal_topk(t: np.ndarray, abnorm_thd: float, min_share: float,
                  step_time: float, k: int
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Device-resident abnormal detection: only the winners come home.

    The (P, V) flag matrix and the ranking scores stay on the device
    until report time; the host receives the (vid, proc) indices of the
    ``<= k`` highest-scoring flagged entries (ranked exactly like the
    numpy reference: descending ``time - typical``, ties in vid-major
    enumeration order) plus the total flagged count.  Returns
    ``(vids, procs, typical, n_flagged)``."""
    dtype, ctx = _precision()
    t_host = np.asarray(t, dtype)
    typical = np.median(t_host, axis=0)
    with ctx:
        order, _, count = _abnormal_topk_kernel(
            jnp.asarray(t_host), jnp.asarray(typical),
            float(abnorm_thd), float(min_share), float(step_time), int(k))
        n_flagged = int(count)                 # report time: flags leave
        order = np.asarray(order[:min(int(k), n_flagged)])  # the device
    n_procs = t_host.shape[0]
    return order // n_procs, order % n_procs, typical, n_flagged
