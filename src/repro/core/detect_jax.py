"""Jitted detection kernels — the on-accelerator half of ``detect``.

The detection math (cross-process merges, log-log slope fits, abnormality
thresholding) is pure array arithmetic over :class:`PerfStore` matrices, so
it can run under ``jax.jit`` next to the training job instead of on the
host.  Three kernels cover the pipeline:

* ``_merge_all_kernel`` — ALL jittable merge strategies ("mean" / "max" /
  "p0" / variance-weighted "var") batched into one stacked (S, P, V)
  computation: one fused executable produces the (4, S, V) merged-time
  stack, so switching strategies costs an index, not a recompile.
* ``_non_scalable_kernel`` — the merge stack + batched least-squares
  log-log slopes + share/deviation flagging, fused under one ``jax.jit``.
* ``_abnormal_topk_kernel`` — cross-process median (``jnp.median``,
  bit-identical to numpy's in f64) + AbnormThd thresholding + stable
  top-k, all device-side; only the winners and the (V,) typical
  transfer.  (``_abnormal_kernel`` keeps the host-median parity entry.)

A second kernel family consumes per-host DEVICE blocks instead of one
host-stacked matrix (:class:`~repro.core.shard.DeviceShardView` inputs —
the online path, where only dirty rows re-upload per call):
``_merge_blocks_kernel`` computes each scale's merge column as
block-level reductions, ``_slope_flag_from_M_kernel`` derives the total
step time from the merged stack itself, and
``_abnormal_topk_blocks_kernel`` concatenates blocks on the device.
``non_scalable_views`` / ``abnormal_topk_view`` are their entry points;
the stacked (P, V) matrix exists on neither host nor wire.

Since the fused-detection PR, every entry point dispatches to the
one-launch fused ops in ``repro.kernels.detect_fused`` by default
(Pallas on TPU; a fused-jnp fast path elsewhere — integer-key sort
median + tournament top-k, which is what fixed the ~10-dispatch CPU
overhead), with device-cached historical merge columns making
steady-state ``non_scalable_views`` O(live scale).  The kernels above
are retained verbatim as the unfused baseline: the parity suite pins
``fused == legacy == numpy``, the view entry points accept
``fused=False``, and the bench still times the legacy chain.

All kernels run in float64 (``jax.experimental.enable_x64`` — thread-local,
so the rest of the process keeps jax's float32 default) and match the
numpy reference in ``repro.core.detect`` to reduction-order rounding
(~1e-15 relative).  Setting ``SCALANA_DETECT_F32=1`` switches the kernels
to float32 (no x64 context; the jit cache traces a separate f32 variant) —
the accelerator-native precision, parity-tested against the f64 numpy
reference to ~1e-4.  "median" and "cluster" merges are per-column sorts
with data-dependent cuts; they stay on the numpy path.

This module imports jax at module level and is therefore ONLY imported by
``detect``'s backend resolution — never from the lazy ``repro.core``
namespace — so the analysis layer stays importable without jax.
"""
from __future__ import annotations

import contextlib
import os
from functools import partial
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.detect import JIT_STRATEGIES, VAR_EPS

try:
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    HAS_JAX = True
except ImportError:                                # pragma: no cover
    HAS_JAX = False


if HAS_JAX:
    # The pure merge/slope/flag formulas moved to
    # ``repro.kernels.detect_fused.kernel`` — single source of truth
    # shared by these legacy kernels (kept for parity tests and as the
    # unfused baseline) and the fused one-launch paths the entry points
    # now dispatch to.
    from repro.kernels.detect_fused import ops as _fused
    from repro.kernels.detect_fused.kernel import (
        abnormal_flags as _abnormal_flags,
        merge_all_stack as _merge_all,
        merge_blocks as _merge_blocks,
        slope_share_flag as _slope_share_flag)

    @jax.jit
    def _merge_all_kernel(t, var):
        return _merge_all(t, var)

    @jax.jit
    def _non_scalable_kernel(t, var, logp, present, total_max,
                             ideal_slope, slope_margin, min_share):
        """Fused detect math: merge stack + slope fit + flagging.

        t, var: (S, P, V) stacked per-scale matrices (P padded to the max
        scale; padding rows are dead readings).  logp: (S,) log process
        counts.  present: (S, V) vertex-exists-at-scale mask.  Returns
        (M_all (4, S, V), slope (4, V), share (4, V), flagged (4, V))."""
        M = _merge_all(t, var)                             # (4, S, V)
        slope, share, flagged = _slope_share_flag(
            M, logp, present, total_max, ideal_slope, slope_margin,
            min_share)
        return M, slope, share, flagged

    # -- device-block kernels (DeviceShardView inputs) ------------------
    # One scale's per-host blocks -> its (4, V) merged column, as
    # associative block-level reductions (row order = global proc order;
    # the stacked host matrix never exists on either side).
    _merge_blocks_kernel = jax.jit(_merge_blocks)

    @jax.jit
    def _slope_flag_from_M_kernel(M, logp, present, top_idx,
                                  ideal_slope, slope_margin, min_share):
        """Slope/share/flag over a device-merged (4, S, V) stack.

        The reference scale's total step time is the "max"-merge row at
        the last scale summed over the root's children — exactly the
        host's per-column ``max(initial=0.0)`` sum, since the merge
        already clamps all-dead columns to 0 — so no extra reduction
        over the raw blocks is needed."""
        total_max = M[JIT_STRATEGIES.index("max"), -1, top_idx].sum()
        return _slope_share_flag(M, logp, present, total_max,
                                 ideal_slope, slope_margin, min_share)

    @jax.jit
    def _abnormal_kernel(t, typical, abnorm_thd, min_share, step_time):
        return _abnormal_flags(t, typical, abnorm_thd, min_share, step_time)

    @jax.jit
    def _fit_slopes_kernel(logp, M, valid):
        """Batched masked least-squares slope per column — the jitted
        twin of ``detect._fit_slopes`` (same formulas, same <2-point
        clamp to 0.0)."""
        x = logp[:, None]                              # (S, 1)
        Y = jnp.where(valid, jnp.log(jnp.where(valid, M, 1.0)), 0.0)
        n = valid.sum(axis=0)
        Sx = (x * valid).sum(axis=0)
        Sy = Y.sum(axis=0)
        Sxx = (x * x * valid).sum(axis=0)
        Sxy = (x * Y).sum(axis=0)
        denom = n * Sxx - Sx ** 2
        num = n * Sxy - Sx * Sy
        safe = jnp.where(denom != 0, denom, 1.0)
        slope = jnp.where(denom != 0, num / safe, 0.0)
        return jnp.where(n >= 2, slope, 0.0)

    def _median_flags_topk(t, abnorm_thd, min_share, step_time, k):
        """Fused median + flags + device-side top-k selection — the one
        ranking implementation both the host-fed and the device-block
        kernels trace, so they cannot diverge.

        The cross-process median (``typical``), the (P, V) flag matrix
        and the excess-over-typical scores never leave the device:
        flagged entries are ranked by a stable descending argsort over
        the vid-major flattening (matching the numpy path's
        ``argwhere(flags.T)`` enumeration plus stable sort, so ties rank
        identically) and only the best ``k`` flat indices, their scores,
        the flagged count, and the (V,) typical vector are transferred."""
        typical = jnp.median(t, axis=0)
        flags = _abnormal_flags(t, typical, abnorm_thd, min_share, step_time)
        score = jnp.where(flags, t - typical, -jnp.inf)
        flat = score.T.reshape(-1)                    # vid-major
        order = jnp.argsort(-flat, stable=True)[:k]
        return order, flat[order], flags.sum(), typical

    @partial(jax.jit, static_argnums=(4,))
    def _abnormal_topk_kernel(t, abnorm_thd, min_share, step_time, k):
        return _median_flags_topk(t, abnorm_thd, min_share, step_time, k)

    @partial(jax.jit, static_argnums=(4,))
    def _abnormal_topk_blocks_kernel(ts, top_idx, abnorm_thd, min_share, k):
        """Device-block abnormal detection, end to end on the device.

        ``ts``: tuple of (n_local, V) device blocks in global proc order.
        The blocks concatenate ON THE DEVICE (the host never stacks
        them); the step time, the cross-process median, the flag matrix
        and the ranking all happen there, and only <= k winners + the
        (V,) typical come home."""
        t = jnp.concatenate(ts, axis=0)               # device-side (P, V)
        step_time = t[:, top_idx].sum(axis=1).max()
        step_time = jnp.where(step_time > 0.0, step_time, 1e-12)
        return _median_flags_topk(t, abnorm_thd, min_share, step_time, k)

    @partial(jax.jit, static_argnums=(6,))
    def _abnormal_topk_blocks_live_kernel(ts, live, valid, top_idx,
                                          abnorm_thd, min_share, k):
        """Degraded-fleet variant: gather LIVE rows at a FIXED shape.

        ``live`` holds the live global row indices PADDED to the fleet
        size P (pad entries repeat row 0); ``valid`` marks the real ones.
        The padded gather keeps every traced shape a function of P alone,
        so a flapping host — a different live count every detect call —
        reuses one compiled executable instead of retracing per live-set
        size.  Semantics still match a store that never contained the
        dead rows: the median sorts dead rows to +inf and reads the two
        live middle order statistics (zeroing would poison the count),
        and dead rows are zeroed/mask-excluded everywhere magnitudes
        matter (step time, flags, scores)."""
        t = jnp.concatenate(ts, axis=0)[live]         # (P, V), P static
        vcol = valid[:, None]
        n_live = jnp.maximum(valid.sum(), 1)
        step_time = jnp.where(valid, t[:, top_idx].sum(axis=1), 0.0).max()
        step_time = jnp.where(step_time > 0.0, step_time, 1e-12)
        # masked median == numpy's over the live subset: dead rows sort
        # to the bottom, the middle pair indexes only live entries
        srt = jnp.sort(jnp.where(vcol, t, jnp.inf), axis=0)
        lo = jnp.take(srt, (n_live - 1) // 2, axis=0)
        hi = jnp.take(srt, n_live // 2, axis=0)
        typical = 0.5 * (lo + hi)
        tm = jnp.where(vcol, t, 0.0)
        flags = _abnormal_flags(tm, typical, abnorm_thd, min_share,
                                step_time) & vcol
        score = jnp.where(flags, tm - typical, -jnp.inf)
        flat = score.T.reshape(-1)                    # vid-major
        order = jnp.argsort(-flat, stable=True)[:k]
        return order, flat[order], flags.sum(), typical


def _precision():
    """(dtype, x64-context) for the kernel wrappers.

    float64 under a thread-local ``enable_x64`` by default; float32 with
    no x64 context when ``SCALANA_DETECT_F32`` is set (truthy) — the
    accelerator-native variant (checked per call so tests can toggle)."""
    if os.environ.get("SCALANA_DETECT_F32", "").lower() in (
            "1", "true", "on", "yes"):
        return np.float32, contextlib.nullcontext()
    return np.float64, enable_x64()


def merge_matrix(t: np.ndarray, strategy: str,
                 var: Optional[np.ndarray] = None) -> np.ndarray:
    """Jitted columnwise merge over one (n_procs, V) matrix -> (V,).

    All strategies are computed in one stacked kernel call; ``strategy``
    only selects the output row.  Reference-parity entry point for tests
    and small hosts; detection uses the fused kernels directly."""
    si = JIT_STRATEGIES.index(strategy)
    dtype, ctx = _precision()
    with ctx:
        td = jnp.asarray(np.asarray(t, dtype)[None])
        vd = jnp.asarray(np.zeros_like(t, dtype)[None] if var is None
                         else np.asarray(var, dtype)[None])
        out = _merge_all_kernel(td, vd)
    return np.asarray(out)[si, 0]


def fit_slopes(scales: Sequence[int], M: np.ndarray,
               valid: np.ndarray) -> np.ndarray:
    """Jitted batched log-log slope fit: (S, V) merged times -> (V,).

    The jax side of ``detect.fit_slopes`` — the cross-run diff resolves
    between the two through ``detect._resolve_backend``."""
    dtype, ctx = _precision()
    with ctx:
        out = _fit_slopes_kernel(
            jnp.asarray(np.log(np.asarray(scales, dtype))),
            jnp.asarray(np.asarray(M, dtype)),
            jnp.asarray(np.asarray(valid, bool)))
    return np.asarray(out)


def non_scalable_arrays(scales: Sequence[int], t: np.ndarray, var: np.ndarray,
                        present: np.ndarray, total_max: float,
                        ideal_slope: float, slope_margin: float,
                        min_share: float, strategy: str
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray]:
    """Run the one-launch fused non-scalable op; returns the ``strategy``
    row of (M (S, V), slope (V,), share (V,), flagged (V,))."""
    si = JIT_STRATEGIES.index(strategy)
    dtype, ctx = _precision()
    logp = np.log(np.asarray(scales, dtype))
    with ctx:
        M, slope, share, flagged = _fused.fused_non_scalable(
            jnp.asarray(np.asarray(t, dtype)),
            jnp.asarray(np.asarray(var, dtype)),
            jnp.asarray(logp), jnp.asarray(present),
            ideal_slope=float(ideal_slope),
            slope_margin=float(slope_margin),
            min_share=float(min_share), total_max=float(total_max))
    return (np.asarray(M)[si], np.asarray(slope)[si],
            np.asarray(share)[si], np.asarray(flagged)[si])


def abnormal_arrays(t: np.ndarray, abnorm_thd: float, min_share: float,
                    step_time: float) -> Tuple[np.ndarray, np.ndarray]:
    """Run the abnormal kernel; returns ((P, V) flags, (V,) typical).

    Materializes the full flag matrix on the host — parity/test entry
    point; detection itself uses :func:`abnormal_topk`, which keeps the
    flags device-resident."""
    dtype, ctx = _precision()
    typical = np.median(np.asarray(t, dtype), axis=0)
    with ctx:
        flags = _abnormal_kernel(
            jnp.asarray(np.asarray(t, dtype)), jnp.asarray(typical),
            float(abnorm_thd), float(min_share), float(step_time))
    return np.asarray(flags), typical


def abnormal_topk(t: np.ndarray, abnorm_thd: float, min_share: float,
                  step_time: float, k: int
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Device-resident abnormal detection: only the winners come home.

    The cross-process median (``jnp.median`` — bit-identical to numpy's
    in f64; the order statistic no longer round-trips ``t`` through the
    host), the (P, V) flag matrix and the ranking scores stay on the
    device until report time; the host receives the (vid, proc) indices
    of the ``<= k`` highest-scoring flagged entries (ranked exactly like
    the numpy reference: descending ``time - typical``, ties in
    vid-major enumeration order), the (V,) typical vector, and the total
    flagged count.  Returns ``(vids, procs, typical, n_flagged)``."""
    dtype, ctx = _precision()
    t_host = np.asarray(t, dtype)
    with ctx:
        order, _, count, typical = _fused.fused_abnormal(
            (jnp.asarray(t_host),), None, float(abnorm_thd),
            float(min_share), int(k), step_time=float(step_time))
        n_flagged = int(count)                 # report time: flags leave
        order = np.asarray(order[:min(int(k), n_flagged)])  # the device
        typical = np.asarray(typical)
    n_procs = t_host.shape[0]
    return order // n_procs, order % n_procs, typical, n_flagged


def abnormal_topk_view(view, n_vertices: int, top: Sequence[int],
                       abnorm_thd: float, min_share: float, k: int,
                       live_rows: Optional[np.ndarray] = None,
                       fused: bool = True
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Abnormal detection fed straight from a
    :class:`~repro.core.shard.DeviceShardView` — the online entry point.

    ``view.refresh`` uploads only the rows written since the last call
    (O(dirty rows), not O(P·V)); the per-host blocks then concatenate on
    the device, where the step time, median, flagging and top-k ranking
    all run.  The host never materializes the stacked (P, V) matrix.
    ``top`` is the root's child vids (the step-time columns).  Returns
    ``(vids, procs, typical, n_flagged)`` like :func:`abnormal_topk`.

    ``live_rows``: optional live global row indices (degraded fleets).
    The gather runs on the device at a shape PADDED to the fleet size
    (pad rows masked out), so varying live counts — a flapping host —
    hit one compiled executable instead of retracing per live-set size.
    The returned ``procs`` index INTO ``live_rows`` (the caller maps
    back to global procs), matching the host path's row-subset
    semantics.

    ``fused=True`` (the default) routes through the one-launch fused op
    (``repro.kernels.detect_fused``); ``fused=False`` keeps the legacy
    multi-dispatch kernel chain — the unfused baseline the bench still
    times and the parity tests pin the fused path against."""
    dtype, ctx = _precision()
    n_procs = view.n_procs
    with ctx:
        view.refresh(n_vertices, dtype)
        ts = tuple(view.time_blocks())
        top_d = jnp.asarray(np.asarray(top, np.int32))
        if live_rows is not None:
            live = np.zeros(n_procs, np.int32)
            valid = np.zeros(n_procs, bool)
            n_live = int(len(live_rows))
            live[:n_live] = np.asarray(live_rows, np.int32)
            valid[:n_live] = True
        if fused:
            view.kernel_launches += 1
            if live_rows is None:
                order, _, count, typical = _fused.fused_abnormal(
                    ts, top_d, float(abnorm_thd), float(min_share),
                    int(k))
            else:
                order, _, count, typical = _fused.fused_abnormal(
                    ts, top_d, float(abnorm_thd), float(min_share),
                    int(k), live=jnp.asarray(live),
                    valid=jnp.asarray(valid))
        elif live_rows is None:
            order, _, count, typical = _abnormal_topk_blocks_kernel(
                ts, top_d, float(abnorm_thd), float(min_share), int(k))
        else:
            order, _, count, typical = _abnormal_topk_blocks_live_kernel(
                ts, jnp.asarray(live), jnp.asarray(valid), top_d,
                float(abnorm_thd), float(min_share), int(k))
        n_flagged = int(count)
        order = np.asarray(order[:min(int(k), n_flagged)])
        typical = np.asarray(typical)
    return order // n_procs, order % n_procs, typical, n_flagged


def non_scalable_views(scales: Sequence[int], views: Sequence,
                       n_vertices: int, present: np.ndarray,
                       top: Sequence[int], ideal_slope: float,
                       slope_margin: float, min_share: float, strategy: str,
                       fused: bool = True
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                  np.ndarray]:
    """Non-scalable detection fed from per-scale
    :class:`~repro.core.shard.DeviceShardView`\\ s.

    The fused path (default) exploits that historical scales are
    IMMUTABLE once their run completes: each completed view's (4, V)
    merged column is computed once (``merge_scale_column``) and cached
    on the view keyed by its upload revision
    (:meth:`~repro.core.shard.DeviceShardView.merged_column`), so a
    steady-state call merges only the LIVE scale's blocks and runs the
    slope/share/flag tail — one ``fused_non_scalable_live`` launch over
    the cached (4, S-1, V) stack.  Any write, re-pin, layout or dtype
    change bumps the revision and refills that scale's column.  The
    reference step time still derives from the merged "max" row at the
    final scale.  ``fused=False`` keeps the legacy per-scale merge +
    slope-kernel chain (the unfused baseline).  Returns the ``strategy``
    row of (M (S, V), slope (V,), share (V,), flagged (V,)) as host
    arrays — O(S·V), never O(P·V)."""
    si = JIT_STRATEGIES.index(strategy)
    dtype, ctx = _precision()
    logp = np.log(np.asarray(scales, dtype))
    with ctx:
        for view in views:
            view.refresh(n_vertices, dtype)
        if fused:
            cols = []
            for v in views[:-1]:
                col = v.merged_column()
                if col is None:
                    col = _fused.merge_scale_column(
                        tuple(v.time_blocks()), tuple(v.var_blocks()))
                    v.cache_merged_column(col)
                    v.kernel_launches += 1
                cols.append(col)
            hist = (jnp.stack(cols, axis=1) if cols
                    else jnp.zeros((4, 0, int(n_vertices)), dtype))
            live = views[-1]
            live.kernel_launches += 1
            M, slope, share, flagged = _fused.fused_non_scalable_live(
                tuple(live.time_blocks()), tuple(live.var_blocks()),
                hist, jnp.asarray(logp), jnp.asarray(present),
                jnp.asarray(np.asarray(top, np.int32)),
                ideal_slope=float(ideal_slope),
                slope_margin=float(slope_margin),
                min_share=float(min_share))
            return (np.asarray(M)[si], np.asarray(slope)[si],
                    np.asarray(share)[si], np.asarray(flagged)[si])
        M = jnp.stack(
            [_merge_blocks_kernel(tuple(v.time_blocks()),
                                  tuple(v.var_blocks())) for v in views],
            axis=1)                                        # (4, S, V)
        slope, share, flagged = _slope_flag_from_M_kernel(
            M, jnp.asarray(logp), jnp.asarray(present),
            jnp.asarray(np.asarray(top, np.int32)),
            float(ideal_slope), float(slope_margin), float(min_share))
        return (np.asarray(M)[si], np.asarray(slope)[si],
                np.asarray(share)[si], np.asarray(flagged)[si])
