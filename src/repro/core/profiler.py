"""Graph-guided sampling profiler (paper §III-B).

OS-interrupt sampling of a running XLA executable cannot attribute time to
IR vertices (the program is a single fused binary), so the TPU/JAX-native
equivalent samples in *step space*: every K-th step is executed through an
instrumented jaxpr interpreter that times each top-level PSG vertex
(`block_until_ready` fences); all other steps run the compiled fast path.
Expected overhead ≈ (instrumented_step/compiled_step − 1)/K, directly
tunable like the paper's sampling frequency — measured by
benchmarks/bench_overhead.py.

Per-vertex performance vectors combine this measured channel with the
static counter channel (flops/bytes from repro.core.costs), the PAPI
analogue.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import psg as psg_lib
from repro.core.contraction import contract
from repro.core.graph import COMM, PSG, PerfVector


def _block(x):
    return jax.tree.map(
        lambda v: v.block_until_ready() if hasattr(v, "block_until_ready")
        else v, x)


class _TimedEval:
    """eval_jaxpr with a per-top-level-eqn timing callback."""

    def __init__(self, closed_jaxpr):
        self.closed = closed_jaxpr

    def __call__(self, args: Sequence[Any],
                 on_eqn: Callable[[int, float], None]) -> List[Any]:
        from jax._src.core import Literal
        jaxpr = self.closed.jaxpr
        env: Dict[Any, Any] = {}

        def read(v):
            return v.val if isinstance(v, Literal) else env[v]

        def write(v, val):
            env[v] = val

        for var, val in zip(jaxpr.constvars, self.closed.consts):
            write(var, val)
        flat = list(args)
        assert len(flat) == len(jaxpr.invars), \
            (len(flat), len(jaxpr.invars))
        for var, val in zip(jaxpr.invars, flat):
            write(var, val)

        for idx, eqn in enumerate(jaxpr.eqns):
            invals = [read(v) for v in eqn.invars]
            subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
            t0 = time.perf_counter()
            ans = eqn.primitive.bind(*subfuns, *invals, **bind_params)
            _block(ans)
            on_eqn(idx, time.perf_counter() - t0)
            if eqn.primitive.multiple_results:
                for var, val in zip(eqn.outvars, ans):
                    write(var, val)
            else:
                write(eqn.outvars[0], ans)
        return [read(v) for v in jaxpr.outvars]


class GraphProfiler:
    """Profiles ``fn`` at PSG-vertex granularity with step-space sampling.

    Storage accounting mirrors the paper: per-vertex perf vectors (KBs)
    instead of per-event traces (GBs).
    """

    def __init__(self, fn: Callable, example_args: Sequence[Any], *,
                 sample_every: int = 16, max_loop_depth: int = 10,
                 static_argnums: Tuple[int, ...] = ()):
        self.fn = fn
        self.sample_every = max(int(sample_every), 1)
        self.closed = jax.make_jaxpr(fn)(*example_args)
        self.psg_full = psg_lib.build_psg(jaxpr=self.closed)
        self.psg, self.mapping = contract(self.psg_full, max_loop_depth)
        # top-level eqn index -> contracted vertex id
        top = psg_lib.top_level_order(self.psg_full)
        self._eqn_to_vertex = [self.mapping.get(vid, self.psg.root)
                               for vid in top]
        self._compiled = jax.jit(fn)
        self._evaluator = _TimedEval(self.closed)
        # accumulators
        self._vertex_times: Dict[int, List[float]] = {}
        self.step_times: List[float] = []
        self.sampled_steps = 0
        self.total_steps = 0

    # ------------------------------------------------------------------
    def step(self, *args) -> Any:
        """Run one step; every K-th step is the instrumented sampled run."""
        self.total_steps += 1
        if self.total_steps % self.sample_every == 0:
            return self._sampled_step(*args)
        t0 = time.perf_counter()
        out = self._compiled(*args)
        _block(out)
        self.step_times.append(time.perf_counter() - t0)
        return out

    def _sampled_step(self, *args) -> Any:
        self.sampled_steps += 1
        flat, _ = jax.tree.flatten(args)

        def on_eqn(idx: int, dt: float):
            vid = self._eqn_to_vertex[idx]
            self._vertex_times.setdefault(vid, []).append(dt)

        t0 = time.perf_counter()
        outs = self._evaluator(flat, on_eqn)
        self.step_times.append(time.perf_counter() - t0)
        out_tree = jax.tree.structure(
            jax.eval_shape(self.fn, *args))
        return jax.tree.unflatten(out_tree, outs)

    # ------------------------------------------------------------------
    def perf_vectors(self) -> Dict[int, PerfVector]:
        """Per-vertex perf vectors: measured time + static counters."""
        out: Dict[int, PerfVector] = {}
        for v in self.psg.vertices:
            times = self._vertex_times.get(v.vid, [])
            counters = {"flops": v.flops, "bytes": v.bytes,
                        "comm_bytes": v.comm_bytes}
            if times:
                t = float(np.mean(times))
                counters["flops_per_sec"] = v.flops / t if t > 0 else 0.0
                out[v.vid] = PerfVector(time=t,
                                        time_var=float(np.var(times)),
                                        samples=len(times),
                                        counters=counters)
            elif v.flops or v.comm_bytes:
                out[v.vid] = PerfVector(time=0.0, samples=0,
                                        counters=counters)
        return out

    def perf_shard(self, proc_start: int = 0, n_procs: int = 1):
        """This host's measured profile as a proc-range shard.

        Returns a :class:`~repro.core.shard.PerfShard` covering global
        processes ``[proc_start, proc_start + n_procs)``, each local row
        filled with this profiler's per-vertex vectors (an SPMD host runs
        identical top-level structure on its local processes).  Hosts
        profile independently and the controller merges blocks late:
        ``PerfStore.from_shards(shards)`` or streamed
        ``build_ppg(psg, P, shards)`` — no single-controller gather of
        per-(proc, vertex) vectors.
        """
        from repro.core.shard import PerfShard
        shard = PerfShard(proc_start, n_procs, len(self.psg.vertices))
        procs = np.arange(int(n_procs))
        for vid, vec in self.perf_vectors().items():
            shard.set_entries(procs, vid, vec.time, time_var=vec.time_var,
                              samples=vec.samples, counters=vec.counters)
        return shard

    def base_times(self, default: float = 0.0) -> Callable:
        """Vectorized ``base_times`` seeded from the measured profile.

        Returns a callable with the replay engine's vectorized contract
        (``fn(procs_array, vid) -> seconds``; see
        :func:`repro.core.inject.seeded_base_times`), so case studies
        replay real measured models without O(P·V) Python callbacks.
        Unprofiled vertices replay at ``default`` seconds.
        """
        from repro.core.inject import seeded_base_times
        table = np.full(len(self.psg.vertices), float(default))
        for vid, vec in self.perf_vectors().items():
            table[vid] = vec.time
        return seeded_base_times(table)

    def storage_bytes(self) -> int:
        """Bytes ScalAna retains: contracted PSG + per-vertex vectors."""
        vec_bytes = sum(8 * (3 + len(v.counters))
                        for v in self.perf_vectors().values())
        return self.psg.nbytes() + vec_bytes

    def full_trace_bytes(self) -> int:
        """What a full per-event tracer would have written for the same run:
        one 64-byte event per (eqn execution, step) incl. loop iterations."""
        events_per_step = 0
        for v in self.psg_full.vertices:
            trips = 1
            p = v.parent
            while p >= 0:
                trips *= int(self.psg_full.vertices[p].meta.get(
                    "trip_count", 1) or 1)
                p = self.psg_full.vertices[p].parent
            events_per_step += trips
        return events_per_step * 64 * self.total_steps

    def overhead_estimate(self) -> Dict[str, float]:
        if not self.step_times:
            return {}
        fast = [t for i, t in enumerate(self.step_times, start=1)
                if i % self.sample_every != 0]
        slow = [t for i, t in enumerate(self.step_times, start=1)
                if i % self.sample_every == 0]
        if not fast:
            return {}
        base = float(np.median(fast))
        extra = sum(max(t - base, 0.0) for t in slow)
        total = sum(self.step_times)
        return {
            "base_step_s": base,
            "sampled_step_s": float(np.median(slow)) if slow else 0.0,
            "overhead_frac": extra / max(total - extra, 1e-12),
        }
