"""PSG contraction (paper §III-A "PSG Contraction").

Rules, following the paper:
  * preserve ALL Comm vertices and the control structures containing them;
  * merge runs of consecutive Comp vertices under the same parent into one
    larger Comp vertex (summing static counters);
  * prune Loop/Branch subtrees nested deeper than ``MaxLoopDepth`` unless
    they contain communication (their counters roll up into the parent);
  * drop zero-weight Comp vertices produced by layout/bookkeeping ops.

Returns the contracted PSG and an old->new vid mapping so runtime profiling
data collected at either granularity can be attributed consistently.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from repro.core.graph import BRANCH, CALL, COMM, COMP, LOOP, ROOT, PSG, Vertex


def _contains_comm(psg: PSG, vid: int, cache: Dict[int, bool]) -> bool:
    if vid in cache:
        return cache[vid]
    v = psg.vertices[vid]
    result = v.kind == COMM or any(
        _contains_comm(psg, c, cache) for c in psg.children(vid))
    cache[vid] = result
    return result


def contract(psg: PSG, max_loop_depth: int = 10,
             min_comp_flops: float = 0.0) -> Tuple[PSG, Dict[int, int]]:
    out = PSG()
    root = out.new_vertex(ROOT, "root")
    out.root = root.vid
    mapping: Dict[int, int] = {psg.root: root.vid}
    comm_cache: Dict[int, bool] = {}

    def walk(old_parent: int, new_parent: int, depth: int) -> None:
        pending: Optional[Vertex] = None     # open merged Comp vertex

        def flush():
            nonlocal pending
            pending = None

        for cid in psg.children(old_parent):
            v = psg.vertices[cid]
            if v.kind == COMP:
                if pending is None:
                    nv = out.new_vertex(COMP, "comp", source=v.source,
                                        parent=new_parent, depth=depth)
                    pending = nv
                pending.prims.extend(v.prims)
                pending.flops += v.flops
                pending.bytes += v.bytes
                if not pending.source:
                    pending.source = v.source
                mapping[cid] = pending.vid
                continue
            flush()
            has_comm = _contains_comm(psg, cid, comm_cache)
            if v.kind in (LOOP, BRANCH, CALL):
                if depth >= max_loop_depth and not has_comm:
                    # prune subtree: fold into a single Comp summary vertex
                    nv = out.new_vertex(COMP, f"{v.name}(pruned)",
                                        source=v.source, parent=new_parent,
                                        depth=depth)
                    nv.flops, nv.bytes = v.flops, v.bytes
                    _map_subtree(psg, cid, nv.vid, mapping)
                    continue
                if v.kind == CALL and not has_comm:
                    # inline transparent calls: lift children one level up
                    mapping[cid] = new_parent
                    walk(cid, new_parent, depth)
                    continue
                nv = out.new_vertex(v.kind, v.name, source=v.source,
                                    parent=new_parent, depth=depth)
                nv.flops, nv.bytes = v.flops, v.bytes
                nv.comm_bytes = v.comm_bytes
                nv.meta = dict(v.meta)
                mapping[cid] = nv.vid
                walk(cid, nv.vid, depth + 1)
            else:  # COMM — always preserved verbatim
                nv = out.new_vertex(COMM, v.name, source=v.source,
                                    parent=new_parent, depth=depth)
                nv.comm_kind, nv.comm_bytes = v.comm_kind, v.comm_bytes
                nv.p2p_pairs = list(v.p2p_pairs)
                mapping[cid] = nv.vid
        flush()

    walk(psg.root, root.vid, 0)

    # drop trivial zero-cost Comp leaves (bookkeeping ops)
    if min_comp_flops > 0.0:
        keep = {v.vid for v in out.vertices
                if not (v.kind == COMP and v.flops <= min_comp_flops
                        and v.comm_bytes == 0 and not out.children(v.vid))}
        out, submap = _filter(out, keep)
        mapping = {old: submap[n] for old, n in mapping.items() if n in submap}

    _rebuild_edges(psg, out, mapping)
    return out, mapping


def _map_subtree(psg: PSG, vid: int, target: int,
                 mapping: Dict[int, int]) -> None:
    mapping[vid] = target
    for c in psg.children(vid):
        _map_subtree(psg, c, target, mapping)


def _filter(psg: PSG, keep: Set[int]) -> Tuple[PSG, Dict[int, int]]:
    out = PSG()
    submap: Dict[int, int] = {}
    for v in psg.vertices:
        if v.vid not in keep:
            continue
        nv = out.new_vertex(v.kind, v.name, source=v.source,
                            parent=-1, depth=v.depth)
        # copy container fields: sharing them would alias the source PSG,
        # so mutating the filtered graph corrupts the original
        nv.prims, nv.flops, nv.bytes = list(v.prims), v.flops, v.bytes
        nv.comm_kind, nv.comm_bytes = v.comm_kind, v.comm_bytes
        nv.p2p_pairs, nv.meta = list(v.p2p_pairs), dict(v.meta)
        submap[v.vid] = nv.vid
    for v in psg.vertices:
        if v.vid in submap and v.parent in submap:
            out.set_parent(submap[v.vid], submap[v.parent])
    out.root = submap[psg.root]
    return out, submap


def _rebuild_edges(orig: PSG, out: PSG, mapping: Dict[int, int]) -> None:
    for (s, d, k) in orig.edges:
        ns, nd = mapping.get(s), mapping.get(d)
        if ns is None or nd is None or ns == nd:
            continue
        out.add_edge(ns, nd, k)
