"""Static PSG construction from a jaxpr (the paper's compile-time analysis).

The jaxpr is the compiler IR of a JAX program: ``scan``/``while`` map to the
paper's Loop vertices, ``cond`` to Branch, inlined calls (``pjit``,
``custom_*``, ``remat``) to Call — inter-procedural analysis is literal
sub-jaxpr recursion.  Collective primitives (visible under ``shard_map``)
become Comm vertices directly; for pjit-partitioned programs Comm vertices
are added from the compiled HLO by ``repro.core.commdep.annotate_from_hlo``.

Data-dependence edges are true def-use edges between vertices at the same
nesting level; control edges connect a control vertex to its children.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.extend.core import Var as _JaxVar

from repro.core import costs
from repro.core.graph import (
    BRANCH, CALL, COMM, COMP, LOOP, ROOT,
    COLLECTIVE_PRIMS, P2P_PRIMS, PSG, Vertex,
)

# primitives whose sub-jaxpr we inline as a Call vertex
_CALL_PARAM_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")
_CALL_PRIMS = {
    "pjit", "closed_call", "core_call", "remat", "checkpoint", "remat2",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "custom_jvp_call_jaxpr", "custom_lin", "shard_map", "jit",
}
_LOOP_PRIMS = {"scan", "while"}


def _source_of(eqn) -> str:
    try:
        from jax._src import source_info_util
        si = eqn.source_info
    except Exception:                  # private API: absent on some versions
        return ""
    # newer jax expects the SourceInfo (reads .traceback itself); older
    # versions took the raw Traceback — try both
    for arg in (si, getattr(si, "traceback", si)):
        try:
            frame = source_info_util.user_frame(arg)
            if frame is None:
                frames = list(source_info_util.user_frames(arg))
                frame = frames[0] if frames else None
            if frame is not None:
                return f"{frame.file_name}:{frame.start_line}"
        except Exception:
            continue
    return ""


def _sub_jaxprs(eqn) -> List[Tuple[str, Any]]:
    """(label, jaxpr) pairs for an eqn's nested jaxprs."""
    out = []
    name = eqn.primitive.name
    if name == "scan":
        out.append(("body", eqn.params["jaxpr"]))
    elif name == "while":
        out.append(("cond", eqn.params["cond_jaxpr"]))
        out.append(("body", eqn.params["body_jaxpr"]))
    elif name == "cond":
        for i, br in enumerate(eqn.params["branches"]):
            out.append((f"branch{i}", br))
    else:
        for key in _CALL_PARAM_KEYS:
            if key in eqn.params:
                out.append((key, eqn.params[key]))
                break
    return [(lbl, j) for lbl, j in out if j is not None]


def _raw(jaxpr):
    return jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr


def _comm_bytes(eqn) -> float:
    return float(sum(
        int(np.prod(v.aval.shape, dtype=np.int64)) * v.aval.dtype.itemsize
        for v in eqn.invars if hasattr(v, "aval") and hasattr(v.aval, "shape")))


def _trip_count(eqn) -> int:
    if eqn.primitive.name == "scan":
        return int(eqn.params.get("length", 1))
    return 1   # while: unknown statically


class _Builder:
    def __init__(self, max_depth: int = 64):
        self.psg = PSG()
        root = self.psg.new_vertex(ROOT, "root")
        self.psg.root = root.vid
        self.max_depth = max_depth

    # ------------------------------------------------------------------
    def walk(self, jaxpr, parent: int, depth: int,
             var_def: Optional[Dict[Any, int]] = None) -> None:
        """One nesting level. var_def maps jaxpr Var -> producing vertex."""
        jaxpr = _raw(jaxpr)
        var_def = dict(var_def or {})
        prev_vid: Optional[int] = None
        for eqn in jaxpr.eqns:
            v = self._vertex_for(eqn, parent, depth)
            # true def-use data edges at this level (Literals are not Vars)
            producers = {var_def[iv] for iv in eqn.invars
                         if isinstance(iv, _JaxVar)
                         and iv in var_def and var_def[iv] != v.vid}
            for p in producers:
                self.psg.add_edge(p, v.vid, "data")
            if not producers and prev_vid is not None:
                # fall back to program order so the chain stays connected
                self.psg.add_edge(prev_vid, v.vid, "data")
            for ov in eqn.outvars:
                var_def[ov] = v.vid
            self.psg.add_edge(parent, v.vid, "control")
            prev_vid = v.vid
            # recurse
            if v.is_control and depth < self.max_depth:
                for lbl, sub in _sub_jaxprs(eqn):
                    self.walk(sub, v.vid, depth + 1)
                # roll nested static counters up into the control vertex
                self._rollup(v, _trip_count(eqn))

    # ------------------------------------------------------------------
    def _vertex_for(self, eqn, parent: int, depth: int) -> Vertex:
        name = eqn.primitive.name
        src = _source_of(eqn)
        if name in _LOOP_PRIMS:
            return self.psg.new_vertex(
                LOOP, name, source=src, parent=parent, depth=depth,
                meta={"trip_count": _trip_count(eqn)})
        if name == "cond":
            return self.psg.new_vertex(BRANCH, name, source=src,
                                       parent=parent, depth=depth)
        if name in _CALL_PRIMS and any(k in eqn.params
                                       for k in _CALL_PARAM_KEYS):
            label = eqn.params.get("name", name)
            return self.psg.new_vertex(CALL, f"{name}:{label}", source=src,
                                       parent=parent, depth=depth)
        if name in COLLECTIVE_PRIMS:
            v = self.psg.new_vertex(COMM, name, source=src, parent=parent,
                                    depth=depth)
            v.comm_kind = "all_reduce" if name in ("psum", "pmax", "pmin") \
                else name
            v.comm_bytes = _comm_bytes(eqn)
            if name in P2P_PRIMS:
                v.p2p_pairs = [tuple(p) for p in eqn.params.get("perm", [])]
            return v
        flops, nbytes = costs.eqn_costs(eqn)
        v = self.psg.new_vertex(COMP, name, source=src, parent=parent,
                                depth=depth)
        v.prims = [name]
        v.flops, v.bytes = flops, nbytes
        return v

    def _rollup(self, v: Vertex, trips: int) -> None:
        kids = self.psg.children(v.vid)
        v.flops = trips * sum(self.psg.vertices[c].flops for c in kids)
        v.bytes = trips * sum(self.psg.vertices[c].bytes for c in kids)
        v.comm_bytes = trips * sum(self.psg.vertices[c].comm_bytes
                                   for c in kids)


def build_psg(fn=None, *args, jaxpr=None, max_depth: int = 64, **kwargs) -> PSG:
    """Static analysis: trace ``fn(*args)`` (or take a ready jaxpr) -> PSG."""
    if jaxpr is None:
        jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    b = _Builder(max_depth=max_depth)
    b.walk(jaxpr, parent=b.psg.root, depth=0)
    return b.psg


def top_level_order(psg: PSG) -> List[int]:
    """Program-order vids directly under the root (children index is
    maintained in creation = program order)."""
    return psg.children(psg.root)
