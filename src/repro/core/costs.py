"""Static per-eqn cost model — the 'hardware counter' channel (PAPI analogue).

Estimates FLOPs and bytes-accessed per jaxpr equation so every PSG vertex
carries static counters even before any run.  Matmul-family ops are exact;
elementwise ops are size-based; everything else falls back to operand+result
byte traffic with zero FLOPs.
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize
    except Exception:
        return 0


def _aval_size(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64))
    except Exception:
        return 0


def eqn_costs(eqn) -> Tuple[float, float]:
    """Returns (flops, bytes_accessed) for one equation."""
    name = eqn.primitive.name
    in_avals = [v.aval for v in eqn.invars if hasattr(v, "aval")]
    out_avals = [v.aval for v in eqn.outvars if hasattr(v, "aval")]
    in_bytes = sum(_aval_bytes(a) for a in in_avals)
    out_bytes = sum(_aval_bytes(a) for a in out_avals)
    bytes_accessed = float(in_bytes + out_bytes)

    if name == "dot_general":
        dims = eqn.params["dimension_numbers"]
        (lc, rc), (lb, rb) = dims
        lhs = in_avals[0].shape
        batch = int(np.prod([lhs[i] for i in lb], dtype=np.int64)) if lb else 1
        contract = int(np.prod([lhs[i] for i in lc], dtype=np.int64)) if lc else 1
        m = int(np.prod([s for i, s in enumerate(lhs)
                         if i not in lc and i not in lb], dtype=np.int64))
        rhs = in_avals[1].shape
        n = int(np.prod([s for i, s in enumerate(rhs)
                         if i not in rc and i not in rb], dtype=np.int64))
        return float(2 * batch * m * n * contract), bytes_accessed

    if name in ("conv_general_dilated",):
        out = out_avals[0]
        rhs = in_avals[1]
        # flops = 2 * out_size * (rhs spatial+in-feature size per output)
        per_out = int(np.prod(rhs.shape, dtype=np.int64)) // max(rhs.shape[0], 1)
        return float(2 * _aval_size(out) * per_out), bytes_accessed

    out_size = sum(_aval_size(a) for a in out_avals)
    if name in ("exp", "log", "tanh", "logistic", "erf", "rsqrt", "sqrt",
                "sin", "cos", "pow", "cumsum", "cumlogsumexp"):
        return float(8 * out_size), bytes_accessed        # transcendental-ish
    if name in ("add", "sub", "mul", "div", "max", "min", "neg", "abs",
                "integer_pow", "select_n", "and", "or", "xor", "not",
                "reduce_sum", "reduce_max", "reduce_min", "add_any",
                "square", "sign", "floor", "ceil", "round", "clamp",
                "log1p", "expm1", "nextafter", "rem"):
        in_size = sum(_aval_size(a) for a in in_avals)
        return float(max(in_size, out_size)), bytes_accessed
    # data movement / layout ops and unknowns: 0 flops
    return 0.0, bytes_accessed
