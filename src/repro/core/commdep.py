"""Communication dependence: HLO annotation + graph-guided compression.

Two ScalAna mechanisms live here:

* ``annotate_from_hlo`` — refine a PSG with Comm vertices discovered in the
  compiled HLO (GSPMD-inserted collectives that are invisible in the jaxpr),
  attached to the best-matching control vertex by op-name scope.

* ``CommLog`` — the paper's *graph-guided communication compression* +
  *sampling-based instrumentation* (§III-B2): communication parameters are
  recorded once per (vertex, signature) with a repeat count, and record
  emission is Bernoulli-sampled.  ``full_trace_bytes`` reports what an
  uncompressed tracer would have written, for the storage benchmarks.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import (BRANCH, CALL, COMM, LOOP, PSG, PPG,
                              vertex_pairs_array)
from repro.core.hlo import CollectiveOp, parse_collectives, scope_tokens

_EVENT_BYTES = 64      # what one uncompressed trace event would cost on disk


def _find_scope_vertex(psg: PSG, op: CollectiveOp) -> int:
    """Best PSG attach point for an HLO collective: deepest control vertex
    whose name appears in the op scope path (e.g. 'while' loops)."""
    tokens = scope_tokens(op.op_name)
    best = psg.root
    best_depth, best_vid = -1, -1
    for kind in (LOOP, BRANCH, CALL):     # kind index: skips Comp/Comm bulk
        for v in psg.by_kind(kind):
            base = v.name.split(":")[0]
            if base not in tokens:
                continue
            # deepest wins; depth ties go to the lowest vid (program order)
            if v.depth > best_depth or (v.depth == best_depth
                                        and v.vid < best_vid):
                best, best_depth, best_vid = v.vid, v.depth, v.vid
    return best


def annotate_from_hlo(psg: PSG, hlo_text: str) -> List[int]:
    """Add Comm vertices for GSPMD collectives. Returns new vertex ids."""
    new_vids: List[int] = []
    for op in parse_collectives(hlo_text):
        parent = _find_scope_vertex(psg, op)
        v = psg.new_vertex(COMM, op.kind, source=op.source or op.op_name,
                           parent=parent,
                           depth=psg.vertices[parent].depth + 1)
        v.comm_kind = op.kind
        v.comm_bytes = float(op.bytes)
        v.p2p_pairs = list(op.p2p_pairs)
        v.meta["replica_groups"] = op.replica_groups
        v.meta["from_hlo"] = True
        # data edge from the previous comm/comp vertex under same parent
        sibs = [c for c in psg.children(parent) if c != v.vid]
        if sibs:
            psg.add_edge(sibs[-1], v.vid, "data")
        psg.add_edge(parent, v.vid, "control")
        new_vids.append(v.vid)
    return new_vids


# ---------------------------------------------------------------------------
# Graph-guided communication compression
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CommRecord:
    vertex: int
    kind: str
    nbytes: int
    group: Tuple[int, ...]          # participant ids (or group signature)
    count: int = 0                  # repeats folded into this record


class CommLog:
    """Compressed communication-dependence log (one record per signature)."""

    def __init__(self, sample_prob: float = 1.0, seed: int = 0):
        self.records: Dict[Tuple, CommRecord] = {}
        self.events_seen = 0        # what a full tracer would have recorded
        self.sample_prob = sample_prob
        self._rng = random.Random(seed)

    def record(self, vertex: int, kind: str, nbytes: int,
               group: Sequence[int]) -> None:
        self.events_seen += 1
        key = (vertex, kind, int(nbytes), tuple(group))
        if key in self.records:
            self.records[key].count += 1
            return
        # unseen signature: sampling may skip it, but the paper's random
        # sampling keeps recording occasionally to catch changing patterns
        if self.sample_prob < 1.0 and self._rng.random() > self.sample_prob:
            return
        self.records[key] = CommRecord(vertex, kind, int(nbytes),
                                       tuple(group), count=1)

    def nbytes(self) -> int:
        """Storage actually retained (compressed)."""
        return sum(24 + 8 * len(r.group) for r in self.records.values())

    def full_trace_bytes(self) -> int:
        """Storage a full tracing tool would have written."""
        return self.events_seen * _EVENT_BYTES

    def compression_ratio(self) -> float:
        return self.full_trace_bytes() / max(self.nbytes(), 1)


# ---------------------------------------------------------------------------
# PPG comm-edge construction
# ---------------------------------------------------------------------------

def add_comm_edges(ppg: PPG, psg: Optional[PSG] = None) -> None:
    """Register inter-process dependence for every Comm vertex in the PSG.

    Collectives record their participant group (O(|group|) storage, clique
    edges resolved lazily by ``PPG.comm_partners``); p2p pairs become
    explicit edges."""
    psg = psg or ppg.psg
    for v in psg.by_kind(COMM):
        if v.p2p_pairs:
            # bulk registration: one array append per vertex (folded into
            # the explicit edge indexes lazily on first partner query)
            arr = vertex_pairs_array(v)
            keep = (arr[:, 0] < ppg.n_procs) & (arr[:, 1] < ppg.n_procs)
            ppg.comm.add_p2p_batch(v.vid, arr[keep, 0], arr[keep, 1])
            continue
        groups = v.meta.get("replica_groups")
        if groups:
            for g in groups:
                ppg.add_collective_edges(v.vid,
                                         [p for p in g if p < ppg.n_procs])
        else:
            ppg.add_collective_edges(v.vid)
