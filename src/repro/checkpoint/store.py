"""Fault-tolerant checkpointing: atomic, keep-N, async, mesh-agnostic.

Design for 1000+ nodes:

* **Atomicity** — write to ``step_K.tmp/`` then ``os.rename`` to ``step_K/``;
  a crash mid-write never corrupts the latest checkpoint, and auto-resume
  scans only committed directories.
* **Mesh-agnostic layout** — arrays are saved *logically unsharded* (one npz
  per pytree leaf group); on load they are resharded to whatever mesh the
  restarted job runs with (elastic re-scaling: a 512-chip checkpoint
  restores fine on 256 chips or 1024).
* **Async** — ``save(...)`` snapshots to host memory synchronously (cheap)
  and writes in a background thread so the train loop never blocks on I/O;
  ``wait()`` joins at shutdown.  A failed async write is re-raised at the
  next call site so failures are not silent.
* **Keep-N GC** — old committed checkpoints beyond ``keep`` are removed
  after a successful commit, never before.
* **Integrity** — every leaf's shape/dtype is recorded in ``manifest.json``
  and verified on load; partial/foreign directories are rejected.

jax is OPTIONAL here: arbitrary pytrees (custom nodes, device arrays)
need it, but plain nested dict/list/tuple trees of host arrays — the
monitor's snapshot format — flatten/unflatten through a pure-python
fallback with the same sorted-dict-key order jax uses, so the always-on
monitor checkpoints and recovers in the jax-free analysis layer.  The
on-disk format is identical either way.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

_JAX_UNSET = object()
_jax_mod: Any = _JAX_UNSET


def _jax():
    """jax if importable, else None — resolved on first USE, not at
    import, so the jax-free layer (the always-on monitor snapshots
    through this module) never pulls jax into the process."""
    global _jax_mod
    if _jax_mod is _JAX_UNSET:
        try:
            import jax as j
            _jax_mod = j
        except ImportError:
            _jax_mod = None
    return _jax_mod


Pytree = Any

_STEP_RE = re.compile(r"^step_(\d+)$")


def _is_plain(tree) -> bool:
    """Nested dict/list/tuple of host values: the tree shape the pure-
    python flattener handles.  Plain trees take the jax-free path even
    when jax IS installed (device arrays / custom nodes / None force the
    jax pytree machinery)."""
    if isinstance(tree, dict):
        return all(_is_plain(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return all(_is_plain(v) for v in tree)
    return isinstance(tree, (np.ndarray, np.generic, int, float, bool))


def _to_host(leaf) -> np.ndarray:
    if isinstance(leaf, (np.ndarray, np.generic, int, float, bool)):
        return np.asarray(leaf)
    j = _jax()
    if j is None:
        return np.asarray(leaf)
    return np.asarray(j.device_get(leaf))


def _flatten_plain(tree: Pytree, prefix: List[str],
                   out: List[Tuple[str, Any]]) -> None:
    """dict/list/tuple flattening matching jax's path order (dict keys
    sorted), so both flatteners produce the same manifest keys.

    Dict keys may not contain "/" — manifest keys are slash-joined
    paths, and a slashed key would silently restore as a nested dict in
    the template-free loader."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            if "/" in str(k):
                raise ValueError(f"checkpoint dict key {k!r} contains '/'")
            _flatten_plain(tree[k], prefix + [str(k)], out)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            _flatten_plain(v, prefix + [str(i)], out)
    else:
        out.append(("/".join(prefix), tree))


def _empty_containers(tree: Pytree, prefix: List[str],
                      out: List[Tuple[str, str]]) -> None:
    """Paths of empty dict/list/tuple nodes in a plain tree.

    An empty container produces no leaves, so without recording it the
    template-free loader would rebuild the tree WITHOUT that node — a
    round-trip that silently drops e.g. a counter-less store's
    ``"counters": {}``."""
    if isinstance(tree, dict):
        if not tree:
            out.append(("/".join(prefix), "dict"))
        for k in sorted(tree):
            _empty_containers(tree[k], prefix + [str(k)], out)
    elif isinstance(tree, (list, tuple)):
        if not tree:
            out.append(("/".join(prefix), "list"))
        for i, v in enumerate(tree):
            _empty_containers(v, prefix + [str(i)], out)


def _flatten_with_paths(tree: Pytree) -> List[Tuple[str, Any]]:
    if not _is_plain(tree):
        j = _jax()
        if j is None:
            raise TypeError("checkpoint tree has non-plain leaves and jax "
                            "is not importable")
        flat, _ = j.tree_util.tree_flatten_with_path(tree)
        out = []
        for path, leaf in flat:
            key = "/".join(_path_token(p) for p in path)
            out.append((key, leaf))
        return out
    out: List[Tuple[str, Any]] = []
    _flatten_plain(tree, [], out)
    return out


def _path_token(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for name in os.listdir(directory)
             if (m := _STEP_RE.match(name))
             and os.path.isfile(os.path.join(directory, name,
                                             "manifest.json"))]
    return max(steps) if steps else None


def save_checkpoint(directory: str, step: int, tree: Pytree,
                    *, extra_meta: Optional[dict] = None) -> str:
    """Synchronous atomic save. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten_with_paths(tree)
    manifest: Dict[str, Any] = {
        "step": step,
        "leaves": {},
        "meta": extra_meta or {},
    }
    if _is_plain(tree):
        empties: List[Tuple[str, str]] = []
        _empty_containers(tree, [], empties)
        if empties:
            manifest["empty"] = {path: kind for path, kind in empties}
    arrays: Dict[str, np.ndarray] = {}
    for i, (key, leaf) in enumerate(leaves):
        arr = _to_host(leaf)
        name = f"a{i}"
        arrays[name] = arr
        manifest["leaves"][key] = {
            "file": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(directory: str, step: int, like: Pytree,
                    *, shard_fn: Optional[Callable[[str, np.ndarray], Any]]
                    = None) -> Tuple[Pytree, dict]:
    """Load into the structure of ``like``; reshard via ``shard_fn(key, arr)``
    (e.g. ``lambda k, a: jax.device_put(a, shardings[k])``)."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz = np.load(os.path.join(path, "arrays.npz"))

    def pick(key: str, leaf) -> Any:
        ent = manifest["leaves"].get(key)
        if ent is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = npz[ent["file"]]
        want_shape = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {want_shape}")
        return shard_fn(key, arr) if shard_fn else arr

    if not _is_plain(like):
        j = _jax()
        if j is None:
            raise TypeError("checkpoint template has non-plain leaves and "
                            "jax is not importable")
        flat, treedef = j.tree_util.tree_flatten_with_path(like)
        out_leaves = [pick("/".join(_path_token(t) for t in p), leaf)
                      for p, leaf in flat]
        tree = j.tree_util.tree_unflatten(treedef, out_leaves)
    else:
        def rebuild(node, prefix):
            if isinstance(node, dict):
                return {k: rebuild(node[k], prefix + [str(k)]) for k in node}
            if isinstance(node, (list, tuple)):
                vals = [rebuild(v, prefix + [str(i)])
                        for i, v in enumerate(node)]
                return type(node)(vals)
            return pick("/".join(prefix), node)
        tree = rebuild(like, [])
    return tree, manifest.get("meta", {})


def load_checkpoint_tree(directory: str, step: int) -> Tuple[Pytree, dict]:
    """Template-free restore: rebuild the nested-dict tree straight from
    the manifest keys (split on "/").  No ``like`` structure is needed —
    the monitor's crash recovery uses this, since a cold aggregator knows
    nothing about the fleet it is restoring.  Trees saved from lists come
    back as dicts keyed by the stringified index."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz = np.load(os.path.join(path, "arrays.npz"))
    tree: Dict[str, Any] = {}
    for key, ent in manifest["leaves"].items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = npz[ent["file"]]
    for key, kind in manifest.get("empty", {}).items():
        child: Any = {} if kind == "dict" else []
        if key == "":
            return child, manifest.get("meta", {})
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = child
    return tree, manifest.get("meta", {})


class CheckpointManager:
    """Async keep-N manager with auto-resume."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ----------------------------------------------------------
    def save(self, step: int, tree: Pytree, *, blocking: bool = False,
             extra_meta: Optional[dict] = None) -> None:
        self.wait()                      # one in flight at a time
        if not _is_plain(tree):
            snapshot = _jax().tree.map(_to_host, tree)
        else:
            def _map(node):
                if isinstance(node, dict):
                    return {k: _map(v) for k, v in node.items()}
                if isinstance(node, (list, tuple)):
                    return type(node)(_map(v) for v in node)
                return _to_host(node)
            snapshot = _map(tree)

        def work():
            try:
                save_checkpoint(self.directory, step, snapshot,
                                extra_meta=extra_meta)
                self._gc()
            except BaseException as e:       # surfaced at next wait()
                self._error = e

        if blocking:
            work()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for name in os.listdir(self.directory)
            if (m := _STEP_RE.match(name)))
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore_latest(self, like: Pytree, *,
                       shard_fn: Optional[Callable] = None
                       ) -> Optional[Tuple[int, Pytree, dict]]:
        step = self.latest_step()
        if step is None:
            return None
        tree, meta = load_checkpoint(self.directory, step, like,
                                     shard_fn=shard_fn)
        return step, tree, meta
