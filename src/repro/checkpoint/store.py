"""Fault-tolerant checkpointing: atomic, keep-N, async, mesh-agnostic.

Design for 1000+ nodes:

* **Atomicity** — write to ``step_K.tmp/`` then ``os.rename`` to ``step_K/``;
  a crash mid-write never corrupts the latest checkpoint, and auto-resume
  scans only committed directories.
* **Mesh-agnostic layout** — arrays are saved *logically unsharded* (one npz
  per pytree leaf group); on load they are resharded to whatever mesh the
  restarted job runs with (elastic re-scaling: a 512-chip checkpoint
  restores fine on 256 chips or 1024).
* **Async** — ``save(...)`` snapshots to host memory synchronously (cheap)
  and writes in a background thread so the train loop never blocks on I/O;
  ``wait()`` joins at shutdown.  A failed async write is re-raised at the
  next call site so failures are not silent.
* **Keep-N GC** — old committed checkpoints beyond ``keep`` are removed
  after a successful commit, never before.
* **Integrity** — every leaf's shape/dtype is recorded in ``manifest.json``
  and verified on load; partial/foreign directories are rejected.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_paths(tree: Pytree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_token(p) for p in path)
        out.append((key, leaf))
    return out


def _path_token(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for name in os.listdir(directory)
             if (m := _STEP_RE.match(name))
             and os.path.isfile(os.path.join(directory, name,
                                             "manifest.json"))]
    return max(steps) if steps else None


def save_checkpoint(directory: str, step: int, tree: Pytree,
                    *, extra_meta: Optional[dict] = None) -> str:
    """Synchronous atomic save. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten_with_paths(tree)
    manifest: Dict[str, Any] = {
        "step": step,
        "leaves": {},
        "meta": extra_meta or {},
    }
    arrays: Dict[str, np.ndarray] = {}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        name = f"a{i}"
        arrays[name] = arr
        manifest["leaves"][key] = {
            "file": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(directory: str, step: int, like: Pytree,
                    *, shard_fn: Optional[Callable[[str, np.ndarray], Any]]
                    = None) -> Tuple[Pytree, dict]:
    """Load into the structure of ``like``; reshard via ``shard_fn(key, arr)``
    (e.g. ``lambda k, a: jax.device_put(a, shardings[k])``)."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out_leaves = []
    for p, leaf in flat:
        key = "/".join(_path_token(t) for t in p)
        ent = manifest["leaves"].get(key)
        if ent is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = npz[ent["file"]]
        want_shape = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {want_shape}")
        out_leaves.append(shard_fn(key, arr) if shard_fn else arr)
    tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
    return tree, manifest.get("meta", {})


class CheckpointManager:
    """Async keep-N manager with auto-resume."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ----------------------------------------------------------
    def save(self, step: int, tree: Pytree, *, blocking: bool = False,
             extra_meta: Optional[dict] = None) -> None:
        self.wait()                      # one in flight at a time
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, snapshot,
                                extra_meta=extra_meta)
                self._gc()
            except BaseException as e:       # surfaced at next wait()
                self._error = e

        if blocking:
            work()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for name in os.listdir(self.directory)
            if (m := _STEP_RE.match(name)))
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore_latest(self, like: Pytree, *,
                       shard_fn: Optional[Callable] = None
                       ) -> Optional[Tuple[int, Pytree, dict]]:
        step = self.latest_step()
        if step is None:
            return None
        tree, meta = load_checkpoint(self.directory, step, like,
                                     shard_fn=shard_fn)
        return step, tree, meta
