"""Ground-truth scenario bank: real-trace PPGs with injected root causes.

Public API (all jax-free at runtime; recording new traces needs jax —
``python -m repro.scenarios.record``):

  * :data:`SCENARIOS` / :func:`get_scenario` — the bank;
  * :class:`Scenario` / :class:`GroundTruth` / :class:`ScenarioResult`;
  * :func:`run_and_score` / :func:`score_result` / :class:`Score`;
  * :class:`StepTrace` / :func:`load_trace` / :func:`list_traces` /
    :func:`instantiate_psg` — the committed-trace layer;
  * the declarative fault kinds in :mod:`repro.scenarios.faults`.
"""
from repro.scenarios.bank import (SCENARIOS, SMOKE_SCENARIOS, GroundTruth,
                                  Scenario, ScenarioResult, get_scenario)
from repro.scenarios.faults import (FAULT_KINDS, BatchSkew, DataStall, Fault,
                                    FaultPlan, MoEImbalance, PipelineBubble,
                                    ProcSpec, SerialFraction, VertexSel)
from repro.scenarios.score import (Score, run_and_score, score_nodes,
                                   score_result)
from repro.scenarios.source import (CollectiveSpec, GroupPattern, StepTrace,
                                    classify_groups, instantiate_psg,
                                    list_traces, load_trace)

__all__ = [
    "SCENARIOS", "SMOKE_SCENARIOS", "GroundTruth", "Scenario",
    "ScenarioResult", "get_scenario",
    "FAULT_KINDS", "BatchSkew", "DataStall", "Fault", "FaultPlan",
    "MoEImbalance", "PipelineBubble", "ProcSpec", "SerialFraction",
    "VertexSel",
    "Score", "run_and_score", "score_nodes", "score_result",
    "CollectiveSpec", "GroupPattern", "StepTrace", "classify_groups",
    "instantiate_psg", "list_traces", "load_trace",
]
