"""Record real-model step traces for the scenario bank (needs jax).

``python -m repro.scenarios.record [names...]`` profiles jitted train /
decode steps of zoo models on forced host devices and writes committed
:class:`~repro.scenarios.source.StepTrace` JSON under
``scenarios/traces/`` — the bank then replays them without jax.

Per trace:

  * ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` is set BEFORE
    the first jax import (the ``launch/scaling_profile.py`` idiom) so a
    CPU-only host lowers genuinely multi-device GSPMD programs;
  * :class:`~repro.core.profiler.GraphProfiler` samples the real step —
    train state / KV cache stay RESIDENT in the profiler cell between
    steps (``_RESIDENT``), so re-recording reuses warm state instead of
    re-initializing per call;
  * the sharded step is lowered through
    :func:`repro.launch.shardings.build_cell` (a smoke-scale ``shape``
    override keeps compile time sane) and its compiled HLO walked with
    :func:`~repro.core.hlo_walk.analyze_hlo` /
    :func:`~repro.core.hlo.parse_collectives`; replica groups are
    classified into scale-free patterns (:func:`classify_groups`) and
    aggregated per (kind, pattern) into :class:`CollectiveSpec` rows.
"""
from __future__ import annotations

import os

N_DEVICES = int(os.environ.get("SCALANA_RECORD_DEVICES", "8"))
os.environ.setdefault(                         # before the first jax import
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={N_DEVICES}")

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.configs.base import RunConfig, ShapeConfig
from repro.core.hlo import parse_collectives
from repro.core.profiler import GraphProfiler
from repro.distributed import axes as ax
from repro.launch.mesh import make_host_mesh
from repro.launch.shardings import build_cell
from repro.models.api import build_model
from repro.optim.adamw import adamw_init
from repro.optim.schedule import constant
from repro.scenarios.source import (CollectiveSpec, GroupPattern, StepTrace,
                                    classify_groups, save_trace)
from repro.training.trainer import TrainState, make_train_step

# profile state resident between steps / between record calls: one warm
# (profiler, step args) cell per (arch, kind), the InferenceCache idiom —
# a second record of the same trace reuses the jitted step and state
_RESIDENT: Dict[Tuple[str, str], tuple] = {}

PROFILE_STEPS = 4
SAMPLE_EVERY = 2


def _collective_specs(hlo_text: str, n_devices: int) -> List[CollectiveSpec]:
    """Compiled HLO -> aggregated per-(kind, pattern) collective rows."""
    buckets: Dict[tuple, CollectiveSpec] = {}
    for order, op in enumerate(parse_collectives(hlo_text)):
        if op.p2p_pairs:
            pattern = GroupPattern("ring")
        else:
            pattern = classify_groups(op.replica_groups or [], n_devices)
        key = (op.kind, pattern.layout, pattern.size)
        spec = buckets.get(key)
        if spec is None:
            buckets[key] = CollectiveSpec(kind=op.kind, bytes=float(op.bytes),
                                          count=1, pattern=pattern,
                                          order=order)
        else:
            spec.bytes += float(op.bytes)
            spec.count += 1
    return sorted(buckets.values(), key=lambda c: c.order)


def _profile(key: Tuple[str, str], make_cell) -> GraphProfiler:
    """Run PROFILE_STEPS through a resident profiler cell."""
    cell = _RESIDENT.get(key)
    if cell is None:
        cell = _RESIDENT[key] = make_cell()
    prof, step_args, advance = cell
    for _ in range(PROFILE_STEPS):
        step_args = advance(prof, step_args)
    _RESIDENT[key] = (prof, step_args, advance)
    return prof


def record_train(name: str, arch: str, *, model_axis: int = 2) -> StepTrace:
    cfg = get_smoke(arch).replace(remat=False)
    mesh = make_host_mesh(model_axis=model_axis)
    run = RunConfig(arch=arch)
    B, S = 4, 32

    def make_cell():
        model = build_model(cfg)
        step_fn = make_train_step(model, run, constant(1e-3))
        with ax.use_rules(mesh):
            params = model.init(jax.random.PRNGKey(0))
            state = TrainState(params=params, opt=adamw_init(params),
                               residual=None, step=jnp.zeros((), jnp.int32))
        batch = {"tokens": jnp.ones((B, S + 1), jnp.int32)}
        prof = GraphProfiler(step_fn, (state, batch),
                             sample_every=SAMPLE_EVERY)

        def advance(prof, args):
            state, batch = args
            with ax.use_rules(mesh):
                state, _ = prof.step(state, batch)
            return (state, batch)

        return prof, (state, batch), advance

    prof = _profile((arch, "train"), make_cell)
    # collective mix of the SHARDED step, lowered through launch/shardings
    shape = ShapeConfig(name="train_smoke", seq_len=S, global_batch=B,
                        kind="train")
    cell = build_cell(arch, "train_4k", mesh, cfg=cfg, shape=shape,
                      donate=False)
    hlo = cell.lower().compile().as_text()
    perf = prof.perf_vectors()
    return StepTrace(
        name=name, arch=arch, kind="train", psg=prof.psg,
        base={vid: float(v.time) for vid, v in perf.items()},
        collectives=_collective_specs(hlo, len(jax.devices())),
        recorded_devices=len(jax.devices()),
        mesh={k: int(v) for k, v in mesh.shape.items()},
        meta={"sample_every": SAMPLE_EVERY, "profile_steps": PROFILE_STEPS,
              "batch": B, "seq": S})


def record_decode(name: str, arch: str, *, model_axis: int = 2) -> StepTrace:
    cfg = get_smoke(arch).replace(remat=False)
    mesh = make_host_mesh(model_axis=model_axis)
    B, S, PROMPT = 4, 16, 8

    def make_cell():
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jnp.ones((B, PROMPT), jnp.int32)
        _, cache = model.prefill(params, {"tokens": toks}, S)

        def serve_step(p, c, tok):
            return model.decode_step(p, c, tok)

        tok = jnp.ones((B, 1), jnp.int32)
        prof = GraphProfiler(serve_step, (params, cache, tok),
                             sample_every=SAMPLE_EVERY)

        def advance(prof, args):
            params, cache, tok = args
            _, cache = prof.step(params, cache, tok)
            return (params, cache, tok)

        return prof, (params, cache, tok), advance

    prof = _profile((arch, "decode"), make_cell)
    shape = ShapeConfig(name="decode_smoke", seq_len=S, global_batch=B,
                        kind="decode")
    cell = build_cell(arch, "decode_32k", mesh, cfg=cfg, shape=shape,
                      donate=False)
    hlo = cell.lower().compile().as_text()
    perf = prof.perf_vectors()
    return StepTrace(
        name=name, arch=arch, kind="decode", psg=prof.psg,
        base={vid: float(v.time) for vid, v in perf.items()},
        collectives=_collective_specs(hlo, len(jax.devices())),
        recorded_devices=len(jax.devices()),
        mesh={k: int(v) for k, v in mesh.shape.items()},
        meta={"sample_every": SAMPLE_EVERY, "profile_steps": PROFILE_STEPS,
              "batch": B, "cache_len": S, "prompt": PROMPT})


RECORDERS = {
    "tinyllama_train": lambda: record_train("tinyllama_train",
                                            "tinyllama-1.1b"),
    "moe_train": lambda: record_train("moe_train", "moonshot-v1-16b-a3b"),
    "tinyllama_decode": lambda: record_decode("tinyllama_decode",
                                              "tinyllama-1.1b"),
}


def main(names=None) -> None:
    for name in (names or sorted(RECORDERS)):
        trace = RECORDERS[name]()
        path = save_trace(trace)
        measured = len(trace.base)
        print(f"recorded {name}: {len(trace.psg.vertices)} vertices "
              f"({measured} measured), {len(trace.collectives)} collective "
              f"groups, step={trace.step_time() * 1e3:.1f}ms -> {path}")


if __name__ == "__main__":
    import sys
    main(sys.argv[1:] or None)
