"""Root-cause localization scoring for scenario runs.

Turns one :class:`~repro.scenarios.bank.ScenarioResult` into a
:class:`Score` — the three accuracy axes the bench table asserts:

  * ``precision``     — fraction of REPORTED root-cause nodes that hit the
    ground truth (the report is ``root_causes``'s top-k, with k = number
    of truth vertices by default — precision@k);
  * ``recall``        — fraction of truth VERTICES covered by a correct
    reported node;
  * ``path_hit_rate`` — fraction of backtrack paths that reach the
    planted cause: touch a truth VERTEX, or (when processes matter)
    touch a culprit PROCESS at any vertex.  The process clause is
    deliberate — a ring-bubble walk chains waits back to the straggler
    process and ends at its comm/tail vertices, which localizes the
    cause to the right process even when the max-time pred chain misses
    the injected vertex itself.  A walk that dies at the symptom scores
    0 on both clauses.

A reported node ``(proc, vid)`` is correct when ``vid`` is a truth vertex
AND — on scenarios where ``procs_matter`` — ``proc`` is in the culprit
set.  Degraded fleets (``proc_mask``) shrink the culprit set to its live
intersection first: a diagnosis cannot (and must not) report a dead
process.  Conventions at the edges, pinned by tests: an empty report has
precision 1.0 (nothing wrong was claimed) and, when truth survives the
mask, recall 0.0; an empty live-truth set scores 1.0 everywhere (there
is nothing left to find).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence, Tuple

import numpy as np

from repro.scenarios.bank import ScenarioResult

Node = Tuple[int, int]


@dataclasses.dataclass(frozen=True)
class Score:
    precision: float
    recall: float
    path_hit_rate: float
    n_reported: int
    n_truth: int

    def passes(self, truth) -> bool:
        """Against a :class:`~repro.scenarios.bank.GroundTruth`'s floors."""
        return (self.precision >= truth.min_precision
                and self.recall >= truth.min_recall
                and self.path_hit_rate >= truth.min_path_hit)

    def row(self) -> str:
        return (f"precision={self.precision:.3f} recall={self.recall:.3f} "
                f"path_hit={self.path_hit_rate:.3f} "
                f"reported={self.n_reported} truth={self.n_truth}")


def score_nodes(reported: Sequence[Node], truth_vids: Iterable[int],
                truth_procs: Optional[Sequence[int]],
                paths: Sequence[Sequence[Node]] = ()) -> Score:
    """Score a plain node list — the testable core of :func:`score_result`.

    ``truth_procs=None`` means process identity does not matter (the
    non-scalable channel).  ``paths`` are node sequences; a path hits
    when any of its nodes lies on a truth vertex.
    """
    tvids = set(int(v) for v in truth_vids)
    tprocs = None if truth_procs is None else set(
        int(p) for p in truth_procs)
    n_truth = len(tvids)
    if n_truth == 0 or (tprocs is not None and not tprocs):
        return Score(1.0, 1.0, 1.0, len(reported), n_truth)

    def correct(node: Node) -> bool:
        proc, vid = node
        return vid in tvids and (tprocs is None or proc in tprocs)

    hits = [n for n in reported if correct(n)]
    precision = len(hits) / len(reported) if reported else 1.0
    recall = len({vid for _, vid in hits}) / n_truth

    def path_hits(p: Sequence[Node]) -> bool:
        return any(vid in tvids for _, vid in p) or (
            tprocs is not None and any(proc in tprocs for proc, _ in p))

    path_hit = (sum(1 for p in paths if path_hits(p)) / len(paths)
                if paths else 0.0)
    return Score(precision, recall, path_hit, len(reported), n_truth)


def score_result(result: ScenarioResult,
                 proc_mask: Optional[np.ndarray] = None) -> Score:
    """Score one scenario run against its resolved ground truth.

    ``proc_mask`` (same (n_procs,) bool the run's detection used, if any)
    restricts the culprit set to live processes.
    """
    truth_procs: Optional[Sequence[int]] = result.truth_procs
    if not result.truth.procs_matter:
        truth_procs = None
    elif proc_mask is not None:
        live = np.flatnonzero(np.asarray(proc_mask, bool))
        truth_procs = np.intersect1d(result.truth_procs, live)
    return score_nodes([n for n, _, _ in result.reported],
                       result.truth_vids, truth_procs,
                       [list(p.nodes) for p in result.paths])


def run_and_score(scenario, n_procs: int, *, backend: str = "numpy",
                  seed: Optional[int] = None,
                  proc_mask: Optional[np.ndarray] = None
                  ) -> Tuple[ScenarioResult, Score]:
    """Convenience: one end-to-end run + its score."""
    result = scenario.run(n_procs, backend=backend, seed=seed,
                          proc_mask=proc_mask)
    return result, score_result(result, proc_mask=proc_mask)
